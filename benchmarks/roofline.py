"""Roofline report from the dry-run artifacts (paper deliverable g),
plus the live codec roofline (``--codec``, PR 7).

Three terms per (arch x shape x mesh), all in seconds per step:

  compute    = corrected_dot_flops_per_device / PEAK_FLOPS
  memory     = corrected_output_bytes_per_device / HBM_BW
  collective = corrected_wire_bytes_per_device / ICI_BW

FLOPs/bytes come from :mod:`benchmarks.hlo_analysis` (trip-count
corrected — see its docstring for why raw cost_analysis undercounts
scanned layers). Hardware constants per the brief (TPU v5e):
197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.

MODEL_FLOPS uses 6*N*D for training (N = active params, D = tokens) and
2*N*D for decode; the ratio MODEL_FLOPS / corrected-HLO-FLOPs shows how
much compiled compute is "useful".

``--codec`` models the homomorphic wire codec itself against the same
constants: bytes and FLOPs per bucket for the producer (sketch-encode +
bitmap-pack + maxabs/quantize) and consumer (unpack + dequant + peel),
and — the CI gate — counts the *stream passes* each backend's jaxpr
makes over the bucket stream: eqns touching a stream-sized operand,
layout ops excluded, control-flow wrappers recursed into.  The fused
Pallas wire kernels (``kernels/sketch_wire.py``) must show exactly ONE
producer and ONE consumer pass; the composed reference path shows the
2-3 separate passes it actually makes.  The normalized JSON
(``BENCH_roofline_codec.json``) also carries the bandwidth figures
``core/costmodel.priors_from_codec_report`` turns into ``auto_*``
priors — so the ``auto`` controller's analytic costs come from this
file's roofline, not a guess.
"""

from __future__ import annotations

import glob
import gzip
import json
import os
import statistics
import time
from typing import Dict, List, Optional

try:
    from . import hlo_analysis as ha
except ImportError:          # plain-script invocation: benchmarks/ on path
    import hlo_analysis as ha

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
MESH_CHIPS = {"single": 256, "multi": 512}


def model_flops(rec: dict) -> float:
    """Analytic MODEL_FLOPS for the whole cluster step."""
    n = rec["params_active"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n * tokens
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * rec["global_batch"]


def analyze_cell(path: str) -> Optional[dict]:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return rec
    hlo_path = path.replace(".json", ".hlo.gz")
    if not os.path.exists(hlo_path):
        rec["roofline"] = None
        return rec
    with gzip.open(hlo_path, "rt") as f:
        summ = ha.analyze(f.read())
    chips = MESH_CHIPS[rec["mesh"]]
    compute_s = summ.dot_flops / PEAK_FLOPS
    memory_s = summ.output_bytes / HBM_BW
    coll_s = summ.collective_wire_bytes() / ICI_BW
    mf = model_flops(rec)
    total_hlo = summ.dot_flops * chips
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]
    bound = max(compute_s, memory_s, coll_s)
    rec["roofline"] = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "bound_s": bound,
        # fraction of the roofline the useful work achieves if the step
        # ran exactly at the binding term
        "useful_fraction": (mf / chips / PEAK_FLOPS) / bound if bound else 0.0,
        "model_flops": mf,
        "hlo_flops_total": total_hlo,
        "model_over_hlo": mf / total_hlo if total_hlo else 0.0,
        "hlo_dot_flops_per_device": summ.dot_flops,
        "hlo_output_bytes_per_device": summ.output_bytes,
        "collectives_corrected": summ.collectives,
    }
    return rec


def report(mesh: str = "single", write: bool = True) -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        rec = analyze_cell(path)
        if rec is None:
            continue
        rows.append(rec)
        if write and rec.get("roofline"):
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    return rows


def table(mesh: str = "single") -> str:
    rows = report(mesh, write=True)
    out = [f"# Roofline — mesh={mesh} ({MESH_CHIPS[mesh]} chips)",
           "| arch | shape | status | compute_s | memory_s | collective_s |"
           " dominant | MODEL/HLO | useful_frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status'].upper()}"
                       f" | - | - | - | - | - | - |")
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {rf['compute_s']:.3e} | {rf['memory_s']:.3e} "
            f"| {rf['collective_s']:.3e} | {rf['dominant']} "
            f"| {rf['model_over_hlo']:.2f} | {rf['useful_fraction']:.2f} |")
    return "\n".join(out)


# ----------------------------------------------------------------------
# The live codec roofline (--codec, PR 7)
# ----------------------------------------------------------------------

# Layout/movement primitives: shape bookkeeping XLA fuses away, never an
# extra pass over the stream.
_LAYOUT_PRIMS = {
    "reshape", "broadcast_in_dim", "convert_element_type", "pad", "slice",
    "squeeze", "transpose", "copy", "concatenate", "dynamic_slice",
    "dynamic_update_slice", "bitcast_convert_type",
}
# Control-flow wrappers: count what runs inside, not the wrapper.
_WRAPPER_PRIMS = {
    "scan", "while", "cond", "pjit", "jit", "closed_call", "core_call",
    "custom_jvp_call", "custom_vjp_call", "custom_jvp_call_jaxpr",
    "custom_vjp_call_jaxpr", "remat", "remat2", "checkpoint", "named_call",
    "xla_call",
}


def _subjaxprs(eqn):
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for item in vs:
            if hasattr(item, "jaxpr"):        # ClosedJaxpr
                yield item.jaxpr
            elif hasattr(item, "eqns"):       # raw Jaxpr
                yield item


def count_stream_passes(jaxpr, stream_elems: int) -> int:
    """Number of non-layout eqns touching a stream-sized operand.

    The "pass count over the bucket stream": every eqn whose inputs or
    outputs include an array of >= ``stream_elems`` elements is one more
    time the stream crosses HBM.  Layout ops are excluded; control-flow
    wrappers are recursed into (their body runs, the wrapper doesn't);
    a ``pallas_call`` counts as ONE pass regardless of its kernel body
    (the body works on VMEM tiles — that is the entire point).
    """
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    n = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        touches = any(
            getattr(getattr(v, "aval", None), "size", 0) >= stream_elems
            for v in list(eqn.invars) + list(eqn.outvars))
        if name in _WRAPPER_PRIMS:
            n += sum(count_stream_passes(j, stream_elems)
                     for j in _subjaxprs(eqn))
            continue
        if name in _LAYOUT_PRIMS or not touches:
            continue
        n += 1
        # pallas_call: one pass, do not recurse into the tile body
    return n


def _median_wall_s(fn, iters: int) -> float:
    """Warmup once (compile), then median of ``iters`` blocked walls —
    the same methodology benchmarks/aggregation.py uses, so first-call
    compile noise never lands in a reported wall."""
    import jax
    jax.block_until_ready(fn())          # warmup + compile
    walls = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        walls.append(time.perf_counter() - t0)
    return statistics.median(walls)


def codec_report(n_buckets: int = 4, iters: int = 5,
                 wire_dtype: str = "f32") -> dict:
    """Model + measure the wire codec against the roofline constants.

    Builds a small bucket stream, traces the fused (``use_pallas=
    "always"``) and composed (``"never"``) producer/consumer ops, counts
    their jaxpr stream passes, and models bytes/FLOPs per bucket.  The
    composed leg is also wall-timed (median-of-``iters``); the fused leg
    is wall-timed only on a real TPU — interpret-mode Pallas is a
    Python-loop emulator whose wall says nothing about the kernel.
    """
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.config import CompressionConfig
    from repro.core import costmodel
    from repro.kernels import ops
    from repro.net.fixedpoint import FixedPointWire

    cfg0 = CompressionConfig(ratio=1.0, lanes=128, rows=6, rounds=10,
                             wire_dtype=wire_dtype)
    backend = jax.default_backend()
    on_tpu = backend == "tpu"
    quantized = wire_dtype == "fxp32"
    wire = FixedPointWire(workers=2)

    nbpb = 2                                  # blocks per bucket
    nb = n_buckets * nbpb
    stream_elems = nb * cfg0.block_elems
    rng = np.random.default_rng(0)
    x = np.where(rng.random(stream_elems) < 0.08,
                 rng.standard_normal(stream_elems), 0.0).astype(np.float32)
    xb = jnp.asarray(x.reshape(nb, cfg0.group, cfg0.lanes))
    ids = jnp.arange(nb, dtype=jnp.int32)

    # -- modeled bytes / FLOPs per bucket ------------------------------
    bucket_bytes = nbpb * cfg0.block_elems * 4
    sketch_bytes = nbpb * cfg0.rows * cfg0.lanes * 4
    words_bytes = nbpb * cfg0.block_elems // 8
    # encode contraction: (rows, G*3) x (G*3, c) per block, 2 FLOPs/MAC
    encode_flops = nbpb * 2 * cfg0.rows * (cfg0.group * 3) * cfg0.lanes
    # peel: `rounds` rounds of gather/scatter + the same-shape arithmetic
    peel_flops = encode_flops * cfg0.rounds

    def leg(policy: str) -> dict:
        cfg = _dc.replace(cfg0, use_pallas=policy)
        qkw = {}
        if quantized:
            mx0 = jnp.max(jnp.abs(xb), axis=(1, 2))
            qkw = dict(
                exponents=wire.exponents_from_maxabs(mx0),
                mantissa_bits=wire.mantissa_bits)

        def produce(v):
            return ops.encode_pack_quantize(v, ids, cfg, **qkw)

        sk, w2d, _ = jax.jit(produce)(xb)

        def consume(s, w):
            return ops.dequant_peel_unpack(s, w, ids, cfg, **qkw)

        prod_passes = count_stream_passes(
            jax.make_jaxpr(produce)(xb), stream_elems)
        cons_passes = count_stream_passes(
            jax.make_jaxpr(consume)(sk, w2d), stream_elems)
        row = {"use_pallas": policy,
               "producer_passes": prod_passes,
               "consumer_passes": cons_passes}
        if policy == "never" or on_tpu:
            jp = jax.jit(produce)
            jc = jax.jit(consume)
            row["producer_wall_s"] = _median_wall_s(lambda: jp(xb), iters)
            row["consumer_wall_s"] = _median_wall_s(
                lambda: jc(sk, w2d), iters)
            bps = stream_elems * 4 / (row["producer_wall_s"]
                                      + row["consumer_wall_s"])
            row["achieved_bytes_per_s"] = bps
            row["achieved_hbm_fraction"] = bps / HBM_BW
        return row

    fused = leg("always")
    composed = leg("never")
    # The fused kernels' one-pass roofline vs the composed passes, both
    # priced at the HBM bound (codec compute is bandwidth-shaped: the
    # MXU contraction is tiny next to the stream traffic).
    t_pass = bucket_bytes / HBM_BW
    measured = fused if on_tpu else composed
    achieved = measured.get("achieved_bytes_per_s")
    report = {
        "schema": 1,
        "backend": backend,
        "jax": jax.__version__,
        "wire_dtype": wire_dtype,
        "geometry": {
            "n_buckets": n_buckets, "blocks_per_bucket": nbpb,
            "block_elems": cfg0.block_elems, "rows": cfg0.rows,
            "lanes": cfg0.lanes, "stream_elems": stream_elems,
        },
        "per_bucket": {
            "gradient_bytes": bucket_bytes,
            "sketch_bytes": sketch_bytes,
            "index_bytes": words_bytes,
            "encode_flops": encode_flops,
            "peel_flops": peel_flops,
            "hbm_s_per_pass": t_pass,
            "mxu_s_encode": encode_flops / PEAK_FLOPS,
        },
        "passes": {"fused": {"producer": fused["producer_passes"],
                             "consumer": fused["consumer_passes"]},
                   "composed": {"producer": composed["producer_passes"],
                                "consumer": composed["consumer_passes"]}},
        "legs": {"fused": fused, "composed": composed},
        "hbm_bytes_per_s": HBM_BW,
        "ici_bytes_per_s": ICI_BW,
        "achieved_codec_bytes_per_s": achieved,
        "modeled_codec_s_per_bucket": {
            "fused": (fused["producer_passes"]
                      + fused["consumer_passes"]) * t_pass,
            "composed": (composed["producer_passes"]
                         + composed["consumer_passes"]) * t_pass,
        },
    }
    report["auto_priors"] = costmodel.priors_from_codec_report(report)
    return report


def codec_table(rep: dict) -> str:
    g = rep["geometry"]
    out = [f"# Codec roofline — backend={rep['backend']} "
           f"jax={rep['jax']} wire_dtype={rep['wire_dtype']}",
           f"stream: {g['n_buckets']} buckets x "
           f"{g['blocks_per_bucket']} blocks x {g['block_elems']} elems "
           f"= {g['stream_elems']} f32",
           "| leg | producer passes | consumer passes | wall_s |"
           " achieved B/s | HBM frac |",
           "|---|---|---|---|---|---|"]
    for name in ("fused", "composed"):
        leg = rep["legs"][name]
        wall = leg.get("producer_wall_s")
        wtxt = "-" if wall is None else \
            f"{wall + leg['consumer_wall_s']:.3e}"
        bps = leg.get("achieved_bytes_per_s")
        btxt = "-" if bps is None else f"{bps:.3e}"
        frac = leg.get("achieved_hbm_fraction")
        ftxt = "-" if frac is None else f"{frac:.4f}"
        out.append(f"| {name} | {leg['producer_passes']} "
                   f"| {leg['consumer_passes']} | {wtxt} | {btxt} "
                   f"| {ftxt} |")
    m = rep["modeled_codec_s_per_bucket"]
    out.append(f"modeled codec s/bucket @ HBM bound: "
               f"fused {m['fused']:.3e} vs composed {m['composed']:.3e}")
    pri = rep["auto_priors"]
    out.append(f"auto priors: codec {pri['auto_codec_gbps']:.1f} Gb/s, "
               f"link {pri['auto_link_gbps']:.1f} Gb/s")
    return "\n".join(out)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--codec", action="store_true",
                    help="report the wire-codec roofline (fused vs "
                         "composed stream passes) instead of the "
                         "dry-run artifact table")
    ap.add_argument("--codec-json", default=None,
                    help="write the normalized codec report here "
                         "(e.g. BENCH_roofline_codec.json)")
    ap.add_argument("--wire-dtype", default="f32",
                    choices=["f32", "fxp32"])
    ap.add_argument("--iters", type=int, default=5,
                    help="timed iterations per wall (median)")
    args = ap.parse_args()
    if args.codec:
        rep = codec_report(iters=args.iters, wire_dtype=args.wire_dtype)
        print(codec_table(rep))
        if args.codec_json:
            with open(args.codec_json, "w") as f:
                json.dump(rep, f, indent=1)
            print(f"wrote {args.codec_json}")
        return
    print(table(args.mesh))


if __name__ == "__main__":
    main()
