"""Roofline report from the dry-run artifacts (paper deliverable g).

Three terms per (arch x shape x mesh), all in seconds per step:

  compute    = corrected_dot_flops_per_device / PEAK_FLOPS
  memory     = corrected_output_bytes_per_device / HBM_BW
  collective = corrected_wire_bytes_per_device / ICI_BW

FLOPs/bytes come from :mod:`benchmarks.hlo_analysis` (trip-count
corrected — see its docstring for why raw cost_analysis undercounts
scanned layers). Hardware constants per the brief (TPU v5e):
197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.

MODEL_FLOPS uses 6*N*D for training (N = active params, D = tokens) and
2*N*D for decode; the ratio MODEL_FLOPS / corrected-HLO-FLOPs shows how
much compiled compute is "useful".
"""

from __future__ import annotations

import glob
import gzip
import json
import os
from typing import Dict, List, Optional

from . import hlo_analysis as ha

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
MESH_CHIPS = {"single": 256, "multi": 512}


def model_flops(rec: dict) -> float:
    """Analytic MODEL_FLOPS for the whole cluster step."""
    n = rec["params_active"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n * tokens
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * rec["global_batch"]


def analyze_cell(path: str) -> Optional[dict]:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return rec
    hlo_path = path.replace(".json", ".hlo.gz")
    if not os.path.exists(hlo_path):
        rec["roofline"] = None
        return rec
    with gzip.open(hlo_path, "rt") as f:
        summ = ha.analyze(f.read())
    chips = MESH_CHIPS[rec["mesh"]]
    compute_s = summ.dot_flops / PEAK_FLOPS
    memory_s = summ.output_bytes / HBM_BW
    coll_s = summ.collective_wire_bytes() / ICI_BW
    mf = model_flops(rec)
    total_hlo = summ.dot_flops * chips
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]
    bound = max(compute_s, memory_s, coll_s)
    rec["roofline"] = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "bound_s": bound,
        # fraction of the roofline the useful work achieves if the step
        # ran exactly at the binding term
        "useful_fraction": (mf / chips / PEAK_FLOPS) / bound if bound else 0.0,
        "model_flops": mf,
        "hlo_flops_total": total_hlo,
        "model_over_hlo": mf / total_hlo if total_hlo else 0.0,
        "hlo_dot_flops_per_device": summ.dot_flops,
        "hlo_output_bytes_per_device": summ.output_bytes,
        "collectives_corrected": summ.collectives,
    }
    return rec


def report(mesh: str = "single", write: bool = True) -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        rec = analyze_cell(path)
        if rec is None:
            continue
        rows.append(rec)
        if write and rec.get("roofline"):
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    return rows


def table(mesh: str = "single") -> str:
    rows = report(mesh, write=True)
    out = [f"# Roofline — mesh={mesh} ({MESH_CHIPS[mesh]} chips)",
           "| arch | shape | status | compute_s | memory_s | collective_s |"
           " dominant | MODEL/HLO | useful_frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status'].upper()}"
                       f" | - | - | - | - | - | - |")
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {rf['compute_s']:.3e} | {rf['memory_s']:.3e} "
            f"| {rf['collective_s']:.3e} | {rf['dominant']} "
            f"| {rf['model_over_hlo']:.2f} | {rf['useful_fraction']:.2f} |")
    return "\n".join(out)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    print(table(args.mesh))


if __name__ == "__main__":
    main()
