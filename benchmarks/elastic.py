"""Elastic aggregation benchmark (PR 9): synchronous barrier vs async
sketch-fold at intermittent-client cohorts.

The paper's aggregation point never decompresses in flight: sketches
merge by integer/float add and bitmaps by OR, so a payload can be folded
the moment it arrives. This benchmark measures what that buys once
clients arrive at different times (Poisson arrivals + injected
stragglers via ``ft.failures.FailureSimulator``): the **barrier** arm
holds every payload until the last arrival and then folds all W of them
(the synchronous psum shape), while the **async** arm folds each payload
on arrival, leaving only one fold + finalize after the last arrival.
Both arms run the *same* ``FoldEngine`` code and must produce bitwise
identical streams — the contrast is purely *when* the fold work happens.

Fold throughput is normalized to the close-out tail: folded bytes
divided by the compute remaining after the last folded arrival. That is
the round's critical path — arrival gaps hide the async arm's folds but
cannot hide the barrier's — and it is robust to timer noise (the barrier
tail carries W measured folds vs the async arm's one).

Writes ``BENCH_elastic.json`` and enforces the CI gate in-process:
async fold throughput must strictly exceed the barrier baseline at
cohort >= 64.

    PYTHONPATH=src python benchmarks/elastic.py --json BENCH_elastic.json
"""
import argparse
import dataclasses
import json
import time

import numpy as np

from repro.core.bucketing import make_bucket_plan
from repro.core.config import CompressionConfig
from repro.elastic import ElasticClient, FoldEngine, negotiate_contract
from repro.ft.failures import FailureSimulator, SwitchRetransmitPolicy

CFG = CompressionConfig(ratio=1.0, lanes=128, rows=6, rounds=10,
                        chunk_blocks=8, topk_ratio=0.1, topk_exact=True,
                        error_feedback=True, bucket_bytes=2 * 768 * 4)
SHAPES = {"w": (4000,)}
TEMPLATE = {k: np.zeros(sh, np.float32) for k, sh in SHAPES.items()}
POOL = 4          # distinct encoded payloads, reused cyclically: setup
                  # stays O(1) while the fold loop still sees W clients


def _grad_tree(seed):
    r = np.random.default_rng(seed)
    return {k: r.normal(0, 1, sh).astype(np.float32)
            for k, sh in SHAPES.items()}


def _payload_pool(contract, cfg):
    """POOL distinct payloads; cohort slot w reuses pool[w % POOL]."""
    clients = [ElasticClient(w, cfg) for w in range(POOL)]
    if cfg.wire_dtype == "fxp32":
        props = [clients[w].propose(contract, _grad_tree(w))
                 for w in range(POOL)]
        shared = props[0].exponents
        for p in props[1:]:
            shared = np.maximum(shared, p.exponents)
        pool = [clients[w].payload(
            contract, dataclasses.replace(
                props[w], exponents=np.asarray(shared)).exponents)
            for w in range(POOL)]
        return pool, [p.exponents for p in props], np.asarray(shared)
    pool = [clients[w].contribute(contract, _grad_tree(w))
            for w in range(POOL)]
    return pool, None, None


def _arrivals(workers, sim, deadline):
    """Poisson arrival times + injected straggler delays; returns
    (arrival_s per client, folded client list in arrival order,
    deferred client list)."""
    rng = np.random.default_rng(workers)
    base = rng.exponential(scale=0.002, size=workers).cumsum()
    arr = np.array([base[w] + sim.client_delay(0, w)
                    for w in range(workers)])
    folded = sorted((w for w in range(workers) if arr[w] <= deadline),
                    key=lambda w: arr[w])
    deferred = [w for w in range(workers) if arr[w] > deadline]
    return arr, folded, deferred


def _run_arm(engine, pool, order, delays, proposals, shared):
    """Fold `order` into a fresh state, timing each fold; returns
    (stream, per-fold seconds, finalize seconds, retransmits).

    ``delays[w]`` is the client's *lateness* into its aggregation window
    (the injected straggle), which is what the retransmit policy prices
    — not the absolute Poisson arrival time.
    """
    policy = SwitchRetransmitPolicy(timeout_s=0.05, max_retries=64)
    st = engine.init_state()
    if proposals is not None:
        for w in order:
            engine.propose_exponents(st, w, proposals[w % POOL])
        sealed = engine.seal_exponents(st)
        assert np.array_equal(np.asarray(sealed), shared)
    fold_s = []
    for w in order:
        p = dataclasses.replace(pool[w % POOL], client=w)
        t0 = time.perf_counter()
        engine.fold(st, p, arrival_s=float(delays[w]), policy=policy)
        fold_s.append(time.perf_counter() - t0)
    t0 = time.perf_counter()
    stream = engine.finalize(st)
    return stream, fold_s, time.perf_counter() - t0, st.retransmits


def bench_cohort(workers, cfg=CFG):
    plan = make_bucket_plan(TEMPLATE, cfg)
    contract = negotiate_contract(0, range(workers), plan, cfg)
    engine = FoldEngine(contract, cfg)
    pool, proposals, shared = _payload_pool(contract, cfg)
    payload_bytes = pool[0].nbytes

    # one mid-delay straggler (pays retransmits, still folds) and one
    # past-deadline straggler (deferred into the next round's residual)
    deadline = 0.002 * workers + 0.25
    sim = FailureSimulator(straggle_s=((1 % workers, 0.12),),
                           straggle_at=((0, 2 % workers, deadline + 1.0),))
    arrivals, folded, deferred = _arrivals(workers, sim, deadline)
    last_arrival = max(arrivals[w] for w in folded)
    delays = [sim.client_delay(0, w) for w in range(workers)]

    # warmup: compile/caches for fold + finalize (recover's peel is
    # jitted), so both timed arms see steady-state costs; cover every
    # pool slot so the fxp32 warm round seals the pool-wide exponents
    warm, seen = [], set()
    for w in folded:
        if w % POOL not in seen:
            seen.add(w % POOL)
            warm.append(w)
    _run_arm(engine, pool, warm, delays, proposals, shared)

    out_async, folds_a, fin_a, retr_a = _run_arm(
        engine, pool, folded, delays, proposals, shared)
    out_barrier, folds_b, fin_b, retr_b = _run_arm(
        engine, pool, folded, delays, proposals, shared)
    assert np.array_equal(out_async, out_barrier), \
        "async fold and barrier fold must be the same aggregate"
    assert retr_a == retr_b and retr_a > 0, "straggler must pay retransmits"

    folded_bytes = payload_bytes * len(folded)
    # fold tail: fold compute still pending after the last folded
    # arrival. Async: one fold (arrival gaps hid the rest); barrier:
    # all of them. The finalize pass is identical in both arms and
    # lands in close-out latency, not fold throughput — so the gate
    # margin is ~W x and cannot flip on timer noise.
    tail_async = folds_a[-1]
    tail_barrier = sum(folds_b)

    def arm(tail, fin):
        return {"fold_tail_s": round(tail, 6),
                "finalize_s": round(fin, 6),
                "close_out_latency_s": round(float(last_arrival)
                                             + tail + fin, 4),
                "fold_throughput_bytes_per_s": round(
                    folded_bytes / tail)}

    row = {"workers": workers, "wire": cfg.wire_dtype,
           "payload_bytes": payload_bytes,
           "folded": len(folded), "deferred": len(deferred),
           "retransmits": retr_a,
           "last_arrival_s": round(float(last_arrival), 4),
           "async": arm(tail_async, fin_a),
           "barrier": arm(tail_barrier, fin_b)}
    print(f"W={workers:4d} {cfg.wire_dtype:5s} folded={len(folded):4d} "
          f"deferred={len(deferred)} retransmits={retr_a:3d} | "
          f"async fold tail {tail_async*1e6:8.1f}us vs barrier "
          f"{tail_barrier*1e6:9.1f}us -> {tail_barrier/tail_async:6.1f}x")
    return row


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_elastic.json")
    ap.add_argument("--cohorts", type=int, nargs="*",
                    default=[8, 64, 512])
    args = ap.parse_args()

    rows = [bench_cohort(w) for w in args.cohorts]
    # fxp32 leg: same contrast over the integer wire at the base cohort
    fxp_row = bench_cohort(8, dataclasses.replace(CFG, wire_dtype="fxp32"))

    payload = {"schema": 1, "cohorts": {str(r["workers"]): r
                                        for r in rows},
               "fxp32": fxp_row}
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.json}")

    # CI gate (also re-checked from the artifact by the workflow):
    # at cohort >= 64 the async fold must strictly beat the barrier.
    for r in rows:
        if r["workers"] >= 64:
            a = r["async"]["fold_throughput_bytes_per_s"]
            b = r["barrier"]["fold_throughput_bytes_per_s"]
            if not a > b:
                raise SystemExit(
                    f"GATE FAIL: async fold throughput {a} <= barrier "
                    f"{b} at cohort {r['workers']}")
            print(f"GATE OK: W={r['workers']} async {a:.3g} B/s > "
                  f"barrier {b:.3g} B/s")


if __name__ == "__main__":
    main()
