"""Elastic aggregation benchmark (PR 9/10): synchronous barrier vs
async sketch-fold, and the sharded+batched fold pipeline's scale-out.

The paper's aggregation point never decompresses in flight: sketches
merge by integer/float add and bitmaps by OR, so a payload can be folded
the moment it arrives — and the fold can be partitioned (shards) and
amortized (microbatches) without changing a bit of the result.

Two experiments:

1. **Barrier vs async** (PR 9): Poisson arrivals + injected stragglers
   via ``ft.failures.FailureSimulator``. The **barrier** arm holds every
   payload until the last arrival and then folds all W of them (the
   synchronous psum shape); the **async** arm folds each payload on
   arrival, leaving only one fold + finalize after the last arrival.
   Both arms run the *same* ``FoldEngine`` schedule and must produce
   bitwise identical streams — the contrast is purely *when* the fold
   work happens. Fold throughput is normalized to the close-out tail
   (folded bytes / fold compute remaining after the last folded
   arrival).
2. **Sharded scale-out** (PR 10): cohort 512 on the fxp32 wire through
   ``ShardedFoldService`` at a shard-count sweep. Shards model
   independent hosts — each shard range folds only its stripe — so the
   round's fold wall is the **critical path**: the max over per-shard
   fold walls (each measured on this host, charged only to its shard).
   The microbatched combine (one jit-cached dispatch per ``batch_size``
   arrivals, host int64 register check per flush) is what the
   per-payload PR 9 walk is compared against; the sharded stream is
   asserted bitwise equal to the sequential fold before any timing
   counts.

Timing discipline (PR 7, as in ``benchmarks/aggregation.py``): two
warmup runs (compile + lazy first-dispatch), then median-of-k walls per
arm — gates track steady state, not compile noise.

Writes ``BENCH_elastic.json`` (schema 2: per-shard throughput rows +
the shard sweep) and enforces the CI gates in-process: async fold must
strictly beat the barrier at cohort >= 64, the S=4 sharded fold must be
>= 2x the single-engine fold at cohort 512, and the sweep must be
monotone non-decreasing up to the host's core count.

    PYTHONPATH=src python benchmarks/elastic.py --json BENCH_elastic.json
"""
import argparse
import dataclasses
import json
import os
import statistics
import time

import numpy as np

from repro.core.bucketing import make_bucket_plan
from repro.core.config import CompressionConfig
from repro.elastic import (ElasticClient, FoldEngine, ShardedFoldService,
                           negotiate_contract)
from repro.ft.failures import FailureSimulator, SwitchRetransmitPolicy

CFG = CompressionConfig(ratio=1.0, lanes=128, rows=6, rounds=10,
                        chunk_blocks=8, topk_ratio=0.1, topk_exact=True,
                        error_feedback=True, bucket_bytes=2 * 768 * 4)
SHAPES = {"w": (4000,)}
TEMPLATE = {k: np.zeros(sh, np.float32) for k, sh in SHAPES.items()}
# the sharded sweep folds a much larger stream (128 buckets), so a
# shard range is real work and the critical-path contrast is honest
SHARD_SHAPES = {"w": (196608,)}
SHARD_TEMPLATE = {k: np.zeros(sh, np.float32)
                  for k, sh in SHARD_SHAPES.items()}
POOL = 4          # distinct encoded payloads, reused cyclically: setup
                  # stays O(1) while the fold loop still sees W clients
WARMUPS = 2
REPS = 3


def _grad_tree(seed, shapes=SHAPES):
    r = np.random.default_rng(seed)
    return {k: r.normal(0, 1, sh).astype(np.float32)
            for k, sh in shapes.items()}


def _payload_pool(contract, cfg, shapes=SHAPES):
    """POOL distinct payloads; cohort slot w reuses pool[w % POOL]."""
    clients = [ElasticClient(w, cfg) for w in range(POOL)]
    if cfg.wire_dtype == "fxp32":
        props = [clients[w].propose(contract, _grad_tree(w, shapes))
                 for w in range(POOL)]
        shared = props[0].exponents
        for p in props[1:]:
            shared = np.maximum(shared, p.exponents)
        pool = [clients[w].payload(
            contract, dataclasses.replace(
                props[w], exponents=np.asarray(shared)).exponents)
            for w in range(POOL)]
        return pool, [p.exponents for p in props], np.asarray(shared)
    pool = [clients[w].contribute(contract, _grad_tree(w, shapes))
            for w in range(POOL)]
    return pool, None, None


def _arrivals(workers, sim, deadline):
    """Poisson arrival times + injected straggler delays; returns
    (arrival_s per client, folded client list in arrival order,
    deferred client list)."""
    rng = np.random.default_rng(workers)
    base = rng.exponential(scale=0.002, size=workers).cumsum()
    arr = np.array([base[w] + sim.client_delay(0, w)
                    for w in range(workers)])
    folded = sorted((w for w in range(workers) if arr[w] <= deadline),
                    key=lambda w: arr[w])
    deferred = [w for w in range(workers) if arr[w] > deadline]
    return arr, folded, deferred


def _run_arm(engine, pool, order, delays, proposals, shared):
    """Fold `order` into a fresh state, timing each fold; returns
    (stream, per-fold seconds, finalize seconds, retransmits).

    ``delays[w]`` is the client's *lateness* into its aggregation window
    (the injected straggle), which is what the retransmit policy prices
    — not the absolute Poisson arrival time.
    """
    policy = SwitchRetransmitPolicy(timeout_s=0.05, max_retries=64)
    st = engine.init_state()
    if proposals is not None:
        for w in order:
            engine.propose_exponents(st, w, proposals[w % POOL])
        sealed = engine.seal_exponents(st)
        assert np.array_equal(np.asarray(sealed), shared)
    fold_s = []
    for w in order:
        p = dataclasses.replace(pool[w % POOL], client=w)
        t0 = time.perf_counter()
        engine.fold(st, p, arrival_s=float(delays[w]), policy=policy)
        fold_s.append(time.perf_counter() - t0)
    t0 = time.perf_counter()
    stream = engine.finalize(st)
    return stream, fold_s, time.perf_counter() - t0, st.retransmits


def bench_cohort(workers, cfg=CFG):
    plan = make_bucket_plan(TEMPLATE, cfg)
    contract = negotiate_contract(0, range(workers), plan, cfg)
    engine = FoldEngine(contract, cfg)
    pool, proposals, shared = _payload_pool(contract, cfg)
    payload_bytes = pool[0].nbytes

    # one mid-delay straggler (pays retransmits, still folds) and one
    # past-deadline straggler (deferred into the next round's residual)
    deadline = 0.002 * workers + 0.25
    sim = FailureSimulator(straggle_s=((1 % workers, 0.12),),
                           straggle_at=((0, 2 % workers, deadline + 1.0),))
    arrivals, folded, deferred = _arrivals(workers, sim, deadline)
    last_arrival = max(arrivals[w] for w in folded)
    delays = [sim.client_delay(0, w) for w in range(workers)]

    # PR 7 discipline: both arms run the SAME fold schedule (they
    # differ only in which folds land in the close-out tail), so one
    # rep sequence serves both — 2 warmups (the first also compiles
    # fold + finalize; it covers every pool slot so the fxp32 warm
    # round seals the pool-wide exponents), then median-of-REPS.
    warm, seen = [], set()
    for w in folded:
        if w % POOL not in seen:
            seen.add(w % POOL)
            warm.append(w)
    _run_arm(engine, pool, warm, delays, proposals, shared)
    for _ in range(WARMUPS - 1):
        _run_arm(engine, pool, folded, delays, proposals, shared)

    reps = [_run_arm(engine, pool, folded, delays, proposals, shared)
            for _ in range(REPS)]
    out0, _, _, retr = reps[0]
    for out_r, _, _, retr_r in reps[1:]:
        assert np.array_equal(out0, out_r), \
            "every rep of the fold schedule must be the same aggregate"
        assert retr_r == retr
    assert retr > 0, "straggler must pay retransmits"

    folded_bytes = payload_bytes * len(folded)
    # fold tail: fold compute still pending after the last folded
    # arrival. Async: one fold (arrival gaps hid the rest); barrier:
    # all of them. The finalize pass is identical in both arms and
    # lands in close-out latency, not fold throughput — so the gate
    # margin is ~W x and cannot flip on timer noise.
    tail_async = statistics.median(folds[-1] for _, folds, _, _ in reps)
    tail_barrier = statistics.median(sum(folds)
                                     for _, folds, _, _ in reps)
    fin = statistics.median(f for _, _, f, _ in reps)

    def arm(tail):
        return {"fold_tail_s": round(tail, 6),
                "finalize_s": round(fin, 6),
                "close_out_latency_s": round(float(last_arrival)
                                             + tail + fin, 4),
                "fold_throughput_bytes_per_s": round(
                    folded_bytes / tail)}

    row = {"workers": workers, "wire": cfg.wire_dtype,
           "payload_bytes": payload_bytes,
           "folded": len(folded), "deferred": len(deferred),
           "retransmits": retr,
           "warmups": WARMUPS, "reps": REPS,
           "last_arrival_s": round(float(last_arrival), 4),
           "async": arm(tail_async),
           "barrier": arm(tail_barrier)}
    print(f"W={workers:4d} {cfg.wire_dtype:5s} folded={len(folded):4d} "
          f"deferred={len(deferred)} retransmits={retr:3d} | "
          f"async fold tail {tail_async*1e6:8.1f}us vs barrier "
          f"{tail_barrier*1e6:9.1f}us -> {tail_barrier/tail_async:6.1f}x "
          f"(median of {REPS})")
    return row


# ----------------------------------------------------------------------
# Sharded scale-out sweep (PR 10)
# ----------------------------------------------------------------------

def _run_sequential(engine, pool, workers, proposals, shared):
    """One full PR 9 single-engine fold round; returns (stream, total
    fold wall)."""
    st = engine.init_state()
    if proposals is not None:
        for w in range(workers):
            engine.propose_exponents(st, w, proposals[w % POOL])
        engine.seal_exponents(st)
    wall = 0.0
    for w in range(workers):
        p = dataclasses.replace(pool[w % POOL], client=w)
        t0 = time.perf_counter()
        engine.fold(st, p)
        wall += time.perf_counter() - t0
    return engine.finalize(st), wall


def _run_sharded(svc, pool, workers, proposals, shared):
    """One sharded+batched round; returns (stream, per-shard fold
    walls). Each shard's wall accumulates only that shard's microbatch
    flushes — on a real deployment the shards are separate hosts, so
    the round's fold wall is the max, not the sum."""
    st = svc.init_state()
    if proposals is not None:
        for w in range(workers):
            svc.propose_exponents(st, w, proposals[w % POOL])
        svc.seal_exponents(st)
    for w in range(workers):
        svc.fold(st, dataclasses.replace(pool[w % POOL], client=w))
    svc.flush(st)                    # drain remainders into the walls
    stream = svc.finalize(st)
    return stream, list(st.fold_s), svc.per_shard_report(st)


def bench_sharded(workers=512, shards=(1, 2, 4, 8), batch_size=8):
    """Shard-count sweep at one cohort on the fxp32 wire (the eager
    batched integer combine; the wire the switch actually has)."""
    cfg = dataclasses.replace(CFG, wire_dtype="fxp32")
    plan = make_bucket_plan(SHARD_TEMPLATE, cfg)
    contract = negotiate_contract(0, range(workers), plan, cfg)
    pool, proposals, shared = _payload_pool(contract, cfg, SHARD_SHAPES)
    payload_bytes = pool[0].nbytes
    folded_bytes = payload_bytes * workers

    print(f"sharded sweep: W={workers} fxp32, {plan.n_buckets} buckets "
          f"x {plan.bucket_elems} elems, batch={batch_size}, "
          f"payload {payload_bytes/1e6:.2f} MB")

    # PR 9 single-engine baseline, same discipline
    engine = FoldEngine(contract, cfg)
    for _ in range(WARMUPS):
        ref_stream, _ = _run_sequential(engine, pool, workers,
                                        proposals, shared)
    seq_reps = [_run_sequential(engine, pool, workers, proposals,
                                shared) for _ in range(REPS)]
    seq_wall = statistics.median(w for _, w in seq_reps)
    single = {"fold_wall_s": round(seq_wall, 6),
              "fold_throughput_bytes_per_s": round(
                  folded_bytes / seq_wall)}
    print(f"  single-engine: {seq_wall*1e3:8.1f}ms fold wall "
          f"-> {single['fold_throughput_bytes_per_s']/1e9:6.2f} GB/s")

    sweep = []
    for S in shards:
        svc = ShardedFoldService(contract, cfg, n_shards=S,
                                 batch_size=batch_size, plan=plan)
        for _ in range(WARMUPS):
            stream, walls, _ = _run_sharded(svc, pool, workers,
                                            proposals, shared)
        assert np.array_equal(stream, ref_stream), \
            f"S={S}: sharded fold is not the sequential aggregate"
        rep_runs = [_run_sharded(svc, pool, workers, proposals, shared)
                    for _ in range(REPS)]
        crit = [max(walls) for _, walls, _ in rep_runs]
        med = statistics.median(crit)
        # per-shard rows from the median rep
        med_rep = rep_runs[crit.index(
            sorted(crit)[len(crit) // 2])]
        row = {"shards": S, "batch_size": batch_size,
               "critical_path_s": round(med, 6),
               "fold_throughput_bytes_per_s": round(folded_bytes / med),
               "speedup_vs_single_engine": round(seq_wall / med, 2),
               "per_shard": [
                   {k: (round(v, 6) if isinstance(v, float) else v)
                    for k, v in r.items()}
                   for r in med_rep[2]]}
        sweep.append(row)
        print(f"  S={S}: critical path {med*1e3:8.1f}ms "
              f"-> {row['fold_throughput_bytes_per_s']/1e9:6.2f} GB/s "
              f"({row['speedup_vs_single_engine']:5.1f}x single engine)")

    return {"workers": workers, "wire": "fxp32",
            "batch_size": batch_size,
            "n_buckets": plan.n_buckets,
            "payload_bytes": payload_bytes,
            "host_cores": os.cpu_count() or 1,
            "warmups": WARMUPS, "reps": REPS,
            "single_engine": single, "sweep": sweep}


def check_shard_gates(sharded):
    """The PR 10 CI gates (also re-checked from the artifact): S=4
    sharded fold >= 2x the single-engine fold, and the sweep monotone
    non-decreasing up to the host's core count."""
    base = sharded["single_engine"]["fold_throughput_bytes_per_s"]
    rows = sorted(sharded["sweep"], key=lambda r: r["shards"])
    s4 = next((r for r in rows if r["shards"] == 4), None)
    if s4 is not None:
        t4 = s4["fold_throughput_bytes_per_s"]
        if not t4 >= 2 * base:
            raise SystemExit(
                f"GATE FAIL: S=4 sharded fold {t4} B/s < 2x "
                f"single-engine {base} B/s at cohort "
                f"{sharded['workers']}")
        print(f"GATE OK: S=4 sharded {t4:.3g} B/s >= 2x single-engine "
              f"{base:.3g} B/s")
    cores = sharded["host_cores"]
    in_core = [r for r in rows if r["shards"] <= cores]
    for lo, hi in zip(in_core, in_core[1:]):
        if hi["fold_throughput_bytes_per_s"] < \
                lo["fold_throughput_bytes_per_s"]:
            raise SystemExit(
                f"GATE FAIL: sweep not monotone within the core count "
                f"({cores}): S={hi['shards']} "
                f"{hi['fold_throughput_bytes_per_s']} < S={lo['shards']} "
                f"{lo['fold_throughput_bytes_per_s']}")
    print(f"GATE OK: sweep monotone non-decreasing up to "
          f"{cores} core(s) ({len(in_core)} row(s) in range)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_elastic.json")
    ap.add_argument("--cohorts", type=int, nargs="*",
                    default=[8, 64, 512])
    ap.add_argument("--shards", type=int, nargs="*",
                    default=[1, 2, 4, 8])
    ap.add_argument("--sharded-workers", type=int, default=512)
    ap.add_argument("--batch-size", type=int, default=8)
    args = ap.parse_args()

    rows = [bench_cohort(w) for w in args.cohorts]
    # fxp32 leg: same contrast over the integer wire at the base cohort
    fxp_row = bench_cohort(8, dataclasses.replace(CFG, wire_dtype="fxp32"))
    sharded = bench_sharded(args.sharded_workers, tuple(args.shards),
                            args.batch_size)

    payload = {"schema": 2, "cohorts": {str(r["workers"]): r
                                        for r in rows},
               "fxp32": fxp_row, "sharded": sharded}
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.json}")

    # CI gates (also re-checked from the artifact by the workflow):
    # at cohort >= 64 the async fold must strictly beat the barrier.
    for r in rows:
        if r["workers"] >= 64:
            a = r["async"]["fold_throughput_bytes_per_s"]
            b = r["barrier"]["fold_throughput_bytes_per_s"]
            if not a > b:
                raise SystemExit(
                    f"GATE FAIL: async fold throughput {a} <= barrier "
                    f"{b} at cohort {r['workers']}")
            print(f"GATE OK: W={r['workers']} async {a:.3g} B/s > "
                  f"barrier {b:.3g} B/s")
    check_shard_gates(sharded)


if __name__ == "__main__":
    main()
