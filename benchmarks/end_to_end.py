"""Paper Fig. 7 analogue: per-iteration speedup model for the four
evaluation models, at the paper's fixed 10% compressed size.

We cannot re-run their A100/2080 clusters, so we model one iteration as

    t_iter = t_compute + max(t_codec, t_wire)

with t_wire from the ring-allreduce byte model at the paper's link
bandwidths (100 Gbps NCCL cluster, 10 Gbps ATP cluster), t_codec from the
measured CPU codec scaled per-element, and t_compute chosen such that the
*dense baseline* matches the paper's stated compute/communication balance
(e.g. "aggregation takes almost half the time" for BERT on 8 workers).
The derived speedups are then compared against the paper's reported
2.01x / 1.31x / 3.45x / 1.17x (NCCL) numbers.
"""

from __future__ import annotations

from typing import Dict

from repro.core import CompressionConfig

MODELS = {  # params, sparsity(zeros frac), paper speedup (NCCL, ATP)
    "NCF": (29.7e6, 0.989, 2.01, 3.33),
    "LSTM": (426e6, 0.945, 1.31, 3.37),
    "VGG19": (140e6, 0.304, 3.45, 3.74),
    "BERT-base": (109e6, 0.208, 1.17, 1.17),
}
# compute:comm ratio of the dense baseline per model (BERT ~ 50/50 per the
# paper's intro; comm share grows with gradient size / compute density)
DENSE_COMM_SHARE = {"NCF": 0.65, "LSTM": 0.55, "VGG19": 0.75,
                    "BERT-base": 0.5}
CODEC_GBPS = 80.0        # measured-on-CPU order; TPU/GPU codecs are >5x


def model_iteration(model: str, link_gbps: float, size_frac: float = 0.10
                    ) -> Dict:
    n_params, sparsity, paper_nccl, paper_atp = MODELS[model]
    cfg = CompressionConfig(ratio=size_frac)
    wire = cfg.wire_bytes(int(n_params), grad_bytes_per_elem=4)
    orig_bytes = n_params * 4
    bw = link_gbps * 1e9 / 8
    t_wire_dense = orig_bytes * 2 * 7 / 8 / bw        # 8-worker ring
    comm_share = DENSE_COMM_SHARE[model]
    t_compute = t_wire_dense * (1 - comm_share) / comm_share
    t_dense = t_compute + t_wire_dense

    t_codec = orig_bytes * 8 / (CODEC_GBPS * 1e9)
    # above the lossless threshold? otherwise extra iterations hurt —
    # the paper observes this for VGG/BERT; model as recovery-rate loss
    threshold = 1.23 * (1 - sparsity)
    lossless = size_frac >= threshold
    t_wire_comp = wire["total_bytes"] * 2 * 7 / 8 / bw
    t_ours = t_compute + max(t_codec, t_wire_comp)
    return {
        "model": model,
        "lossless_at_10pct": lossless,
        "t_dense_ms": t_dense * 1e3,
        "t_ours_ms": t_ours * 1e3,
        "modeled_speedup": t_dense / t_ours,
        "paper_speedup": paper_nccl if link_gbps >= 50 else paper_atp,
    }


def main():
    print("cluster,model,lossless,modeled_speedup,paper_speedup")
    for name, gbps in (("nccl_100g", 100.0), ("atp_10g", 10.0)):
        for model in MODELS:
            r = model_iteration(model, gbps)
            print(f"{name},{r['model']},{int(r['lossless_at_10pct'])},"
                  f"{r['modeled_speedup']:.2f},{r['paper_speedup']:.2f}")


if __name__ == "__main__":
    main()
