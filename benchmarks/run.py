"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV summary lines at the end, plus
each suite's own CSV. Roofline sections require dry-run artifacts
(python -m repro.launch.dryrun --all); they are skipped gracefully when
absent so this runs on a fresh checkout.
"""

from __future__ import annotations

import glob
import os
import time


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def main() -> None:
    summary = []

    # Fig. 3 / Fig. 4 — recovery accuracy & Topk comparison
    from . import accuracy
    print("== accuracy (paper Fig. 3 / Fig. 4) ==")
    _, us = _timed(accuracy.main)
    summary.append(("accuracy_sweep", us, "fig3+fig4"))

    # Fig. 5/6 — aggregation throughput
    from . import aggregation
    print("\n== aggregation throughput (paper Fig. 5/6) ==")
    _, us = _timed(aggregation.main)
    summary.append(("aggregation_throughput", us, "fig5+fig6"))

    # Fig. 7 — per-iteration speedup model
    from . import end_to_end
    print("\n== per-iteration speedup (paper Fig. 7) ==")
    _, us = _timed(end_to_end.main)
    summary.append(("end_to_end_speedup", us, "fig7"))

    # Roofline (deliverable g) from dry-run artifacts
    art = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
    from . import roofline
    for mesh in ("single", "multi"):
        if glob.glob(os.path.join(art, mesh, "*.json")):
            print(f"\n== roofline ({mesh}-pod) ==")
            out, us = _timed(roofline.table, mesh)
            print(out)
            summary.append((f"roofline_{mesh}", us, "deliverable_g"))
        else:
            print(f"\n== roofline ({mesh}-pod): no artifacts, run "
                  f"`python -m repro.launch.dryrun --all --mesh {mesh}` ==")

    print("\nname,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
