"""Trip-count-corrected analysis of compiled (SPMD, per-device) HLO text.

``compiled.cost_analysis()`` counts every while-loop body **once**, so a
28-layer ``lax.scan`` transformer under-reports FLOPs and collective
traffic by ~28x. This module re-derives both from the HLO text:

1. split the module into computations,
2. recover each while loop's trip count from the `constant(N)` bound in
   its condition computation,
3. propagate execution multipliers through the call graph
   (while body/cond x trip, fusion/call x 1),
4. sum dot FLOPs (2 * prod(result) * contraction) and collective operand
   bytes per computation, weighted by multiplier.

Everything is per-device (SPMD shapes); multiply by chip count for
cluster totals.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->")


def _split_depth0(s: str) -> List[str]:
    """Split on commas at paren/brace depth 0 (tuple types nest)."""
    out, buf, depth = [], [], 0
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if buf:
        out.append("".join(buf))
    return out
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
                    r"([a-z][\w\-]*)\((.*)$")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """(elements, bytes) of an HLO type string (sums tuple components)."""
    elems = total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    rest: str                     # text after the opening '('
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]        # param name -> type str
    instrs: List[Instr]

    def types(self) -> Dict[str, str]:
        t = dict(self.params)
        for i in self.instrs:
            t[i.name] = i.result_type
        return t


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR.match(line.strip())
        if hdr and line.rstrip().endswith("{"):
            params = {}
            for p in _split_depth0(hdr.group(2)):
                p = p.strip()
                if ":" in p:
                    pname, ptype = p.split(":", 1)
                    params[pname.strip().lstrip("%")] = ptype.strip()
            cur = Computation(name=hdr.group(1), params=params, instrs=[])
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            cur.instrs.append(Instr(name=m.group(1), result_type=m.group(2),
                                    opcode=m.group(3), rest=m.group(4),
                                    line=line))
    return comps


def _callee(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


def while_trip_count(cond: Computation) -> int:
    """Largest s32/u32 scalar constant in the condition ~ the loop bound.

    jax scans lower to `counter < N`; N is the only large constant in the
    condition. Falls back to 1 when nothing is found.
    """
    best = 1
    for i in cond.instrs:
        if i.opcode == "constant" and re.match(r"[su]32\[\]", i.result_type):
            m = re.search(r"constant\((\d+)\)", i.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def execution_multipliers(comps: Dict[str, Computation]) -> Dict[str, int]:
    """computation name -> times executed per step (trip-count product)."""
    entry = None
    for name, c in comps.items():
        if name.startswith("main") or ".main" in name or entry is None:
            pass
    # ENTRY computation: the one nobody calls
    called = set()
    calls: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for name, c in comps.items():
        for i in c.instrs:
            if i.opcode == "while":
                body = _callee(i.rest, "body")
                cond = _callee(i.rest, "condition")
                trip = while_trip_count(comps[cond]) if cond in comps else 1
                if body in comps:
                    calls[name].append((body, trip))
                    called.add(body)
                if cond in comps:
                    calls[name].append((cond, trip + 1))
                    called.add(cond)
            else:
                for key in ("calls", "to_apply", "body", "condition",
                            "true_computation", "false_computation"):
                    cal = _callee(i.rest, key)
                    if cal and cal in comps:
                        calls[name].append((cal, 1))
                        called.add(cal)
    roots = [n for n in comps if n not in called]
    mult: Dict[str, int] = defaultdict(int)
    stack = [(r, 1) for r in roots]
    seen_depth = 0
    while stack:
        name, m = stack.pop()
        mult[name] += m
        seen_depth += 1
        if seen_depth > 200_000:    # cycle guard
            break
        for callee, trip in calls.get(name, []):
            stack.append((callee, m * trip))
    return dict(mult)


def fusion_bodies(comps: Dict[str, Computation]) -> set:
    """Computations that are fusion bodies / reducers — their instruction
    outputs live in registers, not HBM, so the bytes proxy must skip them
    (their dot FLOPs still count)."""
    bodies = set()
    for c in comps.values():
        for i in c.instrs:
            if i.opcode in ("fusion", "reduce", "reduce-window", "scatter",
                            "sort", "map", "all-reduce", "reduce-scatter"):
                cal = _callee(i.rest, "calls") or _callee(i.rest, "to_apply")
                if cal:
                    bodies.add(cal)
    return bodies


def dot_flops(comp: Computation) -> float:
    """Sum of 2*prod(result)*K over dot ops in one computation."""
    types = comp.types()
    total = 0.0
    for i in comp.instrs:
        if i.opcode != "dot":
            continue
        out_elems, _ = shape_elems_bytes(i.result_type)
        ops = [o.strip().lstrip("%") for o in
               re.match(r"([^)]*)\)", i.rest).group(1).split(",")]
        lhs_t = types.get(ops[0], "")
        lhs_elems, _ = shape_elems_bytes(lhs_t)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", i.line)
        k = 1
        if m and lhs_t:
            dims_m = _SHAPE_RE.search(lhs_t)
            dims = [int(d) for d in dims_m.group(2).split(",") if d]
            for ci in m.group(1).split(","):
                if ci:
                    k *= dims[int(ci)]
        total += 2.0 * out_elems * k
    return total


def output_bytes(comp: Computation) -> float:
    """Sum of result bytes over non-trivial ops — a traffic proxy."""
    skip = {"parameter", "constant", "get-tuple-element", "tuple",
            "bitcast", "after-all"}
    total = 0.0
    for i in comp.instrs:
        if i.opcode in skip:
            continue
        _, b = shape_elems_bytes(i.result_type)
        total += b
    return total


def collective_traffic(comp: Computation) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for i in comp.instrs:
        op = None
        for c in _COLLECTIVES:
            if i.opcode == c or i.opcode == c + "-start":
                op = c
                break
        if op is None:
            continue
        _, res_bytes = shape_elems_bytes(i.result_type)
        g = 1
        m = re.search(r"replica_groups=\{\{([^}]*)\}", i.line)
        if m:
            g = len(m.group(1).split(","))
        else:
            m2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", i.line)
            if m2:
                g = int(m2.group(2))
        operand_bytes = res_bytes // g if op == "all-gather" else res_bytes
        d = out.setdefault(op, {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
        d["count"] += 1
        d["bytes"] += operand_bytes
        # ring-time wire bytes per device
        if op == "all-reduce":
            wire = 2 * operand_bytes * (g - 1) / max(g, 1)
        elif op in ("all-gather", "reduce-scatter", "all-to-all"):
            wire = operand_bytes * (g - 1) / max(g, 1) if op != "all-gather" \
                else operand_bytes * (g - 1)
        else:  # collective-permute
            wire = operand_bytes
        d["wire_bytes"] += wire
    return out


@dataclasses.dataclass
class HloSummary:
    dot_flops: float            # per device, trip-corrected
    output_bytes: float         # per device, trip-corrected (proxy)
    collectives: Dict[str, Dict[str, float]]   # trip-corrected

    def collective_wire_bytes(self) -> float:
        return sum(d["wire_bytes"] for d in self.collectives.values())


def analyze(hlo: str) -> HloSummary:
    comps = parse_computations(hlo)
    mult = execution_multipliers(comps)
    fused = fusion_bodies(comps)
    flops = 0.0
    obytes = 0.0
    colls: Dict[str, Dict[str, float]] = {}
    for name, comp in comps.items():
        m = mult.get(name, 1)
        flops += m * dot_flops(comp)
        if name not in fused:
            obytes += m * output_bytes(comp)
        for op, d in collective_traffic(comp).items():
            agg = colls.setdefault(op, {"count": 0, "bytes": 0.0,
                                        "wire_bytes": 0.0})
            agg["count"] += m * d["count"]
            agg["bytes"] += m * d["bytes"]
            agg["wire_bytes"] += m * d["wire_bytes"]
    return HloSummary(dot_flops=flops, output_bytes=obytes, collectives=colls)
