"""Paper Fig. 5/6 analogue: aggregation throughput vs compressed size.

The paper measures end-to-end aggregation Gbps on 100 Gbps / 10 Gbps
clusters. Without that hardware we measure the two halves we *can*:

  - codec throughput: wall-time of jit'd compress / recover on this host
    (the CPU stand-in for the paper's GPU codec of §3.4), and
  - wire model: bytes on the link for [sketch + index] vs dense bf16,
    turned into aggregation throughput at a given link bandwidth.

Aggregation throughput (paper definition: aggregated gradient volume /
wall time, counting each worker's gradient once) is then
    throughput = orig_bytes / max(t_codec, t_wire)
reported for both the dense baseline and the compressed pipeline.

``--compare-bucketing`` (PR 2) additionally compares the bucketed
aggregator (one fused codec + O(1) collective launches for the whole
pytree) against the pre-bucketing per-leaf architecture (one codec plan +
one psum + one OR-AllReduce *per leaf*) on a multi-leaf model-shaped
pytree: static collective-op counts from the jaxpr, plus end-to-end
aggregation wall time, plus the single-leaf case (where bucketing must
not regress). Runs on 2 fake CPU devices so the collectives are real.

``--compare-rs`` (PR 3) compares the four aggregation arms — dense,
``compressed`` (AllReduce wire), ``compressed_rs`` over its emulated
psum+slice wire, and ``compressed_rs`` over the native psum_scatter +
OR-Reduce-Scatter wire — on per-rank wire accounting
(``CompressionConfig.strategy_wire_bytes``), static collective-op
counts, and wall time. The 1-axis mesh keeps the region full-manual so
the native path runs on both JAX legs; CI fails if the native arm's
per-rank payload is not strictly below ``compressed``'s.

``--compare-innet`` (PR 4) compares dense / ``compressed`` /
``compressed_innet`` over both its wire dtypes (idealized f32 and the
switch-honest fixed-point fxp32) on collective-op counts, wall time and
the tree wire model (worker sends the payload ONCE; the root link
carries 1x the payload per direction vs the ring's 2(W-1)/W x). It also
drives the emulated :class:`repro.net.switch.SwitchModel` (bounded SRAM
slots, streaming windows, per-port counters) over the same per-worker
streams and asserts the switch's integer aggregate is bit-identical to
the in-mesh fxp32 arm. CI fails if the fxp32 root-link bytes are not
strictly below the dense ring AllReduce's per-link bytes.

``--compare-overlap`` (PR 5) sweeps the shared stream scheduler's
wire-chunk counts per strategy (AllReduce chunks incl. a non-divisible
grid, per-rank-aligned native-RS chunks, innet switch windows), pins
every chunked output bit-identical to the fused wire, and reports
collective *launches* (scan trip counts included) — CI fails if the
overlapped native RS launch count is not affine in ``n_chunks`` with a
positive slope, i.e. if the per-chunk scatter schedule secretly fused.

``--compare-auto`` (PR 6) drives the online cost-model controller
(:class:`repro.core.costmodel.AutoWireController`) through its probe
schedule on the same toy model: one replan window per fixed wire, one
chunk-grid probe on the measured winner, then the decided per-bucket
plan — executed through the ``auto`` strategy's plan/execute split. It
reports each fixed strategy's steady-state wall, the controller's
decision trace (probe walls, analytic priors, occupancy), a
jaxpr-derived per-link byte count (:func:`_count_link_bytes`) next to
the analytic ``strategy_wire_bytes`` accounting, and the ``auto`` arm's
steady-state wall. CI fails if ``auto`` settles more than 10% above the
best fixed strategy.

``--compare-a2a`` (PR 8) compares the pattern-parametric wire's
``alltoall`` arms — the dense ppermute exchange vs the compressed
sketch exchange that the MoE dispatch/combine hook routes expert
payloads through — on per-rank wire accounting (the ``*_alltoall``
entries of ``strategy_wire_bytes``), jaxpr-measured link bytes (must
reconcile exactly), collective ops/launches, and wall time, with both
wires' merged outputs pinned bit-identical. Runs on 4 fake CPU devices;
CI fails if the compressed arm's per-rank a2a bytes are not strictly
below the dense arm's at W > 2.

``--smoke`` shrinks every size for CI; ``--json PATH`` dumps all rows as
a JSON artifact so the perf trajectory accumulates across CI runs;
``--normalized-json PATH`` additionally writes a compact
strategy -> {payload/link bytes, collective ops, wall} map plus the
per-chunk overlap sweep rows (the ``BENCH_aggregation.json`` the CI
smoke step drops at the repo root to track the perf trajectory across
PRs).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import statistics
import sys
import time
from typing import Any, Dict, List

# Must be set before jax initializes: the bucketing / reduce-scatter /
# in-network comparisons need >1 device so the psum / OR-AllReduce /
# psum_scatter / ppermute-tree launches are real collectives. The
# all-to-all comparison needs W > 2 (its CI gate is vacuous at W=2,
# where the dense a2a already ships only half the payload).
if ("--compare-bucketing" in sys.argv or "--compare-rs" in sys.argv
        or "--compare-innet" in sys.argv
        or "--compare-overlap" in sys.argv
        or "--compare-auto" in sys.argv
        or "--compare-a2a" in sys.argv) and \
        "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    _n_dev = 4 if "--compare-a2a" in sys.argv else 2
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n_dev}")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import CompressionConfig, HomomorphicCompressor, CompressedLeaf
from repro.core import collectives as coll
from repro.core.aggregators import make_aggregator
from repro.core.collectives import AggregationState

N = 1 << 22                  # 4M f32 gradient (16 MiB) per measurement
SPARSITY = 0.945             # LSTM profile
LINK_GBPS = {"nccl_100g": 100.0, "ici_v5e": 400.0}


def _grad(seed=0, n=N):
    r = np.random.default_rng(seed)
    x = np.zeros(n, np.float32)
    k = int(n * (1 - SPARSITY))
    x[r.choice(n, size=k, replace=False)] = r.standard_normal(k).astype(np.float32)
    return jnp.asarray(x)


def measure(frac: float, workers: int = 4, iters: int = 3,
            use_pallas: str = "auto", n: int = N) -> Dict:
    rows = 6 if frac <= 0.4 else 90
    cfg = CompressionConfig(ratio=frac, lanes=512, rows=rows, rounds=16,
                            chunk_blocks=256, use_pallas=use_pallas)
    comp = HomomorphicCompressor(cfg)
    x = _grad(n=n)
    compress = jax.jit(comp.compress)
    recover = jax.jit(lambda c: comp.recover(c, n))
    c = compress(x)
    jax.block_until_ready(c)
    xs = [compress(_grad(s, n=n)) for s in range(workers)]
    agg = CompressedLeaf(sketch=sum(cc.sketch for cc in xs),
                         index_words=xs[0].index_words)
    for cc in xs[1:]:
        agg = CompressedLeaf(agg.sketch, agg.index_words | cc.index_words)
    jax.block_until_ready(recover(agg))

    t_comp = _time_jitted(compress, (x,), iters)
    t_rec = _time_jitted(recover, (agg,), iters)

    wire = comp.wire_bytes(n, grad_bytes_per_elem=4)
    orig_bytes = n * 4
    out = {"size_frac": frac, "backend": use_pallas,
           "t_compress_s": t_comp, "t_recover_s": t_rec,
           "codec_gbps": orig_bytes * 8 / (t_comp + t_rec) / 1e9,
           "wire_fraction": wire["total_bytes"] / orig_bytes}
    for name, gbps in LINK_GBPS.items():
        bw = gbps * 1e9 / 8
        # ring allreduce: 2 (W-1)/W x bytes on the slowest link
        ring = 2 * (workers - 1) / workers
        t_wire_dense = orig_bytes * ring / bw
        t_wire_comp = wire["total_bytes"] * ring / bw
        thr_dense = orig_bytes * 8 / t_wire_dense / 1e9
        thr_comp = orig_bytes * 8 / max(t_wire_comp, t_comp + t_rec) / 1e9
        out[f"{name}_dense_gbps"] = thr_dense
        out[f"{name}_ours_gbps"] = thr_comp
        out[f"{name}_speedup"] = thr_comp / thr_dense
    return out


# ----------------------------------------------------------------------
# Bucketed vs per-leaf aggregation (PR 2)
# ----------------------------------------------------------------------

_COLLECTIVE_PREFIXES = ("psum", "ppermute", "all_gather", "all_to_all",
                        "reduce_scatter", "pmax", "pmin")


def _count_collectives(obj, counts: Dict[str, int]):
    """Recursively count collective eqns in a (Closed)Jaxpr."""
    jaxpr = getattr(obj, "jaxpr", obj)
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if any(name.startswith(p) for p in _COLLECTIVE_PREFIXES):
            counts[name] = counts.get(name, 0) + 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    _count_collectives(sub, counts)
    return counts


def _count_collective_launches(obj, weight: int = 1) -> int:
    """Total runtime collective *launches*: like :func:`_count_collectives`
    but a collective inside a ``lax.scan`` body counts once per trip —
    the number that must scale as O(n_chunks) for the streamed wire
    schedules (the static eqn count stays O(1) there, hiding the
    pipeline). ``while_loop`` bodies keep weight 1 (trip count unknown;
    no collective runs inside the peel loops)."""
    jaxpr = getattr(obj, "jaxpr", obj)
    total = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if any(name.startswith(p) for p in _COLLECTIVE_PREFIXES):
            total += weight
        sub_w = weight * int(eqn.params.get("length", 1)) \
            if name == "scan" else weight
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    total += _count_collective_launches(sub, sub_w)
    return total


def _count_link_bytes(obj, W: int, weight: int = 1) -> float:
    """Per-link bytes implied by the collectives in a jaxpr, under the
    standard ring/gather cost model on a ``W``-way axis:

      - ``psum_scatter`` / ``reduce_scatter``: ``(W-1)/W x`` input bytes
      - ``psum`` / ``pmax`` / ``pmin`` / ``all_to_all`` (ring
        AllReduce): ``2 (W-1)/W x`` operand bytes
      - ``all_gather``: ``(W-1)/W x`` *output* bytes
      - ``ppermute``: ``1 x`` operand bytes (one hop)

    Collectives inside a ``lax.scan`` body count once per trip, like
    :func:`_count_collective_launches`. This is the measured side of the
    ``strategy_wire_bytes`` cross-check: the analytic accounting and the
    bytes the launched collectives actually move must agree.
    """
    def _nbytes(atoms):
        return sum(int(np.prod(a.aval.shape)) * a.aval.dtype.itemsize
                   for a in atoms if hasattr(a, "aval"))

    jaxpr = getattr(obj, "jaxpr", obj)
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if any(name.startswith(p) for p in _COLLECTIVE_PREFIXES):
            if name.startswith(("psum_scatter", "reduce_scatter")):
                total += weight * (W - 1) / W * _nbytes(eqn.invars)
            elif name.startswith("all_gather"):
                total += weight * (W - 1) / W * _nbytes(eqn.outvars)
            elif name.startswith("ppermute"):
                total += weight * _nbytes(eqn.invars)
            else:   # psum / pmax / pmin / all_to_all: ring AllReduce
                total += weight * 2 * (W - 1) / W * _nbytes(eqn.invars)
        sub_w = weight * int(eqn.params.get("length", 1)) \
            if name == "scan" else weight
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    total += _count_link_bytes(sub, W, sub_w)
    return total


def _model_tree(n_leaves: int, width: int, seed: int = 0):
    """A transformer-shaped pytree: n_leaves alternating matrices/vectors."""
    r = np.random.default_rng(seed)
    tree = {}
    for i in range(n_leaves):
        shape = (width, width) if i % 3 == 0 else (
            (width, 4 * width) if i % 3 == 1 else (width,))
        g = np.zeros(int(np.prod(shape)), np.float32)
        k = max(1, int(g.size * 0.03))
        idx = r.choice(g.size, size=k, replace=False)
        g[idx] = r.standard_normal(k).astype(np.float32)
        tree[f"leaf{i:02d}"] = g.reshape(shape)
    return tree


def _stacked_inputs(tree, mesh, W):
    """Per-worker stacked copies of ``tree`` laid over the "data" axis:
    (device_put inputs, in_specs, out_specs, total element count)."""
    stacked = jax.tree.map(
        lambda g: np.stack([g * (1.0 + 0.1 * w) for w in range(W)]), tree)
    in_specs = jax.tree.map(
        lambda g: P(*(("data",) + (None,) * g.ndim)), tree)
    put = jax.tree.map(
        lambda a, s: jax.device_put(jnp.asarray(a), NamedSharding(mesh, s)),
        stacked, in_specs)
    out_specs = jax.tree.map(lambda _: P(), tree)
    total = sum(int(np.prod(g.shape)) for g in tree.values())
    return put, in_specs, out_specs, total


def _time_jitted(fn, args, iters: int) -> float:
    """Median-of-``iters`` wall for one jitted call.

    Two warmup calls (the first pays compilation, the second flushes
    any lazy first-dispatch work), then a per-iteration
    ``block_until_ready`` wall and the *median* — so the CI gates and
    BENCH walls track the steady-state step, not compile noise or one
    scheduler hiccup."""
    for _ in range(2):
        jax.block_until_ready(fn(*args))
    walls = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        walls.append(time.perf_counter() - t0)
    return statistics.median(walls)


def compare_bucketing(smoke: bool = False) -> List[Dict]:
    """Bucketed aggregator vs the per-leaf architecture it replaced."""
    W = jax.device_count()
    mesh = compat.make_mesh((W,), ("data",))
    width = 32 if smoke else 128
    iters = 1 if smoke else 3
    cfg = CompressionConfig(
        ratio=0.3, lanes=128, rows=6, rounds=10, chunk_blocks=64,
        use_pallas="never",
        bucket_bytes=(64 << 10) if smoke else (1 << 20))
    comp = HomomorphicCompressor(cfg)

    def per_leaf_path(grads):
        """The seed architecture: plan + psum + OR-AllReduce per leaf."""
        idx = {"data": jax.lax.axis_index("data")}
        out = {}
        for k, g in grads.items():
            flat = g.reshape(-1).astype(jnp.float32)
            c = comp.compress(flat)
            sk = jax.lax.psum(c.sketch, ("data",))
            words = coll.or_allreduce(c.index_words, ("data",),
                                      axis_indices=idx)
            rec = comp.recover(CompressedLeaf(sk, words), flat.shape[0])
            out[k] = (rec / W).astype(g.dtype).reshape(g.shape)
        return out

    agg = make_aggregator("compressed", cfg, mesh, ("data",), ())

    def bucketed_path(grads):
        specs = jax.tree.map(lambda _: P(), grads)
        res = coll.init_aggregation_state(grads, cfg).residual
        out, _ = agg(grads, AggregationState(residual=res), specs)
        return out

    rows = []
    for case, n_leaves in (("multi_leaf", 24), ("single_leaf", 1)):
        tree = _model_tree(n_leaves, width)
        put, in_specs, out_specs, total = _stacked_inputs(tree, mesh, W)
        wire = cfg.wire_bytes(total, grad_bytes_per_elem=4)
        row = {"case": case, "n_leaves": n_leaves, "workers": W,
               "total_elems": total, "n_buckets": wire["n_buckets"],
               "bucket_elems": wire["bucket_elems"]}
        for name, path in (("perleaf", per_leaf_path),
                           ("bucketed", bucketed_path)):
            fn = jax.jit(compat.shard_map(
                lambda st, path=path: path(jax.tree.map(lambda a: a[0], st)),
                mesh=mesh, in_specs=(in_specs,), out_specs=out_specs,
                axis_names={"data"}, check_vma=False))
            counts = _count_collectives(jax.make_jaxpr(fn)(put), {})
            row[f"{name}_collective_ops"] = sum(counts.values())
            row[f"{name}_collectives"] = dict(sorted(counts.items()))
            row[f"{name}_wall_s"] = _time_jitted(fn, (put,), iters)
        row["collective_ratio"] = (
            row["perleaf_collective_ops"]
            / max(row["bucketed_collective_ops"], 1))
        row["wall_ratio"] = row["perleaf_wall_s"] / row["bucketed_wall_s"]
        rows.append(row)
        print(f"[{case}] leaves={n_leaves} buckets={row['n_buckets']} "
              f"collective_ops per-leaf={row['perleaf_collective_ops']} "
              f"bucketed={row['bucketed_collective_ops']} "
              f"wall per-leaf={row['perleaf_wall_s']:.4f}s "
              f"bucketed={row['bucketed_wall_s']:.4f}s")

    # ---- bucket-size sweep (fused vs overlap-pipelined) --------------
    tree = _model_tree(24, width)
    put, in_specs, out_specs, total = _stacked_inputs(tree, mesh, W)
    sweep = ((16 << 10, 64 << 10, 256 << 10) if smoke
             else (256 << 10, 1 << 20, 4 << 20))
    for bucket_bytes in sweep:
        for overlap in (False, True):
            cfg_b = dataclasses.replace(cfg, bucket_bytes=bucket_bytes,
                                        overlap=overlap)
            agg_b = make_aggregator("compressed", cfg_b, mesh, ("data",), ())

            def bucketed_b(grads, agg_b=agg_b, cfg_b=cfg_b):
                specs = jax.tree.map(lambda _: P(), grads)
                res = coll.init_aggregation_state(grads, cfg_b).residual
                out, _ = agg_b(grads, AggregationState(residual=res), specs)
                return out

            fn = jax.jit(compat.shard_map(
                lambda st, path=bucketed_b: path(
                    jax.tree.map(lambda a: a[0], st)),
                mesh=mesh, in_specs=(in_specs,), out_specs=out_specs,
                axis_names={"data"}, check_vma=False))
            wire_b = cfg_b.wire_bytes(total, grad_bytes_per_elem=4)
            row = {"case": "bucket_sweep", "bucket_bytes": bucket_bytes,
                   "overlap": overlap, "workers": W,
                   "n_buckets": wire_b["n_buckets"],
                   "bucket_elems": wire_b["bucket_elems"],
                   "bucketed_total_bytes": wire_b["bucketed_total_bytes"],
                   "collective_ops": sum(_count_collectives(
                       jax.make_jaxpr(fn)(put), {}).values()),
                   "wall_s": _time_jitted(fn, (put,), iters)}
            rows.append(row)
            print(f"[bucket_sweep] bucket_bytes={bucket_bytes} "
                  f"overlap={overlap} buckets={row['n_buckets']} "
                  f"collective_ops={row['collective_ops']} "
                  f"wall={row['wall_s']:.4f}s")
    return rows


# ----------------------------------------------------------------------
# Dense vs compressed vs emulated-RS vs native-RS (PR 3)
# ----------------------------------------------------------------------

def compare_rs(smoke: bool = False) -> List[Dict]:
    """The reduce-scatter wire story: per-strategy collective-op counts,
    wall time, and per-rank wire accounting for ``dense``,
    ``compressed``, and ``compressed_rs`` over both its wire paths
    (psum+slice emulation vs native psum_scatter + OR-Reduce-Scatter).

    The mesh has only the manual "data" axis, so the region is
    full-manual and the native path runs on both JAX legs. The headline
    number is ``rank_payload_bytes``: the reduced sketch+bitmap that
    lands on each rank is the full payload for ``compressed`` /
    emulated RS but 1/W of it for native RS — the paper's claim that the
    sketch aggregates through the existing reduce-scatter API at full
    collective bandwidth.
    """
    W = jax.device_count()
    mesh = compat.make_mesh((W,), ("data",))
    width = 32 if smoke else 128
    iters = 1 if smoke else 3
    # Small buckets relative to the stream keep the pad-to-W-chunks slack
    # small, so the native arm's payload sits near the ideal 1/W.
    cfg = CompressionConfig(
        ratio=0.3, lanes=128, rows=6, rounds=10, chunk_blocks=64,
        use_pallas="never",
        bucket_bytes=(8 << 10) if smoke else (256 << 10))
    tree = _model_tree(24, width)
    put, in_specs, out_specs, total = _stacked_inputs(tree, mesh, W)
    acc = cfg.strategy_wire_bytes(total, W, grad_bytes_per_elem=4)

    arms = (
        ("dense", "dense", "auto", acc["dense"]),
        ("compressed", "compressed", "auto", acc["compressed"]),
        ("compressed_rs_emulated", "compressed_rs", "emulate",
         acc["compressed_rs_emulated"]),
        ("compressed_rs_native", "compressed_rs", "native",
         acc["compressed_rs_native"]),
    )
    rows = []
    for arm, name, rs_wire, wire in arms:
        cfg_a = dataclasses.replace(cfg, rs_wire=rs_wire)
        agg = make_aggregator(name, cfg_a, mesh, ("data",), (),
                              outer_manual=("data",))

        def path(grads, agg=agg, cfg_a=cfg_a):
            specs = jax.tree.map(lambda _: P(), grads)
            res = coll.init_aggregation_state(grads, cfg_a).residual
            out, _ = agg(grads, AggregationState(residual=res), specs)
            return out

        fn = jax.jit(compat.shard_map(
            lambda st, path=path: path(jax.tree.map(lambda a: a[0], st)),
            mesh=mesh, in_specs=(in_specs,), out_specs=out_specs,
            axis_names={"data"}, check_vma=False))
        counts = _count_collectives(jax.make_jaxpr(fn)(put), {})
        row = {"case": "compare_rs", "arm": arm, "workers": W,
               "total_elems": total,
               "collective_ops": sum(counts.values()),
               "collectives": dict(sorted(counts.items())),
               "wall_s": _time_jitted(fn, (put,), iters)}
        row.update(wire)
        rows.append(row)
        print(f"[compare_rs] {arm}: rank_payload={row['rank_payload_bytes']} "
              f"link={row['link_bytes']} "
              f"collective_ops={row['collective_ops']} "
              f"wall={row['wall_s']:.4f}s")

    by_arm = {r["arm"]: r for r in rows}
    ratio = (by_arm["compressed_rs_native"]["rank_payload_bytes"]
             / by_arm["compressed"]["rank_payload_bytes"])
    print(f"[compare_rs] native-RS rank payload = {ratio:.3f}x compressed "
          f"(ideal 1/W = {1 / W:.3f})")
    return rows


# ----------------------------------------------------------------------
# Stream-scheduler chunk-count sweep (PR 5)
# ----------------------------------------------------------------------

def compare_overlap(smoke: bool = False) -> List[Dict]:
    """The overlap story: sweep wire-chunk counts per strategy through
    the shared stream scheduler (``core/streams.py``) and report, per
    (strategy, n_chunks): collective *launches* (scan trip counts
    included — the static op count is O(1) inside a pipeline), static
    ops, per-chunk payload bytes, and wall time — with every chunked
    output pinned bit-identical to the fused one.

    CI gate: the overlapped **native RS** wire must issue per-chunk
    scatter collectives — its launch count must scale as O(n_chunks)
    per the wire model (affine in the chunk count with a positive
    slope). A schedule that secretly fuses the wire back into one shot
    would fail it.
    """
    W = jax.device_count()
    mesh = compat.make_mesh((W,), ("data",))
    width = 32 if smoke else 128
    iters = 1 if smoke else 3
    cfg = CompressionConfig(
        ratio=0.3, lanes=128, rows=6, rounds=10, chunk_blocks=64,
        use_pallas="never",
        bucket_bytes=(8 << 10) if smoke else (256 << 10))
    tree = _model_tree(24, width)
    put, in_specs, out_specs, total = _stacked_inputs(tree, mesh, W)
    nb = cfg.num_buckets(total)
    per_rank = -(-nb // W)

    # native RS chunk counts must divide the per-rank bucket count:
    # fused, a middle divisor, and the finest (per-rank-chunk) grid
    divs = [d for d in range(1, per_rank + 1) if per_rank % d == 0]
    rs_counts = sorted({divs[0], divs[len(divs) // 2], divs[-1]})
    # AllReduce wire: fused, a non-divisible grid, and per-bucket
    ar_counts = sorted({1, 3 if nb % 3 else 2, nb})
    # innet: slots per window -> window counts
    innet_slots = sorted({nb, max(nb // 3, 1), 1}, reverse=True)

    arms = (
        ("compressed", "compressed", {},
         [("stream_chunks", c) for c in ar_counts]),
        ("compressed_rs_native", "compressed_rs", {"rs_wire": "native"},
         [("stream_chunks", c) for c in rs_counts]),
        ("compressed_innet_fxp32", "compressed_innet",
         {"wire_dtype": "fxp32"},
         [("switch_slots", s) for s in innet_slots]),
    )
    rows = []
    launches_by_arm: Dict[str, Dict[int, int]] = {}
    for arm, name, base_over, sweep in arms:
        baseline = None
        for knob, val in sweep:
            over = dict(base_over)
            if knob == "stream_chunks":
                if val > 1:
                    over["stream_chunks"] = val
            else:
                over["switch_slots"] = val
                over["overlap"] = val < nb   # >1 window -> streamed
            cfg_a = dataclasses.replace(cfg, **over)
            agg = make_aggregator(name, cfg_a, mesh, ("data",), (),
                                  outer_manual=("data",))

            def path(grads, agg=agg, cfg_a=cfg_a):
                specs = jax.tree.map(lambda _: P(), grads)
                res = coll.init_aggregation_state(grads, cfg_a).residual
                out, _ = agg(grads, AggregationState(residual=res), specs)
                return out

            fn = jax.jit(compat.shard_map(
                lambda st, path=path: path(
                    jax.tree.map(lambda a: a[0], st)),
                mesh=mesh, in_specs=(in_specs,), out_specs=out_specs,
                axis_names={"data"}, check_vma=False))
            jaxpr = jax.make_jaxpr(fn)(put)
            out = jax.tree.map(np.asarray, fn(put))
            if baseline is None:
                baseline = out
            else:
                for k in baseline:  # chunking must be bit-invisible
                    assert np.array_equal(baseline[k], out[k]), (arm, k)
            n_chunks = val if knob == "stream_chunks" else -(-nb // val)
            acc = cfg_a.strategy_wire_bytes(total, W,
                                            grad_bytes_per_elem=4)
            wire = acc[arm] if arm in acc else acc[name]
            row = {"case": "compare_overlap", "arm": arm,
                   "workers": W, "total_elems": total, "n_buckets": nb,
                   "n_chunks": n_chunks,
                   "chunk_payload_bytes":
                       -(-wire["rank_payload_bytes"] // max(n_chunks, 1)),
                   "link_bytes": wire["link_bytes"],
                   "collective_ops": sum(
                       _count_collectives(jaxpr, {}).values()),
                   "collective_launches": _count_collective_launches(jaxpr),
                   "wall_s": _time_jitted(fn, (put,), iters)}
            rows.append(row)
            launches_by_arm.setdefault(arm, {})[n_chunks] = \
                row["collective_launches"]
            print(f"[compare_overlap] {arm} n_chunks={n_chunks}: "
                  f"launches={row['collective_launches']} "
                  f"static_ops={row['collective_ops']} "
                  f"wall={row['wall_s']:.4f}s")

    # ---- CI gate: native RS launches scale as O(n_chunks) ------------
    pts = sorted(launches_by_arm["compressed_rs_native"].items())
    assert len(pts) >= 2, "need >= 2 native-RS chunk counts to fit a slope"
    (c0, l0), (c1, l1) = pts[0], pts[-1]
    slope = (l1 - l0) / (c1 - c0)
    assert slope > 0, (
        "overlapped native RS did not issue per-chunk collectives: "
        f"launches {dict(pts)}")
    for (ca, la), (cb, lb) in zip(pts, pts[1:]):
        s = (lb - la) / (cb - ca)
        assert s == slope, (
            "native RS launch count is not affine in n_chunks (the wire "
            f"model demands O(n_chunks) scatter launches): {dict(pts)}")
    print(f"[compare_overlap] native RS launches affine in n_chunks "
          f"(slope {slope:.1f}/chunk) — O(n_chunks) wire confirmed")
    return rows


# ----------------------------------------------------------------------
# Dense vs compressed vs in-network tree (PR 4)
# ----------------------------------------------------------------------

def compare_innet(smoke: bool = False) -> List[Dict]:
    """The in-network aggregation story: the same bucketed stream over
    the emulated switch tree (``compressed_innet``, f32 and fxp32 wires)
    vs the host-side AllReduce strategies, plus a ``SwitchModel`` pass
    over the identical per-worker streams for the SRAM/port accounting a
    collective trace cannot show. The headline number is
    ``root_link_bytes``: the tree's hottest link carries the payload
    once per direction, vs every dense-ring link carrying
    ``2(W-1)/W x`` the raw gradient.
    """
    from repro.core.bucketing import make_bucket_plan
    from repro.net import FixedPointWire, SwitchModel, make_topology

    W = jax.device_count()
    mesh = compat.make_mesh((W,), ("data",))
    width = 32 if smoke else 128
    iters = 1 if smoke else 3
    cfg = CompressionConfig(
        ratio=0.3, lanes=128, rows=6, rounds=10, chunk_blocks=64,
        use_pallas="never",
        bucket_bytes=(8 << 10) if smoke else (256 << 10))
    tree = _model_tree(24, width)
    put, in_specs, out_specs, total = _stacked_inputs(tree, mesh, W)

    arms = (
        ("dense", "dense", {}),
        ("compressed", "compressed", {}),
        ("compressed_innet_f32", "compressed_innet", {"wire_dtype": "f32"}),
        ("compressed_innet_fxp32", "compressed_innet",
         {"wire_dtype": "fxp32"}),
    )
    rows = []
    outs = {}
    for arm, name, over in arms:
        cfg_a = dataclasses.replace(cfg, **over)
        acc = cfg_a.strategy_wire_bytes(total, W, grad_bytes_per_elem=4)
        wire = acc[name]
        agg = make_aggregator(name, cfg_a, mesh, ("data",), (),
                              outer_manual=("data",))

        def path(grads, agg=agg, cfg_a=cfg_a):
            specs = jax.tree.map(lambda _: P(), grads)
            res = coll.init_aggregation_state(grads, cfg_a).residual
            out, _ = agg(grads, AggregationState(residual=res), specs)
            return out

        fn = jax.jit(compat.shard_map(
            lambda st, path=path: path(jax.tree.map(lambda a: a[0], st)),
            mesh=mesh, in_specs=(in_specs,), out_specs=out_specs,
            axis_names={"data"}, check_vma=False))
        counts = _count_collectives(jax.make_jaxpr(fn)(put), {})
        outs[arm] = jax.tree.map(np.asarray, fn(put))
        row = {"case": "compare_innet", "arm": arm, "workers": W,
               "total_elems": total,
               "collective_ops": sum(counts.values()),
               "collectives": dict(sorted(counts.items())),
               "wall_s": _time_jitted(fn, (put,), iters)}
        row.update(wire)
        rows.append(row)
        print(f"[compare_innet] {arm}: "
              f"rank_payload={row['rank_payload_bytes']} "
              f"link={row['link_bytes']} "
              f"root_link={row.get('root_link_bytes', '-')} "
              f"collective_ops={row['collective_ops']} "
              f"wall={row['wall_s']:.4f}s")

    # f32 innet must be bit-identical to the AllReduce strategy (same
    # collectives); the fxp32 wire differs only by the documented
    # quantization roundtrip.
    for k in outs["compressed"]:
        assert np.array_equal(outs["compressed"][k],
                              outs["compressed_innet_f32"][k]), k

    # ---- emulated switch pass over the same per-worker streams -------
    cfg_fx = dataclasses.replace(cfg, wire_dtype="fxp32")
    comp = HomomorphicCompressor(cfg_fx)
    plan = make_bucket_plan(tree, cfg_fx)
    wire = FixedPointWire(workers=W)
    per_worker = [jax.tree.map(lambda g, w=w: g * (1.0 + 0.1 * w), tree)
                  for w in range(W)]
    sks, wds = [], []
    for pw in per_worker:
        c = comp.compress(plan.pack(pw).reshape(-1))
        sks.append(np.asarray(c.sketch))
        wds.append(np.asarray(c.index_words))
    sk_b = [s.reshape(plan.n_buckets, -1) for s in sks]
    exp = np.asarray(wire.bucket_exponents(jnp.asarray(sk_b[0])))
    for s in sk_b[1:]:
        exp = np.maximum(exp, np.asarray(
            wire.bucket_exponents(jnp.asarray(s))))
    qs = np.stack([np.asarray(wire.encode(jnp.asarray(s), jnp.asarray(exp)))
                   for s in sk_b])
    wpb = plan.bucket_elems // 32
    bms = np.stack([w.reshape(plan.n_buckets, wpb) for w in wds])
    switch = SwitchModel(ports=W, slots=cfg_fx.switch_slots)
    q_sum, bm_or = switch.aggregate(qs, bms,
                                    metadata_bytes=exp.size * exp.itemsize)
    dec = np.asarray(wire.decode(jnp.asarray(q_sum), jnp.asarray(exp)))
    rec = comp.recover(
        CompressedLeaf(sketch=jnp.asarray(dec.reshape(sks[0].shape)),
                       index_words=jnp.asarray(bm_or.reshape(-1))),
        plan.padded)
    ref = jax.tree.map(np.asarray, plan.unpack(
        jnp.asarray(rec).reshape(plan.n_buckets, plan.bucket_elems) / W))
    for k in ref:
        assert np.array_equal(ref[k], outs["compressed_innet_fxp32"][k]), (
            f"SwitchModel aggregate diverged from the in-mesh fxp32 "
            f"wire at leaf {k}")
    print("[compare_innet] SwitchModel aggregate == in-mesh fxp32 wire "
          "(bit-for-bit)")
    report = switch.report()
    topo = make_topology(cfg_fx.topology, mesh, ("data",))
    by_arm = {r["arm"]: r for r in rows}
    fx = by_arm["compressed_innet_fxp32"]
    fx["switch_report"] = report
    fx["tree_link_profile"] = topo.link_profile(fx["rank_payload_bytes"])
    # The device model and the static wire accounting must agree on the
    # root link (chunks + exponent metadata), byte for byte.
    assert report["root_link_tx_bytes"] == fx["root_link_bytes"], (
        report["root_link_tx_bytes"], fx["root_link_bytes"])
    print(f"[compare_innet] switch: windows={report['windows']} "
          f"occupancy_peak={report['occupancy_peak']}/{cfg_fx.switch_slots} "
          f"root_link_tx={report['root_link_tx_bytes']}")

    dense_link = by_arm["dense"]["link_bytes"]
    root = fx["root_link_bytes"]
    print(f"[compare_innet] fxp32 root link = {root} bytes vs dense ring "
          f"link {dense_link} ({root / dense_link:.3f}x)")
    assert root < dense_link, (
        "in-network root link did not beat the dense ring AllReduce: "
        f"{root} >= {dense_link}")
    if W > 2:
        # At W=2 the ring factor 2(W-1)/W is exactly 1, a tie by
        # construction; above it the tree beats the compressed ring too.
        assert root < by_arm["compressed"]["link_bytes"]
    return rows


# ----------------------------------------------------------------------
# Online cost-model controller: the `auto` strategy (PR 6)
# ----------------------------------------------------------------------

def compare_auto(smoke: bool = False) -> List[Dict]:
    """Drive the ``auto`` strategy's online controller end-to-end on the
    toy model: measure each fixed strategy's steady-state wall, walk the
    controller through its probe windows (feeding it the measured wall
    and occupancy telemetry of every step), then time the decided plan.

    Emits one row per fixed arm (wall, analytic + jaxpr-measured link
    bytes, collective counts) and one ``auto`` row carrying the
    controller's full decision trace. Asserts the controller finished
    probing and that the decided steady-state wall is within 10% of the
    best fixed strategy's (the satellite-5 CI gate, also re-checked from
    ``BENCH_aggregation.json`` by the workflow).
    """
    from repro.core.bucketing import make_bucket_plan
    from repro.core.costmodel import AutoWireController, fixed_wires

    W = jax.device_count()
    mesh = compat.make_mesh((W,), ("data",))
    width = 32 if smoke else 128
    iters = 3 if smoke else 5
    # replan_every=2 keeps the probe schedule short (one warmup step +
    # one measured step per window) so the full probe->decide arc fits
    # in a CI smoke run.
    cfg = CompressionConfig(
        ratio=0.3, lanes=128, rows=6, rounds=10, chunk_blocks=64,
        use_pallas="never", replan_every=2,
        bucket_bytes=(8 << 10) if smoke else (256 << 10))
    tree = _model_tree(24, width)
    put, in_specs, out_specs, total = _stacked_inputs(tree, mesh, W)
    acc = cfg.strategy_wire_bytes(total, W, grad_bytes_per_elem=4)
    acc_of = {"dense": acc["dense"], "compressed": acc["compressed"],
              "compressed_rs": (acc["compressed_rs_native"]
                                or acc["compressed_rs_emulated"]),
              "compressed_innet": acc["compressed_innet"]}

    def build(name, wplan=None, want_occ=False):
        agg = make_aggregator(name, cfg, mesh, ("data",), (),
                              outer_manual=("data",), wire_plan=wplan)

        def path(grads):
            specs = jax.tree.map(lambda _: P(), grads)
            res = coll.init_aggregation_state(grads, cfg).residual
            out, st = agg(grads, AggregationState(residual=res), specs)
            if want_occ:
                return out, st.telemetry["bucket_occupancy"]
            return out

        outs = (out_specs, P()) if want_occ else out_specs
        return jax.jit(compat.shard_map(
            lambda st: path(jax.tree.map(lambda a: a[0], st)),
            mesh=mesh, in_specs=(in_specs,), out_specs=outs,
            axis_names={"data"}, check_vma=False))

    # ---- fixed arms: the yardstick the controller must match ---------
    rows = []
    fixed_walls: Dict[str, float] = {}
    for wire in fixed_wires():
        fn = build(wire)
        jaxpr = jax.make_jaxpr(fn)(put)
        wall = _time_jitted(fn, (put,), iters)
        fixed_walls[wire] = wall
        row = {"case": "compare_auto", "arm": wire, "workers": W,
               "total_elems": total,
               "collective_ops": sum(
                   _count_collectives(jaxpr, {}).values()),
               "measured_link_bytes": round(
                   _count_link_bytes(jaxpr, W)),
               "wall_s": wall}
        row.update(acc_of[wire])
        rows.append(row)
        print(f"[compare_auto] fixed {wire}: wall={wall:.4f}s "
              f"link(analytic)={row['link_bytes']} "
              f"link(jaxpr)={row['measured_link_bytes']}")

    # ---- the controller's probe -> decide arc ------------------------
    bplan = make_bucket_plan(tree, cfg)
    ctl = AutoWireController(bplan, cfg, workers=W)
    compiled: Dict = {}   # WirePlan -> jitted step (plans recur)
    steps = (len(fixed_wires()) + 3) * cfg.replan_every
    wplan = ctl.plan(0)
    for step in range(steps):
        prev = wplan
        wplan = ctl.plan(step)
        if wplan not in compiled:
            compiled[wplan] = build("auto", wplan=wplan, want_occ=True)
        if wplan != prev:
            print(f"[compare_auto] step {step}: window -> "
                  f"{wplan.describe()}")
        fn = compiled[wplan]
        t0 = time.perf_counter()
        out, occ = fn(put)
        jax.block_until_ready(out)
        ctl.observe(time.perf_counter() - t0,
                    {"bucket_occupancy": np.asarray(occ)})
    trace = ctl.decision_trace()
    assert not trace["probing"], (
        f"controller still probing after {steps} steps: {trace}")

    # ---- steady state of the decided plan ----------------------------
    steady = _time_jitted(compiled[wplan], (put,), iters)
    chosen = wplan.uniform_wire
    best_fixed = min(fixed_walls, key=fixed_walls.get)
    ratio = steady / fixed_walls[best_fixed]
    row = {"case": "compare_auto", "arm": "auto", "workers": W,
           "total_elems": total, "n_buckets": bplan.n_buckets,
           "chosen_wire": chosen, "plan": wplan.describe(),
           "wall_s": steady, "best_fixed": best_fixed,
           "best_fixed_wall_s": fixed_walls[best_fixed],
           "wall_ratio_vs_best_fixed": ratio,
           "decision_trace": trace}
    rows.append(row)
    print(f"[compare_auto] decided plan: {wplan.describe()}")
    print(f"[compare_auto] auto steady wall={steady:.4f}s vs best fixed "
          f"({best_fixed}) {fixed_walls[best_fixed]:.4f}s "
          f"({ratio:.3f}x)")
    assert ratio <= 1.10, (
        f"auto settled {ratio:.3f}x above the best fixed strategy "
        f"({best_fixed}): {steady:.4f}s vs "
        f"{fixed_walls[best_fixed]:.4f}s")
    return rows


# ----------------------------------------------------------------------
# Dense vs compressed expert-parallel all-to-all (PR 8)
# ----------------------------------------------------------------------

def compare_a2a(smoke: bool = False) -> List[Dict]:
    """The pattern-parametric wire story: the MoE dispatch/combine
    exchange (``alltoall`` pattern) over its dense ppermute wire vs the
    compressed sketch wire, on the same per-destination payload.

    Each rank holds a stacked ``(W, n_dest)`` payload — slice ``r`` is
    bound for rank ``r`` — and the exchange routes + homomorphically
    merges so rank ``r`` ends with ``sum_w payload[w][r]``. The dense
    wire ships ``(W-1)/W x`` the stack per rank (W-1 ppermute lanes);
    the compressed wire ships the same lanes carrying [sketch + bitmap]
    at the sparse-payload codec profile, where the wire undercuts dense
    and the peel recovery of the merged sketch is still exact.

    Per arm: analytic per-rank payload/link bytes
    (``strategy_wire_bytes``'s ``*_alltoall`` entries), the
    jaxpr-measured link bytes (must reconcile exactly — the mesh's
    single manual axis keeps the region full-manual, so the native
    ppermute wire runs on both JAX legs), collective ops/launches, and
    wall time. CI gate: at W > 2 the compressed arm's per-rank a2a
    bytes must be strictly below the dense arm's.
    """
    from repro.core.aggregators import make_exchange

    W = jax.device_count()
    mesh = compat.make_mesh((W,), ("data",))
    iters = 1 if smoke else 3
    # The sparse-payload codec profile (ratio=0.3, like the aggregation
    # arms): this is where the compressed a2a wire undercuts dense. The
    # train-step hook instead pins the always-exact ratio=2.5 profile —
    # bigger than dense on the wire but lossless for arbitrarily dense
    # expert payloads; its parity is pinned by test_dispatch.py and the
    # collectives driver, while this benchmark measures the wire story.
    cfg = CompressionConfig(
        ratio=0.3, lanes=128, rows=6, rounds=10, chunk_blocks=64,
        use_pallas="never", topk_ratio=None, error_feedback=False,
        bucket_bytes=(8 << 10) if smoke else (256 << 10))
    n_d = cfg.bucket_elems_for(1 << 30) * (2 if smoke else 4)
    assert n_d % cfg.bucket_elems_for(n_d) == 0  # exact per-dest grid
    total = W * n_d
    acc = cfg.strategy_wire_bytes(total, W, grad_bytes_per_elem=4)

    # 3%-dense dyadic per-destination slices: sparse enough that the
    # W-way merged sketch peels exactly, dyadic (sign * 2^e) so the fp
    # sums are order-insensitive and the dense/compressed outputs can be
    # compared bit-for-bit.
    r = np.random.default_rng(0)
    stack = np.zeros((W, n_d), np.float32)
    k = int(n_d * 0.03)
    for w in range(W):
        idx = r.choice(n_d, size=k, replace=False)
        stack[w, idx] = (r.choice([-1.0, 1.0], size=k)
                         * np.exp2(r.integers(-2, 3, size=k))
                         ).astype(np.float32)
    payload = {"g": jnp.asarray(stack)}

    rows = []
    outs = {}
    for arm in ("dense_alltoall", "compressed_alltoall"):
        ex = make_exchange(arm.split("_")[0], cfg, mesh, ("data",),
                           outer_manual=("data",))
        fn = jax.jit(compat.shard_map(
            lambda p, ex=ex: jax.tree.map(lambda l: l[None], ex(p)),
            mesh=mesh, in_specs=({"g": P()},),
            out_specs={"g": P("data", None)},
            axis_names={"data"}, check_vma=False))
        jaxpr = jax.make_jaxpr(fn)(payload)
        outs[arm] = np.asarray(fn(payload)["g"])
        row = {"case": "compare_a2a", "arm": arm, "pattern": "alltoall",
               "workers": W, "total_elems": total, "dest_elems": n_d,
               "collective_ops": sum(
                   _count_collectives(jaxpr, {}).values()),
               "collective_launches": _count_collective_launches(jaxpr),
               "measured_link_bytes": round(_count_link_bytes(jaxpr, W)),
               "wall_s": _time_jitted(fn, (payload,), iters)}
        row.update(acc[arm])
        assert row["measured_link_bytes"] == row["link_bytes"], (
            f"{arm}: jaxpr-counted link bytes "
            f"{row['measured_link_bytes']} != analytic "
            f"{row['link_bytes']}")
        rows.append(row)
        print(f"[compare_a2a] {arm}: rank_payload={row['rank_payload_bytes']} "
              f"link={row['link_bytes']} (jaxpr {row['measured_link_bytes']}) "
              f"collective_ops={row['collective_ops']} "
              f"wall={row['wall_s']:.4f}s")

    # Both wires must merge to the same result bit-for-bit: the exchange
    # codec is lossless-exact at this profile (the train-step parity
    # pins in test_dispatch.py cover the chunked grids and both
    # backends; this is the end-to-end benchmark-side check).
    assert np.array_equal(outs["dense_alltoall"],
                          outs["compressed_alltoall"]), \
        "compressed a2a merge diverged from the dense wire"

    by_arm = {r["arm"]: r for r in rows}
    dense_b = by_arm["dense_alltoall"]["rank_payload_bytes"]
    comp_b = by_arm["compressed_alltoall"]["rank_payload_bytes"]
    print(f"[compare_a2a] compressed per-rank a2a bytes = "
          f"{comp_b / dense_b:.3f}x dense (W={W})")
    assert W > 2, "a2a CI gate needs W > 2 (bootstrap forces 4 devices)"
    assert comp_b < dense_b, (
        "compressed a2a did not undercut the dense wire's per-rank "
        f"bytes at W={W}: {comp_b} >= {dense_b}")
    return rows


def write_normalized(path: str, rows: List[Dict],
                     overlap_rows: List[Dict] = (),
                     auto_rows: List[Dict] = (),
                     a2a_rows: List[Dict] = ()) -> None:
    """Write the compact strategy -> metrics map CI drops at the repo
    root (``BENCH_aggregation.json``) to track the perf trajectory
    across PRs. Rows come from the ``--compare-rs`` / ``--compare-innet``
    arms; later rows win when an arm (e.g. ``dense``) appears in both.
    ``overlap_rows`` (the ``--compare-overlap`` chunk-count sweep, PR 5)
    land under ``"overlap"`` as per-chunk wire/launch/wall rows keyed by
    strategy arm. ``auto_rows`` (the ``--compare-auto`` controller run,
    PR 6) land under ``"auto"``: per-fixed-wire steady walls and
    analytic-vs-jaxpr link bytes, plus the controller's decided plan,
    decision trace, and steady wall ratio (the <= 1.1x CI gate reads
    ``auto.wall_ratio_vs_best_fixed``). ``a2a_rows`` (the
    ``--compare-a2a`` exchange comparison, PR 8 — schema 4) land under
    ``"alltoall"`` keyed by wire arm: per-rank payload/link bytes
    (analytic + jaxpr-measured), collective ops/launches, wall — the
    compressed arm's ``rank_payload_bytes`` must stay strictly below the
    dense arm's (re-checked from the artifact by the CI workflow).

    Sections this invocation produced no rows for are carried over from
    an existing artifact at ``path``: the a2a arm needs 4 forced host
    devices while the timing-gated arms are calibrated at 2, so the CI
    smoke runs them as two processes writing the same artifact.
    """
    keep = ("rank_payload_bytes", "link_bytes", "root_link_bytes",
            "exponent_bytes", "collective_ops", "wall_s", "workers",
            "total_elems")
    strategies = {}
    for r in rows:
        if "arm" not in r:
            continue
        entry = {k: r[k] for k in keep if k in r}
        # byte/op fields are deterministic; wall_s is a per-machine
        # snapshot — round it so the committed copy does not churn on
        # sub-0.1ms timing noise (CI artifacts keep full precision in
        # the --json dump).
        if "wall_s" in entry:
            entry["wall_s"] = round(entry["wall_s"], 4)
        strategies[r["arm"]] = entry
    overlap: Dict[str, List[Dict]] = {}
    for r in overlap_rows:
        overlap.setdefault(r["arm"], []).append({
            "n_chunks": r["n_chunks"],
            "chunk_payload_bytes": r["chunk_payload_bytes"],
            "link_bytes": r["link_bytes"],
            "collective_launches": r["collective_launches"],
            "wall_s": round(r["wall_s"], 4),
        })
    auto: Dict[str, Any] = {}
    for r in auto_rows:
        if r["arm"] == "auto":
            auto.update({
                "plan": r["plan"],
                "chosen_wire": r["chosen_wire"],
                "wall_s": round(r["wall_s"], 4),
                "best_fixed": r["best_fixed"],
                "best_fixed_wall_s": round(r["best_fixed_wall_s"], 4),
                "wall_ratio_vs_best_fixed":
                    round(r["wall_ratio_vs_best_fixed"], 4),
                "decision_trace": r["decision_trace"],
            })
        else:
            auto.setdefault("fixed", {})[r["arm"]] = {
                "wall_s": round(r["wall_s"], 4),
                "link_bytes": r["link_bytes"],
                "measured_link_bytes": r["measured_link_bytes"],
                "collective_ops": r["collective_ops"],
            }
    alltoall: Dict[str, Dict] = {}
    for r in a2a_rows:
        alltoall[r["arm"]] = {
            "pattern": r["pattern"],
            "workers": r["workers"],
            "total_elems": r["total_elems"],
            "rank_payload_bytes": r["rank_payload_bytes"],
            "link_bytes": r["link_bytes"],
            "measured_link_bytes": r["measured_link_bytes"],
            "collective_ops": r["collective_ops"],
            "collective_launches": r["collective_launches"],
            "wall_s": round(r["wall_s"], 4),
        }
    prev: Dict[str, Any] = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
        except (OSError, json.JSONDecodeError):
            prev = {}
    payload = {"schema": 4,
               "strategies": strategies or prev.get("strategies", {}),
               "overlap": overlap or prev.get("overlap", {}),
               "auto": auto or prev.get("auto", {}),
               "alltoall": alltoall or prev.get("alltoall", {})}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")


def _fmt(v):
    return v if isinstance(v, str) else f"{v:.4g}"


def main(fracs=(0.02, 0.05, 0.10, 0.25, 0.60, 1.0),
         backends=("auto",), smoke=False, compare=False, compare_rs_flag=False,
         compare_innet_flag=False, compare_overlap_flag=False,
         compare_auto_flag=False, compare_a2a_flag=False,
         json_path=None, normalized_path=None):
    """One CSV row per (size fraction, compute backend).

    ``--backends never always`` compares the jnp reference codec against
    the Pallas kernels (interpret-emulated off-TPU — on a TPU host
    "always"/"auto" exercises the real kernels and this becomes the
    paper's codec-throughput comparison).
    """
    n = (1 << 16) if smoke else N
    iters = 1 if smoke else 3
    rows: List[Dict] = []
    keys = None
    for frac in fracs:
        for backend in backends:
            r = measure(frac, use_pallas=backend, n=n, iters=iters)
            rows.append(r)
            if keys is None:
                keys = list(r)
                print(",".join(keys))
            print(",".join(_fmt(r[k]) for k in keys))
    bucket_rows = compare_bucketing(smoke=smoke) if compare else []
    rs_rows = compare_rs(smoke=smoke) if compare_rs_flag else []
    innet_rows = compare_innet(smoke=smoke) if compare_innet_flag else []
    overlap_rows = compare_overlap(smoke=smoke) if compare_overlap_flag \
        else []
    auto_rows = compare_auto(smoke=smoke) if compare_auto_flag else []
    a2a_rows = compare_a2a(smoke=smoke) if compare_a2a_flag else []
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"codec": rows, "bucketing": bucket_rows,
                       "compare_rs": rs_rows, "compare_innet": innet_rows,
                       "compare_overlap": overlap_rows,
                       "compare_auto": auto_rows,
                       "compare_a2a": a2a_rows},
                      f, indent=2)
        print(f"wrote {json_path}")
    if normalized_path:
        write_normalized(normalized_path, rs_rows + innet_rows,
                         overlap_rows, auto_rows, a2a_rows)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fracs", type=float, nargs="+",
                    default=(0.02, 0.05, 0.10, 0.25, 0.60, 1.0))
    ap.add_argument("--backends", nargs="+", default=("auto",),
                    choices=("never", "always", "auto"),
                    help="use_pallas policies to compare")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke runs")
    ap.add_argument("--compare-bucketing", action="store_true",
                    help="bucketed aggregator vs the per-leaf architecture")
    ap.add_argument("--compare-rs", action="store_true",
                    help="dense vs compressed vs emulated-RS vs native-RS "
                         "wire bytes, collective counts and wall time")
    ap.add_argument("--compare-innet", action="store_true",
                    help="dense vs compressed vs the in-network tree "
                         "(f32 + fxp32 wires), incl. the emulated "
                         "SwitchModel parity/occupancy pass")
    ap.add_argument("--compare-overlap", action="store_true",
                    help="sweep stream-scheduler wire-chunk counts per "
                         "strategy: collective launches (must scale "
                         "O(n_chunks) on the native RS wire — CI "
                         "gate), per-chunk payload, wall time")
    ap.add_argument("--compare-auto", action="store_true",
                    help="drive the `auto` strategy's online cost-model "
                         "controller through probe -> decide on the toy "
                         "model; CI fails if its steady wall exceeds the "
                         "best fixed strategy's by >10%%")
    ap.add_argument("--compare-a2a", action="store_true",
                    help="dense vs compressed expert-parallel all-to-all "
                         "exchange (the MoE dispatch/combine wire): "
                         "per-rank payload/link bytes, collective "
                         "ops/launches, wall; CI fails if the compressed "
                         "arm's per-rank bytes are not strictly below "
                         "dense at W > 2")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump all rows as a JSON artifact")
    ap.add_argument("--normalized-json", default=None, metavar="PATH",
                    help="also write the compact strategy->metrics map "
                         "(BENCH_aggregation.json at the repo root in CI)")
    args = ap.parse_args()
    main(tuple(args.fracs), tuple(args.backends), smoke=args.smoke,
         compare=args.compare_bucketing, compare_rs_flag=args.compare_rs,
         compare_innet_flag=args.compare_innet,
         compare_overlap_flag=args.compare_overlap,
         compare_auto_flag=args.compare_auto,
         compare_a2a_flag=args.compare_a2a, json_path=args.json,
         normalized_path=args.normalized_json)
