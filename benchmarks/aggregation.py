"""Paper Fig. 5/6 analogue: aggregation throughput vs compressed size.

The paper measures end-to-end aggregation Gbps on 100 Gbps / 10 Gbps
clusters. Without that hardware we measure the two halves we *can*:

  - codec throughput: wall-time of jit'd compress / recover on this host
    (the CPU stand-in for the paper's GPU codec of §3.4), and
  - wire model: bytes on the link for [sketch + index] vs dense bf16,
    turned into aggregation throughput at a given link bandwidth.

Aggregation throughput (paper definition: aggregated gradient volume /
wall time, counting each worker's gradient once) is then
    throughput = orig_bytes / max(t_codec, t_wire)
reported for both the dense baseline and the compressed pipeline.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import CompressionConfig, HomomorphicCompressor, CompressedLeaf

N = 1 << 22                  # 4M f32 gradient (16 MiB) per measurement
SPARSITY = 0.945             # LSTM profile
LINK_GBPS = {"nccl_100g": 100.0, "ici_v5e": 400.0}


def _grad(seed=0):
    r = np.random.default_rng(seed)
    x = np.zeros(N, np.float32)
    k = int(N * (1 - SPARSITY))
    x[r.choice(N, size=k, replace=False)] = r.standard_normal(k).astype(np.float32)
    return jnp.asarray(x)


def measure(frac: float, workers: int = 4, iters: int = 3,
            use_pallas: str = "auto") -> Dict:
    rows = 6 if frac <= 0.4 else 90
    cfg = CompressionConfig(ratio=frac, lanes=512, rows=rows, rounds=16,
                            chunk_blocks=256, use_pallas=use_pallas)
    comp = HomomorphicCompressor(cfg)
    x = _grad()
    compress = jax.jit(comp.compress)
    recover = jax.jit(lambda c: comp.recover(c, N))
    c = compress(x)
    jax.block_until_ready(c)
    xs = [compress(_grad(s)) for s in range(workers)]
    agg = CompressedLeaf(sketch=sum(cc.sketch for cc in xs),
                         index_words=xs[0].index_words)
    for cc in xs[1:]:
        agg = CompressedLeaf(agg.sketch, agg.index_words | cc.index_words)
    jax.block_until_ready(recover(agg))

    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(compress(x))
    t_comp = (time.perf_counter() - t0) / iters
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(recover(agg))
    t_rec = (time.perf_counter() - t0) / iters

    wire = comp.wire_bytes(N, grad_bytes_per_elem=4)
    orig_bytes = N * 4
    out = {"size_frac": frac, "backend": use_pallas,
           "t_compress_s": t_comp, "t_recover_s": t_rec,
           "codec_gbps": orig_bytes * 8 / (t_comp + t_rec) / 1e9,
           "wire_fraction": wire["total_bytes"] / orig_bytes}
    for name, gbps in LINK_GBPS.items():
        bw = gbps * 1e9 / 8
        # ring allreduce: 2 (W-1)/W x bytes on the slowest link
        ring = 2 * (workers - 1) / workers
        t_wire_dense = orig_bytes * ring / bw
        t_wire_comp = wire["total_bytes"] * ring / bw
        thr_dense = orig_bytes * 8 / t_wire_dense / 1e9
        thr_comp = orig_bytes * 8 / max(t_wire_comp, t_comp + t_rec) / 1e9
        out[f"{name}_dense_gbps"] = thr_dense
        out[f"{name}_ours_gbps"] = thr_comp
        out[f"{name}_speedup"] = thr_comp / thr_dense
    return out


def _fmt(v):
    return v if isinstance(v, str) else f"{v:.4g}"


def main(fracs=(0.02, 0.05, 0.10, 0.25, 0.60, 1.0),
         backends=("auto",)):
    """One CSV row per (size fraction, compute backend).

    ``--backends never always`` compares the jnp reference codec against
    the Pallas kernels (interpret-emulated off-TPU — on a TPU host
    "always"/"auto" exercises the real kernels and this becomes the
    paper's codec-throughput comparison).
    """
    keys = None
    for frac in fracs:
        for backend in backends:
            r = measure(frac, use_pallas=backend)
            if keys is None:
                keys = list(r)
                print(",".join(keys))
            print(",".join(_fmt(r[k]) for k in keys))


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fracs", type=float, nargs="+",
                    default=(0.02, 0.05, 0.10, 0.25, 0.60, 1.0))
    ap.add_argument("--backends", nargs="+", default=("auto",),
                    choices=("never", "always", "auto"),
                    help="use_pallas policies to compare")
    args = ap.parse_args()
    main(tuple(args.fracs), tuple(args.backends))
