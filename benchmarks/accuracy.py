"""Paper Fig. 3 + Fig. 4 reproduction: recovery accuracy vs compressed
size, and lossless-vs-Topk at equal compressed size.

The paper sweeps compressed size from 2% to 200% of the original and
shows: once size crosses gamma*(1-sparsity), relative error collapses to
~0 and recovery rate jumps to 100%, with recovery rounds ~ log log n.
We reproduce the sweep for each Table-1 sparsity profile (NCF 98.9%,
LSTM 94.5%, VGG19 30.4%, BERT 20.8% zeros) on synthetic gradients with
the matching support size.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import CompressionConfig, HomomorphicCompressor
from repro.core.blocks import make_plan, to_blocks
from repro.core.sketch import encode_blocks
from repro.core.peeling import peel_blocks
from repro.core.topk import sparsify_topk

TABLE1 = {  # model -> fraction of *zero* parameters ("Average Sparsity")
    "NCF": 0.989,
    "LSTM": 0.945,
    "VGG19": 0.304,
    "BERT-base": 0.208,
}
N = 1 << 20     # 1M-coordinate gradient proxy (fits CPU comfortably)


def _gradient(sparsity: float, seed: int = 0) -> np.ndarray:
    r = np.random.default_rng(seed)
    x = np.zeros(N, np.float32)
    k = int(N * (1 - sparsity))
    idx = r.choice(N, size=k, replace=False)
    x[idx] = r.standard_normal(k).astype(np.float32)
    return x


def _cfg_for_size(frac_of_original: float) -> CompressionConfig:
    """Sketch elements = frac * N (fp32 sketch vs fp32 original, matching
    the paper's element-count convention)."""
    rows = 6
    if frac_of_original > 0.4:
        rows = 30 * 3
    return CompressionConfig(ratio=frac_of_original, lanes=512, rows=rows,
                             rounds=24, chunk_blocks=64)


def sweep(model: str, sizes=None) -> List[Dict]:
    sparsity = TABLE1[model]
    x = _gradient(sparsity, seed=hash(model) % 2**31)
    sizes = sizes or [0.02, 0.05, 0.10, 0.20, 0.40, 0.60, 0.85, 1.0, 1.5, 2.0]
    rows = []
    for frac in sizes:
        cfg = _cfg_for_size(frac)
        plan = make_plan(N, cfg)
        xb = to_blocks(jnp.asarray(x), plan)
        ids = jnp.arange(plan.nb, dtype=jnp.int32)
        y = encode_blocks(xb, ids, cfg)
        res = peel_blocks(y, xb != 0, ids, cfg)
        vals = np.asarray(res.values).reshape(-1)[:N]
        nz = x != 0
        nnz = int(nz.sum())
        rel = np.abs(vals[nz] - x[nz]) / np.abs(x[nz])
        recovery = float(np.asarray(res.peeled).sum()) / max(nnz, 1)
        rows.append({
            "model": model, "size_frac": frac,
            "avg_rel_error": float(np.mean(rel)),
            "recovery_rate": recovery,
            "rounds": int(res.rounds_used),
            "threshold": 1.23 * (1 - sparsity),
        })
    return rows


def topk_comparison(model: str = "VGG19") -> List[Dict]:
    """Fig. 4 analogue: equal *wire bytes*, lossless sketch recovery vs
    vanilla top-k. Top-k ships a coordinate list (4B index + 4B value per
    kept coordinate); we ship sketch + bitmap. Above the peeling threshold
    ours is exact while top-k still truncates; below it top-k wins the L2
    metric (it is the L2-optimal truncation) but is *biased* — the paper's
    convergence argument (unbiased estimates for near-zero params) is
    exercised by tests/drivers/train_step_driver.py instead."""
    x = _gradient(TABLE1[model], seed=7)
    out = []
    for frac in (0.10, 1.0):
        cfg = _cfg_for_size(frac)
        comp = HomomorphicCompressor(cfg)
        c = comp.compress(jnp.asarray(x))
        ours = np.asarray(comp.recover(c, N))
        wire = comp.wire_bytes(N, grad_bytes_per_elem=4)["total_bytes"]
        k = max(1, int(wire / 8))            # same bytes as (idx,val) pairs
        tk = np.asarray(sparsify_topk(jnp.asarray(x), min(k, N)))
        def err(a):
            return float(np.linalg.norm(a - x) / np.linalg.norm(x))
        out.append({"model": model, "size_frac": frac,
                    "wire_bytes": wire, "lossless": frac >= 1.0,
                    "ours_l2_rel": err(ours), "topk_l2_rel": err(tk)})
    return out


def main():
    t0 = time.perf_counter()
    print("model,size_frac,avg_rel_error,recovery_rate,rounds,threshold")
    for model in TABLE1:
        for row in sweep(model):
            print(f"{row['model']},{row['size_frac']:.2f},"
                  f"{row['avg_rel_error']:.4e},{row['recovery_rate']:.4f},"
                  f"{row['rounds']},{row['threshold']:.3f}")
    for cmp_ in topk_comparison():
        print(f"topk_comparison,{cmp_['size_frac']},"
              f"ours={cmp_['ours_l2_rel']:.4f},"
              f"topk={cmp_['topk_l2_rel']:.4f}")
    print(f"# accuracy suite: {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
