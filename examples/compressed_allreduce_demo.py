"""Distributed demo: the paper's pipeline on a multi-device mesh —
8 simulated devices, TP-sharded gradients, sketch psum + OR-AllReduce
ring, lossless recovery. (Runs the same code path the production
train_step uses.)

    PYTHONPATH=src python examples/compressed_allreduce_demo.py
"""
import os
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count=8 "
    "--xla_disable_hlo_passes=all-reduce-promotion")
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.compat import make_mesh, shard_map
from repro.core import CompressionConfig
from repro.core.collectives import (compressed_all_reduce,
                                    init_aggregation_state)

mesh = make_mesh((4, 2), ("data", "model"))
D, F, W = 512, 1024, 4
cfg = CompressionConfig(ratio=0.15)

rng = np.random.default_rng(0)
def grad(seed):
    g = np.zeros(D * F, np.float32)
    idx = rng.choice(g.size, size=int(g.size * 0.01), replace=False)
    g[idx] = rng.standard_normal(idx.size).astype(np.float32)
    return g.reshape(D, F)

per_worker = np.stack([grad(s) for s in range(W)])
mean_ref = per_worker.mean(0)
specs = {"w": P(None, "model")}

def step(stacked):
    g = {"w": stacked[0]}
    st = init_aggregation_state(g, cfg)
    agg, _ = compressed_all_reduce(g, st, specs, mesh, cfg,
                                   dp_axes=("data",), tp_axes=("model",))
    return agg

put = jax.device_put(jnp.asarray(per_worker),
                     NamedSharding(mesh, P("data", None, "model")))
got = jax.jit(shard_map(step, mesh=mesh, in_specs=P("data", None, None),
                            out_specs={"w": P()}, axis_names={"data"},
                            check_vma=False))(put)
err = np.abs(np.asarray(got["w"]) - mean_ref).max()
wire = cfg.wire_bytes(D * F)
print(f"4-worker compressed mean-allreduce max|err| = {err:.2e}")
print(f"wire: {wire['wire_fraction']*100:.1f}% of dense bf16")
assert err < 1e-5
print("OK")
