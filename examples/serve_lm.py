"""Serve a small LM with batched requests + continuous batching
(deliverable b, serving scenario).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np
import jax

from repro.models import ModelConfig, model_api
from repro.serve import ServeEngine, ContinuousBatcher, Request

cfg = ModelConfig(name="serve-demo", family="dense", n_layers=4,
                  d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                  vocab=32_768, dtype="float32", q_block=64)
api = model_api(cfg)
params = api.init(jax.random.PRNGKey(0))
eng = ServeEngine(api, params, max_len=96, batch=4)

rng = np.random.default_rng(0)
prompts = rng.integers(1, cfg.vocab, (4, 16), dtype=np.int32)

t0 = time.perf_counter()
out = eng.generate(prompts, max_new=24)
dt = time.perf_counter() - t0
print(f"batched generate: {out.shape[0]} x {out.shape[1]} tokens "
      f"in {dt:.2f}s")

cb = ContinuousBatcher(eng)
for uid in range(10):
    cb.submit(Request(uid=uid, prompt=rng.integers(1, cfg.vocab, 12,
                                                   dtype=np.int32),
                      max_new_tokens=8))
t0 = time.perf_counter()
done = cb.run(decode_steps=64)
dt = time.perf_counter() - t0
toks = sum(len(c.tokens) for c in done)
print(f"continuous batching: {len(done)} requests / {toks} tokens "
      f"in {dt:.2f}s ({toks/dt:.1f} tok/s)")
assert len(done) == 10
