"""Quickstart: the paper's algorithm in six lines.

Compress two workers' sparse gradients, aggregate the *compressed* forms
(sum the sketches, OR the index words — no decompression in the middle),
and recover the exact aggregated gradient.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import CompressionConfig, HomomorphicCompressor, CompressedLeaf

rng = np.random.default_rng(0)
N = 1_000_000


def sparse_grad(seed, density=0.01):
    r = np.random.default_rng(seed)
    g = np.zeros(N, np.float32)
    idx = r.choice(N, size=int(N * density), replace=False)
    g[idx] = r.standard_normal(idx.size).astype(np.float32)
    return g


g1, g2 = sparse_grad(1), sparse_grad(2)

comp = HomomorphicCompressor(CompressionConfig(ratio=0.10))
s1, s2 = comp.compress(jnp.asarray(g1)), comp.compress(jnp.asarray(g2))

# --- the aggregation API sees only compressed data -------------------
agg = CompressedLeaf(sketch=s1.sketch + s2.sketch,              # psum
                     index_words=s1.index_words | s2.index_words)  # OR

recovered, stats = comp.recover(agg, N, with_stats=True)

err = np.abs(np.asarray(recovered) - (g1 + g2)).max()
wire = comp.wire_bytes(N)
print(f"non-zeros:       {int(stats.nnz):,}")
print(f"peeled exactly:  {int(stats.peeled):,} "
      f"(residual {int(stats.residual)})")
print(f"max |error|:     {err:.2e}")
print(f"wire size:       {wire['wire_fraction']*100:.1f}% of dense bf16")
assert err < 1e-5
print("lossless homomorphic aggregation OK")
