"""End-to-end driver (deliverable b): train a ~100M-param decoder LM for a
few hundred steps with the paper's compressed gradient aggregation, with
checkpointing and an injected failure + recovery along the way.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

On this CPU container it uses a single device; the same script runs
unchanged on a pod (the mesh helper picks up all devices).
"""
import argparse
import tempfile

import jax

from repro.core import CompressionConfig
from repro.ft import FailureSimulator
from repro.launch.mesh import make_host_mesh
from repro.models import ModelConfig, model_api
from repro.parallel.sharding import ShardingProfile
from repro.train import TrainConfig, OptimizerConfig
from repro.train.loop import run_training

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--aggregator", default="compressed",
                choices=["dense", "compressed"])
args = ap.parse_args()

# ~100M params: 8 layers x d512 x ff2048, 32k vocab
cfg = ModelConfig(name="lm100m", family="dense", n_layers=8, d_model=512,
                  n_heads=8, n_kv_heads=4, d_ff=2048, vocab=32_768,
                  dtype="float32", q_block=128)
api = model_api(cfg)
print(f"params: {cfg.param_count()/1e6:.1f}M")

tc = TrainConfig(
    aggregator=args.aggregator,
    compression=CompressionConfig(ratio=0.1, topk_ratio=0.02),
    optimizer=OptimizerConfig(lr=1e-3, warmup_steps=20,
                              total_steps=args.steps),
    sharding=ShardingProfile(zero1=False),
    remat="none")

with tempfile.TemporaryDirectory() as ckpt_dir:
    res = run_training(
        api, tc, make_host_mesh(), global_batch=8, seq_len=128,
        steps=args.steps, ckpt_dir=ckpt_dir, ckpt_every=50,
        failure_sim=FailureSimulator(fail_at_steps=(args.steps // 2,)),
        log_every=20)

print(f"\nloss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} over "
      f"{res.final_step} steps with {res.restarts} recovered failure(s)")
assert res.losses[-1] < res.losses[0]
