"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward/train step on CPU, asserting output shapes + finite values."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import model_api
from repro.data.pipeline import batch_fn


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_forward_and_grad(name):
    arch = get_arch(name)
    cfg = arch.smoke
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    assert n_params > 0
    B, S = 2, 32
    batch = batch_fn(cfg, B, S, seed=1)(0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    def loss_fn(p):
        loss, metrics = api.loss(p, batch)
        return loss, metrics

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"
    assert float(loss) > 0
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{name}: bad grads"


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_decode_step(name):
    arch = get_arch(name)
    cfg = arch.smoke
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, CTX = 2, 16
    cache = api.init_cache(params, B, CTX)
    tok = jnp.ones((B,), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, t, c: api.decode(p, t, c, jnp.int32(3)))(params, tok, cache)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


def test_published_param_counts():
    """Full configs match the published sizes (analytic count)."""
    expect = {
        "qwen2-7b": (7.0e9, 8.2e9),
        "qwen2.5-3b": (3.0e9, 3.7e9),
        "qwen1.5-32b": (30e9, 36e9),
        "granite-3-2b": (2.2e9, 2.9e9),
        "mamba2-1.3b": (1.2e9, 1.45e9),
        "internvl2-2b": (1.7e9, 2.2e9),
        "jamba-v0.1-52b": (49e9, 54e9),
        "deepseek-moe-16b": (15.5e9, 17.5e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.1e12),
        "whisper-tiny": (3.0e7, 4.5e7),
    }
    for name, (lo, hi) in expect.items():
        n = get_arch(name).model.param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo},{hi}]"


def test_moe_active_params():
    a = get_arch("kimi-k2-1t-a32b").model
    assert 30e9 <= a.active_param_count() <= 38e9
    d = get_arch("deepseek-moe-16b").model
    assert 2.0e9 <= d.active_param_count() <= 3.5e9
