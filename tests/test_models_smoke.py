"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward/train step on CPU, asserting output shapes + finite values."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import model_api
from repro.data.pipeline import batch_fn


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_forward_and_grad(name):
    arch = get_arch(name)
    cfg = arch.smoke
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    assert n_params > 0
    B, S = 2, 32
    batch = batch_fn(cfg, B, S, seed=1)(0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    def loss_fn(p):
        loss, metrics = api.loss(p, batch)
        return loss, metrics

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"
    assert float(loss) > 0
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{name}: bad grads"


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_decode_step(name):
    arch = get_arch(name)
    cfg = arch.smoke
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, CTX = 2, 16
    cache = api.init_cache(params, B, CTX)
    tok = jnp.ones((B,), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, t, c: api.decode(p, t, c, jnp.int32(3)))(params, tok, cache)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


def test_published_param_counts():
    """Full configs match the published sizes (analytic count)."""
    expect = {
        "qwen2-7b": (7.0e9, 8.2e9),
        "qwen2.5-3b": (3.0e9, 3.7e9),
        "qwen1.5-32b": (30e9, 36e9),
        "granite-3-2b": (2.2e9, 2.9e9),
        "mamba2-1.3b": (1.2e9, 1.45e9),
        "internvl2-2b": (1.7e9, 2.2e9),
        "jamba-v0.1-52b": (49e9, 54e9),
        "deepseek-moe-16b": (15.5e9, 17.5e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.1e12),
        "whisper-tiny": (3.0e7, 4.5e7),
    }
    for name, (lo, hi) in expect.items():
        n = get_arch(name).model.param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo},{hi}]"


def test_hybrid_moe_cadence_follows_config():
    """PR 8 satellite: the hybrid family's MoE cadence comes from
    ``MoEConfig.every_k_layers`` — it was hardcoded to every-other-layer,
    so only jamba's k=2 counted correctly.  Pin jamba-style analytic
    counts at k=3 against a by-hand sum, and check init agrees with the
    analytic count at a non-default cadence too."""
    import dataclasses
    from repro.models.config import ModelConfig, MoEConfig, SSMConfig

    base = get_arch("jamba-v0.1-52b").model
    assert base.moe.every_k_layers == 2

    def expect(cfg):
        # independent recomputation: every_k=k -> MoE on layers with
        # l % k == k - 1, dense FFN elsewhere
        k = cfg.moe.every_k_layers
        n_moe = sum(1 for l in range(cfg.n_layers) if l % k == k - 1)
        m = cfg.moe
        moe_p = (cfg.d_model * m.num_experts
                 + (m.num_experts + m.shared_experts)
                 * 3 * cfg.d_model * m.expert_d_ff)
        dense_p = 3 * cfg.d_model * cfg.d_ff
        # swap cadences against the k=1 (all-MoE) reference
        all_moe = dataclasses.replace(cfg, moe=dataclasses.replace(
            m, every_k_layers=1))
        return all_moe.param_count() - (cfg.n_layers - n_moe) * (
            moe_p - dense_p)

    for k in (1, 2, 3, 4):
        cfg = dataclasses.replace(base, moe=dataclasses.replace(
            base.moe, every_k_layers=k))
        assert cfg.param_count() == expect(cfg), f"k={k}"
    # k=3 on a 32-layer model: 10 MoE layers, not 16
    cfg3 = dataclasses.replace(base, moe=dataclasses.replace(
        base.moe, every_k_layers=3))
    assert cfg3.param_count() < base.param_count()
    assert cfg3.active_param_count() < base.active_param_count()

    # init must lay down MoE params exactly where the analytic count
    # assumes: smoke-size hybrid, k=3, superblock of 6
    smoke = dataclasses.replace(
        get_arch("jamba-v0.1-52b").smoke, n_layers=6, attn_period=6,
        attn_offset=1,
        moe=dataclasses.replace(get_arch("jamba-v0.1-52b").smoke.moe,
                                every_k_layers=3))
    params = model_api(smoke).init(jax.random.PRNGKey(0))
    sup = params["superblocks"]
    moe_pos = sorted(int(k[3:]) for k in sup if "moe" in sup[k])
    assert moe_pos == [2, 5], moe_pos
    n_params = sum(x.size for x in jax.tree.leaves(params))
    # embedding uses the 128-padded vocab, and the analytic SSM block is
    # a close approximation — allow 1% while still catching a cadence
    # mismatch (one swapped MoE/dense FFN here is a ~25% shift)
    pad = (smoke.padded_vocab - smoke.vocab) * smoke.d_model
    pad *= 1 if smoke.tie_embeddings else 2
    assert abs(n_params - (smoke.param_count() + pad)) < 0.01 * n_params, \
        (n_params, smoke.param_count(), pad)


def test_moe_active_params():
    a = get_arch("kimi-k2-1t-a32b").model
    assert 30e9 <= a.active_param_count() <= 38e9
    d = get_arch("deepseek-moe-16b").model
    assert 2.0e9 <= d.active_param_count() <= 3.5e9
