"""Per-bucket wire planning (PR 6): the WirePlan partition type, the
analytic cost model, and the online AutoWireController — all host-side
logic, no collectives (the execute half is covered by test_dispatch and
the multi-device drivers).

The controller tests drive ``plan``/``observe`` with *synthetic* wall
clocks so the probe -> decide arc is deterministic: measured walls must
override the analytic priors, occupancy must veto compressed wires per
bucket, and the decided plan must be stable across replan windows.
"""
import dataclasses
import json
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core.bucketing import make_bucket_plan
from repro.core.config import CompressionConfig
from repro.core.wireplan import (WIRES, WireGroup, WirePlan,
                                 plan_from_assignments, uniform_plan)

# ratio=1.0 -> block_elems=768; two blocks per bucket -> a 6-bucket
# stream for the ~9K-element tree below (mirrors test_dispatch's AGG_BASE)
CFG = CompressionConfig(ratio=1.0, lanes=128, rows=6, rounds=10,
                        chunk_blocks=4, bucket_bytes=2 * 768 * 4,
                        replan_every=4)


def _bucket_plan(n_buckets=6):
    tree = {"a": jnp.zeros(n_buckets * 1536 - 10, jnp.float32)}
    plan = make_bucket_plan(tree, CFG)
    assert plan.n_buckets == n_buckets
    return plan


# ----------------------------------------------------------------------
# WirePlan / WireGroup
# ----------------------------------------------------------------------

def test_wires_match_registry():
    """Satellite 1: the controller's search space is enumerated from the
    aggregator registry, and the registry stays in sync with WIRES."""
    from repro.core.aggregators import AGGREGATORS
    assert set(WIRES) == set(AGGREGATORS) - {"auto"}
    assert set(cm.fixed_wires()) == set(WIRES)
    assert "auto" in AGGREGATORS


def test_uniform_plan_trivial():
    p = uniform_plan(6, "compressed")
    assert p.is_trivial and p.uniform_wire == "compressed"
    assert [p.wire_of(b) for b in range(6)] == ["compressed"] * 6
    # a chunk override is not trivial: it must reach the group executor
    pc = uniform_plan(6, "compressed", stream_chunks=3)
    assert not pc.is_trivial and pc.uniform_wire == "compressed"


def test_mixed_plan_properties():
    p = WirePlan(6, (WireGroup(0, 2, "dense"),
                     WireGroup(2, 2, "compressed"),
                     WireGroup(4, 2, "compressed_rs")))
    assert p.uniform_wire is None and not p.is_trivial
    assert p.wire_of(0) == "dense"
    assert p.wire_of(3) == "compressed"
    assert p.wire_of(5) == "compressed_rs"
    assert "dense" in p.describe() and "[2:4]" in p.describe()


@pytest.mark.parametrize("groups", [
    (),                                              # empty
    (WireGroup(0, 5, "dense"),),                     # short coverage
    (WireGroup(1, 5, "dense"),),                     # gap at the front
    (WireGroup(0, 4, "dense"), WireGroup(3, 3, "compressed")),  # overlap
    (WireGroup(0, 4, "dense"), WireGroup(5, 1, "compressed")),  # hole
])
def test_plan_rejects_non_tilings(groups):
    with pytest.raises(ValueError):
        WirePlan(6, tuple(groups))


def test_group_validation():
    with pytest.raises(ValueError):
        WireGroup(0, 2, "quantum")          # not a wire
    with pytest.raises(ValueError):
        WireGroup(0, 0, "dense")            # empty group
    with pytest.raises(ValueError):
        WireGroup(-1, 2, "dense")           # negative start
    with pytest.raises(ValueError):
        WireGroup(0, 2, "compressed", stream_chunks=0)
    with pytest.raises(ValueError):
        WireGroup(0, 2, "dense", stream_chunks=2)   # dense has no chunks


def test_plan_from_assignments_coalesces():
    p = plan_from_assignments(["dense", "dense", "compressed",
                              "compressed", "compressed", "dense"])
    assert [(g.start, g.n_buckets, g.wire) for g in p.groups] == [
        (0, 2, "dense"), (2, 3, "compressed"), (5, 1, "dense")]
    assert plan_from_assignments(["dense"] * 4).is_trivial


# ----------------------------------------------------------------------
# The pattern axis (PR 8)
# ----------------------------------------------------------------------

def test_pattern_wires_and_exchange_registry_in_sync():
    from repro.core.aggregators import EXCHANGES
    from repro.core.wireplan import PATTERNS, pattern_wires
    assert set(PATTERNS) == {"allreduce", "alltoall"}
    assert pattern_wires("allreduce") == WIRES
    assert set(pattern_wires("alltoall")) == set(EXCHANGES)
    with pytest.raises(ValueError, match="unknown pattern"):
        pattern_wires("broadcast")


def test_group_rejects_pattern_incapable_wire():
    # RS/innet wires are reduce-tree refinements of all-reduce; they have
    # no permute analogue and must be rejected on the alltoall pattern
    for wire in ("compressed_rs", "compressed_innet"):
        with pytest.raises(ValueError,
                           match=f"wire '{wire}' cannot run the 'alltoall'"):
            WireGroup(0, 2, wire, pattern="alltoall")
    with pytest.raises(ValueError, match="unknown pattern"):
        WireGroup(0, 2, "dense", pattern="gossip")
    # the capable pair is accepted
    assert WireGroup(0, 2, "dense", pattern="alltoall").pattern == "alltoall"
    assert WireGroup(0, 2, "compressed", pattern="alltoall").stop == 2


def test_plan_rejects_mixed_patterns():
    groups = (WireGroup(0, 3, "compressed"),
              WireGroup(3, 3, "compressed", pattern="alltoall"))
    with pytest.raises(ValueError,
                       match="must be single-pattern.*allreduce or the "
                             "alltoall shape"):
        WirePlan(6, groups)


def test_uniform_plan_pattern_and_describe():
    p = uniform_plan(6, "compressed", pattern="alltoall")
    assert p.pattern == "alltoall"
    assert p.describe().endswith("@alltoall")
    # default stays allreduce and existing describe() output is unchanged
    q = uniform_plan(6, "compressed")
    assert q.pattern == "allreduce"
    assert "@" not in q.describe()
    # positional back-compat: pattern rides after stream_chunks
    g = WireGroup(0, 6, "compressed", 3, "alltoall")
    assert g.stream_chunks == 3 and g.pattern == "alltoall"


# ----------------------------------------------------------------------
# BucketPlan.group_view / StreamPlan.base_block (the execute-side seams)
# ----------------------------------------------------------------------

def test_group_view_geometry():
    plan = _bucket_plan()
    g = plan.group_view(2, 2)
    assert g.n_buckets == 2 and g.bucket_elems == plan.bucket_elems
    assert g.total == 2 * plan.bucket_elems
    # the LAST group's view stops at the stream's true element count so
    # its padding region reconstructs exactly
    tail = plan.group_view(4, 2)
    assert tail.total == plan.total - 4 * plan.bucket_elems
    with pytest.raises(ValueError):
        plan.group_view(5, 2)
    with pytest.raises(ValueError):
        plan.group_view(0, 0)


def test_stream_plan_base_block_offsets():
    from repro.core.streams import make_stream_plan
    plan = _bucket_plan()
    sp0 = make_stream_plan(plan, CFG)
    sp2 = make_stream_plan(plan, CFG, base_block=7)
    assert sp0.chunk_start_block(1) + 7 == sp2.chunk_start_block(1)


# ----------------------------------------------------------------------
# Analytic cost model
# ----------------------------------------------------------------------

def test_analytic_costs_and_plan():
    plan = _bucket_plan()
    costs = cm.analytic_bucket_costs(plan, CFG, workers=4)
    assert set(costs) == set(WIRES)
    assert all(c >= 0 and math.isfinite(c) for c in costs.values())
    # compressed wires pay the codec term on top of the link term
    assert costs["compressed"] > 0
    p = cm.analytic_plan(plan, CFG, workers=4)
    assert p.uniform_wire in WIRES and p.n_buckets == plan.n_buckets


def test_analytic_plan_single_worker_is_dense():
    # W=1: zero link traffic everywhere, but the compressed wires still
    # pay the codec -> dense is free and must win
    plan = _bucket_plan()
    assert cm.analytic_plan(plan, CFG, workers=1).uniform_wire == "dense"


def test_occupancy_feasibility_margin():
    cap = CFG.peel_capacity / CFG.block_elems
    assert cm.occupancy_feasible(0.0, CFG)
    assert cm.occupancy_feasible(0.9 * CFG.auto_occupancy_margin * cap, CFG)
    assert not cm.occupancy_feasible(1.01 * CFG.auto_occupancy_margin * cap,
                                     CFG)


def test_finest_chunks():
    assert cm._finest_chunks("dense", 6, 4, CFG) is None
    assert cm._finest_chunks("compressed", 6, 4, CFG) == 6
    assert cm._finest_chunks("compressed_rs", 6, 4, CFG) == 2
    slots = CFG.switch_slots
    assert cm._finest_chunks("compressed_innet", 6, 4, CFG) == -(-6 // slots)


# ----------------------------------------------------------------------
# The online controller
# ----------------------------------------------------------------------

def _drive(ctl, steps, walls, occupancy=None):
    """Run the controller against synthetic walls: every uniform plan's
    wall is the probed wire's entry in ``walls``; mixed plans cost the
    bucket-weighted mix."""
    for step in range(steps):
        p = ctl.plan(step)
        w = p.uniform_wire
        if w is not None:
            wall = walls[w]
        else:
            wall = sum(walls[g.wire] * g.n_buckets for g in p.groups) \
                / p.n_buckets
        tel = None if occupancy is None else \
            {"bucket_occupancy": occupancy}
        ctl.observe(wall, tel)
    return ctl.plan(steps)


WALLS = {"dense": 0.0030, "compressed": 0.0055,
         "compressed_rs": 0.0050, "compressed_innet": 0.0060}


def test_controller_probes_every_wire_then_decides():
    plan = _bucket_plan()
    ctl = cm.AutoWireController(plan, CFG, workers=4)
    final = _drive(ctl, 10 * CFG.replan_every, WALLS)
    trace = ctl.decision_trace()
    assert not trace["probing"]
    # measured walls overrode the analytic prior: dense wins
    assert final.uniform_wire == "dense"
    probed = {k.split("/")[0] for k in trace["measured_wall_s"]}
    assert probed == set(WIRES), "controller skipped a wire probe"


def test_controller_occupancy_vetoes_compressed_buckets():
    plan = _bucket_plan()
    ctl = cm.AutoWireController(plan, CFG, workers=4)
    walls = dict(WALLS, compressed=0.0010)   # compressed is fastest...
    occ = [0.01] * plan.n_buckets
    occ[2] = occ[3] = 0.99                   # ...but 2 buckets can't peel
    final = _drive(ctl, 10 * CFG.replan_every, walls, occupancy=occ)
    assert [(g.start, g.stop, g.wire) for g in final.groups] == [
        (0, 2, "compressed"), (2, 4, "dense"), (4, 6, "compressed")]


def test_controller_plan_static_within_window():
    plan = _bucket_plan()
    ctl = cm.AutoWireController(plan, CFG, workers=4)
    plans = [ctl.plan(s) for s in range(CFG.replan_every)]
    assert all(p == plans[0] for p in plans), \
        "plan changed inside a replan window (would retrigger compiles)"


def test_decision_trace_is_json_serializable():
    plan = _bucket_plan()
    ctl = cm.AutoWireController(plan, CFG, workers=4)
    _drive(ctl, 6 * CFG.replan_every, WALLS,
           occupancy=[0.05] * plan.n_buckets)
    trace = ctl.decision_trace()
    rt = json.loads(json.dumps(trace))
    assert rt["plan"][0]["wire"] in WIRES
    assert rt["occupancy"]["max"] >= rt["occupancy"]["min"]
    assert set(rt["analytic_bucket_cost_s"]) == set(WIRES)


def test_controller_mixed_plan_wall_not_attributed():
    """A mixed plan's wall trains no single wire's EWMA (its cost is a
    sum of already-measured parts)."""
    plan = _bucket_plan()
    ctl = cm.AutoWireController(plan, CFG, workers=4)
    mixed = WirePlan(6, (WireGroup(0, 3, "dense"),
                         WireGroup(3, 3, "compressed")))
    assert ctl._plan_key(mixed) is None
    assert ctl._plan_key(uniform_plan(6, "dense")) == ("dense", None)
    assert ctl._plan_key(uniform_plan(6, "compressed", stream_chunks=3)) \
        == ("compressed", 3)
