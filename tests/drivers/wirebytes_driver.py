"""Multi-device driver (PR 6 satellite): cross-check the analytic wire
accounting (``CompressionConfig.strategy_wire_bytes``) against the bytes
the launched collectives actually move, counted off the jaxpr with the
benchmark's ``_count_link_bytes`` model — at W=2 and W=4, for all four
fixed strategies.

Per strategy:

- ``dense``                 — per-link bytes of the leaf psums must equal
  ``link_bytes`` exactly (ring AllReduce, ``2(W-1)/W x`` payload).
- ``compressed``            — sketch psum at the ring factor plus the
  bitmap OR. On a leg with partial-auto ppermute the bitmap rides the
  OR-ring at the same factor and the total equals ``link_bytes``
  exactly; on the pinned 0.4.x leg ``or_allreduce`` is psum-emulated at
  the documented ``or_emulated_factor`` (32x) — after dividing that
  factor back out of the index traffic, the totals must still agree.
- ``compressed_rs`` native  — psum_scatter sketch + OR-Reduce-Scatter
  bitmap + recovered-chunk all_gather; ppermute-based and full-manual,
  so it must equal ``link_bytes`` (gather included) exactly on BOTH legs.
- ``compressed_rs`` emulate — AllReduce wire (psum + local slice): same
  expected bytes as ``compressed`` — plus the recovered-chunk all_gather
  the implementation launches to reassemble the per-rank peeled chunks.
  The analytic entry deliberately models only the AllReduce wire
  (``compressed_rs_emulated == compressed``, pinned by
  test_collectives), so the gather term is added here from the native
  entry's ``rs_gather_link_bytes`` (same collective, same bytes).
- ``compressed_innet``      — its analytic numbers model the *switch
  tree* (payload crosses each link once), which the in-mesh ppermute
  emulation cannot reproduce (reduce-to-root reships the payload per
  tier). Cross-checked instead by (a) the wire-model self-consistency
  ``link_bytes == rank_payload_bytes == root_link_bytes`` (+ per-bucket
  exponent metadata on the fxp32 wire only), and (b) the f32 arm's
  output being bit-identical to ``compressed`` (same payload objects on
  the wire).

The stream is sized so the packed bitmap is >= 64 KiB: above
``or_allreduce``'s ring threshold, so the ppermute leg takes the
bandwidth-optimal ring at W=4 (recursive doubling would cost
``log2(W) x`` instead and the cross-check would be leg-dependent).
"""
import os
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count=8 "
    "--xla_disable_hlo_passes=all-reduce-promotion")
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))
from benchmarks.aggregation import _count_collectives, _count_link_bytes

from repro import compat
from repro.core import CompressionConfig
from repro.core import collectives as coll
from repro.core.aggregators import make_aggregator
from repro.core.collectives import AggregationState

# block_elems = round(6/0.3)*128 = 2560; 16 buckets of 16 blocks each.
# Total 655360 elems -> packed bitmap = 655360/8 = 81920 bytes >= 64 KiB
# (forces the OR-ring on the ppermute leg), and the 20480 bitmap words
# divide evenly into W in {2, 4} ring chunks (no ring padding slack).
N = 2560 * 16 * 16
cfg = CompressionConfig(ratio=0.3, lanes=128, rows=6, rounds=10,
                        chunk_blocks=64, use_pallas="never",
                        bucket_bytes=2560 * 16 * 4)

EMULATED_OR = not compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE
print(f"leg: or_allreduce {'psum-emulated (0.4.x)' if EMULATED_OR else 'ppermute ring'}")


def dyadic(n, seed, frac=0.03):
    r = np.random.default_rng(seed)
    x = np.zeros(n, np.float32)
    k = int(n * frac)
    idx = r.choice(n, size=k, replace=False)
    x[idx] = (r.choice([-1.0, 1.0], size=k)
              * np.exp2(r.integers(-2, 3, size=k))).astype(np.float32)
    return x


for W in (2, 4):
    mesh = compat.make_mesh((W,), ("data",), devices=jax.devices()[:W])
    tree = {"g": dyadic(N, seed=0)}
    stacked = {"g": jnp.asarray(np.stack(
        [dyadic(N, seed=w) for w in range(W)]))}
    put = jax.device_put(stacked, NamedSharding(mesh, P("data", None)))
    in_specs = {"g": P("data", None)}
    out_specs = {"g": P()}

    acc = cfg.strategy_wire_bytes(N, W, grad_bytes_per_elem=4)
    wb = cfg.wire_bytes(N, grad_bytes_per_elem=4)
    nb = wb["n_buckets"]
    sketch_full = nb * wb["bucket_sketch_bytes"]
    idx_full = nb * wb["bucket_index_bytes"]
    ring = 2 * (W - 1) / W

    def jaxpr_of(name, rs_wire="auto", wire_dtype="f32"):
        import dataclasses
        cfg_a = dataclasses.replace(cfg, rs_wire=rs_wire,
                                    wire_dtype=wire_dtype)
        agg = make_aggregator(name, cfg_a, mesh, ("data",), (),
                              outer_manual=("data",))

        def path(grads):
            specs = {"g": P()}
            res = coll.init_aggregation_state(grads, cfg_a).residual
            out, _ = agg(grads, AggregationState(residual=res), specs)
            return out

        fn = jax.jit(compat.shard_map(
            lambda st: path(jax.tree.map(lambda a: a[0], st)),
            mesh=mesh, in_specs=(in_specs,), out_specs=out_specs,
            axis_names={"data"}, check_vma=False))
        return fn, jax.make_jaxpr(fn)(put)

    # ---- dense: exact ------------------------------------------------
    _, jx = jaxpr_of("dense")
    got = _count_link_bytes(jx, W)
    want = acc["dense"]["link_bytes"]
    assert round(got) == want, (W, "dense", got, want)
    print(f"OK W={W} dense: measured {round(got)} == analytic {want}")

    # ---- compressed + emulated RS: AllReduce wire --------------------
    or_factor = acc["or_emulated_factor"] if EMULATED_OR else 1
    emu_gather = acc["compressed_rs_native"]["rs_gather_link_bytes"]
    for name, rs_wire, key, extra in (
            ("compressed", "auto", "compressed", 0),
            ("compressed_rs", "emulate", "compressed_rs_emulated",
             emu_gather)):
        _, jx = jaxpr_of(name, rs_wire=rs_wire)
        got = _count_link_bytes(jx, W)
        want = ring * (sketch_full + or_factor * idx_full) + extra
        assert round(got) == round(want), (W, key, got, want)
        # dividing the documented emulation factor (and the emulated
        # arm's recovered-chunk gather) back out of the traffic must
        # recover the analytic link accounting
        normalized = got - ring * (or_factor - 1) * idx_full - extra
        assert abs(normalized - acc[key]["link_bytes"]) <= 1, \
            (W, key, normalized, acc[key]["link_bytes"])
        print(f"OK W={W} {key}: measured {round(got)} == "
              f"sketch*ring + {or_factor}x index*ring"
              + (f" + gather {extra}" if extra else "")
              + f" (analytic {acc[key]['link_bytes']})")

    # ---- native RS: ppermute wire, exact on both legs ----------------
    _, jx = jaxpr_of("compressed_rs", rs_wire="native")
    got = _count_link_bytes(jx, W)
    want = acc["compressed_rs_native"]["link_bytes"]
    assert round(got) == want, (W, "rs_native", got, want)
    counts = _count_collectives(jx, {})
    assert any(k.startswith(("psum_scatter", "reduce_scatter"))
               for k in counts), counts
    print(f"OK W={W} compressed_rs_native: measured {round(got)} == "
          f"analytic {want} (incl. gather)")
    # rank payload really is the 1/W slice
    assert acc["compressed_rs_native"]["rank_payload_bytes"] \
        == (sketch_full + idx_full) // W

    # ---- innet: model self-consistency + f32 == compressed -----------
    for wd in ("f32", "fxp32"):
        import dataclasses
        acc_w = dataclasses.replace(cfg, wire_dtype=wd).strategy_wire_bytes(
            N, W, grad_bytes_per_elem=4)
        e = acc_w["compressed_innet"]
        assert e["link_bytes"] == e["rank_payload_bytes"] \
            == e["root_link_bytes"], (W, wd, e)
        assert e["exponent_bytes"] == (nb * 4 if wd == "fxp32" else 0)
        assert e["rank_payload_bytes"] == sketch_full + idx_full \
            + e["exponent_bytes"]
    fn_c, _ = jaxpr_of("compressed")
    fn_i, _ = jaxpr_of("compressed_innet")
    out_c = np.asarray(fn_c(put)["g"])
    out_i = np.asarray(fn_i(put)["g"])
    assert np.array_equal(out_c, out_i), \
        "innet f32 output diverged from compressed"
    print(f"OK W={W} compressed_innet: wire model self-consistent, "
          "f32 arm == compressed bitwise")

    # ---- all-to-all exchange (PR 8): W-1 permute lanes, exact --------
    # The mesh's single manual axis makes the region full-manual, so the
    # native ppermute wire runs on BOTH legs and each rank ships exactly
    # (W-1)/W of its stacked payload: the analytic *_alltoall entries
    # must match the jaxpr-counted bytes with no emulation factor. N/W
    # fills the per-destination bucket grid exactly (no padding slack).
    from repro.core.aggregators import make_exchange
    n_d = N // W
    assert n_d % cfg.bucket_elems_for(n_d) == 0
    a2a_payload = {"g": jnp.asarray(np.stack(
        [dyadic(n_d, seed=100 + w) for w in range(W)]))}
    for wire in ("dense_alltoall", "compressed_alltoall"):
        ex = make_exchange(wire.split("_")[0], cfg, mesh, ("data",),
                           outer_manual=("data",))
        fn = jax.jit(compat.shard_map(
            lambda p, ex=ex: jax.tree.map(lambda l: l[None], ex(p)),
            mesh=mesh, in_specs=({"g": P()},),
            out_specs={"g": P("data", None)},
            axis_names={"data"}, check_vma=False))
        jx = jax.make_jaxpr(fn)(a2a_payload)
        got = _count_link_bytes(jx, W)
        want = acc[wire]["link_bytes"]
        assert round(got) == want, (W, wire, got, want)
        print(f"OK W={W} {wire}: measured {round(got)} == analytic {want}")
    assert acc["compressed_alltoall"]["rank_payload_bytes"] \
        < acc["dense_alltoall"]["rank_payload_bytes"], \
        "compressed a2a must undercut dense per-rank bytes at this ratio"

print("ALL OK")
