"""Multi-device driver: run with XLA_FLAGS=--xla_force_host_platform_device_count=8.

Validates, on a (2, 2, 2) pod/data/model mesh:
  1. or_allreduce (ring + doubling) == numpy bitwise-or reduce
  2. compressed_all_reduce of a TP-sharded gradient pytree == mean of
     per-worker gradients (within fp tolerance), via nested shard_map.
"""
import os
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count=8 "
    "--xla_disable_hlo_passes=all-reduce-promotion")
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.compat import make_mesh, shard_map
from repro.core import CompressionConfig
from repro.core.collectives import (
    or_allreduce, compressed_all_reduce, dense_all_reduce,
    init_aggregation_state)

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(0)

# ---- 1. OR-allreduce ------------------------------------------------
W = 4  # pod*data workers
words = rng.integers(0, 2**32, size=(W, 4096), dtype=np.uint32)
expect = np.bitwise_or.reduce(words, axis=0)

def or_fn(x):
    return or_allreduce(x, ("pod", "data"))

# lay the 4 distinct worker payloads over (pod,data); replicate over model
x = jnp.asarray(words.reshape(2, 2, 4096))
sh = NamedSharding(mesh, P("pod", "data", None))
got = jax.jit(shard_map(
    lambda a: or_fn(a[0, 0]),
    mesh=mesh, in_specs=P("pod", "data", None),
    out_specs=P(), axis_names={"pod", "data"}, check_vma=False,
))(jax.device_put(x, sh))
assert np.array_equal(np.asarray(got), expect), "OR-allreduce mismatch"
print("OK or_allreduce hierarchical")

# ring + doubling individually over one axis. Full-manual region: on
# 0.4.x the partitioner cannot run ppermute while other axes stay auto
# (see repro.compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE), and taking every
# axis manual tests the collective itself on every JAX.
words2 = rng.integers(0, 2**32, size=(2, 100_000), dtype=np.uint32)
from repro.core.collectives import or_allreduce_ring, or_allreduce_doubling
for name, fn in [("ring", or_allreduce_ring), ("doubling", or_allreduce_doubling)]:
    got2 = jax.jit(shard_map(
        lambda a, fn=fn: fn(a[0], "pod"),
        mesh=mesh, in_specs=P("pod", None), out_specs=P(),
        axis_names={"pod", "data", "model"}, check_vma=False,
    ))(jax.device_put(jnp.asarray(words2.reshape(2, 1, -1)[:, 0]),
                      NamedSharding(mesh, P("pod", None))))
    assert np.array_equal(np.asarray(got2), np.bitwise_or.reduce(words2, 0)), name
    print(f"OK or_allreduce_{name}")

# ---- 2. compressed_all_reduce on a TP-sharded pytree ----------------
cfg = CompressionConfig(ratio=0.25, rounds=10, lanes=512, chunk_blocks=64)
D, F = 256, 512
n_workers = 4


def make_grads(seed):
    r = np.random.default_rng(seed)
    def sparse(shape, frac=0.04):
        g = np.zeros(np.prod(shape), np.float32)
        idx = r.choice(g.size, size=int(g.size * frac), replace=False)
        g[idx] = r.normal(size=idx.size).astype(np.float32)
        return g.reshape(shape)
    return {"w1": sparse((D, F)), "w2": sparse((F, D)), "scale": sparse((D,), 0.1)}


per_worker = [make_grads(s) for s in range(n_workers)]
mean_ref = jax.tree.map(lambda *g: np.mean(g, axis=0), *per_worker)

specs = {"w1": P(None, "model"), "w2": P("model", None), "scale": P()}

# global arrays whose (pod,data) shard w is per_worker[w]
stacked = jax.tree.map(lambda *g: np.stack(g).reshape((2, 2) + g[0].shape), *per_worker)


def outer(grads_stacked):
    grads = jax.tree.map(lambda a: a[0, 0], grads_stacked)  # this worker's grads
    params_like = jax.tree.map(lambda a: a, grads)
    st = init_aggregation_state(params_like, cfg)
    agg, _ = compressed_all_reduce(grads, st, specs, mesh, cfg,
                                   dp_axes=("pod", "data"), tp_axes=("model",))
    return agg


in_specs = {"w1": P("pod", "data", None, None),
            "w2": P("pod", "data", None, None),
            "scale": P("pod", "data")}
# model placement is auto: apply via device_put sharding below
put_specs = {"w1": P("pod", "data", None, "model"),
             "w2": P("pod", "data", "model", None),
             "scale": P("pod", "data")}
out_specs = {"w1": P(), "w2": P(), "scale": P()}  # model placement is auto

put = jax.tree.map(
    lambda a, s: jax.device_put(jnp.asarray(a), NamedSharding(mesh, s)),
    stacked, put_specs, is_leaf=lambda x: isinstance(x, np.ndarray))

got = jax.jit(shard_map(outer, mesh=mesh, in_specs=(in_specs,),
                            out_specs=out_specs,
                            axis_names={"pod", "data"}, check_vma=False))(put)
got = jax.tree.map(np.asarray, got)
for k in ("w1", "w2", "scale"):
    ok = np.allclose(got[k], mean_ref[k], atol=1e-5)
    print(f"{'OK' if ok else 'FAIL'} compressed_all_reduce[{k}] maxerr={np.abs(got[k]-mean_ref[k]).max():.2e}")
    assert ok, k

# dense baseline for comparison
got_d = jax.jit(shard_map(
    lambda gs: dense_all_reduce(jax.tree.map(lambda a: a[0, 0], gs), ("pod", "data")),
    mesh=mesh, in_specs=(in_specs,), out_specs=out_specs,
    axis_names={"pod", "data"}, check_vma=False))(put)
for k in ("w1", "w2", "scale"):
    assert np.allclose(np.asarray(got_d[k]), mean_ref[k], atol=1e-6), k
print("OK dense_all_reduce baseline")
print("ALL OK")
