"""Multi-device driver: run with XLA_FLAGS=--xla_force_host_platform_device_count=8.

Validates, on a (2, 2, 2) pod/data/model mesh:
  1. or_allreduce (ring + doubling) == numpy bitwise-or reduce
  2. compressed_all_reduce of a TP-sharded gradient pytree == mean of
     per-worker gradients (within fp tolerance), via the bucketed
     aggregator (nested shard_map packing where supported).
  3. bucketed compressed aggregation with topk_ratio + error_feedback
     matches the pre-bucketing per-leaf path BIT-FOR-BIT over 3 steps
     (residual roundtrip included; reference computed per leaf with the
     same sparsifier, dyadic values so every psum order is exact), and
     the overlap-pipelined schedule matches the fused one bitwise.
  4. the reduce-scatter aggregator (per-rank bucket peeling) matches the
     dense mean like the plain one.
  5. multi-axis hierarchical OR-AllReduce with a non-power-of-2 *inner*
     axis (a (2, 3) pod/data mesh) == numpy, on whichever wire this JAX
     leg takes (ring+doubling vs psum emulation), the explicit
     ring-then-doubling composition, and the chunked psum emulation ==
     unchunked bit-for-bit.
  6. or_reduce_scatter: every rank's chunk reassembles to the numpy OR
     reduce (power-of-2 and non-power-of-2 axes, single and multi axis,
     rank-major chunk order pinned against psum_scatter's).
  7. the native reduce-scatter wire (psum_scatter sketch + OR-RS bitmap,
     full-manual region so it runs on BOTH JAX legs) is bit-identical to
     the emulated psum+slice wire and to CompressedAggregator over 3
     error-feedback steps.
  8. the in-network tier (PR 4): tree_all_reduce (ppermute reduce-to-root
     + broadcast) == psum / numpy-OR on pow2 and non-pow2 axes for both
     topology kinds and on the no-ppermute fallback wire; compressed_innet
     with wire_dtype=f32 is bit-identical to CompressedAggregator over 3
     EF steps; with wire_dtype=fxp32 it equals BOTH the f32 path (dyadic
     values round-trip the fixed-point wire exactly) and an independent
     host-side replay of the documented codec roundtrip
     (shared-exponent quantize -> integer sum -> dequantize -> peel),
     for the flat and tor_spine topologies.
  9. the stream scheduler (PR 5): chunked wire grids — per-bucket and
     non-divisible AllReduce chunks, per-rank-aligned native-RS chunks
     (per-chunk psum_scatter/OR-RS), emulated-RS chunks, and innet
     switch-window chunks (f32 + fxp32) — are ALL bit-identical to the
     fused wire over 3 EF steps; dense ignores the knob; a grid that
     splits a per-rank RS boundary raises ValueError naming the
     constraint; tree_all_reduce's windowed mode == one-shot.
 10. the ZeRO-1 gather-skip: on a chunk grid aligned with the ZeRO-1
     slices the native-RS aggregator skips the recovered-chunk
     all_gather (pinned on the jaxpr), each rank's slice is bit-exact
     vs the full wire, off-slice values are zero, residuals identical.
 11. per-bucket wire plans (PR 6): mixed plans partitioning the 5-bucket
     EF stream across dense / compressed / native-RS / innet groups —
     executed by both the ``compressed`` strategy (explicit plan) and
     the ``auto`` strategy — are bit-identical to the fixed
     ``compressed`` run over 3 EF steps, outputs and residuals, at W=4
     over the (pod, data) axes (every wire is exact on dyadic values).
 12. the all-to-all exchange (PR 8): stacked (W, n) payloads routed
     slice-r-to-rank-r; the compressed permute wire (sketch add + bitmap
     OR merged in flight, ratio 2.5 = always-exact peel) equals the
     dense wire and the numpy per-destination sum bit-for-bit over 3
     steps — native single-axis ppermute lanes (W=2), the psum-emulated
     multi-axis wire (W=4 over pod x data), and a chunked
     (stream_chunks=2) lane grid.
"""
import os
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count=8 "
    "--xla_disable_hlo_passes=all-reduce-promotion")
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.compat import make_mesh, shard_map
from repro.core import CompressionConfig
from repro.core.collectives import (
    or_allreduce, compressed_all_reduce, dense_all_reduce,
    init_aggregation_state)

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(0)

# ---- 1. OR-allreduce ------------------------------------------------
W = 4  # pod*data workers
words = rng.integers(0, 2**32, size=(W, 4096), dtype=np.uint32)
expect = np.bitwise_or.reduce(words, axis=0)

def or_fn(x):
    return or_allreduce(x, ("pod", "data"))

# lay the 4 distinct worker payloads over (pod,data); replicate over model
x = jnp.asarray(words.reshape(2, 2, 4096))
sh = NamedSharding(mesh, P("pod", "data", None))
got = jax.jit(shard_map(
    lambda a: or_fn(a[0, 0]),
    mesh=mesh, in_specs=P("pod", "data", None),
    out_specs=P(), axis_names={"pod", "data"}, check_vma=False,
))(jax.device_put(x, sh))
assert np.array_equal(np.asarray(got), expect), "OR-allreduce mismatch"
print("OK or_allreduce hierarchical")

# ring + doubling individually over one axis. Full-manual region: on
# 0.4.x the partitioner cannot run ppermute while other axes stay auto
# (see repro.compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE), and taking every
# axis manual tests the collective itself on every JAX.
words2 = rng.integers(0, 2**32, size=(2, 100_000), dtype=np.uint32)
from repro.core.collectives import or_allreduce_ring, or_allreduce_doubling
for name, fn in [("ring", or_allreduce_ring), ("doubling", or_allreduce_doubling)]:
    got2 = jax.jit(shard_map(
        lambda a, fn=fn: fn(a[0], "pod"),
        mesh=mesh, in_specs=P("pod", None), out_specs=P(),
        axis_names={"pod", "data", "model"}, check_vma=False,
    ))(jax.device_put(jnp.asarray(words2.reshape(2, 1, -1)[:, 0]),
                      NamedSharding(mesh, P("pod", None))))
    assert np.array_equal(np.asarray(got2), np.bitwise_or.reduce(words2, 0)), name
    print(f"OK or_allreduce_{name}")

# ---- 2. compressed_all_reduce on a TP-sharded pytree ----------------
cfg = CompressionConfig(ratio=0.25, rounds=10, lanes=512, chunk_blocks=64)
D, F = 256, 512
n_workers = 4


def make_grads(seed):
    r = np.random.default_rng(seed)
    def sparse(shape, frac=0.04):
        g = np.zeros(np.prod(shape), np.float32)
        idx = r.choice(g.size, size=int(g.size * frac), replace=False)
        g[idx] = r.normal(size=idx.size).astype(np.float32)
        return g.reshape(shape)
    return {"w1": sparse((D, F)), "w2": sparse((F, D)), "scale": sparse((D,), 0.1)}


per_worker = [make_grads(s) for s in range(n_workers)]
mean_ref = jax.tree.map(lambda *g: np.mean(g, axis=0), *per_worker)

specs = {"w1": P(None, "model"), "w2": P("model", None), "scale": P()}

# global arrays whose (pod,data) shard w is per_worker[w]
stacked = jax.tree.map(lambda *g: np.stack(g).reshape((2, 2) + g[0].shape), *per_worker)


def outer(grads_stacked):
    grads = jax.tree.map(lambda a: a[0, 0], grads_stacked)  # this worker's grads
    params_like = jax.tree.map(lambda a: a, grads)
    st = init_aggregation_state(params_like, cfg)
    agg, _ = compressed_all_reduce(grads, st, specs, mesh, cfg,
                                   dp_axes=("pod", "data"), tp_axes=("model",))
    return agg


in_specs = {"w1": P("pod", "data", None, None),
            "w2": P("pod", "data", None, None),
            "scale": P("pod", "data")}
# model placement is auto: apply via device_put sharding below
put_specs = {"w1": P("pod", "data", None, "model"),
             "w2": P("pod", "data", "model", None),
             "scale": P("pod", "data")}
out_specs = {"w1": P(), "w2": P(), "scale": P()}  # model placement is auto

put = jax.tree.map(
    lambda a, s: jax.device_put(jnp.asarray(a), NamedSharding(mesh, s)),
    stacked, put_specs, is_leaf=lambda x: isinstance(x, np.ndarray))

got = jax.jit(shard_map(outer, mesh=mesh, in_specs=(in_specs,),
                            out_specs=out_specs,
                            axis_names={"pod", "data"}, check_vma=False))(put)
got = jax.tree.map(np.asarray, got)
for k in ("w1", "w2", "scale"):
    ok = np.allclose(got[k], mean_ref[k], atol=1e-5)
    print(f"{'OK' if ok else 'FAIL'} compressed_all_reduce[{k}] maxerr={np.abs(got[k]-mean_ref[k]).max():.2e}")
    assert ok, k

# dense baseline for comparison
got_d = jax.jit(shard_map(
    lambda gs: dense_all_reduce(jax.tree.map(lambda a: a[0, 0], gs), ("pod", "data")),
    mesh=mesh, in_specs=(in_specs,), out_specs=out_specs,
    axis_names={"pod", "data"}, check_vma=False))(put)
for k in ("w1", "w2", "scale"):
    assert np.allclose(np.asarray(got_d[k]), mean_ref[k], atol=1e-6), k
print("OK dense_all_reduce baseline")

# ---- 3. bucketed top-k + EF == per-leaf path, bit-for-bit, 3 steps ---
# Pure-DP pytree (replicated specs) so the per-leaf reference below has
# exactly the shard-local view the aggregator sparsifies. Dyadic values
# (sign * 2^e) make every summation order exact, so bitwise equality
# checks the math. ratio=1.0 keeps peel capacity far above the top-k
# density: recovery is exact and the only "lossy" step is the
# sparsifier — which must be the seed's per-leaf one, bit-for-bit.
import dataclasses
from repro.core import topk as topk_lib
from repro.core.aggregators import make_aggregator
from repro.core.collectives import AggregationState

cfg_ef = CompressionConfig(ratio=1.0, lanes=128, rows=6, rounds=10,
                           chunk_blocks=8, topk_ratio=0.1, topk_exact=True,
                           error_feedback=True, bucket_bytes=2 * 768 * 4)
assert cfg_ef.block_elems == 768
ef_shapes = {"wa": (96, 40), "wb": (3000,), "wc": (11,)}
ef_specs = {k: P() for k in ef_shapes}


def dyadic_tree(seed):
    r = np.random.default_rng(seed)
    out = {}
    for k, sh in ef_shapes.items():
        n = int(np.prod(sh))
        g = np.zeros(n, np.float32)
        nz = max(1, int(n * 0.3))
        idx = r.choice(n, size=nz, replace=False)
        g[idx] = (r.choice([-1.0, 1.0], size=nz)
                  * np.exp2(r.integers(-2, 3, size=nz))).astype(np.float32)
        out[k] = g.reshape(sh)
    return out


def run_ef(overlap, name="compressed", rs_wire="auto", wire_plan=None,
           **overrides):
    cfg = dataclasses.replace(cfg_ef, overlap=overlap, rs_wire=rs_wire,
                              **overrides)
    # The region below takes every mesh axis manual, so declare it:
    # full-manual callers unlock the native RS wire on every JAX leg.
    agg = make_aggregator(name, cfg, mesh, ("pod", "data"), (),
                          outer_manual=("pod", "data", "model"),
                          wire_plan=wire_plan)

    def ef_step(gs, rs):
        g = jax.tree.map(lambda a: a[0], gs)
        r = jax.tree.map(lambda a: a[0], rs)
        out, st = agg(g, AggregationState(residual=r), ef_specs)
        return out, jax.tree.map(lambda a: a[None], st.residual)

    res_in_specs = {k: P(("pod", "data")) for k in ef_shapes}
    jfn = jax.jit(shard_map(
        ef_step, mesh=mesh,
        in_specs=({k: P(("pod", "data")) for k in ef_shapes}, res_in_specs),
        out_specs=(ef_specs, res_in_specs),
        axis_names={"pod", "data", "model"}, check_vma=False))

    res = {k: jnp.zeros((n_workers,) + sh, jnp.float32)
           for k, sh in ef_shapes.items()}
    outs = []
    for step in range(3):
        per_w = [dyadic_tree(100 + 10 * step + w) for w in range(n_workers)]
        stacked = {k: jnp.asarray(np.stack([pw[k] for pw in per_w]))
                   for k in ef_shapes}
        stacked = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            stacked, {k: P(("pod", "data")) for k in ef_shapes})
        out, res = jfn(stacked, res)
        outs.append((jax.tree.map(np.asarray, out),
                     jax.tree.map(np.asarray, res)))
    return outs


got_ef = run_ef(overlap=False)

# per-leaf reference: the seed architecture, per worker, per leaf
res_ref = {k: np.zeros((n_workers, int(np.prod(sh))), np.float32)
           for k, sh in ef_shapes.items()}
for step in range(3):
    per_w = [dyadic_tree(100 + 10 * step + w) for w in range(n_workers)]
    out_np, res_np = got_ef[step]
    for k, sh in ef_shapes.items():
        n = int(np.prod(sh))
        kk = max(1, int(n * cfg_ef.topk_ratio))
        sparses = []
        for w in range(n_workers):
            flat = jnp.asarray(per_w[w][k].reshape(-1))
            sp, nr = topk_lib.apply_error_feedback(
                flat, jnp.asarray(res_ref[k][w]), kk, exact=True)
            sparses.append(np.asarray(sp))
            res_ref[k][w] = np.asarray(nr)
        want = (np.sum(sparses, axis=0) / n_workers).reshape(sh)
        assert np.array_equal(out_np[k], want), \
            f"EF step {step} leaf {k}: bucketed != per-leaf reference"
        assert np.array_equal(res_np[k].reshape(n_workers, n),
                              res_ref[k]), \
            f"EF step {step} leaf {k}: residuals diverged"
print("OK bucketed topk+EF == per-leaf path bit-for-bit over 3 steps")

got_ef_ov = run_ef(overlap=True)
for step in range(3):
    for k in ef_shapes:
        assert np.array_equal(got_ef[step][0][k], got_ef_ov[step][0][k]), \
            f"overlap schedule diverged at step {step} leaf {k}"
        assert np.array_equal(got_ef[step][1][k], got_ef_ov[step][1][k])
print("OK overlap pipeline == fused bitwise")

# ---- 5. hierarchical OR with a non-power-of-2 inner axis -------------
from repro.core.collectives import (
    _or_allreduce_psum, or_reduce_scatter, or_reduce_scatter_ring)

mesh6 = make_mesh((2, 3), ("pod", "data"), devices=jax.devices()[:6])
W6 = 6
words6 = rng.integers(0, 2**32, size=(W6, 6 * 37), dtype=np.uint32)
expect6 = np.bitwise_or.reduce(words6, axis=0)
put6 = jax.device_put(jnp.asarray(words6.reshape(2, 3, -1)),
                      NamedSharding(mesh6, P("pod", "data", None)))


def _run6(fn, out_specs=P()):
    return np.asarray(jax.jit(shard_map(
        fn, mesh=mesh6, in_specs=P("pod", "data", None),
        out_specs=out_specs, axis_names={"pod", "data"},
        check_vma=False))(put6))

# whatever wire this leg supports (ring/doubling vs psum emulation)
got6 = _run6(lambda a: or_allreduce(a[0, 0], ("pod", "data")))
assert np.array_equal(got6, expect6), "hierarchical non-pow2 or_allreduce"
print("OK or_allreduce hierarchical non-pow2 inner axis")

# the explicit ring(non-pow2 data) -> doubling(pod) composition is
# ppermute-based and full-manual, so it runs on BOTH legs
got6r = _run6(lambda a: or_allreduce_doubling(
    or_allreduce_ring(a[0, 0], "data"), "pod"))
assert np.array_equal(got6r, expect6), "ring+doubling composition"
print("OK ring(non-pow2) + doubling composition")

# chunked psum emulation == unchunked, bit-for-bit
got6c = _run6(lambda a: _or_allreduce_psum(a[0, 0], ("pod", "data"),
                                           chunk_words=16))
got6u = _run6(lambda a: _or_allreduce_psum(a[0, 0], ("pod", "data"),
                                           chunk_words=1 << 30))
assert np.array_equal(got6c, expect6) and np.array_equal(got6u, expect6)
print("OK chunked == unchunked psum OR emulation")

# ---- 6. or_reduce_scatter ------------------------------------------
# Multi-axis on the (2,2,2) mesh: rank-major chunks must reassemble to
# the full numpy OR via the same out_specs tiling psum_scatter uses.
wordsRS = rng.integers(0, 2**32, size=(W, 4 * 41), dtype=np.uint32)
expectRS = np.bitwise_or.reduce(wordsRS, axis=0)
putRS = jax.device_put(jnp.asarray(wordsRS.reshape(2, 2, -1)),
                       NamedSharding(mesh, P("pod", "data", None)))
gotRS = np.asarray(jax.jit(shard_map(
    lambda a: or_reduce_scatter(
        a[0, 0], ("pod", "data"),
        axis_indices={ax: jax.lax.axis_index(ax) for ax in ("pod", "data")},
        use_ppermute=True),
    mesh=mesh, in_specs=P("pod", "data", None),
    out_specs=P(("pod", "data")), axis_names={"pod", "data", "model"},
    check_vma=False))(putRS))
assert np.array_equal(gotRS, expectRS), "or_reduce_scatter multi-axis"
print("OK or_reduce_scatter multi-axis rank-major")

# chunk placement must match psum_scatter's exactly
gotPS = np.asarray(jax.jit(shard_map(
    lambda a: jax.lax.psum_scatter(a[0, 0].astype(jnp.float64
                                                  if jax.config.jax_enable_x64
                                                  else jnp.float32),
                                   ("pod", "data"), scatter_dimension=0,
                                   tiled=True),
    mesh=mesh, in_specs=P("pod", "data", None),
    out_specs=P(("pod", "data")), axis_names={"pod", "data", "model"},
    check_vma=False))(jax.device_put(
        jnp.asarray((wordsRS & 0xFFFF).astype(np.float32).reshape(2, 2, -1)),
        NamedSharding(mesh, P("pod", "data", None)))))
assert np.array_equal(gotPS, (wordsRS & 0xFFFF).astype(np.float32).sum(0)), \
    "psum_scatter chunk order diverged from or_reduce_scatter's"
print("OK psum_scatter chunk order == or_reduce_scatter")

# single non-pow2 axis ring (data=3 on the 6-device mesh)
words3 = rng.integers(0, 2**32, size=(3, 3 * 29), dtype=np.uint32)
got3 = np.asarray(jax.jit(shard_map(
    lambda a: or_reduce_scatter_ring(a[0], "data"),
    mesh=mesh6, in_specs=P("data", None), out_specs=P("data"),
    axis_names={"pod", "data"}, check_vma=False))(
        jax.device_put(jnp.asarray(words3),
                       NamedSharding(mesh6, P("data", None)))))
assert np.array_equal(got3, np.bitwise_or.reduce(words3, 0)), \
    "or_reduce_scatter_ring non-pow2"
print("OK or_reduce_scatter_ring non-pow2 axis")

# ---- 7. native RS wire == emulated == CompressedAggregator (3 EF steps)
got_rs_native = run_ef(overlap=False, name="compressed_rs",
                       rs_wire="native")
got_rs_emul = run_ef(overlap=False, name="compressed_rs",
                     rs_wire="emulate")
for step in range(3):
    for k in ef_shapes:
        assert np.array_equal(got_ef[step][0][k], got_rs_native[step][0][k]), \
            f"native RS diverged from compressed at step {step} leaf {k}"
        assert np.array_equal(got_ef[step][1][k], got_rs_native[step][1][k]), \
            f"native RS residuals diverged at step {step} leaf {k}"
        assert np.array_equal(got_rs_native[step][0][k],
                              got_rs_emul[step][0][k]), \
            f"native RS != emulated RS at step {step} leaf {k}"
        assert np.array_equal(got_rs_native[step][1][k],
                              got_rs_emul[step][1][k])
print("OK native RS wire == emulated RS == CompressedAggregator, 3 EF steps")

# ---- 8. in-network tier: tree collectives + compressed_innet ---------
from repro.core.bucketing import make_bucket_plan
from repro.core.compressor import HomomorphicCompressor, CompressedLeaf
from repro.net import FixedPointWire, make_topology, tree_all_reduce

# tree_all_reduce == psum / numpy OR, both topology kinds, (2,2)-axes
ints8 = rng.integers(-2**20, 2**20, size=(W, 257), dtype=np.int32)
wordsT = rng.integers(0, 2**32, size=(W, 123), dtype=np.uint32)
for kind in ("flat", "tor_spine"):
    topoK = make_topology(kind, mesh, ("pod", "data"))

    def tree_fn(a, w, topoK=topoK, use_ppermute=True):
        idx = {ax: jax.lax.axis_index(ax) for ax in ("pod", "data")}
        return (tree_all_reduce(a[0, 0], topoK, "add", axis_indices=idx,
                                use_ppermute=use_ppermute),
                tree_all_reduce(w[0, 0], topoK, "or", axis_indices=idx,
                                use_ppermute=use_ppermute))

    for use_pp in (True, False):   # ppermute tree vs psum/OR fallback
        gi, gw = jax.jit(shard_map(
            lambda a, w, t=topoK, u=use_pp: tree_fn(a, w, t, u),
            mesh=mesh,
            in_specs=(P("pod", "data", None), P("pod", "data", None)),
            out_specs=(P(), P()), axis_names={"pod", "data", "model"},
            check_vma=False))(
            jax.device_put(jnp.asarray(ints8.reshape(2, 2, -1)),
                           NamedSharding(mesh, P("pod", "data", None))),
            jax.device_put(jnp.asarray(wordsT.reshape(2, 2, -1)),
                           NamedSharding(mesh, P("pod", "data", None))))
        assert np.array_equal(np.asarray(gi), ints8.sum(0)), (kind, use_pp)
        assert np.array_equal(np.asarray(gw),
                              np.bitwise_or.reduce(wordsT, 0)), (kind, use_pp)
    print(f"OK tree_all_reduce == psum/OR ({kind}, tree + fallback)")

# non-pow2 inner axis on the 6-device mesh
ints6 = rng.integers(-2**20, 2**20, size=(6, 37), dtype=np.int32)
topo6 = make_topology("tor_spine", mesh6, ("pod", "data"))
g6 = jax.jit(shard_map(
    lambda a: tree_all_reduce(a[0, 0], topo6, "add", use_ppermute=True),
    mesh=mesh6, in_specs=P("pod", "data", None), out_specs=P(),
    axis_names={"pod", "data"}, check_vma=False))(
    jax.device_put(jnp.asarray(ints6.reshape(2, 3, -1)),
                   NamedSharding(mesh6, P("pod", "data", None))))
assert np.array_equal(np.asarray(g6), ints6.sum(0)), "tree non-pow2"
print("OK tree_all_reduce non-pow2 inner axis")

# compressed_innet, f32 wire: bit-identical to CompressedAggregator
got_in = run_ef(overlap=False, name="compressed_innet")
for step in range(3):
    for k in ef_shapes:
        assert np.array_equal(got_ef[step][0][k], got_in[step][0][k]), \
            f"innet f32 diverged from compressed at step {step} leaf {k}"
        assert np.array_equal(got_ef[step][1][k], got_in[step][1][k]), \
            f"innet f32 residuals diverged at step {step} leaf {k}"
print("OK compressed_innet f32 == CompressedAggregator, 3 EF steps")

# fxp32 wire: the dyadic values (sign * 2^e, |e| <= 2) sit far inside
# the fixed-point mantissa budget, so the documented quantize -> integer
# sum -> dequantize roundtrip is *exact* here and the fxp32 output must
# equal the f32 path bit-for-bit — for both topology kinds.
got_fx = run_ef(overlap=False, name="compressed_innet",
                wire_dtype="fxp32")
got_fx_ts = run_ef(overlap=False, name="compressed_innet",
                   wire_dtype="fxp32", topology="tor_spine")
for step in range(3):
    for k in ef_shapes:
        assert np.array_equal(got_ef[step][0][k], got_fx[step][0][k]), \
            f"innet fxp32 diverged at step {step} leaf {k}"
        assert np.array_equal(got_fx[step][0][k], got_fx_ts[step][0][k]), \
            f"tor_spine diverged from flat at step {step} leaf {k}"
        assert np.array_equal(got_ef[step][1][k], got_fx[step][1][k])
        assert np.array_equal(got_fx[step][1][k], got_fx_ts[step][1][k])
print("OK innet fxp32 (flat & tor_spine) == f32 on dyadic data, 3 EF steps")

# Independent host replay of the documented codec roundtrip: per-worker
# sparsify (the same per-leaf EF reference as section 3) -> pack ->
# compress -> shared-exponent quantize -> int32 sum -> dequantize -> OR
# bitmaps -> peel -> unpack/W. Must match the in-mesh fxp32 wire
# bit-for-bit at every step.
cfg_fx = dataclasses.replace(cfg_ef, wire_dtype="fxp32")
comp_fx = HomomorphicCompressor(cfg_fx)
plan_fx = make_bucket_plan(
    {k: np.zeros(sh, np.float32) for k, sh in ef_shapes.items()}, cfg_fx)
wire_fx = FixedPointWire(workers=n_workers)
res_fx = {k: np.zeros((n_workers, int(np.prod(sh))), np.float32)
          for k, sh in ef_shapes.items()}
fx_replay_refs = []   # per-step replay trees, reused by section 13
for step in range(3):
    per_w = [dyadic_tree(100 + 10 * step + w) for w in range(n_workers)]
    sks, wrds = [], []
    for w in range(n_workers):
        sp_tree = {}
        for k, sh in ef_shapes.items():
            n = int(np.prod(sh))
            kk = max(1, int(n * cfg_fx.topk_ratio))
            sp, nr = topk_lib.apply_error_feedback(
                jnp.asarray(per_w[w][k].reshape(-1)),
                jnp.asarray(res_fx[k][w]), kk, exact=True)
            sp_tree[k] = np.asarray(sp).reshape(sh)
            res_fx[k][w] = np.asarray(nr)
        c = comp_fx.compress(plan_fx.pack(
            jax.tree.map(jnp.asarray, sp_tree)).reshape(-1))
        sks.append(np.asarray(c.sketch))
        wrds.append(np.asarray(c.index_words))
    dec = wire_fx.roundtrip_reference(
        [s.reshape(plan_fx.n_buckets, -1) for s in sks])
    w_or = wrds[0]
    for wd in wrds[1:]:
        w_or = w_or | wd
    rec = comp_fx.recover(
        CompressedLeaf(sketch=jnp.asarray(dec).reshape(sks[0].shape),
                       index_words=jnp.asarray(w_or)), plan_fx.padded)
    ref_tree = plan_fx.unpack(
        jnp.asarray(rec).reshape(plan_fx.n_buckets, plan_fx.bucket_elems)
        / n_workers)
    fx_replay_refs.append(jax.tree.map(np.asarray, ref_tree))
    out_fx = got_fx[step][0]
    for k in ef_shapes:
        assert np.array_equal(out_fx[k], np.asarray(ref_tree[k])), \
            f"fxp32 wire != documented codec roundtrip, step {step} leaf {k}"
print("OK innet fxp32 == host replay of the documented codec roundtrip")

# ---- 9. stream scheduler (PR 5): chunked == unchunked, all strategies
# The 5-bucket EF stream over W=4 ranks: per-rank bucket count is
# ceil(5/4) = 2, so the native RS wire admits chunk grids {1, 2};
# stream_chunks=3 on the AllReduce wire is non-divisible (pads to 6);
# switch_slots=2 gives the innet tree 3 windows. Every grid must be
# bit-invisible over 3 EF steps.
stream_arms = [
    ("compressed overlap=per-bucket", dict(overlap=True)),
    ("compressed chunks=3 (non-divisible)",
     dict(overlap=False, stream_chunks=3)),
    ("compressed_rs native overlap=per-rank-chunk",
     dict(overlap=True, name="compressed_rs", rs_wire="native")),
    ("compressed_rs native chunks=2",
     dict(overlap=False, name="compressed_rs", rs_wire="native",
          stream_chunks=2)),
    ("compressed_rs emulated chunks=3",
     dict(overlap=False, name="compressed_rs", rs_wire="emulate",
          stream_chunks=3)),
    ("compressed_innet f32 windows=2",
     dict(overlap=True, name="compressed_innet", switch_slots=2)),
    ("compressed_innet fxp32 windows=2",
     dict(overlap=True, name="compressed_innet", wire_dtype="fxp32",
          switch_slots=2)),
]
for label, kw in stream_arms:
    got_s = run_ef(**kw)
    for step in range(3):
        for k in ef_shapes:
            assert np.array_equal(got_ef[step][0][k], got_s[step][0][k]), \
                f"[{label}] diverged at step {step} leaf {k}"
            assert np.array_equal(got_ef[step][1][k], got_s[step][1][k]), \
                f"[{label}] residuals diverged at step {step} leaf {k}"
    print(f"OK stream scheduler: {label} == fused, 3 EF steps")

# dense ignores the chunk knob entirely (no wire chunks to cut)
got_d1 = run_ef(overlap=False, name="dense")
got_d2 = run_ef(overlap=False, name="dense", stream_chunks=3)
for step in range(3):
    for k in ef_shapes:
        assert np.array_equal(got_d1[step][0][k], got_d2[step][0][k])
print("OK stream scheduler: dense chunked == unchunked")

# forcing a grid that splits a per-rank RS boundary names the constraint
try:
    run_ef(overlap=False, name="compressed_rs", rs_wire="native",
           stream_chunks=3)
except ValueError as e:
    assert "ceil(n_buckets/W)" in str(e), e
else:
    raise AssertionError("boundary-splitting stream_chunks did not raise")
print("OK stream scheduler: RS boundary split raises ValueError")

# windowed tree mode == one-shot tree == psum/OR (both combiners)
topoW = make_topology("flat", mesh, ("pod", "data"))
giW, gwW = jax.jit(shard_map(
    lambda a, w: (
        tree_all_reduce(a[0, 0], topoW, "add",
                        axis_indices={ax: jax.lax.axis_index(ax)
                                      for ax in ("pod", "data")},
                        use_ppermute=True, window_slots=3),
        tree_all_reduce(w[0, 0], topoW, "or",
                        axis_indices={ax: jax.lax.axis_index(ax)
                                      for ax in ("pod", "data")},
                        use_ppermute=True, window_slots=3)),
    mesh=mesh,
    in_specs=(P("pod", "data", None), P("pod", "data", None)),
    out_specs=(P(), P()), axis_names={"pod", "data", "model"},
    check_vma=False))(
    jax.device_put(jnp.asarray(ints8.reshape(2, 2, -1)),
                   NamedSharding(mesh, P("pod", "data", None))),
    jax.device_put(jnp.asarray(wordsT.reshape(2, 2, -1)),
                   NamedSharding(mesh, P("pod", "data", None))))
assert np.array_equal(np.asarray(giW), ints8.sum(0))
assert np.array_equal(np.asarray(gwW), np.bitwise_or.reduce(wordsT, 0))
print("OK tree_all_reduce windowed mode == one-shot")

# ---- 10. ZeRO-1 gather-skip: aligned chunk grid feeds optimizer shards
# Two 4-bucket leaves (8-bucket stream), W=4: with stream_chunks=2 the
# grid is 2 chunks x 4 buckets, rank r owns bucket r of each chunk —
# exactly each leaf's dim-0 ZeRO-1 slice r. The aggregator must skip
# the recovered-chunk all_gather, return leaves exact inside this
# rank's slice (zero outside), and keep residuals bit-identical.
E_skip = 1536  # cfg_ef bucket_elems (2 blocks)
skip_shapes = {"wa": (4 * E_skip,), "wb": (4 * E_skip,)}
skip_specs = {k: P() for k in skip_shapes}


def skip_tree(seed):
    r = np.random.default_rng(seed)
    out = {}
    for k, sh in skip_shapes.items():
        n = int(np.prod(sh))
        g = np.zeros(n, np.float32)
        nz = max(1, int(n * 0.2))
        idx = r.choice(n, size=nz, replace=False)
        g[idx] = (r.choice([-1.0, 1.0], size=nz)
                  * np.exp2(r.integers(-2, 3, size=nz))).astype(np.float32)
        out[k] = g.reshape(sh)
    return out


def run_skip(name, zero1_dims=None, **overrides):
    cfg = dataclasses.replace(cfg_ef, **overrides)
    agg = make_aggregator(name, cfg, mesh, ("pod", "data"), (),
                          outer_manual=("pod", "data", "model"),
                          zero1_dims=zero1_dims)

    def ef_step(gs, rs):
        g = jax.tree.map(lambda a: a[0], gs)
        r = jax.tree.map(lambda a: a[0], rs)
        out, st = agg(g, AggregationState(residual=r), skip_specs)
        # keep per-rank outputs visible (the skip path returns
        # rank-local data): stack on the dp axes
        return (jax.tree.map(lambda a: a[None], out),
                jax.tree.map(lambda a: a[None], st.residual))

    ris = {k: P(("pod", "data")) for k in skip_shapes}
    jfn = jax.jit(shard_map(
        ef_step, mesh=mesh, in_specs=(ris, ris), out_specs=(ris, ris),
        axis_names={"pod", "data", "model"}, check_vma=False))
    res = {k: jnp.zeros((n_workers,) + sh, jnp.float32)
           for k, sh in skip_shapes.items()}
    outs = []
    for step in range(3):
        per_w = [skip_tree(500 + 10 * step + w) for w in range(n_workers)]
        stacked = {k: jnp.asarray(np.stack([pw[k] for pw in per_w]))
                   for k in skip_shapes}
        stacked = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            stacked, ris)
        out, res = jfn(stacked, res)
        outs.append((jax.tree.map(np.asarray, out),
                     jax.tree.map(np.asarray, res)))
    return agg, jfn, outs


agg_skip, jfn_skip, got_skip = run_skip(
    "compressed_rs", zero1_dims=(0, 0), rs_wire="native", stream_chunks=2)
assert agg_skip.gather_skip_active(
    {k: np.zeros(sh, np.float32) for k, sh in skip_shapes.items()}), \
    "aligned grid did not activate the gather skip"
# misaligned (fused) grid and missing zero1_dims keep the gather
agg_1c, _, _ = run_skip("compressed_rs", zero1_dims=(0, 0),
                        rs_wire="native", stream_chunks=1)
assert not agg_1c.gather_skip_active(
    {k: np.zeros(sh, np.float32) for k, sh in skip_shapes.items()})
_, _, got_full = run_skip("compressed", rs_wire="auto")
for step in range(3):
    for k in skip_shapes:
        # residuals are per-leaf, before the wire: identical
        assert np.array_equal(got_skip[step][1][k], got_full[step][1][k]), \
            f"gather-skip residuals diverged at step {step} leaf {k}"
        for r in range(n_workers):
            sl = slice(r * E_skip, (r + 1) * E_skip)
            assert np.array_equal(got_skip[step][0][k][r][sl],
                                  got_full[step][0][k][r][sl]), \
                f"gather-skip slice wrong at step {step} leaf {k} rank {r}"
            mask = np.ones(4 * E_skip, bool)
            mask[sl] = False
            assert not got_skip[step][0][k][r][mask].any(), \
                f"gather-skip off-slice values leaked at step {step} " \
                f"leaf {k} rank {r}"
print("OK gather-skip: per-rank slices exact, off-slice zero, 3 EF steps")

# the skip path must launch NO all_gather; the gathered path must
agg_g, jfn_g, _ = run_skip("compressed_rs", rs_wire="native",
                           stream_chunks=2)
_stk = {k: jax.device_put(
    jnp.zeros((n_workers,) + sh, jnp.float32),
    NamedSharding(mesh, P(("pod", "data"))))
    for k, sh in skip_shapes.items()}
_res = {k: jnp.zeros((n_workers,) + sh, jnp.float32)
        for k, sh in skip_shapes.items()}
assert "all_gather" not in str(jax.make_jaxpr(jfn_skip)(_stk, _res)), \
    "gather-skip path still launches all_gather"
assert "all_gather" in str(jax.make_jaxpr(jfn_g)(_stk, _res)), \
    "gathered path lost its all_gather"
print("OK gather-skip: no all_gather in the skip jaxpr")

# ---- 11. mixed per-bucket wire plans (PR 6) --------------------------
# The EF stream packs into 5 buckets; carve it into groups spanning all
# four wires. Per the numerics contract every group encodes at its
# global block offsets, so any plan must reproduce the fixed
# ``compressed`` run bit-for-bit — through the ``compressed`` executor
# (explicit plan) and the ``auto`` strategy alike.
from repro.core.wireplan import WireGroup, WirePlan

_nb_ef = make_bucket_plan(
    {k: np.zeros(sh, np.float32) for k, sh in ef_shapes.items()},
    cfg_ef).n_buckets
assert _nb_ef == 5, _nb_ef
mixed_plans = [
    ("dense[0:2] | compressed[2:4] | rs[4:5]",
     WirePlan(5, (WireGroup(0, 2, "dense"),
                  WireGroup(2, 2, "compressed"),
                  WireGroup(4, 1, "compressed_rs")))),
    ("innet[0:3] | dense[3:5]",
     WirePlan(5, (WireGroup(0, 3, "compressed_innet"),
                  WireGroup(3, 2, "dense")))),
]
for label, wp in mixed_plans:
    for strat in ("compressed", "auto"):
        got_mx = run_ef(overlap=False, name=strat, wire_plan=wp)
        for step in range(3):
            for k in ef_shapes:
                assert np.array_equal(got_ef[step][0][k],
                                      got_mx[step][0][k]), \
                    f"[{strat}: {label}] diverged at step {step} leaf {k}"
                assert np.array_equal(got_ef[step][1][k],
                                      got_mx[step][1][k]), \
                    f"[{strat}: {label}] residuals diverged at step " \
                    f"{step} leaf {k}"
        print(f"OK mixed wire plan ({strat}): {label} == compressed, "
              "3 EF steps")

# ---- 12. the all-to-all exchange (PR 8) ------------------------------
# The expert-parallel dispatch/combine wire: each rank holds a stacked
# (W, n) payload — slice r routed to rank r — and the exchange must
# deliver merged_r = sum_s payload_s[r] at rank r. Dyadic payloads make
# every fp sum exact, so the compressed permute wire (sketch add +
# bitmap OR in flight, ratio 2.5 = always-exact peel) must equal the
# dense wire AND the numpy reference bit-for-bit, over 3 steps of
# evolving payloads, on the native single-axis ppermute leg (W=2 over
# "data"; the region is full-manual so it runs on both JAX legs), the
# psum-emulated multi-axis leg (W=4 over pod x data), and a chunked
# (stream_chunks=2) lane grid.
from repro.core.aggregators import make_exchange

cfg_a2a = dataclasses.replace(cfg_ef, ratio=2.5, topk_ratio=None,
                              error_feedback=False)
N_DEST = 2 * 1536          # 2 buckets/dest: the chunked grid divides it


def dyadic_payload(seed, w):
    r = np.random.default_rng(seed)
    out = np.zeros((w, N_DEST), np.float32)
    for d in range(w):
        n_nz = int(N_DEST * 0.9)
        idx = r.choice(N_DEST, size=n_nz, replace=False)
        out[d, idx] = (r.choice([-1.0, 1.0], size=n_nz)
                       * np.exp2(r.integers(-2, 3, size=n_nz)))
    return out


for label, ep_axes, w_ep, in_spec, out_spec in (
        ("native W=2 (data)", ("data",), 2,
         P("data", None, None), P("data", None)),
        ("emulated W=4 (pod,data)", ("pod", "data"), 4,
         P("pod", "data", None, None), P("pod", "data", None))):
    for chunks in (None, 2):
        outs = {}
        for wire in ("dense", "compressed"):
            cfg_w = dataclasses.replace(cfg_a2a, stream_chunks=chunks)
            ex = make_exchange(wire, cfg_w, mesh, ep_axes,
                               outer_manual=("pod", "data", "model"))

            def body(stack, ex=ex, n_lead=len(ep_axes)):
                local = stack
                for _ in range(n_lead):
                    local = local[0]
                merged = ex({"g": local})["g"]
                for _ in range(n_lead):
                    merged = merged[None]
                return merged

            fn = jax.jit(shard_map(
                body, mesh=mesh, in_specs=(in_spec,), out_specs=out_spec,
                axis_names={"pod", "data", "model"}, check_vma=False))
            step_outs = []
            for step in range(3):
                pay = np.stack([dyadic_payload(1000 + 10 * step + s, w_ep)
                                for s in range(w_ep)])
                lead = (2, 2) if len(ep_axes) > 1 else (2,)
                put_a2a = jax.device_put(
                    jnp.asarray(pay.reshape(lead + (w_ep, N_DEST))),
                    NamedSharding(mesh, in_spec))
                got = np.asarray(fn(put_a2a)).reshape(w_ep, N_DEST)
                want = pay.sum(axis=0)     # merged_r = sum_s payload_s[r]
                assert np.array_equal(got, want), \
                    (label, chunks, wire, step)
                step_outs.append(got)
            outs[wire] = step_outs
        for step in range(3):
            assert np.array_equal(outs["dense"][step],
                                  outs["compressed"][step]), \
                (label, chunks, step)
        grid = f"chunked x{chunks}" if chunks else "fused"
        print(f"OK a2a exchange [{label}, {grid}]: compressed == dense "
              "== numpy, 3 steps")

# ---- 4. reduce-scatter aggregator on the TP-sharded tree -------------
got_rs = jax.jit(shard_map(
    lambda gs: compressed_all_reduce(
        jax.tree.map(lambda a: a[0, 0], gs),
        init_aggregation_state(jax.tree.map(lambda a: a[0, 0], gs), cfg),
        specs, mesh, cfg, dp_axes=("pod", "data"), tp_axes=("model",),
        reduce_scatter=True)[0],
    mesh=mesh, in_specs=(in_specs,), out_specs=out_specs,
    axis_names={"pod", "data"}, check_vma=False))(put)
for k in ("w1", "w2", "scale"):
    ok = np.allclose(np.asarray(got_rs[k]), mean_ref[k], atol=1e-5)
    print(f"{'OK' if ok else 'FAIL'} compressed_rs[{k}] "
          f"maxerr={np.abs(np.asarray(got_rs[k]) - mean_ref[k]).max():.2e}")
    assert ok, k

# ---- 13. elastic aggregation service (PR 9/10) vs the in-mesh strategies
# Fold-equivalence gate: a fixed-membership elastic round is the same
# aggregate as the synchronous collective. Per EF step, every client
# contributes the same dyadic gradient its in-mesh worker saw and the
# server folds payloads in a permuted arrival order; the finalized
# stream must match the `compressed` strategy's psum+OR output (f32)
# and both the `compressed_innet` output and section 8's host replay of
# FixedPointWire.roundtrip_reference (fxp32) — bit-for-bit, residuals
# included. The PR 10 arm replays the same schedule through the
# sharded+batched fold pipeline (2 shard engines, microbatches of 3):
# f32 matches via the canonical client-sorted reduction order, fxp32 in
# any arrival order — the scale-out path changes nothing the wire can
# observe.
from repro.elastic import ElasticClient, ElasticServer

el_template = {k: np.zeros(sh, np.float32) for k, sh in ef_shapes.items()}
perm_rng = np.random.default_rng(13)
for wire_name, el_cfg, refs in (
        ("f32", cfg_ef, [(got_ef[s][0], got_ef[s][1]) for s in range(3)]),
        ("fxp32", cfg_fx, [(got_fx[s][0], got_fx[s][1]) for s in range(3)])):
    for arm, srv_kwargs in (("sequential", {}),
                            ("sharded S=2 b=3",
                             {"n_shards": 2, "batch_size": 3})):
        srv = ElasticServer(el_template, el_cfg, **srv_kwargs)
        clients = [ElasticClient(w, el_cfg) for w in range(n_workers)]
        for w in range(n_workers):
            srv.join(w)
        for step in range(3):
            contract = srv.open_round()
            trees = [jax.tree.map(jnp.asarray,
                                  dyadic_tree(100 + 10 * step + w))
                     for w in range(n_workers)]
            if wire_name == "fxp32":
                for w in range(n_workers):
                    srv.submit_exponents(
                        clients[w].propose(contract, trees[w]))
                shared = srv.seal_exponents()
                payloads = [clients[w].payload(contract, shared)
                            for w in range(n_workers)]
            else:
                payloads = [clients[w].contribute(contract, trees[w])
                            for w in range(n_workers)]
            for w in perm_rng.permutation(n_workers):
                assert srv.submit(payloads[w]) == "folded"
            stream, rep = srv.close_round()
            assert rep.close_reason == "complete" and \
                rep.folded == n_workers
            out_tree = jax.tree.map(np.asarray,
                                    srv.plan.unpack(stream / n_workers))
            want_out, want_res = refs[step]
            for k in ef_shapes:
                assert np.array_equal(out_tree[k], want_out[k]), \
                    (f"elastic {wire_name} [{arm}] != in-mesh, "
                     f"step {step} leaf {k}")
                if wire_name == "fxp32":
                    assert np.array_equal(out_tree[k],
                                          fx_replay_refs[step][k]), \
                        (f"elastic fxp32 [{arm}] != codec replay, "
                         f"step {step} leaf {k}")
                for w in range(n_workers):
                    assert np.array_equal(
                        np.asarray(clients[w].residual[k]),
                        want_res[k][w]), \
                        (f"elastic {wire_name} [{arm}] EF residual "
                         f"drift, step {step} leaf {k} client {w}")
        print(f"OK elastic {wire_name} [{arm}] rounds == in-mesh "
              "aggregate, 3 EF steps")

print("ALL OK")
