"""8-device driver: full train_step (manual DP + auto TP) with dense and
compressed aggregation, ZeRO-1 on and off. Asserts loss decreases and the
two aggregators track each other. Also drives the PR 8 expert-parallel
all-to-all exchange (`TrainConfig.ep_exchange`) through the MoE combine
at W=2 and asserts both exchange wires train bit-identically to the
local combine."""
import os
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count=8 "
    "--xla_disable_hlo_passes=all-reduce-promotion")
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh
from repro.models import ModelConfig, MoEConfig, model_api
from repro.core import CompressionConfig
from repro.train import TrainConfig, OptimizerConfig, init_train_state, build_train_step
from repro.train.step import state_specs, batch_specs
from repro.parallel.sharding import ShardingProfile

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = ModelConfig(name="tiny", family="moe", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  moe=MoEConfig(num_experts=8, top_k=2, shared_experts=1,
                                expert_d_ff=64, capacity_factor=2.0),
                  dtype="float32")
api = model_api(cfg)
rng = np.random.default_rng(0)
B, S = 8, 32
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}


def run(tc, steps=6):
    state = init_train_state(api, tc, mesh, jax.random.PRNGKey(0))
    make = build_train_step(api, tc, mesh)
    step_fn, specs = make(state)
    _, bnamed = batch_specs(batch, mesh, tc)
    jitted = jax.jit(step_fn,
                     in_shardings=(specs["named"], bnamed),
                     out_shardings=(specs["named"], None))
    b = jax.device_put(batch, bnamed)
    st = jax.device_put(state, specs["named"])
    losses = []
    for i in range(steps):
        st, m = jitted(st, b)
        losses.append(float(m["loss"]))
    return losses


opt = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=100)
tc_dense = TrainConfig(aggregator="dense", optimizer=opt,
                       sharding=ShardingProfile(zero1=False), remat="block")
tc_dense_z = TrainConfig(aggregator="dense", optimizer=opt,
                         sharding=ShardingProfile(zero1=True), remat="block")
# sketch big enough for a fully dense gradient (paper Fig.3's ">= gamma*n"
# regime): recovery is lossless, so training must match dense psum.
tc_comp_ll = TrainConfig(aggregator="compressed", optimizer=opt,
                         compression=CompressionConfig(ratio=2.0, lanes=512,
                                                       rows=60, chunk_blocks=64),
                         sharding=ShardingProfile(zero1=True), remat="block")
# production setting: top-k budget + error feedback (dense-grad models)
tc_comp_tk = TrainConfig(aggregator="compressed", optimizer=opt,
                         compression=CompressionConfig(ratio=0.4, lanes=512,
                                                       rows=6, chunk_blocks=64,
                                                       topk_ratio=0.1),
                         sharding=ShardingProfile(zero1=True), remat="block")

l_dense = run(tc_dense)
print("dense        :", [round(x, 4) for x in l_dense])
l_dz = run(tc_dense_z)
print("dense+z1     :", [round(x, 4) for x in l_dz])
# strict losslessness check under a *linear* optimizer (momentum), where
# fp-eps recovery noise stays fp-eps instead of being amplified by Adam's
# rsqrt(v) at near-zero second moments.
opt_m = OptimizerConfig(kind="momentum", lr=1e-2, warmup_steps=0,
                        total_steps=100, grad_clip=0.0)
l_dense_m = run(TrainConfig(aggregator="dense", optimizer=opt_m,
                            sharding=ShardingProfile(zero1=False),
                            remat="block"))
l_ll_m = run(TrainConfig(aggregator="compressed", optimizer=opt_m,
                         compression=tc_comp_ll.compression,
                         sharding=ShardingProfile(zero1=False),
                         remat="block"))
print("dense (mom)  :", [round(x, 5) for x in l_dense_m])
print("comp  (mom)  :", [round(x, 5) for x in l_ll_m])
l_ll = run(tc_comp_ll)
print("comp lossless:", [round(x, 4) for x in l_ll])
# reduce-scatter aggregator: each DP rank peels only its bucket range,
# feeding the ZeRO-1 slice-update path; must track the lossless run.
l_rs = run(TrainConfig(aggregator="compressed_rs", optimizer=opt,
                       compression=tc_comp_ll.compression,
                       sharding=ShardingProfile(zero1=True), remat="block"))
print("comp rs+z1   :", [round(x, 4) for x in l_rs])
l_tk = run(tc_comp_tk)
print("comp topk+EF :", [round(x, 4) for x in l_tk])
# in-network tier (PR 4): f32 wire reuses the AllReduce collectives and
# must match the lossless compressed run exactly; the fxp32 switch wire
# adds only the documented ~2^-29-relative quantization, so the curve
# must stay on track.
import dataclasses
l_in = run(TrainConfig(aggregator="compressed_innet", optimizer=opt,
                       compression=tc_comp_ll.compression,
                       sharding=ShardingProfile(zero1=True), remat="block"))
print("comp innet   :", [round(x, 4) for x in l_in])
l_in_fx = run(TrainConfig(
    aggregator="compressed_innet", optimizer=opt,
    compression=dataclasses.replace(tc_comp_ll.compression,
                                    wire_dtype="fxp32"),
    sharding=ShardingProfile(zero1=True), remat="block"))
print("comp innet fx:", [round(x, 4) for x in l_in_fx])

assert l_dense[-1] < l_dense[0], "dense loss must decrease"
assert all(abs(a - b) < 1e-4 for a, b in zip(l_dense, l_dz)), \
    f"zero1 diverged from replicated: {l_dense} vs {l_dz}"
assert all(abs(a - b) < 1e-4 for a, b in zip(l_dense_m, l_ll_m)), \
    f"lossless compressed diverged under momentum: {l_dense_m} vs {l_ll_m}"
assert all(abs(a - b) < 0.1 for a, b in zip(l_dense, l_ll)), \
    f"lossless compressed (adam) off-track: {l_dense} vs {l_ll}"
assert all(abs(a - b) < 1e-4 for a, b in zip(l_ll, l_rs)), \
    f"reduce-scatter aggregator diverged from lossless: {l_ll} vs {l_rs}"
assert l_tk[-1] < l_tk[0] and l_tk[-1] < 5.0, \
    f"topk+EF compressed failed to converge: {l_tk}"
assert all(abs(a - b) < 1e-4 for a, b in zip(l_ll, l_in)), \
    f"in-network f32 wire diverged from lossless: {l_ll} vs {l_in}"
assert all(abs(a - b) < 0.05 for a, b in zip(l_ll, l_in_fx)), \
    f"in-network fxp32 wire off-track: {l_ll} vs {l_in_fx}"
assert l_in_fx[-1] < l_in_fx[0], "fxp32 training loss must decrease"

# PR 6: the `auto` strategy inside the real train step. Its analytic
# plan (no telemetry yet at trace time) must stay on the lossless track,
# and the per-bucket occupancy telemetry must surface through the step
# metrics as a vector for the host-side controller to fold back in.
def run_auto(tc, steps=6):
    state = init_train_state(api, tc, mesh, jax.random.PRNGKey(0))
    step_fn, specs = build_train_step(api, tc, mesh)(state)
    _, bnamed = batch_specs(batch, mesh, tc)
    jitted = jax.jit(step_fn, in_shardings=(specs["named"], bnamed),
                     out_shardings=(specs["named"], None))
    st = jax.device_put(state, specs["named"])
    b = jax.device_put(batch, bnamed)
    losses, occ = [], None
    for _ in range(steps):
        st, m = jitted(st, b)
        losses.append(float(m["loss"]))
        occ = np.asarray(m["bucket_occupancy"])
    return losses, occ


l_auto, occ = run_auto(TrainConfig(
    aggregator="auto", optimizer=opt,
    compression=tc_comp_ll.compression,
    sharding=ShardingProfile(zero1=True), remat="block"))
print("comp auto    :", [round(x, 4) for x in l_auto],
      f"occ=[{occ.min():.3f},{occ.max():.3f}] n_buckets={occ.size}")
assert all(abs(a - b) < 1e-4 for a, b in zip(l_ll, l_auto)), \
    f"auto strategy diverged from lossless: {l_ll} vs {l_auto}"
assert occ.ndim == 1 and occ.size >= 1, occ.shape
assert float(occ.min()) >= 0.0 and float(occ.max()) <= 1.0, occ

# PR 5: the streamed native RS wire (per-chunk psum_scatter staged
# against the next chunk's encode by core/streams.py) inside the real
# train step must stay exactly on the one-shot track.
l_rs_ov = run(TrainConfig(
    aggregator="compressed_rs", optimizer=opt,
    compression=dataclasses.replace(tc_comp_ll.compression, overlap=True),
    sharding=ShardingProfile(zero1=True), remat="block"))
print("comp rs ovl  :", [round(x, 4) for x in l_rs_ov])
assert all(abs(a - b) < 1e-4 for a, b in zip(l_rs, l_rs_ov)), \
    f"streamed RS wire diverged from one-shot in the step: {l_rs} vs {l_rs_ov}"

# PR 5 gather-skip inside the real train step: a stub model whose two
# 4-bucket leaves align with the ZeRO-1 slices on a 2-chunk grid. With
# tc.rs_gather_skip the step must drop the recovered-chunk all_gather
# (fewer all_gather eqns in the jaxpr) and train identically (the only
# off-shard consumer, the grad-norm, is psum-reduced on that path).
from repro.models.registry import ModelAPI

E_skip = 1536  # bucket_elems of the skip compression config below
n_p = 4 * E_skip


def _stub_init(key):
    del key
    base = jnp.linspace(-1.0, 1.0, n_p, dtype=jnp.float32)
    return {"wa": base, "wb": base[::-1] * 0.5}


def _stub_loss(p, b, remat="none"):
    del remat
    pred = b["x"] * (p["wa"] + p["wb"])[None, :]
    loss = jnp.mean((pred - b["y"]) ** 2)
    return loss, {"mse": loss}


stub_api = ModelAPI(cfg=None, init=_stub_init, loss=_stub_loss,
                    prefill=None, decode=None, init_cache=None)
stub_batch = {
    "x": jnp.asarray(rng.standard_normal((8, n_p)).astype(np.float32)),
    "y": jnp.asarray(rng.standard_normal((8, n_p)).astype(np.float32)),
}
skip_comp = CompressionConfig(ratio=1.0, lanes=128, rows=6, chunk_blocks=8,
                              topk_ratio=0.1, topk_exact=True,
                              error_feedback=True, bucket_bytes=2 * 768 * 4,
                              rs_wire="native", stream_chunks=2)
stub_prof = ShardingProfile(tp_axis=None, vocab_axis=None, zero1=True)


def run_stub(rs_gather_skip):
    tc = TrainConfig(aggregator="compressed_rs", optimizer=opt,
                     compression=skip_comp, sharding=stub_prof,
                     remat="none", rs_gather_skip=rs_gather_skip)
    state = init_train_state(stub_api, tc, mesh, jax.random.PRNGKey(0))
    step_fn, specs = build_train_step(stub_api, tc, mesh)(state)
    _, bnamed = batch_specs(stub_batch, mesh, tc)
    n_ag = str(jax.make_jaxpr(step_fn)(state, stub_batch)).count("all_gather")
    jitted = jax.jit(step_fn, in_shardings=(specs["named"], bnamed),
                     out_shardings=(specs["named"], None))
    st = jax.device_put(state, specs["named"])
    b = jax.device_put(stub_batch, bnamed)
    losses = []
    for _ in range(6):
        st, m = jitted(st, b)
        losses.append(float(m["loss"]))
    return losses, n_ag


l_skip, ag_skip = run_stub(True)
l_gather, ag_gather = run_stub(False)
print("stub skip    :", [round(x, 5) for x in l_skip], f"all_gathers={ag_skip}")
print("stub gather  :", [round(x, 5) for x in l_gather],
      f"all_gathers={ag_gather}")
assert ag_skip < ag_gather, (
    "gather-skip step did not drop the recovered-chunk all_gather: "
    f"{ag_skip} vs {ag_gather}")
assert all(abs(a - b) < 1e-5 for a, b in zip(l_skip, l_gather)), \
    f"gather-skip training diverged: {l_skip} vs {l_gather}"
assert l_skip[-1] < l_skip[0], "stub training loss must decrease"

# PR 8: the expert-parallel all-to-all exchange inside the real train
# step. On the full-manual leg the MoE combine routes each rank's
# expert-group partial sums through the dense / compressed exchange
# (W=2 over the profile's "model" EP axis; the executor pins the
# always-exact ratio=2.5 codec) and the stop_gradient splice keeps the
# backward pass on the local-combine cotangent — so training must be
# BIT-identical to the local combine, under Adam and, stricter, under
# the linear momentum optimizer. On the partial-auto leg the hook's
# full-manual gate leaves the local combine in place and the runs are
# trivially identical.
ep_comp = CompressionConfig(lanes=128, rows=6, chunk_blocks=8)


def run_ep(ep, o=opt):
    return run(TrainConfig(aggregator="dense", optimizer=o,
                           sharding=ShardingProfile(zero1=False),
                           remat="block", ep_exchange=ep,
                           compression=ep_comp))


l_ep_none = run_ep("none")
l_ep_dense = run_ep("dense")
l_ep_comp = run_ep("compressed")
print("ep none      :", [round(x, 4) for x in l_ep_none])
print("ep dense     :", [round(x, 4) for x in l_ep_dense])
print("ep compressed:", [round(x, 4) for x in l_ep_comp])
assert l_ep_none == l_ep_dense, \
    f"dense exchange diverged from local combine: {l_ep_none} vs {l_ep_dense}"
assert l_ep_none == l_ep_comp, \
    f"compressed exchange diverged from local combine: {l_ep_none} vs {l_ep_comp}"
assert l_ep_none[-1] < l_ep_none[0], "ep-exchange training must decrease"
l_epm_none = run_ep("none", opt_m)
l_epm_comp = run_ep("compressed", opt_m)
print("ep none (mom):", [round(x, 5) for x in l_epm_none])
print("ep comp (mom):", [round(x, 5) for x in l_epm_comp])
assert l_epm_none == l_epm_comp, \
    f"exchange diverged under momentum: {l_epm_none} vs {l_epm_comp}"
print("ALL OK")
