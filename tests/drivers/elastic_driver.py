"""Elastic re-meshing on real (fake-CPU) devices — PR 9 satellite.

``ft.failures.elastic_mesh`` was dead code until the elastic tier made
it the device-side sizing hook: when a cohort maps onto local devices
(``Membership.local_mesh``), the data axis must shrink to the largest
power of two that fits the survivors while keeping the model axis
intact. This driver runs under 8 forced host devices and checks the
built meshes — non-divisible device counts included — plus a live
collective on a degraded mesh.

Run via tests/test_multidevice.py with
XLA_FLAGS="--xla_force_host_platform_device_count=8 ...".
"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.elastic import Membership
from repro.ft.failures import elastic_mesh

assert jax.device_count() == 8, "driver expects 8 forced host devices"

# ---- 1. elastic_mesh on non-divisible survivor counts ----------------
for avail, mp, want in [(8, 1, (8, 1)), (7, 1, (4, 1)), (6, 2, (2, 2)),
                        (5, 4, (1, 4)), (8, 2, (4, 2)), (3, 2, (1, 2)),
                        (6, 3, (2, 3)), (1, 1, (1, 1))]:
    m = elastic_mesh(avail, mp)
    got = (m.shape["data"], m.shape["model"])
    assert got == want, (avail, mp, got, want)
    assert m.devices.size == want[0] * want[1]
    # the mesh holds real, distinct devices
    assert len({d.id for d in m.devices.reshape(-1)}) == m.devices.size
print("OK elastic_mesh sizes (data, model) for non-divisible survivors")

# ---- 2. Membership.local_mesh tracks the roster ----------------------
mem = Membership()
for c in range(3):
    mem.join(c)
m3 = mem.local_mesh()                 # 3 clients on 8 devices -> data=2
assert m3.shape == {"data": 2, "model": 1}
for c in range(3, 10):
    mem.join(c)
m10 = mem.local_mesh()                # 10 clients capped by 8 devices
assert m10.shape == {"data": 8, "model": 1}
m10mp = mem.local_mesh(model_parallel=2)
assert m10mp.shape == {"data": 4, "model": 2}
mem.leave(0)
mem.leave(1)
assert mem.local_mesh().shape == {"data": 8, "model": 1}
print("OK Membership.local_mesh follows joins/leaves")

# ---- 3. a real collective on a degraded (5 -> 4x1) mesh --------------
m = elastic_mesh(5, 1)
W = m.shape["data"]
x = jnp.arange(W * 6, dtype=jnp.float32).reshape(W, 6)
out = jax.jit(shard_map(
    lambda a: jax.lax.psum(a, "data"), mesh=m,
    in_specs=(P("data"),), out_specs=P("data"),
    axis_names={"data", "model"}, check_vma=False))(x)
want = np.tile(np.asarray(x).sum(axis=0), (W, 1))
assert np.array_equal(np.asarray(out), want)
print("OK psum on the degraded elastic mesh")

print("ALL OK")
