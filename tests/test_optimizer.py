import numpy as np
import jax.numpy as jnp

from repro.train.optimizer import (OptimizerConfig, lr_schedule,
                                   init_opt_state, opt_leaf_update,
                                   global_grad_norm, clip_grads)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    assert float(lr_schedule(jnp.int32(0), cfg)) == 0.0
    assert abs(float(lr_schedule(jnp.int32(10), cfg)) - 1.0) < 1e-6
    end = float(lr_schedule(jnp.int32(100), cfg))
    assert abs(end - 0.1) < 1e-6


def test_adamw_matches_reference():
    cfg = OptimizerConfig(kind="adamw", lr=1e-2, b1=0.9, b2=0.99,
                          eps=1e-8, weight_decay=0.1)
    r = np.random.default_rng(0)
    p = r.normal(size=(32,)).astype(np.float32)
    g = r.normal(size=(32,)).astype(np.float32)
    st = {"m": jnp.zeros(32), "v": jnp.zeros(32)}
    new_p, st = opt_leaf_update(jnp.asarray(p), jnp.asarray(g), st,
                                jnp.float32(1e-2), jnp.int32(0), cfg)
    # reference numpy AdamW, step 1
    m = 0.1 * g
    v = 0.01 * g * g
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.99)
    ref = p - 1e-2 * (mh / (np.sqrt(vh) + 1e-8) + 0.1 * p)
    np.testing.assert_allclose(np.asarray(new_p), ref, atol=1e-6)


def test_momentum_update():
    cfg = OptimizerConfig(kind="momentum", momentum=0.9)
    p = jnp.ones((4,))
    g = jnp.full((4,), 2.0)
    st = {"m": jnp.zeros((4,))}
    new_p, st2 = opt_leaf_update(p, g, st, jnp.float32(0.1), jnp.int32(0),
                                 cfg)
    np.testing.assert_allclose(np.asarray(new_p), 1 - 0.1 * 2.0)
    np.testing.assert_allclose(np.asarray(st2["m"]), 2.0)


def test_bf16_state_roundtrips():
    cfg = OptimizerConfig(kind="adamw", state_dtype="bfloat16")
    st = init_opt_state({"w": jnp.zeros((8, 8))}, cfg)
    assert st["m"]["w"].dtype == jnp.bfloat16


def test_clipping():
    g = {"w": jnp.full((4,), 10.0)}
    n = global_grad_norm(g)
    clipped = clip_grads(g, n, 1.0)
    np.testing.assert_allclose(float(global_grad_norm(clipped)), 1.0,
                               rtol=1e-5)
