"""Prefill-vs-decode logit consistency for every family."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import ModelConfig, MoEConfig, SSMConfig, model_api

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab=256, dtype="float32", q_block=16)
CFGS = {
    "dense": ModelConfig(name="d", family="dense", qkv_bias=True, **BASE),
    "moe": ModelConfig(name="m", family="moe",
                       moe=MoEConfig(num_experts=8, top_k=2,
                                     shared_experts=1, expert_d_ff=64,
                                     capacity_factor=4.0,
                                     capacity_factor_decode=8.0), **BASE),
    "ssm": ModelConfig(name="s", family="ssm",
                       ssm=SSMConfig(d_state=16, head_dim=16, chunk=8),
                       **BASE),
    "hybrid": ModelConfig(name="h", family="hybrid", attn_period=2,
                          attn_offset=1,
                          moe=MoEConfig(num_experts=4, top_k=2,
                                        expert_d_ff=64, capacity_factor=4.0,
                                        capacity_factor_decode=8.0),
                          ssm=SSMConfig(d_state=16, head_dim=16, chunk=8),
                          **BASE),
    "encdec": ModelConfig(name="e", family="encdec", enc_layers=2,
                          enc_seq=24, **BASE),
}


@pytest.mark.parametrize("family", sorted(CFGS))
def test_prefill_decode_consistency(family):
    cfg = CFGS[family]
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S, MAX = 2, 12, 20
    toks = rng.integers(1, cfg.vocab, (B, S + 3))
    batch = {"tokens": jnp.asarray(toks[:, :S])}
    if family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)).astype(np.float32))
    _, cache = api.prefill(params, batch, MAX)
    pos = S
    for i in range(3):
        logits_d, cache = api.decode(params, jnp.asarray(toks[:, S + i]),
                                     cache, pos)
        pos += 1
    b2 = dict(batch)
    b2["tokens"] = jnp.asarray(toks[:, :S + 3])
    logits_p, _ = api.prefill(params, b2, MAX)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_p),
                               atol=2e-3)
