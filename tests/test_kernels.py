"""Pallas kernels (interpret mode) vs the pure-jnp oracle: shape/dtype
sweeps per the brief."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import CompressionConfig
from repro.kernels import (sketch_encode_pallas, sketch_peel_pallas, ref)
from repro.kernels.ops import sketch_encode, sketch_peel
from conftest import make_sparse


CFGS = [
    CompressionConfig(ratio=0.2, lanes=128, rows=6, rounds=8),
    CompressionConfig(ratio=0.2, lanes=256, rows=6, rounds=8),
    CompressionConfig(ratio=0.1, lanes=256, rows=12, rounds=8),
    CompressionConfig(ratio=0.5, lanes=512, rows=6, rounds=8),
]


def _blocks(cfg, nb, frac, seed, dtype=np.float32):
    x = make_sparse(nb * cfg.block_elems, frac, seed, np.float32)
    return x.astype(dtype).reshape(nb, cfg.group, cfg.lanes)


@pytest.mark.parametrize("cfg", CFGS, ids=[f"l{c.lanes}r{c.rows}g{c.group}"
                                           for c in CFGS])
@pytest.mark.parametrize("nb", [1, 3])
def test_encode_matches_oracle(cfg, nb):
    xb = jnp.asarray(_blocks(cfg, nb, 0.04, seed=nb))
    ids = jnp.arange(nb, dtype=jnp.int32)
    got = sketch_encode_pallas(xb, ids, cfg, interpret=True)
    want = ref.sketch_encode_ref(xb, ids, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_encode_dtypes(dtype):
    cfg = CFGS[1]
    xb = jnp.asarray(_blocks(cfg, 2, 0.03, 5, dtype))
    ids = jnp.arange(2, dtype=jnp.int32)
    got = sketch_encode_pallas(xb, ids, cfg, interpret=True)
    want = ref.sketch_encode_ref(xb, ids, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


@pytest.mark.parametrize("cfg", CFGS[:3], ids=["a", "b", "c"])
@pytest.mark.parametrize("frac", [0.01, 0.08])
def test_peel_matches_oracle(cfg, frac):
    nb = 2
    xb = jnp.asarray(_blocks(cfg, nb, frac, seed=17))
    ids = jnp.arange(nb, dtype=jnp.int32)
    y = ref.sketch_encode_ref(xb, ids, cfg)
    bits = xb != 0
    v_p, r_p = sketch_peel_pallas(y, bits, ids, cfg, interpret=True)
    v_r, r_r = ref.sketch_peel_ref(y, bits, ids, cfg)
    np.testing.assert_allclose(np.asarray(v_p), np.asarray(v_r), atol=1e-5)
    assert np.array_equal(np.asarray(r_p), np.asarray(r_r))


@pytest.mark.parametrize("nb,tile", [(1, 4), (6, 3), (11, 3), (2, 8)],
                         ids=["single", "exact-tiles", "padded", "tile>nb"])
def test_peel_multiblock_tiling_matches_oracle(nb, tile):
    """Multi-block grid-cell tiling of the peel kernel (same scheme as
    encode): cells of ``peel_block_tile`` blocks, padded when nb is not a
    tile multiple, must be bit-identical to the untiled oracle."""
    import dataclasses
    cfg = dataclasses.replace(CFGS[0], peel_block_tile=tile, rounds=10)
    xb = jnp.asarray(_blocks(cfg, nb, 0.05, seed=nb + 23))
    ids = jnp.arange(nb, dtype=jnp.int32)
    y = ref.sketch_encode_ref(xb, ids, cfg)
    bits = xb != 0
    v_p, r_p = sketch_peel_pallas(y, bits, ids, cfg, interpret=True)
    v_r, r_r = ref.sketch_peel_ref(y, bits, ids, cfg)
    assert v_p.shape == v_r.shape and r_p.shape == r_r.shape
    np.testing.assert_array_equal(np.asarray(v_p), np.asarray(v_r))
    assert np.array_equal(np.asarray(r_p), np.asarray(r_r))


def test_peel_tiling_with_offset_ids():
    """Bucketed aggregators peel sub-ranges with shifted block ids; the
    tiled kernel must honour arbitrary (non-contiguous-from-zero) ids."""
    cfg = CFGS[0]
    import dataclasses
    cfg = dataclasses.replace(cfg, peel_block_tile=2)
    nb = 5
    ids = jnp.arange(nb, dtype=jnp.int32) + 37
    xb = jnp.asarray(_blocks(cfg, nb, 0.04, seed=91))
    y = ref.sketch_encode_ref(xb, ids, cfg)
    bits = xb != 0
    v_p, r_p = sketch_peel_pallas(y, bits, ids, cfg, interpret=True)
    v_r, r_r = ref.sketch_peel_ref(y, bits, ids, cfg)
    np.testing.assert_array_equal(np.asarray(v_p), np.asarray(v_r))
    assert np.array_equal(np.asarray(r_p), np.asarray(r_r))


def test_ops_dispatch_never_uses_pallas_on_cpu():
    cfg = CompressionConfig(ratio=0.2, lanes=128, rows=6, use_pallas="auto")
    xb = jnp.asarray(_blocks(cfg, 1, 0.02, 3))
    ids = jnp.arange(1, dtype=jnp.int32)
    got = sketch_encode(xb, ids, cfg)            # auto -> ref on CPU
    want = ref.sketch_encode_ref(xb, ids, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_ops_dispatch_always():
    cfg = CompressionConfig(ratio=0.2, lanes=128, rows=6,
                            use_pallas="always")
    xb = jnp.asarray(_blocks(cfg, 1, 0.02, 3))
    ids = jnp.arange(1, dtype=jnp.int32)
    got = sketch_encode(xb, ids, cfg)            # pallas interpret path
    want = ref.sketch_encode_ref(xb, ids, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    v_p, r_p = sketch_peel(want, xb != 0, ids, cfg)
    v_r, r_r = ref.sketch_peel_ref(want, xb != 0, ids, cfg)
    np.testing.assert_allclose(np.asarray(v_p), np.asarray(v_r), atol=1e-5)
