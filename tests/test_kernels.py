"""Pallas kernels (interpret mode) vs the pure-jnp oracle: shape/dtype
sweeps per the brief."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import CompressionConfig
from repro.kernels import (sketch_encode_pallas, sketch_peel_pallas, ref)
from repro.kernels.ops import sketch_encode, sketch_peel
from conftest import make_sparse


CFGS = [
    CompressionConfig(ratio=0.2, lanes=128, rows=6, rounds=8),
    CompressionConfig(ratio=0.2, lanes=256, rows=6, rounds=8),
    CompressionConfig(ratio=0.1, lanes=256, rows=12, rounds=8),
    CompressionConfig(ratio=0.5, lanes=512, rows=6, rounds=8),
]


def _blocks(cfg, nb, frac, seed, dtype=np.float32):
    x = make_sparse(nb * cfg.block_elems, frac, seed, np.float32)
    return x.astype(dtype).reshape(nb, cfg.group, cfg.lanes)


@pytest.mark.parametrize("cfg", CFGS, ids=[f"l{c.lanes}r{c.rows}g{c.group}"
                                           for c in CFGS])
@pytest.mark.parametrize("nb", [1, 3])
def test_encode_matches_oracle(cfg, nb):
    xb = jnp.asarray(_blocks(cfg, nb, 0.04, seed=nb))
    ids = jnp.arange(nb, dtype=jnp.int32)
    got = sketch_encode_pallas(xb, ids, cfg, interpret=True)
    want = ref.sketch_encode_ref(xb, ids, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_encode_dtypes(dtype):
    cfg = CFGS[1]
    xb = jnp.asarray(_blocks(cfg, 2, 0.03, 5, dtype))
    ids = jnp.arange(2, dtype=jnp.int32)
    got = sketch_encode_pallas(xb, ids, cfg, interpret=True)
    want = ref.sketch_encode_ref(xb, ids, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


@pytest.mark.parametrize("cfg", CFGS[:3], ids=["a", "b", "c"])
@pytest.mark.parametrize("frac", [0.01, 0.08])
def test_peel_matches_oracle(cfg, frac):
    nb = 2
    xb = jnp.asarray(_blocks(cfg, nb, frac, seed=17))
    ids = jnp.arange(nb, dtype=jnp.int32)
    y = ref.sketch_encode_ref(xb, ids, cfg)
    bits = xb != 0
    v_p, r_p = sketch_peel_pallas(y, bits, ids, cfg, interpret=True)
    v_r, r_r = ref.sketch_peel_ref(y, bits, ids, cfg)
    np.testing.assert_allclose(np.asarray(v_p), np.asarray(v_r), atol=1e-5)
    assert np.array_equal(np.asarray(r_p), np.asarray(r_r))


@pytest.mark.parametrize("nb,tile", [(1, 4), (6, 3), (11, 3), (2, 8)],
                         ids=["single", "exact-tiles", "padded", "tile>nb"])
def test_peel_multiblock_tiling_matches_oracle(nb, tile):
    """Multi-block grid-cell tiling of the peel kernel (same scheme as
    encode): cells of ``peel_block_tile`` blocks, padded when nb is not a
    tile multiple, must be bit-identical to the untiled oracle."""
    import dataclasses
    cfg = dataclasses.replace(CFGS[0], peel_block_tile=tile, rounds=10)
    xb = jnp.asarray(_blocks(cfg, nb, 0.05, seed=nb + 23))
    ids = jnp.arange(nb, dtype=jnp.int32)
    y = ref.sketch_encode_ref(xb, ids, cfg)
    bits = xb != 0
    v_p, r_p = sketch_peel_pallas(y, bits, ids, cfg, interpret=True)
    v_r, r_r = ref.sketch_peel_ref(y, bits, ids, cfg)
    assert v_p.shape == v_r.shape and r_p.shape == r_r.shape
    np.testing.assert_array_equal(np.asarray(v_p), np.asarray(v_r))
    assert np.array_equal(np.asarray(r_p), np.asarray(r_r))


def test_peel_tiling_with_offset_ids():
    """Bucketed aggregators peel sub-ranges with shifted block ids; the
    tiled kernel must honour arbitrary (non-contiguous-from-zero) ids."""
    cfg = CFGS[0]
    import dataclasses
    cfg = dataclasses.replace(cfg, peel_block_tile=2)
    nb = 5
    ids = jnp.arange(nb, dtype=jnp.int32) + 37
    xb = jnp.asarray(_blocks(cfg, nb, 0.04, seed=91))
    y = ref.sketch_encode_ref(xb, ids, cfg)
    bits = xb != 0
    v_p, r_p = sketch_peel_pallas(y, bits, ids, cfg, interpret=True)
    v_r, r_r = ref.sketch_peel_ref(y, bits, ids, cfg)
    np.testing.assert_array_equal(np.asarray(v_p), np.asarray(v_r))
    assert np.array_equal(np.asarray(r_p), np.asarray(r_r))


def test_ops_dispatch_never_uses_pallas_on_cpu():
    cfg = CompressionConfig(ratio=0.2, lanes=128, rows=6, use_pallas="auto")
    xb = jnp.asarray(_blocks(cfg, 1, 0.02, 3))
    ids = jnp.arange(1, dtype=jnp.int32)
    got = sketch_encode(xb, ids, cfg)            # auto -> ref on CPU
    want = ref.sketch_encode_ref(xb, ids, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_ops_dispatch_always():
    cfg = CompressionConfig(ratio=0.2, lanes=128, rows=6,
                            use_pallas="always")
    xb = jnp.asarray(_blocks(cfg, 1, 0.02, 3))
    ids = jnp.arange(1, dtype=jnp.int32)
    got = sketch_encode(xb, ids, cfg)            # pallas interpret path
    want = ref.sketch_encode_ref(xb, ids, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    v_p, r_p = sketch_peel(want, xb != 0, ids, cfg)
    v_r, r_r = ref.sketch_peel_ref(want, xb != 0, ids, cfg)
    np.testing.assert_allclose(np.asarray(v_p), np.asarray(v_r), atol=1e-5)


# ----------------------------------------------------------------------
# Fused wire codec (PR 7): one-VMEM-pass encode+pack(+quantize) and
# dequant+unpack+peel vs the composed reference ops. Values are dyadic
# (sign * 2^e, |e| <= 2) so every floating-point sum along either
# implementation's reduction order is exact — bitwise equality then
# pins the math, not addition-order luck.
# ----------------------------------------------------------------------
import dataclasses

from repro.kernels import (encode_pack_quantize_pallas,
                           dequant_peel_unpack_pallas)
from repro.kernels import ops as ops_lib
from repro.net.fixedpoint import FixedPointWire


def _dyadic_blocks(cfg, nb, frac, seed):
    r = np.random.default_rng(seed)
    n = nb * cfg.block_elems
    x = np.zeros(n, np.float32)
    k = max(1, int(n * frac))
    idx = r.choice(n, size=k, replace=False)
    x[idx] = (r.choice([-1.0, 1.0], size=k)
              * np.exp2(r.integers(-2, 3, size=k))).astype(np.float32)
    return x.reshape(nb, cfg.group, cfg.lanes)


@pytest.mark.parametrize("cfg", CFGS, ids=[f"l{c.lanes}r{c.rows}g{c.group}"
                                           for c in CFGS])
@pytest.mark.parametrize("nb,etile,ptile",
                         [(1, 4, 4), (5, 3, 2), (7, 4, 3)],
                         ids=["single", "padded-5", "padded-7"])
def test_fused_wire_matches_composed_bitwise(cfg, nb, etile, ptile):
    """Fused producer/consumer vs composed refs, including padded last
    grid tiles and a nonzero block-id offset (mid-stream bucket)."""
    cfg = dataclasses.replace(cfg, rounds=10, encode_block_tile=etile,
                              peel_block_tile=ptile)
    xb = jnp.asarray(_dyadic_blocks(cfg, nb, 0.05, seed=nb + 3))
    ids = jnp.arange(nb, dtype=jnp.int32) + 37
    sk_p, w_p, mx_p = encode_pack_quantize_pallas(xb, ids, cfg,
                                                  interpret=True)
    sk_r, w_r, mx_r = ref.encode_pack_quantize_ref(xb, ids, cfg)
    np.testing.assert_array_equal(np.asarray(sk_p), np.asarray(sk_r))
    np.testing.assert_array_equal(np.asarray(w_p), np.asarray(w_r))
    np.testing.assert_array_equal(np.asarray(mx_p), np.asarray(mx_r))
    v_p, r_p = dequant_peel_unpack_pallas(sk_r, w_r, ids, cfg,
                                          interpret=True)
    v_r, r_r = ref.dequant_peel_unpack_ref(sk_r, w_r, ids, cfg)
    np.testing.assert_array_equal(np.asarray(v_p), np.asarray(v_r))
    np.testing.assert_array_equal(np.asarray(r_p), np.asarray(r_r))
    # lossless regime: the composed consumer reproduces the input
    np.testing.assert_array_equal(np.asarray(v_r), np.asarray(xb))


def test_fused_maxabs_matches_bucket_exponents():
    """The producer's streamed per-block max-|sketch| must yield the
    exact same fxp32 exponents as re-scanning the materialized sketch
    (max is exact, so max-of-block-maxes == bucket max)."""
    cfg = dataclasses.replace(CFGS[0], rounds=10)
    wire = FixedPointWire(workers=2)
    xb = jnp.asarray(_dyadic_blocks(cfg, 4, 0.05, seed=11))
    ids = jnp.arange(4, dtype=jnp.int32)
    sk, _, mx = ref.encode_pack_quantize_ref(xb, ids, cfg)
    e_stream = wire.exponents_from_maxabs(mx)
    e_rescan = wire.bucket_exponents(sk.reshape(4, -1))
    np.testing.assert_array_equal(np.asarray(e_stream),
                                  np.asarray(e_rescan))


@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_fused_quantized_wire_matches_roundtrip_reference(backend):
    """fxp32 leg: two workers quantize through the fused producer
    against shared exponents, integer-sum, and the fused consumer's
    folded dequant must peel to exactly what FixedPointWire's
    documented roundtrip_reference + composed peel produce."""
    cfg = dataclasses.replace(CFGS[0], rounds=10, encode_block_tile=3,
                              peel_block_tile=2)
    nb, W = 5, 2
    wire = FixedPointWire(workers=W)
    M = wire.mantissa_bits
    ids = jnp.arange(nb, dtype=jnp.int32)
    xbs = [jnp.asarray(_dyadic_blocks(cfg, nb, 0.04, seed=s + 5))
           for s in range(W)]
    f32 = [ref.encode_pack_quantize_ref(xb, ids, cfg) for xb in xbs]
    e = wire.exponents_from_maxabs(jnp.maximum(f32[0][2], f32[1][2]))

    def produce(xb):
        if backend == "pallas":
            return encode_pack_quantize_pallas(
                xb, ids, cfg, exponents=e, mantissa_bits=M, interpret=True)
        return ref.encode_pack_quantize_ref(xb, ids, cfg, exponents=e,
                                            mantissa_bits=M)

    outs = [produce(xb) for xb in xbs]
    for (q, _, _), xb in zip(outs, xbs):
        assert q.dtype == jnp.int32
        want_q = wire.encode(
            ref.sketch_encode_ref(xb, ids, cfg).reshape(nb, -1), e)
        np.testing.assert_array_equal(np.asarray(q.reshape(nb, -1)),
                                      np.asarray(want_q))
    q_sum = outs[0][0] + outs[1][0]
    words = outs[0][1] | outs[1][1]
    if backend == "pallas":
        v, r = dequant_peel_unpack_pallas(q_sum, words, ids, cfg,
                                          exponents=e, mantissa_bits=M,
                                          interpret=True)
    else:
        v, r = ref.dequant_peel_unpack_ref(q_sum, words, ids, cfg,
                                           exponents=e, mantissa_bits=M)
    rt = wire.roundtrip_reference(
        [sk.reshape(nb, -1) for sk, _, _ in f32]).reshape(f32[0][0].shape)
    bits = (xbs[0] != 0) | (xbs[1] != 0)
    v_want, r_want = ref.sketch_peel_ref(rt, bits, ids, cfg)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_want))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(r_want))
    # dyadic values well inside the mantissa budget: exact recovery
    np.testing.assert_array_equal(np.asarray(v),
                                  np.asarray(xbs[0] + xbs[1]))


def test_fused_wire_dispatch_guards():
    cfg = CFGS[0]
    xb = jnp.asarray(_dyadic_blocks(cfg, 1, 0.02, seed=1))
    ids = jnp.arange(1, dtype=jnp.int32)
    with pytest.raises(ValueError, match="together"):
        ops_lib.encode_pack_quantize(xb, ids, cfg,
                                     exponents=jnp.zeros(1, jnp.int32))
    bloom = dataclasses.replace(cfg, index="bloom")
    assert not ops_lib.fused_wire_supported(bloom)
    with pytest.raises(ValueError, match="unsupported"):
        ops_lib.encode_pack_quantize(xb, ids, bloom)
    fused = ops_lib.wire_codec_passes(
        dataclasses.replace(cfg, use_pallas="always"))
    composed = ops_lib.wire_codec_passes(
        dataclasses.replace(cfg, use_pallas="never"))
    composed_q = ops_lib.wire_codec_passes(
        dataclasses.replace(cfg, use_pallas="never"), quantized=True)
    assert fused == {"producer": 1, "consumer": 1}
    assert composed["producer"] > 1 and composed["consumer"] > 1
    assert composed_q["producer"] == composed["producer"] + 1
