"""BucketPlan packing edge cases: leaf larger than a bucket, pytree
smaller than one bucket, padding correctness, dtype-mixed leaves, and the
per-bucket segment/residual views."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import CompressionConfig, make_bucket_plan

# lanes=128, ratio=0.3 -> group=20, block_elems=2560 (= bucket quantum).
CFG = CompressionConfig(ratio=0.3, lanes=128, rows=6,
                        bucket_bytes=2 * 2560 * 4)  # 2 blocks / bucket


def _tree():
    r = np.random.default_rng(0)
    return {
        "big": r.standard_normal(3 * 5120 + 17).astype(np.float32),  # > bucket
        "mat": r.standard_normal((40, 50)).astype(np.float16),       # mixed dt
        "small": r.standard_normal(7).astype(np.float32),
        "int-ish": r.standard_normal((3, 4)).astype(np.float32),
    }


def test_pack_unpack_roundtrip_mixed_dtypes():
    tree = _tree()
    plan = make_bucket_plan(tree, CFG)
    assert plan.bucket_elems == 5120
    assert plan.n_buckets == -(-plan.total // 5120)
    assert plan.total == sum(v.size for v in tree.values())
    buckets = plan.pack(jax.tree.map(jnp.asarray, tree))
    assert buckets.shape == (plan.n_buckets, plan.bucket_elems)
    assert buckets.dtype == jnp.float32
    out = plan.unpack(buckets)
    for k, v in tree.items():
        got = np.asarray(out[k])
        assert got.shape == v.shape and got.dtype == v.dtype, k
        # f16 leaves roundtrip through f32 exactly; f32 leaves bitwise
        np.testing.assert_array_equal(got, v, err_msg=k)


def test_padding_is_zero_and_dropped():
    tree = {"a": jnp.arange(10, dtype=jnp.float32)}
    plan = make_bucket_plan(tree, CFG)
    # pytree smaller than one configured bucket: single right-sized bucket
    assert plan.n_buckets == 1
    assert plan.bucket_elems == CFG.bucket_quantum  # capped, not 5120
    buckets = plan.pack(tree)
    flat = np.asarray(buckets).reshape(-1)
    np.testing.assert_array_equal(flat[:10], np.arange(10, dtype=np.float32))
    assert np.all(flat[10:] == 0.0), "padding must be zero"
    out = plan.unpack(buckets)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10))


def test_leaf_larger_than_bucket_spans_segments():
    tree = _tree()
    plan = make_bucket_plan(tree, CFG)
    segs = plan.bucket_segments
    assert len(segs) == plan.n_buckets
    # "big" (leaf 0 in sorted-dict flatten order) spans several buckets
    big_segs = [s for bucket in segs for s in bucket if s.leaf == 0]
    assert len(big_segs) >= 3
    # segments tile the stream exactly: lengths sum to total, no overlap
    assert sum(s.length for bucket in segs for s in bucket) == plan.total
    for b, bucket in enumerate(segs):
        pos = None
        for s in bucket:
            assert s.bucket == b
            if pos is not None:
                assert s.bucket_start == pos, "segments must be contiguous"
            pos = s.bucket_start + s.length
            assert pos <= plan.bucket_elems


def test_residual_slices_match_segments():
    tree = _tree()
    plan = make_bucket_plan(tree, CFG)
    res = jax.tree.map(lambda v: jnp.asarray(np.arange(v.size, dtype=np.float32)
                                             .reshape(v.shape)), tree)
    slices = plan.residual_slices(res)
    res_leaves = [np.asarray(r).reshape(-1)
                  for r in plan.treedef.flatten_up_to(res)]
    for bucket, segs in zip(slices, plan.bucket_segments):
        for sl, s in zip(bucket, segs):
            want = res_leaves[s.leaf][s.leaf_start:s.leaf_start + s.length]
            np.testing.assert_array_equal(np.asarray(sl), want)


def test_bucket_alignment_quantum():
    # bucket sizes are whole sketch blocks AND whole uint32 bitmap words
    for lanes, ratio in ((128, 0.3), (256, 0.1), (512, 0.25)):
        cfg = dataclasses.replace(CFG, lanes=lanes, ratio=ratio)
        q = cfg.bucket_quantum
        assert q % cfg.block_elems == 0 and q % 32 == 0
        for total in (1, q - 1, q, q + 1, 10 * q + 3):
            be = cfg.bucket_elems_for(total)
            assert be % q == 0 and be >= 1
            assert cfg.num_buckets(total) * be >= total


def test_pack_rejects_wrong_shapes():
    tree = {"a": jnp.zeros((8,), jnp.float32)}
    plan = make_bucket_plan(tree, CFG)
    with pytest.raises(ValueError):
        plan.pack_flat([jnp.zeros((9,), jnp.float32)])
    with pytest.raises(ValueError):
        plan.unpack_flat(jnp.zeros((2, plan.bucket_elems), jnp.float32))


def test_wire_bytes_reports_buckets():
    w = CFG.wire_bytes(3 * 5120 + 100, grad_bytes_per_elem=4)
    assert w["n_buckets"] == 4 and w["bucket_elems"] == 5120
    assert w["bucket_total_bytes"] == (w["bucket_sketch_bytes"]
                                       + w["bucket_index_bytes"])
    assert w["bucketed_total_bytes"] == 4 * w["bucket_total_bytes"]
    # bucketed total >= exact-stream total (last-bucket padding only)
    assert w["bucketed_total_bytes"] >= w["total_bytes"]
