import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (CompressionConfig, HomomorphicCompressor,
                        CompressedLeaf)
from conftest import make_sparse

CFG = CompressionConfig(ratio=0.1, lanes=512, rows=6, rounds=10,
                        chunk_blocks=16)


@pytest.mark.parametrize("n", [5_000, 61_440, 200_000])
@pytest.mark.parametrize("frac", [0.0, 0.005, 0.03])
def test_roundtrip_lossless(n, frac):
    x = make_sparse(n, frac, seed=n + int(frac * 1000))
    comp = HomomorphicCompressor(CFG)
    c = comp.compress(jnp.asarray(x))
    xr, st = comp.recover(c, n, with_stats=True)
    assert int(st.residual) == 0
    np.testing.assert_allclose(np.asarray(xr), x, atol=1e-6)


def test_multiworker_aggregation_lossless():
    n, W = 150_000, 8
    comp = HomomorphicCompressor(CFG)
    xs = [make_sparse(n, 0.004, s) for s in range(W)]
    comps = [comp.compress(jnp.asarray(x)) for x in xs]
    agg = CompressedLeaf(
        sketch=sum(c.sketch for c in comps),
        index_words=jnp.asarray(np.bitwise_or.reduce(
            [np.asarray(c.index_words) for c in comps])))
    xr, st = comp.recover(agg, n, with_stats=True)
    assert int(st.residual) == 0
    np.testing.assert_allclose(np.asarray(xr), np.sum(xs, 0), atol=1e-5)


def test_matrix_shaped_leaf():
    comp = HomomorphicCompressor(CFG)
    x = make_sparse(64 * 384, 0.02, 7).reshape(64, 384)
    c = comp.compress(jnp.asarray(x))
    xr = comp.recover(c, x.size, shape=x.shape)
    assert xr.shape == x.shape
    np.testing.assert_allclose(np.asarray(xr), x, atol=1e-6)


def test_wire_accounting():
    comp = HomomorphicCompressor(CFG)
    wb = comp.wire_bytes(1_000_000)
    # fp32 sketch at ratio 0.1 of elements = 0.2 of bf16 bytes, + bitmap
    assert 0.2 < wb["wire_fraction"] < 0.35
    assert wb["index_bytes"] * 8 >= 1_000_000  # >= 1 bit per element


def test_bloom_index_mode():
    cfg = CompressionConfig(ratio=0.2, lanes=512, rows=6, rounds=10,
                            index="bloom", bloom_bits_ratio=0.25,
                            chunk_blocks=16)
    comp = HomomorphicCompressor(cfg)
    x = make_sparse(100_000, 0.005, 9)
    c = comp.compress(jnp.asarray(x))
    # bloom index is smaller than the bitmap would be
    assert c.index_words.size * 32 < 1.05 * cfg.bloom_bits_ratio * 130_000
    xr = comp.recover(c, x.size)
    np.testing.assert_allclose(np.asarray(xr), x, atol=1e-5)


def test_estimate_mode_is_lossy_but_unbiased():
    comp = HomomorphicCompressor(CFG)
    x = make_sparse(100_000, 0.02, 11)
    c = comp.compress(jnp.asarray(x))
    est = np.asarray(comp.estimate(c, x.size))
    # exact on zeros (bitmap gate), approximate elsewhere
    assert np.all(est[x == 0] == 0)


def test_jit_compatible():
    comp = HomomorphicCompressor(CFG)
    x = jnp.asarray(make_sparse(60_000, 0.01, 13))
    c = jax.jit(comp.compress)(x)
    xr = jax.jit(lambda c: comp.recover(c, 60_000))(c)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(x), atol=1e-6)
