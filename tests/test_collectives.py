"""Unit coverage for the OR-AllReduce algorithm-selection policy.

The multi-device semantics (ring == doubling == numpy OR-reduce) live in
``tests/drivers/collectives_driver.py``; here we pin the *decision*:
``ring_threshold`` is payload **bytes** (not element count), and axes
whose size is not a power of two must take the ring instead of raising
from ``or_allreduce_doubling``.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.collectives import _use_ring, or_allreduce


def test_threshold_is_bytes_not_elements():
    thr = 65536
    # 16384 uint32 words == 65536 bytes: exactly at the byte threshold
    assert _use_ring(16384 * 4, 4, thr)
    # 16384 *elements* would have crossed an element-count threshold,
    # but it is only 64 KiB-of-4 == under the byte threshold at 16383
    assert not _use_ring(16383 * 4, 4, thr)
    assert not _use_ring(65535, 4, thr)
    assert _use_ring(65536, 4, thr)


@pytest.mark.parametrize("n,ring", [(1, False), (2, False), (3, True),
                                    (4, False), (6, True), (12, True),
                                    (16, False), (24, True)])
def test_non_power_of_two_axes_take_ring(n, ring):
    assert _use_ring(payload_bytes=4, axis_size=n, ring_threshold=1 << 30) \
        == ring


def test_or_allreduce_single_shard_identity():
    # axis size 1 on a trivial mesh context: both branches short-circuit.
    # (No shard_map needed: compat.axis_size is only consulted per axis,
    # and an empty axis list never consults it.)
    x = jnp.asarray(np.arange(8, dtype=np.uint32))
    out = or_allreduce(x, ())
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
