"""Unit coverage for the OR-AllReduce algorithm-selection policy, the
argument validation of the collective primitives, and the per-strategy
wire accounting.

The multi-device semantics (ring == doubling == numpy OR-reduce, the
reduce-scatter chunk placement, native-RS bit-parity) live in
``tests/drivers/collectives_driver.py``; here we pin the *decisions*:
``ring_threshold`` is payload **bytes** (not element count), axes whose
size is not a power of two must take the ring instead of raising from
``or_allreduce_doubling``, a partial ``axis_indices`` dict is a loud
error (silently recomputing ``axis_index`` re-binds outer-shard_map axes
— the Shardy failure the parameter exists to avoid), the psum-emulated
OR is chunk-invariant, and ``compressed_all_reduce`` forwards
``outer_manual`` so fully-manual callers reach the native RS wire.
"""
import dataclasses
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core import CompressionConfig
from repro.core.collectives import (
    AggregationState, _or_allreduce_psum, _use_ring, compressed_all_reduce,
    init_aggregation_state, or_allreduce, or_reduce_scatter)


def test_threshold_is_bytes_not_elements():
    thr = 65536
    # 16384 uint32 words == 65536 bytes: exactly at the byte threshold
    assert _use_ring(16384 * 4, 4, thr)
    # 16384 *elements* would have crossed an element-count threshold,
    # but it is only 64 KiB-of-4 == under the byte threshold at 16383
    assert not _use_ring(16383 * 4, 4, thr)
    assert not _use_ring(65535, 4, thr)
    assert _use_ring(65536, 4, thr)


@pytest.mark.parametrize("n,ring", [(1, False), (2, False), (3, True),
                                    (4, False), (6, True), (12, True),
                                    (16, False), (24, True)])
def test_non_power_of_two_axes_take_ring(n, ring):
    assert _use_ring(payload_bytes=4, axis_size=n, ring_threshold=1 << 30) \
        == ring


def test_or_allreduce_single_shard_identity():
    # axis size 1 on a trivial mesh context: both branches short-circuit.
    # (No shard_map needed: compat.axis_size is only consulted per axis,
    # and an empty axis list never consults it.)
    x = jnp.asarray(np.arange(8, dtype=np.uint32))
    out = or_allreduce(x, ())
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


# ----------------------------------------------------------------------
# axis_indices validation: a partial dict must fail loudly
# ----------------------------------------------------------------------

@pytest.mark.parametrize("fn", [or_allreduce, or_reduce_scatter],
                         ids=["allreduce", "reduce_scatter"])
def test_partial_axis_indices_dict_raises(fn):
    x = jnp.zeros((8,), jnp.uint32)
    with pytest.raises(ValueError, match="axis_indices is missing"):
        fn(x, ("pod", "data"), axis_indices={"pod": jnp.int32(0)})
    # an empty dict over real axes is just as partial
    with pytest.raises(ValueError, match="axis_indices is missing"):
        fn(x, ("data",), axis_indices={})


def test_complete_axis_indices_dict_accepted():
    # validation must not reject a complete dict (axis size 1 context)
    mesh = make_mesh((1,), ("data",))
    x = jnp.asarray(np.arange(8, dtype=np.uint32))

    def f(a):
        idx = {"data": jax.lax.axis_index("data")}
        return (or_allreduce(a, ("data",), axis_indices=idx),
                or_reduce_scatter(a, ("data",), axis_indices=idx))

    ar, rs = jax.jit(shard_map(f, mesh=mesh, in_specs=P(), out_specs=(P(), P()),
                               axis_names={"data"}, check_vma=False))(x)
    np.testing.assert_array_equal(np.asarray(ar), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(rs), np.asarray(x))


# ----------------------------------------------------------------------
# chunked psum-emulated OR == unchunked (single-device harness; the
# multi-device parity lives in the collectives driver)
# ----------------------------------------------------------------------

def test_psum_or_emulation_chunk_invariant():
    mesh = make_mesh((1,), ("data",))
    words = np.random.default_rng(3).integers(
        0, 2**32, size=1009, dtype=np.uint32)

    def run(chunk_words):
        return np.asarray(jax.jit(shard_map(
            lambda a: _or_allreduce_psum(a, ("data",),
                                         chunk_words=chunk_words),
            mesh=mesh, in_specs=P(), out_specs=P(),
            axis_names={"data"}, check_vma=False))(jnp.asarray(words)))

    unchunked = run(1 << 30)
    np.testing.assert_array_equal(unchunked, words)  # 1-rank OR == identity
    for chunk in (1, 7, 64, 1008, 1009):
        np.testing.assert_array_equal(run(chunk), unchunked)
    with pytest.raises(ValueError, match="chunk_words"):
        _or_allreduce_psum(jnp.asarray(words), ("data",), chunk_words=0)


# ----------------------------------------------------------------------
# compressed_all_reduce must forward outer_manual (regression: the
# wrapper used to drop it, so fully-manual callers silently degraded to
# all-ranks peeling over the emulated wire on 0.4.x)
# ----------------------------------------------------------------------

def test_compressed_all_reduce_forwards_outer_manual(monkeypatch):
    import repro.core.aggregators as agg_mod
    captured = {}

    def fake_make_aggregator(name, cfg, mesh, dp_axes, tp_axes=("model",),
                             mean=True, outer_manual=None):
        captured.update(name=name, outer_manual=outer_manual)
        return lambda grads, state, specs: (grads, state)

    monkeypatch.setattr(agg_mod, "make_aggregator", fake_make_aggregator)
    cfg = CompressionConfig(ratio=0.5, lanes=8, rows=3)
    grads = {"w": jnp.zeros((4,), jnp.float32)}
    st = AggregationState(residual={"w": jnp.zeros((0,), jnp.float32)})
    compressed_all_reduce(grads, st, {"w": P()}, mesh=None, cfg=cfg,
                          dp_axes=("data",), reduce_scatter=True,
                          outer_manual=("data", "model"))
    assert captured["name"] == "compressed_rs"
    assert captured["outer_manual"] == ("data", "model")


def test_compressed_all_reduce_native_rs_through_wrapper():
    """End-to-end: rs_wire='native' must work through the wrapper when
    the caller declares a full-manual region — on 0.4.x this is exactly
    the configuration the dropped ``outer_manual`` used to break."""
    cfg = CompressionConfig(ratio=1.0, lanes=128, rows=6, rounds=10,
                            chunk_blocks=8, rs_wire="native",
                            bucket_bytes=768 * 4)
    mesh = make_mesh((1,), ("data",))
    g = np.zeros(2000, np.float32)
    r = np.random.default_rng(0)
    idx = r.choice(2000, size=100, replace=False)
    g[idx] = r.standard_normal(100).astype(np.float32)
    grads = {"w": jnp.asarray(g)}
    specs = {"w": P()}

    def fn(g):
        st = init_aggregation_state(g, cfg)
        agg, _ = compressed_all_reduce(
            g, st, specs, mesh, cfg, dp_axes=("data",), tp_axes=(),
            reduce_scatter=True, outer_manual=("data",))
        return agg

    out = jax.jit(shard_map(fn, mesh=mesh, in_specs=(specs,),
                            out_specs=specs, axis_names={"data"},
                            check_vma=False))(grads)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(grads["w"]), atol=1e-6)


# ----------------------------------------------------------------------
# per-strategy wire accounting (CompressionConfig.strategy_wire_bytes)
# ----------------------------------------------------------------------

def test_strategy_wire_bytes_native_rs_is_one_over_w():
    cfg = CompressionConfig(ratio=1.0, lanes=128, rows=6,
                            bucket_bytes=768 * 4)
    W = 4
    # n = whole buckets, a multiple of W: no padding slack at all
    n = cfg.bucket_elems_for(768 * 8) * 8
    acc = cfg.strategy_wire_bytes(n, workers=W, grad_bytes_per_elem=4)
    full = acc["compressed"]["rank_payload_bytes"]
    nat = acc["compressed_rs_native"]["rank_payload_bytes"]
    assert nat * W == full, "native RS payload must be exactly 1/W"
    # emulated RS ships the AllReduce wire
    assert acc["compressed_rs_emulated"] == acc["compressed"]
    # link traffic: the RS ring itself sends half of what the AR ring
    # sends (the no-gather number)
    nat_acc = acc["compressed_rs_native"]
    assert nat_acc["link_bytes_no_gather"] * 2 == \
        acc["compressed"]["link_bytes"]
    # the default (unaligned) accounting ships the recovered-chunk
    # gather too; ZeRO-1-aligned chunk grids skip it entirely
    assert nat_acc["link_bytes"] == nat_acc["link_bytes_with_gather"] \
        == nat_acc["link_bytes_no_gather"] + nat_acc["rs_gather_link_bytes"]
    assert not nat_acc["zero1_aligned"]
    aligned = cfg.strategy_wire_bytes(n, workers=W, grad_bytes_per_elem=4,
                                      zero1_aligned=True)[
        "compressed_rs_native"]
    assert aligned["zero1_aligned"]
    assert aligned["link_bytes"] == aligned["link_bytes_no_gather"]
    assert acc["dense"]["rank_payload_bytes"] == n * 4


def test_strategy_wire_bytes_padding_and_edges():
    cfg = CompressionConfig(ratio=1.0, lanes=128, rows=6,
                            bucket_bytes=768 * 4)
    # 3 buckets across 4 ranks: padded to 4, payload still strictly below
    # the full AllReduce payload
    n = cfg.bucket_elems_for(768 * 3) * 3
    acc = cfg.strategy_wire_bytes(n, workers=4, grad_bytes_per_elem=4)
    assert acc["compressed_rs_native"]["rank_payload_bytes"] \
        < acc["compressed"]["rank_payload_bytes"]
    # W=1: degenerate but well-defined (no wire at all on the links)
    acc1 = cfg.strategy_wire_bytes(n, workers=1)
    assert acc1["compressed"]["link_bytes"] == 0
    assert acc1["compressed_rs_native"]["link_bytes"] == 0
    with pytest.raises(ValueError, match="workers"):
        cfg.strategy_wire_bytes(n, workers=0)
    # Bloom index cannot be sliced per-rank: no native RS wire entry
    bloom = dataclasses.replace(cfg, index="bloom")
    assert bloom.strategy_wire_bytes(n, workers=4)[
        "compressed_rs_native"] is None


def test_rs_wire_config_validation():
    with pytest.raises(ValueError, match="rs_wire"):
        CompressionConfig(rs_wire="sometimes")
    for ok in ("auto", "native", "emulate"):
        assert CompressionConfig(rs_wire=ok).rs_wire == ok


@pytest.mark.parametrize("workers", [3, 6])
def test_strategy_wire_bytes_padding_non_power_of_two(workers):
    """Non-power-of-two worker counts: the native-RS chunk padding must
    round n_buckets up to the next multiple of W (and ONLY the native
    arm pays it); every other strategy ships the bucket-padded stream
    unpadded. Exact byte accounting, derived independently here."""
    cfg = CompressionConfig(ratio=1.0, lanes=128, rows=6,
                            bucket_bytes=768 * 4)
    assert cfg.block_elems == 768 and cfg.bucket_quantum == 768
    nb = 7                                    # 7 buckets: ceil(7/3)*3 = 9,
    n = 768 * nb                              # ceil(7/6)*6 = 12
    acc = cfg.strategy_wire_bytes(n, workers=workers, grad_bytes_per_elem=4)

    per_bucket = 768 * 4 + (768 // 32) * 4    # ratio=1 sketch + bitmap
    full = nb * per_bucket
    nb_p = -(-nb // workers) * workers
    ring = 2 * (workers - 1) / workers
    rs = (workers - 1) / workers

    assert acc["dense"]["rank_payload_bytes"] == n * 4
    assert acc["dense"]["link_bytes"] == int(n * 4 * ring)
    assert acc["compressed"]["rank_payload_bytes"] == full
    assert acc["compressed"]["link_bytes"] == int(full * ring)
    assert acc["compressed_rs_emulated"] == acc["compressed"]
    nat = acc["compressed_rs_native"]
    assert nat["rank_payload_bytes"] == nb_p * per_bucket // workers
    assert nat["link_bytes_no_gather"] == int(nb_p * per_bucket * rs)
    assert nat["rs_gather_link_bytes"] == int(nb_p * 768 * 4 * rs)
    assert nat["link_bytes"] == \
        nat["link_bytes_no_gather"] + nat["rs_gather_link_bytes"]
    # chunk padding never erases the win for this bucket count
    assert nat["rank_payload_bytes"] < full
    # innet: bucket-padded stream once up the tree, no chunk padding;
    # fxp32 additionally ships one int32 exponent per bucket
    innet = acc["compressed_innet"]
    assert innet["rank_payload_bytes"] == full
    assert innet["link_bytes"] == full
    assert innet["root_link_bytes"] == full
    assert innet["exponent_bytes"] == 0
    fx = dataclasses.replace(cfg, wire_dtype="fxp32")
    innet_fx = fx.strategy_wire_bytes(n, workers,
                                      grad_bytes_per_elem=4)[
        "compressed_innet"]
    assert innet_fx["exponent_bytes"] == nb * 4
    assert innet_fx["rank_payload_bytes"] == full + nb * 4
    assert innet_fx["root_link_bytes"] == full + nb * 4
    # the tree's hottest link beats every ring link at W >= 3
    assert innet_fx["root_link_bytes"] < acc["compressed"]["link_bytes"]
    assert innet_fx["root_link_bytes"] < acc["dense"]["link_bytes"]


def test_strategy_wire_bytes_innet_single_worker_no_wire():
    cfg = CompressionConfig(ratio=1.0, lanes=128, rows=6,
                            bucket_bytes=768 * 4, wire_dtype="fxp32")
    acc = cfg.strategy_wire_bytes(768 * 2, workers=1)
    assert acc["compressed_innet"]["link_bytes"] == 0
    assert acc["compressed_innet"]["root_link_bytes"] == 0
    # the aggregate a rank holds is still the full (metadata-bearing) one
    assert acc["compressed_innet"]["rank_payload_bytes"] > 0


# ----------------------------------------------------------------------
# make_aggregator: unknown strategies must name the valid ones
# ----------------------------------------------------------------------

def test_make_aggregator_unknown_strategy_names_valid_ones():
    from repro.core.aggregators import AGGREGATORS, make_aggregator
    cfg = CompressionConfig(ratio=0.5, lanes=8, rows=3)
    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError) as ei:
        make_aggregator("compresed", cfg, mesh, ("data",))
    msg = str(ei.value)
    assert "compresed" in msg
    for name in ("dense", "compressed", "compressed_rs",
                 "compressed_innet", "auto"):
        assert name in msg, f"error message should offer {name!r}: {msg}"
    assert set(AGGREGATORS) == {"dense", "compressed", "compressed_rs",
                                "compressed_innet", "auto"}


# ----------------------------------------------------------------------
# cfg.overlap is honored on EVERY wire now (PR 5): constructing and
# running the native-RS / innet strategies with overlap must stay
# silent (the PR 4 one-time "overlap ignored" warnings are retired;
# unsatisfiable chunk grids raise ValueError from core/streams.py
# naming the alignment constraint — see tests/test_streams.py).
# ----------------------------------------------------------------------

@pytest.mark.parametrize("name", ["compressed_rs", "compressed_innet"])
def test_overlap_is_honored_without_warning(name):
    from repro.core.aggregators import make_aggregator
    cfg = CompressionConfig(ratio=1.0, lanes=128, rows=6, overlap=True,
                            bucket_bytes=768 * 4, switch_slots=1)
    fused = dataclasses.replace(cfg, overlap=False)
    mesh = make_mesh((1,), ("data",))
    tree = {"w": jnp.asarray(
        np.linspace(-2.0, 2.0, 3 * 768, dtype=np.float32))}
    specs = {"w": P()}

    def run(c):
        agg = make_aggregator(name, c, mesh, ("data",), (),
                              outer_manual=("data",))

        def fn(g, r):
            out, st = agg(g, AggregationState(residual=r), specs)
            return out

        jfn = jax.jit(shard_map(
            fn, mesh=mesh, in_specs=(specs, specs), out_specs=specs,
            axis_names={"data"}, check_vma=False))
        return np.asarray(jfn(tree, init_aggregation_state(
            tree, c).residual)["w"])

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        got = run(cfg)
    assert np.array_equal(got, run(fused)), \
        "overlapped schedule diverged from the fused wire"
