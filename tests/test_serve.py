import numpy as np
import jax

from repro.models import ModelConfig, model_api
from repro.serve import ServeEngine, ContinuousBatcher, Request

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                  dtype="float32")


def _engine(batch=2, max_len=48):
    api = model_api(CFG)
    params = api.init(jax.random.PRNGKey(0))
    return ServeEngine(api, params, max_len=max_len, batch=batch)


def test_generate_shapes_and_determinism():
    eng = _engine()
    prompts = np.ones((2, 8), np.int32)
    a = eng.generate(prompts, max_new=5)
    b = eng.generate(prompts, max_new=5)
    assert a.shape == (2, 5)
    assert np.array_equal(a, b)          # greedy = deterministic
    assert a.min() >= 0 and a.max() < CFG.vocab


def test_continuous_batching_completes_all():
    eng = _engine(batch=2)
    cb = ContinuousBatcher(eng)
    for u in range(5):
        cb.submit(Request(uid=u, prompt=np.ones(4, np.int32) * (u + 1),
                          max_new_tokens=3))
    done = cb.run(decode_steps=64)
    assert sorted(c.uid for c in done) == [0, 1, 2, 3, 4]
    assert all(len(c.tokens) == 3 for c in done)


def test_continuous_matches_batch_generate():
    """A single request through the batcher equals batch generate."""
    eng = _engine(batch=1)
    prompt = np.arange(1, 9, dtype=np.int32)
    ref = eng.generate(prompt[None], max_new=4)[0]
    cb = ContinuousBatcher(eng)
    cb.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    done = cb.run(decode_steps=16)
    assert list(ref) == done[0].tokens
