import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import CompressionConfig
from repro.core.blocks import make_plan, to_blocks
from repro.core.sketch import encode_blocks, estimate_blocks
from repro.core.peeling import peel_blocks
from conftest import make_sparse

CFG = CompressionConfig(ratio=0.2, lanes=256, rows=6, rounds=10)


def _blocks(x):
    plan = make_plan(x.size, CFG)
    return to_blocks(jnp.asarray(x), plan), plan


def test_encode_linearity():
    """Homomorphism: Y(x1 + x2) == Y(x1) + Y(x2) (exactly, same hashes)."""
    x1 = make_sparse(40_000, 0.03, 1)
    x2 = make_sparse(40_000, 0.02, 2)
    b1, plan = _blocks(x1)
    b2, _ = _blocks(x2)
    bs, _ = _blocks(x1 + x2)
    ids = jnp.arange(plan.nb, dtype=jnp.int32)
    y1 = encode_blocks(b1, ids, CFG)
    y2 = encode_blocks(b2, ids, CFG)
    ys = encode_blocks(bs, ids, CFG)
    np.testing.assert_allclose(np.asarray(y1 + y2), np.asarray(ys),
                               rtol=0, atol=1e-5)


def test_peel_recovers_sparse_exactly():
    x = make_sparse(100_000, 0.02, 3)
    xb, plan = _blocks(x)
    ids = jnp.arange(plan.nb, dtype=jnp.int32)
    y = encode_blocks(xb, ids, CFG)
    res = peel_blocks(y, xb != 0, ids, CFG)
    assert int(jnp.sum(res.residual)) == 0
    np.testing.assert_allclose(np.asarray(res.values), np.asarray(xb),
                               atol=1e-6)


def test_peel_degrades_gracefully_when_overloaded():
    # 2x over capacity: some coordinates unpeelable, but every peeled
    # coordinate is exact and residuals get the unbiased estimate
    frac = 2.0 * CFG.peel_capacity / CFG.block_elems
    x = make_sparse(60_000, frac, 4)
    xb, plan = _blocks(x)
    ids = jnp.arange(plan.nb, dtype=jnp.int32)
    y = encode_blocks(xb, ids, CFG)
    res = peel_blocks(y, xb != 0, ids, CFG)
    assert int(jnp.sum(res.residual)) > 0
    peeled = np.asarray(res.peeled)
    np.testing.assert_allclose(np.asarray(res.values)[peeled],
                               np.asarray(xb)[peeled], atol=1e-4)


def test_estimate_unbiased_sign():
    """Count-Sketch median estimate has the right sign/scale for large
    coordinates even without peeling."""
    x = make_sparse(50_000, 0.01, 5) * 10
    xb, plan = _blocks(x)
    ids = jnp.arange(plan.nb, dtype=jnp.int32)
    y = encode_blocks(xb, ids, CFG)
    est = estimate_blocks(y, ids, CFG)
    big = np.abs(np.asarray(xb)) > 5
    rel = np.abs(np.asarray(est)[big] - np.asarray(xb)[big]) / np.abs(np.asarray(xb)[big])
    assert np.median(rel) < 0.05


def test_peel_zero_input():
    x = np.zeros(10_000, np.float32)
    xb, plan = _blocks(x)
    ids = jnp.arange(plan.nb, dtype=jnp.int32)
    y = encode_blocks(xb, ids, CFG)
    res = peel_blocks(y, xb != 0, ids, CFG)
    assert float(jnp.abs(res.values).max()) == 0.0
