"""Sharded, batched fold pipeline (PR 10): tiling, striping, and the
bit-for-bit parity pins against the PR 9 sequential fold.

The core claims under test:

- sharded+batched fold == sequential fold **bit-for-bit** — outputs AND
  per-client RX/retransmit accounting — for any shard count, microbatch
  size, and arrival permutation (fxp32 in any order; f32 against the
  sequential engine fed client-id-sorted arrivals, which is exactly the
  canonical order the batched pipeline reduces in);
- the batched f32 fold is arrival-order invariant bit-for-bit — the
  property PR 9 could only pin for the integer wire;
- a microbatch whose running partial exceeds the fxp32
  ``mantissa_bits = 30 - ceil_log2(W)`` budget raises through the
  ``SwitchModel`` register check exactly as the sequential fold does
  (the PR 9 dynamic-W gate scenario, batched);
- the recover pass is cached by contract geometry: same-geometry rounds
  share one compiled fn, renegotiated geometry gets its own (the PR 10
  stale-shape bugfix).
"""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.bucketing import make_bucket_plan
from repro.core.config import CompressionConfig
from repro.elastic import (AdmissionPolicy, ClientPayload, ElasticClient,
                           ElasticServer, FoldEngine, ShardedFoldService,
                           negotiate_contract, shard_contract, shard_ranges,
                           stripe_payload)
from repro.elastic.fold import _recover_fn
from repro.ft.failures import FailureSimulator, SwitchRetransmitPolicy
from repro.net.fixedpoint import FixedPointWire
from repro.net.switch import SwitchModel

CFG = CompressionConfig(ratio=1.0, lanes=128, rows=6, rounds=10,
                        chunk_blocks=8, topk_ratio=0.1, topk_exact=True,
                        error_feedback=True, bucket_bytes=2 * 768 * 4)
CFG_FX = dataclasses.replace(CFG, wire_dtype="fxp32")
# 9000 elems -> 6 buckets of 1536: enough range for real shard sweeps
SHAPES = {"a": (7000,), "b": (50, 40)}
TEMPLATE = {k: np.zeros(sh, np.float32) for k, sh in SHAPES.items()}


def dyadic_tree(seed):
    """sign * 2^e values: every summation order is exact, so bitwise
    equality isolates the fold math (same trick as test_elastic.py)."""
    r = np.random.default_rng(seed)
    out = {}
    for k, sh in SHAPES.items():
        n = int(np.prod(sh))
        g = np.zeros(n, np.float32)
        idx = r.choice(n, size=max(1, n // 3), replace=False)
        g[idx] = (r.choice([-1.0, 1.0], size=idx.size)
                  * np.exp2(r.integers(-2, 3, size=idx.size))
                  ).astype(np.float32)
        out[k] = jnp.asarray(g.reshape(sh))
    return out


def _plan(cfg=CFG):
    return make_bucket_plan(TEMPLATE, cfg)


# ----------------------------------------------------------------------
# Tiling + striping
# ----------------------------------------------------------------------

def test_shard_ranges_balanced_contiguous_tiling():
    rs = shard_ranges(10, 3)
    assert [(r.start, r.count) for r in rs] == [(0, 4), (4, 3), (7, 3)]
    assert rs[0].stop == rs[1].start and rs[1].stop == rs[2].start
    assert rs[-1].stop == 10
    assert shard_ranges(4, 4) == shard_ranges(4, 4)    # frozen/hashable
    with pytest.raises(ValueError, match=">= 1"):
        shard_ranges(4, 0)
    with pytest.raises(ValueError, match="at least one bucket"):
        shard_ranges(3, 4)


def test_shard_contract_truncates_like_group_view():
    plan = _plan()
    contract = negotiate_contract(0, range(3), plan, CFG)
    rs = shard_ranges(contract.n_buckets, 3)
    # with and without the plan, the shard-view geometry is identical
    for r in rs:
        a = shard_contract(contract, r, plan)
        b = shard_contract(contract, r)
        assert (a.n_buckets, a.total_elems) == (b.n_buckets, b.total_elems)
        assert a.total_elems == plan.group_view(r.start, r.count).total
    # the last shard carries the stream's padding truncation
    assert sum(shard_contract(contract, r).total_elems for r in rs) \
        == contract.total_elems


def test_stripe_payload_is_exact_and_lossless():
    plan = _plan()
    contract = negotiate_contract(0, range(2), plan, CFG)
    payload = ElasticClient(0, CFG).contribute(contract, dyadic_tree(7))
    bpb = contract.bucket_elems // CFG.block_elems
    wpb = contract.bucket_elems // 32
    for S in (1, 2, 3, contract.n_buckets):
        rs = shard_ranges(contract.n_buckets, S)
        subs = stripe_payload(payload, contract, rs, bpb, wpb)
        assert len(subs) == S
        # stripes reassemble the full payload exactly and their byte
        # counts sum to the wire total
        assert np.array_equal(
            np.concatenate([np.asarray(s.sketch) for s in subs]),
            np.asarray(payload.sketch))
        assert np.array_equal(
            np.concatenate([np.asarray(s.index_words) for s in subs]),
            np.asarray(payload.index_words))
        assert sum(s.nbytes for s in subs) == payload.nbytes


def test_client_side_striping_matches_server_striping():
    plan = _plan()
    contract = negotiate_contract(0, range(2), plan, CFG)
    client = ElasticClient(0, CFG)
    client.propose(contract, dyadic_tree(9))
    full = client.payload(contract)
    stripes = client.payload_stripes(contract, 3)
    server_side = stripe_payload(
        full, contract, shard_ranges(contract.n_buckets, 3),
        contract.bucket_elems // CFG.block_elems,
        contract.bucket_elems // 32)
    for a, b in zip(stripes, server_side):
        assert a.client == b.client and a.contract_id == b.contract_id
        assert np.array_equal(np.asarray(a.sketch), np.asarray(b.sketch))
        assert np.array_equal(np.asarray(a.index_words),
                              np.asarray(b.index_words))


# ----------------------------------------------------------------------
# The parity pin: sharded+batched == sequential, bit-for-bit
# ----------------------------------------------------------------------

def _run_pair(wire_cfg, cohort, n_shards, batch_size, perm, delays,
              seed0=100):
    """Fold one round through both paths; returns (sequential state,
    sharded state, sequential out, sharded out, both engines)."""
    plan = _plan(wire_cfg)
    contract = negotiate_contract(0, cohort, plan, wire_cfg)
    clients = {c: ElasticClient(c, wire_cfg) for c in cohort}
    seq = FoldEngine(contract, wire_cfg)
    svc = ShardedFoldService(contract, wire_cfg, n_shards=n_shards,
                             batch_size=batch_size, plan=plan)
    st_seq, st_sh = seq.init_state(), svc.init_state()
    if wire_cfg.wire_dtype == "fxp32":
        for i, c in enumerate(cohort):
            p = clients[c].propose(contract, dyadic_tree(seed0 + i))
            seq.propose_exponents(st_seq, c, p.exponents)
            svc.propose_exponents(st_sh, c, p.exponents)
        sealed = seq.seal_exponents(st_seq)
        assert np.array_equal(sealed, svc.seal_exponents(st_sh))
        payloads = {c: clients[c].payload(contract, sealed)
                    for c in cohort}
    else:
        payloads = {c: clients[c].contribute(
            contract, dyadic_tree(seed0 + i))
            for i, c in enumerate(cohort)}
    pol_seq = SwitchRetransmitPolicy(timeout_s=0.05, max_retries=64)
    pol_sh = SwitchRetransmitPolicy(timeout_s=0.05, max_retries=64)
    # the sequential reference folds in client-id-sorted order — the
    # canonical order; fxp32 would match in ANY order (integer adds)
    for c in sorted(cohort):
        seq.fold(st_seq, payloads[c], arrival_s=delays[c], policy=pol_seq)
    for c in perm:
        svc.fold(st_sh, payloads[c], arrival_s=delays[c], policy=pol_sh)
    return seq, svc, st_seq, st_sh, payloads


@pytest.mark.parametrize("wire", ["f32", "fxp32"])
@pytest.mark.parametrize("n_shards,batch_size", [(2, 3), (3, 1), (6, 2)])
def test_sharded_batched_fold_matches_sequential(wire, n_shards,
                                                 batch_size):
    cfg = CFG if wire == "f32" else CFG_FX
    cohort = (3, 7, 11, 20, 21)       # non-contiguous client ids
    r = np.random.default_rng(n_shards * 10 + batch_size)
    perm = list(r.permutation(list(cohort)))
    delays = {c: float(d) for c, d in
              zip(cohort, r.choice([0.0, 0.08, 0.17], size=len(cohort)))}
    seq, svc, st_seq, st_sh, payloads = _run_pair(
        cfg, cohort, n_shards, batch_size, perm, delays)
    out_seq, out_sh = seq.finalize(st_seq), svc.finalize(st_sh)
    assert np.array_equal(out_seq, out_sh)            # bit-for-bit
    # per-client accounting parity: RX bytes and retransmit totals
    assert st_seq.rx_bytes == st_sh.rx_bytes
    assert st_seq.retransmits == st_sh.retransmits
    assert st_seq.contributions == st_sh.contributions
    assert st_sh.occupancy_peak <= svc.window_slots
    # the deferred-residual path decodes identically too
    c0 = cohort[0]
    assert np.array_equal(seq.decode_payload(payloads[c0]),
                          svc.decode_payload(payloads[c0]))


def test_randomized_parity_sweep():
    """Seeded randomized version of the hypothesis property (see
    test_elastic_shard_property.py, which needs the 'test' extra):
    random cohort sizes, shard counts, microbatch sizes, and arrival
    permutations, both wires — outputs and accounting bit-identical."""
    r = np.random.default_rng(2026)
    for trial in range(6):
        wire_cfg = CFG if trial % 2 == 0 else CFG_FX
        n_clients = int(r.integers(2, 8))
        cohort = tuple(sorted(r.choice(64, size=n_clients,
                                       replace=False).tolist()))
        plan = _plan(wire_cfg)
        n_shards = int(r.integers(1, plan.n_buckets + 1))
        batch_size = int(r.integers(1, n_clients + 2))
        perm = list(r.permutation(list(cohort)))
        delays = {c: float(r.choice([0.0, 0.06, 0.13])) for c in cohort}
        seq, svc, st_seq, st_sh, _ = _run_pair(
            wire_cfg, cohort, n_shards, batch_size, perm, delays,
            seed0=300 + 20 * trial)
        assert np.array_equal(seq.finalize(st_seq), svc.finalize(st_sh))
        assert st_seq.rx_bytes == st_sh.rx_bytes
        assert st_seq.retransmits == st_sh.retransmits


def test_sharded_f32_fold_is_arrival_order_invariant():
    """The new PR 10 property: batched f32 folds reduce in canonical
    client-sorted order, so ANY arrival permutation and ANY microbatch
    partition give the same f32 bits — PR 9 could only pin this for the
    integer fxp32 wire."""
    plan = _plan()
    cohort = tuple(range(5))
    contract = negotiate_contract(0, cohort, plan, CFG)
    clients = {c: ElasticClient(c, CFG) for c in cohort}
    # non-dyadic gradients: f32 rounding IS live, ordering matters
    r = np.random.default_rng(5)
    payloads = {}
    for c in cohort:
        g = {k: jnp.asarray(r.normal(size=sh).astype(np.float32) * np.pi)
             for k, sh in SHAPES.items()}
        payloads[c] = clients[c].contribute(contract, g)
    outs = []
    for (perm, bs) in [((0, 1, 2, 3, 4), 1), ((4, 2, 0, 3, 1), 2),
                       ((1, 3, 0, 4, 2), 5), ((2, 4, 1, 0, 3), 3)]:
        svc = ShardedFoldService(contract, CFG, n_shards=2,
                                 batch_size=bs, plan=plan)
        st = svc.init_state()
        for c in perm:
            svc.fold(st, payloads[c])
        outs.append(svc.finalize(st))
    for o in outs[1:]:
        assert np.array_equal(outs[0], o)


# ----------------------------------------------------------------------
# fxp32 batched-partial overflow: the PR 9 dynamic-W gate, batched
# ----------------------------------------------------------------------

def _overflow_round(q_cell, batch_size):
    """A 9-client fxp32 round whose payload cells all hold ``q_cell``,
    folded as microbatches of ``batch_size``; returns the finalize-ready
    (service, state)."""
    plan = _plan(CFG_FX)
    cohort = tuple(range(9))
    contract = negotiate_contract(0, cohort, plan, CFG_FX)
    svc = ShardedFoldService(contract, CFG_FX, n_shards=3,
                             batch_size=batch_size, plan=plan)
    st = svc.init_state()
    exps = np.full((contract.n_buckets,), 10, np.int32)
    for c in cohort:
        svc.propose_exponents(st, c, exps)
    sealed = svc.seal_exponents(st)
    e0 = svc.engines[0]
    sk = np.full((plan.n_buckets * e0.blocks_per_bucket,
                  CFG_FX.rows, CFG_FX.lanes), q_cell, np.int32)
    wd = np.zeros((plan.padded // 32,), np.uint32)
    for c in cohort:
        svc.fold(st, ClientPayload(
            client=c, contract_id=contract.contract_id, sketch=sk,
            index_words=wd, exponents=sealed))
    return svc, st


def test_fxp32_batched_partial_overflow_matches_sequential_gate():
    """The PR 9 dynamic-W scenario (W grows 4 -> 9), restated for
    batched partials: nine stale-budget (M=28) worst-case payloads
    overflow int32 — the microbatched fold raises the SwitchModel's
    register-check OverflowError exactly as the sequential per-payload
    walk does — while the renegotiated budget (M=26) folds clean."""
    w4, w9 = FixedPointWire(4), FixedPointWire(4).with_workers(9)
    assert (w4.mantissa_bits, w9.mantissa_bits) == (28, 26)
    q_stale = 2**28 - 2**4            # worst-case stale-budget cell
    assert 9 * q_stale > 2**31 - 1
    q_new = 2**26 - 2**2              # same cell under the new budget
    assert 9 * q_new <= 2**30

    # direct check-surface pin: the batched extrema raise the exact
    # error the streaming aggregate raises
    with pytest.raises(OverflowError, match="32-bit switch register"):
        SwitchModel(ports=9, slots=4).check_batched_partial(
            9 * q_stale, 0, ports=9)
    SwitchModel(ports=9, slots=4).check_batched_partial(9 * q_new, 0)

    # through the batched pipeline: the flush whose running partial
    # crosses int32 raises (batch_size=9 folds all nine in ONE
    # microbatch — k <= headroom in count, but the stale budget breaks
    # the magnitude bound the contract's mantissa budget guarantees)
    with pytest.raises(OverflowError, match="32-bit switch register"):
        _overflow_round(q_stale, batch_size=9)
    # and the sequential engine raises the same error on the same data
    plan = _plan(CFG_FX)
    contract = negotiate_contract(0, range(9), plan, CFG_FX)
    seq = FoldEngine(contract, CFG_FX)
    st = seq.init_state()
    exps = np.full((contract.n_buckets,), 10, np.int32)
    for c in range(9):
        seq.propose_exponents(st, c, exps)
    sealed = seq.seal_exponents(st)
    sk = np.full(seq.sketch_shape, q_stale, np.int32)
    wd = np.zeros((seq.n_words,), np.uint32)
    with pytest.raises(OverflowError, match="32-bit switch register"):
        for c in range(9):
            seq.fold(st, ClientPayload(
                client=c, contract_id=contract.contract_id, sketch=sk,
                index_words=wd, exponents=sealed))

    # renegotiated budget: the same nine-payload microbatch is provably
    # safe and the fold completes
    svc, st = _overflow_round(q_new, batch_size=9)
    assert st.contributions == 9
    assert int(st.shard_states[0].sketch[0, 0, 0]) == 9 * q_new


def test_batched_fold_accounting_rolls_up_through_switch_pools():
    svc, st = _overflow_round(2**20, batch_size=4)
    out = svc.finalize(st)
    assert out.shape == (st.contract.n_buckets, st.contract.bucket_elems)
    # every shard walked its slots-bounded window grid at least once
    # per flush, and the rollup exposes the sequential FoldState surface
    assert st.windows > 0
    assert 0 < st.occupancy_peak <= svc.window_slots
    per_shard = svc.per_shard_report(st)
    assert len(per_shard) == 3
    assert sum(row["buckets"] for row in per_shard) \
        == st.contract.n_buckets
    assert all(row["contributions"] == 9 for row in per_shard)
    assert sum(row["windows"] for row in per_shard) == st.windows


# ----------------------------------------------------------------------
# Recover-fn cache: keyed by contract geometry (the PR 10 bugfix)
# ----------------------------------------------------------------------

def test_recover_cache_shared_across_same_geometry_rounds():
    plan = _plan()
    c0 = negotiate_contract(0, range(3), plan, CFG)
    c1 = negotiate_contract(1, range(3), plan, CFG)
    e0, e1 = FoldEngine(c0, CFG), FoldEngine(c1, CFG)
    # same geometry -> the SAME compiled fn object (no per-round retrace)
    assert e0._recover_jit is e1._recover_jit
    # every equal-sized shard of a sharded round shares ONE compiled fn
    # too (block_offset is traced, so different offsets don't retrace) —
    # but NOT the full-range engine's, whose padded length differs
    svc = ShardedFoldService(c0, CFG, n_shards=2, plan=plan)
    assert svc.engines[0]._recover_jit is svc.engines[1]._recover_jit
    assert svc.engines[0]._recover_jit is not e0._recover_jit


def test_recover_cache_distinct_across_renegotiated_geometry():
    """Regression for the stale-shape hazard: consecutive rounds whose
    bucket geometry renegotiates must not reuse a stale-shaped compiled
    fn — and both rounds must recover correct values."""
    plan_a = _plan()
    small = {"a": np.zeros((900,), np.float32)}
    plan_b = make_bucket_plan(small, CFG)
    assert plan_a.n_buckets != plan_b.n_buckets
    ca = negotiate_contract(0, range(2), plan_a, CFG)
    cb = negotiate_contract(1, range(2), plan_b, CFG)
    ea, eb = FoldEngine(ca, CFG), FoldEngine(cb, CFG)
    assert ea._recover_jit is not eb._recover_jit
    # geometry A round, then geometry B round, back-to-back: both exact
    for contract, engine, tree in (
            (ca, ea, None),
            (cb, eb, {"a": np.ones((900,), np.float32)})):
        st = engine.init_state()
        ref = np.zeros((contract.n_buckets * contract.bucket_elems,),
                       np.float32)
        for w in range(2):
            cl = ElasticClient(w, CFG)
            g = tree if tree is not None else dyadic_tree(500 + w)
            p = cl.contribute(contract, g)
            engine.fold(st, p)
            dec = np.asarray(engine.decode_payload(p)).reshape(-1)
            # the compiled fn in use matches THIS round's geometry
            assert dec.shape == ref.shape
        out = engine.finalize(st)
        assert out.shape == (contract.n_buckets, contract.bucket_elems)
        assert np.isfinite(out).all()
    # wire dtype and mantissa budget are part of the key
    plan_fx = _plan(CFG_FX)
    f4 = FoldEngine(negotiate_contract(0, range(4), plan_fx, CFG_FX),
                    CFG_FX)
    f9 = FoldEngine(negotiate_contract(1, range(9), plan_fx, CFG_FX),
                    CFG_FX)
    assert f4._recover_jit is not f9._recover_jit      # mantissa differs
    assert f4._recover_jit is not ea._recover_jit      # wire differs
    # and the cache key is exactly (cfg, padded, wire, mantissa)
    assert _recover_fn(CFG, ca.n_buckets * ca.bucket_elems, "f32",
                       None) is ea._recover_jit


# ----------------------------------------------------------------------
# Server integration: sharded rounds close out identically
# ----------------------------------------------------------------------

def test_sharded_server_matches_unsharded_server_with_deferrals():
    """Two servers — sequential and sharded+batched — replay the same
    two-round schedule with a straggler deferral: outputs, reports, and
    the loss-free residual carry are bit-identical."""
    sim = FailureSimulator(straggle_at=((0, 2, 5.0),))
    servers = [
        ElasticServer(TEMPLATE, CFG,
                      policy=AdmissionPolicy(max_cohort=8, quorum=0.5,
                                             deadline_s=1.0)),
        ElasticServer(TEMPLATE, CFG,
                      policy=AdmissionPolicy(max_cohort=8, quorum=0.5,
                                             deadline_s=1.0),
                      n_shards=2, batch_size=2),
    ]
    outs = []
    for srv in servers:
        clients = [ElasticClient(w, CFG) for w in range(4)]
        for w in range(4):
            srv.join(w)
        round_outs = []
        for rnd in range(2):
            contract = srv.open_round()
            for w in sorted(range(4)):     # canonical arrival order
                p = clients[w].contribute(contract,
                                          dyadic_tree(700 + 10 * rnd + w))
                srv.submit(p, arrival_s=sim.client_delay(rnd, w))
            out, rep = srv.close_round(now_s=1.5)
            round_outs.append((out, rep))
        outs.append(round_outs)
    for (o_a, r_a), (o_b, r_b) in zip(*outs):
        assert np.array_equal(o_a, o_b)               # bit-for-bit
        assert r_a.folded == r_b.folded
        assert r_a.deferred == r_b.deferred
        assert r_a.close_reason == r_b.close_reason
        assert r_a.rx_bytes_total == r_b.rx_bytes_total
        assert r_a.residual_carried_in == r_b.residual_carried_in
    # round 0 deferred the straggler, round 1 carried it back in
    assert outs[0][0][1].deferred == 1
    assert outs[0][1][1].residual_carried_in


def test_sharded_service_validation_mirrors_sequential():
    plan = _plan()
    contract = negotiate_contract(0, (0, 1), plan, CFG)
    svc = ShardedFoldService(contract, CFG, n_shards=2, batch_size=2,
                             plan=plan)
    st = svc.init_state()
    p = ElasticClient(0, CFG).contribute(contract, dyadic_tree(1))
    svc.fold(st, p)
    from repro.elastic import FoldError, StaleContractError
    with pytest.raises(FoldError, match="already contributed"):
        svc.fold(st, p)
    with pytest.raises(FoldError, match="not in this round's cohort"):
        svc.fold(st, ElasticClient(9, CFG).contribute(
            contract, dyadic_tree(2)))
    with pytest.raises(StaleContractError, match="re-encode"):
        stale = dataclasses.replace(p, contract_id="r9:bogus")
        svc.fold(st, stale)
    with pytest.raises(FoldError, match="nothing folded"):
        svc.finalize(svc.init_state())
    with pytest.raises(ValueError, match="batch_size"):
        ShardedFoldService(contract, CFG, n_shards=2, batch_size=0)
