"""End-to-end single-device training: dense vs lossless-compressed must
match; checkpoint restart must resume identically."""
import tempfile
import numpy as np
import jax
import pytest

from repro.models import ModelConfig, model_api
from repro.core import CompressionConfig
from repro.train import TrainConfig, OptimizerConfig
from repro.train.loop import run_training
from repro.parallel.sharding import ShardingProfile
from repro.ft import FailureSimulator

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                  dtype="float32")


def _mesh():
    from repro.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))


def _run(tc, steps=8, **kw):
    api = model_api(CFG)
    return run_training(api, tc, _mesh(), global_batch=4, seq_len=32,
                        steps=steps, log_every=0, **kw)


OPT = OptimizerConfig(lr=5e-3, warmup_steps=1, total_steps=50)


def test_dense_loss_decreases():
    res = _run(TrainConfig(aggregator="dense", optimizer=OPT,
                           sharding=ShardingProfile(zero1=False),
                           remat="none"))
    assert res.losses[-1] < res.losses[0]


def test_compressed_single_worker_is_identity():
    """With one worker the compressed path still encodes+peels; training
    must track dense to fp tolerance (lossless regime)."""
    comp = CompressionConfig(ratio=2.0, lanes=512, rows=60, rounds=10,
                             chunk_blocks=16)
    r1 = _run(TrainConfig(aggregator="dense", optimizer=OPT,
                          sharding=ShardingProfile(zero1=False),
                          remat="none"))
    r2 = _run(TrainConfig(aggregator="compressed", compression=comp,
                          optimizer=OPT,
                          sharding=ShardingProfile(zero1=False),
                          remat="none"))
    np.testing.assert_allclose(r1.losses, r2.losses, atol=2e-3)


def test_auto_single_worker_matches_dense():
    """PR 6: `auto` must be trainable through the full loop. At dp=1
    the step's pre-existing single-worker rule substitutes dense for
    every strategy (nothing to aggregate), so training is bit-identical
    to `dense`; the multi-worker auto path (analytic plan + occupancy
    telemetry through the metrics) is driven by
    tests/drivers/train_step_driver.py and --compare-auto."""
    comp = CompressionConfig(ratio=2.0, lanes=512, rows=60, rounds=10,
                             chunk_blocks=16)
    r1 = _run(TrainConfig(aggregator="dense", optimizer=OPT,
                          sharding=ShardingProfile(zero1=False),
                          remat="none"))
    r2 = _run(TrainConfig(aggregator="auto", compression=comp,
                          optimizer=OPT,
                          sharding=ShardingProfile(zero1=False),
                          remat="none"))
    np.testing.assert_array_equal(r1.losses, r2.losses)


def test_ep_exchange_single_worker_matches_local_combine():
    """PR 8: the MoE combine routed through the expert-parallel
    all-to-all exchange (dense and compressed wires) must reproduce the
    local scatter-add combine. At W=1 the wire merge is the identity and
    the exchange codec's recovery is exact, so training is bit-identical
    on all three settings; the multi-rank legs are driven by
    tests/drivers/train_step_driver.py."""
    from repro.models.config import MoEConfig
    moe_cfg = ModelConfig(name="tinymoe", family="moe", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab=128,
                          moe=MoEConfig(num_experts=4, top_k=2,
                                        shared_experts=1, expert_d_ff=64,
                                        capacity_factor=2.0),
                          dtype="float32")
    api = model_api(moe_cfg)

    def run(ep):
        tc = TrainConfig(aggregator="dense", optimizer=OPT,
                         sharding=ShardingProfile(zero1=False),
                         remat="none", ep_exchange=ep,
                         compression=CompressionConfig(lanes=128, rows=6,
                                                       chunk_blocks=8))
        from repro.train.loop import run_training
        return run_training(api, tc, _mesh(), global_batch=4, seq_len=32,
                            steps=4, log_every=0).losses

    l_none = run("none")
    np.testing.assert_array_equal(l_none, run("dense"))
    np.testing.assert_array_equal(l_none, run("compressed"))
    assert l_none[-1] < l_none[0] * 1.05   # sanity: the model trains


def test_restart_resumes_from_checkpoint():
    tc = TrainConfig(aggregator="dense", optimizer=OPT,
                     sharding=ShardingProfile(zero1=False), remat="none")
    with tempfile.TemporaryDirectory() as d:
        res = _run(tc, steps=12, ckpt_dir=d, ckpt_every=4,
                   failure_sim=FailureSimulator(fail_at_steps=(6,)))
        assert res.restarts == 1
        assert res.final_step == 12
        # the replayed segment re-runs steps 4..6 on the deterministic
        # stream: the loss at a replayed step must match the first pass
        # (loss *decrease* over so few steps is flaky; convergence is
        # asserted by the other tests in this module)
        assert len(res.losses) == 12 + 2   # 12 + 2 replayed steps
        np.testing.assert_allclose(res.losses[7], res.losses[5], atol=1e-4)
