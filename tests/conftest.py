"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
single real CPU device; multi-device tests run subprocess drivers that
set their own flags (tests/drivers/)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_sparse(n, frac, seed=0, dtype=np.float32):
    r = np.random.default_rng(seed)
    x = np.zeros(n, dtype)
    k = int(n * frac)
    if k:
        idx = r.choice(n, size=k, replace=False)
        x[idx] = r.normal(size=k).astype(dtype)
    return x
