"""Hypothesis property tests for the system's core invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'test' extra (pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core import (CompressionConfig, HomomorphicCompressor,
                        CompressedLeaf)
from repro.core import index as idx
from repro.core import topk as topk_lib


def sparse_vec(data, n, max_frac):
    r = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    frac = data.draw(st.floats(0.0, max_frac))
    x = np.zeros(n, np.float32)
    k = int(n * frac)
    if k:
        ii = r.choice(n, size=k, replace=False)
        x[ii] = r.normal(size=k).astype(np.float32) * 10
    return x


@settings(max_examples=20, deadline=None)
@given(data=st.data(),
       n=st.integers(1_000, 80_000),
       lanes=st.sampled_from([128, 256, 512]),
       rows=st.sampled_from([6, 12]))
def test_homomorphic_sum_recovery(data, n, lanes, rows):
    """recover(S(x1) + S(x2)) == x1 + x2 whenever load is under gamma."""
    cfg = CompressionConfig(ratio=0.3, lanes=lanes, rows=rows, rounds=12,
                            chunk_blocks=8)
    comp = HomomorphicCompressor(cfg)
    # keep the union load safely under capacity (block size matters for
    # the w.h.p. guarantee; small lanes need more margin)
    margin = 0.35 if lanes >= 512 else 0.2
    max_frac = margin * cfg.peel_capacity / cfg.block_elems
    x1 = sparse_vec(data, n, max_frac)
    x2 = sparse_vec(data, n, max_frac)
    c1, c2 = comp.compress(jnp.asarray(x1)), comp.compress(jnp.asarray(x2))
    agg = CompressedLeaf(sketch=c1.sketch + c2.sketch,
                         index_words=c1.index_words | c2.index_words)
    xr, stats = comp.recover(agg, n, with_stats=True)
    assert int(stats.residual) == 0
    np.testing.assert_allclose(np.asarray(xr), x1 + x2, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31), nbits=st.integers(1, 256))
def test_pack_unpack_inverse(seed, nbits):
    r = np.random.default_rng(seed)
    n = nbits * 32
    bits = r.random(n) < r.random()
    words = idx.pack_bits(jnp.asarray(bits))
    assert np.array_equal(np.asarray(idx.unpack_bits(words, (n,))), bits)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31),
       n=st.integers(100, 5_000),
       ratio=st.floats(0.01, 0.5))
def test_error_feedback_conserves_mass(seed, n, ratio):
    """sparsified + residual == grad + old_residual (nothing is lost)."""
    r = np.random.default_rng(seed)
    g = r.normal(size=n).astype(np.float32)
    res = r.normal(size=n).astype(np.float32)
    k = max(1, int(n * ratio))
    sent, new_res = topk_lib.apply_error_feedback(
        jnp.asarray(g), jnp.asarray(res), k, exact=True)
    np.testing.assert_allclose(np.asarray(sent) + np.asarray(new_res),
                               g + res, atol=1e-5)
    # sent is k-sparse (up to ties)
    assert int((np.asarray(sent) != 0).sum()) <= k + 5


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_bloom_never_false_negative(seed):
    cfg = CompressionConfig(bloom_bits_ratio=0.3)
    r = np.random.default_rng(seed)
    x = np.zeros(16_384, np.float32)
    k = r.integers(0, 300)
    if k:
        x[r.choice(x.size, size=k, replace=False)] = 1.0
    xb = x.reshape(2, 16, 512)
    filt = idx.bloom_build(jnp.asarray(xb), cfg)
    cand = np.asarray(idx.bloom_query(xb.shape, cfg, filt))
    assert np.all(cand[xb != 0])
