"""Hypothesis property test (PR 10): sharded+batched fold == PR 9
sequential fold bit-for-bit — outputs AND per-client RX/retransmit
accounting — over random cohort sizes, shard counts, microbatch sizes,
and arrival permutations, for both wires.

``tests/test_elastic_shard.py::test_randomized_parity_sweep`` is the
seeded fallback that runs without the 'test' extra; this module is the
generative version.
"""

import dataclasses

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'test' extra (pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core.bucketing import make_bucket_plan
from repro.core.config import CompressionConfig
from repro.elastic import (ElasticClient, FoldEngine, ShardedFoldService,
                           negotiate_contract)
from repro.ft.failures import SwitchRetransmitPolicy

CFG = CompressionConfig(ratio=1.0, lanes=128, rows=6, rounds=10,
                        chunk_blocks=8, topk_ratio=0.1, topk_exact=True,
                        error_feedback=True, bucket_bytes=2 * 768 * 4)
CFG_FX = dataclasses.replace(CFG, wire_dtype="fxp32")
SHAPES = {"a": (7000,), "b": (50, 40)}


def _tree(seed):
    r = np.random.default_rng(seed)
    return {k: (r.normal(size=sh) * np.pi).astype(np.float32)
            for k, sh in SHAPES.items()}


@settings(max_examples=12, deadline=None)
@given(data=st.data(),
       wire=st.sampled_from(["f32", "fxp32"]),
       n_clients=st.integers(2, 7),
       batch_size=st.integers(1, 8),
       seed=st.integers(0, 2**31))
def test_sharded_batched_fold_is_bitwise_equal_to_sequential(
        data, wire, n_clients, batch_size, seed):
    cfg = CFG if wire == "f32" else CFG_FX
    r = np.random.default_rng(seed)
    cohort = tuple(sorted(r.choice(128, size=n_clients,
                                   replace=False).tolist()))
    plan = make_bucket_plan(
        {k: np.zeros(sh, np.float32) for k, sh in SHAPES.items()}, cfg)
    n_shards = data.draw(st.integers(1, plan.n_buckets))
    contract = negotiate_contract(0, cohort, plan, cfg)
    clients = {c: ElasticClient(c, cfg) for c in cohort}
    seq = FoldEngine(contract, cfg)
    svc = ShardedFoldService(contract, cfg, n_shards=n_shards,
                             batch_size=batch_size, plan=plan)
    st_seq, st_sh = seq.init_state(), svc.init_state()
    if wire == "fxp32":
        for i, c in enumerate(cohort):
            p = clients[c].propose(contract, _tree(seed + i))
            seq.propose_exponents(st_seq, c, p.exponents)
            svc.propose_exponents(st_sh, c, p.exponents)
        sealed = seq.seal_exponents(st_seq)
        svc.seal_exponents(st_sh)
        payloads = {c: clients[c].payload(contract, sealed)
                    for c in cohort}
    else:
        payloads = {c: clients[c].contribute(contract, _tree(seed + i))
                    for i, c in enumerate(cohort)}
    delays = {c: float(r.choice([0.0, 0.07, 0.16])) for c in cohort}
    pol_a = SwitchRetransmitPolicy(timeout_s=0.05, max_retries=64)
    pol_b = SwitchRetransmitPolicy(timeout_s=0.05, max_retries=64)
    # sequential reference in canonical (client-sorted) order; the
    # sharded service in a drawn arrival permutation
    perm = list(r.permutation(list(cohort)))
    for c in sorted(cohort):
        seq.fold(st_seq, payloads[c], arrival_s=delays[c], policy=pol_a)
    for c in perm:
        svc.fold(st_sh, payloads[c], arrival_s=delays[c], policy=pol_b)
    assert np.array_equal(seq.finalize(st_seq), svc.finalize(st_sh))
    assert st_seq.rx_bytes == st_sh.rx_bytes
    assert st_seq.retransmits == st_sh.retransmits
