"""Smoke tests for the seed-era benchmark entry points (PR 6 satellite):
``benchmarks/accuracy.py``, ``benchmarks/end_to_end.py`` and
``benchmarks/roofline.py`` must stay importable and runnable at tiny
sizes — they are exercised by hand and from CI artifacts, so a refactor
that breaks their imports or call signatures should fail fast here, not
in a nightly run. PR 8 extends the net to the remaining entry points:
``benchmarks/run.py`` (the one-shot all-tables driver) and
``benchmarks/hlo_analysis.py`` (the trip-count-corrected HLO analyzer).

The heavyweight benchmark (``aggregation.py``) has its own CI smoke run
(all ``--compare-*`` arms); here we only pin its import + pure helpers.
"""
import importlib
import json
import os
import sys

import numpy as np
import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")
if REPO not in sys.path:
    sys.path.insert(0, REPO)


@pytest.mark.slow
def test_accuracy_sweep_single_size():
    acc = importlib.import_module("benchmarks.accuracy")
    # one lossless-regime size instead of the paper's 10-point sweep
    rows = acc.sweep("LSTM", sizes=[1.5])
    assert len(rows) == 1
    r = rows[0]
    assert {"model", "size_frac", "avg_rel_error", "recovery_rate",
            "rounds", "threshold"} <= set(r)
    # 1.5x sketch is above the peeling threshold for every Table-1
    # density: recovery must be total, with only float32-accumulation
    # residue in the values (the benchmark's recovery convention)
    assert r["size_frac"] > r["threshold"]
    assert r["recovery_rate"] == 1.0
    assert r["avg_rel_error"] < 1e-6


@pytest.mark.slow
def test_accuracy_topk_comparison():
    acc = importlib.import_module("benchmarks.accuracy")
    rows = acc.topk_comparison(model="VGG19")
    assert {r["size_frac"] for r in rows} == {0.10, 1.0}
    for r in rows:
        assert {"wire_bytes", "lossless", "ours_l2_rel",
                "topk_l2_rel"} <= set(r)
    lossless = next(r for r in rows if r["size_frac"] == 1.0)
    assert lossless["lossless"] and lossless["ours_l2_rel"] < 1e-5


def test_end_to_end_model_is_pure_python():
    e2e = importlib.import_module("benchmarks.end_to_end")
    row = e2e.model_iteration("VGG19", link_gbps=10.0, size_frac=0.10)
    assert row["modeled_speedup"] > 0
    assert np.isfinite(row["t_dense_ms"]) and np.isfinite(row["t_ours_ms"])
    # shipping the full-size sketch can't beat dense on the wire model
    full = e2e.model_iteration("VGG19", link_gbps=10.0, size_frac=1.0)
    assert full["modeled_speedup"] <= row["modeled_speedup"]


def test_end_to_end_main_runs(capsys):
    e2e = importlib.import_module("benchmarks.end_to_end")
    e2e.main()
    out = capsys.readouterr().out
    assert "VGG19" in out and "modeled_speedup" in out


def test_roofline_report_handles_empty_artifacts():
    roof = importlib.import_module("benchmarks.roofline")
    # report()/table() must cope with a mesh that has no dry-run
    # artifacts yet (fresh checkout): empty rows, header-only table
    for mesh in ("single", "multi"):
        assert roof.report(mesh, write=False) == []
        txt = roof.table(mesh)
        assert f"mesh={mesh}" in txt


def test_aggregation_helpers_and_schema4():
    agg = importlib.import_module("benchmarks.aggregation")
    # the jaxpr counters are shared with tests/drivers/wirebytes_driver
    assert callable(agg._count_collectives)
    assert callable(agg._count_collective_launches)
    assert callable(agg._count_link_bytes)
    # schema-4 normalized JSON round-trips the auto + alltoall sections
    auto_rows = [
        {"case": "compare_auto", "arm": "dense", "wall_s": 0.001,
         "link_bytes": 10, "measured_link_bytes": 10,
         "collective_ops": 1},
        {"case": "compare_auto", "arm": "auto", "wall_s": 0.001,
         "plan": "[0:6]=dense", "chosen_wire": "dense",
         "best_fixed": "dense", "best_fixed_wall_s": 0.001,
         "wall_ratio_vs_best_fixed": 1.0,
         "decision_trace": {"probing": False}},
    ]
    a2a_rows = [
        {"case": "compare_a2a", "arm": "dense_alltoall",
         "pattern": "alltoall", "workers": 4, "total_elems": 100,
         "rank_payload_bytes": 300, "link_bytes": 300,
         "measured_link_bytes": 300, "collective_ops": 3,
         "collective_launches": 3, "wall_s": 0.001},
        {"case": "compare_a2a", "arm": "compressed_alltoall",
         "pattern": "alltoall", "workers": 4, "total_elems": 100,
         "rank_payload_bytes": 100, "link_bytes": 100,
         "measured_link_bytes": 100, "collective_ops": 6,
         "collective_launches": 6, "wall_s": 0.001},
    ]
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "norm.json")
        agg.write_normalized(path, [], auto_rows=auto_rows,
                             a2a_rows=a2a_rows)
        with open(path) as f:
            payload = json.load(f)
    assert payload["schema"] == 4
    assert payload["auto"]["chosen_wire"] == "dense"
    assert payload["auto"]["wall_ratio_vs_best_fixed"] == 1.0
    assert payload["auto"]["fixed"]["dense"]["measured_link_bytes"] == 10
    a2a = payload["alltoall"]
    assert set(a2a) == {"dense_alltoall", "compressed_alltoall"}
    assert a2a["compressed_alltoall"]["pattern"] == "alltoall"
    assert (a2a["compressed_alltoall"]["rank_payload_bytes"]
            < a2a["dense_alltoall"]["rank_payload_bytes"])


def test_run_driver_entry_point():
    """``benchmarks/run.py`` is the one-shot all-tables driver CI and
    humans both invoke; it imports the other benchmark modules lazily
    inside main(), so pin the module surface and the timing helper
    (running main() would replay every paper table — too heavy here)."""
    run = importlib.import_module("benchmarks.run")
    assert callable(run.main)
    out, us = run._timed(lambda a, b: a + b, 2, 3)
    assert out == 5 and us >= 0.0


_PIN_HLO = """
HloModule pin

%body (p.1: (s32[], f32[8,16], f32[16,4])) -> (s32[], f32[8,16], f32[16,4]) {
  %p.1 = (s32[], f32[8,16], f32[16,4]) parameter(0)
  %it = s32[] get-tuple-element(%p.1), index=0
  %one = s32[] constant(1)
  %next = s32[] add(%it, %one)
  %a = f32[8,16] get-tuple-element(%p.1), index=1
  %b = f32[16,4] get-tuple-element(%p.1), index=2
  %d = f32[8,4] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %cp = f32[8,4] collective-permute(%d), source_target_pairs={{0,1},{1,0}}
  ROOT %t = (s32[], f32[8,16], f32[16,4]) tuple(%next, %a, %b)
}

%cond (p.2: (s32[], f32[8,16], f32[16,4])) -> pred[] {
  %p.2 = (s32[], f32[8,16], f32[16,4]) parameter(0)
  %it2 = s32[] get-tuple-element(%p.2), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%it2, %n), direction=LT
}

ENTRY %main (x: s32[], a0: f32[8,16], b0: f32[16,4]) -> (s32[], f32[8,16], f32[16,4]) {
  %x = s32[] parameter(0)
  %a0 = f32[8,16] parameter(1)
  %b0 = f32[16,4] parameter(2)
  %init = (s32[], f32[8,16], f32[16,4]) tuple(%x, %a0, %b0)
  ROOT %w = (s32[], f32[8,16], f32[16,4]) while(%init), condition=%cond, body=%body
}
"""


def test_hlo_analysis_trip_corrected_pins():
    """``benchmarks/hlo_analysis.py``'s whole point is the trip-count
    correction cost_analysis() lacks: a dot inside a while body must
    count once per trip. Pin it on a hand-written module (a 7-trip loop
    around an 8x16 @ 16x4 dot + one collective-permute), plus the shape
    parser, plus an analyze() smoke over real compiled HLO (whose op
    mix shifts across jax versions — only invariants asserted there)."""
    import jax
    import jax.numpy as jnp
    hlo = importlib.import_module("benchmarks.hlo_analysis")
    summary = hlo.analyze(_PIN_HLO)
    assert summary.dot_flops == 7 * 2 * 8 * 4 * 16
    cp = summary.collectives["collective-permute"]
    assert cp["count"] == 7
    assert summary.collective_wire_bytes() == 7 * 8 * 4 * 4
    elems, nbytes = hlo.shape_elems_bytes("f32[8,16]")
    assert (elems, nbytes) == (8 * 16, 8 * 16 * 4)
    # real lowering: must parse without error and see the dot's work
    x = jnp.ones((8, 16), jnp.float32)
    w = jnp.ones((16, 4), jnp.float32)
    txt = jax.jit(lambda a, b: a @ b).lower(x, w).compile().as_text()
    real = hlo.analyze(txt)
    assert real.dot_flops > 0
    assert real.collectives == {}           # single device: no wire
