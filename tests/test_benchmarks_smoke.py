"""Smoke tests for the seed-era benchmark entry points (PR 6 satellite):
``benchmarks/accuracy.py``, ``benchmarks/end_to_end.py`` and
``benchmarks/roofline.py`` must stay importable and runnable at tiny
sizes — they are exercised by hand and from CI artifacts, so a refactor
that breaks their imports or call signatures should fail fast here, not
in a nightly run.

The heavyweight benchmark (``aggregation.py``) has its own CI smoke run
(all ``--compare-*`` arms); here we only pin its import + pure helpers.
"""
import importlib
import json
import os
import sys

import numpy as np
import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")
if REPO not in sys.path:
    sys.path.insert(0, REPO)


@pytest.mark.slow
def test_accuracy_sweep_single_size():
    acc = importlib.import_module("benchmarks.accuracy")
    # one lossless-regime size instead of the paper's 10-point sweep
    rows = acc.sweep("LSTM", sizes=[1.5])
    assert len(rows) == 1
    r = rows[0]
    assert {"model", "size_frac", "avg_rel_error", "recovery_rate",
            "rounds", "threshold"} <= set(r)
    # 1.5x sketch is above the peeling threshold for every Table-1
    # density: recovery must be total, with only float32-accumulation
    # residue in the values (the benchmark's recovery convention)
    assert r["size_frac"] > r["threshold"]
    assert r["recovery_rate"] == 1.0
    assert r["avg_rel_error"] < 1e-6


@pytest.mark.slow
def test_accuracy_topk_comparison():
    acc = importlib.import_module("benchmarks.accuracy")
    rows = acc.topk_comparison(model="VGG19")
    assert {r["size_frac"] for r in rows} == {0.10, 1.0}
    for r in rows:
        assert {"wire_bytes", "lossless", "ours_l2_rel",
                "topk_l2_rel"} <= set(r)
    lossless = next(r for r in rows if r["size_frac"] == 1.0)
    assert lossless["lossless"] and lossless["ours_l2_rel"] < 1e-5


def test_end_to_end_model_is_pure_python():
    e2e = importlib.import_module("benchmarks.end_to_end")
    row = e2e.model_iteration("VGG19", link_gbps=10.0, size_frac=0.10)
    assert row["modeled_speedup"] > 0
    assert np.isfinite(row["t_dense_ms"]) and np.isfinite(row["t_ours_ms"])
    # shipping the full-size sketch can't beat dense on the wire model
    full = e2e.model_iteration("VGG19", link_gbps=10.0, size_frac=1.0)
    assert full["modeled_speedup"] <= row["modeled_speedup"]


def test_end_to_end_main_runs(capsys):
    e2e = importlib.import_module("benchmarks.end_to_end")
    e2e.main()
    out = capsys.readouterr().out
    assert "VGG19" in out and "modeled_speedup" in out


def test_roofline_report_handles_empty_artifacts():
    roof = importlib.import_module("benchmarks.roofline")
    # report()/table() must cope with a mesh that has no dry-run
    # artifacts yet (fresh checkout): empty rows, header-only table
    for mesh in ("single", "multi"):
        assert roof.report(mesh, write=False) == []
        txt = roof.table(mesh)
        assert f"mesh={mesh}" in txt


def test_aggregation_helpers_and_schema3():
    agg = importlib.import_module("benchmarks.aggregation")
    # the jaxpr counters are shared with tests/drivers/wirebytes_driver
    assert callable(agg._count_collectives)
    assert callable(agg._count_collective_launches)
    assert callable(agg._count_link_bytes)
    # schema-3 normalized JSON round-trips the auto section
    auto_rows = [
        {"case": "compare_auto", "arm": "dense", "wall_s": 0.001,
         "link_bytes": 10, "measured_link_bytes": 10,
         "collective_ops": 1},
        {"case": "compare_auto", "arm": "auto", "wall_s": 0.001,
         "plan": "[0:6]=dense", "chosen_wire": "dense",
         "best_fixed": "dense", "best_fixed_wall_s": 0.001,
         "wall_ratio_vs_best_fixed": 1.0,
         "decision_trace": {"probing": False}},
    ]
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "norm.json")
        agg.write_normalized(path, [], auto_rows=auto_rows)
        with open(path) as f:
            payload = json.load(f)
    assert payload["schema"] == 3
    assert payload["auto"]["chosen_wire"] == "dense"
    assert payload["auto"]["wall_ratio_vs_best_fixed"] == 1.0
    assert payload["auto"]["fixed"]["dense"]["measured_link_bytes"] == 10
