"""Unit coverage for the in-network aggregation tier (PR 4):
``repro.net.fixedpoint`` (the overflow-free shared-exponent int32 wire),
``repro.net.switch`` (bounded-SRAM streaming SwitchModel + straggler
retransmit), ``repro.net.topology`` (tree construction, validation, wire
model), and the ``compressed_innet`` aggregator's single-rank semantics.

The multi-worker semantics — tree_all_reduce == psum/OR on real fake
devices, innet == CompressedAggregator over 3 EF steps, fxp32 == the
documented codec roundtrip — live in
``tests/drivers/collectives_driver.py``.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core import CompressionConfig, HomomorphicCompressor, CompressedLeaf
from repro.core.aggregators import make_aggregator
from repro.core.bucketing import make_bucket_plan
from repro.core.collectives import AggregationState, init_aggregation_state
from repro.ft.failures import SwitchRetransmitPolicy, SwitchStragglerTimeout
from repro.net import (FixedPointWire, SwitchModel, Topology, ceil_log2,
                       make_topology, pow2, tree_all_reduce)


# ----------------------------------------------------------------------
# fixedpoint: geometry, overflow bound, roundtrip
# ----------------------------------------------------------------------

def test_ceil_log2():
    assert [ceil_log2(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [0, 1, 2, 2, 3, 3, 4]
    with pytest.raises(ValueError):
        ceil_log2(0)


def test_pow2_exact_across_range():
    ks = np.arange(-126, 128, dtype=np.int32)
    got = np.asarray(pow2(jnp.asarray(ks)))
    np.testing.assert_array_equal(got, np.exp2(ks.astype(np.float64))
                                  .astype(np.float32))


@pytest.mark.parametrize("workers,mantissa", [(1, 30), (2, 29), (3, 28),
                                              (4, 28), (8, 27), (100, 23)])
def test_mantissa_headroom_split(workers, mantissa):
    w = FixedPointWire(workers=workers)
    assert w.mantissa_bits == mantissa
    assert w.headroom_bits + w.mantissa_bits == 30


def test_wire_validation():
    with pytest.raises(ValueError, match="workers"):
        FixedPointWire(workers=0)
    with pytest.raises(ValueError, match="mantissa"):
        FixedPointWire(workers=1 << 29)


def test_with_workers_reprices_across_pow2_boundary():
    """The elastic renegotiation seam: the mantissa budget is
    W-dependent, so crossing a power-of-two cohort boundary changes the
    wire — same-side resizes keep it."""
    w4 = FixedPointWire(workers=4)
    assert w4.mantissa_bits == 28
    assert w4.with_workers(3).mantissa_bits == 28     # same pow2 bracket
    assert w4.with_workers(5).mantissa_bits == 27     # W=4 -> 5 reprices
    assert w4.with_workers(9).mantissa_bits == 26
    assert w4.with_workers(4) == w4
    with pytest.raises(ValueError, match="workers"):
        w4.with_workers(0)


def test_mixed_mantissa_budgets_decode_misscaled():
    """Why the elastic contract must reject stale payloads outright:
    ints encoded under the W=4 budget (M=28) and decoded under the W=5
    budget (M=27) come back exactly 2x too large — plausible-looking,
    silently wrong. The elastic tier turns this hazard into
    StaleContractError."""
    w4 = FixedPointWire(workers=4)
    w5 = w4.with_workers(5)
    r = np.random.default_rng(0)
    buckets = jnp.asarray(r.normal(0, 3, (2, 256)).astype(np.float32))
    e = w4.bucket_exponents(buckets)
    q = w4.encode(buckets, e)
    d4 = np.asarray(w4.decode(q, e))
    d5 = np.asarray(w5.decode(q, e))
    scale = 2.0 ** (w4.mantissa_bits - w5.mantissa_bits)
    np.testing.assert_array_equal(d5, d4 * scale)
    assert not np.array_equal(d5, d4)


@pytest.mark.parametrize("workers", [1, 2, 7, 16])
def test_encode_bound_and_sum_never_overflows(workers):
    """|q| <= 2^M per worker, so the W-way sum provably fits int32 —
    checked against an int64 reference on adversarial inputs (huge,
    tiny, mixed-sign, all-equal-to-max)."""
    w = FixedPointWire(workers=workers)
    r = np.random.default_rng(workers)
    cases = [
        r.standard_normal((5, 64)).astype(np.float32) * 1e30,
        r.standard_normal((5, 64)).astype(np.float32) * 1e-30,
        np.full((2, 64), 3.14e7, np.float32),
        np.zeros((2, 64), np.float32),
    ]
    for x in cases:
        e = w.bucket_exponents(jnp.asarray(x))
        qs = [np.asarray(w.encode(jnp.asarray(x), e))
              for _ in range(workers)]
        for q in qs:
            assert np.abs(q).max(initial=0) <= 2 ** w.mantissa_bits
        total64 = np.sum([q.astype(np.int64) for q in qs], axis=0)
        assert total64.max(initial=0) <= 2**31 - 1
        assert total64.min(initial=0) >= -(2**31)
        total32 = np.sum(qs, axis=0, dtype=np.int32)
        np.testing.assert_array_equal(total32, total64.astype(np.int32))


def test_roundtrip_exact_on_dyadic():
    """Dyadic values well inside the mantissa budget round-trip
    bit-exactly — the property the EF parity tests lean on."""
    r = np.random.default_rng(0)
    x = (r.choice([-1.0, 1.0], size=(4, 128))
         * np.exp2(r.integers(-2, 3, size=(4, 128)))).astype(np.float32)
    x[:, ::3] = 0.0
    w = FixedPointWire(workers=4)
    got = np.asarray(w.roundtrip_reference([jnp.asarray(x)] * 4))
    np.testing.assert_array_equal(got, 4.0 * x)


def test_roundtrip_error_within_half_ulp_of_scale():
    r = np.random.default_rng(1)
    x = r.standard_normal((3, 256)).astype(np.float32)
    w = FixedPointWire(workers=2)
    e = w.bucket_exponents(jnp.asarray(x))
    dec = np.asarray(w.decode(w.encode(jnp.asarray(x), e), e))
    # one quantization step is 2^(e-M); rint is within half a step
    step = np.exp2(np.asarray(e, np.float64) - w.mantissa_bits)
    assert (np.abs(dec - x) <= 0.5 * step[:, None] + 1e-12).all()


def test_tiny_buckets_clamp_not_inf():
    # 1e-35 is a *normal* float32 whose frexp exponent (-116) sits below
    # the clamp floor; without the clamp the encode scale 2^(M - e)
    # would overflow to inf. (True subnormals come back from jnp.frexp
    # with exponent 0 — harmless, they just quantize to 0.)
    w = FixedPointWire(workers=2)
    x = jnp.full((1, 8), 1e-35, jnp.float32)
    e = w.bucket_exponents(x)
    assert int(e[0]) == w.min_exponent
    q = w.encode(x, e)
    assert np.isfinite(np.asarray(w.decode(q, e))).all()
    assert np.abs(np.asarray(q)).max() <= 2 ** w.mantissa_bits
    sub = jnp.full((1, 8), 1e-40, jnp.float32)
    es = w.bucket_exponents(sub)
    assert (np.asarray(w.encode(sub, es)) == 0).all()


def test_all_zero_slice_does_not_inflate_shared_exponent():
    """With top-k sparsification a worker's slice of a bucket is often
    all zeros; it must report the exponent *floor*, not frexp's 0 —
    otherwise the pmax-shared exponent (and so the quantization step)
    jumps to 1.0-scale for every sub-1.0 bucket the moment any worker
    goes quiet there."""
    w = FixedPointWire(workers=2)
    small = jnp.full((1, 64), 2.0**-11, jnp.float32)   # true exponent -10
    zeros = jnp.zeros((1, 64), jnp.float32)
    e_small = w.bucket_exponents(small)
    e_zero = w.bucket_exponents(zeros)
    assert int(e_zero[0]) == w.min_exponent
    shared = jnp.maximum(e_small, e_zero)
    assert int(shared[0]) == int(e_small[0]) == -10
    # the roundtrip at the shared exponent is exact for this power of two
    got = np.asarray(w.roundtrip_reference([small, zeros]))
    np.testing.assert_array_equal(got, np.asarray(small))


def test_roundtrip_reference_rejects_oversubscription():
    w = FixedPointWire(workers=2)
    x = jnp.ones((1, 8), jnp.float32)
    with pytest.raises(ValueError, match="overflow"):
        w.roundtrip_reference([x, x, x])


# ----------------------------------------------------------------------
# switch: streaming windows, counters, integer-only semantics
# ----------------------------------------------------------------------

def _chunks(ports=3, n_chunks=7, k=16, seed=0):
    r = np.random.default_rng(seed)
    sk = r.integers(-2**20, 2**20, size=(ports, n_chunks, k),
                    dtype=np.int32)
    bm = r.integers(0, 2**32, size=(ports, n_chunks, k // 2),
                    dtype=np.uint32)
    return sk, bm


def test_switch_aggregate_matches_numpy():
    sk, bm = _chunks()
    sw = SwitchModel(ports=3, slots=2)
    osk, obm = sw.aggregate(sk, bm)
    np.testing.assert_array_equal(osk, sk.sum(0, dtype=np.int32))
    np.testing.assert_array_equal(obm, np.bitwise_or.reduce(bm, 0))


def test_switch_streaming_windows_and_counters():
    sk, bm = _chunks(ports=3, n_chunks=7)
    sw = SwitchModel(ports=3, slots=2)
    sw.aggregate(sk, bm)
    rep = sw.report()
    assert rep["windows"] == 4                  # ceil(7 / 2)
    assert rep["occupancy_peak"] == 2           # never above the pool
    stream_bytes = sk[0].nbytes + bm[0].nbytes
    for pc in rep["per_port"]:
        assert pc["rx_bytes"] == stream_bytes   # each child sends once
        assert pc["tx_bytes"] == stream_bytes   # broadcast back down
        assert pc["retransmits"] == 0
    # aggregated stream crosses the root link once per direction
    assert rep["root_link_tx_bytes"] == stream_bytes
    assert rep["root_link_rx_bytes"] == stream_bytes


def test_switch_metadata_bytes_reconcile_with_wire_model():
    """The fxp32 shared-exponent vector rides the same links: with
    ``metadata_bytes`` the switch's root-link counters must equal the
    stream payload + metadata — the exact number
    ``strategy_wire_bytes["compressed_innet"]["root_link_bytes"]``
    models."""
    sk, bm = _chunks(ports=2, n_chunks=4)
    sw = SwitchModel(ports=2, slots=2)
    sw.aggregate(sk, bm, metadata_bytes=16)
    rep = sw.report()
    stream_bytes = sk[0].nbytes + bm[0].nbytes
    assert rep["root_link_tx_bytes"] == stream_bytes + 16
    assert rep["root_link_rx_bytes"] == stream_bytes + 16
    for pc in rep["per_port"]:
        assert pc["rx_bytes"] == stream_bytes + 16
        assert pc["tx_bytes"] == stream_bytes + 16
    with pytest.raises(ValueError, match="metadata_bytes"):
        sw.aggregate(sk, bm, metadata_bytes=-1)


def test_switch_reset_clears_policy_events():
    sk, bm = _chunks(ports=2, n_chunks=2)
    pol = SwitchRetransmitPolicy(timeout_s=0.1, max_retries=3)
    sw = SwitchModel(ports=2, slots=4, policy=pol)
    sw.aggregate(sk, bm, arrival_s=np.array([[0.0, 0.0], [0.25, 0.25]]))
    assert sw.report()["retransmit_events"]
    sw.reset()
    assert sw.report()["retransmit_events"] == []
    assert pol.events == []


def test_switch_slot_pool_bounds_occupancy():
    sk, bm = _chunks(ports=2, n_chunks=5)
    big = SwitchModel(ports=2, slots=100)
    big.aggregate(sk, bm)
    assert big.report()["occupancy_peak"] == 5  # whole stream resident
    assert big.report()["windows"] == 1


def test_switch_rejects_floats_and_bad_shapes():
    sk, bm = _chunks(ports=2)
    sw = SwitchModel(ports=2, slots=2)
    with pytest.raises(TypeError, match="int32"):
        sw.aggregate(sk.astype(np.float32), bm)
    with pytest.raises(TypeError, match="uint32"):
        sw.aggregate(sk, bm.astype(np.int32))
    with pytest.raises(ValueError, match="ports"):
        sw.aggregate(sk[:1], bm[:1])
    with pytest.raises(ValueError, match="chunks"):
        sw.aggregate(sk, bm[:, :1])
    with pytest.raises(ValueError, match="slots"):
        SwitchModel(ports=2, slots=0)


def test_switch_register_overflow_raises():
    sk = np.full((2, 1, 4), 2**30, np.int32)    # 2 * 2^30 > int32
    bm = np.zeros((2, 1, 2), np.uint32)
    with pytest.raises(OverflowError, match="32-bit"):
        SwitchModel(ports=2, slots=1).aggregate(sk, bm)


def test_switch_intermediate_overflow_raises():
    """A port-by-port accumulator overflows on the *running* sum even
    when the final sum is back in range — the register is 32-bit at
    every step, not just at the end."""
    sk = np.array([2**30, 2**30, -(2**30)], np.int32).reshape(3, 1, 1)
    bm = np.zeros((3, 1, 1), np.uint32)
    with pytest.raises(OverflowError, match="running"):
        SwitchModel(ports=3, slots=1).aggregate(sk, bm)


# ----------------------------------------------------------------------
# switch straggler timeout / retransmit (the ft hook)
# ----------------------------------------------------------------------

def test_switch_straggler_retransmit_accounting():
    sk, bm = _chunks(ports=2, n_chunks=4)
    pol = SwitchRetransmitPolicy(timeout_s=0.1, max_retries=3)
    sw = SwitchModel(ports=2, slots=2, policy=pol)
    # port 1 arrives 0.25s late on every chunk: 2 elapsed timeout
    # periods -> 2 retransmits per window
    arrivals = np.array([[0.01] * 4, [0.25] * 4])
    osk, obm = sw.aggregate(sk, bm, arrival_s=arrivals)
    np.testing.assert_array_equal(osk, sk.sum(0, dtype=np.int32))
    rep = sw.report()
    stream_bytes = sk[0].nbytes + bm[0].nbytes
    assert rep["per_port"][0]["retransmits"] == 0
    assert rep["per_port"][1]["retransmits"] == 4      # 2 per window x 2
    assert rep["per_port"][1]["rx_bytes"] == 3 * stream_bytes
    assert len(rep["retransmit_events"]) == 2          # one per window
    assert all(ev["port"] == 1 for ev in rep["retransmit_events"])


def test_switch_straggler_past_budget_raises():
    sk, bm = _chunks(ports=2, n_chunks=2)
    pol = SwitchRetransmitPolicy(timeout_s=0.1, max_retries=1)
    sw = SwitchModel(ports=2, slots=4, policy=pol)
    arrivals = np.array([[0.0, 0.0], [0.45, 0.45]])    # 4 periods late
    with pytest.raises(SwitchStragglerTimeout, match="port 1"):
        sw.aggregate(sk, bm, arrival_s=arrivals)


def test_retransmit_policy_validation():
    with pytest.raises(ValueError, match="timeout_s"):
        SwitchRetransmitPolicy(timeout_s=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        SwitchRetransmitPolicy(max_retries=-1)
    pol = SwitchRetransmitPolicy(timeout_s=0.1, max_retries=5)
    assert pol.retries_for(0.05) == 0
    assert pol.retries_for(0.1) == 0
    assert pol.retries_for(0.11) == 1
    assert pol.retries_for(0.35) == 3


# ----------------------------------------------------------------------
# topology: construction, validation, wire model
# ----------------------------------------------------------------------

def test_make_topology_flat_and_tor_spine():
    mesh = make_mesh((1,), ("data",))
    flat = make_topology("flat", mesh, ("data",))
    assert flat.levels == ("data",) and flat.fanouts == (1,)
    with pytest.raises(ValueError, match="tor_spine"):
        make_topology("tor_spine", mesh, ("data",))
    with pytest.raises(ValueError, match="unknown topology"):
        make_topology("clos", mesh, ("data",))
    with pytest.raises(ValueError, match="no axes"):
        make_topology("flat", mesh, ("pod",))
    with pytest.raises(ValueError, match="at least one"):
        make_topology("flat", mesh, ())


def test_topology_tree_accounting():
    # a 3x4 pod/data world: ToRs group the ICI-near inner axis
    topo = Topology(kind="tor_spine", levels=("data", "pod"), sizes=(4, 3))
    assert topo.workers == 12
    assert topo.fanouts == (4, 3)
    assert topo.switches_per_level() == (3, 1)
    prof = topo.link_profile(1000)
    assert prof["worker_link_bytes"] == 1000
    assert prof["root_link_bytes"] == 1000      # aggregated stream, once
    assert prof["switch_ingress_bytes"] == (4000, 3000)
    flat = Topology(kind="flat", levels=("data", "pod"), sizes=(4, 3))
    assert flat.fanouts == (12,)
    assert flat.switches_per_level() == (1,)
    assert flat.link_profile(1000)["switch_ingress_bytes"] == (12000,)


def test_topology_single_worker_no_wire():
    topo = Topology(kind="flat", levels=("data",), sizes=(1,))
    prof = topo.link_profile(1000)
    assert prof["worker_link_bytes"] == 0
    assert prof["root_link_bytes"] == 0


@pytest.mark.parametrize("n_chunks,slots", [(7, 2), (4, 4), (5, 8), (1, 1)])
def test_window_profile_pins_switch_slot_accounting(n_chunks, slots):
    """The streamed in-mesh tree's static per-window accounting
    (Topology.window_profile, PR 5) must agree window for window with
    what the SwitchModel actually streams through its slot pool —
    windows, peak occupancy, per-window root bytes, and the root-link
    total."""
    topo = Topology(kind="flat", levels=("data",), sizes=(3,))
    sk, bm = _chunks(ports=3, n_chunks=n_chunks)
    chunk_bytes = sk[0, 0].nbytes + bm[0, 0].nbytes
    sw = SwitchModel(ports=3, slots=slots)
    sw.aggregate(sk, bm)
    rep = sw.report()
    prof = topo.window_profile(chunk_bytes, n_chunks, slots)
    assert prof["windows"] == rep["windows"]
    assert prof["occupancy_peak"] == rep["occupancy_peak"]
    assert prof["window_chunks"] == rep["window_chunks"]
    assert prof["window_root_bytes"] == rep["window_root_bytes"]
    assert prof["root_link_bytes"] == rep["root_link_tx_bytes"]
    with pytest.raises(ValueError, match="slots"):
        topo.window_profile(chunk_bytes, n_chunks, 0)


def test_tree_all_reduce_identity_on_one_rank():
    mesh = make_mesh((1,), ("data",))
    topo = make_topology("flat", mesh, ("data",))
    ints = jnp.asarray(np.arange(-8, 8, dtype=np.int32))
    words = jnp.asarray(np.arange(16, dtype=np.uint32))

    def f(a, w):
        return (tree_all_reduce(a, topo, "add"),
                tree_all_reduce(w, topo, "or"))

    gi, gw = jax.jit(shard_map(f, mesh=mesh, in_specs=(P(), P()),
                               out_specs=(P(), P()), axis_names={"data"},
                               check_vma=False))(ints, words)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ints))
    np.testing.assert_array_equal(np.asarray(gw), np.asarray(words))


def test_tree_all_reduce_rejects_floats_and_bad_combine():
    mesh = make_mesh((1,), ("data",))
    topo = make_topology("flat", mesh, ("data",))
    with pytest.raises(TypeError, match="integer adds only"):
        tree_all_reduce(jnp.zeros((4,), jnp.float32), topo, "add")
    with pytest.raises(TypeError, match="unsigned"):
        tree_all_reduce(jnp.zeros((4,), jnp.int32), topo, "or")
    with pytest.raises(ValueError, match="combine"):
        tree_all_reduce(jnp.zeros((4,), jnp.int32), topo, "xor")
    with pytest.raises(ValueError, match="axis_indices is missing"):
        tree_all_reduce(jnp.zeros((4,), jnp.int32), topo, "add",
                        axis_indices={})


# ----------------------------------------------------------------------
# compressed_innet aggregator (single-rank semantics; multi-rank parity
# is in the collectives driver)
# ----------------------------------------------------------------------

_CFG = CompressionConfig(ratio=1.0, lanes=128, rows=6, rounds=10,
                         chunk_blocks=8, bucket_bytes=768 * 4)


def _sparse_tree(seed=0):
    r = np.random.default_rng(seed)
    out = {}
    for k, n in (("a", 2000), ("b", 300)):
        g = np.zeros(n, np.float32)
        idx = r.choice(n, size=n // 20, replace=False)
        g[idx] = r.standard_normal(idx.size).astype(np.float32)
        out[k] = g
    return out


def _run_innet(cfg, grads):
    mesh = make_mesh((1,), ("data",))
    specs = {k: P() for k in grads}
    agg = make_aggregator("compressed_innet", cfg, mesh, ("data",), (),
                          outer_manual=("data",))

    def fn(g):
        st = init_aggregation_state(g, cfg)
        out, _ = agg(g, st, specs)
        return out

    out = jax.jit(shard_map(fn, mesh=mesh, in_specs=(specs,),
                            out_specs=specs, axis_names={"data"},
                            check_vma=False))(
        jax.tree.map(jnp.asarray, grads))
    return jax.tree.map(np.asarray, out)


def test_innet_f32_wire_is_lossless_single_rank():
    grads = _sparse_tree()
    out = _run_innet(_CFG, grads)
    for k in grads:
        np.testing.assert_allclose(out[k], grads[k], atol=1e-6)


def test_innet_fxp32_matches_codec_roundtrip_single_rank():
    """Even at W=1 the fxp32 wire quantizes — the output must equal the
    documented host-side roundtrip exactly, not the float input."""
    cfg = dataclasses.replace(_CFG, wire_dtype="fxp32")
    grads = _sparse_tree()
    out = _run_innet(cfg, grads)

    comp = HomomorphicCompressor(cfg)
    plan = make_bucket_plan(grads, cfg)
    wire = FixedPointWire(workers=1)
    c = comp.compress(plan.pack(jax.tree.map(jnp.asarray, grads)
                                ).reshape(-1))
    dec = wire.roundtrip_reference(
        [np.asarray(c.sketch).reshape(plan.n_buckets, -1)])
    rec = comp.recover(
        CompressedLeaf(sketch=jnp.asarray(dec).reshape(c.sketch.shape),
                       index_words=c.index_words), plan.padded)
    ref = jax.tree.map(np.asarray, plan.unpack(
        jnp.asarray(rec).reshape(plan.n_buckets, plan.bucket_elems)))
    for k in grads:
        np.testing.assert_array_equal(out[k], ref[k])


def test_innet_tor_spine_raises_on_single_axis_mesh():
    cfg = dataclasses.replace(_CFG, topology="tor_spine")
    with pytest.raises(ValueError, match="tor_spine"):
        _run_innet(cfg, _sparse_tree())


def test_innet_config_validation():
    with pytest.raises(ValueError, match="wire_dtype"):
        CompressionConfig(wire_dtype="int8")
    with pytest.raises(ValueError, match="switch_slots"):
        CompressionConfig(switch_slots=0)
    with pytest.raises(ValueError, match="topology"):
        CompressionConfig(topology="butterfly")
    cfg = CompressionConfig(wire_dtype="fxp32", switch_slots=4,
                            topology="tor_spine")
    assert cfg.wire_dtype == "fxp32"


def test_train_config_accepts_innet():
    from repro.train.config import TrainConfig
    assert TrainConfig(aggregator="compressed_innet").aggregator == \
        "compressed_innet"
    with pytest.raises(ValueError, match="compressed_innet"):
        TrainConfig(aggregator="nope")
