import numpy as np
import jax.numpy as jnp

from repro.core import hashing
from repro.core.config import CompressionConfig


def test_mix32_deterministic_and_spread():
    x = np.arange(10000, dtype=np.uint32)
    h1, h2 = hashing.mix32_np(x), hashing.mix32_np(x)
    assert np.array_equal(h1, h2)
    # decent spread: all byte values hit
    assert len(np.unique(h1 & 0xFF)) == 256


def test_mix32_jnp_matches_np():
    x = np.arange(4096, dtype=np.uint32)
    a = hashing.mix32_np(x)
    b = np.asarray(hashing.mix32(jnp.asarray(x)))
    assert np.array_equal(a, b)


def test_batch_rows_partitioned():
    rows = hashing.batch_rows(group=60, rows=6, seed=1)
    assert rows.shape == (60, 3)
    for j in range(3):
        assert rows[:, j].min() >= j * 2
        assert rows[:, j].max() < (j + 1) * 2


def test_batch_signs_pm1():
    s = hashing.batch_signs(group=128, seed=3)
    assert set(np.unique(s)) <= {-1.0, 1.0}
    # roughly balanced
    assert 0.3 < (s > 0).mean() < 0.7


def test_block_rotations_range_and_block_dependence():
    ids = jnp.arange(8, dtype=jnp.int32)
    rot = np.asarray(hashing.block_rotations(ids, 16, 512, seed=0))
    assert rot.shape == (8, 16, 3)
    assert rot.min() >= 0 and rot.max() < 512
    assert not np.array_equal(rot[0], rot[1])  # per-block variation


def test_bloom_positions_in_range():
    ids = jnp.arange(1000, dtype=jnp.uint32)
    pos = np.asarray(hashing.bloom_positions(ids, 3, 4096, seed=0))
    assert pos.shape == (1000, 3)
    assert pos.min() >= 0 and pos.max() < 4096
