"""Multi-device semantics via subprocess drivers (8 fake CPU devices).
Slow-ish (~2 min total); these validate the actual distributed pipeline:
OR-allreduce, nested-shard_map compression, ZeRO-1 vs replicated, and
compressed-vs-dense training equivalence in the lossless regime."""
import os
import subprocess
import sys

import pytest

DRIVERS = os.path.join(os.path.dirname(__file__), "drivers")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(name):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        "--xla_disable_hlo_passes=all-reduce-promotion")
    r = subprocess.run([sys.executable, os.path.join(DRIVERS, name)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"{name} failed:\n{r.stdout}\n{r.stderr}"
    assert "ALL OK" in r.stdout


@pytest.mark.slow
def test_collectives_driver():
    _run("collectives_driver.py")


@pytest.mark.slow
def test_train_step_driver():
    _run("train_step_driver.py")


@pytest.mark.slow
def test_wirebytes_driver():
    """PR 6 satellite: analytic strategy_wire_bytes vs the bytes the
    launched collectives move (jaxpr-counted), W=2 and W=4."""
    _run("wirebytes_driver.py")


@pytest.mark.slow
def test_elastic_driver():
    """PR 9 satellite: elastic_mesh / Membership.local_mesh sizing on 8
    real fake-CPU devices (non-divisible survivor counts), plus a live
    psum on a degraded mesh."""
    _run("elastic_driver.py")
