import os
import numpy as np
import jax.numpy as jnp
import pytest

from repro.ckpt import checkpoint as ck


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.normal(size=(4, 8)).astype(np.float32)),
            "b": {"c": jnp.asarray(r.normal(size=(3,)).astype(np.float32)),
                  "d": jnp.asarray(r.integers(0, 5, (2, 2)), jnp.int32)},
            "bf": jnp.asarray(r.normal(size=(5,)), jnp.bfloat16)}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 7, t, metadata={"note": "x"})
    assert ck.latest_step(str(tmp_path)) == 7
    back = ck.restore(str(tmp_path), template=t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


import jax  # noqa: E402


def test_corruption_detected(tmp_path):
    t = _tree()
    path = ck.save(str(tmp_path), 1, t)
    # flip a byte in the first leaf
    victim = os.path.join(path, "leaf_00000.bin")
    raw = bytearray(open(victim, "rb").read())
    raw[0] ^= 0xFF
    open(victim, "wb").write(bytes(raw))
    with pytest.raises(IOError, match="checksum"):
        ck.restore(str(tmp_path), template=t)


def test_gc_keeps_last(tmp_path):
    t = _tree()
    for s in range(6):
        ck.save(str(tmp_path), s, t, keep_last=3)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path))
    assert steps == [3, 4, 5]


def test_async_checkpointer(tmp_path):
    t = _tree()
    saver = ck.AsyncCheckpointer()
    saver.save(str(tmp_path), 11, t)
    saver.wait()
    assert ck.latest_step(str(tmp_path)) == 11


def test_restore_with_resharding(tmp_path):
    """Bytes on disk are mesh-agnostic: restore onto explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import make_mesh
    mesh = make_mesh((1,), ("data",))
    t = _tree()
    ck.save(str(tmp_path), 2, t)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    back = ck.restore(str(tmp_path), template=t, shardings=sh)
    assert np.array_equal(np.asarray(back["a"]), np.asarray(t["a"]))
