"""The codec roofline (PR 7): the jaxpr stream-pass counter must report
exactly one producer and one consumer pass for the fused wire kernels
and strictly more for the composed refs — the same gate CI enforces —
and the report must feed the cost model's auto priors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel
from repro.core.config import CompressionConfig

import benchmarks.roofline as roof


@pytest.mark.parametrize("wire_dtype", ["f32", "fxp32"])
def test_codec_report_pass_counts(wire_dtype):
    rep = roof.codec_report(n_buckets=2, iters=1, wire_dtype=wire_dtype)
    fused, composed = rep["passes"]["fused"], rep["passes"]["composed"]
    assert fused == {"producer": 1, "consumer": 1}
    assert composed["producer"] > fused["producer"]
    assert composed["consumer"] > fused["consumer"]
    # the quantize/dequant stages cost the composed legs an extra pass
    if wire_dtype == "fxp32":
        f32 = roof.codec_report(n_buckets=2, iters=1, wire_dtype="f32")
        assert composed["producer"] >= f32["passes"]["composed"]["producer"]
    assert rep["modeled_codec_s_per_bucket"]["fused"] < \
        rep["modeled_codec_s_per_bucket"]["composed"]
    # composed leg is wall-timed off-TPU; bandwidth fraction is positive
    assert rep["achieved_codec_bytes_per_s"] is None or \
        rep["achieved_codec_bytes_per_s"] > 0


def test_codec_report_feeds_costmodel_priors():
    rep = roof.codec_report(n_buckets=2, iters=1)
    pri = costmodel.priors_from_codec_report(rep)
    assert set(pri) == {"auto_codec_gbps", "auto_link_gbps"}
    assert pri["auto_link_gbps"] == pytest.approx(
        costmodel.ICI_BW * 8 / 1e9)
    assert pri["auto_codec_gbps"] > 0
    assert rep["auto_priors"] == pri
    assert roof.codec_table(rep)  # renders without error


def test_count_stream_passes_skips_layout_and_recurses_wrappers():
    n = 4096

    def body(x):
        y = (x * 2.0).reshape(n // 2, 2)       # 1 pass + layout reshape
        return jax.jit(lambda r: r + 1.0)(y)   # 1 pass inside pjit wrapper

    jaxpr = jax.make_jaxpr(body)(jnp.zeros(n, jnp.float32))
    got = roof.count_stream_passes(jaxpr.jaxpr, n)
    assert got == 2

    def layout_only(x):
        return x.reshape(n // 2, 2).astype(jnp.float32)

    jaxpr2 = jax.make_jaxpr(layout_only)(jnp.zeros(n, jnp.float32))
    assert roof.count_stream_passes(jaxpr2.jaxpr, n) == 0
