import time
import numpy as np
import pytest

from repro.data.pipeline import batch_fn, Prefetcher
from repro.ft.failures import (FailureSimulator, InjectedFailure,
                               StragglerMonitor, elastic_data_parallel,
                               elastic_mesh)
from repro.models import ModelConfig

CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=1, d_ff=64, vocab=128,
                  dtype="float32")


def test_batches_deterministic():
    f = batch_fn(CFG, 4, 16, seed=3)
    b1, b2 = f(5), f(5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(f(5)["tokens"], f(6)["tokens"])
    # labels are next-token shifted
    assert b1["tokens"].shape == b1["labels"].shape == (4, 16)


def test_prefetcher_orders_steps():
    f = batch_fn(CFG, 2, 8, seed=0)
    pf = Prefetcher(f, depth=2, start_step=0)
    got = [next(pf)[0] for _ in range(5)]
    pf.close()
    assert got == [0, 1, 2, 3, 4]


def test_failure_simulator_fires_once():
    sim = FailureSimulator(fail_at_steps=(3,))
    sim.check(2)
    with pytest.raises(InjectedFailure):
        sim.check(3)
    sim.check(3)   # already fired -> replay passes


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(warmup=2)
    for s in range(5):
        assert not mon.observe(s, 0.1)
    assert mon.observe(5, 1.0)
    assert len(mon.events) == 1
    # EMA not polluted by the outlier
    assert not mon.observe(6, 0.11)


def test_elastic_mesh_single_device():
    m = elastic_mesh(available_devices=1, model_parallel=1)
    assert m.shape == {"data": 1, "model": 1}
    with pytest.raises(ValueError):
        elastic_mesh(available_devices=1, model_parallel=2)


@pytest.mark.parametrize("avail,mp,data", [
    (7, 1, 4),     # non-divisible survivor count rounds down to a pow2
    (6, 2, 2),     # 3 data shards fit but 2 keeps collectives regular
    (5, 4, 1),     # barely enough for the model axis
    (12, 3, 4),
    (8, 2, 4),     # exact fit stays exact
    (3, 2, 1),
    (1, 1, 1),
])
def test_elastic_data_parallel_sizing(avail, mp, data):
    assert elastic_data_parallel(avail, mp) == data


def test_elastic_data_parallel_validation():
    with pytest.raises(ValueError, match="devices"):
        elastic_data_parallel(1, 2)
    with pytest.raises(ValueError, match="model_parallel"):
        elastic_data_parallel(4, 0)


def test_failure_simulator_client_delay():
    sim = FailureSimulator(straggle_s=((2, 0.5),),
                           straggle_at=((1, 3, 2.0),))
    # recurring delay hits client 2 every round
    assert sim.client_delay(0, 2) == 0.5
    assert sim.client_delay(7, 2) == 0.5
    # one-shot delay hits (round 1, client 3) only
    assert sim.client_delay(0, 3) == 0.0
    assert sim.client_delay(1, 3) == 2.0
    assert sim.client_delay(2, 3) == 0.0
    # healthy clients are on time, and both kinds stack
    assert sim.client_delay(1, 0) == 0.0
    sim2 = FailureSimulator(straggle_s=((0, 0.1),),
                            straggle_at=((0, 0, 1.0),))
    assert sim2.client_delay(0, 0) == pytest.approx(1.1)
