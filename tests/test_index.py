import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import CompressionConfig
from repro.core import index as idx
from conftest import make_sparse


def test_pack_unpack_roundtrip():
    r = np.random.default_rng(0)
    bits = r.random((4, 8, 64)) < 0.3
    words = idx.pack_bits(jnp.asarray(bits))
    assert words.dtype == jnp.uint32
    assert words.shape[0] == bits.size // 32
    back = idx.unpack_bits(words, bits.shape)
    assert np.array_equal(np.asarray(back), bits)


def test_pack_rejects_unaligned():
    with pytest.raises(ValueError):
        idx.pack_bits(jnp.ones((33,), bool))


def test_packed_or_equals_mask_or():
    r = np.random.default_rng(1)
    a = r.random(2048) < 0.2
    b = r.random(2048) < 0.2
    wa, wb = idx.pack_bits(jnp.asarray(a)), idx.pack_bits(jnp.asarray(b))
    both = idx.unpack_bits(wa | wb, (2048,))
    assert np.array_equal(np.asarray(both), a | b)


def test_bloom_no_false_negatives():
    cfg = CompressionConfig(bloom_bits_ratio=0.5, bloom_hashes=3)
    x = make_sparse(32_768, 0.01, 2).reshape(4, 16, 512)
    filt = idx.bloom_build(jnp.asarray(x), cfg)
    cand = np.asarray(idx.bloom_query(x.shape, cfg, filt))
    nz = x != 0
    assert np.all(cand[nz]), "bloom filter must never miss a non-zero"
    # and some compression: false-positive rate bounded
    fpr = cand[~nz].mean()
    assert fpr < 0.2


def test_bloom_or_homomorphic():
    cfg = CompressionConfig(bloom_bits_ratio=0.5)
    x1 = make_sparse(16_384, 0.01, 3).reshape(2, 16, 512)
    x2 = make_sparse(16_384, 0.01, 4).reshape(2, 16, 512)
    f1 = idx.bloom_build(jnp.asarray(x1), cfg)
    f2 = idx.bloom_build(jnp.asarray(x2), cfg)
    fs = idx.bloom_build(jnp.asarray(np.where(x1 != 0, x1, x2)), cfg)
    # union of filters covers the union of supports
    cand = np.asarray(idx.bloom_query(x1.shape, cfg, f1 | f2))
    assert np.all(cand[(x1 != 0) | (x2 != 0)])
