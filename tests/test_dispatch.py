"""Backend-dispatch parity: the full compress -> aggregate -> recover
roundtrip must be bit-for-bit identical between ``use_pallas="always"``
(Pallas kernels, interpret mode on CPU) and ``"never"`` (jnp reference) —
both at the compressor level and through the bucketed aggregator layer
(fused and overlap-pipelined; plain, reduce-scatter — over both its
native psum_scatter/OR-RS wire and the psum+slice emulation — and the
in-network tree, over both its f32 and fixed-point wires).

Test values are dyadic (sign * 2^e, small e) so every floating-point sum
along either backend's reduction order is exact — bitwise equality then
checks the *math*, not addition-order luck.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core import CompressionConfig, HomomorphicCompressor, CompressedLeaf
from repro.core import topk as topk_lib
from repro.core.aggregators import make_aggregator
from repro.core.collectives import AggregationState, init_aggregation_state


def dyadic_sparse(n, frac, seed):
    r = np.random.default_rng(seed)
    x = np.zeros(n, np.float32)
    k = int(n * frac)
    idx = r.choice(n, size=k, replace=False)
    x[idx] = (r.choice([-1.0, 1.0], size=k)
              * np.exp2(r.integers(-2, 3, size=k))).astype(np.float32)
    return x


# lanes=128 keeps interpret-mode Pallas fast; chunk_blocks=4 and
# encode_block_tile=3 force the lax.map chunking and the multi-block
# grid-cell tiling (with padding) on the 11-block leaf below.
BASE = CompressionConfig(ratio=0.3, lanes=128, rows=6, rounds=10,
                         chunk_blocks=4, encode_block_tile=3)


def _roundtrip(cfg, n, workers=2):
    comp = HomomorphicCompressor(cfg)
    xs = [dyadic_sparse(n, 0.05, seed=s) for s in range(workers)]
    cs = [comp.compress(jnp.asarray(x)) for x in xs]
    agg = cs[0]
    for c in cs[1:]:
        agg = CompressedLeaf(sketch=agg.sketch + c.sketch,
                             index_words=agg.index_words | c.index_words)
    out, stats = comp.recover(agg, n, with_stats=True)
    return [np.asarray(c.sketch) for c in cs], np.asarray(out), stats, \
        np.sum(xs, axis=0)


@pytest.mark.parametrize("nb", [1, 11], ids=["single-chunk", "chunked"])
def test_roundtrip_parity_bitwise(nb):
    n = nb * BASE.block_elems - (BASE.lanes // 2 if nb > 1 else 0)
    never = dataclasses.replace(BASE, use_pallas="never")
    always = dataclasses.replace(BASE, use_pallas="always")
    sk_n, out_n, st_n, want = _roundtrip(never, n)
    sk_a, out_a, st_a, _ = _roundtrip(always, n)
    for a, b in zip(sk_n, sk_a):
        assert np.array_equal(a, b), "per-worker sketches differ"
    assert np.array_equal(out_n, out_a), "recovered gradients differ"
    assert int(st_n.residual) == 0 and int(st_a.residual) == 0
    assert int(st_n.peeled) == int(st_a.peeled)
    # lossless regime + dyadic values: recovery is exact, not approximate
    assert np.array_equal(out_n, want)


def test_estimate_runs_on_both_backends():
    n = 3 * BASE.block_elems
    x = dyadic_sparse(n, 0.02, seed=7)
    outs = []
    for policy in ("never", "always"):
        cfg = dataclasses.replace(BASE, use_pallas=policy)
        comp = HomomorphicCompressor(cfg)
        outs.append(np.asarray(comp.estimate(comp.compress(jnp.asarray(x)), n)))
    assert np.array_equal(outs[0], outs[1])


# ----------------------------------------------------------------------
# Bucketed aggregator roundtrip (PR 2): pack -> sparsify/EF -> encode ->
# psum/OR -> peel -> unpack, through both strategies and both backends.
# Runs inside a real (1-device) shard_map so the collectives are genuine.
# ----------------------------------------------------------------------

# ratio=1.0 keeps peel capacity (~81%) far above the post-top-k density
# even with dyadic tie overshoot; topk_ratio < nonzero fraction so the
# sparsifier really cuts and residuals are nonzero. bucket_bytes =
# 2 blocks -> the 4-leaf tree below spans several buckets, with one leaf
# larger than a bucket and one mixed-dtype leaf.
_AGG0 = dataclasses.replace(BASE, ratio=1.0, topk_ratio=0.1,
                            topk_exact=True, error_feedback=True)
AGG_BASE = dataclasses.replace(_AGG0, bucket_bytes=2 * _AGG0.block_elems * 4)


def _agg_tree(seed=0):
    r = np.random.default_rng(seed)

    def dyadic(n, frac, dtype=np.float32):
        return dyadic_sparse(n, frac, seed=r.integers(1 << 30)).astype(dtype)

    return {
        "big": dyadic(3 * AGG_BASE.block_elems * 2 + 101, 0.3),
        "mat": dyadic(40 * 64, 0.3).reshape(40, 64),
        "half": dyadic(900, 0.3, np.float16),
        "tiny": dyadic(9, 0.5),
    }


def _run_aggregator(cfg, name, steps=1, wire_plan=None):
    mesh = make_mesh((1,), ("data",))
    tree = jax.tree.map(jnp.asarray, _agg_tree())
    specs = jax.tree.map(lambda _: P(), tree)
    agg = make_aggregator(name, cfg, mesh, ("data",), ("model",),
                          outer_manual=("data",), wire_plan=wire_plan)

    def fn(g, r):
        out, st = agg(g, AggregationState(residual=r), specs)
        return out, st.residual

    jfn = jax.jit(shard_map(
        fn, mesh=mesh, in_specs=(specs, specs), out_specs=(specs, specs),
        axis_names={"data"}, check_vma=False))
    res = init_aggregation_state(tree, cfg).residual
    outs = []
    for s in range(steps):
        g = jax.tree.map(jnp.asarray, _agg_tree(seed=s))
        out, res = jfn(g, res)
        outs.append(jax.tree.map(np.asarray, out))
    return outs, jax.tree.map(np.asarray, res)


@pytest.mark.parametrize("name", ["compressed", "compressed_rs",
                                  "compressed_innet"])
@pytest.mark.parametrize("overlap", [False, True], ids=["fused", "overlap"])
def test_bucketed_aggregate_backend_parity(name, overlap):
    cfg_n = dataclasses.replace(AGG_BASE, use_pallas="never", overlap=overlap)
    cfg_a = dataclasses.replace(AGG_BASE, use_pallas="always", overlap=overlap)
    (out_n,), res_n = _run_aggregator(cfg_n, name)
    (out_a,), res_a = _run_aggregator(cfg_a, name)
    for k in out_n:
        assert np.array_equal(out_n[k], out_a[k]), f"grads differ: {k}"
        assert out_n[k].dtype == out_a[k].dtype
        assert np.array_equal(res_n[k], res_a[k]), f"residuals differ: {k}"
    # single worker + dyadic values: the roundtrip is exact, so the
    # aggregate must equal the sparsified (g + residual) per leaf
    tree = _agg_tree()
    for k, g in tree.items():
        flat = jnp.asarray(g.reshape(-1), jnp.float32)
        k_budget = max(1, int(flat.shape[0] * AGG_BASE.topk_ratio))
        want, want_res = topk_lib.apply_error_feedback(
            flat, jnp.zeros_like(flat), k_budget, exact=True)
        np.testing.assert_array_equal(
            out_n[k].reshape(-1), np.asarray(want).astype(g.dtype), err_msg=k)
        np.testing.assert_array_equal(res_n[k].reshape(-1),
                                      np.asarray(want_res), err_msg=k)


def test_bucketed_overlap_matches_fused_bitwise():
    for name in ("compressed", "compressed_rs"):
        (fused,), rf = _run_aggregator(
            dataclasses.replace(AGG_BASE, use_pallas="never"), name)
        (over,), ro = _run_aggregator(
            dataclasses.replace(AGG_BASE, use_pallas="never", overlap=True),
            name)
        for k in fused:
            assert np.array_equal(fused[k], over[k]), (name, k)
            assert np.array_equal(rf[k], ro[k]), (name, k)


# The shared stream scheduler (PR 5): an explicit wire-chunk grid must be
# bit-invisible for EVERY strategy on EVERY backend over 3 error-feedback
# steps. stream_chunks=4 over the 6-bucket test stream is non-divisible
# (zero-pads to 8); switch_slots=1 gives the innet tree 6 one-bucket
# windows so any chunk count spans whole windows. ``dense`` has no wire
# chunks — it must simply ignore the knob.
@pytest.mark.parametrize("name", ["dense", "compressed", "compressed_rs",
                                  "compressed_innet"])
@pytest.mark.parametrize("backend", ["never", "always"])
def test_stream_chunked_matches_unchunked_bitwise(name, backend):
    base = dataclasses.replace(AGG_BASE, use_pallas=backend)
    chunked = dataclasses.replace(base, stream_chunks=4, switch_slots=1)
    outs_f, res_f = _run_aggregator(base, name, steps=3)
    outs_c, res_c = _run_aggregator(chunked, name, steps=3)
    for step, (of, oc) in enumerate(zip(outs_f, outs_c)):
        for k in of:
            assert np.array_equal(of[k], oc[k]), (name, step, k)
    for k in res_f:
        assert np.array_equal(res_f[k], res_c[k]), (name, k)


def test_rs_matches_plain_bitwise():
    (plain,), _ = _run_aggregator(
        dataclasses.replace(AGG_BASE, use_pallas="never"), "compressed")
    (rs,), _ = _run_aggregator(
        dataclasses.replace(AGG_BASE, use_pallas="never"), "compressed_rs")
    for k in plain:
        assert np.array_equal(plain[k], rs[k]), k


# The innet f32 wire reuses the AllReduce collectives (bit-parity by
# construction); the fxp32 wire quantizes through the fixed-point codec,
# whose roundtrip is *exact* on these dyadic test values (sign * 2^e,
# |e| <= 2, far inside the mantissa budget) — so both wire dtypes must
# reproduce the plain strategy bit-for-bit here, on both backends.
@pytest.mark.parametrize("wire_dtype", ["f32", "fxp32"])
@pytest.mark.parametrize("backend", ["never", "always"])
def test_innet_wires_match_plain_bitwise(wire_dtype, backend):
    (plain,), res_p = _run_aggregator(
        dataclasses.replace(AGG_BASE, use_pallas=backend), "compressed")
    (innet,), res_i = _run_aggregator(
        dataclasses.replace(AGG_BASE, use_pallas=backend,
                            wire_dtype=wire_dtype), "compressed_innet")
    for k in plain:
        assert np.array_equal(plain[k], innet[k]), (wire_dtype, k)
        assert np.array_equal(res_p[k], res_i[k]), (wire_dtype, k)


# The harness mesh has only the (manual) "data" axis, so the region is
# full-manual and the native psum_scatter + OR-Reduce-Scatter wire runs
# on BOTH JAX legs — including pinned 0.4.x — not just where
# compat.SUPPORTS_PSUM_SCATTER is set.
@pytest.mark.parametrize("wire", ["native", "emulate"])
@pytest.mark.parametrize("backend", ["never", "always"])
def test_rs_wire_paths_match_plain_bitwise(wire, backend):
    (plain,), res_p = _run_aggregator(
        dataclasses.replace(AGG_BASE, use_pallas=backend), "compressed")
    (rs,), res_r = _run_aggregator(
        dataclasses.replace(AGG_BASE, use_pallas=backend, rs_wire=wire),
        "compressed_rs")
    for k in plain:
        assert np.array_equal(plain[k], rs[k]), (wire, k)
        assert np.array_equal(res_p[k], res_r[k]), (wire, k)


# ----------------------------------------------------------------------
# Per-bucket wire plans (PR 6): a mixed plan must be bit-identical to
# the fixed strategies it composes on the buckets it assigns. Single
# worker + dyadic values keep every wire (incl. dense psum of the packed
# f32 stream) exact, so the whole aggregate must equal the fixed
# ``compressed`` run bit-for-bit — outputs AND error-feedback residuals,
# over 3 EF steps. The test tree packs into 6 buckets.
# ----------------------------------------------------------------------

from repro.core.wireplan import WireGroup, WirePlan  # noqa: E402

MIXED_PLANS = {
    "dense+comp+rs": WirePlan(6, (WireGroup(0, 2, "dense"),
                                  WireGroup(2, 2, "compressed"),
                                  WireGroup(4, 2, "compressed_rs"))),
    "innet+comp+dense": WirePlan(6, (WireGroup(0, 3, "compressed_innet"),
                                     WireGroup(3, 1, "compressed"),
                                     WireGroup(4, 2, "dense"))),
    "chunk-override": WirePlan(6, (WireGroup(0, 2, "dense"),
                                   WireGroup(2, 2, "compressed",
                                             stream_chunks=2),
                                   WireGroup(4, 2, "compressed_rs"))),
}


@pytest.mark.parametrize("plan_name", sorted(MIXED_PLANS))
def test_mixed_wire_plan_matches_fixed_bitwise(plan_name):
    cfg = dataclasses.replace(AGG_BASE, use_pallas="never")
    outs_f, res_f = _run_aggregator(cfg, "compressed", steps=3)
    outs_m, res_m = _run_aggregator(cfg, "compressed", steps=3,
                                    wire_plan=MIXED_PLANS[plan_name])
    for step, (of, om) in enumerate(zip(outs_f, outs_m)):
        for k in of:
            assert np.array_equal(of[k], om[k]), (plan_name, step, k)
    for k in res_f:
        assert np.array_equal(res_f[k], res_m[k]), (plan_name, k)


def test_mixed_wire_plan_backend_parity():
    plan = MIXED_PLANS["dense+comp+rs"]
    (out_n,), res_n = _run_aggregator(
        dataclasses.replace(AGG_BASE, use_pallas="never"),
        "compressed", wire_plan=plan)
    (out_a,), res_a = _run_aggregator(
        dataclasses.replace(AGG_BASE, use_pallas="always"),
        "compressed", wire_plan=plan)
    for k in out_n:
        assert np.array_equal(out_n[k], out_a[k]), k
        assert np.array_equal(res_n[k], res_a[k]), k


def test_auto_strategy_matches_compressed_bitwise():
    """The `auto` strategy — explicit mixed plan or its zero-telemetry
    analytic fallback — must reproduce the fixed strategy bit-for-bit
    (the plan only moves buckets between lossless wires)."""
    cfg = dataclasses.replace(AGG_BASE, use_pallas="never")
    outs_f, res_f = _run_aggregator(cfg, "compressed", steps=3)
    for wire_plan in (MIXED_PLANS["dense+comp+rs"], None):
        outs_a, res_a = _run_aggregator(cfg, "auto", steps=3,
                                        wire_plan=wire_plan)
        for step, (of, oa) in enumerate(zip(outs_f, outs_a)):
            for k in of:
                assert np.array_equal(of[k], oa[k]), (wire_plan, step, k)
        for k in res_f:
            assert np.array_equal(res_f[k], res_a[k]), (wire_plan, k)


def test_dense_aggregator_rejects_wire_plan():
    cfg = dataclasses.replace(AGG_BASE, use_pallas="never")
    with pytest.raises(ValueError, match="does not execute wire plans"):
        _run_aggregator(cfg, "dense",
                        wire_plan=MIXED_PLANS["dense+comp+rs"])


def test_compressor_has_no_direct_backend_imports():
    """The dispatch layer is the only compute backend: the compressor
    must not reach into core.sketch/core.peeling directly."""
    import inspect
    import repro.core.compressor as m
    src = inspect.getsource(m)
    for needle in ("encode_blocks", "peel_blocks", "estimate_blocks",
                   "from .sketch", "from .peeling"):
        assert needle not in src, f"compressor bypasses kernels.ops: {needle}"
    assert "from repro.kernels import ops" in src


# The fused wire codec (PR 7): every compressed strategy now funnels
# through ONE producer op before its collectives and ONE consumer op
# after. "always" runs the fused Pallas kernels (interpret mode here);
# "never" runs the composed jnp refs — 3 error-feedback steps must stay
# bit-identical in outputs AND carried residuals, including the fxp32
# innet wire whose dequant is folded into the fused consumer.
@pytest.mark.parametrize("name,wire_dtype",
                         [("compressed", "f32"), ("compressed_rs", "f32"),
                          ("compressed_innet", "f32"),
                          ("compressed_innet", "fxp32")])
def test_fused_wire_parity_over_ef_steps(name, wire_dtype):
    cfg_n = dataclasses.replace(AGG_BASE, use_pallas="never",
                                wire_dtype=wire_dtype)
    cfg_a = dataclasses.replace(AGG_BASE, use_pallas="always",
                                wire_dtype=wire_dtype)
    outs_n, res_n = _run_aggregator(cfg_n, name, steps=3)
    outs_a, res_a = _run_aggregator(cfg_a, name, steps=3)
    for step, (on, oa) in enumerate(zip(outs_n, outs_a)):
        for k in on:
            assert np.array_equal(on[k], oa[k]), (name, step, k)
    for k in res_n:
        assert np.array_equal(res_n[k], res_a[k]), (name, k)


# ----------------------------------------------------------------------
# The all-to-all exchange (PR 8): the compressed permute wire must be
# bit-for-bit the dense one on identical routed payloads — the exchange
# codec runs at ratio 2.5, where sketch capacity exceeds the block even
# when every slot is occupied, so recovery of these dyadic payloads is
# exact. Pinned over 3 steps of evolving payloads, both backends, fused
# and chunked (stream_chunks > 1) lane grids; the multi-rank permute
# legs live in tests/drivers/collectives_driver.py.
# ----------------------------------------------------------------------

from repro.core.aggregators import make_exchange  # noqa: E402

# ratio 2.5 -> group=2, block=256 elems; two blocks per bucket
A2A_BASE = dataclasses.replace(
    BASE, ratio=2.5, topk_ratio=None, error_feedback=False,
    bucket_bytes=2 * 2 * BASE.lanes * 4)


def _a2a_payload(seed):
    r = np.random.default_rng(seed)

    def dyadic(shape, frac):
        n = int(np.prod(shape))
        return dyadic_sparse(n, frac, seed=r.integers(1 << 30)).reshape(shape)

    # leading axis = destination ranks (W=1 here); dense-ish payloads
    # exercise the full-occupancy recovery regime the exchange relies on
    # 825 + 1152 elems -> 4 buckets of 512: divisible by the chunked
    # grid below (the lane grid requires chunk count | bucket count)
    return {"x": dyadic((1, 3 * A2A_BASE.block_elems + 57), 0.9),
            "y": dyadic((1, 18, 64), 0.8)}


def _run_exchange(cfg, name, steps=3):
    mesh = make_mesh((1,), ("data",))
    exchange = make_exchange(name, cfg, mesh, ("data",),
                             outer_manual=("data",))

    def fn(payload):
        return exchange(payload)

    jfn = jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), _a2a_payload(0)),),
        out_specs=jax.tree.map(lambda _: P(), exchange_out_struct(cfg)),
        axis_names={"data"}, check_vma=False))
    outs = []
    for s in range(steps):
        payload = jax.tree.map(jnp.asarray, _a2a_payload(seed=s))
        outs.append(jax.tree.map(np.asarray, jfn(payload)))
    return outs


def exchange_out_struct(cfg):
    # merged output drops the destination axis: one slice per leaf
    return {k: v[0] for k, v in _a2a_payload(0).items()}


@pytest.mark.parametrize("backend", ["never", "always"])
@pytest.mark.parametrize("chunks", [None, 2], ids=["fused", "chunked"])
def test_exchange_compressed_matches_dense_bitwise(backend, chunks):
    cfg = dataclasses.replace(A2A_BASE, use_pallas=backend,
                              stream_chunks=chunks)
    outs_d = _run_exchange(cfg, "dense")
    outs_c = _run_exchange(cfg, "compressed")
    for step, (od, oc) in enumerate(zip(outs_d, outs_c)):
        for k in od:
            assert np.array_equal(od[k], oc[k]), (backend, chunks, step, k)
            # W=1: the merge is the identity on the only source's payload
            want = _a2a_payload(seed=step)[k][0]
            np.testing.assert_array_equal(od[k], want, err_msg=str((step, k)))


def test_exchange_backend_parity_bitwise():
    outs = {b: _run_exchange(dataclasses.replace(A2A_BASE, use_pallas=b),
                             "compressed")
            for b in ("never", "always")}
    for step, (on, oa) in enumerate(zip(outs["never"], outs["always"])):
        for k in on:
            assert np.array_equal(on[k], oa[k]), (step, k)


def test_exchange_rejects_bloom_index():
    cfg = dataclasses.replace(A2A_BASE, index="bloom")
    mesh = make_mesh((1,), ("data",))
    exchange = make_exchange("compressed", cfg, mesh, ("data",),
                             outer_manual=("data",))
    with pytest.raises(ValueError, match="bitmap"):
        exchange(jax.tree.map(jnp.asarray, _a2a_payload(0)))
