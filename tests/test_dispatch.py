"""Backend-dispatch parity: the full compress -> aggregate -> recover
roundtrip must be bit-for-bit identical between ``use_pallas="always"``
(Pallas kernels, interpret mode on CPU) and ``"never"`` (jnp reference).

Test values are dyadic (sign * 2^e, small e) so every floating-point sum
along either backend's reduction order is exact — bitwise equality then
checks the *math*, not addition-order luck.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import CompressionConfig, HomomorphicCompressor, CompressedLeaf


def dyadic_sparse(n, frac, seed):
    r = np.random.default_rng(seed)
    x = np.zeros(n, np.float32)
    k = int(n * frac)
    idx = r.choice(n, size=k, replace=False)
    x[idx] = (r.choice([-1.0, 1.0], size=k)
              * np.exp2(r.integers(-2, 3, size=k))).astype(np.float32)
    return x


# lanes=128 keeps interpret-mode Pallas fast; chunk_blocks=4 and
# encode_block_tile=3 force the lax.map chunking and the multi-block
# grid-cell tiling (with padding) on the 11-block leaf below.
BASE = CompressionConfig(ratio=0.3, lanes=128, rows=6, rounds=10,
                         chunk_blocks=4, encode_block_tile=3)


def _roundtrip(cfg, n, workers=2):
    comp = HomomorphicCompressor(cfg)
    xs = [dyadic_sparse(n, 0.05, seed=s) for s in range(workers)]
    cs = [comp.compress(jnp.asarray(x)) for x in xs]
    agg = cs[0]
    for c in cs[1:]:
        agg = CompressedLeaf(sketch=agg.sketch + c.sketch,
                             index_words=agg.index_words | c.index_words)
    out, stats = comp.recover(agg, n, with_stats=True)
    return [np.asarray(c.sketch) for c in cs], np.asarray(out), stats, \
        np.sum(xs, axis=0)


@pytest.mark.parametrize("nb", [1, 11], ids=["single-chunk", "chunked"])
def test_roundtrip_parity_bitwise(nb):
    n = nb * BASE.block_elems - (BASE.lanes // 2 if nb > 1 else 0)
    never = dataclasses.replace(BASE, use_pallas="never")
    always = dataclasses.replace(BASE, use_pallas="always")
    sk_n, out_n, st_n, want = _roundtrip(never, n)
    sk_a, out_a, st_a, _ = _roundtrip(always, n)
    for a, b in zip(sk_n, sk_a):
        assert np.array_equal(a, b), "per-worker sketches differ"
    assert np.array_equal(out_n, out_a), "recovered gradients differ"
    assert int(st_n.residual) == 0 and int(st_a.residual) == 0
    assert int(st_n.peeled) == int(st_a.peeled)
    # lossless regime + dyadic values: recovery is exact, not approximate
    assert np.array_equal(out_n, want)


def test_estimate_runs_on_both_backends():
    n = 3 * BASE.block_elems
    x = dyadic_sparse(n, 0.02, seed=7)
    outs = []
    for policy in ("never", "always"):
        cfg = dataclasses.replace(BASE, use_pallas=policy)
        comp = HomomorphicCompressor(cfg)
        outs.append(np.asarray(comp.estimate(comp.compress(jnp.asarray(x)), n)))
    assert np.array_equal(outs[0], outs[1])


def test_compressor_has_no_direct_backend_imports():
    """The dispatch layer is the only compute backend: the compressor
    must not reach into core.sketch/core.peeling directly."""
    import inspect
    import repro.core.compressor as m
    src = inspect.getsource(m)
    for needle in ("encode_blocks", "peel_blocks", "estimate_blocks",
                   "from .sketch", "from .peeling"):
        assert needle not in src, f"compressor bypasses kernels.ops: {needle}"
    assert "from repro.kernels import ops" in src
