"""Unit tests for the trip-count-corrected HLO analyzer on synthetic HLO."""
from benchmarks import hlo_analysis as ha

SYNTH = """
HloModule test

%wbody (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %ar = f32[8,16] all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%wcond (pc: (s32[], f32[8,16])) -> pred[] {
  %pc = (s32[], f32[8,16]) parameter(0)
  %ic = s32[] get-tuple-element(%pc), index=0
  %n = s32[] constant(28)
  ROOT %lt = pred[] compare(%ic, %n), direction=LT
}

ENTRY %main (a: f32[8,16], b: f32[16,32]) -> f32[8,32] {
  %a = f32[8,16] parameter(0)
  %b = f32[16,32] parameter(1)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %a)
  %w = (s32[], f32[8,16]) while(%init), condition=%wcond, body=%wbody
  %aw = f32[8,16] get-tuple-element(%w), index=1
  ROOT %d = f32[8,32] dot(%aw, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_shape_parse():
    elems, b = ha.shape_elems_bytes("f32[8,16]")
    assert elems == 128 and b == 512
    elems, b = ha.shape_elems_bytes("(s32[], f32[8,16])")
    assert b == 4 + 512


def test_trip_count_and_multipliers():
    comps = ha.parse_computations(SYNTH)
    assert set(comps) >= {"wbody", "wcond", "main"}
    mult = ha.execution_multipliers(comps)
    assert mult["wbody"] == 28
    assert mult["main"] == 1


def test_collective_trip_correction():
    s = ha.analyze(SYNTH)
    ar = s.collectives["all-reduce"]
    assert ar["count"] == 28                      # 1 op x 28 trips
    assert ar["bytes"] == 28 * 512
    # ring wire bytes: 2 * (g-1)/g * operand
    assert abs(ar["wire_bytes"] - 28 * 512 * 2 * 3 / 4) < 1e-6


def test_dot_flops():
    s = ha.analyze(SYNTH)
    # dot: (8,16) x (16,32): 2*8*32*16 = 8192 flops, outside the loop
    assert s.dot_flops == 8192
