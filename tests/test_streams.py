"""Unit coverage for the wire-chunk scheduling layer (core/streams.py).

The aggregator-level guarantees (chunked == unchunked bit-for-bit over 3
EF steps, for all four strategies, on the real multi-device wires
including the gather-skip path) live in ``tests/test_dispatch.py`` and
``tests/drivers/collectives_driver.py``; here we pin the *grid rules*:

- chunk grids align to whole buckets, zero-padding non-divisible counts;
- a forced ``stream_chunks`` that would split a per-rank reduce-scatter
  boundary, or an in-network switch window, raises ``ValueError``
  *naming the alignment constraint* (never a silent fallback — the PR 4
  warning behaviour this layer retired);
- :func:`stream_schedule` is a pure reordering: bit-identical to the
  direct per-chunk loop;
- the ZeRO-1 gather-skip predicate fires exactly when every leaf's
  per-rank optimizer slice sits inside that rank's owned chunk slices,
  using the same ``zero_slice_dim`` rule the train step slices with.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import CompressionConfig
from repro.core.bucketing import make_bucket_plan
from repro.core.streams import (StreamPlan, make_stream_plan,
                                stream_schedule, zero1_gather_skip,
                                zero_slice_dim)

# block_elems = 768; one bucket = one block
CFG = CompressionConfig(ratio=1.0, lanes=128, rows=6, bucket_bytes=768 * 4)


def _plan(n_buckets):
    return make_bucket_plan({"w": np.zeros(768 * n_buckets, np.float32)},
                            CFG)


# ----------------------------------------------------------------------
# grid resolution
# ----------------------------------------------------------------------

def test_fused_grid_is_one_chunk():
    splan = make_stream_plan(_plan(5), CFG)
    assert (splan.n_chunks, splan.chunk_buckets) == (1, 5)
    assert not splan.streamed and splan.pad_buckets == 0


def test_overlap_defaults_to_per_bucket_on_the_allreduce_wire():
    cfg = dataclasses.replace(CFG, overlap=True)
    splan = make_stream_plan(_plan(5), cfg)
    assert (splan.n_chunks, splan.chunk_buckets) == (5, 1)


def test_non_divisible_chunk_count_zero_pads():
    cfg = dataclasses.replace(CFG, stream_chunks=3)
    splan = make_stream_plan(_plan(5), cfg)
    assert (splan.n_chunks, splan.chunk_buckets) == (3, 2)
    assert splan.pad_buckets == 1
    buckets = jnp.arange(5 * 768, dtype=jnp.float32).reshape(5, 768)
    chunks = splan.chunk_view(buckets)
    assert chunks.shape == (3, 2, 768)
    assert not np.asarray(chunks[2, 1]).any()          # zero pad bucket
    np.testing.assert_array_equal(
        np.asarray(chunks).reshape(-1)[:5 * 768],
        np.asarray(buckets).reshape(-1))


def test_stream_chunks_clamps_to_bucket_count():
    cfg = dataclasses.replace(CFG, stream_chunks=99)
    assert make_stream_plan(_plan(5), cfg).n_chunks == 5


def test_empty_chunks_shrink_to_covering_grid():
    """A grid whose tail chunks would be ALL zero-padding shrinks to the
    largest count that still covers the stream — empty chunks would
    spend real collective rounds on all-zero payloads."""
    # AllReduce: 4 chunks of ceil(5/4)=2 buckets -> chunk 4 all padding
    splan = make_stream_plan(_plan(5),
                             dataclasses.replace(CFG, stream_chunks=4))
    assert (splan.n_chunks, splan.chunk_buckets) == (3, 2)
    # window grid: 3 chunks x 2 windows over ceil(7/2)=4 windows ->
    # chunk 3 (buckets 8..11) would be pure padding
    splan = make_stream_plan(_plan(7),
                             dataclasses.replace(CFG, stream_chunks=3),
                             window_buckets=2)
    assert (splan.n_chunks, splan.chunk_buckets) == (2, 4)
    # scatter grids can never go empty (chunk padding is < W while every
    # chunk spans >= W buckets): W=8, nb=9 keeps both 8-bucket chunks
    splan = make_stream_plan(_plan(9),
                             dataclasses.replace(CFG, stream_chunks=2),
                             workers=8, scatter=True)
    assert (splan.n_chunks, splan.chunk_buckets) == (2, 8)
    assert splan.pad_buckets < splan.chunk_buckets


def test_rs_grid_defaults_to_per_rank_chunks():
    cfg = dataclasses.replace(CFG, overlap=True)
    splan = make_stream_plan(_plan(5), cfg, workers=4, scatter=True)
    # per_rank = ceil(5/4) = 2 -> 2 chunks of 4 buckets (1 per rank each)
    assert (splan.n_chunks, splan.chunk_buckets) == (2, 4)
    assert splan.rank_chunk_buckets == 1
    assert splan.pad_buckets == 3
    # rank r owns bucket r of each chunk
    assert splan.rank_intervals(1) == ((768, 2 * 768),
                                       (4 * 768 + 768, 4 * 768 + 2 * 768))


def test_rs_boundary_splitting_chunks_raise_naming_the_constraint():
    cfg = dataclasses.replace(CFG, stream_chunks=3)
    with pytest.raises(ValueError) as ei:
        make_stream_plan(_plan(5), cfg, workers=4, scatter=True)
    msg = str(ei.value)
    assert "per-rank" in msg and "ceil(n_buckets/W)" in msg
    assert "ceil(5/4) = 2" in msg


def test_innet_grid_spans_whole_switch_windows():
    cfg = dataclasses.replace(CFG, overlap=True, switch_slots=2)
    splan = make_stream_plan(_plan(5), cfg, window_buckets=2)
    assert (splan.n_chunks, splan.chunk_buckets) == (3, 2)
    # a coarser explicit grid still spans whole windows
    cfg2 = dataclasses.replace(CFG, stream_chunks=2)
    splan2 = make_stream_plan(_plan(5), cfg2, window_buckets=2)
    assert (splan2.n_chunks, splan2.chunk_buckets) == (2, 4)


def test_innet_window_splitting_chunks_raise_naming_switch_slots():
    cfg = dataclasses.replace(CFG, stream_chunks=4)
    with pytest.raises(ValueError, match="switch_slots"):
        make_stream_plan(_plan(5), cfg, window_buckets=8)  # 1 window


def test_stream_plan_validates_geometry():
    with pytest.raises(ValueError, match="workers"):
        make_stream_plan(_plan(2), CFG, workers=0)
    with pytest.raises(ValueError, match="divisible"):
        StreamPlan(n_buckets=4, bucket_elems=768, blocks_per_bucket=1,
                   words_per_bucket=24, workers=3, n_chunks=1,
                   chunk_buckets=4)
    with pytest.raises(ValueError, match="covers"):
        StreamPlan(n_buckets=4, bucket_elems=768, blocks_per_bucket=1,
                   words_per_bucket=24, workers=1, n_chunks=1,
                   chunk_buckets=2)


# ----------------------------------------------------------------------
# the pipeline driver
# ----------------------------------------------------------------------

def test_stream_schedule_matches_direct_loop_bitwise():
    xs = jnp.asarray(
        np.random.default_rng(0).standard_normal((6, 32)).astype(np.float32))

    def encode(i, x):
        return x * 2.0 + i.astype(jnp.float32), x - 1.0

    def reduce(payload):
        a, b = payload
        return a + b, a * b

    got = jax.jit(lambda v: stream_schedule(v, encode, reduce))(xs)
    want = [reduce(encode(jnp.int32(i), xs[i])) for i in range(6)]
    for j in range(2):
        np.testing.assert_array_equal(
            np.asarray(got[j]), np.stack([np.asarray(w[j]) for w in want]))


def test_stream_schedule_single_chunk():
    xs = jnp.ones((1, 4))
    got = stream_schedule(xs, lambda i, x: x + 1.0, lambda p: p * 3.0)
    np.testing.assert_array_equal(np.asarray(got), np.full((1, 4), 6.0))


# ----------------------------------------------------------------------
# ZeRO-1 alignment
# ----------------------------------------------------------------------

def test_zero_slice_dim_rule():
    assert zero_slice_dim((8,), P(), 4) == 0
    assert zero_slice_dim((2, 8), P(), 4) == 1          # largest wins
    assert zero_slice_dim((8, 8), P(None, "model"), 4) == 0   # sharded out
    assert zero_slice_dim((3, 5), P(), 4) is None


def _skip_case(shapes, zero1_dims, n_chunks, workers=4):
    tree = {f"l{i}": np.zeros(sh, np.float32)
            for i, sh in enumerate(shapes)}
    plan = make_bucket_plan(tree, CFG)
    cfg = dataclasses.replace(CFG, stream_chunks=n_chunks)
    splan = make_stream_plan(plan, cfg, workers=workers, scatter=True)
    return zero1_gather_skip(splan, plan, zero1_dims)


def test_gather_skip_fires_on_aligned_chunk_grid():
    # two leaves of 4 buckets each (8-bucket stream); W=4, per_rank=2,
    # 2 chunks of 4 buckets -> rank r owns bucket r of each chunk, which
    # is exactly each leaf's dim-0 ZeRO-1 slice r.
    assert _skip_case([(4 * 768,), (4 * 768,)], (0, 0), n_chunks=2)
    # leading size-1 dims keep the slice flat-contiguous
    assert _skip_case([(1, 4 * 768), (4 * 768,)], (1, 0), n_chunks=2)


def test_gather_skip_rejects_misaligned_grids_and_leaves():
    # one fused chunk: rank ownership is two whole buckets per rank —
    # leaf 2's slices land on the wrong ranks
    assert not _skip_case([(4 * 768,), (4 * 768,)], (0, 0), n_chunks=1)
    # a leaf with no ZeRO-1 slice dim disables the skip outright
    assert not _skip_case([(4 * 768,), (4 * 768,)], (0, None), n_chunks=2)
    # slice dim with a real (non-1) leading dim is not flat-contiguous
    assert not _skip_case([(2, 2 * 768), (4 * 768,)], (1, 0), n_chunks=2)
    # leaf sizes not divisible by W
    assert not _skip_case([(4 * 768 + 4,), (4 * 768 - 4,)], (0, 0),
                          n_chunks=2)
    # single worker / missing dims: trivially off
    assert not _skip_case([(4 * 768,)], (0,), n_chunks=1, workers=1)
    plan = make_bucket_plan({"w": np.zeros(8 * 768, np.float32)}, CFG)
    splan = make_stream_plan(plan, dataclasses.replace(CFG, stream_chunks=2),
                             workers=4, scatter=True)
    assert not zero1_gather_skip(splan, plan, None)


def test_gather_skip_guard_keys_off_actual_leaf_sharding(monkeypatch):
    """The nested-packing guard must look at whether any leaf is really
    sharded on a non-DP axis — NOT at which axes the mesh merely has:
    a pure-DP profile on a mesh that also carries a (unused) model axis
    must still get the skip on every JAX generation."""
    from repro import compat
    from repro.core.aggregators import make_aggregator

    class FakeMesh:  # shape/axis_names are all the aggregator reads
        shape = {"data": 4, "model": 2}
        axis_names = ("data", "model")

    cfg = dataclasses.replace(CFG, rs_wire="native", stream_chunks=2)
    agg = make_aggregator("compressed_rs", cfg, FakeMesh(), ("data",), (),
                          outer_manual=("data", "model"),
                          zero1_dims=(0, 0))
    tree = {"a": np.zeros(4 * 768, np.float32),
            "b": np.zeros(4 * 768, np.float32)}
    repl = {"a": P(), "b": P()}
    tp = {"a": P("model"), "b": P()}
    for nested in (False, True):
        monkeypatch.setattr(compat, "SUPPORTS_NESTED_SHARD_MAP", nested)
        # replicated leaves: the stream is the global view either way
        assert agg.gather_skip_active(tree, repl), nested
    # a genuinely TP-sharded leaf: 0.4.x still packs the global view,
    # nested JAX packs a TP-local stream -> alignment math invalid
    monkeypatch.setattr(compat, "SUPPORTS_NESTED_SHARD_MAP", False)
    assert agg.gather_skip_active(tree, tp)
    monkeypatch.setattr(compat, "SUPPORTS_NESTED_SHARD_MAP", True)
    assert not agg.gather_skip_active(tree, tp)


# ----------------------------------------------------------------------
# wire accounting picks the gather side by alignment
# ----------------------------------------------------------------------

def test_strategy_wire_bytes_gather_skip_side():
    n = 8 * 768
    base = CFG.strategy_wire_bytes(n, workers=4, grad_bytes_per_elem=4)
    nat = base["compressed_rs_native"]
    assert nat["link_bytes"] == nat["link_bytes_with_gather"]
    aligned = CFG.strategy_wire_bytes(
        n, workers=4, grad_bytes_per_elem=4, zero1_aligned=True)[
        "compressed_rs_native"]
    assert aligned["link_bytes"] == aligned["link_bytes_no_gather"]
    assert aligned["link_bytes"] < nat["link_bytes"]
