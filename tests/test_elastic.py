"""Elastic aggregation service (PR 9): contract renegotiation, async
fold, straggler/deferred-residual close-out.

The bit-for-bit pins against the in-mesh ``compressed`` strategy run in
``tests/drivers/collectives_driver.py`` (multi-device); here the
single-device semantics: the round contract as the versioned handshake
(stale payloads rejected, never silently folded), arrival-order
invariance of the fold, the dynamic-W fxp32 gate (renegotiated mantissa
budget never overflows int32 — while the stale budget provably would),
and the quorum/deadline/deferred-residual close-out with loss-free
accounting.
"""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.bucketing import make_bucket_plan
from repro.core.compressor import CompressedLeaf, HomomorphicCompressor
from repro.core.config import CompressionConfig
from repro.elastic import (AdmissionPolicy, ElasticClient, ElasticServer,
                           FoldEngine, FoldError, Membership,
                           QuorumNotReached, RoundContract,
                           StaleContractError, negotiate_contract)
from repro.ft.failures import (FailureSimulator, StragglerMonitor,
                               SwitchRetransmitPolicy)
from repro.net.fixedpoint import FixedPointWire
from repro.net.switch import SwitchModel

CFG = CompressionConfig(ratio=1.0, lanes=128, rows=6, rounds=10,
                        chunk_blocks=8, topk_ratio=0.1, topk_exact=True,
                        error_feedback=True, bucket_bytes=2 * 768 * 4)
CFG_FX = dataclasses.replace(CFG, wire_dtype="fxp32")
SHAPES = {"a": (2000,), "b": (50, 20)}
TEMPLATE = {k: np.zeros(sh, np.float32) for k, sh in SHAPES.items()}


def dyadic_tree(seed):
    """sign * 2^e values: every summation order is exact, so bitwise
    equality checks the fold math (same trick as the drivers)."""
    r = np.random.default_rng(seed)
    out = {}
    for k, sh in SHAPES.items():
        n = int(np.prod(sh))
        g = np.zeros(n, np.float32)
        idx = r.choice(n, size=max(1, n // 3), replace=False)
        g[idx] = (r.choice([-1.0, 1.0], size=idx.size)
                  * np.exp2(r.integers(-2, 3, size=idx.size))
                  ).astype(np.float32)
        out[k] = jnp.asarray(g.reshape(sh))
    return out


def _plan(cfg=CFG):
    return make_bucket_plan(TEMPLATE, cfg)


# ----------------------------------------------------------------------
# RoundContract: the versioned handshake
# ----------------------------------------------------------------------

def test_contract_negotiation_and_validation():
    plan = _plan(CFG_FX)
    c4 = negotiate_contract(0, [3, 1, 0, 2], plan, CFG_FX)
    assert c4.cohort == (0, 1, 2, 3)
    assert c4.workers == 4
    assert c4.mantissa_bits == 28          # 30 - ceil_log2(4)
    assert c4.wire.mantissa_bits == 28
    # crossing the power-of-two boundary reprices the wire
    c5 = negotiate_contract(1, range(5), plan, CFG_FX)
    assert c5.mantissa_bits == 27
    assert c4.contract_id != c5.contract_id
    # mantissa is derived state: carrying the wrong budget is an error
    with pytest.raises(ValueError, match="renegotiate"):
        RoundContract(round_id=1, cohort=(0, 1, 2, 3, 4),
                      n_buckets=plan.n_buckets,
                      bucket_elems=plan.bucket_elems,
                      total_elems=plan.total, wire_dtype="fxp32",
                      mantissa_bits=28)
    with pytest.raises(ValueError, match="sorted"):
        RoundContract(round_id=0, cohort=(2, 1), n_buckets=1,
                      bucket_elems=1536, total_elems=1536,
                      wire_dtype="f32", mantissa_bits=None)
    with pytest.raises(ValueError, match="no mantissa"):
        RoundContract(round_id=0, cohort=(0,), n_buckets=1,
                      bucket_elems=1536, total_elems=1536,
                      wire_dtype="f32", mantissa_bits=30)
    f32 = negotiate_contract(0, [0, 1], _plan(), CFG)
    assert f32.mantissa_bits is None
    with pytest.raises(ValueError):
        f32.wire


def test_membership_admission_queue_and_leave():
    m = Membership(max_cohort=2)
    assert m.join(0) == "admitted"
    assert m.join(1) == "admitted"
    assert m.join(2) == "queued"
    assert m.roster == (0, 1) and m.queued == (2,)
    with pytest.raises(ValueError):
        m.join(1)
    m.leave(0)
    assert m.admit_queued() == (2,)
    assert m.roster == (1, 2)
    with pytest.raises(KeyError):
        m.leave(0)


# ----------------------------------------------------------------------
# Fold engine: arrival-order invariance, O(1) state, windows
# ----------------------------------------------------------------------

def _f32_payloads(contract, n, seed0=40):
    clients = [ElasticClient(w, CFG) for w in range(n)]
    return clients, [clients[w].contribute(contract, dyadic_tree(seed0 + w))
                     for w in range(n)]


def test_fold_is_arrival_order_invariant_and_loss_free():
    plan = _plan()
    contract = negotiate_contract(0, range(3), plan, CFG)
    engine = FoldEngine(contract, CFG)
    _, payloads = _f32_payloads(contract, 3)
    outs = []
    for perm in ([0, 1, 2], [2, 0, 1], [1, 2, 0]):
        st = engine.init_state()
        for w in perm:
            engine.fold(st, payloads[w])
        outs.append(engine.finalize(st))
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[0], outs[2])
    # dyadic data: the folded aggregate equals the sum of individually
    # decoded payloads, exactly
    want = sum(engine.decode_payload(p) for p in payloads)
    assert np.array_equal(outs[0], want)


def test_fold_state_is_payload_shaped_and_windowed():
    plan = _plan()
    contract = negotiate_contract(0, range(3), plan, CFG)
    engine = FoldEngine(contract, CFG, window_slots=1)
    st = engine.init_state()
    _, payloads = _f32_payloads(contract, 3)
    base = (st.sketch.nbytes, st.index_words.nbytes)
    for p in payloads:
        engine.fold(st, p)
    # O(1) aggregation state: folding did not grow the accumulators
    assert (st.sketch.nbytes, st.index_words.nbytes) == base
    # window_slots=1 with 2 buckets: 2 windows per fold, occupancy <= 1
    assert st.windows == 3 * plan.n_buckets
    assert st.occupancy_peak == 1
    assert st.contributions == 3
    assert set(st.rx_bytes) == {0, 1, 2}
    assert all(v == payloads[0].nbytes for v in st.rx_bytes.values())


def test_fold_rejects_duplicates_unknown_and_oversubscription():
    plan = _plan()
    contract = negotiate_contract(0, range(2), plan, CFG)
    engine = FoldEngine(contract, CFG)
    st = engine.init_state()
    _, payloads = _f32_payloads(contract, 2)
    engine.fold(st, payloads[0])
    with pytest.raises(FoldError, match="already contributed"):
        engine.fold(st, payloads[0])
    stranger = dataclasses.replace(payloads[1], client=7)
    with pytest.raises(FoldError, match="not in this round's cohort"):
        engine.fold(st, stranger)
    engine.fold(st, payloads[1])
    ghost = dataclasses.replace(payloads[0], client=0)
    with pytest.raises(FoldError, match="already contributed"):
        engine.fold(st, ghost)
    # a wire sized for W can never fold more than W payloads
    st2 = engine.init_state()
    st2.contributions = 2
    with pytest.raises(FoldError, match="overflow bound"):
        engine.fold(st2, payloads[0])


# ----------------------------------------------------------------------
# fxp32: two-phase rounds == the documented codec roundtrip
# ----------------------------------------------------------------------

def test_fxp32_fold_matches_roundtrip_reference_bitwise():
    plan = _plan(CFG_FX)
    W = 5
    contract = negotiate_contract(0, range(W), plan, CFG_FX)
    engine = FoldEngine(contract, CFG_FX)
    st = engine.init_state()
    clients = [ElasticClient(w, CFG_FX) for w in range(W)]
    # non-dyadic values: the quantize/rint rounding is live here, so
    # this pins the *documented* roundtrip, not just exact arithmetic
    r = np.random.default_rng(11)
    trees = [{k: jnp.asarray(r.normal(0, 1, sh).astype(np.float32))
              for k, sh in SHAPES.items()} for _ in range(W)]
    for w in range(W):
        p = clients[w].propose(contract, trees[w])
        engine.propose_exponents(st, p.client, p.exponents, p.contract_id)
    shared = engine.seal_exponents(st)
    payloads = [clients[w].payload(contract, shared) for w in range(W)]
    for w in np.random.default_rng(2).permutation(W):
        engine.fold(st, payloads[w])
    got = engine.finalize(st)

    wire = FixedPointWire(workers=W)
    sks = [c._cache["sketch"] for c in clients]
    dec = wire.roundtrip_reference(
        [jnp.asarray(s).reshape(plan.n_buckets, -1) for s in sks])
    words = clients[0]._cache["index_words"].copy()
    for c in clients[1:]:
        words = words | c._cache["index_words"]
    comp = HomomorphicCompressor(CFG_FX)
    rec = comp.recover(
        CompressedLeaf(sketch=jnp.asarray(dec).reshape(sks[0].shape),
                       index_words=jnp.asarray(words)), plan.padded)
    want = np.asarray(rec).reshape(plan.n_buckets, plan.bucket_elems)
    assert np.array_equal(got, want)


def test_fxp32_payload_against_wrong_exponents_is_rejected():
    plan = _plan(CFG_FX)
    contract = negotiate_contract(0, range(2), plan, CFG_FX)
    engine = FoldEngine(contract, CFG_FX)
    st = engine.init_state()
    clients = [ElasticClient(w, CFG_FX) for w in range(2)]
    for w in range(2):
        p = clients[w].propose(contract, dyadic_tree(60 + w))
        engine.propose_exponents(st, p.client, p.exponents)
    shared = engine.seal_exponents(st)
    good = clients[0].payload(contract, shared)
    # quantized against exponents that are not the sealed vector
    bad = dataclasses.replace(good, exponents=good.exponents + 1)
    with pytest.raises(StaleContractError, match="sealed"):
        engine.fold(st, bad)
    # before sealing, no payload is verifiable at all
    st2 = engine.init_state()
    with pytest.raises(StaleContractError, match="sealed"):
        engine.fold(st2, good)
    engine.fold(st, good)


# ----------------------------------------------------------------------
# Dynamic-W gate: renegotiation, stale rejection, overflow freedom
# ----------------------------------------------------------------------

def test_dynamic_w_renegotiates_and_rejects_stale_payloads():
    srv = ElasticServer(TEMPLATE, CFG_FX,
                        policy=AdmissionPolicy(max_cohort=16))
    for w in range(4):
        srv.join(w)
    clients = {w: ElasticClient(w, CFG_FX) for w in range(4)}
    c0 = srv.open_round()
    assert c0.workers == 4 and c0.mantissa_bits == 28
    trees = {w: dyadic_tree(80 + w) for w in range(4)}
    for w in range(4):
        srv.submit_exponents(clients[w].propose(c0, trees[w]))
    shared0 = srv.seal_exponents()
    # client 0 encodes for round 0 but misses the round entirely
    late = clients[0].payload(c0, shared0)
    for w in range(1, 4):
        srv.submit(clients[w].payload(c0, shared0))
    with pytest.raises(QuorumNotReached):
        srv.close_round()                  # 3/4, before deadline
    srv.close_round(now_s=2.0)             # quorum + deadline

    # a 5th client joins: the contract reprices across the pow2 boundary
    srv.join(4)
    clients[4] = ElasticClient(4, CFG_FX)
    c1 = srv.open_round()
    assert c1.workers == 5 and c1.mantissa_bits == 27
    # the stale payload is rejected, never silently folded
    with pytest.raises(StaleContractError, match="re-encode"):
        srv.submit(late)
    assert srv.submit.__self__ is srv      # server survived the reject
    # re-encode under the new contract (EF is not re-charged): client 0
    # re-prices its cached sketch, everyone else proposes fresh
    srv.submit_exponents(clients[0].reencode(c1))
    trees[4] = dyadic_tree(84)
    for w in range(1, 5):
        srv.submit_exponents(clients[w].propose(c1, trees[w]))
    shared1 = srv.seal_exponents()
    # the cached round-0 payload still cannot sneak in
    with pytest.raises(StaleContractError):
        clients[0].payload(c0, shared1)
    for w in range(5):
        assert srv.submit(clients[w].payload(c1, shared1)) == "folded"
    _, rep = srv.close_round()
    assert rep.close_reason == "complete" and rep.folded == 5
    assert rep.rejected_stale == 1


def test_new_cohort_budget_never_overflows_int32_stale_budget_would():
    """W grows 4 -> 9: the renegotiated budget (M=26) keeps a 9-way
    worst-case sum inside int32; the stale budget (M=28) provably does
    not — the SwitchModel's running-register check catches it."""
    w4, w9 = FixedPointWire(4), FixedPointWire(4).with_workers(9)
    assert (w4.mantissa_bits, w9.mantissa_bits) == (28, 26)
    # worst-case cell: the largest float32 below 2^e quantizes to
    # 2^M - 2^(M-24); nine of those under the stale budget exceed int32
    y = np.nextafter(np.float32(1024.0), np.float32(0.0))
    buckets = jnp.full((1, 128), y, jnp.float32)
    e = w4.bucket_exponents(buckets)
    q_stale = int(np.asarray(w4.encode(buckets, e))[0, 0])
    q_new = int(np.asarray(w9.encode(buckets, e))[0, 0])
    assert q_stale == 2**28 - 2**4
    assert 9 * q_stale > 2**31 - 1          # stale budget: overflow
    assert 9 * q_new <= 2**30               # renegotiated: provably safe

    bm = np.zeros((9, 1, 4), np.uint32)
    stale_chunks = np.full((9, 1, 128), q_stale, np.int32)
    with pytest.raises(OverflowError, match="32-bit switch register"):
        SwitchModel(ports=9, slots=4).aggregate(stale_chunks, bm)
    new_chunks = np.full((9, 1, 128), q_new, np.int32)
    out, _ = SwitchModel(ports=9, slots=4).aggregate(new_chunks, bm)
    assert int(out[0, 0]) == 9 * q_new

    # and through the real engine: a full-attendance 9-client fold of
    # max-magnitude payloads raises nothing and recovers finite values
    plan = _plan(CFG_FX)
    contract = negotiate_contract(0, range(9), plan, CFG_FX)
    engine = FoldEngine(contract, CFG_FX)
    st = engine.init_state()
    clients = [ElasticClient(w, CFG_FX) for w in range(9)]
    r = np.random.default_rng(3)
    for w in range(9):
        big = {k: jnp.asarray((r.normal(0, 1, sh) * 1e30
                               ).astype(np.float32))
               for k, sh in SHAPES.items()}
        p = clients[w].propose(contract, big)
        engine.propose_exponents(st, p.client, p.exponents)
    shared = engine.seal_exponents(st)
    for w in range(9):
        engine.fold(st, clients[w].payload(contract, shared))
    out = engine.finalize(st)
    assert np.isfinite(out).all()


# ----------------------------------------------------------------------
# Straggler gate: quorum/deadline close, deferred -> next-round residual
# ----------------------------------------------------------------------

def test_straggler_rounds_close_and_defer_loss_free():
    sim = FailureSimulator(straggle_s=((2, 0.12),),
                           straggle_at=((0, 3, 5.0),))
    monitor = StragglerMonitor(warmup=2)
    retrans = SwitchRetransmitPolicy(timeout_s=0.05, max_retries=3)
    srv = ElasticServer(
        TEMPLATE, CFG,
        policy=AdmissionPolicy(max_cohort=8, quorum=0.5, deadline_s=1.0),
        retransmit=retrans, monitor=monitor)
    for w in range(4):
        srv.join(w)
    clients = [ElasticClient(w, CFG) for w in range(4)]

    all_contributions = np.zeros(
        (srv.plan.n_buckets, srv.plan.bucket_elems), np.float32)
    outs = []
    for rnd in range(2):
        contract = srv.open_round()
        engine = srv._engine
        statuses = {}
        for w in range(4):
            p = clients[w].contribute(contract, dyadic_tree(
                200 + 10 * rnd + w))
            all_contributions += engine.decode_payload(p)
            arrival = 0.01 * (w + 1) + sim.client_delay(rnd, w)
            statuses[w] = srv.submit(p, arrival_s=arrival)
        if rnd == 0:
            # client 3 injected 5s late: past the deadline -> deferred;
            # client 2 is 0.12s late: inside the retransmit budget
            assert statuses[3] == "deferred"
            assert statuses[2] == "folded"
            # everyone is accounted for (3 folded + 1 deferred): the
            # round closes at quorum without burning the deadline
            out, rep = srv.close_round(now_s=0.5)
            assert rep.close_reason == "quorum"
            assert rep.folded == 3 and rep.deferred == 1
            assert rep.retransmits > 0
            assert retrans.events                  # accounted, not dropped
            # the deferred contribution is pending, not lost
            assert np.any(srv.pending_residual != 0)
        else:
            assert all(s == "folded" for s in statuses.values())
            out, rep = srv.close_round()
            assert rep.close_reason == "complete"
            assert rep.residual_carried_in         # round-0 late payload
        outs.append(out)
    # loss-free accounting: folded + deferred == sum of ALL payloads
    # (dyadic values -> bitwise)
    total_out = outs[0] + outs[1] + srv.pending_residual
    assert np.array_equal(total_out, all_contributions)
    # the 5s arrival was flagged by the latency monitor
    assert any(ev["dt"] >= 5.0 for ev in monitor.events)


def test_quorum_not_reached_blocks_close():
    srv = ElasticServer(TEMPLATE, CFG,
                        policy=AdmissionPolicy(quorum=0.75,
                                               deadline_s=1.0))
    for w in range(4):
        srv.join(w)
    contract = srv.open_round()
    c = ElasticClient(0, CFG)
    srv.submit(c.contribute(contract, dyadic_tree(1)))
    # 1/4 folded < quorum 3: not closeable even past the deadline
    with pytest.raises(QuorumNotReached):
        srv.close_round(now_s=5.0)


def test_server_round_lifecycle_guards():
    srv = ElasticServer(TEMPLATE, CFG)
    with pytest.raises(RuntimeError, match="no round is open"):
        srv.seal_exponents()
    srv.join(0)
    srv.open_round()
    with pytest.raises(RuntimeError, match="still open"):
        srv.open_round()
