"""Partition-rule unit tests: the path-based spec table."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh
from repro.configs import get_arch
from repro.models import model_api
from repro.parallel.sharding import (ShardingProfile, param_pspecs,
                                     batch_pspec, cache_pspecs,
                                     filter_rules_for_mesh, strip_axes)


def _specs_for(name):
    arch = get_arch(name)
    api = model_api(arch.smoke)
    params = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    return params, param_pspecs(params, arch.train.sharding)


def test_dense_attention_specs():
    params, specs = _specs_for("qwen2-7b")
    lay = specs["layers"]
    assert lay["attn"]["wq"] == P(None, None, "model")
    assert lay["attn"]["wo"] == P(None, "model", None)
    assert lay["attn"]["bq"] == P(None, "model")
    assert lay["ffn"]["w_down"] == P(None, "model", None)
    assert lay["ln1"]["scale"] == P(None, None)
    assert specs["embed"] == P("model", None)


def test_moe_expert_specs():
    _, specs = _specs_for("deepseek-moe-16b")
    lay = specs["layers"]
    assert lay["moe"]["we_gate"] == P(None, "model", None, None)
    assert lay["moe"]["router"] == P(None, None, None)
    assert lay["moe"]["shared"]["w_up"] == P(None, None, "model")


def test_kimi_ep_over_data():
    _, specs = _specs_for("kimi-k2-1t-a32b")
    lay = specs["layers"]
    assert lay["moe"]["we_gate"] == P(None, "data", None, "model")
    assert lay["moe"]["we_down"] == P(None, "data", "model", None)


def test_mamba_specs():
    _, specs = _specs_for("mamba2-1.3b")
    lay = specs["layers"]
    assert lay["mamba"]["wx"] == P(None, None, "model")
    assert lay["mamba"]["A_log"] == P(None, "model")
    assert lay["mamba"]["conv_w"] == P(None, None, None)


def test_hybrid_nested_paths():
    _, specs = _specs_for("jamba-v0.1-52b")
    sb = specs["superblocks"]
    # smoke config: attn_period=2, attn at pos1 (which is odd -> MoE FFN)
    assert sb["pos1"]["attn"]["wq"] == P(None, None, "model")
    assert sb["pos0"]["mamba"]["wz"] == P(None, None, "model")
    assert sb["pos1"]["moe"]["we_up"] == P(None, "model", None, None)


def test_batch_pspec_coverage():
    mesh = make_mesh((1, 1), ("data", "model"))
    prof = ShardingProfile()
    assert batch_pspec(4, mesh, prof) == P(("data",))
    # batch=1 cannot cover even data=1? 1 % 1 == 0 -> covered
    assert batch_pspec(1, mesh, prof) == P(("data",))


def test_cache_pspecs_families():
    mesh = make_mesh((1, 1), ("data", "model"))
    prof = ShardingProfile()
    dense = get_arch("qwen2-7b").smoke
    c = cache_pspecs(dense, 8, mesh, prof)
    assert set(c) == {"k", "v"}
    hyb = get_arch("jamba-v0.1-52b").smoke
    c = cache_pspecs(hyb, 8, mesh, prof)
    assert set(c) == {"mamba", "kv"}


def test_filter_rules_and_strip():
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = {"dp": ("pod", "data"), "tp": "model", "ep": "pod"}
    f = filter_rules_for_mesh(rules, mesh)
    assert f == {"dp": ("data",), "tp": "model", "ep": None}
    assert strip_axes(P(("pod", "data"), "model"), ["pod", "data"]) \
        == P(None, "model")
