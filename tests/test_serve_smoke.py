"""Smoke pins for the serving entry points (PR 9 satellite).

``launch/serve.py`` and ``examples/serve_lm.py`` were exercised only by
hand; now that the elastic service hangs its ``--elastic`` mode off the
serve launcher, a refactor that breaks the launcher's argument surface
or the example's imports should fail here, not in a user's terminal.
Style follows ``tests/test_benchmarks_smoke.py``: run the real entry
point at tiny sizes, assert on its observable output.
"""
import os
import runpy
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")

TINY = ["--arch", "qwen2-7b", "--smoke", "--batch", "2",
        "--prompt-len", "4", "--max-new", "3"]


def _run_launcher(argv, capsys):
    from repro.launch import serve
    old = sys.argv
    sys.argv = ["serve.py"] + argv
    try:
        serve.main()
    finally:
        sys.argv = old
    return capsys.readouterr().out


@pytest.mark.slow
def test_launch_serve_batch_mode(capsys):
    out = _run_launcher(TINY, capsys)
    assert "batch generate:" in out
    assert "first row:" in out


@pytest.mark.slow
def test_launch_serve_continuous_mode(capsys):
    out = _run_launcher(TINY + ["--continuous"], capsys)
    assert "continuous:" in out
    assert "4 requests" in out          # batch*2 submissions all finish


@pytest.mark.slow
@pytest.mark.parametrize("wire", ["f32", "fxp32"])
def test_launch_serve_elastic_mode(wire, capsys):
    out = _run_launcher(
        TINY + ["--elastic", "--cohort", "2", "--rounds", "2",
                "--wire", wire], capsys)
    # round 1 admits a third client mid-run
    assert "round 0: W=2" in out
    assert "round 1: W=3" in out
    assert f"wire={wire}" in out
    assert "(0 lost)" in out


@pytest.mark.slow
def test_launch_serve_elastic_straggler_defers(capsys):
    out = _run_launcher(
        TINY + ["--elastic", "--cohort", "2", "--rounds", "2",
                "--straggle"], capsys)
    assert "deferred=1" in out          # the injected late payload
    assert "(0 lost)" in out


@pytest.mark.slow
def test_example_serve_lm_runs(capsys):
    # the example asserts len(done) == 10 itself; run it for real
    runpy.run_path(os.path.join(REPO, "examples", "serve_lm.py"),
                   run_name="__main__")
    out = capsys.readouterr().out
    assert "batched generate:" in out
    assert "continuous batching: 10 requests" in out
