"""Parameter / activation / cache partition rules.

Megatron-style tensor parallelism on the ``model`` axis, expert
parallelism on a configurable axis set, vocab-sharded embeddings, and
shape-dependent cache sharding for serving (sequence parallelism when the
batch cannot cover the data axis).

Rules are *path-based*: the leaf's own name plus its parent module name
select the spec, so the same table covers dense layers, MoE experts,
Mamba blocks and the hybrid ``pos{i}`` nesting without per-model code.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingProfile:
    """Per-architecture distribution choices."""
    dp_axes: Tuple[str, ...] = ("pod", "data")   # manual grad-agg axes
    tp_axis: Optional[str] = "model"             # None = pure DP (params
                                                 # replicated, paper arm)
    ep_axes: Tuple[str, ...] = ("model",)        # expert dim of MoE weights
    ep_ff_axis: Optional[str] = None             # extra axis on expert d_ff
    vocab_axis: Optional[str] = "model"
    zero1: bool = True                           # shard optimizer state on dp
    batch_auto_axes: Tuple[str, ...] = ()        # batch sharded on *auto*
                                                 # axes (e.g. kimi: data is
                                                 # an EP axis, dp is pod)

    def logical_rules(self, inside_manual_dp: bool) -> dict:
        """Mapping for activation hints (repro.parallel.hints)."""
        if inside_manual_dp:
            dp = (self.batch_auto_axes if len(self.batch_auto_axes) > 1 else
                  (self.batch_auto_axes[0] if self.batch_auto_axes else None))
        else:
            all_dp = tuple(self.dp_axes) + tuple(self.batch_auto_axes)
            dp = all_dp if len(all_dp) > 1 else (all_dp[0] if all_dp else None)
        return {
            "dp": dp,
            "tp": self.tp_axis,
            "ep": self.ep_axes if len(self.ep_axes) > 1 else self.ep_axes[0],
            "sp": self.tp_axis or "model",
        }


# ----------------------------------------------------------------------
# Parameter rules
# ----------------------------------------------------------------------

def _leaf_spec(path: Tuple[str, ...], leaf, prof: ShardingProfile,
               stacked: bool) -> P:
    """Spec for one parameter leaf. ``stacked`` = has leading layer dim."""
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    tp = prof.tp_axis
    lead: Tuple = (None,) if stacked else ()

    def spec(*parts):
        return P(*(lead + parts))

    # --- embeddings / head (never layer-stacked) ---
    if name == "embed":
        return P(prof.vocab_axis, None)
    if name == "lm_head":
        return P(None, prof.vocab_axis)

    # --- attention ---
    if parent in ("attn", "xattn"):
        if name in ("wq", "wk", "wv"):
            return spec(None, tp)
        if name == "wo":
            return spec(tp, None)
        if name in ("bq", "bk", "bv"):
            return spec(tp)

    # --- dense FFN (incl. MoE shared expert) ---
    if parent in ("ffn", "mlp", "shared"):
        if name in ("w_gate", "w_up"):
            return spec(None, tp)
        if name == "w_down":
            return spec(tp, None)

    # --- MoE experts ---
    if name == "router":
        return spec(None, None)
    if name in ("we_gate", "we_up"):
        ep = prof.ep_axes if len(prof.ep_axes) > 1 else prof.ep_axes[0]
        return spec(ep, None, prof.ep_ff_axis)
    if name == "we_down":
        ep = prof.ep_axes if len(prof.ep_axes) > 1 else prof.ep_axes[0]
        return spec(ep, prof.ep_ff_axis, None)

    # --- Mamba ---
    if parent == "mamba":
        if name in ("wx", "wz", "wdt"):
            return spec(None, tp)
        if name == "wo":
            return spec(tp, None)
        if name in ("A_log", "D_skip", "dt_bias"):
            return spec(tp)
        if name in ("wB", "wC", "conv_w", "conv_b"):
            return spec(*(None,) * (leaf.ndim - len(lead)))

    # --- norms / scalars: replicated ---
    return spec(*(None,) * (leaf.ndim - len(lead)))


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            out.append(k.name)
        else:
            out.append(str(k))
    return tuple(out)


_STACKED_ROOTS = ("layers", "superblocks", "enc_layers", "dec_layers")


def param_pspecs(params: Any, prof: ShardingProfile) -> Any:
    """PartitionSpec pytree matching ``params``."""
    def fn(path, leaf):
        names = _path_names(path)
        stacked = any(n in _STACKED_ROOTS for n in names)
        return _leaf_spec(names, leaf, prof, stacked)
    return jax.tree_util.tree_map_with_path(fn, params)


def param_shardings(params: Any, prof: ShardingProfile, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params, prof),
                        is_leaf=lambda x: isinstance(x, P))


def filter_rules_for_mesh(rules: dict, mesh) -> dict:
    """Drop logical-rule axes the mesh doesn't have (e.g. 'pod' on a
    single-pod mesh)."""
    def keep(v):
        if v is None:
            return None
        if isinstance(v, (tuple, list)):
            kept = tuple(a for a in v if a in mesh.shape)
            return kept if kept else None
        return v if v in mesh.shape else None
    return {k: keep(v) for k, v in rules.items()}


def strip_axes(spec: P, axes: Sequence[str]) -> P:
    """Remove references to ``axes`` from a spec (for nested shard_map)."""
    drop = set(axes)
    parts = []
    for s in spec:
        if s is None:
            parts.append(None)
        elif isinstance(s, (tuple, list)):
            kept = tuple(a for a in s if a not in drop)
            parts.append(kept if kept else None)
        else:
            parts.append(None if s in drop else s)
    return P(*parts)


# ----------------------------------------------------------------------
# Batch / cache specs per serving shape
# ----------------------------------------------------------------------

def batch_pspec(global_batch: int, mesh, prof: ShardingProfile) -> P:
    """Batch-dim sharding: all DP axes the batch can cover."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    covered = []
    size = 1
    for a in axes:
        if global_batch % (size * mesh.shape[a]) == 0:
            covered.append(a)
            size *= mesh.shape[a]
    return P(tuple(covered)) if covered else P()


def cache_pspecs(cfg: ModelConfig, global_batch: int, mesh,
                 prof: ShardingProfile) -> Any:
    """Specs for the decode cache pytree (see models init_cache layout).

    Large batch: shard batch over DP axes, sequence over the TP axis
    (sequence-parallel KV — every model shard holds a sequence slice and
    GSPMD's softmax/contract reductions realise flash-decoding combines).
    batch == 1 (long-context): shard the sequence over *all* axes.
    """
    dp = batch_pspec(global_batch, mesh, prof)
    dp_names = dp[0] if len(dp) else None
    if global_batch >= _dp_size(mesh):
        b_ax, s_ax = dp_names, prof.tp_axis
    else:
        b_ax, s_ax = None, tuple(mesh.axis_names)   # everything on seq

    def kv_spec(ndim_hint=None):
        # (L, B, S, KV, hd)
        return P(None, b_ax, s_ax, None, None)

    def mamba_state_spec(extra_lead: int):
        lead = (None,) * extra_lead
        return {
            "ssm": P(*lead, b_ax, prof.tp_axis, None, None),
            "conv": P(*lead, b_ax, None, None),
        }

    if cfg.family == "ssm":
        return {"ssm": mamba_state_spec(1)}
    if cfg.family == "hybrid":
        return {"mamba": mamba_state_spec(2),
                "kv": {"k": kv_spec(), "v": kv_spec()}}
    if cfg.family == "encdec":
        return {"k": kv_spec(), "v": kv_spec(),
                "xk": P(None, b_ax, None, None, None),
                "xv": P(None, b_ax, None, None, None)}
    return {"k": kv_spec(), "v": kv_spec()}


def _dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n
