"""Distribution: logical sharding hints, partition rules, input specs."""

from .hints import logical_axis_rules, constrain

__all__ = ["logical_axis_rules", "constrain"]
