"""Logical activation-sharding hints.

Model code calls ``constrain(x, ("dp", None, "tp"))`` with *logical* axis
names; a context-scoped mapping translates them to mesh axes (or drops
them entirely when no mapping is active — the single-device CPU path).

Logical names:
  "dp"  — data-parallel batch axis (may be absent inside manual shard_map,
          where the batch is already device-local: map it to None there)
  "tp"  — tensor-parallel feature/head axis
  "ep"  — expert axis of MoE layers
  "sp"  — sequence axis (long-context cache sharding)
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def logical_axis_rules(rules: Optional[dict], mesh=None):
    """rules: {"tp": "model", "ep": "model", "dp": None, ...} or None.

    Pass ``mesh`` when the constrained code runs under plain jit (serving):
    with_sharding_constraint needs NamedSharding there, while inside
    shard_map the raw PartitionSpec binds to the context mesh."""
    prev = (_rules(), getattr(_state, "mesh", None))
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def constrain(x: jax.Array, logical_spec) -> jax.Array:
    """Apply with_sharding_constraint if a rules mapping is active."""
    rules = _rules()
    if not rules:
        return x
    parts = []
    for name in logical_spec:
        if name is None:
            parts.append(None)
        else:
            parts.append(rules.get(name))
    # rank-adapt: align the spec to the trailing dims (a (B,S,F) hint
    # applied to a flattened (T,F) keeps its feature-axis meaning)
    if len(parts) > x.ndim:
        parts = parts[-x.ndim:]
    elif len(parts) < x.ndim:
        parts = [None] * (x.ndim - len(parts)) + parts
    if all(p is None for p in parts):
        return x
    mesh = getattr(_state, "mesh", None)
    if mesh is not None:
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*parts)))
    from repro import compat
    return compat.manual_region_constraint(x, P(*parts))
