"""Atomic, hashed, reshardable checkpoints (+ async saver)."""
from .checkpoint import save, restore, latest_step, AsyncCheckpointer
__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]
