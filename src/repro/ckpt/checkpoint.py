"""Checkpointing: atomic, content-hashed, reshard-on-restore, async-capable.

Layout per step:

    <dir>/step_000123/
        manifest.json   — treedef paths, shapes, dtypes, sha256 per leaf,
                          user metadata, framework versions
        <leaf-id>.bin   — raw little-endian bytes (works for bf16 too)

Writes go to ``step_X.tmp`` and are atomically renamed, so a crash can
never leave a half-written checkpoint that restore would pick up.
Restores ``device_put`` every leaf onto caller-provided shardings, which
is what makes elastic restarts (different mesh shape) work: the bytes on
disk are mesh-agnostic.
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import hashlib
import json
import os
import shutil
from typing import Any, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

_MANIFEST = "manifest.json"


def _leaf_paths(tree: Any) -> List[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for k in path:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(str(k.idx))
            else:
                parts.append(getattr(k, "name", str(k)))
        paths.append(".".join(parts))
    return paths


def _to_bytes(arr: np.ndarray) -> bytes:
    return np.ascontiguousarray(arr).tobytes()


def save(directory: str, step: int, state: Any,
         metadata: Optional[Dict] = None, keep_last: int = 3) -> str:
    """Synchronous atomic save. Returns final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = jax.tree.leaves(state)
    paths = _leaf_paths(state)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.bin"
        raw = _to_bytes(arr)
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(raw)
        manifest["leaves"].append({
            "path": p, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "sha256": hashlib.sha256(raw).hexdigest(),
        })
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep_last)
    return final


class AsyncCheckpointer:
    """Overlaps checkpoint serialization with training (single worker so
    checkpoints land in order)."""

    def __init__(self):
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._last: Optional[cf.Future] = None

    def save(self, directory: str, step: int, state: Any,
             metadata: Optional[Dict] = None, keep_last: int = 3):
        # materialise on host *now* (cheap copy) so training can mutate
        host_state = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                  state)
        self._last = self._pool.submit(save, directory, step, host_state,
                                       metadata, keep_last)
        return self._last

    def wait(self):
        if self._last is not None:
            self._last.result()


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(directory: str, step: Optional[int] = None, template: Any = None,
            shardings: Any = None, verify: bool = True) -> Any:
    """Restore a pytree. ``template`` supplies the treedef; ``shardings``
    (optional pytree of NamedSharding) reshards every leaf — pass the specs
    of the *current* mesh for elastic restore."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)

    host_leaves = []
    for entry in manifest["leaves"]:
        with open(os.path.join(path, entry["file"]), "rb") as f:
            raw = f.read()
        if verify and hashlib.sha256(raw).hexdigest() != entry["sha256"]:
            raise IOError(f"checksum mismatch in {entry['file']} "
                          f"(corrupt checkpoint {path})")
        dtype = jnp.dtype(entry["dtype"])
        arr = np.frombuffer(raw, dtype=dtype).reshape(entry["shape"])
        host_leaves.append(arr)

    if template is None:
        return manifest, host_leaves
    treedef = jax.tree.structure(template)
    tree = jax.tree.unflatten(treedef, host_leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                            shardings)
    return tree


def _gc(directory: str, keep_last: int):
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp"))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
