"""Architecture + shape registry machinery.

Each ``configs/<arch>.py`` exposes ``ARCH: ArchSpec`` with
  - ``model``:    the exact published configuration,
  - ``smoke``:    a reduced same-family config for CPU tests,
  - ``profile``:  the ShardingProfile (TP/EP/DP/ZeRO choices),
  - ``train``:    per-arch TrainConfig overrides (optimizer, compression).

``SHAPES`` defines the four assigned input-shape cells; ``cells_for``
applies the applicability rules from the brief (long_500k only for
sub-quadratic archs; decode only for archs with a decoder — all ten).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardingProfile
from repro.train.config import TrainConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    model: ModelConfig
    smoke: ModelConfig
    profile: ShardingProfile
    train: TrainConfig
    source: str = ""      # citation tag from the assignment

    def __post_init__(self):
        # the profile is authoritative: thread it into the TrainConfig so
        # train/serve builders see one consistent ShardingProfile
        if self.train.sharding is not self.profile:
            object.__setattr__(
                self, "train",
                dataclasses.replace(self.train, sharding=self.profile))

    def shape_supported(self, shape: ShapeConfig) -> Tuple[bool, str]:
        if shape.name == "long_500k" and not self.model.supports_long_context:
            return False, ("SKIP: full quadratic attention at 524k context "
                           "(sub-quadratic archs only, per brief)")
        return True, ""


def make_batch_struct(cfg: ModelConfig, global_batch: int, seq_len: int):
    """ShapeDtypeStruct stand-ins for one training batch (no allocation)."""
    import jax
    d: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    if cfg.family == "encdec":
        d["frames"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        d["vis_embed"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.vis_tokens, cfg.d_model), jnp.float32)
    return d
