"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 with MoE [arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536; one attention
layer per 8 (offset 4); MoE 16 experts top-2 on every other layer.
Sub-quadratic overall: runs the long_500k cell (its 4 attention layers
use a sequence-sharded KV cache).
"""
import dataclasses
from repro.models.config import ModelConfig, MoEConfig, SSMConfig
from repro.parallel.sharding import ShardingProfile
from repro.train.config import TrainConfig
from repro.core.config import CompressionConfig
from repro.train.optimizer import OptimizerConfig
from .base import ArchSpec

_MODEL = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536,
    attn_period=8, attn_offset=4,
    moe=MoEConfig(num_experts=16, top_k=2, shared_experts=0,
                  expert_d_ff=14336, every_k_layers=2),
    ssm=SSMConfig(d_state=16, expand=2, head_dim=64, d_conv=4, chunk=256),
    supports_long_context=True)

_SMOKE = dataclasses.replace(
    _MODEL, n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, attn_period=2, attn_offset=1,
    moe=MoEConfig(num_experts=4, top_k=2, shared_experts=0, expert_d_ff=256,
                  every_k_layers=2),
    ssm=SSMConfig(d_state=16, expand=2, head_dim=16, d_conv=4, chunk=32),
    dtype="float32", q_block=64)

ARCH = ArchSpec(
    model=_MODEL, smoke=_SMOKE,
    profile=ShardingProfile(),
    train=TrainConfig(
        aggregator="compressed",
        accum_steps=8,
        compression=CompressionConfig(ratio=0.1, topk_ratio=0.04),
        optimizer=OptimizerConfig(kind="adamw", state_dtype="bfloat16")),
    source="arXiv:2403.19887; hf")
