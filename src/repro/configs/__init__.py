"""Assigned architecture registry: ``get_arch(id)`` / ``ARCHS``."""

from .base import ArchSpec, ShapeConfig, SHAPES, make_batch_struct

from . import (qwen2_7b, qwen2_5_3b, qwen1_5_32b, granite_3_2b,
               mamba2_1_3b, internvl2_2b, jamba_v0_1_52b,
               deepseek_moe_16b, kimi_k2_1t_a32b, whisper_tiny)

ARCHS = {
    "qwen2-7b": qwen2_7b.ARCH,
    "qwen2.5-3b": qwen2_5_3b.ARCH,
    "qwen1.5-32b": qwen1_5_32b.ARCH,
    "granite-3-2b": granite_3_2b.ARCH,
    "mamba2-1.3b": mamba2_1_3b.ARCH,
    "internvl2-2b": internvl2_2b.ARCH,
    "jamba-v0.1-52b": jamba_v0_1_52b.ARCH,
    "deepseek-moe-16b": deepseek_moe_16b.ARCH,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b.ARCH,
    "whisper-tiny": whisper_tiny.ARCH,
}


def get_arch(name: str) -> ArchSpec:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ArchSpec", "ShapeConfig", "SHAPES", "ARCHS", "get_arch",
           "make_batch_struct"]
