"""whisper-tiny — encoder-decoder, conv/audio frontend stubbed
[arXiv:2212.04356].

4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865, enc_seq=1500.
The 32k decode cells exercise the *shape* far beyond Whisper's real
448-token context (noted in DESIGN.md §4); decoder positions use RoPE.
"""
import dataclasses
from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardingProfile
from repro.train.config import TrainConfig
from repro.core.config import CompressionConfig
from repro.train.optimizer import OptimizerConfig
from .base import ArchSpec

_MODEL = ModelConfig(
    name="whisper-tiny", family="encdec", n_layers=4, enc_layers=4,
    d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865,
    enc_seq=1500, tie_embeddings=True, supports_long_context=False)

_SMOKE = dataclasses.replace(
    _MODEL, n_layers=2, enc_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=512, enc_seq=64, dtype="float32", q_block=64)

ARCH = ArchSpec(
    model=_MODEL, smoke=_SMOKE,
    profile=ShardingProfile(),
    train=TrainConfig(
        aggregator="compressed",
        accum_steps=8,
        compression=CompressionConfig(ratio=0.1, topk_ratio=0.04),
        optimizer=OptimizerConfig(kind="adamw")),
    source="arXiv:2212.04356")
