"""deepseek-moe-16b — fine-grained MoE [arXiv:2401.06066; hf].

28L d_model=2048 16H (MHA kv=16) expert_d_ff=1408 vocab=102400;
2 shared + 64 routed experts, top-6.
"""
import dataclasses
from repro.models.config import ModelConfig, MoEConfig
from repro.parallel.sharding import ShardingProfile
from repro.train.config import TrainConfig
from repro.core.config import CompressionConfig
from repro.train.optimizer import OptimizerConfig
from .base import ArchSpec

_MODEL = ModelConfig(
    name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=102400,
    moe=MoEConfig(num_experts=64, top_k=6, shared_experts=2,
                  expert_d_ff=1408),
    rope_theta=1e4, supports_long_context=False)

_SMOKE = dataclasses.replace(
    _MODEL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=512,
    moe=MoEConfig(num_experts=8, top_k=2, shared_experts=2, expert_d_ff=128),
    dtype="float32", q_block=64)

ARCH = ArchSpec(
    model=_MODEL, smoke=_SMOKE,
    profile=ShardingProfile(),
    train=TrainConfig(
        aggregator="compressed",
        accum_steps=8,
        # expert grads are naturally sparse (the paper's NCF regime):
        # no top-k needed for losslessness at 10% wire size
        compression=CompressionConfig(ratio=0.1, topk_ratio=0.04),
        optimizer=OptimizerConfig(kind="adamw")),
    source="arXiv:2401.06066; hf")
