"""qwen2-7b — dense GQA decoder [arXiv:2407.10671; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064, QKV bias.
"""
import dataclasses
from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardingProfile
from repro.train.config import TrainConfig
from repro.core.config import CompressionConfig
from repro.train.optimizer import OptimizerConfig
from .base import ArchSpec

_MODEL = ModelConfig(
    name="qwen2-7b", family="dense", n_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, d_ff=18944, vocab=152064, qkv_bias=True,
    rope_theta=1e6, supports_long_context=False)

_SMOKE = dataclasses.replace(
    _MODEL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, dtype="float32", q_block=64)

ARCH = ArchSpec(
    model=_MODEL, smoke=_SMOKE,
    profile=ShardingProfile(),
    train=TrainConfig(
        aggregator="compressed",
        accum_steps=8,
        compression=CompressionConfig(ratio=0.1, topk_ratio=0.04),
        optimizer=OptimizerConfig(kind="adamw")),
    source="arXiv:2407.10671; hf")
