"""mamba2-1.3b — attention-free SSD [arXiv:2405.21060].

48L d_model=2048, ssm_state=128, headdim=64, expand 2, vocab 50280.
Sub-quadratic: runs the long_500k cell.
"""
import dataclasses
from repro.models.config import ModelConfig, SSMConfig
from repro.parallel.sharding import ShardingProfile
from repro.train.config import TrainConfig
from repro.core.config import CompressionConfig
from repro.train.optimizer import OptimizerConfig
from .base import ArchSpec

_MODEL = ModelConfig(
    name="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=64, n_kv_heads=64, d_ff=0, vocab=50280, tie_embeddings=True,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, d_conv=4, chunk=256),
    supports_long_context=True)

_SMOKE = dataclasses.replace(
    _MODEL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, vocab=512,
    ssm=SSMConfig(d_state=16, expand=2, head_dim=16, d_conv=4, chunk=32),
    dtype="float32")

ARCH = ArchSpec(
    model=_MODEL, smoke=_SMOKE,
    profile=ShardingProfile(),
    train=TrainConfig(
        aggregator="compressed",
        accum_steps=8,
        compression=CompressionConfig(ratio=0.1, topk_ratio=0.04),
        optimizer=OptimizerConfig(kind="adamw")),
    source="arXiv:2405.21060")
