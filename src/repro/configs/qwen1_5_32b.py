"""qwen1.5-32b — dense MHA decoder [hf:Qwen/Qwen1.5 family].

64L d_model=5120 40H (kv=40, i.e. MHA) d_ff=27392 vocab=152064, QKV bias.
"""
import dataclasses
from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardingProfile
from repro.train.config import TrainConfig
from repro.core.config import CompressionConfig
from repro.train.optimizer import OptimizerConfig
from .base import ArchSpec

_MODEL = ModelConfig(
    name="qwen1.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=40, d_ff=27392, vocab=152064, qkv_bias=True,
    rope_theta=1e6, supports_long_context=False)

_SMOKE = dataclasses.replace(
    _MODEL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab=512, dtype="float32", q_block=64)

ARCH = ArchSpec(
    model=_MODEL, smoke=_SMOKE,
    profile=ShardingProfile(),
    train=TrainConfig(
        aggregator="compressed",
        accum_steps=8,
        compression=CompressionConfig(ratio=0.1, topk_ratio=0.04),
        optimizer=OptimizerConfig(kind="adamw", state_dtype="bfloat16")),
    source="hf:Qwen/Qwen1.5-0.5B; hf")
