"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config)
[arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) expert_d_ff=2048 vocab=163840;
384 routed experts top-8 + 1 shared. 1.03T total / ~32B active.

Distribution at this scale departs from the default profile:
  - experts are sharded over the *data* axis (16-way EP) with expert
    d_ff over *model* (16-way) -> 256-way expert sharding per pod;
  - gradient DP therefore happens only across pods ("pod" axis);
  - momentum optimizer with bf16 state (Adam f32 moments would not fit
    16 GB/chip at 512 chips: 8 TB of optimizer state).
"""
import dataclasses
from repro.models.config import ModelConfig, MoEConfig
from repro.parallel.sharding import ShardingProfile
from repro.train.config import TrainConfig
from repro.core.config import CompressionConfig
from repro.train.optimizer import OptimizerConfig
from .base import ArchSpec

_MODEL = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, d_ff=2048, vocab=163840,
    moe=MoEConfig(num_experts=384, top_k=8, shared_experts=1,
                  expert_d_ff=2048),
    rope_theta=1e6, supports_long_context=False)

_SMOKE = dataclasses.replace(
    _MODEL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512,
    moe=MoEConfig(num_experts=8, top_k=2, shared_experts=1, expert_d_ff=128),
    dtype="float32", q_block=64)

ARCH = ArchSpec(
    model=_MODEL, smoke=_SMOKE,
    # NOTE: gradient DP for this arch is pod-level only (all in-pod axes
    # are consumed by expert/tensor sharding). XLA's SPMD partitioner
    # check-crashes on collectives over a *manual* pod axis when operands
    # are auto-sharded over the two remaining axes (spmd_partitioner_util
    # CHECK at device-group expansion), so the pod-DP gradient reduction
    # runs in pure-auto GSPMD mode (dense psum inserted by sharding
    # propagation) instead of the manual compressed pipeline. See
    # DESIGN.md §Arch-applicability and EXPERIMENTS.md §Dry-run.
    profile=ShardingProfile(
        dp_axes=(), ep_axes=("data",), ep_ff_axis="model",
        batch_auto_axes=("pod", "data")),
    train=TrainConfig(
        aggregator="dense",
        accum_steps=8,
        # error feedback would add an f32 params-sized residual (4 TB);
        # at 1T params that alone exceeds HBM — run threshold top-k
        # without EF (momentum partially compensates; see DESIGN.md)
        compression=CompressionConfig(ratio=0.1, topk_ratio=0.04,
                                      error_feedback=False),
        optimizer=OptimizerConfig(kind="momentum", state_dtype="bfloat16")),
    source="arXiv:2501.kimi2 (paper-table)")
