"""qwen2.5-3b — dense GQA decoder [hf:Qwen/Qwen2.5 family].

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936, QKV bias.
"""
import dataclasses
from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardingProfile
from repro.train.config import TrainConfig
from repro.core.config import CompressionConfig
from repro.train.optimizer import OptimizerConfig
from .base import ArchSpec

_MODEL = ModelConfig(
    name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
    n_heads=16, n_kv_heads=2, d_ff=11008, vocab=151936, qkv_bias=True,
    rope_theta=1e6, supports_long_context=False)

_SMOKE = dataclasses.replace(
    _MODEL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, dtype="float32", q_block=64)

ARCH = ArchSpec(
    model=_MODEL, smoke=_SMOKE,
    profile=ShardingProfile(),
    train=TrainConfig(
        aggregator="compressed",
        accum_steps=8,
        compression=CompressionConfig(ratio=0.1, topk_ratio=0.04),
        optimizer=OptimizerConfig(kind="adamw")),
    source="hf:Qwen/Qwen2.5-0.5B; hf")
