"""granite-3-2b — dense GQA decoder [hf:ibm-granite/granite-3.0-2b-base].

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
"""
import dataclasses
from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardingProfile
from repro.train.config import TrainConfig
from repro.core.config import CompressionConfig
from repro.train.optimizer import OptimizerConfig
from .base import ArchSpec

_MODEL = ModelConfig(
    name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab=49155, qkv_bias=False,
    rope_theta=1e4, supports_long_context=False)

_SMOKE = dataclasses.replace(
    _MODEL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, dtype="float32", q_block=64)

ARCH = ArchSpec(
    model=_MODEL, smoke=_SMOKE,
    profile=ShardingProfile(),
    train=TrainConfig(
        aggregator="compressed",
        accum_steps=8,
        compression=CompressionConfig(ratio=0.1, topk_ratio=0.04),
        optimizer=OptimizerConfig(kind="adamw")),
    source="hf:ibm-granite/granite-3.0-2b-base; hf")
