"""internvl2-2b — VLM: InternViT (stub) + InternLM2 backbone
[arXiv:2404.16821; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553. The vision
frontend is a stub: input_specs provides precomputed patch embeddings
(256 tokens, the InternVL pixel-shuffle output) prepended to the text.
"""
import dataclasses
from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardingProfile
from repro.train.config import TrainConfig
from repro.core.config import CompressionConfig
from repro.train.optimizer import OptimizerConfig
from .base import ArchSpec

_MODEL = ModelConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=8192, vocab=92553, vis_tokens=256,
    rope_theta=1e6, supports_long_context=False)

_SMOKE = dataclasses.replace(
    _MODEL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, vis_tokens=8, dtype="float32", q_block=64)

ARCH = ArchSpec(
    model=_MODEL, smoke=_SMOKE,
    profile=ShardingProfile(),
    train=TrainConfig(
        aggregator="compressed",
        accum_steps=8,
        compression=CompressionConfig(ratio=0.1, topk_ratio=0.04),
        optimizer=OptimizerConfig(kind="adamw")),
    source="arXiv:2404.16821; hf")
