"""Deterministic synthetic data pipeline with background prefetch.

Every batch is a pure function of ``(seed, step)`` — restart/elastic
recovery replays the exact token stream without any persisted iterator
state, which is what makes the checkpoint/restart tests bit-reproducible.

The generator produces whatever the architecture's ``loss`` expects:
  tokens/labels            — all LM families
  + frames (B, enc_seq, D) — encdec (stubbed audio frontend)
  + vis_embed (B, V, D)    — vlm (stubbed vision frontend)
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np
import jax

from repro.models.config import ModelConfig


def batch_fn(cfg: ModelConfig, global_batch: int, seq_len: int,
             seed: int = 0) -> Callable[[int], Dict[str, np.ndarray]]:
    """Returns step -> host batch dict (deterministic)."""

    def make(step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, 0xDA7A]))
        # shifted-window LM stream: labels are next tokens
        toks = rng.integers(0, cfg.vocab, (global_batch, seq_len + 1),
                            dtype=np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == "encdec":
            batch["frames"] = rng.normal(
                0, 1, (global_batch, cfg.enc_seq, cfg.d_model)
            ).astype(np.float32)
        if cfg.family == "vlm":
            batch["vis_embed"] = rng.normal(
                0, 1, (global_batch, cfg.vis_tokens, cfg.d_model)
            ).astype(np.float32)
        return batch

    return make


class Prefetcher:
    """Background-thread prefetch + device_put onto the batch shardings."""

    def __init__(self, make_batch: Callable[[int], Dict[str, np.ndarray]],
                 shardings=None, depth: int = 2, start_step: int = 0):
        self._make = make_batch
        self._shardings = shardings
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            host = self._make(step)
            if self._shardings is not None:
                dev = jax.tree.map(
                    lambda a, s: jax.device_put(a, s), host, self._shardings)
            else:
                dev = host
            try:
                self._q.put((step, dev), timeout=1.0)
            except queue.Full:
                if self._stop.is_set():
                    return
                continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
