"""Deterministic synthetic data pipeline with prefetch."""
from .pipeline import batch_fn, Prefetcher
__all__ = ["batch_fn", "Prefetcher"]
