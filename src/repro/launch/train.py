"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --steps 200 --global-batch 8 --seq-len 128 --aggregator compressed

``--smoke`` selects the reduced same-family config (the full configs need
the production pod). The host mesh spreads over whatever devices exist
(data x model via --model-parallel).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--aggregator",
                    choices=["dense", "compressed", "compressed_rs",
                             "compressed_innet"],
                    default=None)
    ap.add_argument("--compression-ratio", type=float, default=None)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--devices", type=int, default=0,
                    help="fake host devices (0 = real)")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            "--xla_disable_hlo_passes=all-reduce-promotion "
            + os.environ.get("XLA_FLAGS", ""))

    from repro.configs import get_arch
    from repro.models import model_api
    from repro.train.loop import run_training
    from repro.launch.mesh import make_host_mesh

    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.model
    tc = arch.train
    if args.aggregator:
        tc = dataclasses.replace(tc, aggregator=args.aggregator)
    if args.compression_ratio:
        tc = dataclasses.replace(tc, compression=dataclasses.replace(
            tc.compression, ratio=args.compression_ratio))
    if args.lr:
        tc = dataclasses.replace(tc, optimizer=dataclasses.replace(
            tc.optimizer, lr=args.lr, total_steps=args.steps))
    if args.smoke:
        # reduced runs don't need 8-way accumulation or remat
        tc = dataclasses.replace(tc, accum_steps=1, remat="none")

    mesh = make_host_mesh(model_parallel=args.model_parallel)
    api = model_api(cfg)
    res = run_training(api, tc, mesh, global_batch=args.global_batch,
                       seq_len=args.seq_len, steps=args.steps,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    print(json.dumps({
        "arch": args.arch, "aggregator": tc.aggregator,
        "first_loss": res.losses[0], "last_loss": res.losses[-1],
        "restarts": res.restarts, "steps": res.final_step,
    }, indent=1))


if __name__ == "__main__":
    main()
