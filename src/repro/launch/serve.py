"""Serving launcher: batched greedy generation on a host mesh, plus the
elastic aggregation service (PR 9) driven against the same model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --batch 4 --prompt-len 16 --max-new 32

    # elastic: async sketch-fold rounds over an intermittent cohort,
    # using the arch's parameter tree as the gradient template
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --elastic --cohort 4 --rounds 3 --wire fxp32
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def run_elastic(args, cfg, params):
    """Round-driven elastic aggregation over the arch's gradient tree.

    Each round: open a contract for the live cohort, have every client
    contribute a synthetic gradient for the *model's own parameter
    shapes*, fold payloads in arrival order (with injected stragglers
    when asked), close at quorum/deadline. A client joins mid-run so the
    fxp32 wire renegotiates its mantissa budget at least once.
    """
    import dataclasses
    import jax
    from repro.core.config import CompressionConfig
    from repro.elastic import AdmissionPolicy, ElasticClient, ElasticServer
    from repro.ft.failures import FailureSimulator, SwitchRetransmitPolicy

    template = jax.tree.map(np.asarray, params)
    ccfg = CompressionConfig(ratio=1.0, lanes=128, rows=6, rounds=10,
                             chunk_blocks=8, topk_ratio=0.1,
                             topk_exact=True, error_feedback=True,
                             wire_dtype=args.wire)
    policy = AdmissionPolicy(max_cohort=max(args.cohort + 1, 4),
                             quorum=0.5, deadline_s=args.deadline)
    sim = FailureSimulator(
        straggle_at=(((1, 0, args.deadline * 5),) if args.straggle else ()))
    srv = ElasticServer(template, ccfg, policy=policy,
                        retransmit=SwitchRetransmitPolicy())
    clients = {}

    def admit(c):
        srv.join(c)
        clients[c] = ElasticClient(c, ccfg)

    for c in range(args.cohort):
        admit(c)

    rng = np.random.default_rng(0)
    for rnd in range(args.rounds):
        if rnd == args.rounds // 2:    # membership churn mid-run
            admit(args.cohort)
        contract = srv.open_round()
        roster = contract.cohort
        grads = {c: jax.tree.map(
            lambda a: rng.normal(0, 1, a.shape).astype(np.float32),
            template) for c in roster}
        if ccfg.wire_dtype == "fxp32":
            for c in roster:
                srv.submit_exponents(clients[c].propose(contract, grads[c]))
            shared = srv.seal_exponents()
            payloads = {c: clients[c].payload(contract, shared)
                        for c in roster}
        else:
            payloads = {c: clients[c].contribute(contract, grads[c])
                        for c in roster}
        t0 = time.perf_counter()
        for c in roster:
            arrival = 0.001 * (c + 1) + sim.client_delay(rnd, c)
            srv.submit(payloads[c], arrival_s=arrival)
        stream = srv.close_round(now_s=args.deadline)[0]
        dt = time.perf_counter() - t0
        rep = srv.reports[-1]
        m = contract.mantissa_bits
        print(f"round {rep.round_id}: W={rep.workers} "
              f"wire={contract.wire_dtype}"
              f"{'' if m is None else f'/M={m}'} "
              f"folded={rep.folded} deferred={rep.deferred} "
              f"retransmits={rep.retransmits} close={rep.close_reason} "
              f"fold={dt*1e3:.1f}ms |out|={float(np.abs(stream).max()):.3g}")
    total = sum(r.folded + r.deferred for r in srv.reports)
    print(f"elastic: {len(srv.reports)} rounds, {total} payloads "
          f"accounted (0 lost)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="drive the continuous batcher instead")
    ap.add_argument("--elastic", action="store_true",
                    help="run elastic aggregation rounds over the "
                         "arch's gradient tree instead of serving")
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--wire", choices=["f32", "fxp32"], default="f32")
    ap.add_argument("--deadline", type=float, default=1.0)
    ap.add_argument("--straggle", action="store_true",
                    help="inject one past-deadline straggler (deferred "
                         "into the next round's residual)")
    args = ap.parse_args()

    import jax
    from repro.configs import get_arch
    from repro.models import model_api
    from repro.serve import ServeEngine, ContinuousBatcher, Request

    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.model
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))

    if args.elastic:
        run_elastic(args, cfg, params)
        return

    max_len = args.max_len or (args.prompt_len + args.max_new + 8)
    eng = ServeEngine(api, params, max_len=max_len, batch=args.batch)

    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = rng.normal(
            0, 1, (args.batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)

    if args.continuous:
        cb = ContinuousBatcher(eng)
        for u in range(args.batch * 2):
            cb.submit(Request(uid=u, prompt=prompts[u % args.batch],
                              max_new_tokens=args.max_new))
        t0 = time.perf_counter()
        done = cb.run(decode_steps=args.max_new * 3)
        dt = time.perf_counter() - t0
        toks = sum(len(c.tokens) for c in done)
        print(f"continuous: {len(done)} requests, {toks} tokens "
              f"in {dt:.2f}s ({toks/dt:.1f} tok/s)")
        return

    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=args.max_new, extra=extra or None)
    dt = time.perf_counter() - t0
    toks = out.size
    print(f"batch generate: {out.shape} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    print("first row:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
