"""Serving launcher: batched greedy generation on a host mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --batch 4 --prompt-len 16 --max-new 32
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="drive the continuous batcher instead")
    args = ap.parse_args()

    import jax
    from repro.configs import get_arch
    from repro.models import model_api
    from repro.serve import ServeEngine, ContinuousBatcher, Request

    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.model
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    max_len = args.max_len or (args.prompt_len + args.max_new + 8)
    eng = ServeEngine(api, params, max_len=max_len, batch=args.batch)

    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = rng.normal(
            0, 1, (args.batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)

    if args.continuous:
        cb = ContinuousBatcher(eng)
        for u in range(args.batch * 2):
            cb.submit(Request(uid=u, prompt=prompts[u % args.batch],
                              max_new_tokens=args.max_new))
        t0 = time.perf_counter()
        done = cb.run(decode_steps=args.max_new * 3)
        dt = time.perf_counter() - t0
        toks = sum(len(c.tokens) for c in done)
        print(f"continuous: {len(done)} requests, {toks} tokens "
              f"in {dt:.2f}s ({toks/dt:.1f} tok/s)")
        return

    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=args.max_new, extra=extra or None)
    dt = time.perf_counter() - t0
    toks = out.size
    print(f"batch generate: {out.shape} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    print("first row:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
