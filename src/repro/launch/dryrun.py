import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # XLA:CPU crashes cloning bf16 all-reduces in its promotion pass, and
    # its LICM hoists the bf16->f32 convert of the *entire* saved
    # activation stack out of the backward loop (f32 copy of all
    # residuals); neither pass runs like this on TPU. See DESIGN.md.
    "--xla_disable_hlo_passes=all-reduce-promotion,"
    "while-loop-invariant-code-motion "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh)
cell with ShapeDtypeStruct placeholders — no real allocation — and record
memory analysis, cost analysis and the collective schedule for the
roofline report.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results land in benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json
(one file per cell, re-runs skip finished cells unless --force).
"""

import argparse
import json
import re
import time
import traceback
from collections import Counter, defaultdict
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_arch, make_batch_struct
from repro.models.registry import model_api
from repro.train.step import (init_train_state, build_train_step,
                              batch_specs)
from repro.serve.steps import (build_prefill_step, build_decode_step,
                               serve_shardings)
from repro.launch.mesh import make_production_mesh

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/artifacts/dryrun")


# ----------------------------------------------------------------------
# Collective-schedule extraction from compiled HLO
# ----------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u64|u32|s16|u16|s8|u8|pred)"
                       r"\[([\d,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1}
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )


def _shape_bytes(type_str: str) -> int:
    """Total bytes of (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Per-op-type operand-byte totals (per-device payloads; SPMD shapes)."""
    per_op = defaultdict(lambda: {"count": 0, "bytes": 0})
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        name, result_type, op = m.group(1), m.group(2), m.group(3)
        if name.endswith(".done") or "-done(" in line:
            continue   # async pair: count the -start only
        # operand bytes: for all-gather the result is n_shards x operand,
        # so use the *operand* side = payload actually contributed.
        # operands appear after the opcode's '('
        paren = line.split("(", 1)[1]
        # operand types are not inline; approximate with result bytes for
        # reduce-like ops and result/n for all-gather via replica_groups
        res_bytes = _shape_bytes(result_type)
        groups = re.search(r"replica_groups=\{\{([^}]*)\}", line)
        gsize = 1
        if groups:
            gsize = len(groups.group(1).split(","))
        else:
            g2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
            if g2:
                gsize = int(g2.group(2))
        if op == "all-gather":
            operand_bytes = res_bytes // max(gsize, 1)
        else:
            operand_bytes = res_bytes
        d = per_op[op]
        d["count"] += 1
        d["bytes"] += operand_bytes
        per_op[op].setdefault("group_sizes", Counter())
        per_op[op]["group_sizes"][gsize] += 1
    out = {}
    for op, d in per_op.items():
        out[op] = {"count": d["count"], "bytes": d["bytes"],
                   "group_sizes": dict(d.get("group_sizes", {}))}
    return out


# ----------------------------------------------------------------------
# Cell builders
# ----------------------------------------------------------------------

def lower_cell(arch_name: str, shape_name: str, mesh, train_override=None):
    """Returns jax Lowered for one cell."""
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    cfg = arch.model
    api = model_api(cfg)
    tc = train_override or arch.train
    prof = tc.sharding

    if shape.kind == "train":
        state_struct = jax.eval_shape(
            lambda: init_train_state(api, tc, mesh, jax.random.PRNGKey(0)))
        make = build_train_step(api, tc, mesh)
        step_fn, specs = make(state_struct)
        batch_struct = make_batch_struct(cfg, shape.global_batch,
                                         shape.seq_len)
        _, bnamed = batch_specs(batch_struct, mesh, tc)
        jitted = jax.jit(step_fn, in_shardings=(specs["named"], bnamed),
                         out_shardings=(specs["named"], None),
                         donate_argnums=(0,))
        return jitted.lower(state_struct, batch_struct)

    sh = serve_shardings(api, prof, mesh, shape.global_batch, shape.seq_len)
    if shape.kind == "prefill":
        fn = build_prefill_step(api, prof, mesh, shape.seq_len)
        batch_struct = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)}
        bshard: Dict[str, Any] = {"tokens": sh["batch"]}
        if cfg.family == "encdec":
            batch_struct["frames"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.enc_seq, cfg.d_model), jnp.float32)
            bshard["frames"] = sh["batch"]
        if cfg.family == "vlm":
            batch_struct["vis_embed"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.vis_tokens, cfg.d_model),
                jnp.float32)
            bshard["vis_embed"] = sh["batch"]
        jitted = jax.jit(fn, in_shardings=(sh["params"], bshard))
        return jitted.lower(sh["params_struct"], batch_struct)

    # decode
    fn = build_decode_step(api, prof, mesh)
    token_struct = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(fn, in_shardings=(sh["params"], sh["batch"],
                                       sh["cache"], None),
                     out_shardings=(None, sh["cache"]),
                     donate_argnums=(2,))
    return jitted.lower(sh["params_struct"], token_struct,
                        sh["cache_struct"], pos_struct)


def run_cell(arch_name: str, shape_name: str, mesh_name: str,
             force: bool = False, train_override=None) -> Dict[str, Any]:
    mesh_dir = os.path.join(ARTIFACT_DIR, mesh_name)
    os.makedirs(mesh_dir, exist_ok=True)
    out_path = os.path.join(mesh_dir, f"{arch_name}__{shape_name}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            prev = json.load(f)
        if prev.get("status") in ("ok", "skip"):
            return prev           # errored cells are always retried

    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "params_total": arch.model.param_count(),
        "params_active": arch.model.active_param_count(),
        "aggregator": arch.train.aggregator,
    }
    ok, why = arch.shape_supported(shape)
    if not ok:
        rec["status"] = "skip"
        rec["reason"] = why
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    try:
        t0 = time.time()
        lowered = lower_cell(arch_name, shape_name, mesh, train_override)
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gib": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes)
                / 2**30, 3),
        }
        cost = compiled.cost_analysis()
        rec["cost"] = {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
        }
        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo)
        # persist compiled HLO for the roofline extractor (trip-count
        # corrected FLOPs/collectives — cost_analysis counts while bodies
        # once, so scanned layers would be undercounted by L x)
        import gzip
        hlo_path = out_path.replace(".json", ".hlo.gz")
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)
        rec["hlo"] = os.path.basename(hlo_path)
        rec["status"] = "ok"
    except Exception as e:                              # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if not args.all and not args.arch and not args.shape:
        ap.error("pass --arch/--shape or --all")

    for mesh_name in meshes:
        for arch in archs:
            for shape in shapes:
                t0 = time.time()
                rec = run_cell(arch, shape, mesh_name, force=args.force)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f"compile {rec.get('compile_s', '?')}s "
                             f"mem {rec['memory']['peak_per_device_gib']}GiB "
                             f"flops {rec['cost']['flops']:.2e}")
                elif status == "error":
                    extra = rec["error"][:120]
                print(f"[{mesh_name}] {arch:18s} {shape:12s} {status:5s} "
                      f"({time.time()-t0:.1f}s) {extra}", flush=True)


if __name__ == "__main__":
    main()
