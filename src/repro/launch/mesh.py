"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before the
first jax initialisation.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod ("data", "model"); 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1, axis_names=("data", "model")):
    """Mesh over whatever devices this host actually has (tests, examples)."""
    n = len(jax.devices())
    data = max(1, n // model_parallel)
    return make_mesh((data, model_parallel), axis_names)
