"""Round membership + the versioned wire contract (PR 9).

The elastic tier aggregates payloads from an *open* population of
clients — joining and leaving between rounds — instead of a fixed mesh
of W SPMD ranks. That breaks the one assumption every fixed-mesh wire
bakes in at trace time: the fxp32 mantissa budget is W-dependent
(``FixedPointWire.mantissa_bits = 30 - ceil_log2(W)``), so a payload
quantized for a 4-client round is *numerically wrong* in a 5-client
round — the decode scale is off by an exact power of two, and worse,
the int32 overflow-freedom proof no longer holds.

:class:`RoundContract` is therefore the versioned handshake: one frozen
record per round carrying the cohort, the bucket geometry, the wire
dtype and the fxp32 mantissa budget. Every payload quotes the
``contract_id`` it was encoded under, and the fold engine refuses
(:class:`StaleContractError`) anything quoting a different contract —
stale payloads are *rejected or re-encoded, never silently folded*.

:class:`Membership` owns the roster and renegotiates the contract at
every round open; the renegotiation goes through
:meth:`repro.net.fixedpoint.FixedPointWire.with_workers` so the mantissa
budget always tracks the live cohort size. ``local_mesh`` is the
device-side sizing hook: when the cohort is emulated on local devices,
it sizes the data axis through :func:`repro.ft.failures.elastic_mesh`.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.bucketing import BucketPlan
from repro.core.config import CompressionConfig
from repro.net.fixedpoint import FixedPointWire


class StaleContractError(RuntimeError):
    """A payload (or proposal) quotes a contract other than the open
    round's — the sender must re-encode under the current contract."""


@dataclasses.dataclass(frozen=True)
class RoundContract:
    """The per-round wire handshake (frozen, hashable).

    ``mantissa_bits`` is *derived state*: it must equal the
    ``FixedPointWire`` budget for ``len(cohort)`` workers (validated at
    construction) — it is carried explicitly so the contract id, which
    every payload quotes, changes whenever a membership change crosses
    a power-of-two boundary and re-prices the wire.
    """

    round_id: int
    cohort: Tuple[int, ...]          # sorted, unique client ids
    n_buckets: int
    bucket_elems: int
    total_elems: int                 # true stream elems (pre-padding)
    wire_dtype: str                  # "f32" | "fxp32"
    mantissa_bits: Optional[int]     # fxp32 only; None on f32

    def __post_init__(self):
        if not self.cohort:
            raise ValueError("a round needs a non-empty cohort")
        if tuple(sorted(set(self.cohort))) != self.cohort:
            raise ValueError(
                f"cohort must be sorted and unique, got {self.cohort}")
        if self.wire_dtype not in ("f32", "fxp32"):
            raise ValueError(f"unknown wire_dtype {self.wire_dtype!r}")
        if self.wire_dtype == "fxp32":
            want = FixedPointWire(workers=len(self.cohort)).mantissa_bits
            if self.mantissa_bits != want:
                raise ValueError(
                    f"mantissa_bits={self.mantissa_bits} does not match "
                    f"the FixedPointWire budget for W={len(self.cohort)} "
                    f"({want}) — renegotiate via negotiate_contract()")
        elif self.mantissa_bits is not None:
            raise ValueError("f32 wire carries no mantissa budget")

    @property
    def workers(self) -> int:
        return len(self.cohort)

    @property
    def wire(self) -> FixedPointWire:
        """The fxp32 codec this round's payloads quantize through."""
        if self.wire_dtype != "fxp32":
            raise ValueError("the f32 wire has no fixed-point codec")
        return FixedPointWire(workers=self.workers)

    @property
    def contract_id(self) -> str:
        """Stable fingerprint every payload quotes (process-independent:
        no salted ``hash()``). Round id + cohort size + wire pricing +
        bucket geometry — everything the fold must agree on."""
        m = "-" if self.mantissa_bits is None else str(self.mantissa_bits)
        return (f"r{self.round_id}:W{self.workers}:{self.wire_dtype}:"
                f"m{m}:{self.n_buckets}x{self.bucket_elems}"
                f"/{self.total_elems}")


def negotiate_contract(round_id: int, cohort, plan: BucketPlan,
                       cfg: CompressionConfig) -> RoundContract:
    """Build the round contract for the live cohort.

    The fxp32 budget is renegotiated through ``with_workers`` — the
    single renegotiation seam — so a cohort-size change that crosses a
    power-of-two boundary re-prices ``mantissa_bits`` here and nowhere
    else.
    """
    cohort = tuple(sorted(set(int(c) for c in cohort)))
    mant = None
    if cfg.wire_dtype == "fxp32":
        mant = FixedPointWire(workers=1).with_workers(
            len(cohort)).mantissa_bits
    return RoundContract(
        round_id=int(round_id), cohort=cohort, n_buckets=plan.n_buckets,
        bucket_elems=plan.bucket_elems, total_elems=plan.total,
        wire_dtype=cfg.wire_dtype, mantissa_bits=mant)


@dataclasses.dataclass(frozen=True)
class ExponentProposal:
    """Phase A of an fxp32 round: one client's per-bucket exponents
    (from its local sketch maxima). Max-folds homomorphically — the
    server may fold proposals in any arrival order."""

    client: int
    contract_id: str
    exponents: np.ndarray            # (n_buckets,) int32


@dataclasses.dataclass(frozen=True)
class ClientPayload:
    """One client's wire payload for one round.

    ``exponents`` (fxp32 only) are the *sealed shared* exponents the
    sketch was quantized against — the fold engine verifies they match
    the round's sealed vector bit-for-bit before integer-summing.
    """

    client: int
    contract_id: str
    sketch: np.ndarray               # (n_blocks, rows, lanes) f32|int32
    index_words: np.ndarray          # (padded // 32,) uint32
    exponents: Optional[np.ndarray] = None   # (n_buckets,) int32

    @property
    def nbytes(self) -> int:
        n = self.sketch.nbytes + self.index_words.nbytes
        if self.exponents is not None:
            n += self.exponents.nbytes
        return n


class Membership:
    """Explicit client roster with per-round contract renegotiation.

    Joins/leaves take effect at the next :meth:`contract` call (round
    open) — mid-round membership is frozen by the contract itself.
    ``max_cohort`` bounds the roster; surplus joiners queue in arrival
    order and are admitted as roster slots free up (the
    ``ContinuousBatcher`` admission shape, applied to clients).
    """

    def __init__(self, max_cohort: Optional[int] = None):
        if max_cohort is not None and max_cohort < 1:
            raise ValueError(f"max_cohort must be >= 1, got {max_cohort}")
        self.max_cohort = max_cohort
        self._roster: set = set()
        self._queue: List[int] = []

    # ---- roster ------------------------------------------------------

    @property
    def roster(self) -> Tuple[int, ...]:
        return tuple(sorted(self._roster))

    @property
    def queued(self) -> Tuple[int, ...]:
        return tuple(self._queue)

    def join(self, client: int) -> str:
        """Returns ``"admitted"`` or ``"queued"`` (roster full)."""
        client = int(client)
        if client in self._roster or client in self._queue:
            raise ValueError(f"client {client} already joined")
        if self.max_cohort is not None and \
                len(self._roster) >= self.max_cohort:
            self._queue.append(client)
            return "queued"
        self._roster.add(client)
        return "admitted"

    def leave(self, client: int) -> None:
        client = int(client)
        if client in self._roster:
            self._roster.discard(client)
        elif client in self._queue:
            self._queue.remove(client)
        else:
            raise KeyError(f"client {client} is not a member")

    def admit_queued(self) -> Tuple[int, ...]:
        """Fill freed roster slots from the queue (called at round
        open); returns the newly admitted clients."""
        admitted = []
        while self._queue and (self.max_cohort is None or
                               len(self._roster) < self.max_cohort):
            c = self._queue.pop(0)
            self._roster.add(c)
            admitted.append(c)
        return tuple(admitted)

    # ---- per-round renegotiation ------------------------------------

    def contract(self, round_id: int, plan: BucketPlan,
                 cfg: CompressionConfig) -> RoundContract:
        if not self._roster:
            raise ValueError("cannot open a round with an empty roster")
        return negotiate_contract(round_id, self._roster, plan, cfg)

    # ---- device-side sizing hook ------------------------------------

    def local_mesh(self, model_parallel: int = 1,
                   axis_names=("data", "model")):
        """Size a local device mesh for this cohort.

        When the elastic cohort is emulated on (or spills onto) local
        devices, the data axis must fit both the device pool and the
        cohort: :func:`repro.ft.failures.elastic_mesh` shrinks it to the
        largest power of two that divides evenly — the same policy the
        failure-recovery path uses, now driven by membership.
        """
        import jax
        from repro.ft.failures import elastic_mesh
        if not self._roster:
            raise ValueError("cannot size a mesh for an empty roster")
        avail = min(len(jax.devices()),
                    len(self._roster) * model_parallel)
        return elastic_mesh(avail, model_parallel, axis_names)
