"""Elastic aggregation server: round orchestration over the async fold.

The service loop the dormant serve scaffolding
(:class:`repro.serve.engine.ContinuousBatcher`) models for token
requests, applied to gradient payloads:

- **Admission** — a bounded roster (:class:`AdmissionPolicy.max_cohort`)
  with a join queue drained at round open, exactly the slot-pool
  admission shape of the continuous batcher.
- **Round open** — membership changes take effect here: the contract is
  renegotiated (new cohort, new fxp32 mantissa budget) and published.
- **Submit** — payloads fold incrementally as they arrive (the
  :class:`repro.elastic.fold.FoldEngine`), with straggler
  timeout/retransmit accounting through
  :class:`repro.ft.failures.SwitchRetransmitPolicy` and arrival-latency
  outlier detection through
  :class:`repro.ft.failures.StragglerMonitor`.
- **Close-out** — at full attendance, or at the deadline with quorum.
  Late payloads (past the deadline, or past the retransmit budget) are
  **deferred, not dropped**: they are decoded individually under their
  own (still-current) contract and carried into the *next* round's
  output as a server-side error-feedback residual — so the accounting
  stays loss-free across membership changes (the deferred contribution
  re-enters even though the next round's contract may price the wire
  differently).

All times are caller-supplied simulated seconds relative to the round
open — the server is deterministic and event-driven, which is what lets
the tests and benchmarks replay arrival schedules exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.core.bucketing import BucketPlan, make_bucket_plan
from repro.core.config import CompressionConfig
from repro.ft.failures import (StragglerMonitor, SwitchRetransmitPolicy,
                               SwitchStragglerTimeout)

from .fold import FoldEngine, FoldState
from .membership import (ClientPayload, ExponentProposal, Membership,
                         RoundContract, StaleContractError)
from .shard import ShardedFoldService


class QuorumNotReached(RuntimeError):
    """close_round() before quorum folded (and no deadline override)."""


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Roster bound + close-out rule."""

    max_cohort: int = 1024
    quorum: float = 0.5              # fraction of the cohort that must
                                     # fold before a deadline close
    deadline_s: float = 1.0          # round close-out deadline (seconds
                                     # from round open)

    def __post_init__(self):
        if self.max_cohort < 1:
            raise ValueError(f"max_cohort must be >= 1, got "
                             f"{self.max_cohort}")
        if not (0.0 < self.quorum <= 1.0):
            raise ValueError(f"quorum must be in (0, 1], got "
                             f"{self.quorum}")
        if self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got "
                             f"{self.deadline_s}")

    def quorum_count(self, workers: int) -> int:
        return max(1, int(np.ceil(self.quorum * workers)))


@dataclasses.dataclass
class RoundReport:
    """Per-round close-out accounting."""

    round_id: int
    contract_id: str
    workers: int
    folded: int
    deferred: int
    rejected_stale: int
    retransmits: int
    close_reason: str                # complete | deadline | quorum
    rx_bytes_total: int
    residual_carried_in: bool        # previous rounds' late payloads
                                     # were added to this output
    windows: int
    occupancy_peak: int
    straggler_events: int


class ElasticServer:
    """Round-orchestrating aggregation service over the async fold."""

    def __init__(self, template: Any, cfg: CompressionConfig,
                 policy: Optional[AdmissionPolicy] = None,
                 retransmit: Optional[SwitchRetransmitPolicy] = None,
                 monitor: Optional[StragglerMonitor] = None,
                 window_slots: Optional[int] = None,
                 n_shards: int = 1, batch_size: int = 1):
        self.cfg = cfg
        self.plan: BucketPlan = make_bucket_plan(template, cfg)
        self.policy = policy or AdmissionPolicy()
        self.retransmit = retransmit
        self.monitor = monitor
        self.window_slots = window_slots
        # PR 10 scale-out: with either knob above 1 every round runs
        # through the ShardedFoldService (same fold surface, identical
        # close-out semantics — the PR 10 pins hold bit-for-bit)
        if n_shards < 1 or batch_size < 1:
            raise ValueError(
                f"n_shards/batch_size must be >= 1, got "
                f"{n_shards}/{batch_size}")
        self.n_shards = int(n_shards)
        self.batch_size = int(batch_size)
        self.membership = Membership(max_cohort=self.policy.max_cohort)
        self.reports: List[RoundReport] = []
        self._round_id = 0
        self._contract: Optional[RoundContract] = None
        self._engine: Optional[FoldEngine] = None
        self._state: Optional[FoldState] = None
        self._deferred: List[ClientPayload] = []
        self._rejected_stale = 0
        # server-side EF residual: deferred late payloads land here and
        # ride the NEXT round's output (never dropped)
        self._residual = np.zeros(
            (self.plan.n_buckets, self.plan.bucket_elems), np.float32)
        self._residual_pending = False

    # ---- membership ---------------------------------------------------

    def join(self, client: int) -> str:
        return self.membership.join(client)

    def leave(self, client: int) -> None:
        self.membership.leave(client)

    # ---- round lifecycle ---------------------------------------------

    @property
    def contract(self) -> Optional[RoundContract]:
        return self._contract

    def open_round(self) -> RoundContract:
        if self._contract is not None:
            raise RuntimeError(
                f"round {self._contract.round_id} is still open")
        self.membership.admit_queued()
        self._contract = self.membership.contract(
            self._round_id, self.plan, self.cfg)
        if self.n_shards > 1 or self.batch_size > 1:
            self._engine = ShardedFoldService(
                self._contract, self.cfg, n_shards=self.n_shards,
                batch_size=self.batch_size,
                window_slots=self.window_slots, plan=self.plan)
        else:
            self._engine = FoldEngine(self._contract, self.cfg,
                                      window_slots=self.window_slots)
        self._state = self._engine.init_state()
        self._deferred = []
        self._rejected_stale = 0
        return self._contract

    def _require_open(self) -> None:
        if self._contract is None:
            raise RuntimeError("no round is open")

    def submit_exponents(self, proposal: ExponentProposal) -> None:
        """Phase A (fxp32): max-fold one exponent proposal."""
        self._require_open()
        self._engine.propose_exponents(
            self._state, proposal.client, proposal.exponents,
            contract_id=proposal.contract_id)

    def seal_exponents(self) -> np.ndarray:
        """Freeze + publish the shared exponents for this round."""
        self._require_open()
        return self._engine.seal_exponents(self._state)

    def submit(self, payload: ClientPayload,
               arrival_s: float = 0.0) -> str:
        """Fold one arriving payload; returns ``"folded"`` or
        ``"deferred"`` (past the deadline or past the retransmit
        budget — carried into the next round's residual).

        A payload quoting a stale contract raises
        :class:`StaleContractError` — the client must ``reencode()``
        and resubmit; it is never silently folded OR silently deferred
        (a stale payload cannot even be decoded under this round's
        budget).
        """
        self._require_open()
        if payload.contract_id != self._contract.contract_id:
            self._rejected_stale += 1
            raise StaleContractError(
                f"payload quotes {payload.contract_id}, round is "
                f"{self._contract.contract_id} — re-encode under the "
                "current contract")
        if self.monitor is not None:
            self.monitor.observe(self._round_id, float(arrival_s))
        if arrival_s > self.policy.deadline_s:
            self._deferred.append(payload)
            return "deferred"
        try:
            self._engine.fold(self._state, payload,
                              arrival_s=float(arrival_s),
                              policy=self.retransmit)
        except SwitchStragglerTimeout:
            self._deferred.append(payload)
            return "deferred"
        return "folded"

    def close_round(self, now_s: Optional[float] = None
                    ) -> Tuple[np.ndarray, RoundReport]:
        """Close the round; returns ``(sum_stream, report)`` where
        ``sum_stream`` is the recovered ``(n_buckets, bucket_elems)``
        f32 *sum* over contributions (callers divide by
        ``contract.workers`` for the mean), including any residual
        carried from previous rounds' deferred payloads.

        Close is allowed at full attendance, or once ``now_s`` reaches
        the deadline with quorum folded; otherwise
        :class:`QuorumNotReached`.
        """
        self._require_open()
        c, st = self._contract, self._state
        folded = st.contributions
        quorum = self.policy.quorum_count(c.workers)
        if folded == c.workers:
            reason = "complete"
        elif folded >= quorum and now_s is not None and \
                now_s >= self.policy.deadline_s:
            reason = "deadline"
        elif folded >= quorum and folded + len(self._deferred) == \
                c.workers:
            # every cohort member is accounted for (folded or deferred):
            # nothing left to wait on, close without burning the deadline
            reason = "quorum"
        else:
            raise QuorumNotReached(
                f"round {c.round_id}: {folded}/{c.workers} folded, "
                f"quorum is {quorum} (pass now_s >= deadline_s to close "
                "at quorum)")

        out = self._engine.finalize(st)
        carried = self._residual_pending
        if carried:
            out = out + self._residual
        # this round's late payloads become the NEXT round's residual
        self._residual = np.zeros_like(self._residual)
        self._residual_pending = bool(self._deferred)
        for p in self._deferred:
            self._residual += self._engine.decode_payload(p)

        report = RoundReport(
            round_id=c.round_id, contract_id=c.contract_id,
            workers=c.workers, folded=folded,
            deferred=len(self._deferred),
            rejected_stale=self._rejected_stale,
            retransmits=st.retransmits, close_reason=reason,
            rx_bytes_total=sum(st.rx_bytes.values()),
            residual_carried_in=carried, windows=st.windows,
            occupancy_peak=st.occupancy_peak,
            straggler_events=(len(self.monitor.events)
                              if self.monitor is not None else 0))
        self.reports.append(report)
        self._round_id += 1
        self._contract = None
        self._engine = None
        self._state = None
        self._deferred = []
        return out, report

    @property
    def pending_residual(self) -> np.ndarray:
        """The deferred-contribution stream that will ride the next
        round's output (zeros when nothing is pending) — exposed so
        loss-free accounting is assertable from outside."""
        return self._residual.copy()
