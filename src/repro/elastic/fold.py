"""Asynchronous sketch-fold engine (PR 9).

The paper's wire format is homomorphic — sketches merge by addition,
bitmaps by OR — so an aggregation point can *fold* payloads one at a
time, as they arrive, without barriering on the cohort and without ever
decompressing. This module is that fold:

- :meth:`FoldEngine.fold` is incremental: sketch add + bitmap OR +
  contribution counter. The aggregation state is **O(1) in the cohort
  size** — one payload-shaped accumulator per round, whether 8 clients
  contribute or 8000 (per-client RX byte counters are telemetry, not
  aggregation state).
- Streaming-window mode: each fold runs through a
  :class:`repro.net.switch.SwitchModel` slot pool (fxp32) or an
  equivalent windowed loop (f32), so at most ``window_slots`` bucket
  chunks are in flight at once — the switch SRAM bound is the
  backpressure model — with the switch's running-partial int32
  overflow check live on every fxp32 window.
- :meth:`FoldEngine.finalize` recovers the folded stream through the
  existing one-consumer contract: a single
  ``HomomorphicCompressor.recover`` call, with the fxp32 dequant folded
  into the fused consumer pass (``dequant=(exponents, mantissa_bits)``).

fxp32 rounds are two-phase, mirroring the in-mesh ``pmax`` → encode
order of the ``compressed_innet`` strategy: clients first propose
per-bucket exponents (max-folds — order-free), the server seals the
elementwise max, and only then do clients quantize and ship int32
sketches. The folded integers therefore equal
``FixedPointWire.roundtrip_reference`` bit-for-bit for any arrival
order — integer adds are exact in every association order.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Set

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.blocks import make_plan
from repro.core.compressor import CompressedLeaf, HomomorphicCompressor
from repro.core.config import CompressionConfig
from repro.ft.failures import SwitchRetransmitPolicy
from repro.net.switch import SwitchModel

from .membership import ClientPayload, RoundContract, StaleContractError


class FoldError(RuntimeError):
    """A payload that can never be folded into this round (duplicate
    client, unknown client, oversubscribed cohort, wrong geometry)."""


@functools.lru_cache(maxsize=128)
def _recover_fn(cfg: CompressionConfig, padded: int, wire_dtype: str,
                mantissa_bits: Optional[int]):
    """The round's jit-compiled recover pass, cached by contract
    geometry — ``(padded = n_buckets * bucket_elems, wire dtype, fxp32
    mantissa budget)`` plus the full compression config.

    Engines used to hold a per-instance ``jax.jit`` closure, which
    silently *retraced* the fused consumer every round (each
    ``open_round`` builds a fresh engine); worse, had an engine been
    reused across renegotiated geometries it would have *hit* a
    stale-shaped compiled fn. Keying the cache by geometry gives
    consecutive same-geometry rounds (and every shard of a sharded
    round with equal bucket counts) one shared compiled fn, while a
    renegotiated geometry — or a repriced fxp32 mantissa budget, which
    changes the dequant scale — gets its own entry. ``block_offset`` is
    a *traced* argument, so shards peeling at different global block
    offsets share one compiled fn too.
    """
    comp = HomomorphicCompressor(cfg)
    if wire_dtype == "fxp32":
        @jax.jit
        def rec(sk, wd, exps, block_offset):
            return comp.recover(
                CompressedLeaf(sketch=sk, index_words=wd), padded,
                block_offset=block_offset,
                dequant=(exps, mantissa_bits))
    else:
        @jax.jit
        def rec(sk, wd, block_offset):
            return comp.recover(
                CompressedLeaf(sketch=sk, index_words=wd), padded,
                block_offset=block_offset)
    return rec


@dataclasses.dataclass
class FoldState:
    """One round's aggregation state.

    ``sketch`` / ``index_words`` / ``exponents`` are payload-shaped —
    O(1) in the cohort size. ``clients`` / ``rx_bytes`` are per-client
    *telemetry* (who contributed, what the wire carried), not inputs to
    the aggregate.
    """

    contract: RoundContract
    sketch: np.ndarray               # (n_blocks, rows, lanes) f32|int32
    index_words: np.ndarray          # (n_buckets, words_per_bucket) u32
    exponents: Optional[np.ndarray]  # sealed shared exps (fxp32)
    exp_acc: Optional[np.ndarray]    # running max during phase A
    exp_clients: Set[int] = dataclasses.field(default_factory=set)
    contributions: int = 0
    clients: Set[int] = dataclasses.field(default_factory=set)
    rx_bytes: Dict[int, int] = dataclasses.field(default_factory=dict)
    retransmits: int = 0
    windows: int = 0
    occupancy_peak: int = 0


class FoldEngine:
    """Per-round async fold over one :class:`RoundContract`."""

    def __init__(self, contract: RoundContract, cfg: CompressionConfig,
                 window_slots: Optional[int] = None,
                 block_offset: int = 0):
        if cfg.wire_dtype != contract.wire_dtype:
            raise ValueError(
                f"config wire_dtype {cfg.wire_dtype!r} != contract "
                f"{contract.wire_dtype!r}")
        if contract.bucket_elems % cfg.block_elems:
            raise ValueError(
                f"bucket_elems {contract.bucket_elems} is not a whole "
                f"number of sketch blocks ({cfg.block_elems})")
        self.contract = contract
        self.cfg = cfg
        self.comp = HomomorphicCompressor(cfg)
        self.window_slots = int(window_slots or cfg.switch_slots)
        if self.window_slots < 1:
            raise ValueError(
                f"window_slots must be >= 1, got {self.window_slots}")
        # static stream geometry, shared with every client's compressor
        self.padded = contract.n_buckets * contract.bucket_elems
        splan = make_plan(self.padded, cfg)
        self.blocks_per_bucket = contract.bucket_elems // cfg.block_elems
        self.n_blocks = splan.nb
        self.sketch_shape = (splan.nb, cfg.rows, cfg.lanes)
        self.words_per_bucket = contract.bucket_elems // 32
        self.n_words = self.padded // 32
        self.fxp32 = contract.wire_dtype == "fxp32"
        # the slot pool: 2 ports (resident accumulator + the arriving
        # payload), window_slots resident bucket chunks — the switch's
        # windowing, occupancy accounting and running-partial int32
        # register check all apply to every incremental fxp32 fold
        self._switch = SwitchModel(ports=2, slots=self.window_slots) \
            if self.fxp32 else None
        # ``block_offset``: hash-plan id of this engine's first sketch
        # block. 0 for a full-range engine; a sharded round's per-shard
        # engines peel their bucket range at its global block position
        # (the same offset rule the PR 3/5 per-chunk peels use).
        self.block_offset = int(block_offset)
        # the recover pass is cached by contract geometry (see
        # _recover_fn): every finalize/decode of every same-geometry
        # round hits one compiled fn — recover called eagerly
        # re-dispatches its fused consumer every time, which dominates
        # the round close-out tail
        self._recover_jit = _recover_fn(
            cfg, self.padded, contract.wire_dtype, contract.mantissa_bits)

    # ------------------------------------------------------------------

    def init_state(self) -> FoldState:
        dt = np.int32 if self.fxp32 else np.float32
        return FoldState(
            contract=self.contract,
            sketch=np.zeros(self.sketch_shape, dt),
            index_words=np.zeros(
                (self.contract.n_buckets, self.words_per_bucket),
                np.uint32),
            exponents=None,
            exp_acc=None)

    # ---- phase A (fxp32): exponent negotiation -----------------------

    def propose_exponents(self, state: FoldState, client: int,
                          exponents: np.ndarray,
                          contract_id: Optional[str] = None) -> None:
        """Max-fold one client's per-bucket exponent proposal.

        Homomorphic like the sketch itself (max is associative and
        commutative), so proposals fold in any arrival order.
        """
        if not self.fxp32:
            raise FoldError("the f32 wire negotiates no exponents")
        if contract_id is not None and \
                contract_id != self.contract.contract_id:
            raise StaleContractError(
                f"proposal quotes {contract_id}, round is "
                f"{self.contract.contract_id}")
        client = int(client)
        if client not in self.contract.cohort:
            raise FoldError(
                f"client {client} is not in this round's cohort")
        if client in state.exp_clients:
            raise FoldError(f"client {client} already proposed exponents")
        if state.exponents is not None:
            raise FoldError("exponents already sealed for this round")
        e = np.asarray(exponents)
        if e.shape != (self.contract.n_buckets,) or e.dtype != np.int32:
            raise FoldError(
                f"exponent proposal must be ({self.contract.n_buckets},) "
                f"int32, got {e.shape} {e.dtype}")
        state.exp_acc = e.copy() if state.exp_acc is None \
            else np.maximum(state.exp_acc, e)
        state.exp_clients.add(client)

    def seal_exponents(self, state: FoldState) -> np.ndarray:
        """Freeze the shared exponents (elementwise max of proposals);
        every payload must be quantized against exactly this vector."""
        if not self.fxp32:
            raise FoldError("the f32 wire negotiates no exponents")
        if state.exp_acc is None:
            raise FoldError("no exponent proposals to seal")
        if state.exponents is None:
            state.exponents = state.exp_acc.copy()
        return state.exponents

    # ---- phase B: the fold -------------------------------------------

    def fold(self, state: FoldState, payload: ClientPayload,
             arrival_s: float = 0.0,
             policy: Optional[SwitchRetransmitPolicy] = None) -> int:
        """Fold one payload into the round: sketch add + bitmap OR +
        contribution counter. Returns the retransmit count the arrival
        cost under ``policy`` (0 without one).

        Raises :class:`StaleContractError` for a payload quoting another
        contract (or, on fxp32, quantized against non-sealed exponents)
        and :class:`repro.ft.failures.SwitchStragglerTimeout` — state
        untouched — when the arrival delay blows the retransmit budget.
        """
        if payload.contract_id != self.contract.contract_id:
            raise StaleContractError(
                f"payload quotes {payload.contract_id}, round is "
                f"{self.contract.contract_id} — re-encode under the "
                "current contract")
        client = int(payload.client)
        if client not in self.contract.cohort:
            raise FoldError(
                f"client {client} is not in this round's cohort")
        if client in state.clients:
            raise FoldError(
                f"client {client} already contributed this round")
        if state.contributions >= self.contract.workers:
            raise FoldError(
                f"{state.contributions} payloads already folded on a "
                f"wire sized for {self.contract.workers} workers "
                "(overflow bound would not hold)")
        sk = np.asarray(payload.sketch)
        wd = np.asarray(payload.index_words)
        want_dt = np.int32 if self.fxp32 else np.float32
        if sk.shape != self.sketch_shape or sk.dtype != want_dt:
            raise FoldError(
                f"sketch must be {self.sketch_shape} "
                f"{np.dtype(want_dt).name}, got {sk.shape} {sk.dtype}")
        if wd.shape != (self.n_words,) or wd.dtype != np.uint32:
            raise FoldError(
                f"index_words must be ({self.n_words},) uint32, got "
                f"{wd.shape} {wd.dtype}")
        if self.fxp32:
            if state.exponents is None:
                raise StaleContractError(
                    "fxp32 payload before the shared exponents were "
                    "sealed — nothing to verify the quantization against")
            if payload.exponents is None or not np.array_equal(
                    np.asarray(payload.exponents), state.exponents):
                raise StaleContractError(
                    f"client {client}'s payload was quantized against "
                    "exponents that are not this round's sealed vector "
                    "— re-encode")

        nb = self.contract.n_buckets
        # per-bucket chunks: the streaming unit of the slot pool
        sk_b = sk.reshape(nb, -1)
        wd_b = wd.reshape(nb, self.words_per_bucket)
        acc_sk = state.sketch.reshape(nb, -1)
        acc_wd = state.index_words

        # straggler accounting first (state must stay untouched when the
        # arrival blows the budget): the client is uniformly late, so
        # every window of its payload pays the same delay
        retries = 0
        rx = payload.nbytes
        if policy is not None and arrival_s > 0:
            cohort_port = self.contract.cohort.index(client)
            row_bytes = sk_b[0].nbytes + wd_b[0].nbytes
            for w, w0 in enumerate(range(0, nb, self.window_slots)):
                w1 = min(w0 + self.window_slots, nb)
                r = policy.on_window(state.windows + w, cohort_port,
                                     float(arrival_s),
                                     (w1 - w0) * row_bytes)
                retries += r
                rx += r * (w1 - w0) * row_bytes

        if self.fxp32:
            self._switch.reset()
            out_sk, out_wd = self._switch.aggregate(
                np.stack([acc_sk, sk_b]), np.stack([acc_wd, wd_b]))
            state.sketch = out_sk.reshape(self.sketch_shape)
            state.index_words = out_wd
            rep = self._switch.report()
            state.windows += rep["windows"]
            state.occupancy_peak = max(state.occupancy_peak,
                                       rep["occupancy_peak"])
        else:
            # idealized float tier: same windowed slot-pool walk, plain
            # f32 adds (a real switch can't — see net/fixedpoint.py)
            for w0 in range(0, nb, self.window_slots):
                w1 = min(w0 + self.window_slots, nb)
                acc_sk[w0:w1] += sk_b[w0:w1]
                acc_wd[w0:w1] |= wd_b[w0:w1]
                state.windows += 1
                state.occupancy_peak = max(state.occupancy_peak, w1 - w0)

        state.contributions += 1
        state.clients.add(client)
        state.rx_bytes[client] = state.rx_bytes.get(client, 0) + rx
        state.retransmits += retries
        return retries

    # ---- recovery ----------------------------------------------------

    def finalize(self, state: FoldState) -> np.ndarray:
        """Recover the folded *sum* stream: ONE consumer call
        (``HomomorphicCompressor.recover``), fxp32 dequant folded in via
        ``dequant=(per_block_exponents, mantissa_bits)``. Returns
        ``(n_buckets, bucket_elems)`` f32."""
        if state.contributions == 0:
            raise FoldError("nothing folded — cannot finalize")
        sk = jnp.asarray(state.sketch)
        wd = jnp.asarray(state.index_words.reshape(-1))
        if self.fxp32:
            if state.exponents is None:
                raise FoldError("fxp32 round closed without sealed "
                                "exponents")
            rec = self._recover_jit(
                sk, wd,
                jnp.asarray(np.repeat(state.exponents,
                                      self.blocks_per_bucket)),
                jnp.int32(self.block_offset))
        else:
            rec = self._recover_jit(sk, wd, jnp.int32(self.block_offset))
        return np.asarray(rec).reshape(self.contract.n_buckets,
                                       self.contract.bucket_elems)

    def decode_payload(self, payload: ClientPayload) -> np.ndarray:
        """Recover ONE payload on its own (used for late arrivals that
        missed the round: their contribution is decoded and carried into
        the next round's residual rather than dropped). The payload's
        own sealed exponents make the single-payload dequant exact to
        the documented roundtrip."""
        sk = jnp.asarray(np.asarray(payload.sketch))
        wd = jnp.asarray(np.asarray(payload.index_words).reshape(-1))
        if self.fxp32:
            if payload.exponents is None:
                raise FoldError("fxp32 payload without exponents")
            rec = self._recover_jit(
                sk, wd,
                jnp.asarray(np.repeat(np.asarray(payload.exponents),
                                      self.blocks_per_bucket)),
                jnp.int32(self.block_offset))
        else:
            rec = self._recover_jit(sk, wd, jnp.int32(self.block_offset))
        return np.asarray(rec).reshape(self.contract.n_buckets,
                                       self.contract.bucket_elems)
