"""Sharded, batched fold pipeline (PR 10) — parameter-server-style
scale-out for the elastic aggregation service.

PR 9's :class:`~repro.elastic.fold.FoldEngine` folds one payload at a
time through a sequential per-bucket slot-pool walk: every arrival
costs a full pass over the bucket stream, so round latency is
O(cohort x stream) on one host. This module is the scale-out half of
the ROADMAP's elastic direction — the parameter-server analogue of the
PR 3 reduce-scatter split:

- **Shard.** :class:`ShardedFoldService` tiles the round's bucket range
  into ``n_shards`` contiguous shard ranges (the
  ``BucketPlan.group_view`` / PR 6 ``WirePlan`` tiling rule: balanced,
  contiguous, validated at construction), one
  :class:`~repro.elastic.fold.FoldEngine` per shard with its own
  :class:`~repro.net.switch.SwitchModel` slot pool and its own
  shard-view :class:`~repro.elastic.membership.RoundContract`. A
  payload is *striped* across shards (zero-copy views of its sketch
  blocks / bitmap words / exponent slices) and shards fold with no
  shared state — on real deployments each shard range lives on its own
  host, so the round's fold wall is the max over shards, not the sum.
- **Batch.** An ingest queue accumulates striped arrivals per shard and
  folds them as stacked microbatches through one jit-cached vectorized
  combine — an int64-checked segment-sum over the client axis for fxp32
  sketches, a ``lax.reduce`` OR for bitmap words — instead of the
  per-payload eager numpy walk, amortizing dispatch to O(1) per
  microbatch. Per-payload work at ingest is validation + straggler
  pricing + staging views: O(1) numpy.
- **Canonical reduction order.** f32 adds are not associative, so PR 9
  could only pin arrival-order invariance for the integer fxp32 wire.
  Here the f32 stack is held per cohort slot and reduced at finalize in
  **client-id-sorted chain order** (the left-leaning canonical tree:
  ``((0 + p_c0) + p_c1) + ...`` over ascending client ids), so an f32
  round's folded bits are a function of the contribution *set* — any
  arrival permutation and any microbatch partition give the same
  stream, bit-for-bit equal to the sequential engine fed client-sorted
  arrivals. fxp32 microbatches fold eagerly into the int32 accumulator
  (exact in every association), with the running-partial register
  check restated for batched partials via
  :meth:`~repro.net.switch.SwitchModel.check_batched_partial` — a
  microbatch of ``k`` payloads is safe iff the round still has ``k``
  contributions of worker-budget headroom.
- **Telemetry rollup.** Per-shard windows/occupancy/RX/retransmit
  counters live in each shard's :class:`~repro.elastic.fold.FoldState`
  and roll up through :class:`ShardedFoldState`'s properties, so
  ``server.py`` close-out (quorum, deferred-residual, the loss-free
  assertion) reads the exact fields it reads from a sequential round.

Straggler pricing walks the *same* full-range window grid the
sequential engine walks (per-client retransmit counts and RX bytes are
bit-identical to PR 9 — the property tests pin this), with each window
attributed to the shard owning its first bucket through
:meth:`repro.ft.failures.SwitchRetransmitPolicy.shard_view`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Set, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.bucketing import BucketPlan
from repro.core.config import CompressionConfig
from repro.ft.failures import SwitchRetransmitPolicy
from repro.net.switch import SwitchModel

from .fold import FoldEngine, FoldError, FoldState
from .membership import ClientPayload, RoundContract, StaleContractError


# ----------------------------------------------------------------------
# Shard tiling
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardRange:
    """One shard's contiguous bucket range (the PR 6 ``WireGroup``
    tiling shape, minus the wire name)."""

    index: int
    start: int                       # first bucket
    count: int                       # buckets in this shard

    @property
    def stop(self) -> int:
        return self.start + self.count


def shard_ranges(n_buckets: int, n_shards: int) -> Tuple[ShardRange, ...]:
    """Balanced contiguous tiling of ``n_buckets`` into ``n_shards``
    ranges: the first ``n_buckets % n_shards`` shards take one extra
    bucket, and the ranges tile ``[0, n_buckets)`` exactly — the same
    contiguity/coverage rule ``WirePlan`` validates for wire groups."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n_shards > n_buckets:
        raise ValueError(
            f"cannot split {n_buckets} buckets into {n_shards} shards "
            "(a shard needs at least one bucket)")
    base, extra = divmod(n_buckets, n_shards)
    ranges, start = [], 0
    for s in range(n_shards):
        count = base + (1 if s < extra else 0)
        ranges.append(ShardRange(index=s, start=start, count=count))
        start += count
    assert start == n_buckets
    return tuple(ranges)


def shard_contract(contract: RoundContract, rng: ShardRange,
                   plan: Optional[BucketPlan] = None) -> RoundContract:
    """The shard-view round contract: same cohort / wire pricing, the
    shard's bucket count, and ``total_elems`` truncated at the stream's
    true length — the ``BucketPlan.group_view`` rule, so the last
    shard's zero padding sits exactly where the full plan pads. When
    the server's :class:`BucketPlan` is at hand, the view is derived
    through ``group_view`` itself."""
    if plan is not None:
        total = plan.group_view(rng.start, rng.count).total
    else:
        total = min(rng.count * contract.bucket_elems,
                    contract.total_elems - rng.start * contract.bucket_elems)
    return dataclasses.replace(contract, n_buckets=rng.count,
                               total_elems=total)


# ----------------------------------------------------------------------
# Payload striping
# ----------------------------------------------------------------------

def stripe_payload(payload: ClientPayload, contract: RoundContract,
                   ranges: Tuple[ShardRange, ...], blocks_per_bucket: int,
                   words_per_bucket: int) -> List[ClientPayload]:
    """Split one full-range payload into per-shard sub-payloads —
    zero-copy views of the sketch block rows, bitmap word rows, and
    exponent entries covering each shard's bucket range. Striping is
    exact because buckets align to whole sketch blocks *and* whole
    bitmap words (``CompressionConfig.bucket_quantum``), and the
    per-shard slice byte counts sum to ``payload.nbytes``."""
    sk = np.asarray(payload.sketch)
    wd = np.asarray(payload.index_words).reshape(
        contract.n_buckets, words_per_bucket)
    exps = None if payload.exponents is None \
        else np.asarray(payload.exponents)
    out = []
    for r in ranges:
        b0, b1 = r.start * blocks_per_bucket, r.stop * blocks_per_bucket
        out.append(ClientPayload(
            client=payload.client,
            contract_id=payload.contract_id,
            sketch=sk[b0:b1],
            index_words=wd[r.start:r.stop].reshape(-1),
            exponents=None if exps is None else exps[r.start:r.stop]))
    return out


# ----------------------------------------------------------------------
# The jit-cached vectorized combines (one dispatch per microbatch)
# ----------------------------------------------------------------------

@jax.jit
def _fxp_batch_fold(acc_sk, stack_sk):
    """Batched integer fold: segment-sum of ``k`` stacked int32 payload
    sketches into the resident accumulator. Integer adds are exact in
    every association, so any staging order gives the same bits. The
    running-partial register check happens on the host (true int64 —
    JAX may run with x64 disabled, where an in-graph int64 cumsum would
    silently truncate to int32 and *wrap past the very overflow it is
    checking for*) and gates the commit of this sum."""
    return acc_sk + jnp.sum(stack_sk, axis=0, dtype=jnp.int32)


def _fxp_partial_extrema(acc_sk, stack_sk):
    """int64 running-partial extrema of ``[accumulator; payload 1; ...;
    payload k]`` — the operand order of the batched fold — for
    :meth:`repro.net.switch.SwitchModel.check_batched_partial`."""
    rows = np.concatenate(
        [acc_sk.reshape(1, -1).astype(np.int64),
         stack_sk.reshape(stack_sk.shape[0], -1).astype(np.int64)], axis=0)
    partials = np.cumsum(rows, axis=0)
    return int(partials.max()), int(partials.min())


@jax.jit
def _or_batch_fold(acc_wd, stack_wd):
    """Batched bitmap fold: reduce-OR over the client axis (exact and
    commutative — OR folds eagerly on both wires)."""
    red = jax.lax.reduce(stack_wd, np.uint32(0),
                         jax.lax.bitwise_or, (0,))
    return acc_wd | red


@jax.jit
def _f32_sorted_chain(stack, idx, k):
    """Canonical f32 reduction: left-fold ``stack[idx[0..k)]`` from a
    zero accumulator — ``idx`` holds the contributing cohort slots in
    ascending client-id order, so the association and operand order are
    exactly the sequential engine's fold fed client-sorted arrivals."""
    def body(i, acc):
        return acc + stack[idx[i]]
    return jax.lax.fori_loop(0, k, body,
                             jnp.zeros(stack.shape[1:], jnp.float32))


# ----------------------------------------------------------------------
# State
# ----------------------------------------------------------------------

@dataclasses.dataclass
class ShardedFoldState:
    """One sharded round's state: per-shard accumulator
    :class:`FoldState`s plus the service-level roster/telemetry the
    server's close-out reads. The rollup properties expose the exact
    fields a sequential :class:`FoldState` exposes, so ``server.py`` is
    oblivious to the sharding."""

    contract: RoundContract
    shard_states: List[FoldState]
    # staged (cohort_slot, sketch_view, words_view) per shard, drained
    # by each microbatch flush
    queues: List[list]
    # f32 only: per-shard cohort-slotted payload stacks (slot = cohort
    # position, ascending client id), reduced at finalize in canonical
    # order; None on the fxp32 wire, which folds eagerly
    stacks: Optional[List[np.ndarray]]
    exponents: Optional[np.ndarray] = None   # sealed full-range vector
    exp_acc: Optional[np.ndarray] = None     # running max during phase A
    exp_clients: Set[int] = dataclasses.field(default_factory=set)
    contributions: int = 0
    clients: Set[int] = dataclasses.field(default_factory=set)
    slots: List[int] = dataclasses.field(default_factory=list)
    rx_bytes: Dict[int, int] = dataclasses.field(default_factory=dict)
    retransmits: int = 0
    priced_windows: int = 0          # straggler-pricing walk cursor
    flushes: int = 0
    fold_s: List[float] = dataclasses.field(default_factory=list)
    finalize_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def windows(self) -> int:
        return sum(st.windows for st in self.shard_states)

    @property
    def occupancy_peak(self) -> int:
        return max((st.occupancy_peak for st in self.shard_states),
                   default=0)


# ----------------------------------------------------------------------
# The service
# ----------------------------------------------------------------------

class ShardedFoldService:
    """Scale-out fold over one round: S shard engines + microbatched
    ingest. Drop-in for :class:`FoldEngine` (same ``init_state`` /
    ``propose_exponents`` / ``seal_exponents`` / ``fold`` / ``finalize``
    / ``decode_payload`` surface), with identical validation, straggler
    accounting, and — via the canonical f32 order — identical folded
    bits for any arrival permutation and microbatch partition."""

    def __init__(self, contract: RoundContract, cfg: CompressionConfig,
                 n_shards: int = 1, batch_size: int = 8,
                 window_slots: Optional[int] = None,
                 plan: Optional[BucketPlan] = None):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.contract = contract
        self.cfg = cfg
        self.batch_size = int(batch_size)
        self.ranges = shard_ranges(contract.n_buckets, n_shards)
        self.n_shards = len(self.ranges)
        # one engine per shard range, each with its own slot pool and a
        # shard-view contract; the geometry-keyed recover cache means
        # equal-sized shards share ONE compiled recover fn, peeling at
        # their global block offsets via the traced offset argument
        self.engines = [
            FoldEngine(shard_contract(contract, r, plan), cfg,
                       window_slots=window_slots,
                       block_offset=r.start
                       * (contract.bucket_elems // cfg.block_elems))
            for r in self.ranges]
        e0 = self.engines[0]
        self.window_slots = e0.window_slots
        self.fxp32 = e0.fxp32
        self.blocks_per_bucket = e0.blocks_per_bucket
        self.words_per_bucket = e0.words_per_bucket
        # full-range geometry (payloads arrive full-range and are
        # striped here — or pre-striped client-side, which is pinned
        # identical)
        self.n_blocks = contract.n_buckets * self.blocks_per_bucket
        self.sketch_shape = (self.n_blocks, cfg.rows, cfg.lanes)
        self.n_words = contract.n_buckets * self.words_per_bucket
        # per-shard batched slot pools: port 0 is the resident
        # accumulator, port 1 the (batched) ingest stream
        self._pools = [SwitchModel(ports=2, slots=self.window_slots)
                       for _ in self.ranges] if self.fxp32 else None

    # ------------------------------------------------------------------

    def init_state(self) -> ShardedFoldState:
        shard_states = [eng.init_state() for eng in self.engines]
        stacks = None
        if not self.fxp32:
            W = self.contract.workers
            stacks = [np.zeros((W,) + st.sketch.shape, np.float32)
                      for st in shard_states]
        return ShardedFoldState(
            contract=self.contract, shard_states=shard_states,
            queues=[[] for _ in self.ranges], stacks=stacks,
            fold_s=[0.0] * self.n_shards,
            finalize_s=[0.0] * self.n_shards)

    # ---- phase A (fxp32): exponent negotiation -----------------------

    def propose_exponents(self, state: ShardedFoldState, client: int,
                          exponents: np.ndarray,
                          contract_id: Optional[str] = None) -> None:
        """Max-fold one full-range exponent proposal (order-free, same
        semantics as the sequential engine); the sealed vector is
        striped to the shards at :meth:`seal_exponents`."""
        if not self.fxp32:
            raise FoldError("the f32 wire negotiates no exponents")
        if contract_id is not None and \
                contract_id != self.contract.contract_id:
            raise StaleContractError(
                f"proposal quotes {contract_id}, round is "
                f"{self.contract.contract_id}")
        client = int(client)
        if client not in self.contract.cohort:
            raise FoldError(
                f"client {client} is not in this round's cohort")
        if client in state.exp_clients:
            raise FoldError(f"client {client} already proposed exponents")
        if state.exponents is not None:
            raise FoldError("exponents already sealed for this round")
        e = np.asarray(exponents)
        if e.shape != (self.contract.n_buckets,) or e.dtype != np.int32:
            raise FoldError(
                f"exponent proposal must be ({self.contract.n_buckets},) "
                f"int32, got {e.shape} {e.dtype}")
        state.exp_acc = e.copy() if state.exp_acc is None \
            else np.maximum(state.exp_acc, e)
        state.exp_clients.add(client)

    def seal_exponents(self, state: ShardedFoldState) -> np.ndarray:
        if not self.fxp32:
            raise FoldError("the f32 wire negotiates no exponents")
        if state.exp_acc is None:
            raise FoldError("no exponent proposals to seal")
        if state.exponents is None:
            state.exponents = state.exp_acc.copy()
            for r, st in zip(self.ranges, state.shard_states):
                st.exponents = state.exponents[r.start:r.stop].copy()
        return state.exponents

    # ---- phase B: batched ingest -------------------------------------

    def fold(self, state: ShardedFoldState, payload: ClientPayload,
             arrival_s: float = 0.0,
             policy: Optional[SwitchRetransmitPolicy] = None) -> int:
        """Ingest one payload: validate (identically to the sequential
        engine), price straggler retransmits over the full-range window
        walk, then stage the striped slices on each shard's microbatch
        queue — a queue that reaches ``batch_size`` flushes through the
        jit-cached combine. Returns the retransmit count; raises
        exactly what :meth:`FoldEngine.fold` raises, with state
        untouched on a straggler timeout."""
        if payload.contract_id != self.contract.contract_id:
            raise StaleContractError(
                f"payload quotes {payload.contract_id}, round is "
                f"{self.contract.contract_id} — re-encode under the "
                "current contract")
        client = int(payload.client)
        if client not in self.contract.cohort:
            raise FoldError(
                f"client {client} is not in this round's cohort")
        if client in state.clients:
            raise FoldError(
                f"client {client} already contributed this round")
        if state.contributions >= self.contract.workers:
            raise FoldError(
                f"{state.contributions} payloads already folded on a "
                f"wire sized for {self.contract.workers} workers "
                "(overflow bound would not hold)")
        sk = np.asarray(payload.sketch)
        wd = np.asarray(payload.index_words)
        want_dt = np.int32 if self.fxp32 else np.float32
        if sk.shape != self.sketch_shape or sk.dtype != want_dt:
            raise FoldError(
                f"sketch must be {self.sketch_shape} "
                f"{np.dtype(want_dt).name}, got {sk.shape} {sk.dtype}")
        if wd.shape != (self.n_words,) or wd.dtype != np.uint32:
            raise FoldError(
                f"index_words must be ({self.n_words},) uint32, got "
                f"{wd.shape} {wd.dtype}")
        if self.fxp32:
            if state.exponents is None:
                raise StaleContractError(
                    "fxp32 payload before the shared exponents were "
                    "sealed — nothing to verify the quantization against")
            if payload.exponents is None or not np.array_equal(
                    np.asarray(payload.exponents), state.exponents):
                raise StaleContractError(
                    f"client {client}'s payload was quantized against "
                    "exponents that are not this round's sealed vector "
                    "— re-encode")

        nb = self.contract.n_buckets
        wd_b = wd.reshape(nb, self.words_per_bucket)
        # straggler pricing first (state untouched when the arrival
        # blows the budget): the SAME full-range window walk the
        # sequential engine prices — per-client retransmit counts and
        # RX bytes are bit-identical to PR 9 — with each window
        # attributed to the shard owning its first bucket
        retries = 0
        rx = payload.nbytes
        if policy is not None and arrival_s > 0:
            cohort_port = self.contract.cohort.index(client)
            row_bytes = sk[:self.blocks_per_bucket].nbytes + wd_b[0].nbytes
            views = [policy.shard_view(r.index) for r in self.ranges]
            owner = np.searchsorted(
                [r.stop for r in self.ranges],
                np.arange(0, nb, self.window_slots), side="right")
            for w, w0 in enumerate(range(0, nb, self.window_slots)):
                w1 = min(w0 + self.window_slots, nb)
                r = views[int(owner[w])].on_window(
                    state.priced_windows + w, cohort_port,
                    float(arrival_s), (w1 - w0) * row_bytes)
                retries += r
                rx += r * (w1 - w0) * row_bytes
            state.priced_windows += w + 1

        # stage: zero-copy stripes on each shard's microbatch queue
        slot = self.contract.cohort.index(client)
        for r, st, q in zip(self.ranges, state.shard_states,
                            state.queues):
            b0 = r.start * self.blocks_per_bucket
            b1 = r.stop * self.blocks_per_bucket
            q.append((slot, sk[b0:b1], wd_b[r.start:r.stop]))
            st.contributions += 1
            st.clients.add(client)
            slice_bytes = sk[b0:b1].nbytes + wd_b[r.start:r.stop].nbytes
            if payload.exponents is not None:
                slice_bytes += r.count * np.asarray(
                    payload.exponents).dtype.itemsize
            st.rx_bytes[client] = st.rx_bytes.get(client, 0) + slice_bytes
        state.contributions += 1
        state.clients.add(client)
        state.slots.append(slot)
        state.rx_bytes[client] = state.rx_bytes.get(client, 0) + rx
        state.retransmits += retries

        for s in range(self.n_shards):
            if len(state.queues[s]) >= self.batch_size:
                self._flush_shard(state, s)
        return retries

    def flush(self, state: ShardedFoldState) -> None:
        """Drain every shard's queue through the batched combine (the
        service flushes automatically at ``batch_size`` and at
        :meth:`finalize`; this is the explicit hook)."""
        for s in range(self.n_shards):
            self._flush_shard(state, s)

    def _flush_shard(self, state: ShardedFoldState, s: int) -> None:
        q = state.queues[s]
        if not q:
            return
        state.queues[s] = []
        st = state.shard_states[s]
        rng = self.ranges[s]
        k = len(q)
        t0 = time.perf_counter()
        stack_wd = np.stack([e[2] for e in q])
        if self.fxp32:
            stack_sk = np.stack([e[1] for e in q])
            # the register-width check BEFORE committing anything — the
            # switch is the authority on the int32 bound, restated for
            # the batched partial (acc + k stacked payloads)
            pmax, pmin = _fxp_partial_extrema(st.sketch, stack_sk)
            pool = self._pools[s]
            pool.reset()
            pool.check_batched_partial(pmax, pmin,
                                       ports=k + 1, window=st.windows)
            st.sketch = np.asarray(_fxp_batch_fold(
                jnp.asarray(st.sketch), jnp.asarray(stack_sk)))
            st.index_words = np.asarray(_or_batch_fold(
                jnp.asarray(st.index_words), jnp.asarray(stack_wd)))
            chunk_bytes = (st.sketch[:self.blocks_per_bucket].nbytes
                           + st.index_words[0].nbytes)
            pool.account_batched_fold(
                n_chunks=rng.count, k_ports=k,
                port_bytes=rng.count * chunk_bytes,
                chunk_bytes=chunk_bytes)
            rep = pool.report()
            st.windows += rep["windows"]
            st.occupancy_peak = max(st.occupancy_peak,
                                    rep["occupancy_peak"])
        else:
            # f32: bitmap OR folds eagerly (exact); the sketch stack is
            # staged per cohort slot and reduced at finalize in the
            # canonical client-sorted order
            slots = np.asarray([e[0] for e in q], np.int64)
            state.stacks[s][slots] = np.stack([e[1] for e in q])
            st.index_words = np.asarray(_or_batch_fold(
                jnp.asarray(st.index_words), jnp.asarray(stack_wd)))
            for w0 in range(0, rng.count, self.window_slots):
                w1 = min(w0 + self.window_slots, rng.count)
                st.windows += 1
                st.occupancy_peak = max(st.occupancy_peak, w1 - w0)
        state.flushes += 1
        state.fold_s[s] += time.perf_counter() - t0

    # ---- recovery ----------------------------------------------------

    def finalize(self, state: ShardedFoldState) -> np.ndarray:
        """Flush the remaining microbatches, reduce the f32 stacks in
        canonical order, recover each shard at its global block offset
        (one jit-cached consumer call per shard — equal-sized shards
        share one compiled fn), and reassemble the
        ``(n_buckets, bucket_elems)`` stream."""
        if state.contributions == 0:
            raise FoldError("nothing folded — cannot finalize")
        self.flush(state)
        if not self.fxp32:
            W = self.contract.workers
            order = np.sort(np.asarray(state.slots, np.int64))
            idx = np.zeros((W,), np.int32)
            idx[:order.size] = order
            k = np.int32(order.size)
            for s, st in enumerate(state.shard_states):
                t0 = time.perf_counter()
                flat = _f32_sorted_chain(
                    jnp.asarray(state.stacks[s].reshape(W, -1)),
                    jnp.asarray(idx), k)
                st.sketch = np.asarray(flat).reshape(st.sketch.shape)
                state.fold_s[s] += time.perf_counter() - t0
        rows = []
        for s, (eng, st) in enumerate(zip(self.engines,
                                          state.shard_states)):
            t0 = time.perf_counter()
            rows.append(eng.finalize(st))
            state.finalize_s[s] += time.perf_counter() - t0
        return np.concatenate(rows, axis=0)

    def decode_payload(self, payload: ClientPayload) -> np.ndarray:
        """Recover ONE payload on its own (the deferred-residual path):
        striped per shard and peeled at each shard's global block
        offset — bit-identical to the sequential engine's full-range
        decode because blocks peel independently."""
        subs = stripe_payload(payload, self.contract, self.ranges,
                              self.blocks_per_bucket,
                              self.words_per_bucket)
        return np.concatenate(
            [eng.decode_payload(sub)
             for eng, sub in zip(self.engines, subs)], axis=0)

    # ---- telemetry ---------------------------------------------------

    def per_shard_report(self, state: ShardedFoldState) -> List[dict]:
        """Per-shard rollup rows (the benchmark's per-shard throughput
        table): bucket range, windows, occupancy, RX bytes, staged
        fold/finalize seconds."""
        out = []
        for r, st, fold_s, fin_s in zip(self.ranges, state.shard_states,
                                        state.fold_s, state.finalize_s):
            out.append({
                "shard": r.index, "bucket_start": r.start,
                "buckets": r.count, "windows": st.windows,
                "occupancy_peak": st.occupancy_peak,
                "contributions": st.contributions,
                "rx_bytes": sum(st.rx_bytes.values()),
                "fold_s": fold_s, "finalize_s": fin_s})
        return out
