"""Elastic aggregation service (PR 9): async sketch-fold for
intermittent many-client training.

The fixed-mesh aggregators (``core/aggregators.py``) assume W SPMD
ranks that all arrive at the collective together. This package is the
parameter-server-shaped tier for the ROADMAP's "millions of users"
regime: an open population of clients whose payloads *fold* into an
aggregation point as they arrive — which the paper's homomorphic wire
makes possible without barriers and without ever decompressing:

- :mod:`repro.elastic.membership` — roster + the per-round
  :class:`RoundContract` handshake. Membership changes renegotiate the
  wire each round (the fxp32 mantissa budget is W-dependent:
  ``30 - ceil_log2(W)``); stale-contract payloads are rejected or
  re-encoded, never silently folded.
- :mod:`repro.elastic.fold` — the incremental fold engine: sketch add
  + bitmap OR + contribution counter, O(1) aggregation state in the
  cohort size, streamed through the ``SwitchModel`` slot pool
  (bounded in-flight buckets, per-client RX accounting, int32
  overflow checks on fxp32), recovered through the one-consumer
  ``kernels/ops`` contract.
- :mod:`repro.elastic.server` / :mod:`repro.elastic.client` — round
  orchestration: admission (continuous-batcher slot shape),
  quorum/deadline close-out, straggler timeout/retransmit via
  ``ft/failures.py``, and late payloads carried into the *next*
  round's error-feedback residual rather than dropped.
- :mod:`repro.elastic.shard` — the scale-out fold path (PR 10):
  :class:`ShardedFoldService` tiles the bucket range into contiguous
  shard ranges (one ``FoldEngine`` + ``SwitchModel`` pool each, no
  shared state), stripes payloads across them, and folds microbatches
  through jit-cached vectorized combines; batched f32 folds reduce in
  canonical client-sorted order, so f32 rounds are arrival-order
  invariant bit-for-bit — the property PR 9 could only pin for fxp32.

Fold-equivalence is pinned bit-for-bit against the fixed-mesh
``compressed`` strategy (f32) and ``FixedPointWire.roundtrip_reference``
(fxp32) by ``tests/drivers/collectives_driver.py``;
``benchmarks/elastic.py`` measures async fold vs the synchronous
barrier baseline.
"""

from .membership import (ClientPayload, ExponentProposal, Membership,
                         RoundContract, StaleContractError,
                         negotiate_contract)
from .fold import FoldEngine, FoldError, FoldState
from .shard import (ShardRange, ShardedFoldService, ShardedFoldState,
                    shard_contract, shard_ranges, stripe_payload)
from .client import ElasticClient
from .server import (AdmissionPolicy, ElasticServer, QuorumNotReached,
                     RoundReport)

__all__ = [
    "AdmissionPolicy", "ClientPayload", "ElasticClient", "ElasticServer",
    "ExponentProposal", "FoldEngine", "FoldError", "FoldState",
    "Membership", "QuorumNotReached", "RoundContract", "RoundReport",
    "ShardRange", "ShardedFoldService", "ShardedFoldState",
    "StaleContractError", "negotiate_contract", "shard_contract",
    "shard_ranges", "stripe_payload",
]
