"""Elastic client: sparsify + EF + compress, re-encode on renegotiation.

The client side of the PR 9 round protocol:

1. ``propose(contract, grads)`` — per-leaf top-k + error feedback
   (*exactly* the fixed-mesh aggregators' :func:`sparsify_leaf`, so the
   residual semantics match the in-mesh strategies bit-for-bit), pack
   through the shared :class:`BucketPlan` geometry, and run ONE fused
   ``compress_wire`` producer pass. On the fxp32 wire this returns the
   client's :class:`ExponentProposal` (per-bucket exponents from the
   producer's per-block maxabs byproduct — max-of-maxes is exact);
   the f32 wire has no phase A and returns ``None``.
2. ``payload(contract, shared_exponents)`` — stamp the cached sketch
   with the round contract; fxp32 quantizes the cached f32 sketch
   against the *sealed* shared exponents (a sketch-sized op, not a
   stream pass — mirroring the in-mesh quantize-post-pmax order).

Error feedback is applied once, at ``propose`` time: the sparsified
values *will* reach the aggregate (on time, or via the server's
deferred-residual path), so the residual must not be re-charged if the
round closes before this client lands. ``reencode(new_contract)``
therefore re-stamps the *cached* compressed payload under a new
contract without touching EF — the recovery move after a
:class:`StaleContractError`.
"""

from __future__ import annotations

from typing import Any, Optional

import numpy as np
import jax.numpy as jnp

from repro.core.aggregators import sparsify_leaf
from repro.core.bucketing import make_bucket_plan
from repro.core.compressor import HomomorphicCompressor
from repro.core.config import CompressionConfig

from .membership import (ClientPayload, ExponentProposal, RoundContract,
                         StaleContractError)


class ElasticClient:
    """One intermittent training client."""

    def __init__(self, client: int, cfg: CompressionConfig):
        self.client = int(client)
        self.cfg = cfg
        self.comp = HomomorphicCompressor(cfg)
        self._plan = None
        self._residual = None        # pytree leaves, flat f32 (EF state)
        self._cache = None           # dict: one encoded round payload

    # ------------------------------------------------------------------

    @property
    def residual(self):
        """Per-leaf EF residual pytree (None before the first propose)."""
        if self._plan is None or self._residual is None:
            return None
        import jax
        leaves = [np.asarray(r).reshape(sh) for r, sh in
                  zip(self._residual, self._plan.shapes)]
        return jax.tree.unflatten(self._plan.treedef, leaves)

    def _check_geometry(self, contract: RoundContract) -> None:
        p = self._plan
        if (p.n_buckets, p.bucket_elems, p.total) != \
                (contract.n_buckets, contract.bucket_elems,
                 contract.total_elems):
            raise ValueError(
                f"client plan ({p.n_buckets}x{p.bucket_elems}/{p.total}) "
                f"does not match contract geometry "
                f"({contract.n_buckets}x{contract.bucket_elems}"
                f"/{contract.total_elems})")

    # ---- phase A ------------------------------------------------------

    def propose(self, contract: RoundContract,
                grads: Any) -> Optional[ExponentProposal]:
        """Sparsify (EF), compress, cache the wire payload; fxp32
        returns the exponent proposal for the server's max-fold."""
        if self._plan is None:
            self._plan = make_bucket_plan(grads, self.cfg)
        self._check_geometry(contract)
        plan = self._plan
        leaves = plan.treedef.flatten_up_to(grads)
        if self._residual is None:
            self._residual = [jnp.zeros((n,), jnp.float32)
                              for n in plan.sizes]
        sparse, new_res = [], []
        for leaf, res in zip(leaves, self._residual):
            flat = jnp.asarray(leaf).reshape(-1).astype(jnp.float32)
            sp, nr = sparsify_leaf(flat, res, self.cfg)
            sparse.append(sp)
            new_res.append(nr)
        self._residual = new_res
        stream = plan.pack_flat(sparse)
        comp, maxabs = self.comp.compress_wire(stream.reshape(-1))
        bucket_max = np.asarray(maxabs).reshape(
            plan.n_buckets, -1).max(axis=1)
        self._cache = {
            "contract_id": contract.contract_id,
            "sketch": np.asarray(comp.sketch),        # f32, pre-quantize
            "index_words": np.asarray(comp.index_words),
            "bucket_max": bucket_max,
        }
        return self._proposal_from_cache(contract)

    def reencode(self, contract: RoundContract
                 ) -> Optional[ExponentProposal]:
        """Re-stamp the cached payload under a new contract — EF is NOT
        re-applied (the sparsified values were never delivered, so the
        residual charge from ``propose`` still stands). The fxp32
        proposal is re-derived from the cached maxima under the new
        cohort's wire, which re-prices the mantissa budget."""
        if self._cache is None:
            raise StaleContractError(
                f"client {self.client} has nothing to re-encode — call "
                "propose() first")
        self._check_geometry(contract)
        self._cache["contract_id"] = contract.contract_id
        return self._proposal_from_cache(contract)

    def _proposal_from_cache(self, contract: RoundContract
                             ) -> Optional[ExponentProposal]:
        if contract.wire_dtype != "fxp32":
            return None
        exps = np.asarray(contract.wire.exponents_from_maxabs(
            jnp.asarray(self._cache["bucket_max"]))).astype(np.int32)
        return ExponentProposal(client=self.client,
                                contract_id=contract.contract_id,
                                exponents=exps)

    # ---- phase B ------------------------------------------------------

    def payload(self, contract: RoundContract,
                shared_exponents: Optional[np.ndarray] = None
                ) -> ClientPayload:
        """Build the wire payload for the round. fxp32 quantizes the
        cached f32 sketch against the sealed shared exponents."""
        if self._cache is None:
            raise StaleContractError(
                f"client {self.client} must propose() before payload()")
        if self._cache["contract_id"] != contract.contract_id:
            raise StaleContractError(
                f"client {self.client}'s cached payload was encoded "
                f"under {self._cache['contract_id']}, round is "
                f"{contract.contract_id} — reencode() first")
        sk = self._cache["sketch"]
        if contract.wire_dtype == "fxp32":
            if shared_exponents is None:
                raise ValueError("fxp32 payload needs the sealed shared "
                                 "exponents")
            exps = np.asarray(shared_exponents).astype(np.int32)
            q = np.asarray(contract.wire.encode(
                jnp.asarray(sk).reshape(contract.n_buckets, -1),
                jnp.asarray(exps))).reshape(sk.shape)
            return ClientPayload(
                client=self.client, contract_id=contract.contract_id,
                sketch=q, index_words=self._cache["index_words"],
                exponents=exps)
        return ClientPayload(
            client=self.client, contract_id=contract.contract_id,
            sketch=sk, index_words=self._cache["index_words"])

    def payload_stripes(self, contract: RoundContract, n_shards: int,
                        shared_exponents: Optional[np.ndarray] = None
                        ) -> list:
        """Client-side striping for a sharded aggregation point (PR 10):
        the round payload pre-split into per-shard sub-payloads, so each
        stripe can be shipped straight to the shard host that owns its
        bucket range instead of transiting the full payload through one
        ingress. The split is the server's own
        :func:`repro.elastic.shard.stripe_payload` over the canonical
        :func:`repro.elastic.shard.shard_ranges` tiling — the tests pin
        client-side stripes byte-identical to the server striping the
        full payload itself."""
        from .shard import shard_ranges, stripe_payload
        p = self.payload(contract, shared_exponents)
        return stripe_payload(
            p, contract, shard_ranges(contract.n_buckets, n_shards),
            contract.bucket_elems // self.cfg.block_elems,
            contract.bucket_elems // 32)

    def contribute(self, contract: RoundContract, grads: Any
                   ) -> ClientPayload:
        """f32 convenience: propose + payload in one call (the f32 wire
        has no exponent phase to wait on)."""
        if contract.wire_dtype != "f32":
            raise ValueError(
                "contribute() is the single-phase f32 path; fxp32 "
                "rounds go propose() -> seal -> payload()")
        self.propose(contract, grads)
        return self.payload(contract)
