"""Batched serving engine: prefill + decode with slot-based continuous
batching.

Serving steps are pure jit + GSPMD auto-sharding (no manual axes): there
is no gradient aggregation, so the paper's compression plays no role here
— the serve cells exist to prove the distribution configs (batch-DP,
sequence-parallel KV caches) lower and compile on the production meshes.

``ServeEngine.generate`` is the simple batch API; ``ContinuousBatcher``
keeps a fixed pool of decode slots and admits queued requests as slots
free up (the vLLM-style loop, minus paging).
"""

from __future__ import annotations

import dataclasses
import queue
from typing import Any, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.registry import ModelAPI


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]


class ServeEngine:
    def __init__(self, api: ModelAPI, params, max_len: int, batch: int,
                 greedy: bool = True):
        self.api = api
        self.params = params
        self.max_len = max_len
        self.batch = batch
        self.greedy = greedy
        self._decode = jax.jit(
            lambda p, tok, cache, pos: api.decode(p, tok, cache, pos))
        self._prefill = jax.jit(
            lambda p, batch_: api.prefill(p, batch_, max_len))

    # -- simple batch generate ----------------------------------------

    def generate(self, tokens: np.ndarray, max_new: int,
                 extra: Optional[Dict[str, Any]] = None) -> np.ndarray:
        """tokens: (B, S) prompts (same length). Greedy decode."""
        B, S = tokens.shape
        batch = {"tokens": jnp.asarray(tokens)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        logits, cache = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = S
        for _ in range(max_new):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.int32(pos))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            pos += 1
        return np.stack(out, axis=1)           # (B, max_new)


class ContinuousBatcher:
    """Slot-based continuous batching over a fixed decode batch.

    Each slot holds one in-flight request; finished slots are refilled
    from the queue between decode steps. The KV cache is allocated once
    at engine size and slots are overwritten on admission (prefill into
    slot i via a single-request prefill + cache splice).
    """

    def __init__(self, engine: ServeEngine):
        self.engine = engine
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self.done: List[Completion] = []

    def submit(self, req: Request):
        self.queue.put(req)

    def run(self, decode_steps: int = 64) -> List[Completion]:
        eng = self.engine
        B = eng.batch
        slots: List[Optional[Request]] = [None] * B
        remaining = np.zeros(B, np.int32)
        produced: List[List[int]] = [[] for _ in range(B)]
        cache = eng.api.init_cache(eng.params, B, eng.max_len)
        cur = jnp.zeros((B,), jnp.int32)
        pos = 0

        def admit():
            nonlocal cur, cache, pos
            for i in range(B):
                if slots[i] is None and not self.queue.empty():
                    req = self.queue.get()
                    slots[i] = req
                    remaining[i] = req.max_new_tokens
                    produced[i] = []
                    # single-request prefill, spliced into slot i
                    logits, c1 = eng._prefill(
                        eng.params, {"tokens": jnp.asarray(req.prompt[None])})
                    cache_i = jax.tree.map(lambda full, one: full.at[:, i:i+1].set(
                        one.astype(full.dtype)), cache, c1)
                    cache = cache_i
                    cur = cur.at[i].set(jnp.argmax(logits[0]).astype(jnp.int32))
                    pos = max(pos, int(req.prompt.shape[0]))

        admit()
        for _ in range(decode_steps):
            if all(s is None for s in slots):
                break
            logits, cache = eng._decode(eng.params, cur, cache,
                                        jnp.int32(pos))
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            pos += 1
            host = np.asarray(cur)
            for i in range(B):
                if slots[i] is not None:
                    produced[i].append(int(host[i]))
                    remaining[i] -= 1
                    if remaining[i] <= 0:
                        self.done.append(
                            Completion(uid=slots[i].uid, tokens=produced[i]))
                        slots[i] = None
            cur = nxt
            admit()
        return self.done
