"""Batched serving engine with continuous batching."""
from .engine import ServeEngine, ContinuousBatcher, Request, Completion
__all__ = ["ServeEngine", "ContinuousBatcher", "Request", "Completion"]
