"""Serving step builders — pure jit + GSPMD auto-sharding.

These produce the (function, in_shardings, out_shardings, placeholder
inputs) tuples the multi-pod dry-run lowers and compiles for the
``prefill_*`` / ``decode_*`` / ``long_*`` shape cells.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.registry import ModelAPI
from repro.parallel import sharding as shd
from repro.parallel.hints import logical_axis_rules


def serve_batch_pspec(global_batch: int, mesh, prof) -> P:
    return shd.batch_pspec(global_batch, mesh, prof)


def build_prefill_step(api: ModelAPI, prof: shd.ShardingProfile, mesh,
                       max_len: int):
    rules = shd.filter_rules_for_mesh(
        prof.logical_rules(inside_manual_dp=False), mesh)

    def prefill_fn(params, batch):
        with logical_axis_rules(rules, mesh=mesh):
            return api.prefill(params, batch, max_len)

    return prefill_fn


def build_decode_step(api: ModelAPI, prof: shd.ShardingProfile, mesh):
    rules = shd.filter_rules_for_mesh(
        prof.logical_rules(inside_manual_dp=False), mesh)

    def decode_fn(params, token, cache, position):
        with logical_axis_rules(rules, mesh=mesh):
            return api.decode(params, token, cache, position)

    return decode_fn


def serve_shardings(api: ModelAPI, prof: shd.ShardingProfile, mesh,
                    global_batch: int, seq_len: int):
    """NamedShardings for (params, batch/token, cache) of serve steps."""
    cfg = api.cfg
    params_struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    pspecs = shd.param_pspecs(params_struct, prof)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
    bspec = shd.batch_pspec(global_batch, mesh, prof)
    b_sh = NamedSharding(mesh, bspec)
    cache_struct = jax.eval_shape(
        lambda: api.init_cache(params_struct, global_batch, seq_len))
    cspecs = shd.cache_pspecs(cfg, global_batch, mesh, prof)

    def _apply(spec_tree, struct_tree):
        return jax.tree.map(
            lambda s, _: NamedSharding(mesh, s), spec_tree, struct_tree,
            is_leaf=lambda x: isinstance(x, P))

    # cache spec tree is coarser than the struct tree (one spec per group
    # for mamba states); broadcast specs over matching subtrees
    def broadcast(spec, struct):
        if isinstance(spec, P):
            return jax.tree.map(lambda _: NamedSharding(mesh, spec), struct)
        if isinstance(spec, dict):
            return {k: broadcast(spec[k], struct[k]) for k in struct}
        raise TypeError(type(spec))

    c_sh = broadcast(cspecs, cache_struct)
    return {"params_struct": params_struct, "params": p_sh,
            "batch": b_sh, "cache_struct": cache_struct, "cache": c_sh,
            "pspecs": pspecs}
