"""Fixed-size gradient buckets: the aggregation substrate (PR 2).

The paper aggregates the *whole* gradient through one homomorphic sketch
stream; THC (arXiv:2302.08545) and ScaleCom (arXiv:2104.11125) show that
fusing gradients into fixed-size buckets before compression is what makes
compressed aggregation scale. ``BucketPlan`` is the static geometry for
that fusion:

- built **once** from the (shard-local) leaf shapes/dtypes — pure Python,
  outside jit;
- ``pack``   — flatten every leaf to f32, concatenate in leaf order, pad,
  and view as ``(n_buckets, bucket_elems)``. Pure and jittable: nothing
  but reshape / pad / concat, so XLA fuses it into the producers.
- ``unpack`` — the exact inverse (slices the stream back into leaves,
  restoring shape and dtype; padding is dropped).

``bucket_elems`` is ``cfg.bucket_bytes`` rounded to the *bucket quantum*
(whole sketch blocks and whole packed-bitmap uint32 words), so the fused
compressed stream's sketch ``(n_blocks, rows, lanes)`` and bitmap words
slice into exact per-bucket views — which is what lets the overlap
pipeline and the reduce-scatter aggregator ship bucket ``i`` while bucket
``i+1`` is still encoding (see :mod:`repro.core.aggregators`).

Error feedback: sparsification semantics are **per leaf** (pinned
bit-for-bit against the pre-bucketing per-leaf path by
``tests/drivers/collectives_driver.py``), so residuals keep the parameter
pytree layout. ``bucket_segments`` / ``residual_slices`` expose the
per-bucket view of those residuals — each bucket's slice of every leaf
(and its residual) that lands in it — for per-bucket wire accounting and
for future per-bucket EF policies.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .config import CompressionConfig


@dataclasses.dataclass(frozen=True)
class BucketSegment:
    """One contiguous run of a leaf inside one bucket."""

    leaf: int          # index into the flattened leaf list
    leaf_start: int    # offset into the leaf's flat vector
    bucket: int        # bucket index
    bucket_start: int  # offset into the bucket
    length: int


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static packing of a pytree into ``(n_buckets, bucket_elems)``."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    offsets: Tuple[int, ...]   # start of each leaf in the flat stream
    total: int                 # true element count (sum of sizes)
    bucket_elems: int
    n_buckets: int

    @property
    def padded(self) -> int:
        return self.n_buckets * self.bucket_elems

    @property
    def pad(self) -> int:
        return self.padded - self.total

    def blocks_per_bucket(self, cfg: CompressionConfig) -> int:
        """Whole sketch blocks per bucket — exact by construction
        (``bucket_elems`` is a multiple of the bucket quantum). The one
        definition the aggregators and the stream scheduler share."""
        return self.bucket_elems // cfg.block_elems

    @property
    def words_per_bucket(self) -> int:
        """Whole packed-bitmap uint32 words per bucket (exact, ditto)."""
        return self.bucket_elems // 32

    # ------------------------------------------------------------------
    # pack / unpack (pure, jittable)
    # ------------------------------------------------------------------

    def pack_flat(self, flats: Sequence[jnp.ndarray]) -> jnp.ndarray:
        """Already-flat f32 leaves (in treedef order) -> (n_buckets, E)."""
        if len(flats) != len(self.sizes):
            raise ValueError(f"{len(flats)} leaves, plan has {len(self.sizes)}")
        for f, n in zip(flats, self.sizes):
            if f.shape != (n,):
                raise ValueError(f"leaf shape {f.shape} != plan size ({n},)")
        stream = jnp.concatenate(
            [f.astype(jnp.float32) for f in flats]) if len(flats) > 1 \
            else flats[0].astype(jnp.float32)
        stream = jnp.pad(stream, (0, self.pad))
        return stream.reshape(self.n_buckets, self.bucket_elems)

    def pack(self, grads: Any) -> jnp.ndarray:
        """Pytree of leaves (any shapes/dtypes) -> (n_buckets, E) f32."""
        leaves = self.treedef.flatten_up_to(grads)
        return self.pack_flat([g.reshape(-1) for g in leaves])

    def unpack_flat(self, buckets: jnp.ndarray) -> List[jnp.ndarray]:
        """(n_buckets, E) -> list of flat f32 leaves (padding dropped)."""
        if buckets.shape != (self.n_buckets, self.bucket_elems):
            raise ValueError(
                f"buckets shape {buckets.shape} != "
                f"({self.n_buckets}, {self.bucket_elems})")
        stream = buckets.reshape(-1)
        return [jax.lax.dynamic_slice_in_dim(stream, off, n)
                for off, n in zip(self.offsets, self.sizes)]

    def unpack(self, buckets: jnp.ndarray) -> Any:
        """(n_buckets, E) f32 -> pytree with original shapes and dtypes."""
        flats = self.unpack_flat(buckets)
        leaves = [f.astype(dt).reshape(sh)
                  for f, dt, sh in zip(flats, self.dtypes, self.shapes)]
        return jax.tree.unflatten(self.treedef, leaves)

    # ------------------------------------------------------------------
    # per-bucket views
    # ------------------------------------------------------------------

    @property
    def bucket_segments(self) -> Tuple[Tuple[BucketSegment, ...], ...]:
        """For each bucket, the (leaf, leaf_start, bucket_start, length)
        runs that land in it. Padding tail is not a segment."""
        out: List[List[BucketSegment]] = [[] for _ in range(self.n_buckets)]
        for li, (off, n) in enumerate(zip(self.offsets, self.sizes)):
            pos = off
            while pos < off + n:
                b = pos // self.bucket_elems
                b_start = pos - b * self.bucket_elems
                length = min(off + n - pos, self.bucket_elems - b_start)
                out[b].append(BucketSegment(
                    leaf=li, leaf_start=pos - off, bucket=b,
                    bucket_start=b_start, length=length))
                pos += length
        return tuple(tuple(s) for s in out)

    def group_view(self, start: int, count: int) -> "BucketPlan":
        """A BucketPlan over buckets ``[start, start + count)`` as one
        flat pseudo-leaf (PR 6 wire-plan groups).

        The view keeps this plan's ``bucket_elems`` and truncates
        ``total`` at the stream's true element count, so the last
        group's zero padding is reconstructed exactly where the full
        plan pads.  Per-group executors feed the view the corresponding
        row slice of the packed ``(n_buckets, E)`` stream; leaf
        structure is irrelevant below the pack boundary (sparsify/EF
        already happened per leaf), so one flat leaf is the honest
        geometry.
        """
        if not (0 <= start and count >= 1
                and start + count <= self.n_buckets):
            raise ValueError(
                f"group [{start}, {start + count}) out of range for "
                f"{self.n_buckets} buckets")
        total = min(count * self.bucket_elems,
                    self.total - start * self.bucket_elems)
        flat = jax.tree.structure((0,))
        return BucketPlan(
            treedef=flat, shapes=((total,),), dtypes=(jnp.float32,),
            sizes=(total,), offsets=(0,), total=total,
            bucket_elems=self.bucket_elems, n_buckets=count)

    def residual_slices(self, residual: Any) -> List[List[jnp.ndarray]]:
        """Per-bucket error-feedback residual slices: for each bucket, the
        flat residual runs (one per segment) whose coordinates it covers."""
        leaves = [r.reshape(-1) for r in self.treedef.flatten_up_to(residual)]
        return [[jax.lax.dynamic_slice_in_dim(
                    leaves[s.leaf], s.leaf_start, s.length)
                 for s in segs]
                for segs in self.bucket_segments]


def make_dest_bucket_plans(payload: Any, cfg: CompressionConfig,
                           n_dests: int = None) -> Tuple[BucketPlan, ...]:
    """Per-destination bucket plans for the all-to-all pattern (PR 8).

    ``payload`` is a pytree whose leaves carry a leading *destination*
    axis (one slice per destination EP rank).  Returns one
    :class:`BucketPlan` per destination, built over the per-destination
    slice shapes, all sharing one ``(n_buckets, bucket_elems)`` grid
    aligned to sketch blocks / bitmap words exactly like today's
    buckets.  The permute wire ships a single stacked
    ``(W, n_buckets, ...)`` payload — one ppermute lane per destination
    — so the lane geometry must be uniform; a ragged destination axis
    is rejected.
    """
    leaves = jax.tree.leaves(payload)
    if not leaves:
        raise ValueError("empty all-to-all payload")
    dests = {int(l.shape[0]) for l in leaves}
    if len(dests) != 1:
        raise ValueError(
            "all-to-all payload leaves disagree on the destination axis "
            f"(leading dim): {sorted(dests)}")
    W = dests.pop()
    if n_dests is not None and n_dests != W:
        raise ValueError(
            f"payload carries {W} destination slices but the exchange "
            f"has {n_dests} destination ranks")
    slice0 = jax.tree.map(lambda l: l[0], payload)
    plan = make_bucket_plan(slice0, cfg)
    # identical geometry per destination: the slices are same-shaped by
    # construction (one leading-axis row each), so one frozen plan
    # serves every lane
    return (plan,) * W


def make_bucket_plan(grads: Any, cfg: CompressionConfig,
                     shapes: Any = None) -> BucketPlan:
    """Build the static plan from a pytree (or from a same-structured
    pytree of shape tuples via ``shapes`` — used when the packed leaves
    are shard-local views of globally-sharded arrays)."""
    leaves, treedef = jax.tree.flatten(grads)
    if shapes is None:
        shape_list = [tuple(g.shape) for g in leaves]
    else:
        shape_list = [tuple(s) for s in treedef.flatten_up_to(shapes)]
    dtypes = tuple(jnp.asarray(g).dtype if not hasattr(g, "dtype") else g.dtype
                   for g in leaves)
    sizes, offsets, off = [], [], 0
    for sh in shape_list:
        n = 1
        for d in sh:
            n *= d
        sizes.append(n)
        offsets.append(off)
        off += n
    total = off
    bucket_elems = cfg.bucket_elems_for(total)
    return BucketPlan(
        treedef=treedef, shapes=tuple(shape_list), dtypes=dtypes,
        sizes=tuple(sizes), offsets=tuple(offsets), total=total,
        bucket_elems=bucket_elems, n_buckets=-(-total // bucket_elems))
