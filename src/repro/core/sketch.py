"""Block-local Count Sketch (paper §3.1 + §3.4) — pure-jnp reference.

Every function here operates on the block layout ``(nb, G, c)`` produced by
:mod:`repro.core.blocks`. The sketch for a block is ``(rows, c)``; batch
``i`` of a block contributes its ``c`` values to row ``h_j(i)`` for the
three hashes ``j``, rotated by ``rot_j(i, blk)`` lanes and multiplied by
the sign ``g_j(i)``:

    Y[h_j(i), (l + rot_j(i,blk)) % c] += g_j(i) * x[i, l]

Row tables and signs are compile-time constants shared across blocks; the
rotations vary per block (computed in-graph from the block id), which is
what makes each block an independent random 3-partite hypergraph.

Linearity of every step gives the homomorphic property:
``encode(sum_w X_w) == sum_w encode(X_w)`` exactly (up to fp addition
order), so sketches aggregate with a plain ``psum``.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .config import CompressionConfig
from . import hashing


def plan_tables(cfg: CompressionConfig) -> Tuple[np.ndarray, np.ndarray]:
    """Static (rows, signs) tables: int32 (G, 3), float32 (G, 3)."""
    return (hashing.batch_rows(cfg.group, cfg.rows, cfg.seed),
            hashing.batch_signs(cfg.group, cfg.seed))


# ----------------------------------------------------------------------
# Lane rotations (the §3.4 locality randomisation)
# ----------------------------------------------------------------------

def _rolled_slices(ext: jnp.ndarray, starts: jnp.ndarray, lanes: int) -> jnp.ndarray:
    """Per-row dynamic-slice out of a doubled row. ext (..., 2c), starts
    (...,) -> (..., c). Lowers to a gather with *scalar* per-row indices —
    O(1) index memory, unlike take_along_axis whose (…, c, ndim) index
    tensor costs 4x the payload."""
    def one(row, s):
        return jax.lax.dynamic_slice(row, (s,), (lanes,))
    f = one
    for _ in range(ext.ndim - 1):
        f = jax.vmap(f)
    return f(ext, starts)


def roll_to_sketch(x: jnp.ndarray, rot: jnp.ndarray, lanes: int) -> jnp.ndarray:
    """Forward rotation: x (nb,G,c) -> (nb,G,3,c) where out[m] = x[(m-rot)%c]."""
    ext = jnp.concatenate([x, x], axis=-1)                 # (nb,G,2c)
    ext = jnp.broadcast_to(ext[:, :, None, :], ext.shape[:2] + (3, 2 * lanes))
    starts = (lanes - rot) % lanes                         # (nb,G,3)
    return _rolled_slices(ext, starts, lanes)


def roll_from_sketch(y: jnp.ndarray, rot: jnp.ndarray, lanes: int) -> jnp.ndarray:
    """Inverse rotation: y (nb,G,3,c) -> (nb,G,3,c) where out[l] = y[(l+rot)%c]."""
    ext = jnp.concatenate([y, y], axis=-1)                 # (nb,G,3,2c)
    return _rolled_slices(ext, rot % lanes, lanes)


# ----------------------------------------------------------------------
# Scatter / gather between batches and sketch rows
# ----------------------------------------------------------------------

def scatter_rows(contrib: jnp.ndarray, rows_tbl: np.ndarray, rows: int) -> jnp.ndarray:
    """contrib (nb,G,3,c) -> sketch (nb,rows,c) via scatter-add on h_j(i)."""
    nb, g, _, c = contrib.shape
    flat = contrib.reshape(nb, g * 3, c)
    h_flat = jnp.asarray(rows_tbl.reshape(-1), dtype=jnp.int32)
    return jnp.zeros((nb, rows, c), contrib.dtype).at[:, h_flat, :].add(flat)


def gather_rows(sketch: jnp.ndarray, rows_tbl: np.ndarray) -> jnp.ndarray:
    """sketch (nb,rows,c) -> (nb,G,3,c) gathered at h_j(i)."""
    nb, _, c = sketch.shape
    h_flat = jnp.asarray(rows_tbl.reshape(-1), dtype=jnp.int32)
    g3 = h_flat.shape[0]
    return sketch[:, h_flat, :].reshape(nb, g3 // 3, 3, c)


# ----------------------------------------------------------------------
# Encode / estimate
# ----------------------------------------------------------------------

def encode_blocks(xb: jnp.ndarray, block_ids: jnp.ndarray,
                  cfg: CompressionConfig) -> jnp.ndarray:
    """Count-Sketch encode: (nb,G,c) values -> (nb,rows,c) sketch (f32)."""
    rows_tbl, signs = plan_tables(cfg)
    rot = hashing.block_rotations(block_ids, cfg.group, cfg.lanes, cfg.seed)
    x = xb.astype(jnp.float32)
    contrib = roll_to_sketch(x, rot, cfg.lanes) * jnp.asarray(signs)[None, :, :, None]
    return scatter_rows(contrib, rows_tbl, cfg.rows)


def estimate_blocks(sketch: jnp.ndarray, block_ids: jnp.ndarray,
                    cfg: CompressionConfig) -> jnp.ndarray:
    """Unbiased median-of-3 Count-Sketch estimate for every coordinate.

    This is the paper's fallback for coordinates the peeling process cannot
    resolve (footnote 5) and the entire decoder of the sketch-only
    (Sketched-SGD-style) lossy baseline.
    """
    rows_tbl, signs = plan_tables(cfg)
    rot = hashing.block_rotations(block_ids, cfg.group, cfg.lanes, cfg.seed)
    y = gather_rows(sketch, rows_tbl)                       # (nb,G,3,c)
    y = roll_from_sketch(y, rot, cfg.lanes)
    est = y * jnp.asarray(signs)[None, :, :, None]
    v0, v1, v2 = est[:, :, 0], est[:, :, 1], est[:, :, 2]
    # median3 = sum - max - min
    return v0 + v1 + v2 - jnp.maximum(jnp.maximum(v0, v1), v2) \
        - jnp.minimum(jnp.minimum(v0, v1), v2)
