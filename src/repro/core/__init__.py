"""The paper's primary contribution: lossless homomorphic gradient
compression (Count Sketch + OR-aggregable non-zero index + parallel
peeling recovery), plus the collectives that aggregate the compressed
form across a TPU mesh."""

from .config import CompressionConfig, GAMMA
from .blocks import LeafPlan, make_plan, to_blocks, from_blocks
from .bucketing import BucketPlan, BucketSegment, make_bucket_plan
from .streams import (StreamPlan, make_stream_plan, stream_schedule,
                      zero1_gather_skip, zero_slice_dim)
from .compressor import HomomorphicCompressor, CompressedLeaf, RecoveryStats
from .sketch import encode_blocks, estimate_blocks
from .peeling import peel_blocks, PeelResult
from . import index
from . import hashing
from . import topk

__all__ = [
    "CompressionConfig", "GAMMA", "LeafPlan", "make_plan", "to_blocks",
    "from_blocks", "BucketPlan", "BucketSegment", "make_bucket_plan",
    "StreamPlan", "make_stream_plan", "stream_schedule",
    "zero1_gather_skip", "zero_slice_dim",
    "HomomorphicCompressor", "CompressedLeaf", "RecoveryStats",
    "encode_blocks", "estimate_blocks", "peel_blocks", "PeelResult",
    "index", "hashing", "topk",
]
