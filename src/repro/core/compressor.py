"""Top-level homomorphic compressor (paper Algorithm 1).

``HomomorphicCompressor`` turns a gradient leaf (any shape) into the wire
format ``CompressedLeaf(sketch, index_words)`` and back:

    compress:  X -> S(X) = [Y, B]          (phase I)
    recover :  S(sum X) -> sum X           (phase II, peeling + estimate)

Both directions are pure jittable functions of statically-planned shape.
Aggregation happens *between* the two calls and is someone else's job —
``psum`` for the sketch, OR-AllReduce for the index words (see
:mod:`repro.core.aggregators`, which feeds the compressor whole bucketed
gradient streams, and :mod:`repro.core.collectives` for the primitives) —
which is exactly the homomorphic contract of the paper: the aggregation
API never decompresses. ``block_offset`` lets a caller encode/recover a
sub-range of a larger bucket stream under the stream's global hash plan.

All sketch compute (encode, peel, estimate) goes through the backend
dispatch in :mod:`repro.kernels.ops`, so ``cfg.use_pallas`` selects the
Pallas TPU kernels or the jnp reference for every consumer of this class.

Large leaves are processed in chunks of ``cfg.chunk_blocks`` blocks via
``lax.map`` to bound peak memory (the (nb, G, 3, c) rotation intermediates
would otherwise dwarf the gradient itself).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import CompressionConfig
from .blocks import LeafPlan, make_plan, to_blocks, from_blocks
from . import index as index_lib


class CompressedLeaf(NamedTuple):
    """Wire format for one leaf. Sketch aggregates by +, words by |."""
    sketch: jnp.ndarray       # (nb, rows, lanes) f32
    index_words: jnp.ndarray  # (w,) uint32 — packed bitmap or Bloom filter


class RecoveryStats(NamedTuple):
    nnz: jnp.ndarray          # indexed coordinates (candidates)
    peeled: jnp.ndarray       # exactly recovered
    residual: jnp.ndarray     # fell back to median estimate
    rounds: jnp.ndarray       # peeling rounds used


def _chunked_map(fn, nb: int, chunk: int, *arrays):
    """lax.map ``fn`` over blocks in chunks; pads nb to a chunk multiple.

    ``arrays`` all have leading dim nb. Padding blocks are all-zero, which
    is harmless for both encode (zero sketch) and peel (empty index).
    """
    if nb <= chunk:
        return fn(*arrays)
    nchunks = -(-nb // chunk)
    padded = nchunks * chunk

    def pad(a):
        return jnp.pad(a, [(0, padded - nb)] + [(0, 0)] * (a.ndim - 1))

    stacked = [pad(a).reshape((nchunks, chunk) + a.shape[1:]) for a in arrays]
    out = jax.lax.map(lambda args: fn(*args), tuple(stacked))
    return jax.tree.map(
        lambda o: o.reshape((padded,) + o.shape[2:])[:nb], out)


@dataclasses.dataclass(frozen=True)
class HomomorphicCompressor:
    cfg: CompressionConfig

    # ------------------------------------------------------------------
    # Phase I — compression
    # ------------------------------------------------------------------

    def compress(self, x: jnp.ndarray, block_offset=0) -> CompressedLeaf:
        """``block_offset`` (static or traced int32) shifts the hash/
        rotation block ids — used by the bucketed aggregators so a bucket
        encoded on its own is bit-identical to its slice of the fused
        whole-stream encode (the block at stream position ``b`` always
        hashes as block ``b``)."""
        plan = make_plan(x.size, self.cfg)
        xb = to_blocks(x.astype(jnp.float32), plan)
        ids = jnp.arange(plan.nb, dtype=jnp.int32) + jnp.int32(block_offset)

        def enc(ids_c, xb_c):
            return ops.sketch_encode(xb_c, ids_c, self.cfg)

        sketch = _chunked_map(enc, plan.nb, self.cfg.chunk_blocks, ids, xb)
        if self.cfg.index == "bitmap":
            words = index_lib.pack_bits(index_lib.bitmap_build(xb))
        else:
            words = index_lib.bloom_build(xb, self.cfg)
        return CompressedLeaf(sketch=sketch, index_words=words)

    # ------------------------------------------------------------------
    # Phase II — recovery
    # ------------------------------------------------------------------

    def recover(self, comp: CompressedLeaf, n: int, shape=None,
                with_stats: bool = False, block_offset=0
                ) -> jnp.ndarray | Tuple[jnp.ndarray, RecoveryStats]:
        """``block_offset``: hash-plan id of the first block in
        ``comp`` — pass the same offset the sketch was encoded with when
        recovering a sub-range of a fused bucket stream (bitmap index
        only: a Bloom filter hashes global coordinates and cannot be
        sliced per-range)."""
        plan = make_plan(n, self.cfg)
        bshape = (plan.nb, plan.group, plan.lanes)
        if self.cfg.index == "bitmap":
            bits = index_lib.unpack_bits(comp.index_words, bshape)
        else:
            bits = index_lib.bloom_query(bshape, self.cfg, comp.index_words)
        ids = jnp.arange(plan.nb, dtype=jnp.int32) + jnp.int32(block_offset)

        def rec(ids_c, sk_c, bits_c):
            return ops.sketch_peel(sk_c, bits_c, ids_c, self.cfg)

        values, residual = _chunked_map(
            rec, plan.nb, self.cfg.chunk_blocks, ids, comp.sketch, bits)
        x = from_blocks(values, plan, shape)
        if not with_stats:
            return x
        nnz = jnp.sum(bits)
        n_residual = jnp.sum(residual.astype(jnp.int32))
        stats = RecoveryStats(
            nnz=nnz, peeled=nnz - n_residual,   # peeled == indexed & exact
            residual=n_residual, rounds=jnp.int32(self.cfg.rounds))
        return x, stats

    # ------------------------------------------------------------------
    # Lossy sketch-only decode (Sketched-SGD style) for ablations
    # ------------------------------------------------------------------

    def estimate(self, comp: CompressedLeaf, n: int, shape=None,
                 block_offset=0) -> jnp.ndarray:
        plan = make_plan(n, self.cfg)
        ids = jnp.arange(plan.nb, dtype=jnp.int32) + jnp.int32(block_offset)

        def est(ids_c, sk_c):
            return ops.sketch_estimate(sk_c, ids_c, self.cfg)

        values = _chunked_map(est, plan.nb, self.cfg.chunk_blocks, ids, comp.sketch)
        if self.cfg.index == "bitmap":
            bits = index_lib.unpack_bits(
                comp.index_words, (plan.nb, plan.group, plan.lanes))
            values = jnp.where(bits, values, 0.0)
        return from_blocks(values, plan, shape)

    # ------------------------------------------------------------------
    # Wire accounting
    # ------------------------------------------------------------------

    def wire_bytes(self, n: int, grad_bytes_per_elem: int = 2) -> dict:
        return self.cfg.wire_bytes(n, grad_bytes_per_elem)
