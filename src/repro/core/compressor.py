"""Top-level homomorphic compressor (paper Algorithm 1).

``HomomorphicCompressor`` turns a gradient leaf (any shape) into the wire
format ``CompressedLeaf(sketch, index_words)`` and back:

    compress:  X -> S(X) = [Y, B]          (phase I)
    recover :  S(sum X) -> sum X           (phase II, peeling + estimate)

Both directions are pure jittable functions of statically-planned shape.
Aggregation happens *between* the two calls and is someone else's job —
``psum`` for the sketch, OR-AllReduce for the index words (see
:mod:`repro.core.aggregators`, which feeds the compressor whole bucketed
gradient streams, and :mod:`repro.core.collectives` for the primitives) —
which is exactly the homomorphic contract of the paper: the aggregation
API never decompresses. ``block_offset`` lets a caller encode/recover a
sub-range of a larger bucket stream under the stream's global hash plan.

All sketch compute (encode, peel, estimate) goes through the backend
dispatch in :mod:`repro.kernels.ops`, so ``cfg.use_pallas`` selects the
Pallas TPU kernels or the jnp reference for every consumer of this class.

Large leaves are processed in chunks of ``cfg.chunk_blocks`` blocks via
``lax.map`` to bound peak memory (the (nb, G, 3, c) rotation intermediates
would otherwise dwarf the gradient itself).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import CompressionConfig
from .blocks import LeafPlan, make_plan, to_blocks, from_blocks
from . import index as index_lib


class CompressedLeaf(NamedTuple):
    """Wire format for one leaf. Sketch aggregates by +, words by |."""
    sketch: jnp.ndarray       # (nb, rows, lanes) f32
    index_words: jnp.ndarray  # (w,) uint32 — packed bitmap or Bloom filter


class RecoveryStats(NamedTuple):
    nnz: jnp.ndarray          # indexed coordinates (candidates)
    peeled: jnp.ndarray       # exactly recovered
    residual: jnp.ndarray     # fell back to median estimate
    rounds: jnp.ndarray       # peeling rounds used


def _chunked_map(fn, nb: int, chunk: int, *arrays):
    """lax.map ``fn`` over blocks in chunks; pads nb to a chunk multiple.

    ``arrays`` all have leading dim nb. Padding blocks are all-zero, which
    is harmless for both encode (zero sketch) and peel (empty index).
    """
    if nb <= chunk:
        return fn(*arrays)
    nchunks = -(-nb // chunk)
    padded = nchunks * chunk

    def pad(a):
        return jnp.pad(a, [(0, padded - nb)] + [(0, 0)] * (a.ndim - 1))

    stacked = [pad(a).reshape((nchunks, chunk) + a.shape[1:]) for a in arrays]
    out = jax.lax.map(lambda args: fn(*args), tuple(stacked))
    return jax.tree.map(
        lambda o: o.reshape((padded,) + o.shape[2:])[:nb], out)


@dataclasses.dataclass(frozen=True)
class HomomorphicCompressor:
    cfg: CompressionConfig

    # ------------------------------------------------------------------
    # Phase I — compression
    # ------------------------------------------------------------------

    def compress_wire(self, x: jnp.ndarray, block_offset=0
                      ) -> Tuple[CompressedLeaf, jnp.ndarray]:
        """One wire-producer pass: ``(CompressedLeaf, per-block maxabs)``.

        On fused-capable geometries (`ops.fused_wire_supported`) this is
        ONE pass over the gradient stream — sketch, packed bitmap and the
        per-block max magnitude come out of a single
        `ops.encode_pack_quantize` grid pass (the maxabs feeds the fxp32
        shared-exponent `pmax`; max is exact, so max-of-block-maxes ==
        bucket max, bit for bit). Bloom / unaligned geometries fall back
        to the composed encode-then-pack passes.

        ``block_offset`` (static or traced int32) shifts the hash/
        rotation block ids — used by the bucketed aggregators so a bucket
        encoded on its own is bit-identical to its slice of the fused
        whole-stream encode (the block at stream position ``b`` always
        hashes as block ``b``).
        """
        plan = make_plan(x.size, self.cfg)
        xb = to_blocks(x.astype(jnp.float32), plan)
        ids = jnp.arange(plan.nb, dtype=jnp.int32) + jnp.int32(block_offset)

        if ops.fused_wire_supported(self.cfg):
            def enc(ids_c, xb_c):
                return ops.encode_pack_quantize(xb_c, ids_c, self.cfg)

            sketch, words2d, maxabs = _chunked_map(
                enc, plan.nb, self.cfg.chunk_blocks, ids, xb)
            return (CompressedLeaf(sketch=sketch,
                                   index_words=words2d.reshape(-1)),
                    maxabs)

        def enc(ids_c, xb_c):
            return ops.sketch_encode(xb_c, ids_c, self.cfg)

        sketch = _chunked_map(enc, plan.nb, self.cfg.chunk_blocks, ids, xb)
        if self.cfg.index == "bitmap":
            words = index_lib.pack_bits(index_lib.bitmap_build(xb))
        else:
            words = index_lib.bloom_build(xb, self.cfg)
        maxabs = jnp.max(jnp.abs(sketch), axis=(1, 2))
        return CompressedLeaf(sketch=sketch, index_words=words), maxabs

    def compress(self, x: jnp.ndarray, block_offset=0) -> CompressedLeaf:
        """Wire payload only — see :meth:`compress_wire`."""
        return self.compress_wire(x, block_offset=block_offset)[0]

    def exchange_wire(self, lane_buckets: jnp.ndarray, block_offset=0
                      ) -> Tuple[CompressedLeaf, jnp.ndarray]:
        """One producer pass for the permute-pattern wire (PR 8).

        ``lane_buckets`` is one chunk of the all-to-all payload:
        ``(lanes, chunk_buckets, bucket_elems)`` — one bucket slab per
        destination lane, laid out chunk-major so the whole stack is a
        single *contiguous* block range starting at ``block_offset``.
        That keeps the PR 7 one-producer contract: the entire chunk —
        every lane — encodes in ONE :meth:`compress_wire` pass (one
        fused `encode_pack_quantize` grid on capable geometries), and
        the per-lane payloads are pure reshaped views of that pass:

            sketch      (lanes, lane_blocks, rows, cfg.lanes)
            index_words (lanes, lane_words)

        Lane ``d`` of the result is bit-identical to compressing lane
        ``d``'s slab alone at offset ``block_offset + d * lane_blocks``
        — the property the all-to-all merge relies on (every source
        rank encodes destination ``d``'s slab under the same hash ids,
        so the ppermuted sketches add homomorphically).  Also returns
        the per-block maxabs reshaped per lane, ``(lanes,
        lane_blocks)``.
        """
        lanes, nb_c, elems = lane_buckets.shape
        if elems % self.cfg.block_elems:
            raise ValueError(
                f"bucket_elems {elems} is not a whole number of sketch "
                f"blocks ({self.cfg.block_elems})")
        comp, maxabs = self.compress_wire(
            lane_buckets.reshape(-1), block_offset=block_offset)
        lane_blocks = (nb_c * elems) // self.cfg.block_elems
        sk = comp.sketch.reshape((lanes, lane_blocks) + comp.sketch.shape[1:])
        wd = comp.index_words.reshape(lanes, -1)
        return (CompressedLeaf(sketch=sk, index_words=wd),
                maxabs.reshape(lanes, lane_blocks))

    # ------------------------------------------------------------------
    # Phase II — recovery
    # ------------------------------------------------------------------

    def recover(self, comp: CompressedLeaf, n: int, shape=None,
                with_stats: bool = False, block_offset=0, dequant=None
                ) -> jnp.ndarray | Tuple[jnp.ndarray, RecoveryStats]:
        """``block_offset``: hash-plan id of the first block in
        ``comp`` — pass the same offset the sketch was encoded with when
        recovering a sub-range of a fused bucket stream (bitmap index
        only: a Bloom filter hashes global coordinates and cannot be
        sliced per-range).

        ``dequant``: optional ``(per_block_exponents (nb,) int32,
        mantissa_bits int)`` — the aggregated int32 fxp32 sketch is then
        dequantized *inside* the fused consumer pass (exponent-bitcast
        scale, see `net/fixedpoint.py`) instead of in a separate
        stream-sized op before peeling.

        On fused-capable geometries the whole receive side — bitmap
        unpack, optional dequant, peel — is ONE pass over the wire
        payload (`ops.dequant_peel_unpack`); recovery stats come from a
        `population_count` over the packed words, never materializing
        the unpacked bitmap outside the kernel.
        """
        plan = make_plan(n, self.cfg)
        bshape = (plan.nb, plan.group, plan.lanes)
        ids = jnp.arange(plan.nb, dtype=jnp.int32) + jnp.int32(block_offset)

        if ops.fused_wire_supported(self.cfg):
            wpb = self.cfg.block_elems // 32
            words2d = comp.index_words.reshape(plan.nb, wpb)
            if dequant is not None:
                exps, mbits = dequant

                def rec(ids_c, sk_c, w_c, e_c):
                    return ops.dequant_peel_unpack(
                        sk_c, w_c, ids_c, self.cfg,
                        exponents=e_c, mantissa_bits=mbits)

                values, residual = _chunked_map(
                    rec, plan.nb, self.cfg.chunk_blocks,
                    ids, comp.sketch, words2d,
                    jnp.asarray(exps, jnp.int32))
            else:
                def rec(ids_c, sk_c, w_c):
                    return ops.dequant_peel_unpack(sk_c, w_c, ids_c, self.cfg)

                values, residual = _chunked_map(
                    rec, plan.nb, self.cfg.chunk_blocks,
                    ids, comp.sketch, words2d)
            nnz = jnp.sum(jax.lax.population_count(comp.index_words)
                          ).astype(jnp.int32)
        else:
            if self.cfg.index == "bitmap":
                bits = index_lib.unpack_bits(comp.index_words, bshape)
            else:
                bits = index_lib.bloom_query(bshape, self.cfg,
                                             comp.index_words)
            sketch = comp.sketch
            if dequant is not None:
                exps, mbits = dequant
                from repro.net.fixedpoint import pow2
                scale = pow2(jnp.asarray(exps, jnp.int32) - int(mbits))
                sketch = sketch.astype(jnp.float32) * scale[:, None, None]

            def rec(ids_c, sk_c, bits_c):
                return ops.sketch_peel(sk_c, bits_c, ids_c, self.cfg)

            values, residual = _chunked_map(
                rec, plan.nb, self.cfg.chunk_blocks, ids, sketch, bits)
            nnz = jnp.sum(bits)
        x = from_blocks(values, plan, shape)
        if not with_stats:
            return x
        n_residual = jnp.sum(residual.astype(jnp.int32))
        stats = RecoveryStats(
            nnz=nnz, peeled=nnz - n_residual,   # peeled == indexed & exact
            residual=n_residual, rounds=jnp.int32(self.cfg.rounds))
        return x, stats

    # ------------------------------------------------------------------
    # Lossy sketch-only decode (Sketched-SGD style) for ablations
    # ------------------------------------------------------------------

    def estimate(self, comp: CompressedLeaf, n: int, shape=None,
                 block_offset=0) -> jnp.ndarray:
        plan = make_plan(n, self.cfg)
        ids = jnp.arange(plan.nb, dtype=jnp.int32) + jnp.int32(block_offset)

        def est(ids_c, sk_c):
            return ops.sketch_estimate(sk_c, ids_c, self.cfg)

        values = _chunked_map(est, plan.nb, self.cfg.chunk_blocks, ids, comp.sketch)
        if self.cfg.index == "bitmap":
            bits = index_lib.unpack_bits(
                comp.index_words, (plan.nb, plan.group, plan.lanes))
            values = jnp.where(bits, values, 0.0)
        return from_blocks(values, plan, shape)

    # ------------------------------------------------------------------
    # Wire accounting
    # ------------------------------------------------------------------

    def wire_bytes(self, n: int, grad_bytes_per_elem: int = 2) -> dict:
        return self.cfg.wire_bytes(n, grad_bytes_per_elem)
