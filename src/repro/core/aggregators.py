"""Aggregator strategies: bucketed gradient aggregation (PR 2).

The pre-bucketing pipeline unrolled a Python loop over every pytree leaf —
each leaf got its own codec plan, its own nested ``shard_map`` regions and
its own ``psum`` + OR-AllReduce launch, so a 100-leaf model compiled ~100
copies of the codec and paid ~100x collective launch latency. Here the
whole gradient is packed into fixed-byte flat buckets
(:mod:`repro.core.bucketing`) and aggregation is a pluggable strategy:

- :class:`DenseAggregator`              — plain ``psum`` (the paper's NCCL
  baseline arm);
- :class:`CompressedAggregator`         — ONE sketch encode over the packed
  stream, ONE stacked sketch-``psum`` and ONE OR-AllReduce for *all*
  buckets. With ``cfg.overlap`` the per-bucket collectives are staged
  against the next bucket's encode via a ``lax.scan`` double-buffer carry,
  so on hardware with async collectives bucket *i*'s wire time hides
  bucket *i+1*'s encode;
- :class:`CompressedReduceScatterAggregator` — recovers (peels) only this
  DP-rank's bucket range, 1/W of the peeling compute per rank, and
  reassembles via the same scatter+``psum`` trick the ZeRO-1 optimizer
  path uses (see ``train/step.py``). The sketch reduction is ``psum`` +
  local slice rather than a native ``psum_scatter``: XLA's
  reduce-scatter-creation pass can fuse the pair, and Shardy un-shards
  auto TP axes around manual-axis ``all_gather``/``psum_scatter`` (the
  same issue noted at the ZeRO-1 gather) — native lowering is a ROADMAP
  open item.

All strategies run *inside* the outer train-step ``shard_map`` (manual DP
axes). On JAX with nested partial-manual support, packing/unpacking runs
in a nested ``shard_map`` that takes the tensor-parallel axes manual too,
so each device packs only its local parameter shards — no GSPMD
resharding of gradients — while the codec and the DP collectives run at
the outer level on the shard-local buckets. On 0.4.x the packed stream is
the auto-sharded global view (same math; see ``repro.compat``).

Sparsification / error feedback are applied **per leaf** inside the pack
stage — identical semantics (and bits) to the per-leaf path this replaced,
pinned by ``tests/drivers/collectives_driver.py`` — and residuals keep the
parameter pytree layout. :meth:`BucketPlan.residual_slices` exposes the
per-bucket view of those residuals.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Protocol, Sequence, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from .config import CompressionConfig
from .compressor import HomomorphicCompressor, CompressedLeaf
from .bucketing import BucketPlan, make_bucket_plan
from .collectives import (AggregationState, dense_all_reduce, or_allreduce)
from . import topk as topk_lib


@runtime_checkable
class Aggregator(Protocol):
    """Strategy for aggregating a gradient pytree across the DP axes.

    Called inside a ``shard_map`` where the DP axes are manual. Returns
    the aggregated (mean) gradients and the new error-feedback state.
    """

    def __call__(self, grads: Any, state: AggregationState,
                 param_specs: Any) -> Tuple[Any, AggregationState]:
        ...


# ----------------------------------------------------------------------
# Dense (the NCCL-AllReduce baseline arm)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DenseAggregator:
    """Same constructor surface as the compressed strategies so the
    registry can build any entry uniformly; cfg/tp_axes/outer_manual are
    simply unused here."""

    mesh: Any
    dp_axes: Tuple[str, ...]
    cfg: Any = None
    tp_axes: Tuple[str, ...] = ()
    mean: bool = True
    outer_manual: Any = None

    def __call__(self, grads, state: AggregationState, param_specs=None):
        return dense_all_reduce(grads, self.dp_axes, mean=self.mean), state


# ----------------------------------------------------------------------
# Shared machinery for the compressed strategies
# ----------------------------------------------------------------------

def _tp_only(spec, dp_set):
    """Strip DP-axis references from a PartitionSpec (those axes are
    manual in the outer shard_map; nested regions partition TP only)."""
    if spec is None:
        return P()
    parts = []
    for s in spec:
        if s is None:
            parts.append(None)
        elif isinstance(s, (tuple, list)):
            kept = tuple(a for a in s if a not in dp_set)
            parts.append(kept if kept else None)
        else:
            parts.append(None if s in dp_set else s)
    return P(*parts)


def _spec_axes(spec) -> set:
    out = set()
    for part in spec:
        if part is None:
            continue
        out |= set(part) if isinstance(part, (tuple, list)) else {part}
    return out


def _local_shape(shape, spec, mesh):
    """Per-device shape of a leaf sharded as ``spec`` on ``mesh``."""
    def div(i):
        part = spec[i] if i < len(spec) else None
        if part is None:
            return 1
        names = part if isinstance(part, (tuple, list)) else (part,)
        d = 1
        for nm in names:
            d *= mesh.shape[nm]
        return d
    return tuple(sz // div(i) for i, sz in enumerate(shape))


def _sparsify_leaf(flat: jnp.ndarray, res: jnp.ndarray,
                   cfg: CompressionConfig):
    """Per-leaf phase-0: top-k budget + error feedback on one flat leaf.

    Identical math to the per-leaf path this layer replaced (pinned
    bit-for-bit by the collectives driver): k is proportional to *this
    leaf's* (shard-local) element count.
    """
    new_res = res
    if cfg.topk_ratio is not None:
        k = max(1, int(flat.shape[0] * cfg.topk_ratio))
        if cfg.error_feedback:
            flat, new_res = topk_lib.apply_error_feedback(
                flat, res.reshape(-1), k, exact=cfg.topk_exact)
        elif cfg.topk_exact:
            flat = topk_lib.sparsify_topk(flat, k)
        else:
            flat = topk_lib.sparsify_threshold(flat, k)
    return flat, new_res


@dataclasses.dataclass(frozen=True)
class CompressedAggregator:
    """The paper's pipeline over one fused bucket stream.

    pack (shard-local) -> per-leaf sparsify/EF -> encode all buckets ->
    sketch psum + index OR-AllReduce -> peel -> unpack.
    """

    cfg: CompressionConfig
    mesh: Any
    dp_axes: Tuple[str, ...]
    tp_axes: Tuple[str, ...] = ("model",)
    mean: bool = True
    # The axis set the *caller's* shard_map takes manual. Only consulted
    # by the reduce-scatter variant: on 0.4.x, axis_index in a
    # partial-auto region lowers to a PartitionId the old partitioner
    # rejects, so per-rank slicing needs either new JAX or a full-manual
    # caller (the 0.4.x train step is full-manual; see compat).
    outer_manual: Any = None

    # -- construction helpers ------------------------------------------

    def _n_workers(self) -> int:
        if not self.mean:
            return 1
        n = 1
        for ax in self.dp_axes:
            n *= self.mesh.shape[ax]
        return n

    def _manual_set(self, spec_leaves) -> set:
        """Axes the nested pack/unpack regions must take manual: the TP
        axes plus every axis any leaf's (DP-stripped) spec references
        (e.g. expert-parallel axes)."""
        manual = {a for a in self.tp_axes if a and a in self.mesh.shape}
        for spec in spec_leaves:
            manual |= _spec_axes(spec)
        return manual

    # -- phase I/II bucket codec (runs on shard-local buckets) ---------

    def _encode(self, buckets: jnp.ndarray, plan: BucketPlan,
                comp: HomomorphicCompressor, dp_idx):
        """(n_buckets, E) local buckets -> aggregated (sketch, words)."""
        if self.cfg.overlap and plan.n_buckets > 1:
            return self._encode_overlapped(buckets, plan, comp, dp_idx)
        c = comp.compress(buckets.reshape(-1))
        sk = jax.lax.psum(c.sketch, tuple(self.dp_axes))
        words = or_allreduce(c.index_words, self.dp_axes,
                             axis_indices=dp_idx)
        return sk, words

    def _encode_overlapped(self, buckets, plan: BucketPlan,
                           comp: HomomorphicCompressor, dp_idx):
        """Double-buffered staging: bucket i's collectives are issued in
        the same scan step as bucket i+1's encode, with no data
        dependence between them — async-collective backends overlap the
        wire with the MXU encode. Bit-identical to the fused path (same
        global block ids via block_offset; bitmap index slices exactly
        per bucket)."""
        cfg = self.cfg
        nbpb = plan.bucket_elems // cfg.block_elems   # blocks per bucket
        wpb = plan.bucket_elems // 32                 # bitmap words/bucket

        def enc(i, bucket):
            c = comp.compress(bucket, block_offset=i * nbpb)
            return c.sketch, c.index_words

        def reduce_one(sk, words):
            return (jax.lax.psum(sk, tuple(self.dp_axes)),
                    or_allreduce(words, self.dp_axes, axis_indices=dp_idx))

        sk0, w0 = enc(jnp.int32(0), buckets[0])

        def body(carry, xs):
            i, bucket = xs
            agg = reduce_one(*carry)
            return enc(i, bucket), agg

        idx = jnp.arange(1, plan.n_buckets, dtype=jnp.int32)
        (sk_l, w_l), (sks, ws) = jax.lax.scan(body, (sk0, w0),
                                              (idx, buckets[1:]))
        sk_last, w_last = reduce_one(sk_l, w_l)
        sk = jnp.concatenate([sks, sk_last[None]], axis=0)
        words = jnp.concatenate([ws, w_last[None]], axis=0)
        # (n_buckets, nbpb, rows, lanes) / (n_buckets, wpb) -> fused views
        return (sk.reshape(plan.n_buckets * nbpb, cfg.rows, cfg.lanes),
                words.reshape(plan.n_buckets * wpb))

    def _recover(self, sk, words, plan: BucketPlan,
                 comp: HomomorphicCompressor, dp_idx, dp_rank):
        """Aggregated (sketch, words) -> recovered (n_buckets, E)."""
        rec = comp.recover(CompressedLeaf(sketch=sk, index_words=words),
                           plan.padded)
        return rec.reshape(plan.n_buckets, plan.bucket_elems)

    # -- the strategy --------------------------------------------------

    def __call__(self, grads, state: AggregationState, param_specs):
        cfg = self.cfg
        comp = HomomorphicCompressor(cfg)
        mesh = self.mesh
        dp_set = set(self.dp_axes)
        n_workers = self._n_workers()
        ef_on = cfg.topk_ratio is not None and cfg.error_feedback

        leaves, treedef = jax.tree.flatten(grads)
        spec_leaves = [_tp_only(s, dp_set)
                       for s in treedef.flatten_up_to(param_specs)]
        res_tree = state.residual
        res_specs = jax.tree.unflatten(
            treedef, [s if ef_on else P() for s in spec_leaves])
        specs = jax.tree.unflatten(treedef, spec_leaves)

        # Shard indices on the (outer-manual) DP axes, computed here where
        # those axes are directly bound; threaded into the OR-rings because
        # axis_index inside nested regions would re-bind the axis (Shardy).
        dp_idx = {ax: jax.lax.axis_index(ax) for ax in self.dp_axes}
        dp_rank = jnp.int32(0)
        for ax in self.dp_axes:
            dp_rank = dp_rank * mesh.shape[ax] + dp_idx[ax]

        manual = self._manual_set(spec_leaves)
        nested = bool(manual) and compat.SUPPORTS_NESTED_SHARD_MAP
        if nested:
            local_shapes = [
                _local_shape(g.shape, s, mesh)
                for g, s in zip(leaves, spec_leaves)]
        else:
            # Pure DP, or a JAX without nested partial-manual shard_map:
            # pack the auto-sharded global view (same compress -> psum/OR
            # -> recover math; nesting only avoids GSPMD resharding).
            local_shapes = [tuple(g.shape) for g in leaves]
        plan = make_bucket_plan(
            grads, cfg, shapes=jax.tree.unflatten(treedef, local_shapes))

        def pack_stage(g_tree, r_tree):
            """Shard-local: per-leaf sparsify/EF, then bucket-pack."""
            g_leaves = plan.treedef.flatten_up_to(g_tree)
            r_leaves = plan.treedef.flatten_up_to(r_tree)
            flats, new_res = [], []
            for g, r in zip(g_leaves, r_leaves):
                flat, nr = _sparsify_leaf(
                    g.reshape(-1).astype(jnp.float32), r, cfg)
                flats.append(flat)
                new_res.append(nr.reshape(r.shape))
            return (plan.pack_flat(flats),
                    jax.tree.unflatten(plan.treedef, new_res))

        def unpack_stage(buckets):
            """Shard-local: bucket stream -> leaf pytree (mean)."""
            return plan.unpack(buckets / n_workers)

        if nested:
            enc = compat.shard_map(
                pack_stage, mesh=mesh, in_specs=(specs, res_specs),
                out_specs=(P(), res_specs), axis_names=manual,
                check_vma=False)
            buckets, new_res = enc(grads, res_tree)
        else:
            buckets, new_res = pack_stage(grads, res_tree)

        sk, words = self._encode(buckets, plan, comp, dp_idx)
        rec = self._recover(sk, words, plan, comp, dp_idx, dp_rank)

        if nested:
            dec = compat.shard_map(
                unpack_stage, mesh=mesh, in_specs=(P(),),
                out_specs=specs, axis_names=manual, check_vma=False)
            agg = dec(rec)
        else:
            agg = unpack_stage(rec)
        return agg, AggregationState(residual=new_res)


@dataclasses.dataclass(frozen=True)
class CompressedReduceScatterAggregator(CompressedAggregator):
    """Bucketed compressed aggregation that peels only this DP-rank's
    bucket range.

    Phase I is identical to :class:`CompressedAggregator`. Phase II
    reduces the stacked sketch across DP, slices this rank's
    ``n_buckets/W`` range, peels *only that range* (1/W of the recovery
    compute per rank), and reassembles the recovered buckets with the
    zero-pad + ``psum`` gather the ZeRO-1 slice-update path uses. That
    feeds ZeRO-1 sharded optimizers without every rank paying the full
    peel; recovered values are bit-identical to the all-ranks path (the
    per-range peel runs the same ops on the same sketch slice, and the
    disjoint-chunk psum adds each value to zeros exactly once).
    """

    def _recover(self, sk, words, plan: BucketPlan,
                 comp: HomomorphicCompressor, dp_idx, dp_rank):
        cfg = self.cfg
        if cfg.index != "bitmap":
            raise ValueError(
                "compressed_rs requires index='bitmap' (a Bloom filter "
                "hashes global coordinates and cannot be sliced per-rank)")
        mesh_axes = set(self.mesh.axis_names)
        full_manual = (self.outer_manual is not None
                       and mesh_axes <= set(self.outer_manual))
        if not (compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE or full_manual):
            # 0.4.x partial-auto caller: the rank (axis_index) cannot be
            # lowered — degrade to all-ranks peeling (same values, no
            # per-rank compute scattering). See ``outer_manual``.
            return CompressedAggregator._recover(
                self, sk, words, plan, comp, dp_idx, dp_rank)
        W = 1
        for ax in self.dp_axes:
            W *= self.mesh.shape[ax]
        nbpb = plan.bucket_elems // cfg.block_elems
        wpb = plan.bucket_elems // 32
        nb_p = -(-plan.n_buckets // W) * W      # buckets padded to W ranks
        pad_b = nb_p - plan.n_buckets
        if pad_b:
            # zero sketch blocks / zero index words peel to exact zeros
            sk = jnp.pad(sk, ((0, pad_b * nbpb), (0, 0), (0, 0)))
            words = jnp.pad(words, (0, pad_b * wpb))
        chunk_b = nb_p // W                      # buckets per rank
        chunk_elems = chunk_b * plan.bucket_elems
        sk_loc = jax.lax.dynamic_slice_in_dim(
            sk, dp_rank * chunk_b * nbpb, chunk_b * nbpb, axis=0)
        w_loc = jax.lax.dynamic_slice_in_dim(
            words, dp_rank * chunk_b * wpb, chunk_b * wpb, axis=0)
        rec_loc = comp.recover(
            CompressedLeaf(sketch=sk_loc, index_words=w_loc), chunk_elems,
            block_offset=dp_rank * chunk_b * nbpb)
        # Disjoint-chunk gather via zero-pad + psum (see class docstring
        # and the ZeRO-1 note in train/step.py on manual-axis all_gather).
        full = jnp.zeros((nb_p * plan.bucket_elems,), rec_loc.dtype)
        full = jax.lax.dynamic_update_slice_in_dim(
            full, rec_loc, dp_rank * chunk_elems, axis=0)
        full = jax.lax.psum(full, tuple(self.dp_axes))
        return full[:plan.padded].reshape(plan.n_buckets, plan.bucket_elems)


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------

AGGREGATORS = {
    "dense": DenseAggregator,
    "compressed": CompressedAggregator,
    "compressed_rs": CompressedReduceScatterAggregator,
}


def make_aggregator(name: str, cfg: CompressionConfig, mesh,
                    dp_axes: Sequence[str],
                    tp_axes: Sequence[str] = ("model",),
                    mean: bool = True, outer_manual=None) -> Aggregator:
    """Build the named strategy (see :data:`AGGREGATORS`).

    ``outer_manual``: the axis set the calling shard_map takes manual
    (see :class:`CompressedAggregator.outer_manual`).
    """
    if isinstance(dp_axes, str):
        dp_axes = (dp_axes,)
    if isinstance(tp_axes, str):
        tp_axes = (tp_axes,)
    try:
        cls = AGGREGATORS[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {name!r}; have {sorted(AGGREGATORS)}")
    return cls(cfg=cfg, mesh=mesh, dp_axes=tuple(dp_axes),
               tp_axes=tuple(tp_axes), mean=mean,
               outer_manual=None if outer_manual is None
               else tuple(outer_manual))
