"""Aggregator strategies: bucketed gradient aggregation (PR 2).

The pre-bucketing pipeline unrolled a Python loop over every pytree leaf —
each leaf got its own codec plan, its own nested ``shard_map`` regions and
its own ``psum`` + OR-AllReduce launch, so a 100-leaf model compiled ~100
copies of the codec and paid ~100x collective launch latency. Here the
whole gradient is packed into fixed-byte flat buckets
(:mod:`repro.core.bucketing`) and aggregation is a pluggable strategy:

- :class:`DenseAggregator`              — plain ``psum`` (the paper's NCCL
  baseline arm);
- :class:`CompressedAggregator`         — ONE sketch encode over the packed
  stream, ONE stacked sketch-``psum`` and ONE OR-AllReduce for *all*
  buckets. With ``cfg.overlap`` / ``cfg.stream_chunks`` the wire is cut
  into whole-bucket chunks and driven through the shared
  :func:`repro.core.streams.stream_schedule` double-buffer pipeline, so
  on hardware with async collectives chunk *i*'s wire time hides chunk
  *i+1*'s encode;
- :class:`CompressedReduceScatterAggregator` — the native reduce-scatter
  wire path (PR 3): the sketch reduces with ``jax.lax.psum_scatter`` and
  the bitmap with the ppermute-ring
  :func:`~repro.core.collectives.or_reduce_scatter`, so each rank
  *receives* only its own ``n_buckets/W`` sketch+bitmap slice (1/W the
  reduced payload of the AllReduce strategies — the paper's full
  reduce-scatter bandwidth win), peels only that range (1/W of the
  recovery compute), and reassembles the recovered chunks with a
  manual-axis ``all_gather`` (full-manual regions) or the zero-pad +
  ``psum`` ZeRO-1 gather trick (partial-auto, where Shardy would
  un-shard auto TP axes around the gather). Gated by
  ``compat.SUPPORTS_PSUM_SCATTER`` / a full-manual caller, with the
  older ``psum`` + local-slice emulation kept as the 0.4.x partial-auto
  fallback (AllReduce wire, per-rank peel compute only); the
  ``cfg.rs_wire`` knob forces either path. Overlap is honored on the
  native wire too: the stream scheduler stages per-chunk
  ``psum_scatter``/OR-Reduce-Scatter calls over chunks of whole
  per-rank bucket runs, and when the chunk grid aligns with the ZeRO-1
  optimizer slices (``zero1_dims``) the per-rank recovered chunks feed
  the optimizer shards directly and the recovered-chunk all_gather is
  skipped entirely.
- :class:`CompressedInNetworkAggregator` — the in-network tier (PR 4):
  the stream goes up an emulated worker->ToR->spine switch tree
  (:mod:`repro.net`) once per worker — integer-add sketch (via the
  fixed-point wire when ``cfg.wire_dtype='fxp32'``) and OR bitmap —
  instead of around a ring, so the hottest (root) link carries ``1 x``
  the payload per direction vs the ring's ``2(W-1)/W x``.

Plan/execute split (PR 6): every compressed strategy is now a per-group
*executor* behind a :class:`~repro.core.wireplan.WirePlan`.  A fixed
strategy executes the degenerate uniform plan (one group, its own wire —
byte-for-byte today's jaxprs), while a non-trivial ``wire_plan`` splits
the bucket stream into contiguous groups and runs each group through the
assigned wire's executor at its global block offsets
(``StreamPlan.base_block``), so any mixed plan is bit-for-bit the fixed
strategies it composes on the buckets it assigns.  The 5th registry
entry ``auto`` (:class:`WirePlannedAggregator`) executes plans produced
by the :mod:`repro.core.costmodel` controller and measures the per-bucket
occupancy telemetry the controller feeds on.

All strategies run *inside* the outer train-step ``shard_map`` (manual DP
axes). On JAX with nested partial-manual support, packing/unpacking runs
in a nested ``shard_map`` that takes the tensor-parallel axes manual too,
so each device packs only its local parameter shards — no GSPMD
resharding of gradients — while the codec and the DP collectives run at
the outer level on the shard-local buckets. On 0.4.x the packed stream is
the auto-sharded global view (same math; see ``repro.compat``).

Sparsification / error feedback are applied **per leaf** inside the pack
stage — identical semantics (and bits) to the per-leaf path this replaced,
pinned by ``tests/drivers/collectives_driver.py`` — and residuals keep the
parameter pytree layout. :meth:`BucketPlan.residual_slices` exposes the
per-bucket view of those residuals.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Protocol, Sequence, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import functools

from repro import compat
from repro.net.fixedpoint import FixedPointWire
from repro.net.topology import make_topology, tree_all_reduce
from .config import CompressionConfig
from .compressor import HomomorphicCompressor, CompressedLeaf
from .bucketing import BucketPlan, make_bucket_plan, make_dest_bucket_plans
from .collectives import (AggregationState, alltoall_lane_sum,
                          dense_all_reduce, gather_chunk_slices, linear_rank,
                          or_allreduce, or_reduce_scatter, sketch_all_to_all)
from .streams import (StreamPlan, make_alltoall_stream_plan, make_stream_plan,
                      stream_schedule, zero1_gather_skip)
from .wireplan import WIRES, WirePlan, pattern_wires, uniform_plan
from . import topk as topk_lib


@runtime_checkable
class Aggregator(Protocol):
    """Strategy for aggregating a gradient pytree across the DP axes.

    Called inside a ``shard_map`` where the DP axes are manual. Returns
    the aggregated (mean) gradients and the new error-feedback state.
    """

    def __call__(self, grads: Any, state: AggregationState,
                 param_specs: Any) -> Tuple[Any, AggregationState]:
        ...


# ----------------------------------------------------------------------
# Dense (the NCCL-AllReduce baseline arm)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DenseAggregator:
    """Same constructor surface as the compressed strategies so the
    registry can build any entry uniformly; cfg/tp_axes/outer_manual are
    simply unused here."""

    wire = "dense"  # the WirePlan wire this strategy is the executor for

    mesh: Any
    dp_axes: Tuple[str, ...]
    cfg: Any = None
    tp_axes: Tuple[str, ...] = ()
    mean: bool = True
    outer_manual: Any = None
    zero1_dims: Any = None
    wire_plan: Any = None  # ctor uniformity only: dense groups of a
                           # mixed plan run inline in the compressed
                           # executors (a psum needs no codec plumbing)

    def __call__(self, grads, state: AggregationState, param_specs=None):
        if self.wire_plan is not None:
            raise ValueError(
                "DenseAggregator does not execute wire plans; use the "
                "'auto' strategy (or a compressed strategy with "
                "wire_plan=...) for per-bucket-group wires")
        return dense_all_reduce(grads, self.dp_axes, mean=self.mean), state


# ----------------------------------------------------------------------
# Shared machinery for the compressed strategies
# ----------------------------------------------------------------------

def _tp_only(spec, dp_set):
    """Strip DP-axis references from a PartitionSpec (those axes are
    manual in the outer shard_map; nested regions partition TP only)."""
    if spec is None:
        return P()
    parts = []
    for s in spec:
        if s is None:
            parts.append(None)
        elif isinstance(s, (tuple, list)):
            kept = tuple(a for a in s if a not in dp_set)
            parts.append(kept if kept else None)
        else:
            parts.append(None if s in dp_set else s)
    return P(*parts)


def _spec_axes(spec) -> set:
    out = set()
    for part in spec:
        if part is None:
            continue
        out |= set(part) if isinstance(part, (tuple, list)) else {part}
    return out


def _local_shape(shape, spec, mesh):
    """Per-device shape of a leaf sharded as ``spec`` on ``mesh``."""
    def div(i):
        part = spec[i] if i < len(spec) else None
        if part is None:
            return 1
        names = part if isinstance(part, (tuple, list)) else (part,)
        d = 1
        for nm in names:
            d *= mesh.shape[nm]
        return d
    return tuple(sz // div(i) for i, sz in enumerate(shape))


def sparsify_leaf(flat: jnp.ndarray, res: jnp.ndarray,
                  cfg: CompressionConfig):
    """Per-leaf phase-0: top-k budget + error feedback on one flat leaf.

    Identical math to the per-leaf path this layer replaced (pinned
    bit-for-bit by the collectives driver): k is proportional to *this
    leaf's* (shard-local) element count. Public because the elastic
    client (``repro.elastic.client``) must sparsify with exactly these
    semantics for its folds to be bit-identical to the in-mesh
    strategies.
    """
    new_res = res
    if cfg.topk_ratio is not None:
        k = max(1, int(flat.shape[0] * cfg.topk_ratio))
        if cfg.error_feedback:
            flat, new_res = topk_lib.apply_error_feedback(
                flat, res.reshape(-1), k, exact=cfg.topk_exact)
        elif cfg.topk_exact:
            flat = topk_lib.sparsify_topk(flat, k)
        else:
            flat = topk_lib.sparsify_threshold(flat, k)
    return flat, new_res


_sparsify_leaf = sparsify_leaf      # internal call sites / back-compat


@dataclasses.dataclass(frozen=True)
class CompressedAggregator:
    """The paper's pipeline over one fused bucket stream.

    pack (shard-local) -> per-leaf sparsify/EF -> encode all buckets ->
    sketch psum + index OR-AllReduce -> peel -> unpack.
    """

    wire = "compressed"        # the WirePlan wire this class executes
    collect_telemetry = False  # WirePlannedAggregator flips this

    cfg: CompressionConfig
    mesh: Any
    dp_axes: Tuple[str, ...]
    tp_axes: Tuple[str, ...] = ("model",)
    mean: bool = True
    # The axis set the *caller's* shard_map takes manual. Only consulted
    # by the reduce-scatter variant: on 0.4.x, axis_index in a
    # partial-auto region lowers to a PartitionId the old partitioner
    # rejects, so per-rank slicing needs either new JAX or a full-manual
    # caller (the 0.4.x train step is full-manual; see compat).
    outer_manual: Any = None
    # Per-leaf ZeRO-1 slice dims (from streams.zero_slice_dim, in
    # flattened-leaf order; None entries = unsliced leaves). Only the
    # reduce-scatter variant consults it — when the stream chunk grid
    # aligns with these slices, its recovered-chunk all_gather is
    # skipped and each rank feeds its optimizer shard directly.
    zero1_dims: Any = None
    # Explicit per-bucket-group wire assignment (PR 6). None = the
    # degenerate uniform plan on this strategy's own wire, i.e. exactly
    # the pre-PR-6 behaviour (same jaxprs). A non-trivial WirePlan runs
    # each group through the assigned wire's executor; see
    # :meth:`_execute_plan`.
    wire_plan: Any = None
    # Global hash-plan block id of this executor's first bucket —
    # nonzero only on group delegates, so a group's encode/peel hash
    # exactly like the corresponding slice of the full-stream pass.
    base_block: int = 0

    # -- construction helpers ------------------------------------------

    def _n_workers(self) -> int:
        if not self.mean:
            return 1
        return self._dp_world()

    def _dp_world(self) -> int:
        W = 1
        for ax in self.dp_axes:
            W *= self.mesh.shape[ax]
        return W

    def _full_manual(self) -> bool:
        return (self.outer_manual is not None
                and compat.full_manual_region(self.outer_manual, self.mesh))

    def _manual_set(self, spec_leaves) -> set:
        """Axes the nested pack/unpack regions must take manual: the TP
        axes plus every axis any leaf's (DP-stripped) spec references
        (e.g. expert-parallel axes)."""
        manual = {a for a in self.tp_axes if a and a in self.mesh.shape}
        for spec in spec_leaves:
            manual |= _spec_axes(spec)
        return manual

    # -- phase I/II bucket codec (runs on shard-local buckets) ---------

    def _stream_plan(self, plan: BucketPlan) -> StreamPlan:
        """The wire-chunk grid for this strategy (subclasses align it to
        their wire's boundaries — per-rank RS chunks, switch windows)."""
        return make_stream_plan(plan, self.cfg, base_block=self.base_block)

    def _reduce_allreduce(self, dp_idx):
        """The AllReduce wire for one (sketch, words) payload chunk."""
        def red(payload):
            sk, words = payload
            return (jax.lax.psum(sk, tuple(self.dp_axes)),
                    or_allreduce(words, self.dp_axes, axis_indices=dp_idx))
        return red

    def _encode_streamed(self, buckets, splan: StreamPlan,
                         comp: HomomorphicCompressor, reduce_fn,
                         with_maxabs: bool = False):
        """Per-chunk encode + wire through the shared scheduler.

        Each chunk makes ONE producer-op pass over its gradient slice
        (`HomomorphicCompressor.compress_wire` — fused sketch + packed
        bitmap + per-block maxabs on fused-capable geometries) and hands
        the payload to ``reduce_fn`` for the collectives. Returns the
        reduced per-chunk payloads stacked on a leading ``n_chunks`` dim
        (whatever shapes ``reduce_fn`` emits). Bit-identical to the
        one-shot path: each chunk encodes under the stream's global hash
        plan via ``block_offset``, the bitmap slices exactly per bucket,
        and padding buckets are zeros end to end.

        ``with_maxabs``: include the per-block max magnitudes in the
        per-chunk payload (the fxp32 wire's exponent ingredient — free
        on the fused path, where the producer kernel emits it anyway).
        """
        def enc(i, chunk):
            leaf, mx = comp.compress_wire(
                chunk.reshape(-1),
                block_offset=splan.chunk_start_block(i))
            if with_maxabs:
                return leaf.sketch, leaf.index_words, mx
            return leaf.sketch, leaf.index_words

        return stream_schedule(splan.chunk_view(buckets), enc, reduce_fn)

    def _trim_fused(self, stacked_sk, stacked_words, plan: BucketPlan,
                    splan: StreamPlan):
        """Stacked per-chunk (sketch, words) -> fused full-stream views,
        padding chunks dropped."""
        cfg = self.cfg
        sk = stacked_sk.reshape(-1, cfg.rows, cfg.lanes)
        words = stacked_words.reshape(-1)
        return (sk[:plan.n_buckets * splan.blocks_per_bucket],
                words[:plan.n_buckets * splan.words_per_bucket])

    def _encode(self, buckets: jnp.ndarray, plan: BucketPlan,
                comp: HomomorphicCompressor, dp_idx):
        """(n_buckets, E) local buckets -> aggregated wire payload.

        The wire-contract half of PR 7: every strategy's ``_encode``
        makes ONE producer-op pass over the bucket stream (fused
        sketch + pack (+ maxabs) via ``compress``/``compress_wire``)
        before its collectives, and returns a payload tuple its own
        ``_recover`` consumes in ONE consumer-op pass after them. This
        class's payload is ``(sketch, words)``; subclasses may extend it
        (the fxp32 tree adds the shared exponents)."""
        splan = self._stream_plan(plan)
        if not splan.streamed:
            c = comp.compress(buckets.reshape(-1),
                              block_offset=self.base_block)
            sk = jax.lax.psum(c.sketch, tuple(self.dp_axes))
            words = or_allreduce(c.index_words, self.dp_axes,
                                 axis_indices=dp_idx)
            return sk, words
        sks, ws = self._encode_streamed(buckets, splan, comp,
                                        self._reduce_allreduce(dp_idx))
        return self._trim_fused(sks, ws, plan, splan)

    def _recover(self, payload, plan: BucketPlan,
                 comp: HomomorphicCompressor, dp_idx, dp_rank,
                 spec_leaves=None):
        """Aggregated wire payload -> recovered (n_buckets, E), in ONE
        consumer-op pass (fused unpack + peel via ``recover``).

        ``spec_leaves``: the leaves' DP-stripped PartitionSpecs — only
        the reduce-scatter subclass consults them (the gather-skip path
        must know whether the packed stream is a TP-local view)."""
        sk, words = payload
        rec = comp.recover(CompressedLeaf(sketch=sk, index_words=words),
                           plan.padded, block_offset=self.base_block)
        return rec.reshape(plan.n_buckets, plan.bucket_elems)

    # -- plan / execute (PR 6) -----------------------------------------

    def _wire_plan(self, plan: BucketPlan) -> WirePlan:
        """The WirePlan this pass executes: the explicit one when set,
        else the degenerate uniform plan on this strategy's own wire."""
        if self.wire_plan is not None:
            if self.wire_plan.n_buckets != plan.n_buckets:
                raise ValueError(
                    f"wire_plan covers {self.wire_plan.n_buckets} "
                    f"buckets, stream has {plan.n_buckets}")
            return self.wire_plan
        return uniform_plan(plan.n_buckets, self.wire)

    def _group_delegate(self, group, base_block: int):
        """The executor instance for one wire group: the group wire's
        registry class, offset to the group's global block position.
        Group delegates never gather-skip (``zero1_dims=None``): the
        ZeRO-1 alignment math is defined on the full stream."""
        cfg = self.cfg if group.stream_chunks is None else \
            dataclasses.replace(self.cfg, stream_chunks=group.stream_chunks)
        return AGGREGATORS[group.wire](
            cfg=cfg, mesh=self.mesh, dp_axes=self.dp_axes,
            tp_axes=self.tp_axes, mean=self.mean,
            outer_manual=self.outer_manual, zero1_dims=None,
            base_block=base_block)

    def _run_group(self, buckets, plan: BucketPlan,
                   comp: HomomorphicCompressor, dp_idx, dp_rank):
        """Execute one group's encode -> wire -> recover on this
        executor's own wire (``plan`` is the group view; ``buckets`` its
        row slice of the packed stream)."""
        payload = self._encode(buckets, plan, comp, dp_idx)
        return self._recover(payload, plan, comp, dp_idx, dp_rank)

    def _execute_plan(self, buckets, plan: BucketPlan,
                      comp: HomomorphicCompressor, dp_idx, dp_rank,
                      spec_leaves=None):
        """(n_buckets, E) local buckets -> aggregated (n_buckets, E).

        The trivial uniform plan on this strategy's own wire takes the
        exact pre-PR-6 path over the original BucketPlan (same jaxprs —
        gather-skip and ZeRO-1 plumbing intact). Otherwise each group
        runs through its wire's executor at its global block offsets:
        dense groups are a plain ``psum`` of the packed f32 stream (the
        mean lands at unpack with everyone else's), compressed groups
        re-dispatch through the registry. Per-leaf sparsify/EF already
        happened at pack, so every group is bit-for-bit the fixed
        strategy it names on the buckets it covers (dense groups match
        the compressed wires bitwise in the lossless regime, where
        recovery is exact).
        """
        wplan = self._wire_plan(plan)
        if wplan.is_trivial and wplan.groups[0].wire == self.wire:
            payload = self._encode(buckets, plan, comp, dp_idx)
            return self._recover(payload, plan, comp, dp_idx, dp_rank,
                                 spec_leaves=spec_leaves)
        nbpb = plan.blocks_per_bucket(self.cfg)
        parts = []
        for g in wplan.groups:
            bgroup = buckets[g.start:g.stop]
            if g.wire == "dense":
                parts.append(jax.lax.psum(bgroup, tuple(self.dp_axes)))
                continue
            gview = plan.group_view(g.start, g.n_buckets)
            delegate = self._group_delegate(g, base_block=g.start * nbpb)
            parts.append(delegate._run_group(
                bgroup, gview, HomomorphicCompressor(delegate.cfg),
                dp_idx, dp_rank))
        return jnp.concatenate(parts, axis=0)

    # -- the strategy --------------------------------------------------

    def __call__(self, grads, state: AggregationState, param_specs):
        cfg = self.cfg
        comp = HomomorphicCompressor(cfg)
        mesh = self.mesh
        dp_set = set(self.dp_axes)
        n_workers = self._n_workers()
        ef_on = cfg.topk_ratio is not None and cfg.error_feedback

        leaves, treedef = jax.tree.flatten(grads)
        spec_leaves = [_tp_only(s, dp_set)
                       for s in treedef.flatten_up_to(param_specs)]
        res_tree = state.residual
        res_specs = jax.tree.unflatten(
            treedef, [s if ef_on else P() for s in spec_leaves])
        specs = jax.tree.unflatten(treedef, spec_leaves)

        # Shard indices on the (outer-manual) DP axes, computed here where
        # those axes are directly bound; threaded into the OR-rings because
        # axis_index inside nested regions would re-bind the axis (Shardy).
        dp_idx = {ax: jax.lax.axis_index(ax) for ax in self.dp_axes}
        dp_rank = linear_rank(self.dp_axes, dp_idx)

        manual = self._manual_set(spec_leaves)
        nested = bool(manual) and compat.SUPPORTS_NESTED_SHARD_MAP
        if nested:
            local_shapes = [
                _local_shape(g.shape, s, mesh)
                for g, s in zip(leaves, spec_leaves)]
        else:
            # Pure DP, or a JAX without nested partial-manual shard_map:
            # pack the auto-sharded global view (same compress -> psum/OR
            # -> recover math; nesting only avoids GSPMD resharding).
            local_shapes = [tuple(g.shape) for g in leaves]
        plan = make_bucket_plan(
            grads, cfg, shapes=jax.tree.unflatten(treedef, local_shapes))

        def pack_stage(g_tree, r_tree):
            """Shard-local: per-leaf sparsify/EF, then bucket-pack."""
            g_leaves = plan.treedef.flatten_up_to(g_tree)
            r_leaves = plan.treedef.flatten_up_to(r_tree)
            flats, new_res = [], []
            for g, r in zip(g_leaves, r_leaves):
                flat, nr = _sparsify_leaf(
                    g.reshape(-1).astype(jnp.float32), r, cfg)
                flats.append(flat)
                new_res.append(nr.reshape(r.shape))
            return (plan.pack_flat(flats),
                    jax.tree.unflatten(plan.treedef, new_res))

        def unpack_stage(buckets):
            """Shard-local: bucket stream -> leaf pytree (mean)."""
            return plan.unpack(buckets / n_workers)

        if nested:
            enc = compat.shard_map(
                pack_stage, mesh=mesh, in_specs=(specs, res_specs),
                out_specs=(P(), res_specs), axis_names=manual,
                check_vma=False)
            buckets, new_res = enc(grads, res_tree)
        else:
            buckets, new_res = pack_stage(grads, res_tree)

        rec = self._execute_plan(buckets, plan, comp, dp_idx, dp_rank,
                                 spec_leaves=spec_leaves)

        if nested:
            dec = compat.shard_map(
                unpack_stage, mesh=mesh, in_specs=(P(),),
                out_specs=specs, axis_names=manual, check_vma=False)
            agg = dec(rec)
        else:
            agg = unpack_stage(rec)
        telemetry = None
        if self.collect_telemetry:
            # Per-bucket nonzero fraction of the aggregated stream —
            # identical on every rank (the recovered stream is), so the
            # train step may psum/average it freely. The controller
            # compares it against the peeling capacity to rule the
            # compressed wires in or out per bucket.
            telemetry = {"bucket_occupancy": jnp.mean(
                (rec != 0).astype(jnp.float32), axis=1)}
        return agg, AggregationState(residual=new_res, telemetry=telemetry)


@dataclasses.dataclass(frozen=True)
class CompressedReduceScatterAggregator(CompressedAggregator):
    """Bucketed compressed aggregation over a reduce-scattered wire.

    Phase I (pack/sparsify/encode) is identical to
    :class:`CompressedAggregator`. Phase II comes in two wire paths,
    selected by ``cfg.rs_wire`` and the capability map:

    **Native** (``compat.SUPPORTS_PSUM_SCATTER``, or any JAX when the
    caller's region is full-manual): the stacked sketch reduces with
    ``jax.lax.psum_scatter`` and the bitmap with the ring
    :func:`~repro.core.collectives.or_reduce_scatter`, both padded to
    whole per-rank chunks of ``nb_p/W`` buckets, so each rank *receives*
    only its own sketch+bitmap slice — 1/W the reduced payload (and
    roughly half the link traffic) of the AllReduce strategies. The rank
    peels its range (1/W of the recovery compute, hash ids offset to the
    chunk's global block position) and the recovered chunks reassemble
    with a manual-axis ``all_gather`` in full-manual regions, else the
    zero-pad + ``psum`` ZeRO-1 gather trick (Shardy un-shards auto TP
    axes around a partial-auto manual-axis all_gather; see
    train/step.py).

    ``cfg.overlap`` / ``cfg.stream_chunks`` are honored on the native
    wire (PR 5): the shared stream scheduler cuts the payload into
    chunks of whole *per-rank bucket runs* (``chunk_buckets = k * W``,
    so every per-chunk ``psum_scatter`` / OR-Reduce-Scatter lands whole
    buckets on their peeling rank — the chunk count must divide
    ``ceil(n_buckets/W)``, ValueError otherwise), pipelines chunk
    ``i``'s scatter against chunk ``i+1``'s encode, and peels each
    received slice at its global block offset. Reassembly restores the
    exact one-shot stream
    (:func:`~repro.core.collectives.gather_chunk_slices`) — unless the
    chunk grid aligns with the ZeRO-1 optimizer slices (``zero1_dims``;
    :func:`repro.core.streams.zero1_gather_skip`), in which case each
    rank already holds every gradient value its optimizer shard
    consumes, the recovered-chunk all_gather is skipped, and the
    returned leaves are exact inside this rank's owned coordinates and
    zero outside (the train step reduces the grad-norm across ranks on
    that path; ``strategy_wire_bytes`` shows the saved gather wire).

    **Emulated** (the 0.4.x partial-auto fallback, or
    ``rs_wire="emulate"``): full ``psum`` + OR-AllReduce, then a local
    slice — AllReduce wire cost, but still only 1/W of the peel compute
    per rank. On 0.4.x partial-auto callers that did not declare
    ``outer_manual`` it further degrades to all-ranks peeling (the rank
    index cannot be lowered there). Overlap on this wire is plain
    AllReduce chunking (the base class schedule).

    All paths are bit-identical to :class:`CompressedAggregator` (modulo
    the gather-skip output contract above): the per-range peel runs the
    same ops on the same sketch slice, and the disjoint-chunk gather
    (all_gather, or psum onto zeros) reproduces each value exactly once.
    """

    wire = "compressed_rs"

    # -- geometry / capability helpers ---------------------------------

    def _native_wire_possible(self) -> bool:
        """The wire-selection predicate shared by :meth:`_native_wire`
        and :meth:`_stream_plan` — one definition so the chunk grid can
        never drift from the actual wire path taken."""
        return self.cfg.rs_wire != "emulate" and (
            compat.SUPPORTS_PSUM_SCATTER or self._full_manual())

    def _native_wire(self) -> bool:
        """Whether phase II takes the psum_scatter/OR-RS wire path."""
        if self.cfg.rs_wire == "emulate":
            return False
        ok = self._native_wire_possible()
        if not ok and self.cfg.rs_wire == "native":
            raise ValueError(
                "rs_wire='native' requires a JAX with psum_scatter in "
                "partial-auto manual regions (compat.SUPPORTS_PSUM_SCATTER) "
                "or a caller whose shard_map takes every mesh axis manual "
                "(pass outer_manual); use rs_wire='auto' to fall back")
        return ok

    def _check_bitmap(self):
        if self.cfg.index != "bitmap":
            raise ValueError(
                "compressed_rs requires index='bitmap' (a Bloom filter "
                "hashes global coordinates and cannot be sliced per-rank)")

    def _rs_geometry(self, plan: BucketPlan):
        """(W, blocks/bucket, words/bucket, n_buckets padded to W)."""
        W = self._dp_world()
        nbpb = plan.blocks_per_bucket(self.cfg)
        wpb = plan.words_per_bucket
        nb_p = -(-plan.n_buckets // W) * W
        return W, nbpb, wpb, nb_p

    def _stream_plan(self, plan: BucketPlan) -> StreamPlan:
        """Per-rank-aligned scatter grid on the native wire (chunks of
        whole per-rank bucket runs); the base AllReduce grid elsewhere
        (the emulated wire ships the whole stream anyway, and a 1-rank
        'scatter' is a no-op)."""
        if self._native_wire() and self._dp_world() > 1:
            return make_stream_plan(plan, self.cfg,
                                    workers=self._dp_world(), scatter=True,
                                    base_block=self.base_block)
        return super()._stream_plan(plan)

    def _gather_skip(self, plan: BucketPlan, splan: StreamPlan,
                     spec_leaves=None) -> bool:
        """Static: does the chunk grid align with the ZeRO-1 slices so
        the recovered-chunk all_gather can be skipped?

        ``spec_leaves`` (DP-stripped specs): on a JAX with nested
        shard_map, a leaf actually sharded on a non-DP axis makes the
        packed stream a TP-*local* view while the ZeRO-1 slices are
        global — the alignment math does not apply, keep the gather.
        (On 0.4.x the packed stream is the auto-sharded global view, so
        TP sharding does not disturb the stream coordinates.)"""
        if self.zero1_dims is None:
            return False
        if compat.SUPPORTS_NESTED_SHARD_MAP and spec_leaves is not None \
                and any(_spec_axes(s) for s in spec_leaves):
            return False
        return zero1_gather_skip(splan, plan, tuple(self.zero1_dims))

    def gather_skip_active(self, grads, param_specs=None) -> bool:
        """Static answer (no tracing): will aggregating gradients shaped
        like ``grads`` (sharded as ``param_specs``; None = replicated)
        skip the recovered-chunk all_gather? The train step consults
        this to switch the grad-norm to a cross-rank reduction on the
        skip path; tests pin it against the wire accounting
        (``strategy_wire_bytes(..., zero1_aligned=...)``)."""
        if not (self._native_wire() and self._dp_world() > 1):
            return False
        plan = make_bucket_plan(grads, self.cfg)
        splan = self._stream_plan(plan)
        spec_leaves = None
        if param_specs is not None:
            dp_set = set(self.dp_axes)
            spec_leaves = [_tp_only(s, dp_set) for s in
                           plan.treedef.flatten_up_to(param_specs)]
        return splan.streamed and self._gather_skip(plan, splan,
                                                    spec_leaves)

    # -- phase II ------------------------------------------------------

    def _encode(self, buckets: jnp.ndarray, plan: BucketPlan,
                comp: HomomorphicCompressor, dp_idx):
        self._check_bitmap()
        if not self._native_wire() or self._dp_world() == 1:
            if self._native_wire() and not self._stream_plan(plan).streamed:
                # 1-rank native wire: nothing to scatter or reduce.
                c = comp.compress(buckets.reshape(-1),
                                  block_offset=self.base_block)
                return c.sketch, c.index_words
            return super()._encode(buckets, plan, comp, dp_idx)
        splan = self._stream_plan(plan)
        if splan.streamed:
            return self._encode_streamed(buckets, splan, comp,
                                         self._reduce_scatter(dp_idx))
        # One-shot native wire: a single psum_scatter + OR-RS over the
        # whole stream, padded to whole per-rank chunks.
        c = comp.compress(buckets.reshape(-1), block_offset=self.base_block)
        W, nbpb, wpb, nb_p = self._rs_geometry(plan)
        sk, words = c.sketch, c.index_words
        pad_b = nb_p - plan.n_buckets
        if pad_b:
            # zero sketch blocks / zero index words peel to exact zeros
            sk = jnp.pad(sk, ((0, pad_b * nbpb), (0, 0), (0, 0)))
            words = jnp.pad(words, (0, pad_b * wpb))
        return self._reduce_scatter(dp_idx)((sk, words))

    def _reduce_scatter(self, dp_idx):
        """The native wire for one (sketch, words) payload chunk: each
        rank receives its own fully-reduced whole-bucket slice."""
        def red(payload):
            sk, words = payload
            sk_loc = jax.lax.psum_scatter(
                sk, tuple(self.dp_axes), scatter_dimension=0, tiled=True)
            w_loc = or_reduce_scatter(
                words, self.dp_axes, axis_indices=dp_idx,
                use_ppermute=True if self._full_manual() else None)
            return sk_loc, w_loc
        return red

    def _recover(self, payload, plan: BucketPlan,
                 comp: HomomorphicCompressor, dp_idx, dp_rank,
                 spec_leaves=None):
        cfg = self.cfg
        sk, words = payload
        self._check_bitmap()
        W, nbpb, wpb, nb_p = self._rs_geometry(plan)
        chunk_b = nb_p // W                      # buckets per rank
        chunk_elems = chunk_b * plan.bucket_elems
        if self._native_wire():
            splan = self._stream_plan(plan)
            if W > 1 and splan.streamed:
                return self._recover_streamed(sk, words, plan, splan, comp,
                                              dp_idx, dp_rank, spec_leaves)
            # (sk, words) are already this rank's reduced 1/W slice (the
            # whole stream at W == 1).
            rec_loc = comp.recover(
                CompressedLeaf(sketch=sk, index_words=words), chunk_elems,
                block_offset=self.base_block + dp_rank * chunk_b * nbpb)
            return self._gather_chunks(rec_loc, plan, nb_p, chunk_elems,
                                       dp_rank)
        full_manual = self._full_manual()
        if not (compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE or full_manual):
            # 0.4.x partial-auto caller: the rank (axis_index) cannot be
            # lowered — degrade to all-ranks peeling (same values, no
            # per-rank compute scattering). See ``outer_manual``.
            return CompressedAggregator._recover(
                self, (sk, words), plan, comp, dp_idx, dp_rank)
        pad_b = nb_p - plan.n_buckets
        if pad_b:
            sk = jnp.pad(sk, ((0, pad_b * nbpb), (0, 0), (0, 0)))
            words = jnp.pad(words, (0, pad_b * wpb))
        sk_loc = jax.lax.dynamic_slice_in_dim(
            sk, dp_rank * chunk_b * nbpb, chunk_b * nbpb, axis=0)
        w_loc = jax.lax.dynamic_slice_in_dim(
            words, dp_rank * chunk_b * wpb, chunk_b * wpb, axis=0)
        rec_loc = comp.recover(
            CompressedLeaf(sketch=sk_loc, index_words=w_loc), chunk_elems,
            block_offset=self.base_block + dp_rank * chunk_b * nbpb)
        return self._gather_chunks(rec_loc, plan, nb_p, chunk_elems, dp_rank)

    def _recover_streamed(self, sk, words, plan: BucketPlan,
                          splan: StreamPlan, comp: HomomorphicCompressor,
                          dp_idx, dp_rank, spec_leaves=None):
        """Streamed native wire: ``(sk, words)`` are the per-chunk
        reduced slices stacked on a leading ``n_chunks`` dim — peel each
        at its global block offset (still 1/W of the recovery compute),
        then reassemble (or skip the gather when the chunk grid aligns
        with the ZeRO-1 slices: each rank keeps its recovered values in
        place in a zero stream — exact inside its owned coordinates)."""
        slice_elems = splan.rank_chunk_buckets * plan.bucket_elems

        def peel(args):
            j, sk_j, w_j = args
            return comp.recover(
                CompressedLeaf(sketch=sk_j, index_words=w_j), slice_elems,
                block_offset=splan.rank_slice_start_block(j, dp_rank))

        idx = jnp.arange(splan.n_chunks, dtype=jnp.int32)
        rec = jax.lax.map(peel, (idx, sk, words))  # (n_chunks, slice_elems)
        if self._gather_skip(plan, splan, spec_leaves):
            full = jnp.zeros((splan.n_chunks, splan.chunk_elems), rec.dtype)
            full = jax.lax.dynamic_update_slice(
                full, rec, (jnp.int32(0), dp_rank * slice_elems))
        else:
            # Same gate as _gather_chunks: the manual-axis all_gather
            # only in full-manual regions — partial-auto keeps the
            # zero-pad + psum trick so Shardy does not un-shard the
            # auto TP axes around the gather.
            full = gather_chunk_slices(
                rec, tuple(self.dp_axes), axis_indices=dp_idx,
                use_all_gather=self._full_manual())
        stream = full.reshape(-1)[:plan.padded]
        return stream.reshape(plan.n_buckets, plan.bucket_elems)

    def _gather_chunks(self, rec_loc, plan: BucketPlan, nb_p: int,
                       chunk_elems: int, dp_rank):
        """Reassemble the per-rank recovered chunks into the full stream.

        Full-manual regions take a manual-axis ``all_gather`` (rank-major
        tiling, half the wire of the psum trick); partial-auto regions
        keep the zero-pad + ``psum`` gather so Shardy does not un-shard
        the auto TP axes around the gather (see train/step.py). Both
        reproduce each recovered value exactly once (bit-identical).
        """
        if self._dp_world() == 1:
            full = rec_loc
        elif self._full_manual():
            full = jax.lax.all_gather(rec_loc, tuple(self.dp_axes),
                                      axis=0, tiled=True)
        else:
            full = jnp.zeros((nb_p * plan.bucket_elems,), rec_loc.dtype)
            full = jax.lax.dynamic_update_slice_in_dim(
                full, rec_loc, dp_rank * chunk_elems, axis=0)
            full = jax.lax.psum(full, tuple(self.dp_axes))
        return full[:plan.padded].reshape(plan.n_buckets, plan.bucket_elems)


@dataclasses.dataclass(frozen=True)
class CompressedInNetworkAggregator(CompressedAggregator):
    """Bucketed compressed aggregation through an emulated in-network
    tier (PR 4): the paper's "aggregate inside the switch" deployment.

    Phase I (pack/sparsify/encode) is :class:`CompressedAggregator`'s.
    Phase II ships the stream up a worker -> ToR -> spine reduction tree
    (:mod:`repro.net.topology`, mapped onto the DP mesh axes by
    ``cfg.topology``) instead of a ring, in one of two wire dtypes:

    - ``cfg.wire_dtype == "fxp32"`` — the honest switch wire: the
      sketch is quantized per bucket to shared-exponent int32
      (:class:`repro.net.fixedpoint.FixedPointWire`, overflow-free for
      this DP world size by construction), the per-bucket exponents are
      agreed with a ``pmax`` (4 bytes/bucket of metadata), and both the
      integer sketch and the uint32 bitmap ride
      :func:`repro.net.topology.tree_all_reduce` — integer add + OR,
      the only operations a programmable data plane has. Because
      integer adds are exact in any association order, the result is
      bit-identical to the documented codec roundtrip (and to the psum
      fallback on legs whose partitioner cannot run ppermute in the
      calling region — same gating as the reduce-scatter wire).
    - ``cfg.wire_dtype == "f32"`` — an idealized float-capable
      aggregation tier (e.g. host-based aggregation servers): reuses
      the sketch-``psum`` + OR-AllReduce collectives, so it is
      bit-for-bit :class:`CompressedAggregator` and serves as the
      innet arm's parity baseline; the tree is wire-model only (a tree
      of *float* adds would be order-sensitive and break that parity).

    The wire/occupancy story of the physical tree (bounded switch SRAM,
    streaming windows of ``cfg.switch_slots`` bucket chunks, per-port
    counters, straggler retransmit) is modeled by
    :class:`repro.net.switch.SwitchModel`, which the ``--compare-innet``
    benchmark drives over the same streams and pins against this
    strategy's output. The in-mesh collective streams the same windows
    (PR 5): the fxp32 tree reduces ``switch_slots`` buckets at a time
    (``tree_all_reduce(..., window_slots=...)``, matching the switch's
    slot pool window for window), and with ``cfg.overlap`` /
    ``cfg.stream_chunks`` the shared stream scheduler additionally
    pipelines window ``i``'s tree against window ``i+1``'s encode (the
    chunk grid spans whole switch windows; a forced ``stream_chunks``
    that cannot raises ``ValueError``).
    """

    wire = "compressed_innet"

    def _stream_plan(self, plan: BucketPlan) -> StreamPlan:
        """Chunks span whole ``switch_slots`` bucket windows, so the
        collective schedule and the SwitchModel slot pool agree."""
        return make_stream_plan(plan, self.cfg,
                                window_buckets=self.cfg.switch_slots,
                                base_block=self.base_block)

    def _encode(self, buckets: jnp.ndarray, plan: BucketPlan,
                comp: HomomorphicCompressor, dp_idx):
        cfg = self.cfg
        if cfg.wire_dtype == "f32":
            # Idealized float tier: same collectives (and bits) as
            # CompressedAggregator — including the streamed schedule,
            # whose chunks here span whole switch windows; see class
            # docstring. The tree is wire-model only on this dtype.
            make_topology(cfg.topology, self.mesh, self.dp_axes)  # validate
            return super()._encode(buckets, plan, comp, dp_idx)
        topo = make_topology(cfg.topology, self.mesh, self.dp_axes)
        use_pp = True if self._full_manual() else None
        wire = FixedPointWire(workers=self._dp_world())
        splan = self._stream_plan(plan)
        nbpb = splan.blocks_per_bucket

        def tree_window(sk_buckets, maxabs_blocks, words_buckets):
            """One chunk (whole buckets) over the fxp32 tree, window by
            window: pmax-agree exponents from the producer's per-block
            maxabs byproduct (max-of-maxes == bucket max, exactly — no
            second pass over the sketch), quantize the Γ-compressed
            sketch, integer tree. The int32 sum and the agreed exponents
            ride the payload; dequantization happens inside the fused
            consumer pass (:meth:`_recover`)."""
            n_b = sk_buckets.shape[0]
            bucket_max = maxabs_blocks.reshape(n_b, nbpb).max(axis=1)
            exp = jax.lax.pmax(wire.exponents_from_maxabs(bucket_max),
                               tuple(self.dp_axes))
            q = tree_all_reduce(wire.encode(sk_buckets, exp), topo, "add",
                                axis_indices=dp_idx, use_ppermute=use_pp,
                                window_slots=cfg.switch_slots)
            w = tree_all_reduce(words_buckets, topo, "or",
                                axis_indices=dp_idx, use_ppermute=use_pp,
                                window_slots=cfg.switch_slots)
            return q, w, exp

        if not splan.streamed:
            c, mx = comp.compress_wire(buckets.reshape(-1),
                                       block_offset=self.base_block)
            sk, words = c.sketch, c.index_words
            q_b, w_b, exp = tree_window(
                sk.reshape(plan.n_buckets, -1), mx,
                words.reshape(plan.n_buckets, splan.words_per_bucket))
            return q_b.reshape(sk.shape), w_b.reshape(-1), exp

        def red(payload):
            sk, words, mx = payload      # one chunk's local payload
            q_b, w_b, exp = tree_window(
                sk.reshape(splan.chunk_buckets, -1), mx,
                words.reshape(splan.chunk_buckets, splan.words_per_bucket))
            return q_b.reshape(sk.shape), w_b.reshape(words.shape), exp

        qs, ws, exps = self._encode_streamed(buckets, splan, comp, red,
                                             with_maxabs=True)
        q, w = self._trim_fused(qs, ws, plan, splan)
        return q, w, exps.reshape(-1)[:plan.n_buckets]

    def _recover(self, payload, plan: BucketPlan,
                 comp: HomomorphicCompressor, dp_idx, dp_rank,
                 spec_leaves=None):
        """fxp32 payloads carry ``(q int32, words, exponents)``: the
        exponent-bitcast dequantization runs *inside* the fused consumer
        pass (``recover(dequant=...)``) instead of as a separate
        sketch-sized decode before peeling. f32 payloads are the base
        class's ``(sketch, words)``."""
        if len(payload) == 2:
            return super()._recover(payload, plan, comp, dp_idx, dp_rank,
                                    spec_leaves=spec_leaves)
        q, words, exp = payload
        wire = FixedPointWire(workers=self._dp_world())
        nbpb = plan.blocks_per_bucket(self.cfg)
        rec = comp.recover(
            CompressedLeaf(sketch=q, index_words=words), plan.padded,
            block_offset=self.base_block,
            dequant=(jnp.repeat(exp, nbpb), wire.mantissa_bits))
        return rec.reshape(plan.n_buckets, plan.bucket_elems)


# ----------------------------------------------------------------------
# The `auto` strategy (PR 6): execute controller-produced wire plans
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WirePlannedAggregator(CompressedAggregator):
    """The 5th registry strategy: per-bucket-group wire selection.

    Executes whatever :class:`~repro.core.wireplan.WirePlan` it is
    handed (``wire_plan=...``, produced by the
    :class:`~repro.core.costmodel.AutoWireController` host-side between
    steps); without one it falls back to the controller's *analytic*
    plan — ``strategy_wire_bytes`` plus the ``auto_*`` bandwidth priors,
    no telemetry — so the first compiled step is already a reasonable
    mixed plan. The compiled step is static per plan; the controller
    re-plans only every ``cfg.replan_every`` steps.

    Also the telemetry source: measures per-bucket occupancy of the
    aggregated stream into ``AggregationState.telemetry`` for the
    controller's feasibility test (occupancy near the peeling capacity
    rules the compressed wires out for that bucket).
    """

    wire = "auto"
    collect_telemetry = True

    def _wire_plan(self, plan: BucketPlan) -> WirePlan:
        if self.wire_plan is not None:
            return super()._wire_plan(plan)
        from .costmodel import analytic_plan  # late: costmodel imports us
        return analytic_plan(plan, self.cfg, workers=self._dp_world())


# ----------------------------------------------------------------------
# Expert-parallel all-to-all exchanges (the permute pattern, PR 8)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DenseAllToAllExchange:
    """Plain expert-parallel all-to-all over the shared bucket grid —
    the parity baseline for the compressed exchange.

    Unlike the aggregators (which build their own nested regions), an
    exchange is a plain callable used *inside* the model's manual
    region, where the EP axes are already bound: MoE dispatch/combine
    happens mid-forward, not at the gradient boundary.  Input: a pytree
    whose leaves carry a leading destination axis ``(W, ...)`` — lane
    ``d`` is this rank's payload for EP rank ``d`` (rank-major,
    :func:`~repro.core.collectives.linear_rank` order).  Output: the
    merged slice pytree ``sum_s payload_s[this_rank]`` (leaf shapes
    minus the lane axis) — the homomorphic combine lands at the
    receiving expert, never at a barrier.

    This baseline packs every lane into one per-destination
    :class:`~repro.core.bucketing.BucketPlan` grid (identical padding to
    the compressed wire, so the two are bit-comparable), ships the
    packed f32 stack over the permute lanes
    (:func:`~repro.core.collectives.alltoall_lane_sum`) and unpacks the
    merged slice.
    """

    wire = "dense"          # the pattern_wires("alltoall") entry executed
    pattern = "alltoall"

    cfg: CompressionConfig
    mesh: Any
    ep_axes: Tuple[str, ...]
    # The axis set the caller's shard_map takes manual — same role as
    # CompressedAggregator.outer_manual: on 0.4.x the native ppermute
    # lanes need a full-manual caller.
    outer_manual: Any = None

    @property
    def workers(self) -> int:
        W = 1
        for ax in self.ep_axes:
            W *= self.mesh.shape[ax]
        return W

    def _full_manual(self) -> bool:
        return (self.outer_manual is not None
                and compat.full_manual_region(self.outer_manual, self.mesh))

    def _use_ppermute(self) -> bool:
        """Native permute lanes: single EP axis (ppermute takes one axis
        name) and either new-JAX partial-auto ppermute or a full-manual
        caller — the same compat gate as the RS wire."""
        if len(self.ep_axes) != 1:
            return False
        return compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE or self._full_manual()

    def _ep_idx(self):
        return {ax: jax.lax.axis_index(ax) for ax in self.ep_axes}

    def _plan(self, payload) -> BucketPlan:
        return make_dest_bucket_plans(payload, self.cfg,
                                      n_dests=self.workers)[0]

    def _pack(self, payload, plan: BucketPlan) -> jnp.ndarray:
        """(W, ...) lane pytree -> (W, n_buckets, E) packed f32 stack."""
        return jnp.stack([
            plan.pack(jax.tree.map(lambda l: l[d], payload))
            for d in range(self.workers)])

    def __call__(self, payload):
        plan = self._plan(payload)
        stack = self._pack(payload, plan)
        merged = alltoall_lane_sum(
            stack, tuple(self.ep_axes), axis_indices=self._ep_idx(),
            use_ppermute=self._use_ppermute(), combine="add")
        return plan.unpack(merged)


@dataclasses.dataclass(frozen=True)
class CompressedAllToAllExchange(DenseAllToAllExchange):
    """Compressed expert-parallel all-to-all: the first permute-pattern
    wire (PR 8).

    Each chunk of the per-destination bucket grid encodes in ONE
    producer pass (:meth:`HomomorphicCompressor.exchange_wire` — all
    ``W`` lanes in a single fused grid, chunk-major block ids), ships
    sketch + bitmap lanes over :func:`sketch_all_to_all` (W-1 ppermutes
    native, psum-emulated under the RS wire's compat gate), and the
    receiving rank recovers its merged lane in ONE consumer pass at the
    lane's global block offset — the PR 7 one-producer/one-consumer
    contract on the permute pattern.  The sketch add / bitmap OR on the
    wire IS the combine: what arrives is the compressed form of
    ``sum_s payload_s[this_rank]``, recovered without any rank ever
    holding another rank's raw payload.

    ``cfg.overlap`` / ``cfg.stream_chunks`` drive the lane chunks
    through the shared double-buffered
    :func:`~repro.core.streams.stream_schedule` (the chunk count must
    divide the per-destination bucket run; see
    :func:`~repro.core.streams.make_alltoall_stream_plan`), so chunk
    ``i``'s permutes hide chunk ``i+1``'s encode exactly like the
    all-reduce wires.  Bit-for-bit equal to
    :class:`DenseAllToAllExchange` on the same payloads in the
    exact-recovery regime (pinned by ``test_dispatch.py`` and the
    collectives driver).
    """

    wire = "compressed"

    def __call__(self, payload):
        cfg = self.cfg
        if cfg.index != "bitmap":
            raise ValueError(
                "the all-to-all exchange requires index='bitmap' (a "
                "Bloom filter hashes global coordinates and cannot be "
                "sliced per destination lane)")
        comp = HomomorphicCompressor(cfg)
        W = self.workers
        plan = self._plan(payload)
        stack = self._pack(payload, plan)          # (W, nb, E)
        splan = make_alltoall_stream_plan(plan, cfg, lanes=W)
        ep_idx = self._ep_idx()
        rank = linear_rank(self.ep_axes, ep_idx)
        use_pp = self._use_ppermute()

        def enc(i, chunk):                          # chunk: (W, cb, E)
            leaf, _ = comp.exchange_wire(
                chunk, block_offset=splan.chunk_start_block(i))
            return leaf.sketch, leaf.index_words

        def red(wire_payload):
            sk, words = wire_payload
            return sketch_all_to_all(sk, words, tuple(self.ep_axes),
                                     axis_indices=ep_idx,
                                     use_ppermute=use_pp)

        sks, ws = stream_schedule(splan.chunk_view(stack), enc, red)
        # sks (n_chunks, lane_blocks, rows, lanes) / ws (n_chunks, w):
        # this rank's merged lane per chunk. Peel each at the lane's
        # global block offset — same hash ids every source encoded it
        # under.

        def peel(args):
            j, sk_j, w_j = args
            return comp.recover(
                CompressedLeaf(sketch=sk_j, index_words=w_j),
                splan.chunk_elems,
                block_offset=splan.lane_start_block(j, rank))

        idx = jnp.arange(splan.n_chunks, dtype=jnp.int32)
        rec = jax.lax.map(peel, (idx, sks, ws))    # (n_chunks, chunk_elems)
        merged = rec.reshape(-1)[:plan.padded]
        return plan.unpack(merged.reshape(plan.n_buckets, plan.bucket_elems))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _exchange_vjp(exchange, payload):
    """Differentiable facade over an exchange executor.

    The exchange is *linear* — ``out_r = sum_s payload_s[r]`` — but the
    compressed path's peeling ``while_loop`` is not reverse-
    differentiable, so we install the exact linear transpose by hand:
    ``d payload_s[d] = d out_d`` (the cotangent each destination rank
    holds), i.e. an ``all_gather`` of the output cotangent over the EP
    axes back onto the lane axis.  Applied to both exchanges so the
    dense baseline and the compressed wire have identical gradient
    semantics.
    """
    return exchange(payload)


def _exchange_vjp_fwd(exchange, payload):
    return exchange(payload), None


def _exchange_vjp_bwd(exchange, _, g):
    axes = tuple(exchange.ep_axes)
    ct = jax.tree.map(
        lambda l: jax.lax.all_gather(l, axes, axis=0, tiled=False), g)
    return (ct,)


_exchange_vjp.defvjp(_exchange_vjp_fwd, _exchange_vjp_bwd)


@dataclasses.dataclass(frozen=True)
class _GradExchange:
    """What :func:`make_exchange` hands the model: the executor wrapped
    with its linear VJP, surface attributes passed through."""

    exchange: Any

    @property
    def workers(self) -> int:
        return self.exchange.workers

    @property
    def ep_axes(self) -> Tuple[str, ...]:
        return self.exchange.ep_axes

    @property
    def wire(self) -> str:
        return self.exchange.wire

    def __call__(self, payload):
        return _exchange_vjp(self.exchange, payload)


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------

AGGREGATORS = {
    "dense": DenseAggregator,
    "compressed": CompressedAggregator,
    "compressed_rs": CompressedReduceScatterAggregator,
    "compressed_innet": CompressedInNetworkAggregator,
    "auto": WirePlannedAggregator,
}

# The controller's search space (wireplan.WIRES) and the executable
# fixed strategies are the same set by construction — checked at import
# so they can never drift apart (satellite of PR 6).
assert set(WIRES) == set(AGGREGATORS) - {"auto"}, (
    f"wireplan.WIRES {WIRES} out of sync with AGGREGATORS "
    f"{sorted(AGGREGATORS)}")

# The permute-pattern executors (PR 8), keyed by the same wire names the
# plan layer validates for pattern='alltoall'. Deliberately a separate
# registry: exchanges are in-model callables (payload -> merged slice),
# not gradient aggregators, and `auto`/`fixed_wires` must not see them.
EXCHANGES = {
    "dense": DenseAllToAllExchange,
    "compressed": CompressedAllToAllExchange,
}

assert set(EXCHANGES) == set(pattern_wires("alltoall")), (
    f"wireplan alltoall wires {pattern_wires('alltoall')} out of sync "
    f"with EXCHANGES {sorted(EXCHANGES)}")


def make_aggregator(name: str, cfg: CompressionConfig, mesh,
                    dp_axes: Sequence[str],
                    tp_axes: Sequence[str] = ("model",),
                    mean: bool = True, outer_manual=None,
                    zero1_dims=None, wire_plan=None) -> Aggregator:
    """Build the named strategy (see :data:`AGGREGATORS`).

    ``outer_manual``: the axis set the calling shard_map takes manual
    (see :class:`CompressedAggregator.outer_manual`). ``zero1_dims``:
    per-leaf ZeRO-1 slice dims enabling the reduce-scatter gather-skip
    path (see :class:`CompressedAggregator.zero1_dims`). ``wire_plan``:
    an explicit per-bucket-group wire assignment (PR 6) — normally only
    set on the ``auto`` strategy by its controller.
    """
    if isinstance(dp_axes, str):
        dp_axes = (dp_axes,)
    if isinstance(tp_axes, str):
        tp_axes = (tp_axes,)
    try:
        cls = AGGREGATORS[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {name!r}; have {sorted(AGGREGATORS)}")
    return cls(cfg=cfg, mesh=mesh, dp_axes=tuple(dp_axes),
               tp_axes=tuple(tp_axes), mean=mean,
               outer_manual=None if outer_manual is None
               else tuple(outer_manual),
               zero1_dims=None if zero1_dims is None else tuple(zero1_dims),
               wire_plan=wire_plan)


def make_exchange(name: str, cfg: CompressionConfig, mesh,
                  ep_axes: Sequence[str], outer_manual=None):
    """Build the named all-to-all exchange (see :data:`EXCHANGES`).

    Returns a differentiable callable for use *inside* a manual region
    where ``ep_axes`` are bound: ``(W, ...)`` lane pytree -> merged
    slice pytree (``sum_s payload_s[this_rank]``), with ``.workers`` /
    ``.ep_axes`` / ``.wire`` exposed for the caller's geometry checks.
    ``outer_manual`` as in :func:`make_aggregator`.
    """
    if isinstance(ep_axes, str):
        ep_axes = (ep_axes,)
    try:
        cls = EXCHANGES[name]
    except KeyError:
        raise ValueError(
            f"unknown exchange {name!r}; have {sorted(EXCHANGES)}")
    return _GradExchange(exchange=cls(
        cfg=cfg, mesh=mesh, ep_axes=tuple(ep_axes),
        outer_manual=None if outer_manual is None else tuple(outer_manual)))
