"""Aggregator strategies: bucketed gradient aggregation (PR 2).

The pre-bucketing pipeline unrolled a Python loop over every pytree leaf —
each leaf got its own codec plan, its own nested ``shard_map`` regions and
its own ``psum`` + OR-AllReduce launch, so a 100-leaf model compiled ~100
copies of the codec and paid ~100x collective launch latency. Here the
whole gradient is packed into fixed-byte flat buckets
(:mod:`repro.core.bucketing`) and aggregation is a pluggable strategy:

- :class:`DenseAggregator`              — plain ``psum`` (the paper's NCCL
  baseline arm);
- :class:`CompressedAggregator`         — ONE sketch encode over the packed
  stream, ONE stacked sketch-``psum`` and ONE OR-AllReduce for *all*
  buckets. With ``cfg.overlap`` the per-bucket collectives are staged
  against the next bucket's encode via a ``lax.scan`` double-buffer carry,
  so on hardware with async collectives bucket *i*'s wire time hides
  bucket *i+1*'s encode;
- :class:`CompressedReduceScatterAggregator` — the native reduce-scatter
  wire path (PR 3): the sketch reduces with ``jax.lax.psum_scatter`` and
  the bitmap with the ppermute-ring
  :func:`~repro.core.collectives.or_reduce_scatter`, so each rank
  *receives* only its own ``n_buckets/W`` sketch+bitmap slice (1/W the
  reduced payload of the AllReduce strategies — the paper's full
  reduce-scatter bandwidth win), peels only that range (1/W of the
  recovery compute), and reassembles the recovered chunks with a
  manual-axis ``all_gather`` (full-manual regions) or the zero-pad +
  ``psum`` ZeRO-1 gather trick (partial-auto, where Shardy would
  un-shard auto TP axes around the gather). Gated by
  ``compat.SUPPORTS_PSUM_SCATTER`` / a full-manual caller, with the
  older ``psum`` + local-slice emulation kept as the 0.4.x partial-auto
  fallback (AllReduce wire, per-rank peel compute only); the
  ``cfg.rs_wire`` knob forces either path.
- :class:`CompressedInNetworkAggregator` — the in-network tier (PR 4):
  the stream goes up an emulated worker->ToR->spine switch tree
  (:mod:`repro.net`) once per worker — integer-add sketch (via the
  fixed-point wire when ``cfg.wire_dtype='fxp32'``) and OR bitmap —
  instead of around a ring, so the hottest (root) link carries ``1 x``
  the payload per direction vs the ring's ``2(W-1)/W x``.

All strategies run *inside* the outer train-step ``shard_map`` (manual DP
axes). On JAX with nested partial-manual support, packing/unpacking runs
in a nested ``shard_map`` that takes the tensor-parallel axes manual too,
so each device packs only its local parameter shards — no GSPMD
resharding of gradients — while the codec and the DP collectives run at
the outer level on the shard-local buckets. On 0.4.x the packed stream is
the auto-sharded global view (same math; see ``repro.compat``).

Sparsification / error feedback are applied **per leaf** inside the pack
stage — identical semantics (and bits) to the per-leaf path this replaced,
pinned by ``tests/drivers/collectives_driver.py`` — and residuals keep the
parameter pytree layout. :meth:`BucketPlan.residual_slices` exposes the
per-bucket view of those residuals.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Protocol, Sequence, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.net.fixedpoint import FixedPointWire
from repro.net.topology import make_topology, tree_all_reduce
from .config import CompressionConfig
from .compressor import HomomorphicCompressor, CompressedLeaf
from .bucketing import BucketPlan, make_bucket_plan
from .collectives import (AggregationState, dense_all_reduce, linear_rank,
                          or_allreduce, or_reduce_scatter)
from . import topk as topk_lib


# One-time notices for configuration knobs a strategy cannot honor (the
# alternative — silently ignoring cfg.overlap — is the ROADMAP bug this
# fixes). Keyed so each (strategy, reason) pair warns once per process;
# tests reset the set to re-arm.
_OVERLAP_WARNED: set = set()


def _warn_overlap_ignored(key: str, message: str) -> None:
    if key not in _OVERLAP_WARNED:
        _OVERLAP_WARNED.add(key)
        warnings.warn(message, UserWarning, stacklevel=3)


@runtime_checkable
class Aggregator(Protocol):
    """Strategy for aggregating a gradient pytree across the DP axes.

    Called inside a ``shard_map`` where the DP axes are manual. Returns
    the aggregated (mean) gradients and the new error-feedback state.
    """

    def __call__(self, grads: Any, state: AggregationState,
                 param_specs: Any) -> Tuple[Any, AggregationState]:
        ...


# ----------------------------------------------------------------------
# Dense (the NCCL-AllReduce baseline arm)
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DenseAggregator:
    """Same constructor surface as the compressed strategies so the
    registry can build any entry uniformly; cfg/tp_axes/outer_manual are
    simply unused here."""

    mesh: Any
    dp_axes: Tuple[str, ...]
    cfg: Any = None
    tp_axes: Tuple[str, ...] = ()
    mean: bool = True
    outer_manual: Any = None

    def __call__(self, grads, state: AggregationState, param_specs=None):
        return dense_all_reduce(grads, self.dp_axes, mean=self.mean), state


# ----------------------------------------------------------------------
# Shared machinery for the compressed strategies
# ----------------------------------------------------------------------

def _tp_only(spec, dp_set):
    """Strip DP-axis references from a PartitionSpec (those axes are
    manual in the outer shard_map; nested regions partition TP only)."""
    if spec is None:
        return P()
    parts = []
    for s in spec:
        if s is None:
            parts.append(None)
        elif isinstance(s, (tuple, list)):
            kept = tuple(a for a in s if a not in dp_set)
            parts.append(kept if kept else None)
        else:
            parts.append(None if s in dp_set else s)
    return P(*parts)


def _spec_axes(spec) -> set:
    out = set()
    for part in spec:
        if part is None:
            continue
        out |= set(part) if isinstance(part, (tuple, list)) else {part}
    return out


def _local_shape(shape, spec, mesh):
    """Per-device shape of a leaf sharded as ``spec`` on ``mesh``."""
    def div(i):
        part = spec[i] if i < len(spec) else None
        if part is None:
            return 1
        names = part if isinstance(part, (tuple, list)) else (part,)
        d = 1
        for nm in names:
            d *= mesh.shape[nm]
        return d
    return tuple(sz // div(i) for i, sz in enumerate(shape))


def _sparsify_leaf(flat: jnp.ndarray, res: jnp.ndarray,
                   cfg: CompressionConfig):
    """Per-leaf phase-0: top-k budget + error feedback on one flat leaf.

    Identical math to the per-leaf path this layer replaced (pinned
    bit-for-bit by the collectives driver): k is proportional to *this
    leaf's* (shard-local) element count.
    """
    new_res = res
    if cfg.topk_ratio is not None:
        k = max(1, int(flat.shape[0] * cfg.topk_ratio))
        if cfg.error_feedback:
            flat, new_res = topk_lib.apply_error_feedback(
                flat, res.reshape(-1), k, exact=cfg.topk_exact)
        elif cfg.topk_exact:
            flat = topk_lib.sparsify_topk(flat, k)
        else:
            flat = topk_lib.sparsify_threshold(flat, k)
    return flat, new_res


@dataclasses.dataclass(frozen=True)
class CompressedAggregator:
    """The paper's pipeline over one fused bucket stream.

    pack (shard-local) -> per-leaf sparsify/EF -> encode all buckets ->
    sketch psum + index OR-AllReduce -> peel -> unpack.
    """

    cfg: CompressionConfig
    mesh: Any
    dp_axes: Tuple[str, ...]
    tp_axes: Tuple[str, ...] = ("model",)
    mean: bool = True
    # The axis set the *caller's* shard_map takes manual. Only consulted
    # by the reduce-scatter variant: on 0.4.x, axis_index in a
    # partial-auto region lowers to a PartitionId the old partitioner
    # rejects, so per-rank slicing needs either new JAX or a full-manual
    # caller (the 0.4.x train step is full-manual; see compat).
    outer_manual: Any = None

    # -- construction helpers ------------------------------------------

    def _n_workers(self) -> int:
        if not self.mean:
            return 1
        return self._dp_world()

    def _dp_world(self) -> int:
        W = 1
        for ax in self.dp_axes:
            W *= self.mesh.shape[ax]
        return W

    def _full_manual(self) -> bool:
        return (self.outer_manual is not None
                and compat.full_manual_region(self.outer_manual, self.mesh))

    def _manual_set(self, spec_leaves) -> set:
        """Axes the nested pack/unpack regions must take manual: the TP
        axes plus every axis any leaf's (DP-stripped) spec references
        (e.g. expert-parallel axes)."""
        manual = {a for a in self.tp_axes if a and a in self.mesh.shape}
        for spec in spec_leaves:
            manual |= _spec_axes(spec)
        return manual

    # -- phase I/II bucket codec (runs on shard-local buckets) ---------

    def _encode(self, buckets: jnp.ndarray, plan: BucketPlan,
                comp: HomomorphicCompressor, dp_idx):
        """(n_buckets, E) local buckets -> aggregated (sketch, words)."""
        if self.cfg.overlap and plan.n_buckets > 1:
            return self._encode_overlapped(buckets, plan, comp, dp_idx)
        c = comp.compress(buckets.reshape(-1))
        sk = jax.lax.psum(c.sketch, tuple(self.dp_axes))
        words = or_allreduce(c.index_words, self.dp_axes,
                             axis_indices=dp_idx)
        return sk, words

    def _encode_overlapped(self, buckets, plan: BucketPlan,
                           comp: HomomorphicCompressor, dp_idx):
        """Double-buffered staging: bucket i's collectives are issued in
        the same scan step as bucket i+1's encode, with no data
        dependence between them — async-collective backends overlap the
        wire with the MXU encode. Bit-identical to the fused path (same
        global block ids via block_offset; bitmap index slices exactly
        per bucket)."""
        cfg = self.cfg
        nbpb = plan.bucket_elems // cfg.block_elems   # blocks per bucket
        wpb = plan.bucket_elems // 32                 # bitmap words/bucket

        def enc(i, bucket):
            c = comp.compress(bucket, block_offset=i * nbpb)
            return c.sketch, c.index_words

        def reduce_one(sk, words):
            return (jax.lax.psum(sk, tuple(self.dp_axes)),
                    or_allreduce(words, self.dp_axes, axis_indices=dp_idx))

        sk0, w0 = enc(jnp.int32(0), buckets[0])

        def body(carry, xs):
            i, bucket = xs
            agg = reduce_one(*carry)
            return enc(i, bucket), agg

        idx = jnp.arange(1, plan.n_buckets, dtype=jnp.int32)
        (sk_l, w_l), (sks, ws) = jax.lax.scan(body, (sk0, w0),
                                              (idx, buckets[1:]))
        sk_last, w_last = reduce_one(sk_l, w_l)
        sk = jnp.concatenate([sks, sk_last[None]], axis=0)
        words = jnp.concatenate([ws, w_last[None]], axis=0)
        # (n_buckets, nbpb, rows, lanes) / (n_buckets, wpb) -> fused views
        return (sk.reshape(plan.n_buckets * nbpb, cfg.rows, cfg.lanes),
                words.reshape(plan.n_buckets * wpb))

    def _recover(self, sk, words, plan: BucketPlan,
                 comp: HomomorphicCompressor, dp_idx, dp_rank):
        """Aggregated (sketch, words) -> recovered (n_buckets, E)."""
        rec = comp.recover(CompressedLeaf(sketch=sk, index_words=words),
                           plan.padded)
        return rec.reshape(plan.n_buckets, plan.bucket_elems)

    # -- the strategy --------------------------------------------------

    def __call__(self, grads, state: AggregationState, param_specs):
        cfg = self.cfg
        comp = HomomorphicCompressor(cfg)
        mesh = self.mesh
        dp_set = set(self.dp_axes)
        n_workers = self._n_workers()
        ef_on = cfg.topk_ratio is not None and cfg.error_feedback

        leaves, treedef = jax.tree.flatten(grads)
        spec_leaves = [_tp_only(s, dp_set)
                       for s in treedef.flatten_up_to(param_specs)]
        res_tree = state.residual
        res_specs = jax.tree.unflatten(
            treedef, [s if ef_on else P() for s in spec_leaves])
        specs = jax.tree.unflatten(treedef, spec_leaves)

        # Shard indices on the (outer-manual) DP axes, computed here where
        # those axes are directly bound; threaded into the OR-rings because
        # axis_index inside nested regions would re-bind the axis (Shardy).
        dp_idx = {ax: jax.lax.axis_index(ax) for ax in self.dp_axes}
        dp_rank = linear_rank(self.dp_axes, dp_idx)

        manual = self._manual_set(spec_leaves)
        nested = bool(manual) and compat.SUPPORTS_NESTED_SHARD_MAP
        if nested:
            local_shapes = [
                _local_shape(g.shape, s, mesh)
                for g, s in zip(leaves, spec_leaves)]
        else:
            # Pure DP, or a JAX without nested partial-manual shard_map:
            # pack the auto-sharded global view (same compress -> psum/OR
            # -> recover math; nesting only avoids GSPMD resharding).
            local_shapes = [tuple(g.shape) for g in leaves]
        plan = make_bucket_plan(
            grads, cfg, shapes=jax.tree.unflatten(treedef, local_shapes))

        def pack_stage(g_tree, r_tree):
            """Shard-local: per-leaf sparsify/EF, then bucket-pack."""
            g_leaves = plan.treedef.flatten_up_to(g_tree)
            r_leaves = plan.treedef.flatten_up_to(r_tree)
            flats, new_res = [], []
            for g, r in zip(g_leaves, r_leaves):
                flat, nr = _sparsify_leaf(
                    g.reshape(-1).astype(jnp.float32), r, cfg)
                flats.append(flat)
                new_res.append(nr.reshape(r.shape))
            return (plan.pack_flat(flats),
                    jax.tree.unflatten(plan.treedef, new_res))

        def unpack_stage(buckets):
            """Shard-local: bucket stream -> leaf pytree (mean)."""
            return plan.unpack(buckets / n_workers)

        if nested:
            enc = compat.shard_map(
                pack_stage, mesh=mesh, in_specs=(specs, res_specs),
                out_specs=(P(), res_specs), axis_names=manual,
                check_vma=False)
            buckets, new_res = enc(grads, res_tree)
        else:
            buckets, new_res = pack_stage(grads, res_tree)

        sk, words = self._encode(buckets, plan, comp, dp_idx)
        rec = self._recover(sk, words, plan, comp, dp_idx, dp_rank)

        if nested:
            dec = compat.shard_map(
                unpack_stage, mesh=mesh, in_specs=(P(),),
                out_specs=specs, axis_names=manual, check_vma=False)
            agg = dec(rec)
        else:
            agg = unpack_stage(rec)
        return agg, AggregationState(residual=new_res)


@dataclasses.dataclass(frozen=True)
class CompressedReduceScatterAggregator(CompressedAggregator):
    """Bucketed compressed aggregation over a reduce-scattered wire.

    Phase I (pack/sparsify/encode) is identical to
    :class:`CompressedAggregator`. Phase II comes in two wire paths,
    selected by ``cfg.rs_wire`` and the capability map:

    **Native** (``compat.SUPPORTS_PSUM_SCATTER``, or any JAX when the
    caller's region is full-manual): the stacked sketch reduces with
    ``jax.lax.psum_scatter`` and the bitmap with the ring
    :func:`~repro.core.collectives.or_reduce_scatter`, both padded to
    whole per-rank chunks of ``nb_p/W`` buckets, so each rank *receives*
    only its own sketch+bitmap slice — 1/W the reduced payload (and
    roughly half the link traffic) of the AllReduce strategies. The rank
    peels its range (1/W of the recovery compute, hash ids offset to the
    chunk's global block position) and the recovered chunks reassemble
    with a manual-axis ``all_gather`` in full-manual regions, else the
    zero-pad + ``psum`` ZeRO-1 gather trick (Shardy un-shards auto TP
    axes around a partial-auto manual-axis all_gather; see
    train/step.py). ``cfg.overlap`` is inapplicable here and ignored:
    per-bucket collective staging would scatter each bucket's *interior*
    across ranks instead of assigning whole buckets to their peeling
    rank (a strided wire format; ROADMAP open item).

    **Emulated** (the 0.4.x partial-auto fallback, or
    ``rs_wire="emulate"``): full ``psum`` + OR-AllReduce, then a local
    slice — AllReduce wire cost, but still only 1/W of the peel compute
    per rank. On 0.4.x partial-auto callers that did not declare
    ``outer_manual`` it further degrades to all-ranks peeling (the rank
    index cannot be lowered there).

    Both paths are bit-identical to :class:`CompressedAggregator`: the
    per-range peel runs the same ops on the same sketch slice, and the
    disjoint-chunk gather (all_gather, or psum onto zeros) reproduces
    each value exactly once.
    """

    def __post_init__(self):
        # cfg.overlap cannot be honored on the native wire: per-bucket
        # collective staging would scatter each bucket's *interior*
        # across ranks instead of assigning whole buckets to their
        # peeling rank (needs a strided wire format; ROADMAP open item).
        # Say so once instead of silently running fused.
        if self.cfg.overlap and self._native_wire_possible():
            _warn_overlap_ignored(
                "rs_native",
                "cfg.overlap is ignored on the native reduce-scatter "
                "wire: per-bucket collective staging would scatter each "
                "bucket's interior across ranks instead of assigning "
                "whole buckets to their peeling rank (needs a strided "
                "wire format — see the ROADMAP open item); running the "
                "fused one-shot psum_scatter + OR-Reduce-Scatter instead")

    # -- geometry / capability helpers ---------------------------------

    def _native_wire_possible(self) -> bool:
        """The wire-selection predicate shared by :meth:`_native_wire`
        and the construction-time overlap warning — one definition so
        the warning can never drift from the actual path taken."""
        return self.cfg.rs_wire != "emulate" and (
            compat.SUPPORTS_PSUM_SCATTER or self._full_manual())

    def _native_wire(self) -> bool:
        """Whether phase II takes the psum_scatter/OR-RS wire path."""
        if self.cfg.rs_wire == "emulate":
            return False
        ok = self._native_wire_possible()
        if not ok and self.cfg.rs_wire == "native":
            raise ValueError(
                "rs_wire='native' requires a JAX with psum_scatter in "
                "partial-auto manual regions (compat.SUPPORTS_PSUM_SCATTER) "
                "or a caller whose shard_map takes every mesh axis manual "
                "(pass outer_manual); use rs_wire='auto' to fall back")
        return ok

    def _check_bitmap(self):
        if self.cfg.index != "bitmap":
            raise ValueError(
                "compressed_rs requires index='bitmap' (a Bloom filter "
                "hashes global coordinates and cannot be sliced per-rank)")

    def _rs_geometry(self, plan: BucketPlan):
        """(W, blocks/bucket, words/bucket, n_buckets padded to W)."""
        W = self._dp_world()
        nbpb = plan.bucket_elems // self.cfg.block_elems
        wpb = plan.bucket_elems // 32
        nb_p = -(-plan.n_buckets // W) * W
        return W, nbpb, wpb, nb_p

    # -- phase II ------------------------------------------------------

    def _encode(self, buckets: jnp.ndarray, plan: BucketPlan,
                comp: HomomorphicCompressor, dp_idx):
        self._check_bitmap()
        if not self._native_wire():
            return super()._encode(buckets, plan, comp, dp_idx)
        # Fused encode only (see class docstring on cfg.overlap).
        c = comp.compress(buckets.reshape(-1))
        W, nbpb, wpb, nb_p = self._rs_geometry(plan)
        sk, words = c.sketch, c.index_words
        pad_b = nb_p - plan.n_buckets
        if pad_b:
            # zero sketch blocks / zero index words peel to exact zeros
            sk = jnp.pad(sk, ((0, pad_b * nbpb), (0, 0), (0, 0)))
            words = jnp.pad(words, (0, pad_b * wpb))
        if W == 1:
            return sk, words
        sk_loc = jax.lax.psum_scatter(
            sk, tuple(self.dp_axes), scatter_dimension=0, tiled=True)
        w_loc = or_reduce_scatter(
            words, self.dp_axes, axis_indices=dp_idx,
            use_ppermute=True if self._full_manual() else None)
        return sk_loc, w_loc

    def _recover(self, sk, words, plan: BucketPlan,
                 comp: HomomorphicCompressor, dp_idx, dp_rank):
        cfg = self.cfg
        self._check_bitmap()
        W, nbpb, wpb, nb_p = self._rs_geometry(plan)
        chunk_b = nb_p // W                      # buckets per rank
        chunk_elems = chunk_b * plan.bucket_elems
        if self._native_wire():
            # (sk, words) are already this rank's reduced 1/W slice.
            rec_loc = comp.recover(
                CompressedLeaf(sketch=sk, index_words=words), chunk_elems,
                block_offset=dp_rank * chunk_b * nbpb)
            return self._gather_chunks(rec_loc, plan, nb_p, chunk_elems,
                                       dp_rank)
        full_manual = self._full_manual()
        if not (compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE or full_manual):
            # 0.4.x partial-auto caller: the rank (axis_index) cannot be
            # lowered — degrade to all-ranks peeling (same values, no
            # per-rank compute scattering). See ``outer_manual``.
            return CompressedAggregator._recover(
                self, sk, words, plan, comp, dp_idx, dp_rank)
        pad_b = nb_p - plan.n_buckets
        if pad_b:
            sk = jnp.pad(sk, ((0, pad_b * nbpb), (0, 0), (0, 0)))
            words = jnp.pad(words, (0, pad_b * wpb))
        sk_loc = jax.lax.dynamic_slice_in_dim(
            sk, dp_rank * chunk_b * nbpb, chunk_b * nbpb, axis=0)
        w_loc = jax.lax.dynamic_slice_in_dim(
            words, dp_rank * chunk_b * wpb, chunk_b * wpb, axis=0)
        rec_loc = comp.recover(
            CompressedLeaf(sketch=sk_loc, index_words=w_loc), chunk_elems,
            block_offset=dp_rank * chunk_b * nbpb)
        return self._gather_chunks(rec_loc, plan, nb_p, chunk_elems, dp_rank)

    def _gather_chunks(self, rec_loc, plan: BucketPlan, nb_p: int,
                       chunk_elems: int, dp_rank):
        """Reassemble the per-rank recovered chunks into the full stream.

        Full-manual regions take a manual-axis ``all_gather`` (rank-major
        tiling, half the wire of the psum trick); partial-auto regions
        keep the zero-pad + ``psum`` gather so Shardy does not un-shard
        the auto TP axes around the gather (see train/step.py). Both
        reproduce each recovered value exactly once (bit-identical).
        """
        if self._dp_world() == 1:
            full = rec_loc
        elif self._full_manual():
            full = jax.lax.all_gather(rec_loc, tuple(self.dp_axes),
                                      axis=0, tiled=True)
        else:
            full = jnp.zeros((nb_p * plan.bucket_elems,), rec_loc.dtype)
            full = jax.lax.dynamic_update_slice_in_dim(
                full, rec_loc, dp_rank * chunk_elems, axis=0)
            full = jax.lax.psum(full, tuple(self.dp_axes))
        return full[:plan.padded].reshape(plan.n_buckets, plan.bucket_elems)


@dataclasses.dataclass(frozen=True)
class CompressedInNetworkAggregator(CompressedAggregator):
    """Bucketed compressed aggregation through an emulated in-network
    tier (PR 4): the paper's "aggregate inside the switch" deployment.

    Phase I (pack/sparsify/encode) is :class:`CompressedAggregator`'s.
    Phase II ships the stream up a worker -> ToR -> spine reduction tree
    (:mod:`repro.net.topology`, mapped onto the DP mesh axes by
    ``cfg.topology``) instead of a ring, in one of two wire dtypes:

    - ``cfg.wire_dtype == "fxp32"`` — the honest switch wire: the
      sketch is quantized per bucket to shared-exponent int32
      (:class:`repro.net.fixedpoint.FixedPointWire`, overflow-free for
      this DP world size by construction), the per-bucket exponents are
      agreed with a ``pmax`` (4 bytes/bucket of metadata), and both the
      integer sketch and the uint32 bitmap ride
      :func:`repro.net.topology.tree_all_reduce` — integer add + OR,
      the only operations a programmable data plane has. Because
      integer adds are exact in any association order, the result is
      bit-identical to the documented codec roundtrip (and to the psum
      fallback on legs whose partitioner cannot run ppermute in the
      calling region — same gating as the reduce-scatter wire).
    - ``cfg.wire_dtype == "f32"`` — an idealized float-capable
      aggregation tier (e.g. host-based aggregation servers): reuses
      the sketch-``psum`` + OR-AllReduce collectives, so it is
      bit-for-bit :class:`CompressedAggregator` and serves as the
      innet arm's parity baseline; the tree is wire-model only (a tree
      of *float* adds would be order-sensitive and break that parity).

    The wire/occupancy story of the physical tree (bounded switch SRAM,
    streaming windows of ``cfg.switch_slots`` bucket chunks, per-port
    counters, straggler retransmit) is modeled by
    :class:`repro.net.switch.SwitchModel`, which the ``--compare-innet``
    benchmark drives over the same streams and pins against this
    strategy's output. ``cfg.overlap`` is inapplicable here and ignored
    with a one-time warning: the tree reduces the fused stream in one
    shot (per-window streaming lives in the switch model, not in the
    collective schedule).
    """

    def __post_init__(self):
        if self.cfg.overlap:
            _warn_overlap_ignored(
                "innet",
                "cfg.overlap is ignored by compressed_innet: the "
                "in-network tree reduces the fused bucket stream in one "
                "shot (streaming happens in the emulated switch's slot "
                "windows, not in the collective schedule)")

    def _encode(self, buckets: jnp.ndarray, plan: BucketPlan,
                comp: HomomorphicCompressor, dp_idx):
        cfg = self.cfg
        c = comp.compress(buckets.reshape(-1))
        sk, words = c.sketch, c.index_words
        if cfg.wire_dtype == "f32":
            # Idealized float tier: same collectives (and bits) as
            # CompressedAggregator; see class docstring.
            make_topology(cfg.topology, self.mesh, self.dp_axes)  # validate
            sk = jax.lax.psum(sk, tuple(self.dp_axes))
            words = or_allreduce(words, self.dp_axes, axis_indices=dp_idx)
            return sk, words
        topo = make_topology(cfg.topology, self.mesh, self.dp_axes)
        use_pp = True if self._full_manual() else None
        wire = FixedPointWire(workers=self._dp_world())
        sk_b = sk.reshape(plan.n_buckets, -1)
        exp = wire.shared_exponents(sk_b, self.dp_axes)
        q = wire.encode(sk_b, exp)
        q = tree_all_reduce(q, topo, "add", axis_indices=dp_idx,
                            use_ppermute=use_pp)
        words = tree_all_reduce(words, topo, "or", axis_indices=dp_idx,
                                use_ppermute=use_pp)
        return wire.decode(q, exp).reshape(sk.shape), words


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------

AGGREGATORS = {
    "dense": DenseAggregator,
    "compressed": CompressedAggregator,
    "compressed_rs": CompressedReduceScatterAggregator,
    "compressed_innet": CompressedInNetworkAggregator,
}


def make_aggregator(name: str, cfg: CompressionConfig, mesh,
                    dp_axes: Sequence[str],
                    tp_axes: Sequence[str] = ("model",),
                    mean: bool = True, outer_manual=None) -> Aggregator:
    """Build the named strategy (see :data:`AGGREGATORS`).

    ``outer_manual``: the axis set the calling shard_map takes manual
    (see :class:`CompressedAggregator.outer_manual`).
    """
    if isinstance(dp_axes, str):
        dp_axes = (dp_axes,)
    if isinstance(tp_axes, str):
        tp_axes = (tp_axes,)
    try:
        cls = AGGREGATORS[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {name!r}; have {sorted(AGGREGATORS)}")
    return cls(cfg=cfg, mesh=mesh, dp_axes=tuple(dp_axes),
               tp_axes=tuple(tp_axes), mean=mean,
               outer_manual=None if outer_manual is None
               else tuple(outer_manual))
