"""Per-bucket wire planning: the *plan* half of the plan/execute split
(PR 6).

"On the Utility of Gradient Compression in Distributed Training Systems"
(PAPERS.md) shows compression frequently loses to dense aggregation on
fast links, and THC argues the wire format should be chosen per tensor,
not per job.  Our own toy benchmark agrees (dense wall ~3.1 ms vs
compressed ~5.5 ms on the CI host).  So the strategy choice moves from
"one wire owns the whole step" to a :class:`WirePlan`: a static
partition of the :class:`~repro.core.bucketing.BucketPlan`'s buckets
into contiguous groups, each assigned one of the four fixed wires.  The
aggregators in :mod:`repro.core.aggregators` *execute* whatever plan
they are handed, group by group, through the shared stream scheduler;
:mod:`repro.core.costmodel` *produces* plans for the ``auto`` strategy.

The numerics contract that makes mixed plans safe: per-leaf
sparsify/error-feedback happen before packing and are untouched by the
plan, buckets are the codec's atomic unit, and every group encodes at
its **global** block offsets (``StreamPlan.base_block``), so a group's
sketch/bitmap payload is bit-for-bit the corresponding slice of the
full-stream payload.  Any plan is therefore bit-identical to the fixed
strategy it assigns on the buckets it assigns — pinned by the mixed-plan
arms in ``tests/drivers/collectives_driver.py`` and
``tests/test_dispatch.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# The four fixed wires a group may be assigned.  This tuple IS the
# controller's search space; ``core/aggregators.py`` asserts at import
# time that it equals the fixed-strategy registry keys, so the `auto`
# search space and the executable strategies can never drift apart.
WIRES = ("dense", "compressed", "compressed_rs", "compressed_innet")

# Collective *patterns* a group may run its wire over (PR 8).  The plan
# layer is pattern-parametric: ``allreduce`` is the gradient-aggregation
# shape every wire above supports; ``alltoall`` is the expert-parallel
# dispatch/combine permute shape, carried today by the ``dense`` and
# ``compressed`` wires only (RS/innet are reduce-tree refinements of the
# all-reduce pattern and have no permute analogue).
PATTERNS = ("allreduce", "alltoall")

# wires that can execute each pattern
_PATTERN_WIRES = {
    "allreduce": WIRES,
    "alltoall": ("dense", "compressed"),
}


def pattern_wires(pattern: str) -> Tuple[str, ...]:
    """The wires able to execute ``pattern`` — the controller's search
    space per pattern (``core/aggregators.py`` asserts its exchange
    registry against the ``alltoall`` entry the same way it pins
    ``AGGREGATORS`` against :data:`WIRES`)."""
    if pattern not in PATTERNS:
        raise ValueError(
            f"unknown pattern {pattern!r}; valid patterns: {PATTERNS}")
    return _PATTERN_WIRES[pattern]


@dataclasses.dataclass(frozen=True)
class WireGroup:
    """One contiguous run of buckets shipped over one wire."""

    start: int             # first bucket index (into the BucketPlan)
    n_buckets: int         # whole buckets in this group
    wire: str              # one of WIRES
    stream_chunks: Optional[int] = None
    # per-group chunk-grid override (None = the config's grid); lets the
    # controller tune overlap granularity per group
    pattern: str = "allreduce"   # one of PATTERNS

    def __post_init__(self):
        if self.wire not in WIRES:
            raise ValueError(
                f"unknown wire {self.wire!r}; valid wires: {WIRES}")
        if self.pattern not in PATTERNS:
            raise ValueError(
                f"unknown pattern {self.pattern!r}; valid patterns: "
                f"{PATTERNS}")
        if self.wire not in _PATTERN_WIRES[self.pattern]:
            raise ValueError(
                f"wire {self.wire!r} cannot run the {self.pattern!r} "
                f"pattern; {self.pattern!r} wires: "
                f"{_PATTERN_WIRES[self.pattern]}")
        if self.start < 0:
            raise ValueError(f"start must be >= 0, got {self.start}")
        if self.n_buckets < 1:
            raise ValueError(
                f"n_buckets must be >= 1, got {self.n_buckets}")
        if self.stream_chunks is not None and self.stream_chunks < 1:
            raise ValueError(
                f"stream_chunks must be >= 1, got {self.stream_chunks}")
        if self.wire == "dense" and self.stream_chunks is not None:
            raise ValueError(
                "dense groups have no wire-chunk grid (they psum the "
                "packed buckets in one shot); stream_chunks must be None")

    @property
    def stop(self) -> int:
        return self.start + self.n_buckets


@dataclasses.dataclass(frozen=True)
class WirePlan:
    """Static partition of ``n_buckets`` buckets into wire groups.

    Groups must tile the bucket range exactly (contiguous, in order,
    full coverage) — a plan never drops or duplicates a bucket.
    Hashable and static: the compiled step is specialized per plan, and
    the ``auto`` controller re-plans only every ``cfg.replan_every``
    steps so recompilation stays rare.
    """

    n_buckets: int
    groups: Tuple[WireGroup, ...]

    def __post_init__(self):
        if self.n_buckets < 1:
            raise ValueError(
                f"n_buckets must be >= 1, got {self.n_buckets}")
        if not self.groups:
            raise ValueError("a WirePlan needs at least one group")
        object.__setattr__(self, "groups", tuple(self.groups))
        pos = 0
        for g in self.groups:
            if g.start != pos:
                raise ValueError(
                    f"groups must tile buckets contiguously: group at "
                    f"bucket {g.start} but previous group ends at {pos}")
            pos = g.stop
        if pos != self.n_buckets:
            raise ValueError(
                f"groups cover {pos} buckets, plan has {self.n_buckets}")
        patterns = {g.pattern for g in self.groups}
        if len(patterns) > 1:
            raise ValueError(
                "a WirePlan must be single-pattern: all groups must share "
                "one collective pattern (a bucket stream is packed for "
                "either the allreduce or the alltoall shape, never both); "
                f"got {sorted(patterns)}")

    @property
    def pattern(self) -> str:
        """The plan's (single, validated) collective pattern."""
        return self.groups[0].pattern

    @property
    def uniform_wire(self) -> Optional[str]:
        """The single wire when every group shares it, else None."""
        wires = {g.wire for g in self.groups}
        return next(iter(wires)) if len(wires) == 1 else None

    @property
    def is_trivial(self) -> bool:
        """One group, one wire, no chunk override — the plan that is
        exactly a fixed strategy over the whole stream."""
        return (len(self.groups) == 1
                and self.groups[0].stream_chunks is None)

    def wire_of(self, bucket: int) -> str:
        """Wire assigned to one bucket (static Python)."""
        if not 0 <= bucket < self.n_buckets:
            raise ValueError(
                f"bucket {bucket} out of range [0, {self.n_buckets})")
        for g in self.groups:
            if g.start <= bucket < g.stop:
                return g.wire
        raise AssertionError("unreachable: plan validated as covering")

    def describe(self) -> str:
        pat = "" if self.pattern == "allreduce" else f" @{self.pattern}"
        return " | ".join(
            f"[{g.start}:{g.stop}]={g.wire}"
            + (f"/c{g.stream_chunks}" if g.stream_chunks else "")
            for g in self.groups) + pat


def uniform_plan(n_buckets: int, wire: str,
                 stream_chunks: Optional[int] = None,
                 pattern: str = "allreduce") -> WirePlan:
    """The degenerate plan: every bucket on one wire (today's fixed
    strategies are exactly these plans)."""
    return WirePlan(n_buckets=n_buckets, groups=(
        WireGroup(start=0, n_buckets=n_buckets, wire=wire,
                  stream_chunks=stream_chunks, pattern=pattern),))


def plan_from_assignments(wires: Sequence[str]) -> WirePlan:
    """Coalesce a per-bucket wire assignment (one wire name per bucket)
    into a plan, merging adjacent same-wire buckets into one group."""
    if not wires:
        raise ValueError("need at least one bucket assignment")
    groups = []
    start = 0
    for i in range(1, len(wires) + 1):
        if i == len(wires) or wires[i] != wires[start]:
            groups.append(WireGroup(
                start=start, n_buckets=i - start, wire=wires[start]))
            start = i
    return WirePlan(n_buckets=len(wires), groups=tuple(groups))
