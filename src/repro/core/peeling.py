"""Parallel peeling recovery (paper §3.2) — pure-jnp reference.

Given the aggregated sketch ``Y`` and the aggregated non-zero index ``B``
for a set of blocks, repeatedly:

1. compute the *degree* ``D[r, m]`` — how many indexed coordinates hash
   into sketch cell ``(r, m)``;
2. every indexed coordinate owning a cell with ``D == 1`` (a singleton) is
   recovered **exactly** as ``g_j(i) * Y[h_j(i), .]``;
3. peel it: subtract its value from all three of its cells, clear its
   index bit, decrement the three degrees.

Each round is fully vectorised over every coordinate of every block (the
"parallel" in parallel peeling); with block-local sketches the process
converges in O(1) rounds (paper §3.2). Coordinates still indexed after the
final round fall back to the unbiased median estimate (footnote 5); with
``nnz_block <= rows*c/1.23`` that set is empty w.h.p. — the lossless case.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import CompressionConfig
from . import hashing
from .sketch import (plan_tables, roll_to_sketch, roll_from_sketch,
                     scatter_rows, gather_rows)


class PeelResult(NamedTuple):
    values: jnp.ndarray      # (nb, G, c) f32 — recovered + estimated
    peeled: jnp.ndarray      # (nb, G, c) bool — recovered exactly
    residual: jnp.ndarray    # (nb, G, c) bool — indexed but unpeeled (estimate used)
    rounds_used: jnp.ndarray # () int32 — rounds until fixpoint (<= cfg.rounds)


def _median3(est: jnp.ndarray) -> jnp.ndarray:
    v0, v1, v2 = est[:, :, 0], est[:, :, 1], est[:, :, 2]
    return (v0 + v1 + v2
            - jnp.maximum(jnp.maximum(v0, v1), v2)
            - jnp.minimum(jnp.minimum(v0, v1), v2))


def peel_blocks(sketch: jnp.ndarray, bits: jnp.ndarray, block_ids: jnp.ndarray,
                cfg: CompressionConfig) -> PeelResult:
    """Recover block values from (sketch, index-bits).

    Args:
      sketch:    (nb, rows, c) f32 — aggregated Count Sketch.
      bits:      (nb, G, c) bool — aggregated non-zero index (bitmap or
                 Bloom-filter candidate set; false positives peel to ~0).
      block_ids: (nb,) int32 — global block ids (rotation seeds).
    """
    rows_tbl, signs_np = plan_tables(cfg)
    signs = jnp.asarray(signs_np)[None, :, :, None]                  # (1,G,3,1)
    rot = hashing.block_rotations(block_ids, cfg.group, cfg.lanes, cfg.seed)

    # Initial degrees: scatter the (rotated) index bits.
    ones = roll_to_sketch(bits.astype(jnp.int32), rot, cfg.lanes)    # (nb,G,3,c)
    deg = scatter_rows(ones, rows_tbl, cfg.rows)                     # (nb,rows,c) i32

    def round_body(state):
        y, b, d, x_rec, it, _changed = state
        d_at = roll_from_sketch(gather_rows(d, rows_tbl), rot, cfg.lanes)   # (nb,G,3,c)
        y_at = roll_from_sketch(gather_rows(y, rows_tbl), rot, cfg.lanes)
        val_at = y_at * signs
        peelable = (d_at == 1) & b[:, :, None, :]
        any_peel = jnp.any(peelable, axis=2)                               # (nb,G,c)
        jstar = jnp.argmax(peelable, axis=2)                               # first true
        val = jnp.take_along_axis(val_at, jstar[:, :, None, :], axis=2)[:, :, 0, :]
        val = jnp.where(any_peel, val, 0.0)
        # Remove peeled coordinates from sketch / degrees / index.
        v_contrib = roll_to_sketch(val, rot, cfg.lanes) * signs
        m_contrib = roll_to_sketch(any_peel.astype(jnp.int32), rot, cfg.lanes)
        y = y - scatter_rows(v_contrib, rows_tbl, cfg.rows)
        d = d - scatter_rows(m_contrib, rows_tbl, cfg.rows)
        b = b & ~any_peel
        x_rec = x_rec + val
        changed = jnp.any(any_peel)
        return y, b, d, x_rec, it + 1, changed

    def round_cond(state):
        *_, it, changed = state
        return (it < cfg.rounds) & changed

    x0 = jnp.zeros(bits.shape, jnp.float32)
    state = (sketch.astype(jnp.float32), bits, deg, x0,
             jnp.int32(0), jnp.bool_(True))
    y, b, d, x_rec, it, _ = jax.lax.while_loop(round_cond, round_body, state)

    # Residue: unbiased Count-Sketch estimate from what is left in the sketch.
    est = _median3(roll_from_sketch(gather_rows(y, rows_tbl), rot, cfg.lanes) * signs)
    values = x_rec + jnp.where(b, est, 0.0)
    peeled_mask = bits & ~b
    return PeelResult(values=values, peeled=peeled_mask, residual=b, rounds_used=it)
