"""Configuration for the lossless homomorphic compressor.

The knobs mirror the paper's design space:

- ``ratio``      — compressed sketch cells / original elements (the paper
                   sweeps 2%..200%; its end-to-end runs fix 10%).
- ``lanes``      — the locality batch width ``c`` of §3.4. On GPU the paper
                   uses 1024 (threads per block); on TPU we default to 512
                   = 4 x 128 so a batch row is lane-aligned in VMEM.
- ``rows``       — sketch rows per block, split into 3 hash partitions
                   (3-partite hypergraph, peeling threshold gamma = 1.23).
- ``rounds``     — peeling iterations; the paper proves log log n + O(1)
                   and reaches O(1) by splitting the sketch into fixed-size
                   blocks, which is structural here.
- ``index``      — "bitmap" (exact, 1 bit/coordinate, §3.2) or "bloom"
                   (probabilistic, §3.3, for extreme sparsity).
- ``bucket_bytes`` / ``overlap`` — the aggregation substrate (PR 2): the
                   whole gradient pytree is packed into fixed-byte flat
                   buckets before encoding (see
                   :mod:`repro.core.bucketing`), so the codec and the
                   collectives launch O(n_buckets) times instead of
                   O(n_leaves); ``overlap`` stages bucket *i*'s
                   collectives against bucket *i+1*'s encode.
- ``wire_dtype`` / ``switch_slots`` / ``topology`` — the in-network
                   aggregation tier (PR 4): the ``compressed_innet``
                   strategy ships the sketch over an emulated
                   programmable-switch tree (:mod:`repro.net`),
                   optionally quantized to overflow-free fixed point.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

GAMMA = 1.23  # 3-ary peeling threshold from the paper (§3.2)


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Static plan for the homomorphic compressor (hashable, jit-friendly)."""

    ratio: float = 0.10          # sketch elements / original elements
    lanes: int = 512             # batch width c (multiple of 128 on TPU)
    rows: int = 6                # sketch rows per block; divisible by 3
    rounds: int = 10             # peeling iteration cap (while_loop exits
                                 # at fixpoint; log log n + O(1) expected)
    index: str = "bitmap"        # "bitmap" | "bloom"
    bloom_hashes: int = 3        # k for the Bloom filter variant
    bloom_bits_ratio: float = 0.125  # bloom bits per original element
    topk_ratio: Optional[float] = None   # optional sparsity budget
    topk_exact: bool = False     # exact lax.top_k (O(n log n) sort buffers)
                                 # vs sampled-quantile threshold (O(n))
    error_feedback: bool = True  # accumulate unsent residual (DGC-style)
    seed: int = 0x5EED
    chunk_blocks: int = 512      # blocks per lax.map chunk (memory bound)
    use_pallas: str = "auto"     # "never" | "always" | "auto"
    encode_block_tile: int = 8   # sketch blocks per encode-kernel grid
                                 # cell (VMEM-bounded; see sketch_encode)
    peel_block_tile: int = 4     # sketch blocks per peel-kernel grid cell
                                 # (smaller than encode: the peel loop
                                 # keeps y/b/d/x tiles live across rounds)
    bucket_bytes: int = 4 << 20  # target f32 bytes per aggregation bucket
                                 # (rounded to block/word alignment; see
                                 # bucketing.BucketPlan)
    overlap: bool = False        # pipeline chunk i's collectives against
                                 # chunk i+1's encode through the shared
                                 # stream scheduler (core/streams.py);
                                 # the default grid is the finest aligned
                                 # one (per bucket on the AllReduce wire,
                                 # per rank-chunk on the native RS wire,
                                 # per switch window on the innet tree)
    stream_chunks: Optional[int] = None
                                 # explicit wire-chunk count for the
                                 # stream scheduler (implies overlap).
                                 # Must respect the strategy's alignment
                                 # constraints: divide ceil(n_buckets/W)
                                 # on the native RS wire, span whole
                                 # switch_slots windows on the innet
                                 # tree (ValueError otherwise); any
                                 # count is valid on the AllReduce wire
                                 # (non-divisible grids zero-pad).
    rs_wire: str = "auto"        # reduce-scatter strategy wire path:
                                 # "auto"    — native psum_scatter + OR-RS
                                 #             when the JAX leg / region
                                 #             supports it, else the
                                 #             psum+slice emulation;
                                 # "native"  — require native (raise if
                                 #             unsupported);
                                 # "emulate" — force the emulation (for
                                 #             parity tests / benchmarks)
    wire_dtype: str = "f32"      # compressed_innet sketch wire (PR 4):
                                 # "f32"   — idealized float-capable
                                 #           aggregation tier (bit-parity
                                 #           with 'compressed');
                                 # "fxp32" — per-bucket shared-exponent
                                 #           int32, overflow-free for the
                                 #           DP world size — what a real
                                 #           switch can sum (see
                                 #           repro.net.fixedpoint)
    switch_slots: int = 8        # emulated switch SRAM aggregation slots
                                 # (bucket-chunks resident per streaming
                                 # window; see repro.net.switch)
    topology: str = "flat"       # in-network reduction tree: "flat" (one
                                 # switch) | "tor_spine" (one tier per DP
                                 # axis; see repro.net.topology)
    sketch_dtype: str = "float32"
    # ---- `auto` strategy cost-model knobs (PR 6) ---------------------
    replan_every: int = 16       # steps between wire-plan refreshes for
                                 # the `auto` strategy; the compiled step
                                 # is static per plan, so this bounds
                                 # recompilation frequency
    auto_link_gbps: float = 400.0  # analytic prior: link bandwidth used
                                 # to turn strategy_wire_bytes into
                                 # seconds before any telemetry exists.
                                 # Default = the per-link ICI roofline
                                 # (costmodel.ICI_BW, 50 GB/s); override
                                 # from benchmarks/roofline.py --codec
                                 # via costmodel.priors_from_codec_report
    auto_codec_gbps: float = 6552.0  # analytic prior: codec streaming
                                 # throughput (bytes of bucket stream
                                 # per second PER PASS) for the
                                 # codec-compute term. Default = the
                                 # HBM roofline (costmodel.HBM_BW,
                                 # 819 GB/s); the per-wire pass counts
                                 # (kernels.ops.wire_codec_passes) turn
                                 # this into seconds
    auto_occupancy_margin: float = 0.9
                                 # compressed wires are infeasible for a
                                 # bucket whose measured nonzero count
                                 # exceeds this fraction of the peeling
                                 # capacity (recovery would go lossy);
                                 # such buckets are planned dense

    def __post_init__(self):
        if self.rows % 3 != 0 or self.rows < 3:
            raise ValueError(f"rows must be a positive multiple of 3, got {self.rows}")
        if not 0.0 < self.ratio:
            raise ValueError(f"ratio must be positive, got {self.ratio}")
        if self.lanes < 8:
            raise ValueError(f"lanes must be >= 8, got {self.lanes}")
        if self.index not in ("bitmap", "bloom"):
            raise ValueError(f"index must be 'bitmap' or 'bloom', got {self.index}")
        if self.encode_block_tile < 1:
            raise ValueError(
                f"encode_block_tile must be >= 1, got {self.encode_block_tile}")
        if self.peel_block_tile < 1:
            raise ValueError(
                f"peel_block_tile must be >= 1, got {self.peel_block_tile}")
        if self.bucket_bytes < 4:
            raise ValueError(
                f"bucket_bytes must be >= 4, got {self.bucket_bytes}")
        if (self.overlap or self.stream_chunks is not None) \
                and self.index != "bitmap":
            # Per-chunk OR collectives slice the packed bitmap by bucket;
            # a Bloom filter is one global structure and cannot be sliced.
            raise ValueError(
                "overlap/stream_chunks require index='bitmap'")
        if self.stream_chunks is not None and self.stream_chunks < 1:
            raise ValueError(
                f"stream_chunks must be >= 1, got {self.stream_chunks}")
        if self.rs_wire not in ("auto", "native", "emulate"):
            raise ValueError(
                f"rs_wire must be 'auto', 'native' or 'emulate', "
                f"got {self.rs_wire!r}")
        if self.wire_dtype not in ("f32", "fxp32"):
            raise ValueError(
                f"wire_dtype must be 'f32' or 'fxp32', got "
                f"{self.wire_dtype!r}")
        if self.switch_slots < 1:
            raise ValueError(
                f"switch_slots must be >= 1, got {self.switch_slots}")
        if self.topology not in ("flat", "tor_spine"):
            raise ValueError(
                f"topology must be 'flat' or 'tor_spine', got "
                f"{self.topology!r}")
        if self.replan_every < 1:
            raise ValueError(
                f"replan_every must be >= 1, got {self.replan_every}")
        if self.auto_link_gbps <= 0 or self.auto_codec_gbps <= 0:
            raise ValueError(
                f"auto_link_gbps/auto_codec_gbps must be positive, got "
                f"{self.auto_link_gbps}/{self.auto_codec_gbps}")
        if not 0.0 < self.auto_occupancy_margin <= 1.0:
            raise ValueError(
                f"auto_occupancy_margin must be in (0, 1], got "
                f"{self.auto_occupancy_margin}")

    # ---- derived static geometry -------------------------------------

    @property
    def group(self) -> int:
        """G — gradient batches per sketch block (rows / ratio)."""
        return max(1, round(self.rows / self.ratio))

    @property
    def block_elems(self) -> int:
        """Original elements covered by one block."""
        return self.group * self.lanes

    @property
    def sketch_elems(self) -> int:
        """Sketch cells per block."""
        return self.rows * self.lanes

    @property
    def peel_capacity(self) -> int:
        """Max non-zeros per block recoverable w.h.p. (|Y| / gamma)."""
        return int(self.sketch_elems / GAMMA)

    def num_blocks(self, n: int) -> int:
        """Blocks needed to cover ``n`` elements."""
        return -(-n // self.block_elems)

    def padded_size(self, n: int) -> int:
        return self.num_blocks(n) * self.block_elems

    # ---- bucket geometry (PR 2 aggregation substrate) ----------------

    @property
    def bucket_quantum(self) -> int:
        """Alignment unit for bucket sizes: whole sketch blocks *and*
        whole packed-bitmap uint32 words, so per-bucket sketch / index
        slices of the fused stream are exact views."""
        return math.lcm(self.block_elems, 32)

    def bucket_elems_for(self, total_elems: int) -> int:
        """f32 elements per bucket for a stream of ``total_elems``.

        ``bucket_bytes`` rounded up to the alignment quantum, but never
        larger than the (quantum-rounded) stream itself — a pytree
        smaller than one configured bucket gets a single right-sized
        bucket instead of megabytes of zero padding.
        """
        if total_elems < 1:
            raise ValueError(f"total_elems must be >= 1, got {total_elems}")
        q = self.bucket_quantum
        want = max(1, self.bucket_bytes // 4)
        elems = -(-want // q) * q
        cap = -(-total_elems // q) * q
        return min(elems, cap)

    def num_buckets(self, total_elems: int) -> int:
        return -(-total_elems // self.bucket_elems_for(total_elems))

    def wire_bytes(self, n: int, grad_bytes_per_elem: int = 2) -> dict:
        """Strategy-agnostic payload sizes for ``n`` elements.

        These are the sizes of the *objects* that cross the wire — the
        fp32 sketch (``sketch_bytes``), the packed index
        (``index_bytes``, 1 bit/element bitmap or the Bloom filter), and
        the dense baseline gradient (``dense_bytes``) — NOT what any
        particular collective ships per rank: an AllReduce materializes
        the whole reduced payload on every rank while a reduce-scatter
        lands only ``1/W`` of it, and link traffic further depends on
        the algorithm (ring AllReduce moves ``2(W-1)/W x`` payload per
        rank, a reduce-scatter ``(W-1)/W x``). For per-rank,
        per-strategy accounting use :meth:`strategy_wire_bytes`.

        Includes the per-bucket totals of the bucketed aggregation path:
        ``n`` is taken as the whole packed stream, split into
        ``n_buckets`` buckets of ``bucket_elems`` each (last one padded),
        and each bucket ships ``bucket_sketch_bytes + bucket_index_bytes``.
        """
        nb = self.num_blocks(n)
        sketch = nb * self.sketch_elems * 4  # fp32 sketch
        if self.index == "bitmap":
            idx = -(-self.padded_size(n) // 32) * 4  # 1 bit / elem, packed u32
        else:
            idx = int(n * self.bloom_bits_ratio / 32 + 1) * 4
        dense = n * grad_bytes_per_elem
        be = self.bucket_elems_for(n)
        n_buckets = self.num_buckets(n)
        b_sketch = (be // self.block_elems) * self.sketch_elems * 4
        if self.index == "bitmap":
            b_idx = (be // 32) * 4
        else:
            b_idx = int(be * self.bloom_bits_ratio / 32 + 1) * 4
        return {
            "sketch_bytes": sketch,
            "index_bytes": idx,
            "total_bytes": sketch + idx,
            "dense_bytes": dense,
            "wire_fraction": (sketch + idx) / max(dense, 1),
            "n_buckets": n_buckets,
            "bucket_elems": be,
            "bucket_sketch_bytes": b_sketch,
            "bucket_index_bytes": b_idx,
            "bucket_total_bytes": b_sketch + b_idx,
            "bucketed_total_bytes": n_buckets * (b_sketch + b_idx),
        }

    def strategy_wire_bytes(self, n: int, workers: int,
                            grad_bytes_per_elem: int = 2,
                            zero1_aligned: bool = False) -> dict:
        """Per-rank wire accounting for each aggregation strategy.

        For a stream of ``n`` elements reduced across ``workers`` (W)
        ranks, reports for every strategy in
        :data:`repro.core.aggregators.AGGREGATORS` (the reduce-scatter
        one split into its native and emulated wire paths):

        - ``rank_payload_bytes`` — the reduced payload that *lands on*
          each rank after its collectives: the full dense gradient /
          full sketch+index for the AllReduce strategies, but only the
          ``1/W`` sketch+bitmap slice for the native reduce-scatter
          path (padded to whole per-rank bucket chunks). This is the
          number the paper's "aggregatable at full collective
          bandwidth" claim is about.
        - ``link_bytes`` — bytes each rank *sends* under the standard
          bandwidth-optimal algorithms: ring AllReduce at
          ``2(W-1)/W x`` payload, reduce-scatter at ``(W-1)/W x``. The
          in-network tree sends the payload exactly **once** up the
          worker's access link (switches combine in flight), so its
          ``link_bytes`` is ``1 x`` payload.
        - ``root_link_bytes`` (``compressed_innet`` only) — what the
          tree's root link carries per direction: the aggregated stream
          crosses it once no matter how many workers hang below
          (``payload/fanout`` per child, amortized), vs every ring
          link carrying ``2(W-1)/W x`` payload. With
          ``wire_dtype='fxp32'`` the payload additionally ships one
          int32 shared exponent per bucket (``exponent_bytes``); the
          per-tier switch ingress/occupancy numbers live in
          :meth:`repro.net.topology.Topology.link_profile` and the
          ``SwitchModel`` report, which need the concrete topology.

        The compressed payloads are those of the *bucket-padded* packed
        stream (``n_buckets x bucket_elems`` elements) — what the
        bucketed aggregators actually encode and ship — further padded
        to whole per-rank chunks of ``ceil(n_buckets/W)`` buckets for
        the native RS arm. (With fewer buckets than ranks that chunk
        padding can erase the native win entirely: one bucket over two
        ranks scatters nothing.) Other caveats: the numbers model the
        *native* collectives; on a 0.4.x partial-auto leg the
        OR-AllReduce is psum-emulated at 32x the bitmap's wire volume
        (``or_emulated_factor`` is provided to scale index traffic for
        that leg).

        ``compressed_rs``'s native path reports the recovered-chunk
        all_gather separately: ``link_bytes_with_gather`` counts it,
        ``link_bytes_no_gather`` does not (the psum-trick fallback ships
        2x ``rs_gather_link_bytes``), and ``link_bytes`` — the number
        the ``--compare-rs`` CI gate measures — picks between them by
        ``zero1_aligned``: pass True when the stream chunk grid aligns
        with the ZeRO-1 optimizer slices
        (:func:`repro.core.streams.zero1_gather_skip`), where the
        aggregator feeds the per-rank recovered chunks straight into the
        optimizer shards and the gather is skipped entirely.

        Every entry names its collective ``pattern`` (PR 8): the
        aggregation strategies above are ``allreduce``; the
        ``dense_alltoall`` / ``compressed_alltoall`` entries model the
        expert-parallel permute wire, where ``n`` is this rank's
        *stacked* W-lane dispatch/combine payload and each rank
        sends/receives ``(W-1)/W x`` of it (its own lane stays local).
        """
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        W = workers
        base = self.wire_bytes(n, grad_bytes_per_elem)
        dense = base["dense_bytes"]
        nb = base["n_buckets"]
        be = base["bucket_elems"]

        def payload(n_buckets: int):
            """sketch+index bytes of ``n_buckets`` whole buckets."""
            elems = n_buckets * be
            sketch = (elems // self.block_elems) * self.sketch_elems * 4
            if self.index == "bitmap":
                return sketch, (elems // 32) * 4
            return sketch, int(elems * self.bloom_bits_ratio / 32 + 1) * 4

        full = sum(payload(nb))
        # Native RS pads the stream to whole per-rank chunks of buckets.
        nb_p = -(-nb // W) * W
        if self.index == "bitmap":
            sketch_p, idx_p = payload(nb_p)
        else:
            idx_p = None  # Bloom cannot be sliced: no native RS wire
        ring = 2 * (W - 1) / W
        rs = (W - 1) / W
        out = {
            "workers": W,
            "elems": n,
            "or_emulated_factor": 32,
            "dense": {
                "rank_payload_bytes": dense,
                "link_bytes": int(dense * ring),
            },
            "compressed": {
                "rank_payload_bytes": full,
                "link_bytes": int(full * ring),
            },
            # Emulated RS reduces the full sketch+index on every rank
            # (psum + local slice): AllReduce wire, RS compute only.
            "compressed_rs_emulated": {
                "rank_payload_bytes": full,
                "link_bytes": int(full * ring),
            },
        }
        if idx_p is not None:
            rs_link = int((sketch_p + idx_p) * rs)
            gather = int(nb_p * be * 4 * rs)
            out["compressed_rs_native"] = {
                "rank_payload_bytes": (sketch_p + idx_p) // W,
                "rs_gather_link_bytes": gather,
                "link_bytes_with_gather": rs_link + gather,
                "link_bytes_no_gather": rs_link,
                "zero1_aligned": zero1_aligned,
                "link_bytes": rs_link + (0 if zero1_aligned else gather),
            }
        else:
            out["compressed_rs_native"] = None
        # In-network tree (PR 4): the bucket-padded stream goes up the
        # tree once per worker and comes back once; no per-rank chunk
        # padding (every rank receives the whole aggregate).
        exp_bytes = nb * 4 if self.wire_dtype == "fxp32" else 0
        innet = full + exp_bytes
        out["compressed_innet"] = {
            "rank_payload_bytes": innet,
            "link_bytes": innet if W > 1 else 0,
            "root_link_bytes": innet if W > 1 else 0,
            "exponent_bytes": exp_bytes,
        }
        for entry in out.values():
            if isinstance(entry, dict):
                entry["pattern"] = "allreduce"
        # ---- the permute pattern (PR 8) ------------------------------
        # ``n`` is reinterpreted as this rank's *stacked* all-to-all
        # payload (all W destination lanes); each destination's slice of
        # ceil(n/W) elements gets its own bucket run. Every rank keeps
        # its own lane local and sends/receives the other W-1 —
        # (W-1)/W x the stacked payload each way, the all-to-all analogue
        # of the reduce-scatter factor. The compressed wire ships the
        # sketch+bitmap of each lane instead of the raw slice; the
        # psum-emulation fallback (0.4.x partial-auto, multi-axis EP)
        # reduces the whole stack at ring AllReduce volume
        # (``link_bytes_emulated``; bitmap additionally at
        # ``or_emulated_factor``).
        n_d = -(-n // W)                  # per-destination slice elems
        be_d = self.bucket_elems_for(n_d)
        nb_d = self.num_buckets(n_d)
        lane_elems = nb_d * be_d
        lane_sketch = (lane_elems // self.block_elems) * self.sketch_elems * 4
        if self.index == "bitmap":
            lane_idx = (lane_elems // 32) * 4
        else:
            lane_idx = int(lane_elems * self.bloom_bits_ratio / 32 + 1) * 4
        lane_bytes = lane_sketch + lane_idx
        comp_stack = W * lane_bytes
        out["dense_alltoall"] = {
            "pattern": "alltoall",
            "payload_bytes": dense,
            "rank_payload_bytes": int(dense * rs),
            "link_bytes": int(dense * rs),
        }
        out["compressed_alltoall"] = {
            "pattern": "alltoall",
            "n_lane_buckets": nb_d,
            "lane_payload_bytes": lane_bytes,
            "payload_bytes": comp_stack,
            "rank_payload_bytes": int(comp_stack * rs),
            "link_bytes": int(comp_stack * rs),
            "link_bytes_emulated": int(comp_stack * ring),
        }
        return out
