"""Configuration for the lossless homomorphic compressor.

The knobs mirror the paper's design space:

- ``ratio``      — compressed sketch cells / original elements (the paper
                   sweeps 2%..200%; its end-to-end runs fix 10%).
- ``lanes``      — the locality batch width ``c`` of §3.4. On GPU the paper
                   uses 1024 (threads per block); on TPU we default to 512
                   = 4 x 128 so a batch row is lane-aligned in VMEM.
- ``rows``       — sketch rows per block, split into 3 hash partitions
                   (3-partite hypergraph, peeling threshold gamma = 1.23).
- ``rounds``     — peeling iterations; the paper proves log log n + O(1)
                   and reaches O(1) by splitting the sketch into fixed-size
                   blocks, which is structural here.
- ``index``      — "bitmap" (exact, 1 bit/coordinate, §3.2) or "bloom"
                   (probabilistic, §3.3, for extreme sparsity).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

GAMMA = 1.23  # 3-ary peeling threshold from the paper (§3.2)


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Static plan for the homomorphic compressor (hashable, jit-friendly)."""

    ratio: float = 0.10          # sketch elements / original elements
    lanes: int = 512             # batch width c (multiple of 128 on TPU)
    rows: int = 6                # sketch rows per block; divisible by 3
    rounds: int = 10             # peeling iteration cap (while_loop exits
                                 # at fixpoint; log log n + O(1) expected)
    index: str = "bitmap"        # "bitmap" | "bloom"
    bloom_hashes: int = 3        # k for the Bloom filter variant
    bloom_bits_ratio: float = 0.125  # bloom bits per original element
    topk_ratio: Optional[float] = None   # optional sparsity budget
    topk_exact: bool = False     # exact lax.top_k (O(n log n) sort buffers)
                                 # vs sampled-quantile threshold (O(n))
    error_feedback: bool = True  # accumulate unsent residual (DGC-style)
    seed: int = 0x5EED
    chunk_blocks: int = 512      # blocks per lax.map chunk (memory bound)
    use_pallas: str = "auto"     # "never" | "always" | "auto"
    encode_block_tile: int = 8   # sketch blocks per encode-kernel grid
                                 # cell (VMEM-bounded; see sketch_encode)
    sketch_dtype: str = "float32"

    def __post_init__(self):
        if self.rows % 3 != 0 or self.rows < 3:
            raise ValueError(f"rows must be a positive multiple of 3, got {self.rows}")
        if not 0.0 < self.ratio:
            raise ValueError(f"ratio must be positive, got {self.ratio}")
        if self.lanes < 8:
            raise ValueError(f"lanes must be >= 8, got {self.lanes}")
        if self.index not in ("bitmap", "bloom"):
            raise ValueError(f"index must be 'bitmap' or 'bloom', got {self.index}")
        if self.encode_block_tile < 1:
            raise ValueError(
                f"encode_block_tile must be >= 1, got {self.encode_block_tile}")

    # ---- derived static geometry -------------------------------------

    @property
    def group(self) -> int:
        """G — gradient batches per sketch block (rows / ratio)."""
        return max(1, round(self.rows / self.ratio))

    @property
    def block_elems(self) -> int:
        """Original elements covered by one block."""
        return self.group * self.lanes

    @property
    def sketch_elems(self) -> int:
        """Sketch cells per block."""
        return self.rows * self.lanes

    @property
    def peel_capacity(self) -> int:
        """Max non-zeros per block recoverable w.h.p. (|Y| / gamma)."""
        return int(self.sketch_elems / GAMMA)

    def num_blocks(self, n: int) -> int:
        """Blocks needed to cover ``n`` elements."""
        return -(-n // self.block_elems)

    def padded_size(self, n: int) -> int:
        return self.num_blocks(n) * self.block_elems

    def wire_bytes(self, n: int, grad_bytes_per_elem: int = 2) -> dict:
        """Bytes on the wire for ``n`` elements vs. the dense baseline."""
        nb = self.num_blocks(n)
        sketch = nb * self.sketch_elems * 4  # fp32 sketch
        if self.index == "bitmap":
            idx = -(-self.padded_size(n) // 32) * 4  # 1 bit / elem, packed u32
        else:
            idx = int(n * self.bloom_bits_ratio / 32 + 1) * 4
        dense = n * grad_bytes_per_elem
        return {
            "sketch_bytes": sketch,
            "index_bytes": idx,
            "total_bytes": sketch + idx,
            "dense_bytes": dense,
            "wire_fraction": (sketch + idx) / max(dense, 1),
        }
