"""Hash family for the sketch / index structures.

Two flavours of the same splitmix32-style mixer:

- a **numpy** version used at *plan time* to derive the static per-batch
  row assignments ``h_j(i)`` and signs ``g_j(i)`` (shared across blocks —
  compile-time constants, which lets the Pallas kernel unroll its scatter
  targets), and
- a **jnp** version used *in-graph* for the per-(block, batch, hash)
  rotation offsets (the §3.4 locality randomisation) and for Bloom-filter
  bit positions, so no O(num_blocks) tables ever materialise.

The mixer is the Murmur3/splitmix finalizer — 2-independent-ish, cheap on
both scalar unit (host) and VPU (TPU): xor-shift + two odd multiplies.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35


def mix32_np(x: np.ndarray) -> np.ndarray:
    """splitmix/murmur3 finalizer on uint32 (numpy, plan time)."""
    x = np.asarray(x, dtype=np.uint32)
    x ^= x >> np.uint32(16)
    x = (x * np.uint32(_M1)) & np.uint32(0xFFFFFFFF)
    x ^= x >> np.uint32(13)
    x = (x * np.uint32(_M2)) & np.uint32(0xFFFFFFFF)
    x ^= x >> np.uint32(16)
    return x


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """Same mixer, traced (uint32 in-graph)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_M2)
    x = x ^ (x >> 16)
    return x


# ----------------------------------------------------------------------
# Static plan-time tables (shared across blocks)
# ----------------------------------------------------------------------

def batch_rows(group: int, rows: int, seed: int) -> np.ndarray:
    """Row assignment h_j(i) for each batch i and hash j.

    3-partite: hash j lands in rows [j*rows/3, (j+1)*rows/3), which is the
    standard construction for the gamma=1.23 peeling threshold.

    Returns int32 (group, 3).
    """
    per = rows // 3
    i = np.arange(group, dtype=np.uint32)
    out = np.empty((group, 3), dtype=np.int32)
    salt = np.uint32((seed * 0x9E3779B9) & 0xFFFFFFFF)
    for j in range(3):
        h = mix32_np(i * np.uint32(3) + np.uint32(j) + salt)
        out[:, j] = (h % np.uint32(per)).astype(np.int32) + j * per
    return out


def batch_signs(group: int, seed: int) -> np.ndarray:
    """Signs g_j(i) in {-1,+1}; float32 (group, 3)."""
    i = np.arange(group, dtype=np.uint32)
    out = np.empty((group, 3), dtype=np.float32)
    salt = np.uint32((seed ^ 0xA5A5A5A5) & 0xFFFFFFFF)
    for j in range(3):
        h = mix32_np(i * np.uint32(3) + np.uint32(j) + salt)
        out[:, j] = np.where(h & np.uint32(1), 1.0, -1.0)
    return out


# ----------------------------------------------------------------------
# Traced per-block tables
# ----------------------------------------------------------------------

def block_rotations(block_ids: jnp.ndarray, group: int, lanes: int, seed: int) -> jnp.ndarray:
    """Rotation offsets rot_j(i, blk) in [0, lanes) — int32 (nb, group, 3).

    Varies per block so different blocks realise different hypergraphs even
    though the row tables are shared (see DESIGN.md §2).
    """
    nb = block_ids.shape[0]
    i = jnp.arange(group, dtype=jnp.uint32)
    j = jnp.arange(3, dtype=jnp.uint32)
    key = (block_ids.astype(jnp.uint32)[:, None, None] * jnp.uint32(0x01000193)
           + i[None, :, None] * jnp.uint32(3)
           + j[None, None, :]
           + jnp.uint32(seed * 2654435761 & 0xFFFFFFFF))
    return (mix32(key) % jnp.uint32(lanes)).astype(jnp.int32)


def bloom_positions(ids: jnp.ndarray, k: int, m_bits: int, seed: int) -> jnp.ndarray:
    """Bloom-filter bit positions for coordinate ids — int32 (..., k)."""
    ids = ids.astype(jnp.uint32)
    ks = jnp.arange(k, dtype=jnp.uint32)
    h = mix32(ids[..., None] * jnp.uint32(k) + ks + jnp.uint32(seed ^ 0xB10053))
    return (h % jnp.uint32(m_bits)).astype(jnp.int32)
