"""Flat-vector <-> block layout used by the compressor.

A leaf of ``n`` elements is padded to ``nb * G * c`` and viewed as
``(nb, G, c)``: ``nb`` independent sketch blocks (the paper's fixed-size
block splitting, §3.2), each covering ``G`` locality batches of ``c``
consecutive elements (§3.4).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .config import CompressionConfig


@dataclasses.dataclass(frozen=True)
class LeafPlan:
    """Static geometry for one gradient leaf."""

    n: int           # true element count
    nb: int          # number of blocks
    group: int       # G
    lanes: int       # c

    @property
    def padded(self) -> int:
        return self.nb * self.group * self.lanes

    @property
    def pad(self) -> int:
        return self.padded - self.n


def make_plan(n: int, cfg: CompressionConfig) -> LeafPlan:
    return LeafPlan(n=n, nb=cfg.num_blocks(n), group=cfg.group, lanes=cfg.lanes)


def to_blocks(x: jnp.ndarray, plan: LeafPlan) -> jnp.ndarray:
    """Flatten, zero-pad, and reshape to (nb, G, c)."""
    flat = x.reshape(-1)
    if flat.shape[0] != plan.n:
        raise ValueError(f"leaf has {flat.shape[0]} elements, plan expects {plan.n}")
    flat = jnp.pad(flat, (0, plan.pad))
    return flat.reshape(plan.nb, plan.group, plan.lanes)


def from_blocks(xb: jnp.ndarray, plan: LeafPlan, shape=None) -> jnp.ndarray:
    """Inverse of :func:`to_blocks` (drops padding)."""
    flat = xb.reshape(-1)[: plan.n]
    return flat.reshape(shape) if shape is not None else flat
