"""Wire-chunk scheduling: ONE overlap engine for every strategy (PR 5).

The paper's throughput win depends on keeping the homomorphic stream
*moving*: workers should be encoding bucket ``i+1`` while bucket ``i`` is
on the wire, and switches aggregate bounded windows of the stream rather
than one monolithic payload (PAPER.md §5; THC and ScaleCom make the same
streaming-aggregation argument).  Before this module the repo had three
divergent half-implementations of that idea — ``CompressedAggregator``'s
private ``lax.scan`` double-buffer, a native reduce-scatter wire that
ignored ``cfg.overlap`` entirely, and ``SwitchModel`` windows that never
reached the in-mesh collective.  ``streams`` is the one scheduling layer
they all share now:

- :class:`StreamPlan` — the static chunk grid.  The fused sketch+bitmap
  payload of a :class:`~repro.core.bucketing.BucketPlan` is partitioned
  into ``n_chunks`` wire chunks of ``chunk_buckets`` whole buckets each
  (zero-padded past the real bucket count; zero buckets encode to zero
  sketch blocks / zero bitmap words, reduce to zeros, and peel to zeros,
  so chunking is bit-invisible).  The grid is aligned simultaneously to

  * whole buckets (always — a bucket is the codec's atomic unit),
  * per-rank reduce-scatter boundaries when the chunks feed per-chunk
    ``psum_scatter`` / OR-Reduce-Scatter calls (``scatter=True``): each
    chunk holds ``chunk_buckets = k * W`` buckets so the scatter lands
    *whole buckets* on their peeling rank — the "strided wire format"
    the ROADMAP open item asked for, spelled as a chunk grid instead of
    a strided element layout, and
  * ``switch_slots`` streaming windows for the in-network tier
    (``window_buckets``): each chunk is a whole number of switch SRAM
    windows, so the collective schedule and the
    :class:`~repro.net.switch.SwitchModel` slot pool agree.

  Unsatisfiable grids (a forced ``cfg.stream_chunks`` that would split a
  per-rank RS boundary or a switch window) raise ``ValueError`` naming
  the violated alignment constraint — they are never silently ignored
  (the old one-time-warning behaviour this layer retires).

- :func:`stream_schedule` — the single double-buffered ``lax.scan``
  pipeline driver.  Chunk ``i``'s wire collectives are issued in the
  same scan step as chunk ``i+1``'s encode, with no data dependence
  between them, so backends with async collectives overlap the wire
  with the MXU encode.  Every aggregator strategy drives its wire
  through this function; none rolls its own scan.

  One-producer / one-consumer contract (PR 7): the ``encode`` callback
  each strategy hands this driver makes exactly ONE producer-op pass
  over its chunk's gradient slice
  (``HomomorphicCompressor.compress_wire`` — the fused
  sketch + bitmap-pack + maxabs kernel of ``kernels/sketch_wire.py`` on
  fused-capable geometries), and the post-scan recovery makes exactly
  ONE consumer-op pass per chunk (``recover`` — fused
  unpack + optional fxp32 dequant + peel).  The quantize leg of the
  fxp32 wire is the one op *between* the two passes, because its shared
  exponents are a cross-worker ``pmax`` product — but it touches only
  the Γ-compressed sketch, never the bucket stream.
  ``benchmarks/roofline.py --codec`` counts these stream-sized passes
  from the jaxpr and CI gates fused < composed.

- :func:`zero1_gather_skip` — the static predicate for the ZeRO-1
  fast path: when every parameter leaf's per-rank optimizer slice lies
  inside that rank's recovered chunk slices, the reduce-scatter
  aggregator can feed the optimizer shards directly and skip the
  recovered-chunk all_gather entirely (see
  ``CompressionConfig.strategy_wire_bytes`` for the wire it saves).

:func:`zero_slice_dim` also lives here — the one definition of "which
dim does ZeRO-1 slice" shared by ``train/step.py`` and the gather-skip
predicate, so the two can never disagree.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .bucketing import BucketPlan
from .config import CompressionConfig


# ----------------------------------------------------------------------
# The static chunk grid
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Static partition of a bucket stream into wire chunks.

    ``workers > 1`` marks a reduce-scatter grid: every chunk's
    ``chunk_buckets`` divide by ``workers`` and each per-chunk scatter
    hands rank ``r`` the chunk's ``r``-th run of
    :attr:`rank_chunk_buckets` whole buckets.
    """

    n_buckets: int        # real buckets in the BucketPlan
    bucket_elems: int     # E — f32 elements per bucket
    blocks_per_bucket: int
    words_per_bucket: int
    workers: int          # W the chunks scatter across (1 = AllReduce wire)
    n_chunks: int
    chunk_buckets: int    # whole buckets per wire chunk
    base_block: int = 0   # global hash-plan block id of the stream's
                          # first bucket — nonzero when this plan covers
                          # one group of a larger BucketPlan (PR 6 wire
                          # plans), so group encodes reproduce the exact
                          # block offsets of the full-stream encode

    def __post_init__(self):
        if self.chunk_buckets % max(self.workers, 1):
            raise ValueError(
                f"chunk_buckets={self.chunk_buckets} not divisible by "
                f"workers={self.workers}")
        if self.padded_buckets < self.n_buckets:
            raise ValueError(
                f"chunk grid covers {self.padded_buckets} buckets, "
                f"stream has {self.n_buckets}")

    # -- derived geometry ----------------------------------------------

    @property
    def padded_buckets(self) -> int:
        return self.n_chunks * self.chunk_buckets

    @property
    def pad_buckets(self) -> int:
        """Zero buckets appended so the grid tiles the stream exactly."""
        return self.padded_buckets - self.n_buckets

    @property
    def chunk_elems(self) -> int:
        return self.chunk_buckets * self.bucket_elems

    @property
    def rank_chunk_buckets(self) -> int:
        """Whole buckets each rank receives from one chunk's scatter."""
        return self.chunk_buckets // self.workers

    @property
    def streamed(self) -> bool:
        return self.n_chunks > 1

    def chunk_start_block(self, chunk):
        """Global hash-plan block id of a chunk's first block (``chunk``
        may be a traced int32 — used inside the scan pipeline)."""
        return self.base_block + \
            chunk * (self.chunk_buckets * self.blocks_per_bucket)

    def rank_slice_start_block(self, chunk, rank):
        """Global block id of the slice rank ``rank`` receives from
        ``chunk``'s scatter (both args may be traced)."""
        return self.chunk_start_block(chunk) + \
            rank * (self.rank_chunk_buckets * self.blocks_per_bucket)

    def rank_intervals(self, rank: int) -> Tuple[Tuple[int, int], ...]:
        """Flat-stream element intervals rank ``rank`` owns after the
        per-chunk scatters (static Python ints; used by the gather-skip
        predicate and tests)."""
        cbw = self.rank_chunk_buckets * self.bucket_elems
        out = []
        for j in range(self.n_chunks):
            lo = j * self.chunk_elems + rank * cbw
            out.append((lo, lo + cbw))
        return tuple(out)

    def chunk_view(self, buckets: jnp.ndarray) -> jnp.ndarray:
        """``(n_buckets, E) -> (n_chunks, chunk_buckets, E)``, zero-padding
        the tail chunk (padding peels to exact zeros)."""
        if buckets.shape != (self.n_buckets, self.bucket_elems):
            raise ValueError(
                f"buckets shape {buckets.shape} != "
                f"({self.n_buckets}, {self.bucket_elems})")
        if self.pad_buckets:
            buckets = jnp.pad(buckets, ((0, self.pad_buckets), (0, 0)))
        return buckets.reshape(
            self.n_chunks, self.chunk_buckets, self.bucket_elems)


def make_stream_plan(plan: BucketPlan, cfg: CompressionConfig, *,
                     workers: int = 1, scatter: bool = False,
                     window_buckets: Optional[int] = None,
                     base_block: int = 0) -> StreamPlan:
    """Resolve the chunk grid for one aggregation pass.

    ``scatter=True`` builds a reduce-scatter grid over ``workers`` ranks:
    the chunk count must divide the per-rank bucket count
    ``ceil(n_buckets / workers)`` so no chunk splits a per-rank RS
    boundary.  ``window_buckets`` aligns chunks to in-network switch
    windows instead (each chunk = a whole number of windows).  With
    neither, any chunk count in ``[1, n_buckets]`` is valid (the
    AllReduce wire has no boundary to respect; non-divisible counts are
    zero-padded).

    The chunk count comes from ``cfg.stream_chunks`` when set; otherwise
    ``cfg.overlap`` picks the finest aligned grid (per bucket / per rank
    chunk / per switch window) and ``False`` means one fused chunk.

    A requested count whose grid would schedule chunks made *entirely*
    of zero-pad buckets (e.g. 4 chunks of 2 over a 5-bucket stream)
    shrinks to the largest count that still covers the stream — empty
    chunks would spend real collective rounds on all-zero payloads.

    ``base_block`` offsets the grid's block ids when ``plan`` is a group
    view over a larger bucket stream (PR 6 wire plans): pass the global
    block id of the group's first bucket so group encodes hash exactly
    like the full-stream encode.
    """
    if workers < 1:
        raise ValueError(f"workers must be >= 1, got {workers}")
    nb = plan.n_buckets
    nbpb = plan.blocks_per_bucket(cfg)
    wpb = plan.words_per_bucket
    streaming = cfg.overlap or cfg.stream_chunks is not None

    def drop_empty(n_chunks: int, cb: int) -> int:
        """Largest chunk count (<= n_chunks) with no all-padding chunk."""
        return min(n_chunks, max(1, -(-nb // cb)))

    if scatter and workers > 1:
        per_rank = -(-nb // workers)           # ceil(n_buckets / W)
        req = cfg.stream_chunks if cfg.stream_chunks is not None \
            else (per_rank if streaming else 1)
        if req < 1 or per_rank % req:
            raise ValueError(
                f"stream_chunks={req} splits a per-rank reduce-scatter "
                f"boundary: the native RS wire scatters whole buckets to "
                f"their peeling rank, so the chunk count must divide the "
                f"per-rank bucket count ceil(n_buckets/W) = "
                f"ceil({nb}/{workers}) = {per_rank} "
                f"(valid counts: divisors of {per_rank})")
        cb = (per_rank // req) * workers
        return StreamPlan(
            n_buckets=nb, bucket_elems=plan.bucket_elems,
            blocks_per_bucket=nbpb, words_per_bucket=wpb, workers=workers,
            n_chunks=drop_empty(req, cb), chunk_buckets=cb,
            base_block=base_block)

    if window_buckets is not None:
        if window_buckets < 1:
            raise ValueError(
                f"window_buckets must be >= 1, got {window_buckets}")
        windows = -(-nb // window_buckets)
        if cfg.stream_chunks is not None:
            n_chunks = cfg.stream_chunks
            if n_chunks < 1 or n_chunks > windows:
                raise ValueError(
                    f"stream_chunks={n_chunks} misaligns the switch "
                    f"windows: in-network chunks span whole switch_slots="
                    f"{window_buckets} bucket windows and the stream has "
                    f"ceil(n_buckets/switch_slots) = ceil({nb}/"
                    f"{window_buckets}) = {windows} window(s); use "
                    f"stream_chunks <= {windows}")
        else:
            n_chunks = windows if streaming else 1
        # fused grid covers the raw stream; streamed chunks span whole
        # switch windows (zero-padded past the real bucket count)
        cb = nb if n_chunks == 1 else \
            -(-windows // n_chunks) * window_buckets
        return StreamPlan(
            n_buckets=nb, bucket_elems=plan.bucket_elems,
            blocks_per_bucket=nbpb, words_per_bucket=wpb, workers=1,
            n_chunks=drop_empty(n_chunks, cb), chunk_buckets=cb,
            base_block=base_block)

    req = cfg.stream_chunks if cfg.stream_chunks is not None \
        else (nb if streaming else 1)
    if req < 1:
        raise ValueError(f"stream_chunks must be >= 1, got {req}")
    n_chunks = min(req, nb)
    cb = -(-nb // n_chunks)
    return StreamPlan(
        n_buckets=nb, bucket_elems=plan.bucket_elems,
        blocks_per_bucket=nbpb, words_per_bucket=wpb, workers=1,
        n_chunks=drop_empty(n_chunks, cb), chunk_buckets=cb,
        base_block=base_block)


# ----------------------------------------------------------------------
# The all-to-all (permute pattern) chunk grid — PR 8
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AllToAllStreamPlan:
    """Static chunk grid for the permute-pattern wire.

    The payload is ``lanes`` per-destination bucket runs of
    ``n_buckets`` buckets each (one :class:`~repro.core.bucketing`
    plan per destination EP rank, identical geometry).  A wire chunk
    carries ``chunk_buckets`` buckets *of every lane* — the producer
    encodes one stacked ``(lanes, chunk_buckets, E)`` slab per chunk in
    a single fused pass, and the same double-buffered
    :func:`stream_schedule` overlaps chunk ``i``'s ppermutes with chunk
    ``i+1``'s encode.

    Block ids are chunk-major: bucket ``j`` of lane ``d`` in chunk ``c``
    encodes at global bucket ``(c * lanes + d) * chunk_buckets + j``, so
    each chunk's producer pass covers one *contiguous* block range (the
    PR 7 one-producer contract) while every lane keeps a fixed offset
    within it.  The fused (``n_chunks = 1``) grid degenerates to plain
    lane-major offsets ``d * n_buckets``.
    """

    lanes: int            # W destination ranks (one ppermute lane each)
    n_buckets: int        # per-destination buckets
    bucket_elems: int
    blocks_per_bucket: int
    words_per_bucket: int
    n_chunks: int
    chunk_buckets: int
    base_block: int = 0

    def __post_init__(self):
        if self.lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {self.lanes}")
        if self.n_chunks * self.chunk_buckets != self.n_buckets:
            raise ValueError(
                f"chunk grid {self.n_chunks} x {self.chunk_buckets} does "
                f"not tile the per-destination bucket run "
                f"({self.n_buckets} buckets)")

    @property
    def chunk_elems(self) -> int:
        return self.chunk_buckets * self.bucket_elems

    @property
    def streamed(self) -> bool:
        return self.n_chunks > 1

    def chunk_start_block(self, chunk):
        """Global block id of chunk ``chunk``'s first block (lane 0) —
        the producer's ``block_offset`` for the stacked slab."""
        return self.base_block + chunk * (
            self.lanes * self.chunk_buckets * self.blocks_per_bucket)

    def lane_start_block(self, chunk, lane):
        """Global block id of lane ``lane``'s first block inside chunk
        ``chunk`` — the consumer's peel offset at the receiving rank
        (both args may be traced)."""
        return self.chunk_start_block(chunk) + \
            lane * (self.chunk_buckets * self.blocks_per_bucket)

    def chunk_view(self, lane_buckets: jnp.ndarray) -> jnp.ndarray:
        """``(lanes, n_buckets, E) -> (n_chunks, lanes, chunk_buckets,
        E)`` — the per-chunk stacked slabs :func:`stream_schedule`
        scans over."""
        if lane_buckets.shape != (self.lanes, self.n_buckets,
                                  self.bucket_elems):
            raise ValueError(
                f"lane buckets shape {lane_buckets.shape} != "
                f"({self.lanes}, {self.n_buckets}, {self.bucket_elems})")
        return lane_buckets.reshape(
            self.lanes, self.n_chunks, self.chunk_buckets,
            self.bucket_elems).transpose(1, 0, 2, 3)


def make_alltoall_stream_plan(plan: BucketPlan, cfg: CompressionConfig, *,
                              lanes: int,
                              base_block: int = 0) -> AllToAllStreamPlan:
    """Resolve the chunk grid for one all-to-all exchange.

    ``plan`` is the per-destination :class:`BucketPlan` (every lane
    shares it).  The chunk count comes from ``cfg.stream_chunks`` when
    set, else ``cfg.overlap`` picks the per-bucket grid and ``False``
    one fused chunk — same policy as :func:`make_stream_plan`.  The
    count must divide the per-destination bucket run exactly: the
    permute wire's chunk-major block-id scheme interleaves all ``lanes``
    lanes inside each chunk, so a ragged tail chunk would shift every
    later lane's hash block ids.
    """
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    nb = plan.n_buckets
    streaming = cfg.overlap or cfg.stream_chunks is not None
    req = cfg.stream_chunks if cfg.stream_chunks is not None \
        else (nb if streaming else 1)
    if req < 1:
        raise ValueError(f"stream_chunks must be >= 1, got {req}")
    req = min(req, nb)
    if nb % req:
        raise ValueError(
            f"stream_chunks={req} misaligns the all-to-all lane grid: "
            f"the permute wire interleaves all {lanes} destination lanes "
            f"chunk-major, so the chunk count must divide the "
            f"per-destination bucket count n_buckets = {nb} "
            f"(valid counts: divisors of {nb})")
    return AllToAllStreamPlan(
        lanes=lanes, n_buckets=nb, bucket_elems=plan.bucket_elems,
        blocks_per_bucket=plan.blocks_per_bucket(cfg),
        words_per_bucket=plan.words_per_bucket,
        n_chunks=req, chunk_buckets=nb // req, base_block=base_block)


# ----------------------------------------------------------------------
# The double-buffered pipeline driver
# ----------------------------------------------------------------------

def stream_schedule(xs: Any, encode, reduce) -> Any:
    """Drive per-chunk (encode -> wire) through a double-buffered scan.

    ``xs``: pytree of arrays with leading dim ``n_chunks`` — the
    per-chunk inputs (e.g. the :meth:`StreamPlan.chunk_view` buckets).
    ``encode(i, x_i) -> payload`` produces chunk ``i``'s wire payload
    (``i`` is a traced int32; payloads must be shape-uniform across
    chunks).  ``reduce(payload) -> reduced`` issues the chunk's wire
    collectives.  Chunk ``i``'s ``reduce`` is staged in the same scan
    step as chunk ``i+1``'s ``encode`` with no data dependence between
    them, so async-collective backends overlap wire and compute.

    Returns the reduced payloads stacked on a leading ``n_chunks`` dim.
    Bit-identical to ``reduce(encode(i))`` chunk by chunk (the schedule
    only reorders independent work).
    """
    leaves = jax.tree.leaves(xs)
    if not leaves:
        raise ValueError("stream_schedule needs at least one input array")
    n = leaves[0].shape[0]
    first = encode(jnp.int32(0), jax.tree.map(lambda a: a[0], xs))
    if n == 1:
        return jax.tree.map(lambda a: a[None], reduce(first))

    def body(carry, inp):
        i, x = inp
        return encode(i, x), reduce(carry)

    idx = jnp.arange(1, n, dtype=jnp.int32)
    rest = jax.tree.map(lambda a: a[1:], xs)
    last_carry, aggs = jax.lax.scan(body, first, (idx, rest))
    last = reduce(last_carry)
    return jax.tree.map(
        lambda s, l: jnp.concatenate([s, l[None]], axis=0), aggs, last)


# ----------------------------------------------------------------------
# ZeRO-1 alignment (the gather-skip fast path)
# ----------------------------------------------------------------------

def zero_slice_dim(shape: Sequence[int], spec, dp: int) -> Optional[int]:
    """Dim ZeRO-1 slices for a leaf: the largest unsharded dim divisible
    by ``dp``.  THE definition — ``train/step.py``'s optimizer sharding
    and the gather-skip predicate both call this, so the slice the
    optimizer consumes and the slice the aggregator checks can never
    drift apart."""
    cands = []
    for i, size in enumerate(shape):
        taken = spec[i] if i < len(spec) else None
        if taken is None and size % dp == 0 and size >= dp:
            cands.append((size, i))
    if not cands:
        return None
    return max(cands)[1]


def zero1_gather_skip(splan: StreamPlan, plan: BucketPlan,
                      zero1_dims: Optional[Sequence[Optional[int]]]) -> bool:
    """True when the chunk grid aligns with the ZeRO-1 optimizer slices.

    Alignment means: for every leaf, the per-rank optimizer slice is
    flat-contiguous (slice dim 0, or only size-1 dims before it) and
    rank ``r``'s slice of the leaf lies entirely inside one of rank
    ``r``'s recovered chunk slices (:meth:`StreamPlan.rank_intervals`).
    Then each rank already holds every gradient value its optimizer
    shard consumes, and the recovered-chunk all_gather is pure waste —
    the reduce-scatter aggregator skips it (returning leaves that are
    exact inside this rank's owned coordinates and zero outside; the
    train step reduces the grad-norm across ranks instead of reading
    off-slice values).  Static Python — evaluated at trace time.
    """
    W = splan.workers
    if W == 1 or zero1_dims is None:
        return False
    dims = tuple(zero1_dims)
    if len(dims) != len(plan.sizes):
        return False
    E = splan.bucket_elems
    cb, cbw = splan.chunk_buckets, splan.rank_chunk_buckets
    for off, n, d, shape in zip(plan.offsets, plan.sizes, dims, plan.shapes):
        if d is None or n == 0:
            return False
        if any(s != 1 for s in shape[:d]):
            return False                    # slice along d is not flat-contig
        if shape[d] % W or n % W:
            return False
        per = n // W
        for r in range(W):
            start = off + r * per
            j = start // (cb * E)
            lo = (j * cb + r * cbw) * E
            if not (lo <= start and start + per <= lo + cbw * E):
                return False
    return True
