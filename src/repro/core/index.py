"""Homomorphic non-zero indexes (paper §3.2 bitmap, §3.3 Bloom filter).

Both structures aggregate with bitwise OR; both ride the wire bit-packed in
``uint32`` words (1 bit per coordinate for the bitmap). Packing keeps the
index at 1/16 of a bf16 gradient — the OR-AllReduce in
:mod:`repro.core.collectives` operates on the packed words directly.

The Bloom filter trades exactness of the *index* (never of recovered
values) for size: it may report false-positive "non-zeros", which enter the
peeling graph as candidates and peel out with value ~0. It never misses a
true non-zero, which is the property the lossless proof needs (§3.3).
"""

from __future__ import annotations

import jax.numpy as jnp

from .config import CompressionConfig
from . import hashing


# ----------------------------------------------------------------------
# Bit packing
# ----------------------------------------------------------------------

def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """bool (...,) with total size divisible by 32 -> packed uint32 (N/32,)."""
    flat = bits.reshape(-1)
    n = flat.shape[0]
    if n % 32 != 0:
        raise ValueError(f"bit count {n} not divisible by 32")
    w = flat.reshape(n // 32, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (w << shifts[None, :]).sum(axis=1, dtype=jnp.uint32)


def unpack_bits(words: jnp.ndarray, shape) -> jnp.ndarray:
    """packed uint32 (N/32,) -> bool array of ``shape`` (N total elements)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts[None, :]) & jnp.uint32(1)
    return bits.reshape(shape).astype(jnp.bool_)


# ----------------------------------------------------------------------
# Bitmap index (exact)
# ----------------------------------------------------------------------

def bitmap_build(xb: jnp.ndarray) -> jnp.ndarray:
    """(nb, G, c) values -> (nb, G, c) bool non-zero mask."""
    return xb != 0


# ----------------------------------------------------------------------
# Bloom filter index (probabilistic, asymptotically optimal size)
# ----------------------------------------------------------------------

def bloom_size_words(n_elems: int, cfg: CompressionConfig) -> int:
    m_bits = max(64, int(n_elems * cfg.bloom_bits_ratio))
    return -(-m_bits // 32)


def bloom_build(xb: jnp.ndarray, cfg: CompressionConfig) -> jnp.ndarray:
    """(nb, G, c) values -> packed uint32 Bloom filter over all coordinates.

    Built as a scatter-max into an *unpacked* bit array (OR of 0/1 flags is
    max), packed to uint32 words once at the end.
    """
    nz = (xb != 0).reshape(-1)
    n = nz.shape[0]
    m_bits = bloom_size_words(n, cfg) * 32
    ids = jnp.arange(n, dtype=jnp.uint32)
    pos = hashing.bloom_positions(ids, cfg.bloom_hashes, m_bits, cfg.seed)  # (n, k)
    flags = jnp.broadcast_to(nz[:, None], pos.shape).astype(jnp.uint32)
    bits = jnp.zeros((m_bits,), jnp.uint32).at[pos.reshape(-1)].max(flags.reshape(-1))
    return pack_bits(bits.astype(jnp.bool_))


def bloom_query(shape, cfg: CompressionConfig, filt: jnp.ndarray) -> jnp.ndarray:
    """Candidate non-zero mask of ``shape`` from a packed Bloom filter."""
    n = 1
    for s in shape:
        n *= s
    m_bits = filt.shape[0] * 32
    ids = jnp.arange(n, dtype=jnp.uint32)
    pos = hashing.bloom_positions(ids, cfg.bloom_hashes, m_bits, cfg.seed)
    word, bit = pos // 32, (pos % 32).astype(jnp.uint32)
    hit = (filt[word] >> bit) & jnp.uint32(1)
    return jnp.all(hit == 1, axis=-1).reshape(shape)
