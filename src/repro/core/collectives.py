"""Aggregation collectives for the compressed wire format.

The paper aggregates ``S(X) = [Y, B]`` through "the existing aggregation
API" — NCCL sum for ``Y`` and switch/NCCL OR for ``B``. On a TPU mesh the
sum is ``jax.lax.psum``; OR is *not* a native ICI reduction, so we build an
OR-AllReduce out of ``jax.lax.ppermute``:

- ``or_allreduce_ring``     — reduce-scatter + all-gather ring with a
  bitwise-OR combiner; bandwidth-optimal (2·(W−1)/W · |B| per link), the
  analogue of NCCL's ring AllReduce.
- ``or_allreduce_doubling`` — recursive doubling (log2 W full-size steps);
  latency-optimal for small bitmaps, used when |B|/W would be tiny.
- ``or_allreduce``          — hierarchical driver: ring within a pod (ICI),
  then doubling across pods (DCN has few, fat hops), then a broadcast-free
  second ring phase. Payloads at or above ``ring_threshold`` *bytes* (and
  any axis whose size is not a power of two) take the ring; small
  power-of-two axes take recursive doubling.
- ``or_reduce_scatter``     — phase 1 of the ring alone: after the
  reduce-scatter each rank holds only its own fully OR-reduced 1/W chunk,
  (W−1)/W · |B| per link and no all-gather phase. This is the bitmap leg
  of the native reduce-scatter wire path (PR 3): the sketch reduces with
  ``jax.lax.psum_scatter`` and the bitmap with this primitive, so the
  reduced payload that lands on each rank is 1/W of the AllReduce
  strategies' — see
  :class:`repro.core.aggregators.CompressedReduceScatterAggregator` and
  ``CompressionConfig.strategy_wire_bytes``.

All functions must run inside ``shard_map`` where ``axis_name`` is manual.

Since PR 2 this module holds only the **primitives** (plus the dense
baseline and the error-feedback state container). Gradient aggregation
itself is a pluggable strategy over fixed-size buckets — ONE sketch
encode, ONE stacked sketch-``psum`` and ONE OR-AllReduce for the whole
pytree instead of a per-leaf Python loop — implemented in
:mod:`repro.core.aggregators` on top of :mod:`repro.core.bucketing`.
:func:`compressed_all_reduce` survives as a thin compatibility wrapper
over the bucketed :class:`~repro.core.aggregators.CompressedAggregator`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import compat
from .config import CompressionConfig


# ----------------------------------------------------------------------
# OR-AllReduce primitives (manual collectives)
# ----------------------------------------------------------------------

def _ring_perm(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def linear_rank(axis_names: Sequence[str],
                axis_indices: Optional[dict] = None) -> jnp.ndarray:
    """This shard's rank-major linear index over ``axis_names``.

    ``rank = (((i0) * s1 + i1) * s2 + i2) ...`` with the first axis most
    significant — the chunk-to-rank order of ``jax.lax.psum_scatter`` /
    tiled ``all_gather`` over the same axis tuple, of
    :func:`or_reduce_scatter`, and of the peel's per-rank
    ``block_offset``. Every site that linearizes mesh axes must use this
    helper so the orders can never drift apart. ``axis_indices``: as in
    :func:`or_allreduce_ring` (required complete if given).
    """
    _check_axis_indices(axis_names, axis_indices)
    rank = jnp.int32(0)
    for ax in axis_names:
        idx = axis_indices[ax] if axis_indices else jax.lax.axis_index(ax)
        rank = rank * compat.axis_size(ax) + idx
    return rank


def or_allreduce_ring(x: jnp.ndarray, axis_name: str,
                      idx: jnp.ndarray | None = None) -> jnp.ndarray:
    """Bitwise-OR AllReduce via a bandwidth-optimal ring (RS + AG).

    ``x``: uint32 words, identical shape on every shard of ``axis_name``.
    ``idx``: this shard's index on ``axis_name``. Pass it in when calling
    from a *nested* shard_map — ``axis_index`` on an axis bound by an
    outer shard_map trips the Shardy verifier (re-binding), while plain
    ppermute/psum on outer axes are fine.
    """
    n = compat.axis_size(axis_name)
    if n == 1:
        return x
    if idx is None:
        idx = jax.lax.axis_index(axis_name)
    size = x.shape[0]
    pad = (-size) % n
    chunks = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)
                     ).reshape((n, (size + pad) // n) + x.shape[1:])
    perm = _ring_perm(n)

    # Phase 1 — reduce-scatter: after n-1 steps, shard i owns the fully
    # OR-reduced chunk (i+1) mod n.
    for t in range(n - 1):
        send = jax.lax.dynamic_index_in_dim(chunks, (idx - t) % n, 0, keepdims=False)
        recv = jax.lax.ppermute(send, axis_name, perm)
        tgt = (idx - t - 1) % n
        upd = jax.lax.dynamic_index_in_dim(chunks, tgt, 0, keepdims=False) | recv
        chunks = jax.lax.dynamic_update_index_in_dim(chunks, upd, tgt, 0)

    # Phase 2 — all-gather of the reduced chunks around the same ring.
    for t in range(n - 1):
        send = jax.lax.dynamic_index_in_dim(chunks, (idx + 1 - t) % n, 0, keepdims=False)
        recv = jax.lax.ppermute(send, axis_name, perm)
        tgt = (idx - t) % n
        chunks = jax.lax.dynamic_update_index_in_dim(chunks, recv, tgt, 0)

    out = chunks.reshape((size + pad,) + x.shape[1:])
    return out[:size] if pad else out


def or_reduce_scatter_ring(x: jnp.ndarray, axis_name: str,
                           idx: jnp.ndarray | None = None) -> jnp.ndarray:
    """Bitwise-OR Reduce-Scatter via the ring's phase 1 alone.

    Returns this rank's fully OR-reduced chunk ``x[idx*C:(idx+1)*C]``
    with ``C = x.shape[0] // n`` — the chunk-to-rank assignment matches
    ``jax.lax.psum_scatter(..., scatter_dimension=0, tiled=True)``, so
    the sketch (psum_scatter) and the bitmap (this ring) arrive sliced
    identically. ``x.shape[0]`` must divide evenly by the axis size (the
    bucketed callers pad to whole per-rank chunks first).

    The send schedule is the reduce-scatter ring shifted so the chunk a
    rank finishes reducing at step n-2 is its *own* chunk ``idx`` (the
    AllReduce ring in :func:`or_allreduce_ring` finishes on chunk
    ``(idx+1) % n``, which only matters there because phase 2 regathers
    everything). ``idx``: see :func:`or_allreduce_ring`.
    """
    n = compat.axis_size(axis_name)
    if x.shape[0] % n:
        raise ValueError(
            f"or_reduce_scatter: leading dim {x.shape[0]} not divisible "
            f"by axis {axis_name!r} size {n}")
    if n == 1:
        return x
    if idx is None:
        idx = jax.lax.axis_index(axis_name)
    chunks = x.reshape((n, x.shape[0] // n) + x.shape[1:])
    perm = _ring_perm(n)
    for t in range(n - 1):
        send = jax.lax.dynamic_index_in_dim(chunks, (idx - t - 1) % n, 0,
                                            keepdims=False)
        recv = jax.lax.ppermute(send, axis_name, perm)
        tgt = (idx - t - 2) % n
        upd = jax.lax.dynamic_index_in_dim(chunks, tgt, 0,
                                           keepdims=False) | recv
        chunks = jax.lax.dynamic_update_index_in_dim(chunks, upd, tgt, 0)
    return jax.lax.dynamic_index_in_dim(chunks, idx, 0, keepdims=False)


def or_allreduce_doubling(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Bitwise-OR AllReduce via recursive doubling (requires power-of-2)."""
    n = compat.axis_size(axis_name)
    if n == 1:
        return x
    if n & (n - 1):
        raise ValueError(f"recursive doubling needs power-of-2 size, got {n}")
    d = 1
    while d < n:
        perm = [(i, i ^ d) for i in range(n)]
        x = x | jax.lax.ppermute(x, axis_name, perm)
        d *= 2
    return x


# Words per chunk of the psum-emulated OR. The 32-way int32 bit-unpack
# (and the psum'd counts) are 64x the bytes of the uint32 words they
# cover, so a one-shot unpack of a large bitmap transiently costs ~128x
# the bitmap; chunking bounds the peak at ~8 MiB per chunk.
PSUM_OR_CHUNK_WORDS = 1 << 16


def _or_allreduce_psum(x: jnp.ndarray, axis_names: Sequence[str],
                       chunk_words: int = PSUM_OR_CHUNK_WORDS) -> jnp.ndarray:
    """OR-AllReduce emulated with the sum collective (exact).

    Unpacks each uint32 word into its 32 bits, psums the bit counts, and
    repacks ``count > 0``. 32x the wire volume of the native OR — this is
    the compatibility path for JAX versions whose partitioner cannot run
    ppermute over a manual axis while other mesh axes stay auto.

    The unpack/psum runs in chunks of ``chunk_words`` leading-dim words
    (one psum per chunk, a static Python loop) so the int32 bit-unpack
    transient is bounded at ~128 bytes x ``chunk_words`` instead of 128x
    the whole bitmap. Bit-exact regardless of chunking: each word's 32
    counts are independent.
    """
    if chunk_words < 1:
        raise ValueError(f"chunk_words must be >= 1, got {chunk_words}")
    shifts = jnp.arange(32, dtype=jnp.uint32)

    def one(xc):
        bits = ((xc[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
        counts = jax.lax.psum(bits, tuple(axis_names))
        return jnp.sum(
            jnp.where(counts > 0, jnp.uint32(1) << shifts, jnp.uint32(0)),
            axis=-1, dtype=jnp.uint32)

    n = x.shape[0] if x.ndim else 0
    if x.ndim == 0 or n <= chunk_words:
        return one(x)
    parts = [one(x[i:i + chunk_words]) for i in range(0, n, chunk_words)]
    return jnp.concatenate(parts, axis=0)


def _use_ring(payload_bytes: int, axis_size: int, ring_threshold: int) -> bool:
    """Ring vs recursive doubling: ring for payloads of ``ring_threshold``
    bytes or more (bandwidth-bound regime), and always for axis sizes
    that are not a power of two (doubling requires 2^k participants)."""
    return payload_bytes >= ring_threshold or bool(axis_size & (axis_size - 1))


def _check_axis_indices(axis_names: Sequence[str],
                        axis_indices: Optional[dict]) -> None:
    """A *partial* ``axis_indices`` dict is always a caller bug: falling
    back to ``axis_index`` for the missing axes would re-bind an axis
    already bound by an outer shard_map inside the nested region — the
    exact Shardy failure the parameter exists to avoid. Fail loudly
    instead of silently recomputing."""
    if axis_indices is None:
        return
    missing = [ax for ax in axis_names if ax not in axis_indices]
    if missing:
        raise ValueError(
            f"axis_indices is missing {missing} (has "
            f"{sorted(axis_indices)}); pass every reduced axis's index "
            "or None — a partial dict would silently re-bind axis_index "
            "inside a nested shard_map region")


def or_allreduce(x: jnp.ndarray, axis_names: Sequence[str],
                 ring_threshold: int = 65536,
                 axis_indices: Optional[dict] = None) -> jnp.ndarray:
    """Hierarchical OR-AllReduce over several (manual) mesh axes.

    Axes are reduced innermost-first (e.g. ``("pod", "data")`` rings over
    ``data`` within each pod, then combines across pods).

    ``ring_threshold``: payload size in **bytes** at or above which the
    bandwidth-optimal ring is used; smaller payloads take recursive
    doubling to dodge ring latency. Axes whose size is not a power of two
    always take the ring (doubling requires power-of-2 participants).

    ``axis_indices``: {axis: this shard's index} — required when calling
    from a nested shard_map (see or_allreduce_ring). If given it must
    cover *every* axis in ``axis_names`` (ValueError otherwise).
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    _check_axis_indices(axis_names, axis_indices)
    if not compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE:
        return _or_allreduce_psum(x, axis_names)
    payload_bytes = x.size * x.dtype.itemsize
    for ax in reversed(tuple(axis_names)):
        if _use_ring(payload_bytes, compat.axis_size(ax), ring_threshold):
            idx = axis_indices[ax] if axis_indices else None
            x = or_allreduce_ring(x, ax, idx=idx)
        else:
            x = or_allreduce_doubling(x, ax)
    return x


def or_reduce_scatter(x: jnp.ndarray, axis_names: Sequence[str],
                      axis_indices: Optional[dict] = None,
                      use_ppermute: Optional[bool] = None) -> jnp.ndarray:
    """Hierarchical bitwise-OR Reduce-Scatter over (manual) mesh axes.

    Each rank receives only its own fully OR-reduced ``1/W`` chunk of
    ``x`` (leading dim, which must divide by the total axis size W).
    Chunk-to-rank assignment is rank-major in ``axis_names`` order —
    identical to ``jax.lax.psum_scatter(x, tuple(axis_names),
    scatter_dimension=0, tiled=True)`` — so axes scatter
    *outermost*-first: the outer axis picks the coarse chunk, each inner
    axis a sub-chunk of it. (The AllReduce driver reduces innermost-first
    instead; order is irrelevant there because everyone ends with
    everything.)

    ``use_ppermute``: force (True) or forbid (False) the ppermute ring.
    Default ``None`` follows ``compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE``;
    callers inside *full-manual* regions on 0.4.x should pass True (the
    ring is supported there — see compat.full_manual_region). When the
    ring is unavailable the result is emulated as a psum-based
    OR-AllReduce plus a local chunk slice: correct, but it forfeits the
    wire win (compat path only).
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    axis_names = tuple(axis_names)
    _check_axis_indices(axis_names, axis_indices)
    W = 1
    for ax in axis_names:
        W *= compat.axis_size(ax)
    if x.shape[0] % W:
        raise ValueError(
            f"or_reduce_scatter: leading dim {x.shape[0]} not divisible "
            f"by the total axis size {W}")
    if use_ppermute is None:
        use_ppermute = compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE
    if not use_ppermute:
        full = _or_allreduce_psum(x, axis_names)
        rank = linear_rank(axis_names, axis_indices)
        return jax.lax.dynamic_slice_in_dim(
            full, rank * (x.shape[0] // W), x.shape[0] // W, axis=0)
    for ax in axis_names:
        idx = axis_indices[ax] if axis_indices else None
        x = or_reduce_scatter_ring(x, ax, idx=idx)
    return x


def gather_chunk_slices(local: jnp.ndarray, axis_names: Sequence[str],
                        axis_indices: Optional[dict] = None,
                        use_all_gather: bool = True) -> jnp.ndarray:
    """Reassemble per-chunk reduce-scatter slices across ranks.

    The inverse of a *per-chunk* ``psum_scatter`` / :func:`or_reduce_scatter`
    schedule (the streamed native RS wire, see :mod:`repro.core.streams`):
    ``local`` is ``(n_chunks, S, ...)`` — this rank's fully-reduced slice
    of each wire chunk.  Returns ``(n_chunks, W * S, ...)`` where every
    chunk's leading dim is the rank-major concatenation of all ranks'
    slices, i.e. chunk ``j`` restored exactly as the one-shot wire would
    have delivered it.  One collective for all chunks.

    ``use_all_gather=True`` (full-manual regions, and new-JAX
    partial-auto) uses a manual-axis ``all_gather``; ``False`` keeps the
    zero-pad + ``psum`` ZeRO-1 gather trick for partial-auto regions
    where Shardy would un-shard the auto TP axes around a manual-axis
    all_gather (2x the all_gather ring's wire, bit-identical values —
    each slice lands exactly once either way).
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    axis_names = tuple(axis_names)
    _check_axis_indices(axis_names, axis_indices)
    W = 1
    for ax in axis_names:
        W *= compat.axis_size(ax)
    if W == 1:
        return local
    n_chunks, s = local.shape[0], local.shape[1]
    if use_all_gather:
        # (W, n_chunks, S, ...) stacked rank-major over the axis tuple,
        # the same linearization as linear_rank / psum_scatter tiling.
        ag = jax.lax.all_gather(local, axis_names, axis=0, tiled=False)
        if ag.ndim == local.ndim + len(axis_names):
            # multi-axis all_gather stacks one dim per axis (outer axis
            # first == rank-major): merge them into the single W dim
            ag = ag.reshape((W,) + local.shape)
        perm = (1, 0, 2) + tuple(range(3, ag.ndim))
        return ag.transpose(perm).reshape(
            (n_chunks, W * s) + local.shape[2:])
    rank = linear_rank(axis_names, axis_indices)
    full = jnp.zeros((n_chunks, W * s) + local.shape[2:], local.dtype)
    full = jax.lax.dynamic_update_slice_in_dim(full, local, rank * s, axis=1)
    return jax.lax.psum(full, axis_names)


# ----------------------------------------------------------------------
# All-to-all lane merge (the permute pattern, PR 8)
# ----------------------------------------------------------------------

def alltoall_lane_sum(x: jnp.ndarray, axis_names: Sequence[str],
                       axis_indices: Optional[dict] = None,
                       use_ppermute: Optional[bool] = None,
                       combine: str = "add") -> jnp.ndarray:
    """Merge stacked all-to-all lanes: rank ``r`` receives
    ``combine_s x_s[r]`` over all source ranks ``s``.

    ``x``: ``(W, ...)`` — lane ``d`` is this rank's payload destined for
    rank ``d``, rank-major over ``axis_names`` (:func:`linear_rank`
    order).  The merge at the receiving rank IS the homomorphic
    aggregation: the sum of sketches (``combine="add"``) / OR of bitmaps
    (``combine="or"``) of every source's payload for this rank.

    Native wire: ``W - 1`` ppermutes — offset ``k`` ships lane
    ``(i + k) % W`` from every source ``i`` to rank ``(i + k) % W``, so
    each rank sends/receives ``(W-1)/W`` of its stacked payload (the
    all-to-all wire model in ``CompressionConfig.strategy_wire_bytes``).
    Single manual axis only (ppermute takes one axis name).

    Emulation (0.4.x partial-auto, or multi-axis EP): reduce the whole
    ``(W, ...)`` stack — psum for ``add``, the psum-based OR for ``or``
    — then slice this rank's lane.  Correct, but ships the ring
    AllReduce volume (and 32x on the bitmap), the same compat cost as
    :func:`or_reduce_scatter`'s fallback.
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    axis_names = tuple(axis_names)
    _check_axis_indices(axis_names, axis_indices)
    if combine not in ("add", "or"):
        raise ValueError(f"combine must be 'add' or 'or', got {combine!r}")
    W = 1
    for ax in axis_names:
        W *= compat.axis_size(ax)
    if x.shape[0] != W:
        raise ValueError(
            f"all-to-all payload has {x.shape[0]} lanes but the axis "
            f"tuple {tuple(axis_names)} has {W} ranks")
    if W == 1:
        return x[0]
    if use_ppermute is None:
        use_ppermute = compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE
    if use_ppermute and len(axis_names) == 1:
        ax = axis_names[0]
        idx = axis_indices[ax] if axis_indices else jax.lax.axis_index(ax)
        out = jax.lax.dynamic_index_in_dim(x, idx, 0, keepdims=False)
        for k in range(1, W):
            perm = [(i, (i + k) % W) for i in range(W)]
            send = jax.lax.dynamic_index_in_dim(x, (idx + k) % W, 0,
                                                keepdims=False)
            recv = jax.lax.ppermute(send, ax, perm)
            out = (out | recv) if combine == "or" else (out + recv)
        return out
    if combine == "or":
        full = _or_allreduce_psum(x, axis_names)
    else:
        full = jax.lax.psum(x, axis_names)
    rank = linear_rank(axis_names, axis_indices)
    return jax.lax.dynamic_index_in_dim(full, rank, 0, keepdims=False)


def sketch_all_to_all(sketches: jnp.ndarray, words: jnp.ndarray,
                      axis_names: Sequence[str],
                      axis_indices: Optional[dict] = None,
                      use_ppermute: Optional[bool] = None):
    """Compressed expert-parallel all-to-all: ship per-destination sketch
    lanes over the permute wire and merge them homomorphically at the
    receiving rank (PR 8).

    ``sketches``: ``(W, *sketch_shape)`` float lanes — lane ``d`` is the
    sketch of this rank's payload destined for rank ``d``.
    ``words``: ``(W, n_words)`` uint32 bitmap lanes, ditto.

    Returns ``(sketch, words)`` — this rank's merged lane: the *sum* of
    every source's sketch for it and the *OR* of their bitmaps, i.e.
    exactly the compressed form of ``sum_s payload_s[this_rank]``.  The
    merge happens on the wire (ppermute-accumulate) — there is no
    barrier and no full gather, the ScaleCom/THC point that the
    homomorphic combine must land at the receiving expert.

    ``use_ppermute``: as in :func:`or_reduce_scatter` — ``None`` follows
    ``compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE``; full-manual callers on
    0.4.x should pass True.  The native path needs a single manual axis;
    multi-axis EP always takes the psum-emulation fallback.
    """
    sk = alltoall_lane_sum(sketches, axis_names, axis_indices=axis_indices,
                            use_ppermute=use_ppermute, combine="add")
    wd = alltoall_lane_sum(words, axis_names, axis_indices=axis_indices,
                            use_ppermute=use_ppermute, combine="or")
    return sk, wd


# ----------------------------------------------------------------------
# Dense baseline (the "NCCL AllReduce" arm of the paper's evaluation)
# ----------------------------------------------------------------------

def dense_all_reduce(grads: Any, axis_names: Sequence[str],
                     acc_dtype=jnp.float32, mean: bool = True) -> Any:
    """Plain psum of raw gradients over the DP axes."""
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    w = 1
    for ax in axis_names:
        w *= compat.axis_size(ax)

    def red(g):
        s = jax.lax.psum(g.astype(acc_dtype), tuple(axis_names))
        if mean:
            s = s / w
        return s.astype(g.dtype)

    return jax.tree.map(red, grads)


# ----------------------------------------------------------------------
# Error-feedback state + the compatibility wrapper
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AggregationState:
    """Per-leaf error-feedback residuals (empty pytree when disabled).

    Residuals keep the parameter pytree layout; the bucketed aggregators
    expose per-bucket views of them via ``BucketPlan.residual_slices``.

    ``telemetry`` (PR 6): measured per-bucket signals the ``auto``
    wire-plan controller folds into its cost model — currently a dict
    with ``bucket_occupancy`` (per-bucket nonzero fraction of the
    aggregated stream, identical on every rank). ``None`` for the fixed
    strategies, whose jaxprs stay telemetry-free; the train step
    surfaces it through the metrics dict, it is never carried across
    steps.
    """
    residual: Any
    telemetry: Any = None


def init_aggregation_state(params: Any, cfg: CompressionConfig) -> AggregationState:
    """Residuals live with the parameters (same shape & sharding)."""
    if cfg.topk_ratio is not None and cfg.error_feedback:
        res = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    else:
        res = jax.tree.map(lambda p: jnp.zeros((0,), jnp.float32), params)
    return AggregationState(residual=res)


def compressed_all_reduce(grads: Any, agg_state: AggregationState,
                          param_specs: Any, mesh,
                          cfg: CompressionConfig,
                          dp_axes: Sequence[str] = ("data",),
                          tp_axes: Sequence[str] = ("model",),
                          mean: bool = True,
                          reduce_scatter: bool = False,
                          outer_manual: Optional[Sequence[str]] = None):
    """Aggregate a gradient pytree with the paper's compressed pipeline.

    Thin wrapper over the bucketed
    :class:`~repro.core.aggregators.CompressedAggregator` (or the
    reduce-scatter variant), kept for API compatibility with the
    pre-bucketing per-leaf path. Must be called *inside* a ``shard_map``
    where ``dp_axes`` are already manual.

    ``outer_manual``: the axis set that enclosing shard_map takes manual
    — forwarded to the aggregator, where it decides whether the
    reduce-scatter strategy may slice/scatter per rank on 0.4.x (a fully
    manual caller supports the native wire path and per-rank peeling even
    without SUPPORTS_PSUM_SCATTER / partial-auto ppermute). Omitting it
    never affects correctness, but silently degrades ``reduce_scatter``
    to all-ranks peeling over the emulated wire on 0.4.x.

    Returns: (aggregated grads pytree, new AggregationState)
    """
    # Imported here: aggregators imports this module's primitives.
    from .aggregators import make_aggregator
    name = "compressed_rs" if reduce_scatter else "compressed"
    agg = make_aggregator(name, cfg, mesh, dp_axes=dp_axes,
                          tp_axes=tp_axes, mean=mean,
                          outer_manual=outer_manual)
    return agg(grads, agg_state, param_specs)
