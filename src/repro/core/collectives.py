"""Aggregation collectives for the compressed wire format.

The paper aggregates ``S(X) = [Y, B]`` through "the existing aggregation
API" — NCCL sum for ``Y`` and switch/NCCL OR for ``B``. On a TPU mesh the
sum is ``jax.lax.psum``; OR is *not* a native ICI reduction, so we build an
OR-AllReduce out of ``jax.lax.ppermute``:

- ``or_allreduce_ring``     — reduce-scatter + all-gather ring with a
  bitwise-OR combiner; bandwidth-optimal (2·(W−1)/W · |B| per link), the
  analogue of NCCL's ring AllReduce.
- ``or_allreduce_doubling`` — recursive doubling (log2 W full-size steps);
  latency-optimal for small bitmaps, used when |B|/W would be tiny.
- ``or_allreduce``          — hierarchical driver: ring within a pod (ICI),
  then doubling across pods (DCN has few, fat hops), then a broadcast-free
  second ring phase. This mirrors production hierarchical collectives.

All functions must run inside ``shard_map`` where ``axis_name`` is manual.

``compressed_all_reduce`` is the full paper pipeline over a gradient
pytree. It runs inside the *outer* train-step ``shard_map`` (manual DP
axes) and opens a *nested* ``shard_map`` that takes the tensor-parallel
axis manual too, so each device compresses only its local parameter shard
— no GSPMD resharding of gradients ever happens, and the block structure
stays aligned with the TP shards (which is what lets the same compressed
stream feed a reduce-scatter for ZeRO-style sharded optimizers).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from .config import CompressionConfig
from .compressor import HomomorphicCompressor, CompressedLeaf
from . import topk as topk_lib


# ----------------------------------------------------------------------
# OR-AllReduce primitives (manual collectives)
# ----------------------------------------------------------------------

def _ring_perm(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def or_allreduce_ring(x: jnp.ndarray, axis_name: str,
                      idx: jnp.ndarray | None = None) -> jnp.ndarray:
    """Bitwise-OR AllReduce via a bandwidth-optimal ring (RS + AG).

    ``x``: uint32 words, identical shape on every shard of ``axis_name``.
    ``idx``: this shard's index on ``axis_name``. Pass it in when calling
    from a *nested* shard_map — ``axis_index`` on an axis bound by an
    outer shard_map trips the Shardy verifier (re-binding), while plain
    ppermute/psum on outer axes are fine.
    """
    n = compat.axis_size(axis_name)
    if n == 1:
        return x
    if idx is None:
        idx = jax.lax.axis_index(axis_name)
    size = x.shape[0]
    pad = (-size) % n
    chunks = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)
                     ).reshape((n, (size + pad) // n) + x.shape[1:])
    perm = _ring_perm(n)

    # Phase 1 — reduce-scatter: after n-1 steps, shard i owns the fully
    # OR-reduced chunk (i+1) mod n.
    for t in range(n - 1):
        send = jax.lax.dynamic_index_in_dim(chunks, (idx - t) % n, 0, keepdims=False)
        recv = jax.lax.ppermute(send, axis_name, perm)
        tgt = (idx - t - 1) % n
        upd = jax.lax.dynamic_index_in_dim(chunks, tgt, 0, keepdims=False) | recv
        chunks = jax.lax.dynamic_update_index_in_dim(chunks, upd, tgt, 0)

    # Phase 2 — all-gather of the reduced chunks around the same ring.
    for t in range(n - 1):
        send = jax.lax.dynamic_index_in_dim(chunks, (idx + 1 - t) % n, 0, keepdims=False)
        recv = jax.lax.ppermute(send, axis_name, perm)
        tgt = (idx - t) % n
        chunks = jax.lax.dynamic_update_index_in_dim(chunks, recv, tgt, 0)

    out = chunks.reshape((size + pad,) + x.shape[1:])
    return out[:size] if pad else out


def or_allreduce_doubling(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Bitwise-OR AllReduce via recursive doubling (requires power-of-2)."""
    n = compat.axis_size(axis_name)
    if n == 1:
        return x
    if n & (n - 1):
        raise ValueError(f"recursive doubling needs power-of-2 size, got {n}")
    d = 1
    while d < n:
        perm = [(i, i ^ d) for i in range(n)]
        x = x | jax.lax.ppermute(x, axis_name, perm)
        d *= 2
    return x


def _or_allreduce_psum(x: jnp.ndarray, axis_names: Sequence[str]) -> jnp.ndarray:
    """OR-AllReduce emulated with the sum collective (exact).

    Unpacks each uint32 word into its 32 bits, psums the bit counts, and
    repacks ``count > 0``. 32x the wire volume of the native OR — this is
    the compatibility path for JAX versions whose partitioner cannot run
    ppermute over a manual axis while other mesh axes stay auto.
    """
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((x[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    counts = jax.lax.psum(bits, tuple(axis_names))
    return jnp.sum(
        jnp.where(counts > 0, jnp.uint32(1) << shifts, jnp.uint32(0)),
        axis=-1, dtype=jnp.uint32)


def or_allreduce(x: jnp.ndarray, axis_names: Sequence[str],
                 ring_threshold: int = 65536,
                 axis_indices: Optional[dict] = None) -> jnp.ndarray:
    """Hierarchical OR-AllReduce over several (manual) mesh axes.

    Axes are reduced innermost-first (e.g. ``("pod", "data")`` rings over
    ``data`` within each pod, then combines across pods). Small payloads
    use recursive doubling to dodge ring latency.

    ``axis_indices``: {axis: this shard's index} — required when calling
    from a nested shard_map (see or_allreduce_ring).
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    if not compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE:
        return _or_allreduce_psum(x, axis_names)
    for ax in reversed(tuple(axis_names)):
        if x.shape[0] >= ring_threshold:
            idx = axis_indices.get(ax) if axis_indices else None
            x = or_allreduce_ring(x, ax, idx=idx)
        else:
            x = or_allreduce_doubling(x, ax)
    return x


# ----------------------------------------------------------------------
# Dense baseline (the "NCCL AllReduce" arm of the paper's evaluation)
# ----------------------------------------------------------------------

def dense_all_reduce(grads: Any, axis_names: Sequence[str],
                     acc_dtype=jnp.float32, mean: bool = True) -> Any:
    """Plain psum of raw gradients over the DP axes."""
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    w = 1
    for ax in axis_names:
        w *= compat.axis_size(ax)

    def red(g):
        s = jax.lax.psum(g.astype(acc_dtype), tuple(axis_names))
        if mean:
            s = s / w
        return s.astype(g.dtype)

    return jax.tree.map(red, grads)


# ----------------------------------------------------------------------
# The paper's pipeline over a gradient pytree
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AggregationState:
    """Per-leaf error-feedback residuals (empty pytree when disabled)."""
    residual: Any


def init_aggregation_state(params: Any, cfg: CompressionConfig) -> AggregationState:
    """Residuals live with the parameters (same shape & sharding)."""
    if cfg.topk_ratio is not None and cfg.error_feedback:
        res = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    else:
        res = jax.tree.map(lambda p: jnp.zeros((0,), jnp.float32), params)
    return AggregationState(residual=res)


def _compress_leaf(g_local: jnp.ndarray, res: jnp.ndarray,
                   comp: HomomorphicCompressor):
    """Phase I on one leaf shard: sparsify -> encode."""
    cfg = comp.cfg
    flat = g_local.reshape(-1).astype(jnp.float32)
    new_res = res
    if cfg.topk_ratio is not None:
        k = max(1, int(flat.shape[0] * cfg.topk_ratio))
        if cfg.error_feedback:
            flat, new_res_flat = topk_lib.apply_error_feedback(
                flat, res.reshape(-1), k, exact=cfg.topk_exact)
            new_res = new_res_flat.reshape(res.shape)
        elif cfg.topk_exact:
            flat = topk_lib.sparsify_topk(flat, k)
        else:
            flat = topk_lib.sparsify_threshold(flat, k)
    c = comp.compress(flat)
    return c.sketch, c.index_words, new_res


def _recover_leaf(sk: jnp.ndarray, words: jnp.ndarray, shape, dtype,
                  comp: HomomorphicCompressor, n_workers: int):
    """Phase II on one leaf shard: peel -> mean."""
    n = 1
    for d in shape:
        n *= d
    rec = comp.recover(CompressedLeaf(sketch=sk, index_words=words), n)
    return (rec / n_workers).astype(dtype).reshape(shape)


def compressed_all_reduce(grads: Any, agg_state: AggregationState,
                          param_specs: Any, mesh,
                          cfg: CompressionConfig,
                          dp_axes: Sequence[str] = ("data",),
                          tp_axes: Sequence[str] = ("model",),
                          mean: bool = True):
    """Aggregate a gradient pytree with the paper's compressed pipeline.

    Must be called *inside* a ``shard_map`` where ``dp_axes`` are already
    manual. Opens a nested ``shard_map`` making ``tp_axes`` manual too, so
    compression happens on local shards with no resharding.

    Args:
      grads:       pytree of (possibly TP-sharded) gradients.
      agg_state:   error-feedback residuals (same treedef).
      param_specs: pytree of ``PartitionSpec`` describing TP placement.
      mesh:        the device mesh (same one the outer shard_map uses).
      cfg:         compression config.

    Returns: (aggregated grads pytree, new AggregationState)
    """
    comp = HomomorphicCompressor(cfg)
    if isinstance(dp_axes, str):
        dp_axes = (dp_axes,)
    n_workers = 1
    for ax in dp_axes:
        n_workers *= mesh.shape[ax]
    if not mean:
        n_workers = 1

    # Strip any DP-axis references from the specs (those axes are manual
    # in the outer shard_map; the nested one only partitions TP axes).
    dp_set = set(dp_axes)

    def tp_only(spec):
        if spec is None:
            return P()
        parts = []
        for s in spec:
            if s is None:
                parts.append(None)
            elif isinstance(s, (tuple, list)):
                kept = tuple(a for a in s if a not in dp_set)
                parts.append(kept if kept else None)
            else:
                parts.append(None if s in dp_set else s)
        return P(*parts)

    specs = jax.tree.map(tp_only, param_specs,
                         is_leaf=lambda s: isinstance(s, P) or s is None)

    leaves, treedef = jax.tree.flatten(grads)
    spec_leaves = treedef.flatten_up_to(specs)
    res_leaves = treedef.flatten_up_to(agg_state.residual)

    # Shard indices on the (outer-manual) DP axes, computed *here* where
    # those axes are directly bound; threaded into OR-rings because
    # axis_index inside nested regions would re-bind the axis (Shardy).
    dp_idx = dict(zip(dp_axes, (jax.lax.axis_index(ax) for ax in dp_axes)))

    ef_on = cfg.topk_ratio is not None and cfg.error_feedback
    out_leaves = []
    new_res_leaves = []
    for g, spec, res in zip(leaves, spec_leaves, res_leaves):
        res_spec = spec if ef_on else P()
        # manual axes = the TP axis plus any axis this leaf's spec
        # references (e.g. kimi's experts are sharded over the EP axis
        # "data" — the nested shard_map must bind it to slice locally)
        tp_set = {a for a in tp_axes if a}
        for part in spec:
            if part is None:
                continue
            tp_set |= set(part) if isinstance(part, (tuple, list)) else {part}
        # sketch/index shapes per shard (for the nested out_specs)
        if tp_set and compat.SUPPORTS_NESTED_SHARD_MAP:
            # Two nested regions with the DP collectives *between* them
            # at the outer level: running psum/ppermute over the outer
            # manual axis inside a doubly-nested manual region check-
            # crashes XLA's SPMD partitioner (AllReduceAlongShardingDims)
            # on 3-axis meshes. Phase boundaries cost nothing — sketch
            # and words stay shard-local either way.
            enc = compat.shard_map(
                functools.partial(_compress_leaf, comp=comp),
                mesh=mesh,
                in_specs=(spec, res_spec),
                out_specs=(P(), P(), res_spec),
                axis_names=tp_set, check_vma=False)
            sk, words, new_res = enc(g, res)
            sk = jax.lax.psum(sk, tuple(dp_axes))
            words = or_allreduce(words, dp_axes, axis_indices=dp_idx)
            # local (per-shard) leaf shape for the recovery region
            def _div(i):
                part = spec[i] if i < len(spec) else None
                if part is None:
                    return 1
                names = part if isinstance(part, (tuple, list)) else (part,)
                d = 1
                for nm in names:
                    d *= mesh.shape[nm]
                return d
            local_shape = tuple(sz // _div(i) for i, sz in enumerate(g.shape))
            dec = compat.shard_map(
                functools.partial(_recover_leaf, comp=comp,
                                  n_workers=n_workers,
                                  shape=local_shape, dtype=g.dtype),
                mesh=mesh,
                in_specs=(P(), P()),
                out_specs=spec,
                axis_names=tp_set, check_vma=False)
            rec = dec(sk, words)
        else:
            # Pure DP, or a TP-sharded leaf on a JAX without nested
            # partial-manual shard_map support: compress the auto-sharded
            # global view. Same compress -> psum/OR -> recover math (the
            # nesting only avoids GSPMD resharding around the codec).
            sk, words, new_res = _compress_leaf(g, res, comp)
            sk = jax.lax.psum(sk, tuple(dp_axes))
            words = or_allreduce(words, dp_axes, axis_indices=dp_idx)
            rec = _recover_leaf(sk, words, g.shape, g.dtype, comp, n_workers)
        out_leaves.append(rec)
        new_res_leaves.append(new_res)

    return (jax.tree.unflatten(treedef, out_leaves),
            AggregationState(residual=jax.tree.unflatten(treedef, new_res_leaves)))
