"""Aggregation collectives for the compressed wire format.

The paper aggregates ``S(X) = [Y, B]`` through "the existing aggregation
API" — NCCL sum for ``Y`` and switch/NCCL OR for ``B``. On a TPU mesh the
sum is ``jax.lax.psum``; OR is *not* a native ICI reduction, so we build an
OR-AllReduce out of ``jax.lax.ppermute``:

- ``or_allreduce_ring``     — reduce-scatter + all-gather ring with a
  bitwise-OR combiner; bandwidth-optimal (2·(W−1)/W · |B| per link), the
  analogue of NCCL's ring AllReduce.
- ``or_allreduce_doubling`` — recursive doubling (log2 W full-size steps);
  latency-optimal for small bitmaps, used when |B|/W would be tiny.
- ``or_allreduce``          — hierarchical driver: ring within a pod (ICI),
  then doubling across pods (DCN has few, fat hops), then a broadcast-free
  second ring phase. Payloads at or above ``ring_threshold`` *bytes* (and
  any axis whose size is not a power of two) take the ring; small
  power-of-two axes take recursive doubling.

All functions must run inside ``shard_map`` where ``axis_name`` is manual.

Since PR 2 this module holds only the **primitives** (plus the dense
baseline and the error-feedback state container). Gradient aggregation
itself is a pluggable strategy over fixed-size buckets — ONE sketch
encode, ONE stacked sketch-``psum`` and ONE OR-AllReduce for the whole
pytree instead of a per-leaf Python loop — implemented in
:mod:`repro.core.aggregators` on top of :mod:`repro.core.bucketing`.
:func:`compressed_all_reduce` survives as a thin compatibility wrapper
over the bucketed :class:`~repro.core.aggregators.CompressedAggregator`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import compat
from .config import CompressionConfig


# ----------------------------------------------------------------------
# OR-AllReduce primitives (manual collectives)
# ----------------------------------------------------------------------

def _ring_perm(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def or_allreduce_ring(x: jnp.ndarray, axis_name: str,
                      idx: jnp.ndarray | None = None) -> jnp.ndarray:
    """Bitwise-OR AllReduce via a bandwidth-optimal ring (RS + AG).

    ``x``: uint32 words, identical shape on every shard of ``axis_name``.
    ``idx``: this shard's index on ``axis_name``. Pass it in when calling
    from a *nested* shard_map — ``axis_index`` on an axis bound by an
    outer shard_map trips the Shardy verifier (re-binding), while plain
    ppermute/psum on outer axes are fine.
    """
    n = compat.axis_size(axis_name)
    if n == 1:
        return x
    if idx is None:
        idx = jax.lax.axis_index(axis_name)
    size = x.shape[0]
    pad = (-size) % n
    chunks = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)
                     ).reshape((n, (size + pad) // n) + x.shape[1:])
    perm = _ring_perm(n)

    # Phase 1 — reduce-scatter: after n-1 steps, shard i owns the fully
    # OR-reduced chunk (i+1) mod n.
    for t in range(n - 1):
        send = jax.lax.dynamic_index_in_dim(chunks, (idx - t) % n, 0, keepdims=False)
        recv = jax.lax.ppermute(send, axis_name, perm)
        tgt = (idx - t - 1) % n
        upd = jax.lax.dynamic_index_in_dim(chunks, tgt, 0, keepdims=False) | recv
        chunks = jax.lax.dynamic_update_index_in_dim(chunks, upd, tgt, 0)

    # Phase 2 — all-gather of the reduced chunks around the same ring.
    for t in range(n - 1):
        send = jax.lax.dynamic_index_in_dim(chunks, (idx + 1 - t) % n, 0, keepdims=False)
        recv = jax.lax.ppermute(send, axis_name, perm)
        tgt = (idx - t) % n
        chunks = jax.lax.dynamic_update_index_in_dim(chunks, recv, tgt, 0)

    out = chunks.reshape((size + pad,) + x.shape[1:])
    return out[:size] if pad else out


def or_allreduce_doubling(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Bitwise-OR AllReduce via recursive doubling (requires power-of-2)."""
    n = compat.axis_size(axis_name)
    if n == 1:
        return x
    if n & (n - 1):
        raise ValueError(f"recursive doubling needs power-of-2 size, got {n}")
    d = 1
    while d < n:
        perm = [(i, i ^ d) for i in range(n)]
        x = x | jax.lax.ppermute(x, axis_name, perm)
        d *= 2
    return x


def _or_allreduce_psum(x: jnp.ndarray, axis_names: Sequence[str]) -> jnp.ndarray:
    """OR-AllReduce emulated with the sum collective (exact).

    Unpacks each uint32 word into its 32 bits, psums the bit counts, and
    repacks ``count > 0``. 32x the wire volume of the native OR — this is
    the compatibility path for JAX versions whose partitioner cannot run
    ppermute over a manual axis while other mesh axes stay auto.
    """
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((x[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    counts = jax.lax.psum(bits, tuple(axis_names))
    return jnp.sum(
        jnp.where(counts > 0, jnp.uint32(1) << shifts, jnp.uint32(0)),
        axis=-1, dtype=jnp.uint32)


def _use_ring(payload_bytes: int, axis_size: int, ring_threshold: int) -> bool:
    """Ring vs recursive doubling: ring for payloads of ``ring_threshold``
    bytes or more (bandwidth-bound regime), and always for axis sizes
    that are not a power of two (doubling requires 2^k participants)."""
    return payload_bytes >= ring_threshold or bool(axis_size & (axis_size - 1))


def or_allreduce(x: jnp.ndarray, axis_names: Sequence[str],
                 ring_threshold: int = 65536,
                 axis_indices: Optional[dict] = None) -> jnp.ndarray:
    """Hierarchical OR-AllReduce over several (manual) mesh axes.

    Axes are reduced innermost-first (e.g. ``("pod", "data")`` rings over
    ``data`` within each pod, then combines across pods).

    ``ring_threshold``: payload size in **bytes** at or above which the
    bandwidth-optimal ring is used; smaller payloads take recursive
    doubling to dodge ring latency. Axes whose size is not a power of two
    always take the ring (doubling requires power-of-2 participants).

    ``axis_indices``: {axis: this shard's index} — required when calling
    from a nested shard_map (see or_allreduce_ring).
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    if not compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE:
        return _or_allreduce_psum(x, axis_names)
    payload_bytes = x.size * x.dtype.itemsize
    for ax in reversed(tuple(axis_names)):
        if _use_ring(payload_bytes, compat.axis_size(ax), ring_threshold):
            idx = axis_indices.get(ax) if axis_indices else None
            x = or_allreduce_ring(x, ax, idx=idx)
        else:
            x = or_allreduce_doubling(x, ax)
    return x


# ----------------------------------------------------------------------
# Dense baseline (the "NCCL AllReduce" arm of the paper's evaluation)
# ----------------------------------------------------------------------

def dense_all_reduce(grads: Any, axis_names: Sequence[str],
                     acc_dtype=jnp.float32, mean: bool = True) -> Any:
    """Plain psum of raw gradients over the DP axes."""
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    w = 1
    for ax in axis_names:
        w *= compat.axis_size(ax)

    def red(g):
        s = jax.lax.psum(g.astype(acc_dtype), tuple(axis_names))
        if mean:
            s = s / w
        return s.astype(g.dtype)

    return jax.tree.map(red, grads)


# ----------------------------------------------------------------------
# Error-feedback state + the compatibility wrapper
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AggregationState:
    """Per-leaf error-feedback residuals (empty pytree when disabled).

    Residuals keep the parameter pytree layout; the bucketed aggregators
    expose per-bucket views of them via ``BucketPlan.residual_slices``.
    """
    residual: Any


def init_aggregation_state(params: Any, cfg: CompressionConfig) -> AggregationState:
    """Residuals live with the parameters (same shape & sharding)."""
    if cfg.topk_ratio is not None and cfg.error_feedback:
        res = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    else:
        res = jax.tree.map(lambda p: jnp.zeros((0,), jnp.float32), params)
    return AggregationState(residual=res)


def compressed_all_reduce(grads: Any, agg_state: AggregationState,
                          param_specs: Any, mesh,
                          cfg: CompressionConfig,
                          dp_axes: Sequence[str] = ("data",),
                          tp_axes: Sequence[str] = ("model",),
                          mean: bool = True,
                          reduce_scatter: bool = False):
    """Aggregate a gradient pytree with the paper's compressed pipeline.

    Thin wrapper over the bucketed
    :class:`~repro.core.aggregators.CompressedAggregator` (or the
    reduce-scatter variant), kept for API compatibility with the
    pre-bucketing per-leaf path. Must be called *inside* a ``shard_map``
    where ``dp_axes`` are already manual.

    Returns: (aggregated grads pytree, new AggregationState)
    """
    # Imported here: aggregators imports this module's primitives.
    from .aggregators import make_aggregator
    name = "compressed_rs" if reduce_scatter else "compressed"
    agg = make_aggregator(name, cfg, mesh, dp_axes=dp_axes,
                          tp_axes=tp_axes, mean=mean)
    return agg(grads, agg_state, param_specs)
