"""Magnitude sparsification + error feedback.

Two roles:

1. the paper's Fig. 4 baseline ("vanilla Top-k"), decoded by zeroing
   everything below the threshold — the comparison our lossless recovery
   must beat at equal compressed size;
2. the *budget enforcer* for dense-gradient models (VGG/BERT regime,
   here: the qwen/granite/internvl dense archs): the compressor's sketch
   has a static capacity, so for dense gradients we keep the top
   ``topk_ratio`` coordinates and carry the remainder in an error-feedback
   accumulator (DGC-style), exactly how the paper's end-to-end runs pin
   compressed size to 10%.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sparsify_topk(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Keep the k largest-magnitude entries of flat ``x`` (ties kept)."""
    if k >= x.shape[0]:
        return x
    vals = jax.lax.top_k(jnp.abs(x), k)[0]
    thresh = vals[-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def sparsify_threshold(x: jnp.ndarray, k: int, oversample: int = 4096) -> jnp.ndarray:
    """Approximate top-k via a sampled quantile threshold.

    O(n) instead of O(n log n); used for very large leaves where exact
    ``top_k`` dominates compression time. Guarantees *approximately* k
    survivors; the compressor tolerates overshoot via its peel fallback.
    """
    n = x.shape[0]
    if k >= n:
        return x
    stride = max(1, n // oversample)
    sample = jnp.abs(x[::stride])
    q = 1.0 - (k / n)
    thresh = jnp.quantile(sample, q)
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def apply_error_feedback(grad: jnp.ndarray, residual: jnp.ndarray,
                         k: int, exact: bool = True
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(grad + residual) -> (sparse part to send, new residual)."""
    full = grad + residual
    sparse = sparsify_topk(full, k) if exact else sparsify_threshold(full, k)
    return sparse, full - sparse
