"""Online cost model for the ``auto`` strategy: the *controller* half of
the PR 6 plan/execute split.

The controller decides, per bucket, which wire
(:data:`repro.core.wireplan.WIRES`) ships it cheapest, from three
inputs:

1. **Analytic wire model** — :meth:`CompressionConfig.strategy_wire_bytes`
   turned into seconds with the ``auto_link_gbps`` / ``auto_codec_gbps``
   bandwidth priors. This seeds the very first plan, before any step has
   run (see :func:`analytic_plan`).
2. **Measured wall telemetry** — per-step wall times observed host-side
   (outside jit) while the controller *probes* each candidate wire with
   a uniform plan. Measured walls override the analytic priors as they
   arrive: the analytic model cannot know, e.g., that this host's psum
   beats the sketch codec (our toy benchmark: dense ~3.1 ms vs
   compressed ~5.5 ms), but the probe walls say so directly.
3. **Measured occupancy** — per-bucket nonzero fraction of the
   aggregated stream (``AggregationState.telemetry``). A bucket whose
   occupancy exceeds ``auto_occupancy_margin`` of the peeling capacity
   would recover lossily, so the compressed wires are infeasible for it
   (infinite cost) and it is planned dense — this is what produces
   genuinely *mixed* plans on skewed-sparsity streams.

The controller is deliberately host-side and slow-moving: plans change
only every ``cfg.replan_every`` steps (each distinct plan is a distinct
compiled step), and wall measurements fold in through an EWMA so one
noisy step cannot flip the plan. After the wire probes it runs one
chunk-grid probe on the winning wire (``stream_chunks`` at the finest
aligned count vs the config grid) — the "tune stream_chunks live" knob.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

from .bucketing import BucketPlan
from .config import CompressionConfig
from .wireplan import WIRES, WirePlan, plan_from_assignments, uniform_plan

COMPRESSED_WIRES = tuple(w for w in WIRES if w != "dense")

# Canonical single-chip roofline constants (TPU v5e class), shared with
# `benchmarks/roofline.py` — the source of the default `auto_*` bandwidth
# priors below and of the codec-compute term in the analytic costs.
# PR 7 recalibration: the old priors (link 10 Gb/s, codec 2 Gb/s) were
# Ethernet-NIC-shaped and put the analytic compressed cost ~7x off the
# measured decision-trace walls; ICI and HBM are the honest in-mesh
# bounds.
PEAK_FLOPS = 197e12    # bf16 MXU peak, FLOP/s
HBM_BW = 819e9         # HBM bytes/s — bounds one codec pass over a bucket
ICI_BW = 50e9          # per-link ICI bytes/s — bounds the wire


def priors_from_codec_report(report: Dict[str, Any]) -> Dict[str, float]:
    """Turn a ``benchmarks/roofline.py --codec`` report into measured
    ``auto_*`` prior overrides (``dataclasses.replace(cfg, **priors)``).

    The codec prior is the *achieved* streaming bandwidth of the fused
    producer/consumer pass when the report measured one (falling back to
    the modeled HBM bound); the link prior stays the ICI roofline (the
    report has no collective timings — measured walls flow in through
    the controller's probes instead).
    """
    codec_bps = float(report.get("achieved_codec_bytes_per_s")
                      or report.get("hbm_bytes_per_s", HBM_BW))
    link_bps = float(report.get("ici_bytes_per_s", ICI_BW))
    return {"auto_codec_gbps": codec_bps * 8 / 1e9,
            "auto_link_gbps": link_bps * 8 / 1e9}


def fixed_wires() -> Tuple[str, ...]:
    """The controller's search space: every fixed strategy in the
    aggregator registry. Enumerated from ``AGGREGATORS`` (not hardcoded)
    so an added/renamed strategy is picked up — or trips the import-time
    WIRES<->registry assert — rather than silently drifting."""
    from .aggregators import AGGREGATORS  # late: aggregators imports us
    wires = tuple(w for w in AGGREGATORS if w != "auto")
    if set(wires) != set(WIRES):
        raise AssertionError(
            f"registry {sorted(wires)} out of sync with WIRES {WIRES}")
    return wires


# ----------------------------------------------------------------------
# Analytic costs (the no-telemetry prior)
# ----------------------------------------------------------------------

def analytic_bucket_costs(plan: BucketPlan, cfg: CompressionConfig,
                          workers: int, grad_bytes_per_elem: int = 4
                          ) -> Dict[str, float]:
    """Per-bucket cost estimate (seconds) for each wire, from the
    analytic wire model and the ``auto_*`` bandwidth priors.

    ``link_bytes`` of the whole bucket-padded stream divided evenly over
    its buckets (buckets are homogeneous by construction), plus a
    per-wire codec-compute term (PR 7): the number of producer/consumer
    *passes* the wire makes over the bucket's f32 stream bytes
    (`repro.kernels.ops.wire_codec_passes` — 1+1 fused, 2-3+2-3
    composed) over the codec bandwidth prior, scaled by each wire's
    consumer share (the reduce-scatter wire peels only 1/W of the
    stream per rank). Serial wire+codec model — the overlap win is
    exactly what the measured probes capture instead.
    """
    from repro.kernels.ops import wire_codec_passes  # late: jax-heavy
    n = plan.n_buckets * plan.bucket_elems
    acc = cfg.strategy_wire_bytes(n, workers,
                                  grad_bytes_per_elem=grad_bytes_per_elem)
    link_bw = cfg.auto_link_gbps * 1e9 / 8
    codec_bw = cfg.auto_codec_gbps * 1e9 / 8
    t_pass = plan.bucket_elems * 4 / codec_bw
    nb = plan.n_buckets
    p = wire_codec_passes(cfg)
    pq = wire_codec_passes(cfg, quantized=cfg.wire_dtype == "fxp32")

    def link_t(entry) -> float:
        return entry["link_bytes"] / nb / link_bw

    rs = acc["compressed_rs_native"] or acc["compressed_rs_emulated"]
    return {
        "dense": link_t(acc["dense"]),
        "compressed": link_t(acc["compressed"])
        + (p["producer"] + p["consumer"]) * t_pass,
        "compressed_rs": link_t(rs)
        + (p["producer"] + p["consumer"] / workers) * t_pass,
        "compressed_innet": link_t(acc["compressed_innet"])
        + (pq["producer"] + pq["consumer"]) * t_pass,
    }


def analytic_alltoall_costs(n: int, cfg: CompressionConfig,
                            workers: int, grad_bytes_per_elem: int = 4
                            ) -> Dict[str, float]:
    """Analytic per-exchange cost (seconds) of the permute-pattern wires
    (PR 8): the all-to-all analogue of :func:`analytic_bucket_costs`,
    priced from the ``dense_alltoall`` / ``compressed_alltoall`` entries
    of :meth:`CompressionConfig.strategy_wire_bytes` and the same
    bandwidth priors.

    ``n`` is one rank's *stacked* W-lane dispatch/combine payload.  The
    link term ships ``(W-1)/W x`` the payload; the codec term charges
    the producer for encoding the full lane stack but the consumer only
    for peeling this rank's merged ``1/W`` lane — the same asymmetry as
    the reduce-scatter wire's per-rank peel.  The dense exchange has no
    codec term.  Serial wire+codec, like the all-reduce model: the
    overlap win is what measured probes would capture.
    """
    from repro.kernels.ops import wire_codec_passes  # late: jax-heavy
    acc = cfg.strategy_wire_bytes(n, workers,
                                  grad_bytes_per_elem=grad_bytes_per_elem)
    link_bw = cfg.auto_link_gbps * 1e9 / 8
    codec_bw = cfg.auto_codec_gbps * 1e9 / 8
    p = wire_codec_passes(cfg)
    comp = acc["compressed_alltoall"]
    stack_elems = comp["n_lane_buckets"] * workers * \
        cfg.bucket_elems_for(-(-n // workers))
    t_pass = stack_elems * 4 / codec_bw
    return {
        "dense": acc["dense_alltoall"]["link_bytes"] / link_bw,
        "compressed": comp["link_bytes"] / link_bw
        + (p["producer"] + p["consumer"] / workers) * t_pass,
    }


def analytic_plan(plan: BucketPlan, cfg: CompressionConfig,
                  workers: int, grad_bytes_per_elem: int = 4) -> WirePlan:
    """The zero-telemetry plan the ``auto`` strategy executes before its
    controller has observed anything: cheapest wire per the analytic
    model (uniform, since the analytic costs are per-bucket uniform)."""
    costs = analytic_bucket_costs(plan, cfg, workers,
                                  grad_bytes_per_elem=grad_bytes_per_elem)
    wire = min(fixed_wires(), key=lambda w: costs[w])
    return uniform_plan(plan.n_buckets, wire)


def occupancy_feasible(occ: float, cfg: CompressionConfig) -> bool:
    """Can a bucket with nonzero fraction ``occ`` still peel exactly?
    Capacity is ``peel_capacity`` per block; ``auto_occupancy_margin``
    keeps headroom below the w.h.p. threshold."""
    cap_frac = cfg.peel_capacity / cfg.block_elems
    return occ <= cfg.auto_occupancy_margin * cap_frac


# ----------------------------------------------------------------------
# The online controller
# ----------------------------------------------------------------------

def _finest_chunks(wire: str, n_buckets: int, workers: int,
                   cfg: CompressionConfig) -> Optional[int]:
    """Finest valid ``stream_chunks`` for a uniform plan on ``wire``
    (None = the wire has no meaningful chunk knob)."""
    if wire == "dense" or cfg.index != "bitmap":
        return None
    if wire == "compressed_rs" and workers > 1:
        return -(-n_buckets // workers)   # chunk per rank-bucket run
    if wire == "compressed_innet":
        return -(-n_buckets // cfg.switch_slots)
    return n_buckets


@dataclasses.dataclass
class AutoWireController:
    """Host-side wire planner for the ``auto`` strategy.

    Drive it from the training/benchmark loop, outside jit::

        ctl = AutoWireController(plan, cfg, workers=W)
        for step in range(...):
            wplan = ctl.plan(step)          # static per replan window
            agg = dataclasses.replace(agg, wire_plan=wplan)
            ... run the (re)compiled step, time it ...
            ctl.observe(wall_s, telemetry)  # wall + bucket occupancy

    Probe schedule: one replan window per fixed wire (uniform plans),
    then one window probing the winner's finest chunk grid, then the
    decided (possibly mixed) plan, refreshed every ``replan_every``
    steps from the latest EWMAs.
    """

    bucket_plan: BucketPlan
    cfg: CompressionConfig
    workers: int
    grad_bytes_per_elem: int = 4
    ewma: float = 0.5           # weight of the newest wall observation
    warmup_steps: int = 1       # per-window steps dropped from the EWMA
                                # (first step pays compilation)

    def __post_init__(self):
        self.wires = fixed_wires()
        self.analytic = analytic_bucket_costs(
            self.bucket_plan, self.cfg, self.workers,
            grad_bytes_per_elem=self.grad_bytes_per_elem)
        # probe cheapest-first so early steps are not worst-case slow
        self._probe_queue: List[Tuple[str, Optional[int]]] = [
            (w, None) for w in sorted(self.wires,
                                      key=lambda w: self.analytic[w])]
        self._walls: Dict[Tuple[str, Optional[int]], float] = {}
        self._occupancy: Optional[List[float]] = None
        self._chunk_probed = False
        self._current: WirePlan = self._start_window(*self._probe_queue[0])
        self._window_steps = 0

    # -- observation ---------------------------------------------------

    def observe(self, wall_s: float, telemetry: Any = None) -> None:
        """Fold one step's measurements into the model. ``telemetry``:
        the ``AggregationState.telemetry`` dict (host arrays ok)."""
        self._window_steps += 1
        if self._window_steps > self.warmup_steps:
            key = self._plan_key(self._current)
            if key is not None:
                prev = self._walls.get(key)
                self._walls[key] = wall_s if prev is None else \
                    (1 - self.ewma) * prev + self.ewma * wall_s
        if telemetry is not None and "bucket_occupancy" in telemetry:
            occ = [float(v) for v in telemetry["bucket_occupancy"]]
            if self._occupancy is None:
                self._occupancy = occ
            else:
                self._occupancy = [
                    (1 - self.ewma) * o + self.ewma * n
                    for o, n in zip(self._occupancy, occ)]

    def _plan_key(self, plan: WirePlan) -> Optional[Tuple[str, Optional[int]]]:
        """Measurement key for a plan's wall: only uniform plans are
        attributable to one wire; mixed plans train nothing (their cost
        is already a sum of measured parts)."""
        w = plan.uniform_wire
        if w is None:
            return None
        chunks = plan.groups[0].stream_chunks
        return (w, chunks)

    # -- planning ------------------------------------------------------

    def plan(self, step: int) -> WirePlan:
        """The plan to execute at ``step``. Changes only on
        ``cfg.replan_every`` boundaries (each distinct plan is a
        distinct compiled step); step 0 runs the first probe window."""
        if step == 0 or step % self.cfg.replan_every:
            return self._current
        nxt = self._next_window()
        if nxt != self._current:
            self._current = nxt
            self._window_steps = 0
        return self._current

    def _start_window(self, wire: str, chunks: Optional[int]) -> WirePlan:
        return uniform_plan(self.bucket_plan.n_buckets, wire,
                            stream_chunks=chunks)

    def _next_window(self) -> WirePlan:
        # still probing wires?
        key = self._plan_key(self._current)
        if self._probe_queue and key == self._probe_queue[0]:
            self._probe_queue.pop(0)
        if self._probe_queue:
            return self._start_window(*self._probe_queue[0])
        # wires probed: one chunk-grid probe on the measured winner
        if not self._chunk_probed:
            self._chunk_probed = True
            w = min(self.wires, key=lambda w: self._wire_wall(w))
            fine = _finest_chunks(w, self.bucket_plan.n_buckets,
                                  self.workers, self.cfg)
            if fine is not None and fine > 1 \
                    and (w, fine) not in self._walls:
                self._probe_queue.append((w, fine))
                return self._start_window(w, fine)
        return self._decide()

    def _wire_wall(self, wire: str) -> float:
        """Best measured wall for a wire (any probed chunk grid), else
        the analytic whole-stream estimate."""
        walls = [v for (w, _), v in self._walls.items() if w == wire]
        if walls:
            return min(walls)
        return self.analytic[wire] * self.bucket_plan.n_buckets

    def _bucket_cost(self, wire: str, bucket: int) -> float:
        if wire in COMPRESSED_WIRES and self._occupancy is not None \
                and not occupancy_feasible(self._occupancy[bucket],
                                           self.cfg):
            return math.inf
        return self._wire_wall(wire) / self.bucket_plan.n_buckets

    def _best_chunks(self, wire: str) -> Optional[int]:
        cands = [(v, c) for (w, c), v in self._walls.items() if w == wire]
        if not cands:
            return None
        return min(cands)[1]

    def _decide(self) -> WirePlan:
        nb = self.bucket_plan.n_buckets
        assign = [min(self.wires,
                      key=lambda w: (self._bucket_cost(w, b),
                                     self.wires.index(w)))
                  for b in range(nb)]
        decided = plan_from_assignments(assign)
        # apply the measured-best chunk grid to single-wire plans (a
        # mixed plan's groups keep the config grid: per-group counts
        # were never probed)
        w = decided.uniform_wire
        if w is not None:
            return uniform_plan(nb, w, stream_chunks=self._best_chunks(w))
        return decided

    # -- reporting (schema-3 benchmark JSON) ---------------------------

    def _codec_passes(self) -> Dict[str, int]:
        """Stream-pass counts feeding the analytic codec term (diagnosable
        from CI output: fused = 1/1, composed = 2-3 each way)."""
        from repro.kernels.ops import wire_codec_passes  # late: jax-heavy
        return wire_codec_passes(
            self.cfg, quantized=self.cfg.wire_dtype == "fxp32")

    def decision_trace(self) -> Dict[str, Any]:
        """The controller's state for the benchmark JSON: per-group
        decisions of the current plan plus the cost inputs behind them."""
        occ = self._occupancy
        return {
            "plan": [{
                "start": g.start,
                "n_buckets": g.n_buckets,
                "wire": g.wire,
                "stream_chunks": g.stream_chunks,
            } for g in self._current.groups],
            "probing": bool(self._probe_queue),
            "measured_wall_s": {
                f"{w}" + (f"/c{c}" if c is not None else ""):
                    round(v, 6)
                for (w, c), v in sorted(
                    self._walls.items(),
                    key=lambda kv: (kv[0][0], kv[0][1] or 0))},
            "analytic_bucket_cost_s": {
                w: round(v, 9) for w, v in self.analytic.items()},
            "codec_passes": self._codec_passes(),
            "occupancy": None if occ is None else {
                "min": round(min(occ), 4),
                "max": round(max(occ), 4),
                "capacity_frac": round(
                    self.cfg.peel_capacity / self.cfg.block_elems, 4),
                "margin": self.cfg.auto_occupancy_margin,
            },
        }
