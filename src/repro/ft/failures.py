"""Fault tolerance: failure injection/detection, straggler mitigation,
elastic re-meshing.

On a real cluster the failure signal comes from the coordinator (missing
heartbeat / ICI link error); in this single-process reproduction the same
control flow is driven by ``FailureSimulator`` so the recovery path —
detect -> drop to a smaller world -> rebuild mesh -> reshard from the
last checkpoint -> replay the deterministic data stream — is exercised
end-to-end by the tests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

import numpy as np
import jax


# ----------------------------------------------------------------------
# Failure injection + recovery policy
# ----------------------------------------------------------------------

class InjectedFailure(RuntimeError):
    def __init__(self, step: int, node: int):
        super().__init__(f"injected node failure at step {step} (node {node})")
        self.step = step
        self.node = node


@dataclasses.dataclass
class FailureSimulator:
    """Bernoulli per-step failure with deterministic seed.

    Also injects deterministic *arrival delays* for the elastic tier
    (PR 9): ``straggle_s`` marks clients late by a fixed amount every
    round, ``straggle_at`` one specific (round, client) arrival —
    :meth:`client_delay` is what the elastic server/benchmark add to
    each payload's simulated arrival time to exercise the
    quorum/deadline and deferred-residual paths.
    """
    p_fail: float = 0.0
    n_nodes: int = 1
    seed: int = 0
    fail_at_steps: Tuple[int, ...] = ()   # deterministic injections
    straggle_s: Tuple[Tuple[int, float], ...] = ()
                                          # (client, delay_s) every round
    straggle_at: Tuple[Tuple[int, int, float], ...] = ()
                                          # (round, client, delay_s) once
    _fired: set = dataclasses.field(default_factory=set, init=False)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)      # a crashed node stays replaced
            raise InjectedFailure(step, node=step % max(self.n_nodes, 1))
        if self.p_fail > 0:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, 0xFA11]))
            if rng.random() < self.p_fail:
                raise InjectedFailure(step, node=int(rng.integers(self.n_nodes)))

    def client_delay(self, round_id: int, client: int) -> float:
        """Injected extra arrival delay for one client in one round
        (seconds; 0.0 when the client is healthy)."""
        delay = 0.0
        for c, d in self.straggle_s:
            if c == client:
                delay += d
        for r, c, d in self.straggle_at:
            if r == round_id and c == client:
                delay += d
        return delay


@dataclasses.dataclass
class RecoveryPolicy:
    """What to do when a failure is detected."""
    max_restarts: int = 3
    # elastic: continue with fewer devices (shrink the data axis) instead
    # of waiting for the node to come back
    elastic: bool = True


# ----------------------------------------------------------------------
# Straggler mitigation
# ----------------------------------------------------------------------

@dataclasses.dataclass
class StragglerMonitor:
    """Per-step wall-time EMA; flags outliers.

    Real deployments attach this to per-host step timings and re-dispatch
    the slow host's shard to a hot spare (backup workers); the monitor
    records the decision so the training log shows mitigation events. The
    single-process version can only *detect* and account.
    """
    ema_decay: float = 0.9
    threshold: float = 2.5           # x EMA counts as straggling
    warmup: int = 3

    _ema: float = dataclasses.field(default=0.0, init=False)
    _n: int = dataclasses.field(default=0, init=False)
    events: List[dict] = dataclasses.field(default_factory=list, init=False)

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self._ema = dt if self._ema == 0 else \
                (self.ema_decay * self._ema + (1 - self.ema_decay) * dt)
            return False
        is_straggler = dt > self.threshold * self._ema
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "ema": self._ema,
                                "action": "flag+rebalance"})
        else:
            self._ema = self.ema_decay * self._ema + (1 - self.ema_decay) * dt
        return is_straggler


# ----------------------------------------------------------------------
# In-network aggregation tier (PR 4): straggler handling at the switch
# ----------------------------------------------------------------------

class SwitchStragglerTimeout(RuntimeError):
    """A child port kept missing the switch's aggregation window past the
    retransmit budget — the coordinator-level analogue of a dropped
    worker (the caller escalates to the recovery policy above)."""

    def __init__(self, port: int, window: int, delay_s: float,
                 max_retries: int):
        super().__init__(
            f"switch port {port} missed aggregation window {window} "
            f"({delay_s:.3f}s late) beyond {max_retries} retransmits")
        self.port = port
        self.window = window
        self.delay_s = delay_s


@dataclasses.dataclass
class SwitchRetransmitPolicy:
    """Timeout/retransmit policy a :class:`repro.net.switch.SwitchModel`
    applies per aggregation window.

    A switch cannot buffer a whole job's gradient: each streaming window
    holds its slot pool open until every child port's chunk arrives, so a
    straggling worker stalls the window. The standard mitigation (SwitchML
    -style) is a per-window timeout after which the switch re-requests the
    chunk. Semantics are **per window**: a chunk arriving ``delay_s``
    late costs ``ceil(delay_s / timeout_s) - 1`` retransmits (one per
    elapsed timeout period), and a port later than ``max_retries + 1``
    timeout periods *within one window* is declared failed
    (:class:`SwitchStragglerTimeout`); a port that is merely degraded —
    late but inside the budget every window — keeps paying retransmits
    indefinitely rather than escalating (cross-window escalation would
    be a coordinator policy, layered on the recorded events). The switch
    accounts the repeated bytes on that port's RX counter and records
    the event here, mirroring :class:`StragglerMonitor.events`.
    """

    timeout_s: float = 0.05
    max_retries: int = 2
    events: List[dict] = dataclasses.field(default_factory=list, init=False)

    def __post_init__(self):
        if self.timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0, got {self.timeout_s}")
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")

    def retries_for(self, delay_s: float) -> int:
        """Retransmits a chunk arriving ``delay_s`` after the window
        opens would cost (0 when it makes the first timeout)."""
        if delay_s <= self.timeout_s:
            return 0
        return int(np.ceil(delay_s / self.timeout_s)) - 1

    def on_window(self, window: int, port: int, delay_s: float,
                  chunk_bytes: int, shard: Optional[int] = None) -> int:
        """Account one (port, window) arrival; returns the retransmit
        count, raising :class:`SwitchStragglerTimeout` past the budget.
        ``shard``: optional shard tag recorded on the event (set by
        :class:`ShardRetransmitView`)."""
        retries = self.retries_for(delay_s)
        if retries > self.max_retries:
            raise SwitchStragglerTimeout(port, window, delay_s,
                                         self.max_retries)
        if retries:
            ev = {
                "window": window, "port": port, "delay_s": delay_s,
                "retries": retries, "retransmit_bytes": retries * chunk_bytes,
                "action": "timeout+retransmit"}
            if shard is not None:
                ev["shard"] = shard
            self.events.append(ev)
        return retries

    def shard_view(self, shard: int,
                   port_stride: int = 1 << 16) -> "ShardRetransmitView":
        """A per-shard namespaced view of this (shared) policy for the
        sharded fold pipeline: shard ``s``'s port ``p`` books as
        ``s * port_stride + p``, so per-shard slot pools never collide
        in the shared event log, and events carry a ``shard`` tag. The
        retry budget and timeout stay global — a client that is late is
        late on every shard's port."""
        return ShardRetransmitView(policy=self, shard=int(shard),
                                   port_stride=int(port_stride))


@dataclasses.dataclass(frozen=True)
class ShardRetransmitView:
    """Shard-scoped facade over a shared :class:`SwitchRetransmitPolicy`
    (see :meth:`SwitchRetransmitPolicy.shard_view`)."""

    policy: SwitchRetransmitPolicy
    shard: int
    port_stride: int = 1 << 16

    @property
    def timeout_s(self) -> float:
        return self.policy.timeout_s

    @property
    def max_retries(self) -> int:
        return self.policy.max_retries

    def retries_for(self, delay_s: float) -> int:
        return self.policy.retries_for(delay_s)

    def on_window(self, window: int, port: int, delay_s: float,
                  chunk_bytes: int) -> int:
        return self.policy.on_window(
            window, self.shard * self.port_stride + port, delay_s,
            chunk_bytes, shard=self.shard)


# ----------------------------------------------------------------------
# Elastic re-meshing
# ----------------------------------------------------------------------

def elastic_data_parallel(available_devices: int,
                          model_parallel: int) -> int:
    """Pure sizing rule behind :func:`elastic_mesh`: the data-axis size
    for a surviving device count.

    Keeps the model axis intact (parameter shards must stay complete)
    and shrinks the data axis to the largest power of two that fits —
    power-of-2 axes keep collectives regular. Unit-testable without any
    devices (non-divisible counts included); :func:`elastic_mesh` and
    ``repro.elastic.Membership.local_mesh`` both build on it.
    """
    if model_parallel < 1:
        raise ValueError(
            f"model_parallel must be >= 1, got {model_parallel}")
    if available_devices < model_parallel:
        raise ValueError(
            f"cannot keep model_parallel={model_parallel} with only "
            f"{available_devices} devices")
    data = available_devices // model_parallel
    # largest power-of-2 data axis keeps collectives regular
    while data & (data - 1):
        data -= 1
    return data


def elastic_mesh(available_devices: int, model_parallel: int,
                 axis_names=("data", "model")):
    """Largest (data, model) mesh fitting the surviving devices.

    Sizing is :func:`elastic_data_parallel`; the restored checkpoint is
    resharded onto the new mesh by ckpt.restore(shardings=…). Also the
    elastic tier's device-side sizing hook
    (``repro.elastic.Membership.local_mesh``) when a cohort maps onto
    local devices.
    """
    data = elastic_data_parallel(available_devices, model_parallel)
    devs = jax.devices()[: data * model_parallel]
    import numpy as _np
    arr = _np.array(devs).reshape(data, model_parallel)
    from jax.sharding import Mesh
    return Mesh(arr, axis_names)
