"""Fault tolerance: failure injection/detection, stragglers, elastic."""
from .failures import (FailureSimulator, InjectedFailure, RecoveryPolicy,
                       StragglerMonitor, SwitchRetransmitPolicy,
                       SwitchStragglerTimeout, elastic_data_parallel,
                       elastic_mesh)
__all__ = ["FailureSimulator", "InjectedFailure", "RecoveryPolicy",
           "StragglerMonitor", "SwitchRetransmitPolicy",
           "SwitchStragglerTimeout", "elastic_data_parallel",
           "elastic_mesh"]
