"""Emulated programmable aggregation switch (PR 4).

:class:`SwitchModel` is the *device model* of the in-network tier: a
single switch with ``ports`` children, a bounded pool of ``slots`` SRAM
aggregation slots, and the only two operations a programmable data plane
offers — 32-bit integer add and 32-bit OR. The in-mesh collective
analogue (what ``compressed_innet`` actually runs under jit) is the tree
schedule in :mod:`repro.net.topology`; this host-level model is what the
benchmarks, tests, and fault-tolerance scenarios drive to account for
what a real switch would do:

- **Bounded SRAM, streaming windows.** A job's sketch stream is far
  larger than switch SRAM (THC's core constraint). The stream arrives as
  per-bucket *chunks*; the switch opens a window of at most ``slots``
  chunks, aggregates every port's contribution into the resident slots,
  emits the reduced chunks upstream, and recycles the slots for the next
  window. ``report()["occupancy_peak"]`` is the high-water slot count —
  never above ``slots`` by construction.
- **Integer semantics only.** Chunk dtypes are enforced: int32 for the
  sketch (quantized through :mod:`repro.net.fixedpoint`), uint32 for the
  bitmap. Float chunks raise ``TypeError``. Register width is honest
  too: a window whose integer sum would exceed int32 raises
  ``OverflowError`` — unreachable when the stream was sized by
  :class:`repro.net.fixedpoint.FixedPointWire` for this port count,
  which is exactly the codec's contract.
- **Per-port counters.** RX bytes/chunks per child port, TX bytes of the
  broadcast back down, and the root-link bytes (the aggregated stream
  crosses the uplink once per direction, regardless of port count).
- **Straggler timeout/retransmit.** Optional per-chunk arrival times are
  checked against a :class:`repro.ft.failures.SwitchRetransmitPolicy`:
  late chunks cost retransmits (accounted on the port's RX counter and
  recorded on the policy), and a port later than the retry budget raises
  :class:`repro.ft.failures.SwitchStragglerTimeout`.

Port numbering is the worker's rank-major linear index over the DP axes
(:func:`repro.core.collectives.linear_rank`), matching the in-mesh tree.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.ft.failures import SwitchRetransmitPolicy

_INT32_MAX = np.int64(2**31 - 1)
_INT32_MIN = np.int64(-(2**31))


@dataclasses.dataclass
class PortCounters:
    """Per-child-port byte/chunk accounting (one aggregation run)."""
    rx_bytes: int = 0
    tx_bytes: int = 0
    rx_chunks: int = 0
    retransmits: int = 0


@dataclasses.dataclass
class SwitchModel:
    """One emulated aggregation switch (see module docstring)."""

    ports: int
    slots: int
    policy: Optional[SwitchRetransmitPolicy] = None

    def __post_init__(self):
        if self.ports < 1:
            raise ValueError(f"ports must be >= 1, got {self.ports}")
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        self.reset()

    def reset(self) -> None:
        self.port_counters: List[PortCounters] = [
            PortCounters() for _ in range(self.ports)]
        self.root_tx_bytes = 0      # aggregated stream up the root link
        self.root_rx_bytes = 0      # broadcast coming back down it
        self.windows = 0
        self.occupancy_peak = 0
        # Per-window log: (resident chunks, root-link bytes) per window,
        # in stream order — what the streamed in-mesh tree's static
        # accounting (Topology.window_profile) is pinned against.
        self.window_chunks: List[int] = []
        self.window_root_bytes: List[int] = []
        if self.policy is not None:
            self.policy.events.clear()  # counters and events are per run

    # ------------------------------------------------------------------

    @staticmethod
    def _check_chunks(name: str, a: np.ndarray, dtype, ports: int):
        if a.dtype != dtype:
            raise TypeError(
                f"{name} chunks must be {np.dtype(dtype).name} (a "
                f"programmable switch has 32-bit integer registers "
                f"only), got {a.dtype}; quantize the sketch through "
                "repro.net.fixedpoint.FixedPointWire")
        if a.ndim < 2 or a.shape[0] != ports:
            raise ValueError(
                f"{name} chunks must be (ports={ports}, n_chunks, ...), "
                f"got shape {a.shape}")

    def aggregate(self, sketch_chunks, bitmap_chunks,
                  arrival_s=None,
                  metadata_bytes: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """Stream ``(ports, n_chunks, ...)`` chunk arrays through the
        slot pool; returns the (int-summed sketch, OR'd bitmap) chunks.

        ``arrival_s``: optional per-port arrival delays in seconds,
        shaped ``(ports,)`` or ``(ports, n_chunks)``, measured from each
        window's open — fed to the straggler policy when one is set.

        ``metadata_bytes``: per-stream metadata riding the same links
        once per direction — e.g. the fxp32 shared-exponent vector
        (``n_buckets * 4`` bytes), which every child sends up and the
        broadcast carries back down. Counted on each port's RX/TX and on
        the root link so the switch report reconciles exactly with
        ``CompressionConfig.strategy_wire_bytes``'s tree accounting.
        """
        sk = np.asarray(sketch_chunks)
        bm = np.asarray(bitmap_chunks)
        self._check_chunks("sketch", sk, np.int32, self.ports)
        self._check_chunks("bitmap", bm, np.uint32, self.ports)
        if sk.shape[1] != bm.shape[1]:
            raise ValueError(
                f"sketch has {sk.shape[1]} chunks, bitmap {bm.shape[1]}")
        n_chunks = sk.shape[1]
        if arrival_s is not None:
            arrival_s = np.broadcast_to(
                np.asarray(arrival_s, np.float64).reshape(self.ports, -1),
                (self.ports, n_chunks))

        if metadata_bytes < 0:
            raise ValueError(
                f"metadata_bytes must be >= 0, got {metadata_bytes}")
        if metadata_bytes:
            for pc in self.port_counters:
                pc.rx_bytes += metadata_bytes
                pc.tx_bytes += metadata_bytes
            self.root_tx_bytes += metadata_bytes
            self.root_rx_bytes += metadata_bytes

        out_sk = np.zeros(sk.shape[1:], np.int32)
        out_bm = np.zeros(bm.shape[1:], np.uint32)
        for w0 in range(0, n_chunks, self.slots):
            w1 = min(w0 + self.slots, n_chunks)
            window = self.windows
            self.windows += 1
            self.occupancy_peak = max(self.occupancy_peak, w1 - w0)
            up_bytes = out_sk[w0:w1].nbytes + out_bm[w0:w1].nbytes
            self.window_chunks.append(w1 - w0)
            self.window_root_bytes.append(up_bytes)
            for p in range(self.ports):
                pc = self.port_counters[p]
                chunk_bytes = sk[p, w0:w1].nbytes + bm[p, w0:w1].nbytes
                retries = 0
                if self.policy is not None and arrival_s is not None:
                    retries = self.policy.on_window(
                        window, p, float(arrival_s[p, w0:w1].max()),
                        chunk_bytes)
                pc.rx_bytes += chunk_bytes * (1 + retries)
                pc.rx_chunks += w1 - w0
                pc.retransmits += retries
                pc.tx_bytes += up_bytes       # broadcast back down
            # A real switch accumulates port by port, so every *running*
            # partial sum must fit the 32-bit register, not just the
            # final one. (FixedPointWire-sized streams satisfy this for
            # any port subset: |partial| <= W * 2^M <= 2^30.)
            partials = np.cumsum(sk[:, w0:w1].astype(np.int64), axis=0)
            if partials.size and (partials.max(initial=0) > _INT32_MAX
                                  or partials.min(initial=0) < _INT32_MIN):
                raise OverflowError(
                    f"window {window}: a running {self.ports}-port sum "
                    "overflows a 32-bit switch register — the stream was "
                    "not sized by FixedPointWire for this port count")
            out_sk[w0:w1] = partials[-1].astype(np.int32)
            out_bm[w0:w1] = np.bitwise_or.reduce(bm[:, w0:w1], axis=0)
            self.root_tx_bytes += up_bytes
            self.root_rx_bytes += up_bytes
        return out_sk, out_bm

    # ------------------------------------------------------------------
    # Batched folds (PR 10 sharded fold pipeline)
    # ------------------------------------------------------------------

    def check_batched_partial(self, partial_max: int, partial_min: int,
                              ports: Optional[int] = None,
                              window: int = 0) -> None:
        """Register-width check for a *batched* fold whose arithmetic
        ran outside the switch (the sharded fold pipeline's jit-cached
        combine): the caller hands the int64 running-partial extrema of
        ``[resident accumulator; payload 1; ...; payload k]`` and this
        raises the exact :class:`OverflowError` the streaming
        :meth:`aggregate` raises when a port-by-port sum leaves int32.

        The semantics restate the sequential proof for batched
        partials: a microbatch of ``k`` payloads on a wire sized by
        :class:`repro.net.fixedpoint.FixedPointWire` for ``W`` workers
        is safe iff the round still has ``k`` contributions of
        headroom, because every client-order prefix sum is then bounded
        by ``W * 2^mantissa_bits <= 2^30`` — the same bound the
        one-payload-at-a-time walk relies on.
        """
        ports = self.ports if ports is None else int(ports)
        if int(partial_max) > int(_INT32_MAX) or \
                int(partial_min) < int(_INT32_MIN):
            raise OverflowError(
                f"window {window}: a running {ports}-port sum "
                "overflows a 32-bit switch register — the stream was "
                "not sized by FixedPointWire for this port count")

    def account_batched_fold(self, n_chunks: int, k_ports: int,
                             port_bytes: int, chunk_bytes: int) -> None:
        """Slot-pool accounting for one batched fold pass: ``k_ports``
        arriving payload streams of ``n_chunks`` bucket chunks folded
        into the resident accumulator through this pool's windows in a
        single vectorized combine. Windows/occupancy walk the same
        ``slots``-bounded grid the streaming :meth:`aggregate` does —
        but ONCE for the whole microbatch, which is the batched
        pipeline's amortization — and the per-port counters book each
        arriving stream's ``port_bytes`` as RX on the ingest port plus
        the reduced stream's TX back down.
        """
        if n_chunks < 1 or k_ports < 1:
            raise ValueError(
                f"need n_chunks >= 1 and k_ports >= 1, got "
                f"{n_chunks}/{k_ports}")
        up_total = 0
        for w0 in range(0, n_chunks, self.slots):
            w1 = min(w0 + self.slots, n_chunks)
            self.windows += 1
            self.occupancy_peak = max(self.occupancy_peak, w1 - w0)
            up = (w1 - w0) * chunk_bytes
            self.window_chunks.append(w1 - w0)
            self.window_root_bytes.append(up)
            up_total += up
        ingest = self.port_counters[-1]
        ingest.rx_bytes += k_ports * port_bytes
        ingest.rx_chunks += k_ports * n_chunks
        ingest.tx_bytes += up_total
        self.root_tx_bytes += up_total
        self.root_rx_bytes += up_total

    # ------------------------------------------------------------------

    def report(self) -> Dict[str, object]:
        return {
            "ports": self.ports,
            "slots": self.slots,
            "windows": self.windows,
            "occupancy_peak": self.occupancy_peak,
            "window_chunks": tuple(self.window_chunks),
            "window_root_bytes": tuple(self.window_root_bytes),
            "root_link_tx_bytes": self.root_tx_bytes,
            "root_link_rx_bytes": self.root_rx_bytes,
            "per_port": [dataclasses.asdict(pc) for pc in self.port_counters],
            "retransmit_events": (list(self.policy.events)
                                  if self.policy is not None else []),
        }
