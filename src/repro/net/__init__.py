"""Emulated in-network aggregation tier (PR 4).

The paper's headline deployment claim is that the sketch+bitmap stream
is *homomorphic*: aggregation can happen inside the network, a switch
summing sketches with integer adds and OR-ing bitmaps, never
decompressing. This package closes that architectural gap for the
reproduction, in three layers that share one wire contract:

- :mod:`repro.net.fixedpoint` — the wire codec: per-bucket
  shared-exponent int32 quantization of the f32 sketch, sized so a
  ``W``-worker sum can never overflow a 32-bit switch register (the
  bitmap is already switch-native uint32 OR).
- :mod:`repro.net.topology` — worker -> ToR -> spine reduction trees
  mapped onto mesh axes, with the in-mesh collective analogue
  (``tree_all_reduce``: ppermute reduce-to-root + broadcast, integer
  add / OR only) and the per-link wire model.
- :mod:`repro.net.switch` — the device model: a ``SwitchModel`` with a
  bounded SRAM slot pool, streaming window aggregation of bucket
  chunks, per-port byte/occupancy counters, and straggler
  timeout/retransmit via :class:`repro.ft.failures.SwitchRetransmitPolicy`.

The training-path consumer is the ``compressed_innet`` strategy in
:mod:`repro.core.aggregators` (select with ``tc.aggregator``, configure
with ``CompressionConfig.wire_dtype/switch_slots/topology``); the
benchmark arm is ``benchmarks/aggregation.py --compare-innet``, which
also drives the ``SwitchModel`` over the same streams and pins it
bit-for-bit against the in-mesh result.
"""

from .fixedpoint import FixedPointWire, ceil_log2, pow2
from .switch import PortCounters, SwitchModel
from .topology import (TOPOLOGIES, Topology, broadcast_from_root,
                       make_topology, reduce_to_root, tree_all_reduce)

__all__ = [
    "FixedPointWire", "ceil_log2", "pow2",
    "PortCounters", "SwitchModel",
    "TOPOLOGIES", "Topology", "broadcast_from_root", "make_topology",
    "reduce_to_root", "tree_all_reduce",
]
