"""Reduction-tree topologies for the in-network aggregation tier (PR 4).

The paper's deployment story puts aggregation *in the network*: workers
send their sketch+bitmap up a worker -> ToR -> spine tree once, switches
combine (integer add / OR) as the stream passes, and the root broadcasts
the aggregate back down. This module maps that tree onto the mesh axes
the repo already reduces over, and provides the collective analogue —
a reduce-to-root + broadcast schedule built from ``jax.lax.ppermute``
binary trees, one level per mesh axis.

Semantics are deliberately restricted to what a programmable switch can
do: :func:`tree_all_reduce` combines with **integer add or bitwise OR
only** and rejects float operands — the float sketch must go through the
fixed-point wire first (:mod:`repro.net.fixedpoint`). Because integer
adds and ORs are exactly associative/commutative, the tree result is
bit-identical to a flat ``psum`` / OR-AllReduce of the same operands,
which is also the fallback wire on JAX legs whose partitioner cannot run
``ppermute`` in the calling region (same gating as the reduce-scatter
wire — ``compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE``, or a full-manual
caller).

Chunk/port ordering follows :func:`repro.core.collectives.linear_rank`:
worker *w*'s switch port is its rank-major linear index over the DP
axes, so the emulated :class:`repro.net.switch.SwitchModel` and the
in-mesh schedule agree on which payload arrives where.

Wire model (per direction; ``P`` = sketch+index payload bytes): every
worker sends ``P`` once up its access link and receives ``P`` once back
— against the ring AllReduce's ``2(W-1)/W * P`` per link. A level-``i``
switch ingests ``fanout_i * P`` across its child ports but forwards
only the aggregated ``P`` up, so the *root* link carries ``P`` per
direction no matter how many workers hang below it (``P/fanout`` per
child, amortized). :meth:`Topology.link_profile` reports these numbers;
:meth:`repro.core.config.CompressionConfig.strategy_wire_bytes` folds
them into the per-strategy accounting.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.collectives import _check_axis_indices, or_allreduce

from .fixedpoint import ceil_log2

TOPOLOGIES = ("flat", "tor_spine")


@dataclasses.dataclass(frozen=True)
class Topology:
    """A reduction tree mapped onto (manual) mesh axes.

    ``levels`` are the per-level mesh axes in leaf-to-root order
    (innermost axis first: workers under one ToR are ICI-near). The
    ppermute schedule is identical for every kind — the kind only
    changes how the physical tree is *accounted*: ``flat`` models one
    big switch with ``workers`` ports, ``tor_spine`` one switch tier
    per level.
    """

    kind: str
    levels: Tuple[str, ...]
    sizes: Tuple[int, ...]

    @property
    def workers(self) -> int:
        w = 1
        for s in self.sizes:
            w *= s
        return w

    @property
    def fanouts(self) -> Tuple[int, ...]:
        """Children per switch, leaf tier first."""
        if self.kind == "flat":
            return (self.workers,)
        return self.sizes

    @property
    def depth(self) -> int:
        return len(self.fanouts)

    def switches_per_level(self) -> Tuple[int, ...]:
        """How many switches each tier has (leaf tier first)."""
        out, below = [], 1
        for f in self.fanouts:
            below *= f
            out.append(self.workers // below)
        return tuple(out)

    def link_profile(self, payload_bytes: int) -> Dict[str, object]:
        """Per-direction byte loads of one aggregation round (see module
        docstring). ``switch_ingress_bytes`` is per switch, per tier."""
        if self.workers == 1:
            return {"worker_link_bytes": 0, "root_link_bytes": 0,
                    "switch_ingress_bytes": (0,) * self.depth}
        return {
            "worker_link_bytes": payload_bytes,
            "root_link_bytes": payload_bytes,
            "switch_ingress_bytes": tuple(
                f * payload_bytes for f in self.fanouts),
        }

    def window_profile(self, chunk_bytes: int, n_chunks: int,
                       slots: int) -> Dict[str, object]:
        """Per-window wire accounting of the *streamed* tree (PR 5).

        The collective schedule reduces the stream in windows of at most
        ``slots`` bucket chunks (``tree_all_reduce(...,
        window_slots=slots)``), exactly as the emulated
        :class:`repro.net.switch.SwitchModel` streams its bounded SRAM
        slot pool — this static profile and the switch's runtime
        ``report()`` must agree window for window (``windows``,
        ``occupancy_peak``, ``window_chunks``, ``window_root_bytes``,
        and the per-direction root-link total), which the tests pin.
        ``chunk_bytes``: wire bytes of one chunk (int32 sketch + uint32
        bitmap words for one bucket on the fxp32 wire).
        """
        if chunk_bytes < 0 or n_chunks < 0:
            raise ValueError("chunk_bytes/n_chunks must be >= 0")
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        window_chunks = tuple(min(slots, n_chunks - w0)
                              for w0 in range(0, n_chunks, slots))
        return {
            "windows": len(window_chunks),
            "occupancy_peak": max(window_chunks, default=0),
            "window_chunks": window_chunks,
            "window_root_bytes": tuple(c * chunk_bytes
                                       for c in window_chunks),
            "root_link_bytes": n_chunks * chunk_bytes,
        }


def make_topology(kind: str, mesh, dp_axes: Sequence[str]) -> Topology:
    """Map ``kind`` onto the mesh's DP axes.

    ``flat``: one switch tier with all ``W`` workers as ports (any
    number of DP axes). ``tor_spine``: one tier per DP axis — needs at
    least two axes so there is a ToR level *and* a spine level; the
    innermost axis is the ToR fanout (ICI-near workers share a ToR), the
    outermost the spine fanout.
    """
    if isinstance(dp_axes, str):
        dp_axes = (dp_axes,)
    dp_axes = tuple(dp_axes)
    if kind not in TOPOLOGIES:
        raise ValueError(f"unknown topology {kind!r}; have {TOPOLOGIES}")
    if not dp_axes:
        raise ValueError("topology needs at least one DP axis")
    missing = [a for a in dp_axes if a not in mesh.shape]
    if missing:
        raise ValueError(f"mesh has no axes {missing}")
    if kind == "tor_spine" and len(dp_axes) < 2:
        raise ValueError(
            "topology='tor_spine' needs >= 2 DP axes (one for the ToR "
            f"tier, one for the spine), got {dp_axes}; use 'flat' for a "
            "single-axis mesh")
    levels = tuple(reversed(dp_axes))  # innermost (ICI-near) tier first
    return Topology(kind=kind, levels=levels,
                    sizes=tuple(mesh.shape[a] for a in levels))


# ----------------------------------------------------------------------
# ppermute tree schedules (manual collectives)
# ----------------------------------------------------------------------

def _combine_fn(combine: str, dtype):
    if combine == "add":
        if not jnp.issubdtype(dtype, jnp.integer):
            raise TypeError(
                "tree_all_reduce combines with integer adds only (switch "
                f"register semantics); got {dtype}. Quantize the sketch "
                "through repro.net.fixedpoint.FixedPointWire first.")
        return lambda a, b: a + b
    if combine == "or":
        if not jnp.issubdtype(dtype, jnp.unsignedinteger):
            raise TypeError(
                f"tree_all_reduce 'or' needs unsigned words, got {dtype}")
        return lambda a, b: a | b
    raise ValueError(f"combine must be 'add' or 'or', got {combine!r}")


def reduce_to_root(x: jnp.ndarray, axis_name: str, combine: str,
                   idx: jnp.ndarray | None = None) -> jnp.ndarray:
    """Binary-tree reduction to rank 0 of ``axis_name``: ceil(log2 n)
    ppermute steps, child ``r + d`` sending its subtotal to ``r``.
    Non-root ranks end with stale partials (a broadcast overwrites
    them). Works for any axis size, power of two or not.

    ``idx`` is accepted for signature symmetry with the broadcast (the
    reduction itself needs no rank test: a rank not targeted by a step
    receives zeros, the identity of both combiners).
    """
    del idx
    n = compat.axis_size(axis_name)
    comb = _combine_fn(combine, x.dtype)
    d = 1
    while d < n:
        pairs = [(i, i - d) for i in range(d, n, 2 * d)]
        x = comb(x, jax.lax.ppermute(x, axis_name, pairs))
        d *= 2
    return x


def broadcast_from_root(x: jnp.ndarray, axis_name: str,
                        idx: jnp.ndarray | None = None) -> jnp.ndarray:
    """Inverse tree: rank 0's value reaches every rank of ``axis_name``
    in ceil(log2 n) ppermute steps. ``idx``: this shard's index on the
    axis — pass it when calling from a nested region (see
    :func:`repro.core.collectives.or_allreduce_ring`)."""
    n = compat.axis_size(axis_name)
    if n == 1:
        return x
    if idx is None:
        idx = jax.lax.axis_index(axis_name)
    d = 1 << (ceil_log2(n) - 1)
    while d >= 1:
        pairs = [(i - d, i) for i in range(d, n, 2 * d)]
        recv = jax.lax.ppermute(x, axis_name, pairs)
        x = jnp.where(idx % (2 * d) == d, recv, x)
        d //= 2
    return x


def tree_all_reduce(x: jnp.ndarray, topo: Topology, combine: str,
                    axis_indices: Optional[dict] = None,
                    use_ppermute: Optional[bool] = None,
                    window_slots: Optional[int] = None) -> jnp.ndarray:
    """Reduce-to-root + broadcast over the topology's levels.

    The in-mesh analogue of in-network aggregation: each level's axis is
    reduced to its rank-0 "switch", the root holds the full aggregate,
    and the broadcast pushes it back down the same tree. ``combine`` is
    ``"add"`` (integer) or ``"or"`` (uint32) — float operands raise (a
    switch cannot sum floats; see :mod:`repro.net.fixedpoint`).

    Because both combiners are exact, the result is bit-identical to the
    flat collective over the same axes — which is also the fallback when
    ``ppermute`` is unsupported in the calling region (``use_ppermute``
    mirrors :func:`repro.core.collectives.or_reduce_scatter`: ``None``
    follows ``compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE``; full-manual
    callers on 0.4.x should pass True).

    ``window_slots`` is the windowed mode (PR 5): the leading dim of
    ``x`` is a stream of chunks (e.g. buckets) and the tree reduces at
    most ``window_slots`` of them per round, window by window, exactly
    as a real switch streams its bounded SRAM slot pool
    (:class:`repro.net.switch.SwitchModel`; per-window traffic in
    :meth:`Topology.window_profile`). Bit-identical to the one-shot
    reduction — windowing only splits the schedule.

    ``axis_indices``: {axis: this shard's index} — required complete (or
    None), as in :func:`repro.core.collectives.or_allreduce`.
    """
    _check_axis_indices(topo.levels, axis_indices)
    if combine not in ("add", "or"):
        raise ValueError(f"combine must be 'add' or 'or', got {combine!r}")
    _combine_fn(combine, x.dtype)  # dtype gate even on the fallback wire
    if window_slots is not None:
        if window_slots < 1:
            raise ValueError(
                f"window_slots must be >= 1, got {window_slots}")
        n = x.shape[0]
        if n > window_slots:
            parts = [
                tree_all_reduce(x[w0:w0 + window_slots], topo, combine,
                                axis_indices=axis_indices,
                                use_ppermute=use_ppermute)
                for w0 in range(0, n, window_slots)]
            return jnp.concatenate(parts, axis=0)
    if use_ppermute is None:
        use_ppermute = compat.SUPPORTS_PARTIAL_AUTO_PPERMUTE
    if not use_ppermute:
        if combine == "add":
            return jax.lax.psum(x, tuple(topo.levels))
        # or_allreduce reduces its axis tuple innermost-first; levels are
        # already innermost-first, so hand it the reversed (outer-first)
        # spelling it expects.
        return or_allreduce(x, tuple(reversed(topo.levels)),
                            axis_indices=axis_indices)
    for ax in topo.levels:
        idx = axis_indices[ax] if axis_indices else None
        x = reduce_to_root(x, ax, combine, idx=idx)
    for ax in reversed(topo.levels):
        idx = axis_indices[ax] if axis_indices else None
        x = broadcast_from_root(x, ax, idx=idx)
    return x
