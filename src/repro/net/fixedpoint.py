"""Fixed-point homomorphic wire codec for the sketch (PR 4).

A programmable switch aggregates with *integer adds on bounded-width
registers* (SwitchML, ATP; THC makes the same point for compressed
streams): it cannot sum float32 sketch cells. The bitmap half of the
paper's wire format is already switch-native (uint32 OR), but the f32
sketch needs an integer representation whose sums are meaningful — this
module is that representation.

Scheme — per-bucket shared-exponent fixed point:

- The fused sketch stream is viewed per aggregation *bucket*
  (:class:`repro.core.bucketing.BucketPlan`), ``(n_buckets, K)`` with
  ``K = blocks_per_bucket * rows * lanes`` cells.
- Every worker derives a per-bucket exponent from its local slice
  (:meth:`FixedPointWire.bucket_exponents`) and the aggregation tier
  takes the elementwise **max** across workers (a 4-byte-per-bucket
  metadata reduction — ``jax.lax.pmax`` in-mesh, a max over ports on
  the emulated switch). All workers then quantize against the *same*
  scale, which is what makes the integer sums homomorphic.
- ``encode``: ``q = rint(y * 2^(M - e))`` as int32, where ``M =
  mantissa_bits`` and ``e`` is the shared exponent of the bucket's
  global max-magnitude cell.
- ``decode``: ``float32(q) * 2^(e - M)``.

Overflow-freedom by construction: ``frexp`` gives ``max|y| < 2^e``, so
every quantized cell satisfies ``|q| <= 2^M``. With ``M = 30 -
ceil_log2(W)`` a sum over ``W`` workers is bounded by ``W * 2^M <=
2^30 < 2^31`` — no int32 add in the tree (or in a 32-bit switch
register) can overflow, for any input values.

Documented roundtrip (what the ``compressed_innet`` aggregator must
reproduce exactly, and what the tests pin): aggregating worker sketches
``y_w`` over this wire yields

    decode(sum_w encode(y_w, e), e)   with   e = max_w exponents(y_w)

where the integer sum is exact (order-free), so the only inexact steps
are the two documented roundings: ``rint`` at encode, and the
float32 cast of the summed integer at decode (exact when the sum fits
24 mantissa bits — in particular, dyadic test values are round-tripped
bit-exactly). Scales are powers of two built by exponent-field bit
manipulation (:func:`pow2`), never ``exp2``/``ldexp``, so the scaling
itself is always exact.

Exponents are clamped to ``>= M - 126`` so the encode scale ``2^(M-e)``
stays a normal float32: buckets whose global max magnitude is below
``2^(M-126)`` (~1e-29 at W=2) quantize with a capped scale, losing only
values below float32's own normal range.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp


def ceil_log2(n: int) -> int:
    """Smallest k with 2**k >= n (n >= 1)."""
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    return int(n - 1).bit_length()


def pow2(k: jnp.ndarray) -> jnp.ndarray:
    """Exact float32 ``2**k`` for int32 ``k`` in [-126, 127].

    Built by writing the biased exponent field directly —
    ``exp2``/``ldexp`` are transcendental-lowered on some backends and
    not guaranteed bit-exact, which would break the codec's homomorphism
    contract.
    """
    k = jnp.asarray(k, jnp.int32)
    return jax.lax.bitcast_convert_type((k + 127) << 23, jnp.float32)


@dataclasses.dataclass(frozen=True)
class FixedPointWire:
    """Shared-exponent int32 wire for ``workers``-way sketch sums."""

    workers: int

    def __post_init__(self):
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")
        if self.mantissa_bits < 2:
            raise ValueError(
                f"workers={self.workers} leaves {self.mantissa_bits} "
                "mantissa bits; the fixed-point wire needs at least 2")

    # ---- static geometry ---------------------------------------------

    def with_workers(self, workers: int) -> "FixedPointWire":
        """The same wire re-priced for a different cohort size.

        This is the elastic tier's renegotiation seam: the mantissa
        budget is W-dependent (``30 - ceil_log2(W)``), so crossing a
        power-of-two cohort boundary (e.g. W=4 -> 5) *changes the wire*
        — payloads quantized under the old budget decode mis-scaled by
        an exact power of two and void the overflow-freedom proof.
        Callers must never mix budgets: the elastic
        :class:`repro.elastic.membership.RoundContract` carries the
        budget per round and rejects stale payloads outright.
        """
        return dataclasses.replace(self, workers=workers)

    @property
    def headroom_bits(self) -> int:
        """Bits reserved so W-worker sums cannot overflow int32."""
        return ceil_log2(self.workers)

    @property
    def mantissa_bits(self) -> int:
        """M — value bits per worker: |q| <= 2^M, W*2^M <= 2^30."""
        return 30 - self.headroom_bits

    @property
    def min_exponent(self) -> int:
        """Exponent floor keeping the encode scale 2^(M-e) normal."""
        return self.mantissa_bits - 126

    # ---- codec --------------------------------------------------------

    def exponents_from_maxabs(self, maxabs: jnp.ndarray) -> jnp.ndarray:
        """Exponents from precomputed per-bucket max magnitudes.

        ``max`` is exact, so a max-of-maxes over any partition of a
        bucket (e.g. the per-block ``maxabs`` the fused wire-codec
        producer kernel emits as a byproduct) equals the direct bucket
        max — this entry point lets the aggregator derive bit-identical
        exponents without a second pass over the sketch.
        """
        maxabs = jnp.asarray(maxabs, jnp.float32)
        _, e = jnp.frexp(maxabs)
        e = jnp.where(maxabs == 0, jnp.int32(self.min_exponent),
                      e.astype(jnp.int32))
        return jnp.maximum(e, jnp.int32(self.min_exponent))

    def bucket_exponents(self, buckets: jnp.ndarray) -> jnp.ndarray:
        """Per-bucket exponent of this worker's slice: ``(nb, K) -> (nb,)``.

        ``frexp`` semantics: ``max|y| < 2^e``, clamped to
        :attr:`min_exponent`. An all-zero slice reports
        :attr:`min_exponent` (NOT frexp's ``e = 0``): with top-k
        sparsification a worker's slice of a bucket is often entirely
        zero, and letting it report 0 would dominate the cross-worker
        ``pmax`` and inflate the shared quantization step for every
        bucket whose true global max is below 1.0. Aggregate across
        workers with an elementwise max before encoding.
        """
        return self.exponents_from_maxabs(
            jnp.max(jnp.abs(buckets.astype(jnp.float32)), axis=-1))

    def shared_exponents(self, buckets: jnp.ndarray,
                         dp_axes: Sequence[str]) -> jnp.ndarray:
        """Globally-agreed per-bucket exponents, inside ``shard_map``."""
        return jax.lax.pmax(self.bucket_exponents(buckets), tuple(dp_axes))

    def encode(self, buckets: jnp.ndarray,
               exponents: jnp.ndarray) -> jnp.ndarray:
        """``(nb, K) f32 -> (nb, K) int32`` against shared exponents."""
        scale = pow2(self.mantissa_bits - exponents)[..., None]
        return jnp.rint(buckets.astype(jnp.float32) * scale
                        ).astype(jnp.int32)

    def decode(self, q: jnp.ndarray, exponents: jnp.ndarray) -> jnp.ndarray:
        """``(nb, K) int32 (summed) -> (nb, K) f32``."""
        scale = pow2(exponents - self.mantissa_bits)[..., None]
        return q.astype(jnp.float32) * scale

    # ---- reference ----------------------------------------------------

    def roundtrip_reference(self, worker_buckets) -> jnp.ndarray:
        """The documented aggregate: shared-exponent quantize every
        worker's ``(nb, K)`` sketch slice, integer-sum, dequantize.

        This is the ground truth the ``compressed_innet`` aggregator's
        fxp32 wire must match bit-for-bit (the in-mesh tree computes the
        same integer sum, which is exact in any association order).
        """
        worker_buckets = [jnp.asarray(b, jnp.float32) for b in worker_buckets]
        if len(worker_buckets) > self.workers:
            raise ValueError(
                f"{len(worker_buckets)} summands on a wire sized for "
                f"{self.workers} workers (overflow bound would not hold)")
        e = self.bucket_exponents(worker_buckets[0])
        for b in worker_buckets[1:]:
            e = jnp.maximum(e, self.bucket_exponents(b))
        q = self.encode(worker_buckets[0], e)
        for b in worker_buckets[1:]:
            q = q + self.encode(b, e)
        return self.decode(q, e)
