"""Pallas TPU kernels for the paper's compute hot spots (§3.4):
Count-Sketch encode, parallel-peeling decode, and (PR 7) the fused
wire-codec producer/consumer — one VMEM pass from gradients to wire
payload and back. Validated in interpret mode against the pure-jnp
oracles in ref.py."""

from .ops import (sketch_encode, sketch_peel, encode_pack_quantize,
                  dequant_peel_unpack, fused_wire_supported,
                  wire_codec_passes)
from .sketch_encode import sketch_encode_pallas
from .sketch_peel import sketch_peel_pallas
from .sketch_wire import (encode_pack_quantize_pallas,
                          dequant_peel_unpack_pallas)
from . import ref

__all__ = ["sketch_encode", "sketch_peel", "encode_pack_quantize",
           "dequant_peel_unpack", "fused_wire_supported",
           "wire_codec_passes", "sketch_encode_pallas",
           "sketch_peel_pallas", "encode_pack_quantize_pallas",
           "dequant_peel_unpack_pallas", "ref"]
