"""Pallas TPU kernels for the paper's compute hot spots (§3.4):
Count-Sketch encode and parallel-peeling decode. Validated in
interpret mode against the pure-jnp oracles in ref.py."""

from .ops import sketch_encode, sketch_peel
from .sketch_encode import sketch_encode_pallas
from .sketch_peel import sketch_peel_pallas
from . import ref

__all__ = ["sketch_encode", "sketch_peel", "sketch_encode_pallas",
           "sketch_peel_pallas", "ref"]
