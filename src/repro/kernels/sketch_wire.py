"""Fused Pallas wire-codec kernels: one VMEM pass per wire direction (PR 7).

ROADMAP open item 3 (fusion half): the compressed hot path used to make
separate passes over the bucket stream — sketch-encode, then bitmap-pack,
then (fxp32) quantize on the send side; dequant, peel, residual-unpack on
the receive side. Each pass re-reads the stream from HBM, and at the
smoke-benchmark sizes that codec compute — not link bytes — dominates
wall time (the regime "On the Utility of Gradient Compression" warns
about, and the one THC's low-overhead codec discipline targets).

This module fuses each trio into ONE `pallas_call` grid pass:

- **producer** (`encode_pack_quantize_pallas`): gradient blocks HBM→VMEM
  once; each grid cell runs the shared :func:`encode_tile` contraction,
  packs the tile's non-zero bitmap into uint32 words *in VMEM*, reduces
  the per-block max magnitude (the fxp32 exponent ingredient — a free
  byproduct of the tile already being resident), and optionally applies
  the shared-exponent int32 quantization before the sketch ever reaches
  HBM. Wire payload out, gradients in, one pass.
- **consumer** (`dequant_peel_unpack_pallas`): wire payload HBM→VMEM
  once; each cell unpacks its bitmap words, optionally dequantizes the
  int32 sketch by exponent-field bitcast (:func:`repro.net.fixedpoint.pow2`
  — exact powers of two, never `exp2`), and runs the shared
  :func:`peel_tile` loop to recovered values + int8 residual.

Both kernels *reuse the exact tile cores* of the unfused kernels
(`encode_tile` / `peel_tile`) and the exact word ordering of
`core/index.pack_bits`, so bit-for-bit parity with the composed path is
structural: there is one implementation of the math, fused and unfused
paths differ only in how many times the stream crosses HBM.

Packing constraint: the bitmap is packed per block, so the pack-word
boundary must align with the block boundary — `block_elems % 32 == 0`
(`repro.kernels.ops.fused_wire_supported`). `bucket_quantum =
lcm(block_elems, 32)` makes default geometries satisfy this; the ops
layer falls back to the composed reference otherwise.

The fxp32 quantize leg takes *precomputed* exponents: deriving shared
exponents needs a cross-worker `pmax`, a collective that cannot live
inside a single-device kernel. The aggregator therefore runs the
producer unquantized (emitting `maxabs`), pmaxes the 4 B/bucket exponent
metadata, then quantizes the (stream-size/Γ) sketch — the *bucket
stream* is still read exactly once. The quantized producer leg exists
for known-exponent callers and parity tests; the dequant consumer leg is
always fused (exponents ride the wire).

VMEM adds over the unfused kernels are small: the packed words tile is
`B * block_elems/32 * 4` bytes (1/32 of the x tile) and maxabs is
`B * 4` bytes; budgets stay as documented in `sketch_encode.py` /
`sketch_peel.py`.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.config import CompressionConfig
from repro.core import hashing
from repro.net.fixedpoint import pow2
from .sketch_encode import encode_tile, _plan_matrix
from .sketch_peel import peel_tile


def _pack_tile_bits(x, cfg: CompressionConfig):
    """(B, G, c) values -> (B, wpb) uint32 packed non-zero bitmap.

    Bit order matches :func:`repro.core.index.pack_bits` on the
    flattened block exactly: word w, bit k covers flat element
    ``w * 32 + k`` of the block — so per-block words, flattened across
    blocks, are bit-identical to the global pack (requires
    ``block_elems % 32 == 0``).
    """
    B = x.shape[0]
    wpb = cfg.block_elems // 32
    bits = (x != 0).reshape(B, wpb, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts[None, None, :], axis=-1)


def _unpack_tile_bits(words, cfg: CompressionConfig):
    """(B, wpb) uint32 -> (B, G, c) bool — inverse of `_pack_tile_bits`."""
    B = words.shape[0]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(B, cfg.group, cfg.lanes) != 0


def _wire_encode_kernel(ids_ref, plan_ref, x_ref, sk_ref, w_ref, mx_ref, *,
                        cfg: CompressionConfig):
    ids = ids_ref[...][:, 0]                                          # (B,)
    x = x_ref[...]
    sk_ref[...] = encode_tile(ids, plan_ref[...], x, cfg)
    w_ref[...] = _pack_tile_bits(x, cfg)
    mx_ref[...] = jnp.max(jnp.abs(sk_ref[...]), axis=(1, 2))[:, None]


def _wire_encode_q_kernel(ids_ref, plan_ref, x_ref, exp_ref,
                          sk_ref, w_ref, mx_ref, *,
                          cfg: CompressionConfig, mantissa_bits: int):
    ids = ids_ref[...][:, 0]
    x = x_ref[...]
    acc = encode_tile(ids, plan_ref[...], x, cfg)                     # f32
    w_ref[...] = _pack_tile_bits(x, cfg)
    mx_ref[...] = jnp.max(jnp.abs(acc), axis=(1, 2))[:, None]
    scale = pow2(mantissa_bits - exp_ref[...][:, 0])                  # (B,)
    sk_ref[...] = jnp.rint(acc * scale[:, None, None]).astype(jnp.int32)


def _wire_peel_kernel(ids_ref, rows_ref, signs_ref, y_ref, w_ref,
                      xo_ref, ro_ref, *, cfg: CompressionConfig):
    ids = ids_ref[...][:, 0]
    b = _unpack_tile_bits(w_ref[...], cfg)
    values, residual = peel_tile(ids, rows_ref[:, 0], signs_ref[...],
                                 y_ref[...], b, cfg)
    xo_ref[...] = values
    ro_ref[...] = residual.astype(jnp.int8)


def _wire_peel_dq_kernel(ids_ref, rows_ref, signs_ref, y_ref, w_ref, exp_ref,
                         xo_ref, ro_ref, *,
                         cfg: CompressionConfig, mantissa_bits: int):
    ids = ids_ref[...][:, 0]
    b = _unpack_tile_bits(w_ref[...], cfg)
    scale = pow2(exp_ref[...][:, 0] - mantissa_bits)                  # (B,)
    y = y_ref[...].astype(jnp.float32) * scale[:, None, None]
    values, residual = peel_tile(ids, rows_ref[:, 0], signs_ref[...],
                                 y, b, cfg)
    xo_ref[...] = values
    ro_ref[...] = residual.astype(jnp.int8)


def _pad_blocks(arrays, pads1d, nb, padded):
    """Zero-pad leading (block) dim from nb to padded."""
    if padded == nb:
        return list(arrays) + list(pads1d)
    out = [jnp.pad(a, ((0, padded - nb),) + ((0, 0),) * (a.ndim - 1))
           for a in arrays]
    out += [jnp.pad(p, (0, padded - nb)) for p in pads1d]
    return out


def encode_pack_quantize_pallas(xb: jnp.ndarray, block_ids: jnp.ndarray,
                                cfg: CompressionConfig,
                                exponents: jnp.ndarray | None = None,
                                mantissa_bits: int | None = None,
                                interpret: bool = True):
    """Fused producer: (nb, G, c) values + (nb,) ids ->
    (sketch (nb, rows, c) f32|int32, words (nb, wpb) uint32,
    maxabs (nb,) f32) in one grid pass.

    With ``exponents`` (per-block int32) + ``mantissa_bits`` the sketch
    leaves the kernel fxp32-quantized; ``maxabs`` is always the
    *pre-quantize* f32 per-block max (the exponent ingredient).
    """
    nb = xb.shape[0]
    quantize = exponents is not None
    wpb = cfg.block_elems // 32
    tile = max(1, min(cfg.encode_block_tile, nb))
    padded = -(-nb // tile) * tile
    if quantize:
        # Padding exponent 0 only scales padded all-zero blocks: harmless.
        xb, block_ids, exponents = _pad_blocks(
            [xb], [block_ids, jnp.asarray(exponents, jnp.int32)], nb, padded)
    else:
        xb, block_ids = _pad_blocks([xb], [block_ids], nb, padded)
    plan = jnp.asarray(_plan_matrix(cfg))
    ids2d = block_ids.reshape(padded, 1).astype(jnp.int32)
    in_specs = [
        pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        pl.BlockSpec((cfg.rows, cfg.group * 3), lambda i: (0, 0)),
        pl.BlockSpec((tile, cfg.group, cfg.lanes), lambda i: (i, 0, 0)),
    ]
    operands = [ids2d, plan, xb]
    if quantize:
        kern = functools.partial(_wire_encode_q_kernel, cfg=cfg,
                                 mantissa_bits=int(mantissa_bits))
        in_specs.append(pl.BlockSpec((tile, 1), lambda i: (i, 0)))
        operands.append(exponents.reshape(padded, 1).astype(jnp.int32))
        sk_dtype = jnp.int32
    else:
        kern = functools.partial(_wire_encode_kernel, cfg=cfg)
        sk_dtype = jnp.float32
    out = pl.pallas_call(
        kern,
        grid=(padded // tile,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((tile, cfg.rows, cfg.lanes), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile, wpb), lambda i: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded, cfg.rows, cfg.lanes), sk_dtype),
            jax.ShapeDtypeStruct((padded, wpb), jnp.uint32),
            jax.ShapeDtypeStruct((padded, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    sk, words, mx = (o[:nb] for o in out) if padded != nb else out
    return sk, words, mx[:, 0]


def dequant_peel_unpack_pallas(sketch: jnp.ndarray, words: jnp.ndarray,
                               block_ids: jnp.ndarray,
                               cfg: CompressionConfig,
                               exponents: jnp.ndarray | None = None,
                               mantissa_bits: int | None = None,
                               interpret: bool = True):
    """Fused consumer: (nb, rows, c) sketch + (nb, wpb) uint32 words +
    (nb,) ids -> (values (nb, G, c) f32, residual (nb, G, c) int8) in
    one grid pass. With ``exponents`` + ``mantissa_bits`` the int32
    sketch is dequantized in-kernel before peeling.
    """
    nb = sketch.shape[0]
    dequant = exponents is not None
    wpb = cfg.block_elems // 32
    tile = max(1, min(cfg.peel_block_tile, nb))
    padded = -(-nb // tile) * tile
    if dequant:
        sketch, words, block_ids, exponents = _pad_blocks(
            [sketch, words],
            [block_ids, jnp.asarray(exponents, jnp.int32)], nb, padded)
    else:
        sketch, words, block_ids = _pad_blocks(
            [sketch, words], [block_ids], nb, padded)
    g3 = cfg.group * 3
    rows_tbl = jnp.asarray(
        hashing.batch_rows(cfg.group, cfg.rows, cfg.seed).reshape(g3, 1))
    signs = jnp.asarray(hashing.batch_signs(cfg.group, cfg.seed))
    ids2d = block_ids.reshape(padded, 1).astype(jnp.int32)
    in_specs = [
        pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        pl.BlockSpec((g3, 1), lambda i: (0, 0)),
        pl.BlockSpec((cfg.group, 3), lambda i: (0, 0)),
        pl.BlockSpec((tile, cfg.rows, cfg.lanes), lambda i: (i, 0, 0)),
        pl.BlockSpec((tile, wpb), lambda i: (i, 0)),
    ]
    operands = [ids2d, rows_tbl, signs, sketch, words]
    if dequant:
        kern = functools.partial(_wire_peel_dq_kernel, cfg=cfg,
                                 mantissa_bits=int(mantissa_bits))
        in_specs.append(pl.BlockSpec((tile, 1), lambda i: (i, 0)))
        operands.append(exponents.reshape(padded, 1).astype(jnp.int32))
    else:
        kern = functools.partial(_wire_peel_kernel, cfg=cfg)
    out = pl.pallas_call(
        kern,
        grid=(padded // tile,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((tile, cfg.group, cfg.lanes), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile, cfg.group, cfg.lanes), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded, cfg.group, cfg.lanes), jnp.float32),
            jax.ShapeDtypeStruct((padded, cfg.group, cfg.lanes), jnp.int8),
        ],
        interpret=interpret,
    )(*operands)
    if padded != nb:
        out = [o[:nb] for o in out]
    return tuple(out)
