"""Pallas TPU kernel: block-local Count-Sketch encode (paper §3.1 + §3.4).

Grid = one cell per sketch block. Each cell:

- loads its (G, c) tile of gradient batches HBM→VMEM,
- accumulates `g_j(i) * roll(x_i, rot_j(i, blk))` into its private
  (rows, c) sketch tile held in VMEM registers — the block-local hashing
  guarantees no other grid cell ever touches this tile, which is how the
  paper's GPU scatter-with-atomics becomes a race-free TPU kernel,
- writes the sketch tile back.

Row targets and signs are compile-time constants (static hash plan), so
the per-batch scatter unrolls into static-row adds; only the lane
*rotations* (the §3.4 locality randomisation) are computed in-kernel from
the block id, as dynamic rolls on the 128-lane axis.

VMEM budget per cell (defaults G=60, c=512, rows=6):
  x tile 60*512*4 = 120 KiB, sketch 6*512*4 = 12 KiB, ids 4 B — well
  under the ~16 MiB/core VMEM of v5e, leaving room for double buffering.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.config import CompressionConfig
from repro.core import hashing


def _rotations_for_block(block_id, group: int, lanes: int, seed: int):
    """(G, 3) int32 rotation offsets for one block — in-kernel hash."""
    i = jnp.arange(group, dtype=jnp.uint32)
    j = jnp.arange(3, dtype=jnp.uint32)
    key = (block_id.astype(jnp.uint32) * jnp.uint32(0x01000193)
           + i[:, None] * jnp.uint32(3) + j[None, :]
           + jnp.uint32(seed * 2654435761 & 0xFFFFFFFF))
    return (hashing.mix32(key) % jnp.uint32(lanes)).astype(jnp.int32)


def _encode_kernel(ids_ref, x_ref, o_ref, *, cfg: CompressionConfig,
                   rows_tbl: np.ndarray, signs: np.ndarray):
    blk = ids_ref[0, 0]
    rot = _rotations_for_block(blk, cfg.group, cfg.lanes, cfg.seed)  # (G,3)
    x = x_ref[0].astype(jnp.float32)                                 # (G,c)
    acc = jnp.zeros((cfg.rows, cfg.lanes), jnp.float32)
    # Static-row scatter: unrolled per row so every update is a
    # constant-index add (MXU-free, pure VPU work).
    for r in range(cfg.rows):
        row_acc = jnp.zeros((cfg.lanes,), jnp.float32)
        for g in range(cfg.group):
            for j in range(3):
                if int(rows_tbl[g, j]) != r:
                    continue
                rolled = jnp.roll(x[g], rot[g, j])
                row_acc = row_acc + float(signs[g, j]) * rolled
        acc = acc.at[r].set(row_acc)
    o_ref[0] = acc


def sketch_encode_pallas(xb: jnp.ndarray, block_ids: jnp.ndarray,
                         cfg: CompressionConfig,
                         interpret: bool = True) -> jnp.ndarray:
    """(nb, G, c) values + (nb,) ids -> (nb, rows, c) sketch."""
    nb = xb.shape[0]
    rows_tbl = hashing.batch_rows(cfg.group, cfg.rows, cfg.seed)
    signs = hashing.batch_signs(cfg.group, cfg.seed)
    kern = functools.partial(_encode_kernel, cfg=cfg, rows_tbl=rows_tbl,
                             signs=signs)
    ids2d = block_ids.reshape(nb, 1).astype(jnp.int32)
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, cfg.group, cfg.lanes), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, cfg.rows, cfg.lanes), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, cfg.rows, cfg.lanes), jnp.float32),
        interpret=interpret,
    )(ids2d, xb)
