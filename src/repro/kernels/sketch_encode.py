"""Pallas TPU kernel: block-local Count-Sketch encode (paper §3.1 + §3.4).

Grid = one cell per *tile* of ``encode_block_tile`` sketch blocks. Each
cell:

- loads its (B, G, c) tile of gradient batches HBM→VMEM,
- rotates every batch row by its per-(block, batch, hash) offset (the
  §3.4 locality randomisation) as one batched lane-gather,
- scatters the rotated contributions onto sketch rows as a single
  (rows, G*3) x (B, G*3, c) contraction against a static sign-folded
  one-hot plan matrix — MXU work instead of the G*3 serial
  roll-and-accumulate VPU ops of the naive formulation,
- writes the (B, rows, c) sketch tile back.

The block-local hashing guarantees no other grid cell ever touches these
rows, which is how the paper's GPU scatter-with-atomics becomes a
race-free TPU kernel. Row targets and signs are compile-time constants
(static hash plan) folded into the plan matrix; only the lane rotations
are computed in-kernel from the block ids.

VMEM budget per cell (defaults B=8, G=60, c=512, rows=6):
  x tile 8*60*512*4 = 960 KiB, rotated contributions 8*60*3*512*4
  = 2.8 MiB, sketch out 8*6*512*4 = 96 KiB, plan 6*180*4 ≈ 4 KiB —
  comfortably under the ~16 MiB/core VMEM of v5e with room for double
  buffering.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.config import CompressionConfig
from repro.core import hashing


def _rotations_for_block(block_ids, group: int, lanes: int, seed: int):
    """Rotation offsets for one block (scalar id -> (G, 3)) or a tile of
    blocks ((B,) ids -> (B, G, 3)).

    Thin adapter over :func:`repro.core.hashing.block_rotations` — the
    kernels and the reference must draw from the same hash stream, so
    there is exactly one implementation of it.
    """
    ids = jnp.asarray(block_ids)
    if ids.ndim == 0:
        return hashing.block_rotations(ids[None], group, lanes, seed)[0]
    return hashing.block_rotations(ids, group, lanes, seed)


def _plan_matrix(cfg: CompressionConfig) -> np.ndarray:
    """(rows, G*3) f32 one-hot row-scatter matrix with signs folded in:
    A[r, (i,j)] = g_j(i) * [h_j(i) == r]."""
    rows_flat = hashing.batch_rows(cfg.group, cfg.rows, cfg.seed).reshape(-1)
    signs_flat = hashing.batch_signs(cfg.group, cfg.seed).reshape(-1)
    onehot = (rows_flat[None, :] == np.arange(cfg.rows)[:, None])
    return (onehot * signs_flat[None, :]).astype(np.float32)


def encode_tile(ids, plan, x, cfg: CompressionConfig):
    """The in-kernel encode math for one tile: (B,) ids + (rows, G*3)
    plan matrix + (B, G, c) values -> (B, rows, c) sketch.

    Shared by :func:`_encode_kernel` and the fused wire-codec kernel in
    :mod:`repro.kernels.sketch_wire` — ONE implementation of the tile
    contraction, so the fused producer can never drift from the plain
    encode (their bit-parity is structural, not test-luck).
    """
    B = x.shape[0]                        # blocks per grid cell (tile)
    G, c = cfg.group, cfg.lanes
    rot = _rotations_for_block(ids, G, c, cfg.seed)                  # (B,G,3)
    x = x.astype(jnp.float32)                                        # (B,G,c)

    # Batched lane rotation: out[m] = x[(m - rot) % c] for all (blk,i,j).
    lane = jnp.arange(c, dtype=jnp.int32)
    fwd_idx = (lane[None, None, None, :] - rot[..., None]) % c       # (B,G,3,c)
    vb = jnp.broadcast_to(x[:, :, None, :], (B, G, 3, c))
    rolled = jnp.take_along_axis(vb, fwd_idx, axis=-1)               # (B,G,3,c)

    # Static-plan row scatter as one contraction over the G*3 axis.
    contrib = rolled.reshape(B, G * 3, c)
    acc = jax.lax.dot_general(
        plan, contrib,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                          # (R,B,c)
    return acc.transpose(1, 0, 2)


def _encode_kernel(ids_ref, plan_ref, x_ref, o_ref, *,
                   cfg: CompressionConfig):
    ids = ids_ref[...][:, 0]                                         # (B,)
    o_ref[...] = encode_tile(ids, plan_ref[...], x_ref[...], cfg)


def sketch_encode_pallas(xb: jnp.ndarray, block_ids: jnp.ndarray,
                         cfg: CompressionConfig,
                         interpret: bool = True) -> jnp.ndarray:
    """(nb, G, c) values + (nb,) ids -> (nb, rows, c) sketch."""
    nb = xb.shape[0]
    tile = max(1, min(cfg.encode_block_tile, nb))
    padded = -(-nb // tile) * tile
    if padded != nb:
        # Zero blocks encode to zero sketches; their (arbitrary) ids only
        # seed rotations of zeros. Sliced back off below.
        xb = jnp.pad(xb, ((0, padded - nb), (0, 0), (0, 0)))
        block_ids = jnp.pad(block_ids, (0, padded - nb))
    kern = functools.partial(_encode_kernel, cfg=cfg)
    ids2d = block_ids.reshape(padded, 1).astype(jnp.int32)
    plan = jnp.asarray(_plan_matrix(cfg))
    out = pl.pallas_call(
        kern,
        grid=(padded // tile,),
        in_specs=[
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((cfg.rows, cfg.group * 3), lambda i: (0, 0)),
            pl.BlockSpec((tile, cfg.group, cfg.lanes), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, cfg.rows, cfg.lanes),
                               lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, cfg.rows, cfg.lanes),
                                       jnp.float32),
        interpret=interpret,
    )(ids2d, plan, xb)
    return out[:nb] if padded != nb else out
