"""Pallas TPU kernel: parallel-peeling recovery (paper §3.2).

Grid = one cell per *tile* of ``peel_block_tile`` sketch blocks (the same
multi-block grid-cell tiling as the encode kernel); the whole peeling
loop for a tile runs *inside* the kernel, so the sketch tile, degree tile
and index tile stay VMEM-resident across rounds — the TPU translation of
the paper's §3.4 cache-locality argument (their GPU version re-reads
global memory per round; here HBM sees exactly one read of [Y, B] and one
write of X). Batching blocks per cell amortises the per-cell hash-plan
setup and keeps the VPU busy on short rows.

The round count is a static unroll bound: with block-local sketches the
paper's peeling finishes in O(1) rounds, so a fixed `cfg.rounds` loses
nothing while keeping the kernel control-flow-free for the TPU scalar
unit. Rounds after the fixpoint are cheap no-ops (all-false peel masks).

Per-round math is identical to :mod:`repro.core.peeling` (the oracle):
degree gather -> singleton test -> exact value extraction -> subtract.

VMEM budget per cell (defaults B=4, G=60, c=512, rows=6): y 4*6*512*4 =
48 KiB, b/d/x tiles 3 x 4*60*512*(1|4) ≈ 1.1 MiB, the (B, G, 3, c)
rotation gathers 2.8 MiB — comfortably under ~16 MiB/core with double
buffering (the peel loop keeps more state live than encode, hence the
smaller default tile).
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.config import CompressionConfig
from repro.core import hashing
from .sketch_encode import _rotations_for_block


def peel_tile(ids, rows_flat, signs, y, b, cfg: CompressionConfig):
    """The in-kernel peel math for one tile: (B,) ids + (G*3,) row table
    + (G, 3) signs + (B, rows, c) sketch + (B, G, c) bool bits ->
    (values (B, G, c) f32, residual (B, G, c) bool).

    Shared by :func:`_peel_kernel` and the fused wire-codec kernel in
    :mod:`repro.kernels.sketch_wire` — ONE implementation of the peeling
    loop, so the fused consumer can never drift from the plain peel.
    """
    B = y.shape[0]                        # blocks per grid cell (tile)
    G, R, c = cfg.group, cfg.rows, cfg.lanes
    rot = _rotations_for_block(ids, G, c, cfg.seed)                   # (B,G,3)
    sg = signs[None, :, :, None]                                      # (1,G,3,1)

    lane = jnp.arange(c, dtype=jnp.int32)
    fwd_idx = (lane[None, None, None, :] - rot[..., None]) % c        # to sketch
    bwd_idx = (lane[None, None, None, :] + rot[..., None]) % c        # roll back

    def roll_fwd(v):   # (B,G,c) -> (B,G,3,c)
        vb = jnp.broadcast_to(v[:, :, None, :], (B, G, 3, c))
        return jnp.take_along_axis(vb, fwd_idx, axis=-1)

    def roll_bwd(v):   # (B,G,3,c) -> (B,G,3,c)
        return jnp.take_along_axis(v, bwd_idx, axis=-1)

    def scatter(contrib):  # (B,G,3,c) -> (B,R,c)
        flat = contrib.reshape(B, G * 3, c)
        return jnp.zeros((B, R, c), contrib.dtype).at[:, rows_flat].add(flat)

    def gather(t):     # (B,R,c) -> (B,G,3,c)
        return jnp.take(t, rows_flat, axis=1).reshape(B, G, 3, c)

    y = y.astype(jnp.float32)                                         # (B,R,c)
    d = scatter(roll_fwd(b.astype(jnp.int32)))                        # (B,R,c)
    x = jnp.zeros((B, G, c), jnp.float32)

    def round_body(_, state):
        y, b, d, x = state
        d_at = roll_bwd(gather(d))
        v_at = roll_bwd(gather(y)) * sg
        peelable = (d_at == 1) & b[:, :, None, :]
        any_peel = jnp.any(peelable, axis=2)
        jstar = jnp.argmax(peelable, axis=2)
        val = jnp.take_along_axis(v_at, jstar[:, :, None, :], axis=2)[:, :, 0, :]
        val = jnp.where(any_peel, val, 0.0)
        y = y - scatter(roll_fwd(val) * sg)
        d = d - scatter(roll_fwd(any_peel.astype(jnp.int32)))
        b = b & ~any_peel
        x = x + val
        return y, b, d, x

    y, b, d, x = jax.lax.fori_loop(0, cfg.rounds, round_body, (y, b, d, x))

    # Residue -> unbiased median-of-3 estimate (paper footnote 5).
    est = roll_bwd(gather(y)) * sg
    v0, v1, v2 = est[:, :, 0], est[:, :, 1], est[:, :, 2]
    med = (v0 + v1 + v2
           - jnp.maximum(jnp.maximum(v0, v1), v2)
           - jnp.minimum(jnp.minimum(v0, v1), v2))
    return x + jnp.where(b, med, 0.0), b


def _peel_kernel(ids_ref, rows_ref, signs_ref, y_ref, b_ref, xo_ref, ro_ref,
                 *, cfg: CompressionConfig):
    ids = ids_ref[...][:, 0]                                          # (B,)
    values, residual = peel_tile(ids, rows_ref[:, 0], signs_ref[...],
                                 y_ref[...], b_ref[...] != 0, cfg)
    xo_ref[...] = values
    ro_ref[...] = residual.astype(jnp.int8)


def sketch_peel_pallas(sketch: jnp.ndarray, bits: jnp.ndarray,
                       block_ids: jnp.ndarray, cfg: CompressionConfig,
                       interpret: bool = True):
    """(nb,rows,c) sketch + (nb,G,c) bits -> (values (nb,G,c) f32,
    residual (nb,G,c) int8)."""
    nb = sketch.shape[0]
    tile = max(1, min(cfg.peel_block_tile, nb))
    padded = -(-nb // tile) * tile
    if padded != nb:
        # Zero sketch blocks with empty indexes peel to exact zeros;
        # their (arbitrary) ids only seed rotations of zeros. Sliced
        # back off below.
        sketch = jnp.pad(sketch, ((0, padded - nb), (0, 0), (0, 0)))
        bits = jnp.pad(bits, ((0, padded - nb), (0, 0), (0, 0)))
        block_ids = jnp.pad(block_ids, (0, padded - nb))
    g3 = cfg.group * 3
    rows_tbl = jnp.asarray(
        hashing.batch_rows(cfg.group, cfg.rows, cfg.seed).reshape(g3, 1))
    signs = jnp.asarray(hashing.batch_signs(cfg.group, cfg.seed))
    kern = functools.partial(_peel_kernel, cfg=cfg)
    ids2d = block_ids.reshape(padded, 1).astype(jnp.int32)
    out = pl.pallas_call(
        kern,
        grid=(padded // tile,),
        in_specs=[
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((g3, 1), lambda i: (0, 0)),          # hash plan
            pl.BlockSpec((cfg.group, 3), lambda i: (0, 0)),   # signs
            pl.BlockSpec((tile, cfg.rows, cfg.lanes), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile, cfg.group, cfg.lanes), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, cfg.group, cfg.lanes), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile, cfg.group, cfg.lanes), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded, cfg.group, cfg.lanes), jnp.float32),
            jax.ShapeDtypeStruct((padded, cfg.group, cfg.lanes), jnp.int8),
        ],
        interpret=interpret,
    )(ids2d, rows_tbl, signs, sketch, bits.astype(jnp.int8))
    if padded != nb:
        out = [o[:nb] for o in out]
    return tuple(out)
