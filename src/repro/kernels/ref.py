"""Pure-jnp oracles for the Pallas kernels.

These delegate to :mod:`repro.core` — the reference implementation the
whole framework runs on CPU — so the kernel tests pin the Pallas bodies
to exactly the semantics the training path uses.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.config import CompressionConfig
from repro.core.sketch import encode_blocks
from repro.core.peeling import peel_blocks


def sketch_encode_ref(xb: jnp.ndarray, block_ids: jnp.ndarray,
                      cfg: CompressionConfig) -> jnp.ndarray:
    """(nb, G, c) -> (nb, rows, c), same contract as sketch_encode_pallas."""
    return encode_blocks(xb, block_ids, cfg)


def sketch_peel_ref(sketch: jnp.ndarray, bits: jnp.ndarray,
                    block_ids: jnp.ndarray, cfg: CompressionConfig):
    """Returns (values f32, residual int8), same contract as
    sketch_peel_pallas.

    Note the oracle's while_loop exits at the peeling fixpoint; the kernel
    always runs ``cfg.rounds`` rounds. Both reach the same fixpoint
    because post-fixpoint rounds peel nothing.
    """
    r = peel_blocks(sketch, bits != 0, block_ids, cfg)
    return r.values, r.residual.astype(jnp.int8)
