"""The pure-jnp oracle backend of :mod:`repro.kernels.ops`.

There is exactly one reference implementation of the sketch math — the
block-layout functions in :mod:`repro.core.sketch` and
:mod:`repro.core.peeling` — and this module is its adapter to the kernel
calling convention (flat outputs, int8 residual). The Pallas kernels are
pinned to these functions by the interpret-mode parity tests, and the
dispatch layer uses them verbatim for the ``use_pallas="never"``/CPU
path, so training, serving and the kernel tests all share one oracle.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.config import CompressionConfig
from repro.core.sketch import encode_blocks, estimate_blocks
from repro.core.peeling import peel_blocks


def sketch_encode_ref(xb: jnp.ndarray, block_ids: jnp.ndarray,
                      cfg: CompressionConfig) -> jnp.ndarray:
    """(nb, G, c) -> (nb, rows, c), same contract as sketch_encode_pallas."""
    return encode_blocks(xb, block_ids, cfg)


def sketch_peel_ref(sketch: jnp.ndarray, bits: jnp.ndarray,
                    block_ids: jnp.ndarray, cfg: CompressionConfig):
    """Returns (values f32, residual int8), same contract as
    sketch_peel_pallas.

    Note the oracle's while_loop exits at the peeling fixpoint; the kernel
    always runs ``cfg.rounds`` rounds. Both reach the same fixpoint
    because post-fixpoint rounds peel nothing.
    """
    r = peel_blocks(sketch, bits != 0, block_ids, cfg)
    return r.values, r.residual.astype(jnp.int8)


def sketch_estimate_ref(sketch: jnp.ndarray, block_ids: jnp.ndarray,
                        cfg: CompressionConfig) -> jnp.ndarray:
    """(nb, rows, c) -> (nb, G, c) median-of-3 estimate for every coord."""
    return estimate_blocks(sketch, block_ids, cfg)
