"""The pure-jnp oracle backend of :mod:`repro.kernels.ops`.

There is exactly one reference implementation of the sketch math — the
block-layout functions in :mod:`repro.core.sketch` and
:mod:`repro.core.peeling` — and this module is its adapter to the kernel
calling convention (flat outputs, int8 residual). The Pallas kernels are
pinned to these functions by the interpret-mode parity tests, and the
dispatch layer uses them verbatim for the ``use_pallas="never"``/CPU
path, so training, serving and the kernel tests all share one oracle.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.config import CompressionConfig
from repro.core.sketch import encode_blocks, estimate_blocks
from repro.core.peeling import peel_blocks
from repro.core import index as index_lib
from repro.net.fixedpoint import pow2


def sketch_encode_ref(xb: jnp.ndarray, block_ids: jnp.ndarray,
                      cfg: CompressionConfig) -> jnp.ndarray:
    """(nb, G, c) -> (nb, rows, c), same contract as sketch_encode_pallas."""
    return encode_blocks(xb, block_ids, cfg)


def sketch_peel_ref(sketch: jnp.ndarray, bits: jnp.ndarray,
                    block_ids: jnp.ndarray, cfg: CompressionConfig):
    """Returns (values f32, residual int8), same contract as
    sketch_peel_pallas.

    Note the oracle's while_loop exits at the peeling fixpoint; the kernel
    always runs ``cfg.rounds`` rounds. Both reach the same fixpoint
    because post-fixpoint rounds peel nothing.
    """
    r = peel_blocks(sketch, bits != 0, block_ids, cfg)
    return r.values, r.residual.astype(jnp.int8)


def sketch_estimate_ref(sketch: jnp.ndarray, block_ids: jnp.ndarray,
                        cfg: CompressionConfig) -> jnp.ndarray:
    """(nb, rows, c) -> (nb, G, c) median-of-3 estimate for every coord."""
    return estimate_blocks(sketch, block_ids, cfg)


# ---- composed wire-codec references (PR 7) ----------------------------
#
# The fused kernels in `sketch_wire.py` are pinned bit-for-bit to these
# compositions of the existing oracles: encode + pack_bits + (rint
# quantize), and unpack_bits + (bitcast dequant) + peel. Each composed
# function makes the 2-3 separate passes over the stream that the fused
# kernel collapses to one — same math, different HBM traffic.


def encode_pack_quantize_ref(xb: jnp.ndarray, block_ids: jnp.ndarray,
                             cfg: CompressionConfig,
                             exponents: jnp.ndarray | None = None,
                             mantissa_bits: int | None = None):
    """Composed producer: (nb, G, c) values + (nb,) ids ->
    (sketch (nb, rows, c) f32|int32, words (nb, wpb) uint32,
    maxabs (nb,) f32). Requires ``cfg.block_elems % 32 == 0``."""
    nb = xb.shape[0]
    wpb = cfg.block_elems // 32
    sketch = encode_blocks(xb, block_ids, cfg)                # pass 1: encode
    words = index_lib.pack_bits(
        index_lib.bitmap_build(xb)).reshape(nb, wpb)          # pass 2: pack
    maxabs = jnp.max(jnp.abs(sketch), axis=(1, 2))
    if exponents is not None:                                 # pass 3: quantize
        scale = pow2(int(mantissa_bits)
                     - jnp.asarray(exponents, jnp.int32))
        sketch = jnp.rint(sketch * scale[:, None, None]).astype(jnp.int32)
    return sketch, words, maxabs


def dequant_peel_unpack_ref(sketch: jnp.ndarray, words: jnp.ndarray,
                            block_ids: jnp.ndarray, cfg: CompressionConfig,
                            exponents: jnp.ndarray | None = None,
                            mantissa_bits: int | None = None):
    """Composed consumer: (nb, rows, c) sketch + (nb, wpb) words + (nb,)
    ids -> (values (nb, G, c) f32, residual (nb, G, c) int8)."""
    nb = sketch.shape[0]
    bits = index_lib.unpack_bits(
        words.reshape(-1), (nb, cfg.group, cfg.lanes))        # pass 1: unpack
    if exponents is not None:                                 # pass 2: dequant
        scale = pow2(jnp.asarray(exponents, jnp.int32)
                     - int(mantissa_bits))
        sketch = sketch.astype(jnp.float32) * scale[:, None, None]
    r = peel_blocks(sketch, bits, block_ids, cfg)             # pass 3: peel
    return r.values, r.residual.astype(jnp.int8)
