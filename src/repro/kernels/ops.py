"""Jitted dispatch between the Pallas kernels and the jnp reference.

This module is the **single compute backend** for the compression
pipeline: ``HomomorphicCompressor`` (and through it training, serving,
collectives and the benchmarks) calls ``sketch_encode`` / ``sketch_peel``
/ ``sketch_estimate`` here and never reaches into ``repro.core.sketch``
or ``repro.core.peeling`` directly, so the ``use_pallas`` policy governs
every consumer.

``use_pallas`` policy:
  "never"  — always the jnp reference (the default on CPU: interpret-mode
             Pallas is a Python-loop emulator, far slower than XLA:CPU).
  "always" — Pallas, interpret=True off-TPU so the kernel body still
             executes (correctness path used by the test suite).
  "auto"   — Pallas on TPU backends, reference elsewhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import CompressionConfig
from . import ref as ref_ops
from .sketch_encode import sketch_encode_pallas
from .sketch_peel import sketch_peel_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _want_pallas(cfg: CompressionConfig) -> bool:
    if cfg.use_pallas == "never":
        return False
    if cfg.use_pallas == "always":
        return True
    return _on_tpu()


def sketch_encode(xb: jnp.ndarray, block_ids: jnp.ndarray,
                  cfg: CompressionConfig) -> jnp.ndarray:
    """(nb, G, c) values + (nb,) ids -> (nb, rows, c) sketch (f32)."""
    if _want_pallas(cfg):
        return sketch_encode_pallas(xb, block_ids, cfg,
                                    interpret=not _on_tpu())
    return ref_ops.sketch_encode_ref(xb, block_ids, cfg)


def sketch_peel(sketch: jnp.ndarray, bits: jnp.ndarray,
                block_ids: jnp.ndarray, cfg: CompressionConfig):
    """(nb, rows, c) sketch + (nb, G, c) bits -> (values f32,
    residual int8), both (nb, G, c)."""
    if _want_pallas(cfg):
        return sketch_peel_pallas(sketch, bits, block_ids, cfg,
                                  interpret=not _on_tpu())
    return ref_ops.sketch_peel_ref(sketch, bits, block_ids, cfg)


def sketch_estimate(sketch: jnp.ndarray, block_ids: jnp.ndarray,
                    cfg: CompressionConfig) -> jnp.ndarray:
    """Median-of-3 Count-Sketch estimate for every coordinate,
    (nb, rows, c) -> (nb, G, c).

    The sketch-only lossy decode (ablation path). Reference-backed on
    every policy: it is off the training hot path, and the peel kernel
    already computes the same median in-kernel for its residue, so a
    dedicated Pallas estimate kernel would duplicate that code for no
    measured benefit.
    """
    return ref_ops.sketch_estimate_ref(sketch, block_ids, cfg)
