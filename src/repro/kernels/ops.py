"""Jitted dispatch between the Pallas kernels and the jnp reference.

This module is the **single compute backend** for the compression
pipeline: ``HomomorphicCompressor`` (and through it training, serving,
collectives and the benchmarks) calls ``sketch_encode`` / ``sketch_peel``
/ ``sketch_estimate`` here and never reaches into ``repro.core.sketch``
or ``repro.core.peeling`` directly, so the ``use_pallas`` policy governs
every consumer.

``use_pallas`` policy:
  "never"  — always the jnp reference (the default on CPU: interpret-mode
             Pallas is a Python-loop emulator, far slower than XLA:CPU).
  "always" — Pallas, interpret=True off-TPU so the kernel body still
             executes (correctness path used by the test suite).
  "auto"   — Pallas on TPU backends, reference elsewhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import CompressionConfig
from . import ref as ref_ops
from .sketch_encode import sketch_encode_pallas
from .sketch_peel import sketch_peel_pallas
from .sketch_wire import (encode_pack_quantize_pallas,
                          dequant_peel_unpack_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _want_pallas(cfg: CompressionConfig) -> bool:
    if cfg.use_pallas == "never":
        return False
    if cfg.use_pallas == "always":
        return True
    return _on_tpu()


def sketch_encode(xb: jnp.ndarray, block_ids: jnp.ndarray,
                  cfg: CompressionConfig) -> jnp.ndarray:
    """(nb, G, c) values + (nb,) ids -> (nb, rows, c) sketch (f32)."""
    if _want_pallas(cfg):
        return sketch_encode_pallas(xb, block_ids, cfg,
                                    interpret=not _on_tpu())
    return ref_ops.sketch_encode_ref(xb, block_ids, cfg)


def sketch_peel(sketch: jnp.ndarray, bits: jnp.ndarray,
                block_ids: jnp.ndarray, cfg: CompressionConfig):
    """(nb, rows, c) sketch + (nb, G, c) bits -> (values f32,
    residual int8), both (nb, G, c)."""
    if _want_pallas(cfg):
        return sketch_peel_pallas(sketch, bits, block_ids, cfg,
                                  interpret=not _on_tpu())
    return ref_ops.sketch_peel_ref(sketch, bits, block_ids, cfg)


def fused_wire_supported(cfg: CompressionConfig) -> bool:
    """Whether the fused wire-codec ops cover this geometry.

    The fused producer packs the bitmap *per block*, so the pack-word
    boundary must coincide with the block boundary (``block_elems %
    32 == 0`` — always true for default geometries, where
    ``bucket_quantum = lcm(block_elems, 32)``), and only the exact
    bitmap index is pack-fusable (Bloom needs a global scatter over all
    coordinates, inherently cross-block).
    """
    return cfg.index == "bitmap" and cfg.block_elems % 32 == 0


def encode_pack_quantize(xb: jnp.ndarray, block_ids: jnp.ndarray,
                         cfg: CompressionConfig,
                         exponents: jnp.ndarray | None = None,
                         mantissa_bits: int | None = None):
    """Fused wire producer: (nb, G, c) values + (nb,) ids ->
    (sketch (nb, rows, c) f32|int32, words (nb, wpb) uint32,
    maxabs (nb,) f32).

    ONE pass over the gradient stream: sketch-encode, bitmap-pack and
    per-block max-magnitude (the fxp32 exponent ingredient) in a single
    grid pass, optionally shared-exponent int32 quantization too when
    per-block ``exponents`` + ``mantissa_bits`` are given (exponents are
    a collective product, so the aggregator usually quantizes the
    already-Γ-compressed sketch after its pmax instead).
    """
    if (exponents is None) != (mantissa_bits is None):
        raise ValueError("exponents and mantissa_bits must be given together")
    if not fused_wire_supported(cfg):
        raise ValueError(
            f"fused wire codec unsupported for index={cfg.index!r}, "
            f"block_elems={cfg.block_elems} (need bitmap and %32==0)")
    if _want_pallas(cfg):
        return encode_pack_quantize_pallas(
            xb, block_ids, cfg, exponents=exponents,
            mantissa_bits=mantissa_bits, interpret=not _on_tpu())
    return ref_ops.encode_pack_quantize_ref(
        xb, block_ids, cfg, exponents=exponents, mantissa_bits=mantissa_bits)


def dequant_peel_unpack(sketch: jnp.ndarray, words: jnp.ndarray,
                        block_ids: jnp.ndarray, cfg: CompressionConfig,
                        exponents: jnp.ndarray | None = None,
                        mantissa_bits: int | None = None):
    """Fused wire consumer: (nb, rows, c) sketch + (nb, wpb) packed
    words + (nb,) ids -> (values f32, residual int8), both (nb, G, c).

    ONE pass over the aggregated wire payload: bitmap-unpack, optional
    exponent-bitcast dequantization of the int32 fxp32 sketch, and the
    full peeling loop in a single grid pass.
    """
    if (exponents is None) != (mantissa_bits is None):
        raise ValueError("exponents and mantissa_bits must be given together")
    if not fused_wire_supported(cfg):
        raise ValueError(
            f"fused wire codec unsupported for index={cfg.index!r}, "
            f"block_elems={cfg.block_elems} (need bitmap and %32==0)")
    if _want_pallas(cfg):
        return dequant_peel_unpack_pallas(
            sketch, words, block_ids, cfg, exponents=exponents,
            mantissa_bits=mantissa_bits, interpret=not _on_tpu())
    return ref_ops.dequant_peel_unpack_ref(
        sketch, words, block_ids, cfg, exponents=exponents,
        mantissa_bits=mantissa_bits)


def wire_codec_passes(cfg: CompressionConfig, quantized: bool = False):
    """Analytic pass counts over the bucket stream per wire direction.

    Feeds `core/costmodel.py`'s codec-compute term and the roofline
    `--codec` report. "Pass" = one full read of the stream-sized
    operand: fused = 1 each way; composed = encode + pack (+ quantize)
    on the producer, unpack + peel (+ dequant) on the consumer.
    """
    if fused_wire_supported(cfg) and _want_pallas(cfg):
        return {"producer": 1, "consumer": 1}
    extra = 1 if quantized else 0
    return {"producer": 2 + extra, "consumer": 2 + extra}


def sketch_estimate(sketch: jnp.ndarray, block_ids: jnp.ndarray,
                    cfg: CompressionConfig) -> jnp.ndarray:
    """Median-of-3 Count-Sketch estimate for every coordinate,
    (nb, rows, c) -> (nb, G, c).

    The sketch-only lossy decode (ablation path). Reference-backed on
    every policy: it is off the training hot path, and the peel kernel
    already computes the same median in-kernel for its residue, so a
    dedicated Pallas estimate kernel would duplicate that code for no
    measured benefit.
    """
    return ref_ops.sketch_estimate_ref(sketch, block_ids, cfg)
