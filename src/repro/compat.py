"""JAX version compatibility shim.

The repo targets two JAX API generations:

- **current JAX** (>= 0.6): ``jax.shard_map`` is a public top-level API
  taking ``axis_names`` (the *manual* axes) and ``check_vma``;
  ``jax.make_mesh`` takes ``axis_types`` (``jax.sharding.AxisType``).
- **JAX 0.4.x** (the pinned toolchain, 0.4.37): ``shard_map`` lives in
  ``jax.experimental.shard_map`` and is parameterised the other way
  round — ``auto`` names the *non-manual* axes and replication checking
  is ``check_rep``; ``jax.make_mesh`` has no ``axis_types`` (every axis
  is implicitly Auto, which is exactly what this repo uses).

Everything in the repo that builds a mesh or opens a manual region goes
through this module, so version differences are handled in exactly one
place. The shim exposes the *new* parameter names and translates down
when running on 0.4.x.
"""

from __future__ import annotations

import functools
from typing import Iterable, Optional, Sequence

import jax

# ``AxisType`` arriving in jax.sharding is the marker for the new-style
# sharding API (top-level jax.shard_map with axis_names/check_vma).
HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")
HAS_TOP_LEVEL_SHARD_MAP = hasattr(jax, "shard_map")

if not HAS_TOP_LEVEL_SHARD_MAP:  # 0.4.x
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

# Opening a *nested* partial-manual region (a shard_map over the TP axes
# inside a shard_map that already holds the DP axes manual) compiles fine
# on new JAX but trips an XLA SPMD-partitioner RET_CHECK
# ("Incompatible manual sharding at %copy") on 0.4.x. Callers that nest
# for performance (shard-local compression in
# :func:`repro.core.collectives.compressed_all_reduce`) must consult this
# flag and fall back to computing on the auto-sharded global view.
SUPPORTS_NESTED_SHARD_MAP = HAS_TOP_LEVEL_SHARD_MAP

# On 0.4.x, ``ppermute`` over a manual axis inside a *partial*-auto region
# (some mesh axes left to GSPMD) hits a fatal partitioner check
# ("target.IsManualSubgroup() == sharding().IsManualSubgroup()"); ``psum``
# in the same region is fine, and full-manual regions support ppermute.
# The OR-AllReduce falls back to a psum-based emulation when this is
# False (see :func:`repro.core.collectives.or_allreduce`).
SUPPORTS_PARTIAL_AUTO_PPERMUTE = HAS_TOP_LEVEL_SHARD_MAP

# Native reduce-scatter lowering (``jax.lax.psum_scatter`` on the sketch,
# the ppermute-ring OR-Reduce-Scatter on the bitmap) inside *partial*-auto
# manual regions. On 0.4.x the same partitioner gaps that break ppermute
# there (and axis_index consumption — the peel's per-rank ``block_offset``
# is real compute fed by the rank) apply, so the flag tracks the new-API
# generation. Full-manual regions support the whole native wire path on
# every JAX — callers that hold EVERY mesh axis manual (the 0.4.x train
# step, single-DP-axis benchmark meshes) may take the native path even
# when this flag is False; see
# :class:`repro.core.aggregators.CompressedReduceScatterAggregator`.
# On new JAX partial-auto regions note the Shardy caveat: auto TP axes are
# un-sharded around a manual-axis psum_scatter/all_gather (perf, not
# correctness — same note as the ZeRO-1 gather in train/step.py).
SUPPORTS_PSUM_SCATTER = HAS_TOP_LEVEL_SHARD_MAP

# The partial-auto failures above are symptoms of a broader 0.4.x gap:
# any value whose HLO parameter/operand carries a plain *replicated*
# sharding annotation (hoisted scan constants, replicated param leaves
# scanned as layer stacks, jax.checkpoint remat calls) aborts the
# partitioner inside a manual subgroup. Regions that scan over
# replicated-sharded operands or remat their bodies (the train step's
# layer stack) must therefore take EVERY mesh axis manual on 0.4.x —
# TP compute degrades to replication there, which is numerically
# identical, merely unsharded. Full-manual regions support ppermute,
# remat and scanned constants on every JAX.
SUPPORTS_PARTIAL_AUTO_SHARD_MAP = HAS_TOP_LEVEL_SHARD_MAP


def full_manual_region(manual_axes, mesh) -> bool:
    """True when ``manual_axes`` covers every mesh axis.

    A full-manual region has no auto axes left for GSPMD/Shardy to
    manage, which unlocks two things the partial-auto paths must avoid:
    ppermute/psum_scatter on 0.4.x (see SUPPORTS_PARTIAL_AUTO_PPERMUTE /
    SUPPORTS_PSUM_SCATTER), and manual-axis ``all_gather`` on new JAX
    without Shardy un-sharding the auto TP axes around it (the reason
    the ZeRO-1 gather in train/step.py otherwise uses zero-pad + psum
    at 2x the wire cost).
    """
    return set(mesh.axis_names) <= set(manual_axes)


def train_step_manual_axes(mesh, dp_axes) -> set:
    """The manual axis set for the train-step region on this JAX.

    New JAX: just the DP axes (TP stays auto/GSPMD inside). 0.4.x: all
    mesh axes (see SUPPORTS_PARTIAL_AUTO_SHARD_MAP).
    """
    if SUPPORTS_PARTIAL_AUTO_SHARD_MAP:
        return set(dp_axes)
    return set(mesh.axis_names)


def checkpoint(f, **kwargs):
    """``jax.checkpoint``, routed through the compat seam.

    Remat works everywhere the repo opens manual regions *today* (plain
    jit, and full-manual shard_map on 0.4.x — see
    SUPPORTS_PARTIAL_AUTO_SHARD_MAP for why partial-auto + remat is
    fatal there and regions are full-manual instead). Model code calls
    this seam rather than jax.checkpoint directly so a future
    incompatibility has one switch to flip.
    """
    return jax.checkpoint(f, **kwargs)


def axis_size(axis_name) -> int:
    """Static size of a (manual) mesh axis, inside shard_map.

    ``jax.lax.axis_size`` postdates 0.4.x; ``psum(1, axis)`` is the
    classic spelling and constant-folds to a Python int on every JAX.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None):
    """``jax.make_mesh`` with every axis in Auto mode, on any JAX.

    On new JAX the Auto axis type is passed explicitly (the default
    changed to Explicit in some releases); on 0.4.x the kwarg does not
    exist and Auto is the only behaviour.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPES:
        kwargs["axis_types"] = (
            jax.sharding.AxisType.Auto,) * len(tuple(axis_shapes))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[Iterable[str]] = None,
              check_vma: bool = False):
    """``jax.shard_map`` spelled with the new-API parameter names.

    Args:
      f:          function to map.
      mesh:       the device mesh. Required (new JAX can infer it from a
                  surrounding manual region; 0.4.x cannot, and every call
                  site in this repo has the mesh in hand anyway).
      in_specs/out_specs: as in jax.shard_map.
      axis_names: the axes to take *manual*. ``None`` means all of them.
      check_vma:  new-API name for replication checking (0.4.x:
                  ``check_rep``).
    """
    manual = set(mesh.axis_names) if axis_names is None else set(axis_names)
    if HAS_TOP_LEVEL_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=manual,
                             check_vma=check_vma)
    auto = frozenset(mesh.axis_names) - manual
    return _legacy_shard_map(f, mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma,
                             auto=auto)


def manual_region_constraint(x, spec):
    """``with_sharding_constraint`` with a bare PartitionSpec, inside a
    partial-auto manual region.

    These constraints are performance hints (keep GSPMD from replicating
    activations/accumulators on the auto TP axes). New JAX resolves the
    bare spec against the context mesh; the 0.4.x partitioner cannot carry
    a plain sharding annotation through a manual subgroup (fatal
    "Incompatible manual sharding" RET_CHECK), so there the hint is
    dropped — GSPMD picks its own placement, correctness unaffected.
    """
    if HAS_TOP_LEVEL_SHARD_MAP:
        return jax.lax.with_sharding_constraint(x, spec)
    return x
