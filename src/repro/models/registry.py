"""Family -> model function dispatch.

Uniform interface used by the train/serve step builders:

  api = model_api(cfg)
  params = api.init(key)
  loss, metrics = api.loss(params, batch, remat=...)
  logits, cache = api.prefill(params, batch, max_len)
  logits, cache = api.decode(params, token, cache, position)
  cache = api.init_cache(params, batch_size, max_len)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax.numpy as jnp

from .config import ModelConfig
from . import transformer as T
from . import encdec as E


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable


def model_api(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "encdec":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: E.init_encdec(key, cfg),
            loss=lambda p, batch, remat="none": E.encdec_loss(
                p, cfg, batch, remat=remat),
            prefill=lambda p, batch, max_len: E.encdec_prefill(
                p, cfg, batch["frames"], batch["tokens"], max_len),
            decode=lambda p, tok, cache, pos: E.encdec_decode(
                p, cfg, tok, cache, pos),
            init_cache=lambda p, b, s: E.init_encdec_cache(p, cfg, b, s),
        )

    def _prefill(p, batch, max_len):
        return T.lm_prefill(p, cfg, batch["tokens"], max_len,
                            vis_embed=batch.get("vis_embed"))

    return ModelAPI(
        cfg=cfg,
        init=lambda key: T.init_lm(key, cfg),
        loss=lambda p, batch, remat="none", ep_exchange=None: T.lm_loss(
            p, cfg, batch, remat=remat, ep_exchange=ep_exchange),
        prefill=_prefill,
        decode=lambda p, tok, cache, pos: T.lm_decode(p, cfg, tok, cache, pos),
        init_cache=lambda p, b, s: T.init_cache(p, cfg, b, s),
    )
