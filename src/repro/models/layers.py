"""Shared neural-net layers: norms, RoPE, flash attention, SwiGLU, MoE.

Functional style: ``init_*`` builds param subtrees (plain dicts of
jnp arrays), ``apply`` functions are pure. Layer params are stacked on a
leading layer axis by the model builders and consumed via ``lax.scan``.

Sharding is communicated through *logical* activation hints
(:mod:`repro.parallel.hints`) so the layers never hard-code mesh axes and
run unchanged on a single CPU device.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from .config import ModelConfig, MoEConfig
from repro.parallel.hints import constrain


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(x: jnp.ndarray, p, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(x: jnp.ndarray, p, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def mask_padded_vocab(logits: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """-inf the padding columns of a padded-vocab logit tensor."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(col < cfg.vocab, logits, -1e30)


# ----------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------

def rope_frequencies(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (...,S,1,hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Initializers
# ----------------------------------------------------------------------

def dense_init(key, shape, in_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.uniform(key, shape, jnp.float32, -scale, scale)
            .astype(dtype))


# ----------------------------------------------------------------------
# Attention (GQA, optional QKV bias, flash-style blockwise softmax)
# ----------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False):
    D, hd = cfg.d_model, cfg.hd
    H, KV = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * hd), D, dt),
        "wk": dense_init(ks[1], (D, KV * hd), D, dt),
        "wv": dense_init(ks[2], (D, KV * hd), D, dt),
        "wo": dense_init(ks[3], (H * hd, D), H * hd, dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KV * hd,), dt)
        p["bv"] = jnp.zeros((KV * hd,), dt)
    return p


def _project_qkv(x, p, cfg: ModelConfig, kv_input=None):
    """Returns q (B,S,H,hd) merged-head, k/v (B,Skv,KV,hd).

    q is constrained to head sharding here, while still bf16 — §Perf
    iteration 3: letting GSPMD reshard at RoPE's internal f32 reshape
    doubled the per-layer gather bytes."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    kv_x = x if kv_input is None else kv_input
    q = x @ p["wq"]
    k = kv_x @ p["wk"]
    v = kv_x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q.reshape(B, S, H, hd), (None, None, "tp", None))
    k = k.reshape(B, kv_x.shape[1], KV, hd)
    v = v.reshape(B, kv_x.shape[1], KV, hd)
    return q, k, v


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool, q_block: int, kv_block: int = 1024,
                    q_offset: int = 0) -> jnp.ndarray:
    """Blockwise online-softmax attention (the lax analogue of flash).

    q: (B, Sq, KV, rep, hd);  k, v: (B, Skv, KV, hd).
    Memory peak is O(bq * bk) per (batch, head) rather than O(Sq * Skv).
    ``q_offset`` positions q tokens at ``q_offset + i`` for causal masking
    (used by decode/prefill-with-cache paths).

    Perf note (§Perf iteration 1): KV heads are *expanded* to the full
    head count before the score einsums, so head_dim is the only
    contraction. With grouped (KV, rep) operands GSPMD sharded head_dim
    across model shards (4 KV heads cannot cover 16-way TP) and inserted
    a partial-sum all-reduce of the scores inside both flash loops —
    ~1.5 TB/device/step on qwen2-7b. Merged heads shard (unevenly) on the
    head axis instead: zero collectives inside the loops, one K/V head
    broadcast per layer.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = 1.0 / math.sqrt(hd)
    bq = min(q_block, Sq)
    bk = min(kv_block, Skv)
    nq, nk = -(-Sq // bq), -(-Skv // bk)
    qh = q
    kh = jnp.broadcast_to(k[:, :, :, None, :],
                          (B, Skv, KV, rep, hd)).reshape(B, Skv, H, hd)
    vh = jnp.broadcast_to(v[:, :, :, None, :],
                          (B, Skv, KV, rep, hd)).reshape(B, Skv, H, hd)
    kh = constrain(kh, (None, None, "tp", None))
    vh = constrain(vh, (None, None, "tp", None))
    # pad to block multiples
    qp = jnp.pad(qh, ((0, 0), (0, nq * bq - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(kh, ((0, 0), (0, nk * bk - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(vh, ((0, 0), (0, nk * bk - Skv), (0, 0), (0, 0)))
    qs = qp.reshape(B, nq, bq, H, hd).transpose(1, 0, 2, 3, 4)
    ks = kp.reshape(B, nk, bk, H, hd).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(B, nk, bk, H, hd).transpose(1, 0, 2, 3, 4)

    # Block indices ride in the scan *carries* and positions are built by
    # in-body iotas: a constant (arange) among the scan xs picks up a
    # plain replicated sharding annotation, which the 0.4.x partitioner
    # cannot carry through a partial-auto manual region (fatal
    # IsManualSubgroup check). Carry counters are annotation-free on
    # every JAX and numerically identical.
    @compat.checkpoint  # flash backward: recompute probs per q-block
    def q_step(qi, q_blk):  # instead of saving the O(Sq*Skv) attn matrix
        q_pos = q_offset + qi * bq + jnp.arange(bq)     # (bq,)

        def kv_step(carry, kv_blk):
            m, l, acc, ki = carry
            k_blk, v_blk = kv_blk
            kpos = ki * bk + jnp.arange(bk)             # (bk,)
            kval = kpos < Skv
            # (§Perf iteration 2 tried bf16 score emission here — wire
            # bytes were unchanged, the f32 resharding happens at the
            # layer level, not in this einsum's cotangents. Reverted.)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.broadcast_to(kval[None, :], (bq, bk))
            if causal:
                mask = mask & (q_pos[:, None] >= kpos[None, :])
            s = jnp.where(mask[None, None, :, :], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
            return (m_new, l_new, acc_new, ki + 1), None

        m0 = jnp.full((B, H, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        a0 = jnp.zeros((B, bq, H, hd), jnp.float32)
        (m, l, acc, _), _ = jax.lax.scan(
            kv_step, (m0, l0, a0, jnp.int32(0)), (ks, vs))
        l = jnp.maximum(l, 1e-30)
        out = acc / l.transpose(0, 2, 1)[..., None]
        return qi + 1, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, jnp.int32(0), qs)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * bq, H, hd)
    return out[:, :Sq]


def attention_train(x, p, cfg: ModelConfig, positions=None, causal=True,
                    kv_input=None):
    """Full self(/cross)-attention for training/prefill. x: (B,S,D)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(x, p, cfg, kv_input=kv_input)   # q (B,S,H,hd)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if kv_input is None:   # self-attention gets RoPE
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions[:, : k.shape[1]], cfg.rope_theta)
    o = flash_attention(q, k, v, causal=causal, q_block=cfg.q_block)
    o = o.reshape(B, S, -1)
    return o @ p["wo"], (k, v)


def attention_decode(x, p, cfg: ModelConfig, cache_k, cache_v, position,
                     rope: bool = True):
    """Single-token decode. x: (B,1,D); cache: (B,Skv,KV,hd).

    Softmax reduces over the (possibly sequence-sharded) cache axis; under
    GSPMD this lowers to the flash-decoding partial-max/-sum combine.
    """
    B = x.shape[0]
    KV, hd = cfg.n_kv_heads, cfg.hd
    q, k_new, v_new = _project_qkv(x, p, cfg)              # q (B,1,H,hd)
    if rope:
        pos = jnp.full((B, 1), position)
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    q = q.reshape(B, 1, KV, cfg.n_heads // KV, hd)
    # in-place cache update at `position`
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), position, 1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), position, 1)
    s = jnp.einsum("bqgrd,bkgd->bgrk", q, cache_k,
                   preferred_element_type=jnp.float32)  # Sq=1 contracts away
    s = s * (1.0 / math.sqrt(hd))
    valid = jnp.arange(cache_k.shape[1]) <= position
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", w.astype(cache_v.dtype), cache_v,
                   preferred_element_type=jnp.float32)
    o = o.astype(x.dtype).reshape(B, 1, -1)
    return o @ p["wo"], cache_k, cache_v


def attention_cross_decode(x, p, cfg: ModelConfig, enc_k, enc_v):
    """Cross-attention for decode: static encoder KV, no cache update."""
    B = x.shape[0]
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_kv_heads,
                              cfg.n_heads // cfg.n_kv_heads, hd)
    s = jnp.einsum("bqgrd,bkgd->bgrk", q, enc_k,
                   preferred_element_type=jnp.float32) * (1.0 / math.sqrt(hd))
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", w.astype(enc_v.dtype), enc_v,
                   preferred_element_type=jnp.float32)
    return (o.astype(x.dtype).reshape(B, 1, -1)) @ p["wo"]


# ----------------------------------------------------------------------
# Dense SwiGLU / GELU MLPs
# ----------------------------------------------------------------------

def init_mlp(key, d: int, f: int, dtype, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, f), d, dtype),
         "w_down": dense_init(ks[1], (f, d), f, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d, f), d, dtype)
    return p


def mlp(x, p):
    h = x @ p["w_up"]
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, ("dp", None, "tp"))
    return h @ p["w_down"]


# ----------------------------------------------------------------------
# Mixture of Experts (capacity-based gather dispatch, EP-shardable)
# ----------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    D, f = cfg.d_model, m.expert_d_ff
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, m.num_experts), D, jnp.float32),
        "we_gate": dense_init(ks[1], (m.num_experts, D, f), D, dt),
        "we_up": dense_init(ks[2], (m.num_experts, D, f), D, dt),
        "we_down": dense_init(ks[3], (m.num_experts, f, D), f, dt),
    }
    if m.shared_experts:
        p["shared"] = init_mlp(ks[4], D, m.shared_experts * f, dt)
    return p


def moe_ffn(x: jnp.ndarray, p, m: MoEConfig,
            capacity_factor: Optional[float] = None,
            ep_exchange=None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (T, D) tokens -> (out (T, D), aux_loss scalar).

    Dropping MoE: tokens are routed to ``top_k`` experts; each expert has a
    static capacity C. Dispatch/combine are gathers/scatter-adds keyed by a
    sorted slot assignment, so the expert einsums see a dense (E, C, D)
    tensor shardable on the expert axis (EP).

    ``ep_exchange`` (PR 8): an all-to-all exchange from
    :func:`repro.core.aggregators.make_exchange`, usable only inside a
    manual region where its EP axes are bound.  When set, the combine
    runs the expert-parallel wire: each EP rank scatter-adds only *its
    own expert group's* contributions (experts ``rank * ceil(E/W) ..``),
    cuts that partial output into ``W`` token blocks, and the exchange
    merges block ``r`` of every rank's partial at rank ``r`` — on the
    compressed wire the sum happens homomorphically in the sketch while
    in flight.  An ``all_gather`` of the merged blocks restores the full
    ``(T, D)`` output.  Mathematically identical to the local combine
    (every expert contribution added exactly once); float summation
    order differs, so train-level parity is allclose, not bitwise.

    The wire carries the forward value only; the *gradient* routes
    through the local combine (``local + stop_gradient(wire - local)``).
    The two are the same linear map of ``y``, so the local vjp is exact
    — and it is the only replica-consistent one in the regime the train
    step enables the wire in (full-manual regions, where expert weights
    enter replicated over the EP axes: every replica must see the
    full-slot gradient, not its group's slice scaled by the all_gather
    transpose's cross-replica sum).
    """
    T, D = x.shape
    E, K = m.num_experts, m.top_k
    cf = capacity_factor if capacity_factor is not None else m.capacity_factor
    C = max(1, int(math.ceil(T * K * cf / E)))

    logits = (x.astype(jnp.float32) @ p["router"])            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, K)                          # (T, K)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)

    e_flat = idx.reshape(-1)                                  # (T*K,)
    t_flat = jnp.repeat(jnp.arange(T), K)
    w_flat = w.reshape(-1)
    order = jnp.argsort(e_flat)                               # stable
    e_s, t_s, w_s = e_flat[order], t_flat[order], w_flat[order]
    counts = jnp.bincount(e_s, length=E)                      # (E,)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * K) - starts[e_s]
    keep = rank < C
    slot = jnp.where(keep, e_s * C + rank, E * C)             # E*C = trash slot

    gather_idx = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        t_s.astype(jnp.int32), mode="drop")[: E * C]
    slot_w = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, w_s, 0.0), mode="drop")[: E * C]

    xp = jnp.concatenate([x, jnp.zeros((1, D), x.dtype)], axis=0)
    xg = xp[gather_idx].reshape(E, C, D)
    xg = constrain(xg, ("ep", None, None))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, p["we_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xg, p["we_up"])
    h = constrain(h, ("ep", None, None))
    # §Perf iteration 4 (kimi): pin the expert *output* to EP sharding
    # too — without it GSPMD replicated the expert compute path over the
    # EP axis and paid a partial-sum all-reduce of every expert weight
    # gradient (~12 TB/device/step at 1T params).
    y = constrain(jnp.einsum("ecf,efd->ecd", h, p["we_down"]),
                  ("ep", None, None)).reshape(E * C, D)

    out = jnp.zeros((T + 1, D), jnp.float32).at[gather_idx].add(
        y.astype(jnp.float32) * slot_w[:, None])[:T]
    if ep_exchange is not None:
        from repro.core.collectives import linear_rank  # late: jax-heavy
        W = ep_exchange.workers
        rank = linear_rank(ep_exchange.ep_axes)
        group_size = -(-E // W)           # experts per EP rank group
        slot_expert = jnp.arange(E * C) // C
        mine = (slot_expert // group_size) == rank
        # partial combine: only this rank's expert group lands; other
        # groups' slots scatter to the drop row
        safe_idx = jnp.where(mine, gather_idx, T)
        partial = jnp.zeros((T + 1, D), jnp.float32).at[safe_idx].add(
            y.astype(jnp.float32) * slot_w[:, None])[:T]
        T_blk = -(-T // W)
        payload = jnp.pad(partial, ((0, W * T_blk - T), (0, 0))
                          ).reshape(W, T_blk, D)
        merged = ep_exchange(payload)     # (T_blk, D): my block, combined
        full = jax.lax.all_gather(merged, tuple(ep_exchange.ep_axes),
                                  axis=0, tiled=False)
        wire = full.reshape(W * T_blk, D)[:T]
        # wire value forward, local-combine vjp backward (see docstring);
        # when the wire is exact (W=1, or dyadic payloads) the correction
        # term is exactly zero and `out` stays bitwise the local combine
        out = out + jax.lax.stop_gradient(wire - out)
    out = out.astype(x.dtype)

    if m.shared_experts:
        out = out + mlp(x, p["shared"])

    # Switch-style load-balance aux loss.
    frac = counts.astype(jnp.float32) / jnp.maximum(T * K, 1)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mean_prob)
    return out, aux
